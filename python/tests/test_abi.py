"""Cross-layer ABI guarantees: the contracts the Rust side relies on.

These tests pin the properties `rust/src/runtime` and the coordinator
assume — if any of them breaks, the Rust integration tests fail at a much
later (and more confusing) stage, so they are asserted here first.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_gemm_artifact_is_a_one_tuple():
    # rust PjrtGemm unwraps exactly one output.
    out = model.gemm_fn(jnp.ones((4, 4), jnp.float32), jnp.ones((4, 4), jnp.float32))
    assert isinstance(out, tuple) and len(out) == 1


def test_grad_output_arity_and_shapes_match_params():
    sizes = (6, 8, 3)
    params = model.init_params(jax.random.PRNGKey(0), sizes)
    x = jnp.zeros((4, 6), jnp.float32)
    y = jax.nn.one_hot(jnp.zeros(4, jnp.int32), 3, dtype=jnp.float32)
    out = model.grad_fn(*params, x, y)
    # (loss, dW0, db0, dW1, db1) — same order and shapes as the inputs.
    assert len(out) == 1 + len(params)
    assert out[0].shape == ()
    for p, g in zip(params, out[1:]):
        assert p.shape == g.shape
        assert g.dtype == jnp.float32


def test_row_major_layout_of_literals():
    # The Rust Tensor<->Literal bridge assumes row-major flattening: the
    # HLO parameter for a (2,3) array must consume values in C order.
    a = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    b = jnp.eye(3, dtype=jnp.float32)
    (c,) = model.gemm_fn(a, b)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a))
    assert np.asarray(a).flags["C_CONTIGUOUS"]


def test_hlo_entry_signature_matches_manifest_order():
    # Parameter count and shapes in the HLO text must equal the manifest's
    # `inputs=` field, in order.
    sizes = (6, 8, 3)
    pshapes = []
    for (w, b) in model.param_shapes(sizes):
        pshapes.extend([_spec(w), _spec(b)])
    in_specs = pshapes + [_spec((4, 6)), _spec((4, 3))]
    lowered = jax.jit(model.grad_fn).lower(*in_specs)
    text = aot.to_hlo_text(lowered)
    for i in range(len(in_specs)):
        assert f"parameter({i})" in text, f"missing parameter({i})"
    assert f"parameter({len(in_specs)})" not in text


def test_losses_are_finite_for_extreme_inputs():
    # The coordinator feeds raw synthetic data; the loss must stay finite
    # for large-magnitude inputs (log-softmax stability).
    sizes = (4, 6, 2)
    params = model.init_params(jax.random.PRNGKey(1), sizes)
    x = jnp.full((8, 4), 1e4, jnp.float32)
    y = jax.nn.one_hot(jnp.zeros(8, jnp.int32), 2, dtype=jnp.float32)
    loss = model.loss_fn(params, x, y)
    assert bool(jnp.isfinite(loss)), f"loss blew up: {loss}"


def test_artifact_flops_fields_are_consistent():
    arts = {a.name: a for a in aot.build_artifacts()}
    for n in aot.GEMM_SIZES:
        assert arts[f"gemm_{n}"].flops == 2.0 * n**3
    assert arts["mlp_grad"].flops == pytest.approx(3 * arts["mlp_forward"].flops)


def test_manifest_row_format_is_stable():
    art = aot.Artifact(
        name="t", fn=model.gemm_fn, in_specs=[_spec((2, 2)), _spec((2, 2))], flops=16.0
    )
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        row = art.lower_and_write(d)
    fields = dict(kv.split("=", 1) for kv in row.split(" "))
    assert set(fields) == {"name", "file", "inputs", "flops"}
    assert fields["inputs"] == "f32[2x2],f32[2x2]"
