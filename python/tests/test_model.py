"""L2 correctness: the MLP graphs the Rust coordinator consumes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

SMALL = (12, 16, 10)  # fast layer sizes for gradient checks


def make_batch(key, batch, sizes):
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (batch, sizes[0]), jnp.float32)
    labels = jax.random.randint(ky, (batch,), 0, sizes[-1])
    y = jax.nn.one_hot(labels, sizes[-1], dtype=jnp.float32)
    return x, y


def test_param_shapes_and_count():
    shapes = model.param_shapes((4, 8, 2))
    assert shapes == [((4, 8), (8,)), ((8, 2), (2,))]
    assert model.param_count((4, 8, 2)) == 4 * 8 + 8 + 8 * 2 + 2


def test_default_network_is_about_a_million_params():
    # Paper section 4: "more than one million adjustable parameters";
    # our default is the same order of magnitude.
    n = model.param_count()
    assert 5e5 < n < 2e6


def test_init_params_shapes():
    params = model.init_params(jax.random.PRNGKey(0), SMALL)
    assert len(params) == 2 * (len(SMALL) - 1)
    assert params[0].shape == (12, 16)
    assert params[1].shape == (16,)
    assert all(p.dtype == jnp.float32 for p in params)


def test_forward_shape_and_finiteness():
    params = model.init_params(jax.random.PRNGKey(1), SMALL)
    x, _ = make_batch(jax.random.PRNGKey(2), 8, SMALL)
    logits = model.forward(params, x)
    assert logits.shape == (8, SMALL[-1])
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_is_log_nclasses_at_init_scale():
    # With random init, softmax CE should be near log(n_classes).
    params = model.init_params(jax.random.PRNGKey(3), SMALL)
    x, y = make_batch(jax.random.PRNGKey(4), 32, SMALL)
    loss = float(model.loss_fn(params, x, y))
    assert abs(loss - np.log(SMALL[-1])) < 0.5


def test_grad_matches_finite_differences():
    params = model.init_params(jax.random.PRNGKey(5), SMALL)
    x, y = make_batch(jax.random.PRNGKey(6), 4, SMALL)
    out = model.grad_fn(*params, x, y)
    grads = out[1:]
    # Check a handful of coordinates of W0 and b1 by central differences.
    eps = 1e-3
    rng = np.random.RandomState(0)
    for (pi, gi) in [(0, 0), (1, 1), (2, 2)]:
        p = np.asarray(params[pi])
        flat_idx = rng.randint(p.size)
        idx = np.unravel_index(flat_idx, p.shape)
        bump = np.zeros_like(p)
        bump[idx] = eps
        plus = list(params)
        plus[pi] = params[pi] + bump
        minus = list(params)
        minus[pi] = params[pi] - bump
        fd = (float(model.loss_fn(plus, x, y)) - float(model.loss_fn(minus, x, y))) / (2 * eps)
        got = float(np.asarray(grads[gi])[idx])
        assert got == pytest.approx(fd, rel=0.05, abs=1e-3), f"param {pi} idx {idx}"


def test_training_reduces_loss():
    params = model.init_params(jax.random.PRNGKey(7), SMALL)
    x, y = make_batch(jax.random.PRNGKey(8), 64, SMALL)
    first = None
    last = None
    for _ in range(30):
        params, loss = model.reference_train_step(params, x, y, lr=0.5)
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < first * 0.7, f"loss did not fall: {first} -> {last}"


def test_grad_fn_abi_matches_value_and_grad():
    """The artifact ABI (flat in, (loss, *grads) out) must equal jax's own
    value_and_grad on the structured loss."""
    params = model.init_params(jax.random.PRNGKey(9), SMALL)
    x, y = make_batch(jax.random.PRNGKey(10), 8, SMALL)
    out = model.grad_fn(*params, x, y)
    loss2, grads2 = jax.value_and_grad(model.loss_fn)(params, x, y)
    assert float(out[0]) == pytest.approx(float(loss2), rel=1e-5)
    assert len(out) - 1 == len(grads2)
    for g1, g2 in zip(out[1:], grads2):
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-6)


def test_train_step_flops_formula():
    # 2*batch*in*hidden per layer forward, x3 for fwd+bwd.
    flops = model.train_step_flops((10, 20, 5), batch=4)
    fwd = 2 * 4 * 10 * 20 + 2 * 4 * 20 * 5
    assert flops == 3.0 * fwd
