"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and tile sizes; numpy.allclose-style comparison
with f32 tolerances. This is the core correctness signal for the kernel
that every artifact embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.emmerald import (
    emmerald_matmul,
    emmerald_sgemm,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.naive import naive_matmul
from compile.kernels.ref import ref_matmul, ref_sgemm

DIMS = st.integers(min_value=1, max_value=96)
TILES = st.sampled_from([8, 16, 32, 128])


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def assert_close(got, want, what=""):
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-5, err_msg=what
    )


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, k=DIMS, bm=TILES, bn=TILES, bk=TILES, seed=st.integers(0, 2**31))
def test_emmerald_matches_ref_over_shapes_and_tiles(m, n, k, bm, bn, bk, seed):
    ka, kb = keys(seed, 2)
    a, b = rand(ka, (m, k)), rand(kb, (k, n))
    got = emmerald_matmul(a, b, bm=bm, bn=bn, bk=bk)
    assert got.shape == (m, n)
    assert got.dtype == jnp.float32
    assert_close(got, ref_matmul(a, b), f"m={m} n={n} k={k} tiles=({bm},{bn},{bk})")


@settings(max_examples=15, deadline=None)
@given(m=DIMS, n=DIMS, k=DIMS, seed=st.integers(0, 2**31))
def test_naive_pallas_matches_ref(m, n, k, seed):
    ka, kb = keys(seed, 2)
    a, b = rand(ka, (m, k)), rand(kb, (k, n))
    assert_close(naive_matmul(a, b), ref_matmul(a, b))


@settings(max_examples=15, deadline=None)
@given(
    m=DIMS,
    n=DIMS,
    k=DIMS,
    alpha=st.floats(-2, 2, allow_nan=False, width=32),
    beta=st.floats(-2, 2, allow_nan=False, width=32),
    transa=st.booleans(),
    transb=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_full_sgemm_semantics(m, n, k, alpha, beta, transa, transb, seed):
    ka, kb, kc = keys(seed, 3)
    a = rand(ka, (k, m) if transa else (m, k))
    b = rand(kb, (n, k) if transb else (k, n))
    c = rand(kc, (m, n))
    got = emmerald_sgemm(a, b, c, alpha, beta, transa=transa, transb=transb, bm=32, bn=32, bk=32)
    want = ref_sgemm(a, b, c, alpha, beta, transa=transa, transb=transb)
    assert_close(got, want)


def test_exact_tile_divisible_case():
    """No padding path: dims are exact multiples of tiles."""
    ka, kb = keys(7, 2)
    a, b = rand(ka, (256, 128)), rand(kb, (128, 384))
    assert_close(emmerald_matmul(a, b), ref_matmul(a, b))


def test_single_element():
    a = jnp.asarray([[2.0]], jnp.float32)
    b = jnp.asarray([[3.0]], jnp.float32)
    assert float(emmerald_matmul(a, b)[0, 0]) == pytest.approx(6.0)


def test_identity():
    eye = jnp.eye(40, dtype=jnp.float32)
    x = rand(jax.random.PRNGKey(3), (40, 17))
    assert_close(emmerald_matmul(eye, x, bm=16, bn=16, bk=16), x)


def test_paper_peak_size_320():
    """The paper's peak configuration m=n=k=320."""
    ka, kb = keys(320, 2)
    a, b = rand(ka, (320, 320)), rand(kb, (320, 320))
    assert_close(emmerald_matmul(a, b), ref_matmul(a, b))


def test_rejects_bad_inner_dims():
    a = jnp.zeros((4, 5), jnp.float32)
    b = jnp.zeros((6, 3), jnp.float32)
    with pytest.raises(AssertionError):
        emmerald_matmul(a, b)


def test_rejects_non_f32():
    a = jnp.zeros((4, 4), jnp.float16)
    b = jnp.zeros((4, 4), jnp.float16)
    with pytest.raises(AssertionError):
        emmerald_matmul(a, b)


# ---------------------------------------------------------------------------
# Structure diagnostics (the TPU-side perf story; interpret mode gives no
# wallclock, so these check the *estimates* used in DESIGN.md section Perf).
# ---------------------------------------------------------------------------
def test_vmem_footprint_fits_budget():
    # Default tiles must use well under a 16 MiB VMEM.
    assert vmem_footprint_bytes(128, 128, 128) < 1 << 20


def test_mxu_utilization_exact_when_divisible():
    assert mxu_utilization_estimate(256, 256, 256, 128, 128, 128) == 1.0


def test_mxu_utilization_penalises_padding():
    u = mxu_utilization_estimate(129, 129, 129, 128, 128, 128)
    assert 0.1 < u < 0.6  # 129 pads to 256 on all three axes → 1/8 + ε


def test_gradients_flow_through_kernel():
    """jax.grad through the pallas call (custom VJP) is numerically right."""
    from compile.model import k_matmul

    ka, kb = keys(11, 2)
    a, b = rand(ka, (8, 6)), rand(kb, (6, 5))

    def f(a, b):
        return jnp.sum(k_matmul(a, b) ** 2)

    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    # d/dA sum((AB)^2) = 2 (AB) B^T ; d/dB = 2 A^T (AB)
    want_ga = 2.0 * (a @ b) @ b.T
    want_gb = 2.0 * a.T @ (a @ b)
    assert_close(ga, want_ga)
    assert_close(gb, want_gb)
