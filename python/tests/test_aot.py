"""AOT pipeline: lowering produces loadable HLO text + a parseable manifest."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_to_hlo_text_structure():
    lowered = jax.jit(model.gemm_fn).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    # HLO text must have an ENTRY computation and f32 parameters; the
    # xla crate's text parser keys off this structure.
    assert "ENTRY" in text
    assert "f32[16,16]" in text
    assert "parameter(0)" in text


def test_artifact_lower_and_write(tmp_path):
    art = aot.Artifact(
        name="gemm_test16",
        fn=model.gemm_fn,
        in_specs=[
            jax.ShapeDtypeStruct((16, 16), jnp.float32),
            jax.ShapeDtypeStruct((16, 16), jnp.float32),
        ],
        flops=2.0 * 16**3,
        extra="kernel:emmerald-pallas",
    )
    row = art.lower_and_write(str(tmp_path))
    assert (tmp_path / "gemm_test16.hlo.txt").exists()
    assert "name=gemm_test16" in row
    assert "inputs=f32[16x16],f32[16x16]" in row
    assert "flops=8192" in row


def test_build_artifacts_inventory():
    arts = aot.build_artifacts()
    names = [a.name for a in arts]
    # Every benchmark size plus the naive comparator and both MLP graphs.
    for n in aot.GEMM_SIZES:
        assert f"gemm_{n}" in names
    assert "gemm_naive_320" in names
    assert "mlp_forward" in names
    assert "mlp_grad" in names
    # MLP grad inputs: params + x + y.
    grad = next(a for a in arts if a.name == "mlp_grad")
    n_params = 2 * (len(model.LAYER_SIZES) - 1)
    assert len(grad.in_specs) == n_params + 2


def test_main_only_subset(tmp_path):
    # --only rebuilds one artifact without touching the manifest.
    aot.main(["--out-dir", str(tmp_path), "--only", "gemm_64"])
    assert (tmp_path / "gemm_64.hlo.txt").exists()
    assert not (tmp_path / aot.MANIFEST_NAME).exists()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_existing_manifest_is_parseable():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.txt")
    with open(path) as f:
        rows = [l.strip() for l in f if l.strip() and not l.startswith("#")]
    assert len(rows) >= 8
    for row in rows:
        fields = dict(kv.split("=", 1) for kv in row.split(" "))
        assert "name" in fields and "file" in fields and "inputs" in fields
        assert float(fields["flops"]) > 0
        for shape in fields["inputs"].split(","):
            assert shape.startswith("f32[")
