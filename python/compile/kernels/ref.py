"""Pure-jnp SGEMM oracle.

The correctness reference for the Pallas kernels: a direct transcription of
the Level-3 BLAS SGEMM contract with no tiling, no Pallas, no cleverness.
Every kernel test asserts allclose against this.
"""

import jax.numpy as jnp


def ref_matmul(a, b):
    """Plain C = A @ B in f32."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def ref_sgemm(a, b, c, alpha=1.0, beta=0.0, transa=False, transb=False):
    """Full SGEMM semantics: C' = alpha * op(A) op(B) + beta * C.

    Mirrors the Rust `blas::sgemm` contract (row-major logical matrices;
    transposition is logical).
    """
    opa = a.T if transa else a
    opb = b.T if transb else b
    prod = jnp.matmul(opa, opb, preferred_element_type=jnp.float32)
    return alpha * prod + beta * c
