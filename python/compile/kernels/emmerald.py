"""L1: the Emmerald GEMM as a Pallas kernel — the TPU rethink.

The paper's insight is *maximise register-level reuse per memory access and
block for the fastest memory*. On the PIII that meant: five dot products
accumulated in XMM registers, a 336x5 panel of B re-buffered into the 16 KB
L1, rows of A streamed with prefetch. The TPU mapping (DESIGN.md
section Hardware-Adaptation):

* XMM accumulators  -> a VMEM accumulator tile held across the k-grid
  (the output block is revisited with the k index innermost).
* 4-wide mulps/addps dot products -> the MXU systolic matmul over
  (bm x bk) @ (bk x bn) tiles.
* L1 re-buffered B' panel -> BlockSpec-staged VMEM tiles; the index maps
  express the same HBM->fast-memory schedule the paper hand-coded.
* SSE prefetch of A' -> Pallas grid pipelining (tile N+1 is copied while
  tile N multiplies).

The kernel is lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers the same schedule to
plain HLO that runs anywhere (see /opt/xla-example/README.md). Real-TPU
performance is therefore *estimated*, not measured — see EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-aligned (128 lanes) and VMEM-sized. With f32,
# a (128,128) A-tile + (128,128) B-tile + (128,128) accumulator is 192 KiB,
# far under the ~16 MiB VMEM budget; production would widen bn/bk, but the
# structure is what matters here (interpret mode gives no TPU timing).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(a_ref, b_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: o[i,j] (+)= a[i,k] @ b[k,j].

    The k axis is the innermost grid dimension, so the (i, j) output block
    stays resident (the VMEM analogue of the paper's register
    accumulation) while k-tiles stream through.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, rows, cols):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def emmerald_matmul(
    a,
    b,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
):
    """C = A @ B with Emmerald-style blocked accumulation.

    Shapes need not be multiples of the tile sizes; operands are
    zero-padded to the grid and the result sliced back (zero padding is
    exact for matmul).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims disagree: {k} vs {k2}"
    assert a.dtype == b.dtype == jnp.float32, "SGEMM is f32"

    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    gm, gn, gk = pl.cdiv(m, bm_), pl.cdiv(n, bn_), pl.cdiv(k, bk_)
    a_p = _pad_to(a, gm * bm_, gk * bk_)
    b_p = _pad_to(b, gk * bk_, gn * bn_)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm_, gn * bn_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def emmerald_sgemm(
    a,
    b,
    c,
    alpha=1.0,
    beta=0.0,
    *,
    transa: bool = False,
    transb: bool = False,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
):
    """Full SGEMM: C' = alpha * op(A) op(B) + beta * C via the kernel."""
    opa = a.T if transa else a
    opb = b.T if transb else b
    prod = emmerald_matmul(opa, opb, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return alpha * prod + beta * c


def vmem_footprint_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4):
    """Estimated VMEM bytes for one grid step (A-tile + B-tile + C-tile),
    x2 for Pallas double-buffering of the streamed inputs.

    Used by DESIGN.md section Perf to justify tile choices in lieu of real
    TPU timing.
    """
    a_tile = bm * bk * dtype_bytes
    b_tile = bk * bn * dtype_bytes
    c_tile = bm * bn * dtype_bytes
    return 2 * (a_tile + b_tile) + c_tile


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int, bn: int, bk: int):
    """Fraction of MXU-issued flops that are useful (non-padding), i.e.
    2mnk / (2 * ceil-padded volume). 1.0 when tiles divide the problem."""
    gm, gn, gk = -(-m // bm), -(-n // bn), -(-k // bk)
    useful = 2.0 * m * n * k
    issued = 2.0 * (gm * bm) * (gn * bn) * (gk * bk)
    return useful / issued
