"""The naive comparator as a Pallas kernel: one program, no tiling.

This is the Pallas analogue of the paper's three-loop multiply: the entire
operands are brought into (V)MEM as a single block and multiplied in one
step. On a real TPU this caps the problem at what fits VMEM and loses all
pipelining — exactly the "no blocking" baseline the paper draws in Fig. 2.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _naive_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def naive_matmul(a, b, *, interpret: bool = True):
    """C = A @ B with a single un-tiled Pallas program."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims disagree: {k} vs {k2}"
    return pl.pallas_call(
        _naive_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
