"""L2: the JAX compute graphs that get AOT-lowered for the Rust runtime.

Two families:

* ``gemm_fn`` — plain C = A @ B through the Emmerald Pallas kernel; one
  artifact per benchmark size.
* the MLP — the paper's section 4 application (ref [1]: ultra-large-scale
  neural-network training with Emmerald as the kernel). Forward, loss and
  gradient graphs all funnel their matmuls through the same Pallas kernel,
  so the full training step exercises the L1 kernel end-to-end.

Everything here runs at *build* time only; the Rust coordinator executes
the lowered HLO through PJRT.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.emmerald import emmerald_matmul

# The paper's application trains networks with "more than one million
# adjustable parameters" (section 4). These sizes give ~0.86M.
LAYER_SIZES = (256, 768, 768, 10)
BATCH = 64
DEFAULT_LR = 0.05


# --------------------------------------------------------------------------
# Kernel-backed matmul with a custom VJP so jax.grad differentiates through
# the Pallas call (both tangent matmuls also go through the kernel — the
# backward pass is Emmerald all the way down).
# --------------------------------------------------------------------------
@jax.custom_vjp
def k_matmul(a, b):
    """C = A @ B through the Emmerald Pallas kernel."""
    return emmerald_matmul(a, b)


def _k_matmul_fwd(a, b):
    return k_matmul(a, b), (a, b)


def _k_matmul_bwd(res, g):
    a, b = res
    return emmerald_matmul(g, b.T), emmerald_matmul(a.T, g)


k_matmul.defvjp(_k_matmul_fwd, _k_matmul_bwd)


# --------------------------------------------------------------------------
# GEMM artifact builders
# --------------------------------------------------------------------------
def gemm_fn(a, b):
    """The artifact body for gemm_<n>: a 1-tuple (rust unwraps to_tuple1)."""
    return (emmerald_matmul(a, b),)


# --------------------------------------------------------------------------
# MLP (the section-4 application)
# --------------------------------------------------------------------------
def param_shapes(sizes=LAYER_SIZES):
    """[(W0, b0), (W1, b1), ...] shapes for the given layer sizes."""
    shapes = []
    for fan_in, fan_out in zip(sizes[:-1], sizes[1:]):
        shapes.append(((fan_in, fan_out), (fan_out,)))
    return shapes


def param_count(sizes=LAYER_SIZES):
    """Total adjustable parameters."""
    return sum(w[0] * w[1] + b[0] for w, b in param_shapes(sizes))


def init_params(key, sizes=LAYER_SIZES):
    """Glorot-ish init, returned as the flat [W0, b0, W1, b1, ...] list
    used by the artifact ABI."""
    flat = []
    for (w_shape, b_shape) in param_shapes(sizes):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / (w_shape[0] + w_shape[1])).astype(jnp.float32)
        flat.append(jax.random.normal(sub, w_shape, jnp.float32) * scale)
        flat.append(jnp.zeros(b_shape, jnp.float32))
    return flat


def forward(flat_params, x):
    """Logits for a batch. tanh hidden activations (period-appropriate —
    ref [1] trained tanh networks), linear output layer."""
    h = x
    n_layers = len(flat_params) // 2
    for i in range(n_layers):
        w, b = flat_params[2 * i], flat_params[2 * i + 1]
        h = k_matmul(h, w) + b
        if i < n_layers - 1:
            h = jnp.tanh(h)
    return h


def loss_fn(flat_params, x, y_onehot):
    """Mean softmax cross-entropy against one-hot targets."""
    logits = forward(flat_params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def grad_fn(*args):
    """Artifact body for mlp_grad: (W0, b0, ..., x, y) -> (loss, dW0, db0, ...).

    SGD itself happens on the Rust side (the coordinator owns the
    parameters and the learning-rate schedule); this graph is pure
    compute, which keeps the artifact reusable for any optimiser.
    """
    flat_params = list(args[:-2])
    x, y = args[-2], args[-1]
    loss, grads = jax.value_and_grad(loss_fn)(flat_params, x, y)
    return (loss, *grads)


def forward_fn(*args):
    """Artifact body for mlp_forward: (W0, b0, ..., x) -> (logits,)."""
    flat_params = list(args[:-1])
    x = args[-1]
    return (forward(flat_params, x),)


def train_step_flops(sizes=LAYER_SIZES, batch=BATCH):
    """Flop estimate for one grad step: forward 2mnk per layer, backward
    approximately 2x forward (dX and dW matmuls)."""
    fwd = sum(2.0 * batch * fan_in * fan_out for fan_in, fan_out in zip(sizes[:-1], sizes[1:]))
    return 3.0 * fwd


@functools.partial(jax.jit, static_argnames=("lr",))
def reference_train_step(flat_params, x, y, lr=DEFAULT_LR):
    """Build-time reference: one SGD step entirely in JAX. Used by the
    python test-suite to validate the grad graph the Rust side consumes."""
    loss, grads = jax.value_and_grad(loss_fn)(flat_params, x, y)
    new_params = [p - lr * g for p, g in zip(flat_params, grads)]
    return new_params, loss
