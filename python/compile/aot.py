"""AOT lowering: jax/Pallas graphs -> artifacts/*.hlo.txt + manifest.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py there.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``make artifacts`` target). Python never runs after this step; the Rust
binary loads the text artifacts through PJRT.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.emmerald import emmerald_matmul  # noqa: F401  (re-export for tests)
from .kernels.naive import naive_matmul

GEMM_SIZES = (64, 128, 256, 320, 512)

MANIFEST_NAME = "manifest.txt"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(s) -> str:
    dims = "x".join(str(d) for d in s.shape)
    return f"f32[{dims}]" if dims else "f32[]"


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


class Artifact:
    """One lowered graph + its manifest row."""

    def __init__(self, name, fn, in_specs, flops, extra=""):
        self.name = name
        self.fn = fn
        self.in_specs = in_specs
        self.flops = flops
        self.extra = extra

    def lower_and_write(self, out_dir) -> str:
        lowered = jax.jit(self.fn).lower(*self.in_specs)
        text = to_hlo_text(lowered)
        fname = f"{self.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        inputs = ",".join(_shape_str(s) for s in self.in_specs)
        return (
            f"name={self.name} file={fname} inputs={inputs} "
            f"flops={self.flops:.0f}"
            + (f" extra={self.extra}" if self.extra else "")
        )


def build_artifacts():
    """The full artifact set (every graph the Rust side loads)."""
    arts = []

    # GEMM artifacts: one per benchmark size, Emmerald kernel.
    for n in GEMM_SIZES:
        arts.append(
            Artifact(
                name=f"gemm_{n}",
                fn=model.gemm_fn,
                in_specs=[_spec((n, n)), _spec((n, n))],
                flops=2.0 * n * n * n,
                extra="kernel:emmerald-pallas",
            )
        )

    # A naive (un-tiled) comparator at one size, for the PJRT bench.
    arts.append(
        Artifact(
            name="gemm_naive_320",
            fn=lambda a, b: (naive_matmul(a, b),),
            in_specs=[_spec((320, 320)), _spec((320, 320))],
            flops=2.0 * 320**3,
            extra="kernel:naive-pallas",
        )
    )

    # The MLP application (paper section 4).
    sizes = model.LAYER_SIZES
    batch = model.BATCH
    pshapes = []
    for (w, b) in model.param_shapes(sizes):
        pshapes.extend([_spec(w), _spec(b)])
    sizes_str = "-".join(str(s) for s in sizes)

    arts.append(
        Artifact(
            name="mlp_forward",
            fn=model.forward_fn,
            in_specs=pshapes + [_spec((batch, sizes[0]))],
            flops=model.train_step_flops(sizes, batch) / 3.0,
            extra=f"sizes:{sizes_str},batch:{batch},params:{model.param_count(sizes)}",
        )
    )
    arts.append(
        Artifact(
            name="mlp_grad",
            fn=model.grad_fn,
            in_specs=pshapes + [_spec((batch, sizes[0])), _spec((batch, sizes[-1]))],
            flops=model.train_step_flops(sizes, batch),
            extra=f"sizes:{sizes_str},batch:{batch},params:{model.param_count(sizes)}",
        )
    )
    return arts


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--only", default="", help="comma-separated artifact names to (re)build"
    )
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    only = set(filter(None, args.only.split(",")))

    rows = []
    for art in build_artifacts():
        if only and art.name not in only:
            continue
        row = art.lower_and_write(args.out_dir)
        rows.append(row)
        print(f"[aot] {row}", file=sys.stderr)

    # The manifest is written last so `make` sees a complete artifact set
    # or none (manifest.txt is the Makefile's stamp file).
    if not only:
        with open(os.path.join(args.out_dir, MANIFEST_NAME), "w") as f:
            f.write("# emmerald artifact manifest: name/file/inputs/flops[/extra]\n")
            f.write("\n".join(rows) + "\n")
        print(f"[aot] wrote {len(rows)} artifacts + {MANIFEST_NAME} to {args.out_dir}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
