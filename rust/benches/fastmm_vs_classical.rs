//! CI guard for the fast-matmul tier: the ⟨m,k,n⟩ recursion (Strassen–
//! Winograd / Laderman through `gemm/fastmm`) must beat the classical
//! parallel tile driver at 2048³ f32, or the sub-2MNK saving has been
//! eaten by scratch traffic, a broken fringe peel, or the recursion
//! falling off the pool.
//!
//! Effective MFlop/s is reported in *classic* (2mnk) terms on both
//! sides so the rates are directly comparable: the fast tier "wins"
//! exactly where its multiply saving outruns its extra passes over
//! memory. Hosts with fewer than 4 worker threads or without AVX2
//! skip-pass — below that the BFS fan-out has nobody to feed and the
//! base case is scalar, so the comparison means nothing.
//!
//! Emits `BENCH_fastmm.json` (GFLOP/s at 1024³ and 2048³) under
//! `target/bench-results/` so the perf trajectory is recorded run over
//! run. Exit code 1 on failure so `ci.sh` can gate on it.

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{sgemm_matrix, Backend, Matrix, Transpose};
use emmerald::gemm::dispatch::global_snapshot;
use emmerald::gemm::{ElementId, GemmContext, KernelId, ShapeClass};
use emmerald::util::testkit::assert_allclose;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let threads = GemmContext::global().threads();
    if !KernelId::Avx2Tile.available_for(ElementId::F32) {
        println!("SKIP-PASS: no AVX2+FMA — the fast tier would recurse onto a scalar base case");
        return;
    }
    if threads < 4 {
        println!(
            "SKIP-PASS: {threads} worker thread(s) — the BFS product fan-out needs >= 4 to beat row-slicing"
        );
        return;
    }

    let d = global_snapshot();
    let choice = d
        .config()
        .fastmm
        .choice(ElementId::F32, ShapeClass::Square)
        .unwrap_or_default();

    // Correctness before speed: the forced fast tier must agree with the
    // naive oracle at a size spanning a couple of recursion levels (384
    // over a 256 crossover splits once per axis; odd quadrants exercise
    // the fringe peel). Multi-level f32 error needs looser bars than the
    // flat kernels (~1 bit per ⟨2,2,2⟩ level).
    let s = 384;
    let a = Matrix::random(s, s, 11, -1.0, 1.0);
    let b = Matrix::random(s, s, 12, -1.0, 1.0);
    let mut got = Matrix::zeros(s, s);
    let mut want = Matrix::zeros(s, s);
    let ran = d.gemm_with(
        KernelId::FastMm,
        Transpose::No,
        Transpose::No,
        1.0,
        a.view(),
        b.view(),
        0.0,
        &mut got.view_mut(),
    );
    assert_eq!(ran, KernelId::FastMm, "forcing the fast tier degraded to {ran:?}");
    sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, 1.0, &a, &b, 0.0, &mut want)
        .unwrap();
    assert_allclose(got.data(), want.data(), 1e-2, 5e-3, "fastmm vs naive oracle at 384^3");

    let sizes: Vec<usize> = if quick { vec![512, 1024] } else { vec![1024, 2048] };
    let mut report = Report::new(
        "FASTMM — fast-matmul tier vs classical parallel tile (effective 2n^3 MFlop/s)",
        &["size", "kernel"],
    );
    let mut last_ratio = 0.0f64;
    for &n in &sizes {
        let a = Matrix::random(n, n, 1, -1.0, 1.0);
        let b = Matrix::random(n, n, 2, -1.0, 1.0);
        let classic = gemm_flops(n, n, n);

        let mut c = Matrix::zeros(n, n);
        let mut bench = Bencher::new(1, 3).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
        let r_classical = bench.run("parallel-tile", classic, || {
            d.gemm_with(
                KernelId::Parallel,
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c.view_mut(),
            );
        });
        report.add(&[n.to_string(), "parallel-tile".into()], r_classical.clone());

        let mut bench = Bencher::new(1, 3).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
        let r_fast = bench.run(choice.algo.name(), classic, || {
            d.gemm_with(
                KernelId::FastMm,
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c.view_mut(),
            );
        });
        report.add(&[n.to_string(), choice.algo.name().into()], r_fast.clone());

        last_ratio = r_fast.mflops() / r_classical.mflops();
        report.note(format!(
            "n={n}: fast/classical = {last_ratio:.2} ({:.2} vs {:.2} effective GFLOP/s, {} crossover {})",
            r_fast.mflops() / 1e3,
            r_classical.mflops() / 1e3,
            choice.algo.name(),
            choice.crossover,
        ));
    }
    report.note("Benson & Ballard: the hybrid DFS/BFS schedule should win at and above ~2048 on multicore; below the crossover the flat tile keeps the lead");
    report.emit("BENCH_fastmm");

    let top = *sizes.last().unwrap();
    if last_ratio < 1.0 {
        println!(
            "FAIL: fast tier below the classical parallel tile at {top}^3 (ratio {last_ratio:.2}) — the sub-2MNK saving has regressed"
        );
        std::process::exit(1);
    }
    println!("PASS: fast tier >= classical parallel tile at {top}^3 (ratio {last_ratio:.2})");
}
