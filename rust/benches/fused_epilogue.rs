//! Fused-epilogue guard: bias + activation applied inside the GEMM
//! writeback must not lose to the GEMM-then-separate-pass route at MLP
//! layer shapes (one traversal of `C` instead of two), and the
//! fused-im2col conv path must allocate strictly less transient memory
//! than the materialised im2col lowering (that is the whole point of
//! packing patches on the fly). Exit code 1 on regression so `ci.sh`
//! gates on it; hosts without AVX2+FMA skip-pass like `tile_vs_dot`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{Backend, GemmContext, Matrix};
use emmerald::gemm::{Activation, DispatchConfig, Epilogue, KernelId};
use emmerald::nn::conv::Conv2d;

/// Counting allocator: tracks live bytes and the high-water mark, so the
/// conv comparison can measure *peak transient allocation* per call.
struct CountingAlloc;

static CURRENT: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let cur = CURRENT.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(cur, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        CURRENT.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Peak bytes allocated above the baseline while running `f`.
fn peak_alloc_during(f: impl FnOnce()) -> usize {
    let base = CURRENT.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    f();
    PEAK.load(Ordering::Relaxed).saturating_sub(base)
}

fn main() {
    if !KernelId::Avx2Tile.available() {
        println!("SKIP-PASS: no AVX2+FMA — fused-epilogue guard needs the tile tier");
        return;
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let mut failed = false;

    // ---- MLP layer shapes: fused vs GEMM + separate bias/tanh pass ----
    let ctx = GemmContext::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });
    let shapes: &[(usize, usize, usize)] =
        if quick { &[(256, 768, 768)] } else { &[(256, 768, 256), (256, 768, 768), (256, 10, 768)] };
    let mut report = Report::new(
        "FUSED EPILOGUE — bias+tanh in the writeback vs separate pass (serial GFLOP/s)",
        &["m", "n", "k", "route"],
    );
    for &(m, n, k) in shapes {
        let a = Matrix::random(m, k, 1, -1.0, 1.0);
        let b = Matrix::random(k, n, 2, -1.0, 1.0);
        let bias: Vec<f32> = (0..n).map(|j| ((j % 13) as f32 - 6.0) / 6.0).collect();
        let ep = Epilogue::new().bias_row(bias).activation(Activation::Tanh);
        let flops = gemm_flops(m, n, k);

        let fused_plan = ctx.gemm().epilogue(ep.clone()).plan(m, n, k).unwrap();
        let plain_plan = ctx.gemm().plan(m, n, k).unwrap();
        let mut c = Matrix::zeros(m, n);

        let mut bench = Bencher::new(1, 5).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
        let two_pass = bench.run("two-pass", flops, || {
            plain_plan.run(a.data(), b.data(), c.data_mut()).unwrap();
            ep.apply(&mut c.view_mut(), 0, 0);
        });
        let mut bench = Bencher::new(1, 5).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
        let fused = bench.run("fused", flops, || {
            fused_plan.run(a.data(), b.data(), c.data_mut()).unwrap();
        });

        println!(
            "{m}x{n}x{k}  two-pass {:>8.2}  fused {:>8.2} GFLOP/s  (fused/two-pass {:.2}x)",
            two_pass.mflops() / 1000.0,
            fused.mflops() / 1000.0,
            fused.mflops() / two_pass.mflops(),
        );
        report.add(&[m.to_string(), n.to_string(), k.to_string(), "two-pass".into()], two_pass.clone());
        report.add(&[m.to_string(), n.to_string(), k.to_string(), "fused".into()], fused.clone());
        // 5% noise margin: fused must not lose to doing strictly more work.
        if fused.mflops() < 0.95 * two_pass.mflops() {
            eprintln!(
                "FAIL: fused epilogue ({:.1} MFlop/s) lost to the two-pass route ({:.1} MFlop/s) at {m}x{n}x{k}",
                fused.mflops(),
                two_pass.mflops(),
            );
            failed = true;
        }
    }
    report.emit("fused_epilogue");

    // ---- Conv: fused im2col must beat materialised im2col on peak allocation ----
    let cfg = Conv2d { in_channels: 8, out_channels: 8, kernel: 3, stride: 1, padding: 1, dilation: 1 };
    let (n_img, h, w) = (4usize, 32usize, 32usize);
    let input: Vec<f32> = (0..n_img * cfg.in_channels * h * w)
        .map(|i| ((i * 37 % 100) as f32 - 50.0) / 50.0)
        .collect();
    let kernels = Matrix::random(cfg.out_channels, cfg.in_channels * 9, 3, -1.0, 1.0);
    // Warm both routes once: global-context setup, pools and lazily-grown
    // scratch must not count against either measurement.
    let warm_fused = cfg.forward(&input, n_img, h, w, &kernels, Backend::Dispatch);
    let warm_mat = cfg.forward(&input, n_img, h, w, &kernels, Backend::Avx2Tile);
    assert!(warm_fused.max_abs_diff(&warm_mat) < 2e-4, "fused and materialised conv disagree");

    let fused_peak = peak_alloc_during(|| {
        let out = cfg.forward(&input, n_img, h, w, &kernels, Backend::Dispatch);
        std::hint::black_box(&out);
    });
    let mat_peak = peak_alloc_during(|| {
        let out = cfg.forward(&input, n_img, h, w, &kernels, Backend::Avx2Tile);
        std::hint::black_box(&out);
    });
    println!(
        "conv {n_img}x{}x{h}x{w} k3p1: peak alloc fused {:.0} KiB vs materialised {:.0} KiB",
        cfg.in_channels,
        fused_peak as f64 / 1024.0,
        mat_peak as f64 / 1024.0,
    );
    if fused_peak >= mat_peak {
        eprintln!(
            "FAIL: fused conv peak allocation ({fused_peak} B) not below the materialised im2col path ({mat_peak} B)"
        );
        failed = true;
    }

    if failed {
        std::process::exit(1);
    }
    println!("PASS: fused epilogue >= two-pass at every shape; fused conv allocates less than im2col");
}
