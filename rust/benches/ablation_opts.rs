//! ABL: ablation of the paper's §3 memory-hierarchy optimisations.
//!
//! The paper lists re-buffering, unrolling, prefetching and L2 blocking as
//! the techniques that make the SIMD kernel sustain its rate. Each is
//! toggled off here in isolation (host SSE kernel, paper methodology:
//! stride 700, caches flushed) plus a prefetch on/off pass on the
//! simulated PIII. Expected: every ablation loses throughput, with
//! re-buffering (packing) the largest single effect.

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{Matrix, Transpose};
use emmerald::gemm::{simd, BlockParams, Unroll};
use emmerald::sim::piii::piii_450;
use emmerald::sim::trace::{trace_emmerald, Layout};

fn main() {
    let n = 448usize;
    let stride = 700usize;
    let flops = gemm_flops(n, n, n);
    let a = Matrix::<f32>::random_strided(n, n, stride, 1);
    let b = Matrix::<f32>::random_strided(n, n, stride, 2);
    let mut c = Matrix::<f32>::zeros_strided(n, n, stride);

    let base = BlockParams::emmerald_sse();
    let variants: Vec<(&str, BlockParams)> = vec![
        ("full (paper config)", base),
        ("no re-buffering (pack_b off)", BlockParams { pack_b: false, ..base }),
        ("no prefetch", BlockParams { prefetch: false, ..base }),
        ("no unrolling (x1)", BlockParams { unroll: Unroll::X1, ..base }),
        ("no L2 blocking (mb=4096)", BlockParams { mb: 4096, ..base }),
        ("tiny L1 block (kb=32)", BlockParams { kb: 32, ..base }),
    ];

    let mut report = Report::new("ABL — §3 optimisation ablations (host SSE, stride 700, flushed)", &["variant"]);
    let mut base_rate = 0.0;
    for (name, params) in &variants {
        let mut bencher = Bencher::new(1, 3).flush_mode(FlushMode::Flush).min_sample_secs(0.005);
        let r = bencher.run(name, flops, || {
            simd::gemm(
                params,
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c.view_mut(),
            );
        });
        if base_rate == 0.0 {
            base_rate = r.mflops();
        } else {
            let pct = 100.0 * (r.mflops() / base_rate - 1.0);
            report.note(format!("{name}: {pct:+.1}% vs full config"));
        }
        report.add(&[name.to_string()], r);
    }

    // Simulated PIII: prefetch ablation (stall cycles are the signal).
    let machine = piii_450();
    for (label, prefetch) in [("sim prefetch on", true), ("sim prefetch off", false)] {
        let mut h = machine.hierarchy();
        let lay = Layout::with_stride(stride);
        trace_emmerald(&mut h, n, n, n, &lay, 336, 192, 5, prefetch);
        let stall = h.stats().stall_cycles as f64;
        let cycles = flops / 2.2 + stall;
        let mflops = flops / (cycles / (machine.clock_mhz * 1e6)) / 1e6;
        report.add_info(vec![
            label.to_string(),
            "sim-piii450".into(),
            format!("{:.6e}", cycles / (machine.clock_mhz * 1e6)),
            format!("{mflops:.1}"),
            format!("{mflops:.1}"),
            "0.0".into(),
        ]);
    }
    report.note("paper: all four §3 techniques are required to reach 1.69x clock average");
    report.emit("ablation_opts");
}
