//! Dispatch overhead: what does the registry + heuristic layer cost on
//! top of calling a kernel directly?
//!
//! The dispatch subsystem must be free at production sizes and near-free
//! even at small ones — the whole point of a runtime registry is to spend
//! nanoseconds choosing and microseconds computing. This bench times
//! `sgemm(Backend::Dispatch, ..)` against a *direct* call to the very
//! kernel the dispatcher selects for that shape, at small sizes where the
//! overhead is most visible, and **guards** that the median overhead at
//! 64×64 stays under 5% (exit code 1 otherwise, so CI can run this
//! binary as a regression gate).

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{sgemm, Backend, Matrix, Transpose};
use emmerald::gemm::dispatch::GemmShape;
use emmerald::gemm::{avx2, simd, tile, GemmDispatch, KernelId};

fn run_direct(id: KernelId, d: &GemmDispatch, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let mut cv = c.view_mut();
    match id {
        KernelId::Avx2Tile => tile::gemm(
            d.params_tile(),
            Transpose::No,
            Transpose::No,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut cv,
        ),
        KernelId::Avx2 => avx2::gemm(
            d.params_avx2(),
            Transpose::No,
            Transpose::No,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut cv,
        ),
        _ => simd::gemm(
            d.params_sse(),
            Transpose::No,
            Transpose::No,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut cv,
        ),
    }
}

fn run_dispatched(n: usize, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    sgemm(
        Backend::Dispatch,
        Transpose::No,
        Transpose::No,
        n,
        n,
        n,
        1.0,
        a.data(),
        a.ld(),
        b.data(),
        b.ld(),
        0.0,
        c.data_mut(),
        c.ld(),
    )
    .expect("dispatched sgemm");
}

fn main() {
    let d = GemmDispatch::default();
    if !d.has_sse() {
        println!("dispatch_overhead: no SSE on this host; nothing to compare");
        return;
    }
    let mut report = Report::new(
        "Dispatch overhead — sgemm(Backend::Dispatch) vs direct kernel call",
        &["size", "path"],
    );
    let mut guard_failed = false;
    for n in [16usize, 32, 64, 128] {
        let a = Matrix::random(n, n, 1, -1.0, 1.0);
        let b = Matrix::random(n, n, 2, -1.0, 1.0);
        let mut c = Matrix::zeros(n, n);
        let flops = gemm_flops(n, n, n);
        let picked = d.select(
            &GemmShape { m: n, n, k: n, transa: Transpose::No, transb: Transpose::No },
            1.0,
        );

        let mut bench = Bencher::new(3, 7).flush_mode(FlushMode::Warm).min_sample_secs(0.01);
        let direct = bench.run(&format!("direct/{}", picked.name()), flops, || {
            run_direct(picked, &d, &a, &b, &mut c);
        });
        let mut bench = Bencher::new(3, 7).flush_mode(FlushMode::Warm).min_sample_secs(0.01);
        let dispatched = bench.run("dispatched", flops, || {
            run_dispatched(n, &a, &b, &mut c);
        });

        // Median-of-samples comparison; mflops is inversely proportional
        // to time, so overhead = direct/dispatched - 1 in rate terms.
        let overhead = direct.mflops() / dispatched.mflops() - 1.0;
        println!(
            "n={n:<4} direct {:>8.1} MFlop/s  dispatched {:>8.1} MFlop/s  overhead {:>6.2}%  (kernel: {})",
            direct.mflops(),
            dispatched.mflops(),
            overhead * 100.0,
            picked.name()
        );
        if n == 64 && overhead > 0.05 {
            guard_failed = true;
        }
        report.add(&[n.to_string(), "direct".into()], direct);
        report.add(&[n.to_string(), "dispatched".into()], dispatched);
    }
    report.emit("dispatch_overhead");
    if guard_failed {
        eprintln!("FAIL: dispatch overhead at 64x64 exceeded the 5% budget");
        std::process::exit(1);
    }
    println!("PASS: dispatch overhead at 64x64 within the 5% budget");
}
