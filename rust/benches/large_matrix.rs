//! LARGE: the paper's large-matrix claim — L2 blocking keeps the peak
//! rate for matrices that spill every cache level ("the largest tested
//! size was m=n=k=stride=3696 on a 550 MHz machine which ran at 940
//! MFlop/s", i.e. *no fall-off* vs the 320-sized peak).
//!
//! Host check: Emmerald-SSE rate at the L2-resident sweet spot vs a
//! far-beyond-LLC size; the ratio must stay near 1. Simulated check: the
//! PIII-550 at an L2-spilling size vs its 320 peak.

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{sgemm, Backend, Matrix, Transpose};
use emmerald::sim::{piii_550, simulate_gemm, Algorithm};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let big = if quick { 1024 } else { 1848 }; // 1848² × 3 matrices ≈ 41 MB ≫ LLC
    let mut report = Report::new("LARGE — rate retention beyond cache capacity", &["path", "size"]);

    // Host: small (cache-resident) vs large for SSE and the ATLAS proxy.
    let mut rates = Vec::new();
    for backend in [Backend::Simd, Backend::Blocked] {
        for &n in &[320usize, big] {
            let a = Matrix::random(n, n, 1, -1.0, 1.0);
            let b = Matrix::random(n, n, 2, -1.0, 1.0);
            let mut c = Matrix::zeros(n, n);
            let mut bencher = Bencher::new(1, if n > 1500 { 2 } else { 4 })
                .flush_mode(FlushMode::Warm)
                .min_sample_secs(0.02);
            let r = bencher.run(backend.name(), gemm_flops(n, n, n), || {
                let (lda, ldb, ldc) = (a.ld(), b.ld(), c.ld());
                sgemm(backend, Transpose::No, Transpose::No, n, n, n, 1.0, a.data(), lda, b.data(), ldb, 0.0, c.data_mut(), ldc)
                    .unwrap();
            });
            rates.push((backend, n, r.mflops()));
            report.add(&["host".to_string(), n.to_string()], r);
        }
    }
    let retention = |b: Backend| {
        let small = rates.iter().find(|(bk, n, _)| *bk == b && *n == 320).unwrap().2;
        let large = rates.iter().find(|(bk, n, _)| *bk == b && *n == big).unwrap().2;
        large / small
    };
    report.note(format!(
        "host emmerald-sse retention at {big}: {:.2} (paper: ~1.0 — 940 vs 890 MFlop/s, i.e. no fall-off)",
        retention(Backend::Simd)
    ));
    report.note(format!("host blocked retention at {big}: {:.2}", retention(Backend::Blocked)));

    // Simulated PIII-550 (the paper's large-matrix machine).
    let m550 = piii_550();
    let sim_peak = simulate_gemm(&m550, Algorithm::Emmerald, 320, 320);
    let spill = if quick { 576 } else { 896 };
    let sim_large = simulate_gemm(&m550, Algorithm::Emmerald, spill, spill);
    report.add_info(vec![
        "sim-piii550".into(),
        "320".into(),
        "emmerald".into(),
        format!("{:.6e}", sim_peak.seconds),
        format!("{:.1}", sim_peak.mflops),
        format!("{:.1}", sim_peak.mflops),
        "0.0".into(),
    ]);
    report.add_info(vec![
        "sim-piii550".into(),
        spill.to_string(),
        "emmerald".into(),
        format!("{:.6e}", sim_large.seconds),
        format!("{:.1}", sim_large.mflops),
        format!("{:.1}", sim_large.mflops),
        "0.0".into(),
    ]);
    report.note(format!(
        "sim PIII-550: {:.0} MFlop/s at 320 vs {:.0} at {spill} (retention {:.2}; paper: 940 MFlop/s at 3696 = 1.71 x clock)",
        sim_peak.mflops,
        sim_large.mflops,
        sim_large.mflops / sim_peak.mflops
    ));
    report.emit("large_matrix");
}
