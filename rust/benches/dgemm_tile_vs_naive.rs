//! CI guard for the f64 kernel ladder: the 6×8 outer-product tile tier
//! must beat the naive triple loop by a wide margin at 512³, or the
//! DGEMM subsystem has regressed to scalar speed.
//!
//! The bar is deliberately conservative (≥ 2× naive — in practice the
//! vector tile is an order of magnitude faster) so the guard is about
//! wiring, not about machine-to-machine variance: it fails when dispatch
//! stops routing f64 to the vector tier or the f64 micro-kernel breaks,
//! not when a noisy neighbour steals half the core. Hosts without
//! AVX2+FMA skip-pass — there is no f64 vector tier to regress.
//!
//! Exit code 1 on failure so `ci.sh` can gate on it.

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{Matrix, Transpose};
use emmerald::gemm::{naive, tile, ElementId, KernelId, TileParams};

fn main() {
    if !KernelId::Avx2Tile.available_for(ElementId::F64) {
        println!("SKIP-PASS: no AVX2+FMA — f64 tile tier unavailable on this host");
        return;
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let n: usize = 512;
    let naive_n: usize = if quick { 128 } else { 256 };

    let a = Matrix::<f64>::random(n, n, 1, -1.0, 1.0);
    let b = Matrix::<f64>::random(n, n, 2, -1.0, 1.0);
    let mut c_tile = Matrix::<f64>::zeros(n, n);
    let mut c_ref = Matrix::<f64>::zeros(n, n);
    let params = TileParams::avx2_6x8_f64();

    // Correctness before speed.
    tile::gemm(&params, Transpose::No, Transpose::No, 1.0, a.view(), b.view(), 0.0, &mut c_tile.view_mut());
    naive::gemm(Transpose::No, Transpose::No, 1.0, a.view(), b.view(), 0.0, &mut c_ref.view_mut());
    let mut worst = 0.0f64;
    for i in 0..n * n {
        let want = c_ref.data()[i];
        worst = worst.max((c_tile.data()[i] - want).abs() / (1.0 + want.abs()));
    }
    assert!(worst < 1e-12, "f64 tile disagrees with naive: rel err {worst:e}");

    let mut report = Report::new(
        "DGEMM — f64 6x8 tile tier vs naive triple loop (MFlop/s)",
        &["size", "kernel"],
    );

    // Naive is measured at a smaller size (it is O(n³) at ~1 flop/cycle;
    // 512³ would dominate CI time) — MFlop/s compares fairly across sizes.
    let a_s = Matrix::<f64>::random(naive_n, naive_n, 3, -1.0, 1.0);
    let b_s = Matrix::<f64>::random(naive_n, naive_n, 4, -1.0, 1.0);
    let mut c_s = Matrix::<f64>::zeros(naive_n, naive_n);
    let mut bench = Bencher::new(1, 3).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
    let r_naive = bench.run("naive-f64", gemm_flops(naive_n, naive_n, naive_n), || {
        naive::gemm(Transpose::No, Transpose::No, 1.0, a_s.view(), b_s.view(), 0.0, &mut c_s.view_mut());
    });
    report.add(&[naive_n.to_string(), "naive".into()], r_naive.clone());

    let mut bench = Bencher::new(1, 3).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
    let r_tile = bench.run("tile-f64", gemm_flops(n, n, n), || {
        tile::gemm(&params, Transpose::No, Transpose::No, 1.0, a.view(), b.view(), 0.0, &mut c_tile.view_mut());
    });
    report.add(&[n.to_string(), "tile-6x8".into()], r_tile.clone());
    report.emit("dgemm_tile_vs_naive");

    let speedup = r_tile.mflops() / r_naive.mflops();
    println!(
        "f64 tile {:.1} MFlop/s vs naive {:.1} MFlop/s — {speedup:.2}x",
        r_tile.mflops(),
        r_naive.mflops()
    );
    if speedup < 2.0 {
        println!("FAIL: f64 tile tier below 2x naive — the DGEMM vector path has regressed");
        std::process::exit(1);
    }
    println!("PASS: f64 tile ≥ 2x naive");
}
