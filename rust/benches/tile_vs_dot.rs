//! Ablation guard: the outer-product register-tiled tier vs the
//! dot-panel AVX2 kernel — the design experiment behind `gemm::tile`.
//!
//! The dot-panel kernel pays a horizontal reduction plus a store per `kb`
//! multiply-adds and reloads `A`/`B` vectors per FMA; the 6×16 tile holds
//! `C` resident in 12 YMM accumulators and amortises every load across
//! the tile. This binary measures both on identical problems and
//! **guards** that the tile tier is at least as fast at 512³ and 1024³
//! (exit code 1 otherwise, so `ci.sh` can gate on it). Hosts without
//! AVX2+FMA skip-pass — there is no tile tier to regress.

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{Matrix, Transpose};
use emmerald::gemm::{DispatchConfig, GemmDispatch, KernelId};
use emmerald::util::testkit::assert_allclose;

fn main() {
    if !KernelId::Avx2Tile.available() {
        println!("SKIP-PASS: no AVX2+FMA — tile tier unavailable on this host");
        return;
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick { &[512] } else { &[512, 1024] };
    // Serial apples-to-apples: both kernels forced, one thread.
    let d = GemmDispatch::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });

    let mut report = Report::new(
        "TILE vs DOT — outer-product 6x16 tier vs dot-panel AVX2 (serial GFLOP/s)",
        &["size", "kernel"],
    );
    let mut failed = false;
    for &n in sizes {
        let a = Matrix::random(n, n, 1, -1.0, 1.0);
        let b = Matrix::random(n, n, 2, -1.0, 1.0);
        let flops = gemm_flops(n, n, n);
        let mut c_tile = Matrix::zeros(n, n);
        let mut c_dot = Matrix::zeros(n, n);

        // Correctness before speed: both kernels agree on the problem.
        let ran = d.gemm_with(
            KernelId::Avx2Tile,
            Transpose::No,
            Transpose::No,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut c_tile.view_mut(),
        );
        assert_eq!(ran, KernelId::Avx2Tile, "forced tile must not degrade here");
        d.gemm_with(
            KernelId::Avx2,
            Transpose::No,
            Transpose::No,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut c_dot.view_mut(),
        );
        assert_allclose(c_tile.data(), c_dot.data(), 5e-4, 1e-4, &format!("tile vs dot at {n}"));

        let mut bench = Bencher::new(1, 5).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
        let dot = bench.run("dot", flops, || {
            d.gemm_with(
                KernelId::Avx2,
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c_dot.view_mut(),
            );
        });
        let mut bench = Bencher::new(1, 5).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
        let tile = bench.run("tile", flops, || {
            d.gemm_with(
                KernelId::Avx2Tile,
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c_tile.view_mut(),
            );
        });

        println!(
            "{n}x{n}x{n}  dot {:>9.2}  tile {:>9.2} GFLOP/s  (tile/dot {:.2}x)",
            dot.mflops() / 1000.0,
            tile.mflops() / 1000.0,
            tile.mflops() / dot.mflops(),
        );
        report.add(&[n.to_string(), "dot".into()], dot.clone());
        report.add(&[n.to_string(), "tile".into()], tile.clone());
        if tile.mflops() < dot.mflops() {
            eprintln!(
                "FAIL: tile tier ({:.1} MFlop/s) lost to the dot-panel AVX2 kernel ({:.1} MFlop/s) at {n}^3",
                tile.mflops(),
                dot.mflops(),
            );
            failed = true;
        }
    }
    report.emit("tile_vs_dot");
    if failed {
        std::process::exit(1);
    }
    println!("PASS: tile tier >= dot-panel AVX2 at every measured size");
}
