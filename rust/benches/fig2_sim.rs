//! FIG2-SIM: Figure 2 in the paper's own units — the trace-driven PIII-450
//! simulation of all three algorithms (naive / ATLAS proxy / Emmerald)
//! with the paper's fixed-stride-700, cold-cache methodology.
//!
//! Expected (paper): Emmerald rises to ≈890 MFlop/s by size 320 and stays
//! flat; ATLAS ≈ 0.83 × clock ≈ 375; naive collapses once a column of B
//! no longer fits L1. Average Emmerald/ATLAS for size > 100 ≈ 2.09×.

use emmerald::sim::{piii_450, simulate_gemm, Algorithm};
use emmerald::util::json::Json;
use emmerald::util::table::{fnum, Table};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: Vec<usize> = if quick {
        vec![32, 96, 192, 320]
    } else {
        vec![16, 32, 48, 64, 96, 128, 160, 224, 256, 320, 384, 448, 512, 576, 700]
    };
    let stride = 700usize;
    let machine = piii_450();

    println!("simulating {} on {} sizes (stride {stride}, cold caches)...", machine.name, sizes.len());
    let mut table = Table::new(["size", "naive", "atlas", "emmerald", "emm x clock", "emm/atlas"]);
    let mut rows_json = Vec::new();
    let mut ratios = Vec::new();
    let mut peak = (0usize, 0.0f64);
    for &size in &sizes {
        let st = stride.max(size);
        // Naive at ≥576 costs ~2·n³ simulated accesses; cap it in quick runs.
        let naive = if quick && size > 320 {
            None
        } else {
            Some(simulate_gemm(&machine, Algorithm::Naive, size, st))
        };
        let atlas = simulate_gemm(&machine, Algorithm::Atlas, size, st);
        let emm = simulate_gemm(&machine, Algorithm::Emmerald, size, st);
        if size > 100 {
            ratios.push(emm.mflops / atlas.mflops);
        }
        if emm.mflops > peak.1 {
            peak = (size, emm.mflops);
        }
        table.row([
            size.to_string(),
            naive.as_ref().map(|r| fnum(r.mflops, 0)).unwrap_or_else(|| "-".into()),
            fnum(atlas.mflops, 0),
            fnum(emm.mflops, 0),
            fnum(emm.mflops / machine.clock_mhz, 2),
            fnum(emm.mflops / atlas.mflops, 2),
        ]);
        rows_json.push(Json::obj([
            ("size", size.into()),
            ("naive", naive.map(|r| Json::Num(r.mflops)).unwrap_or(Json::Null)),
            ("atlas", Json::Num(atlas.mflops)),
            ("emmerald", Json::Num(emm.mflops)),
        ]));
    }
    println!("== FIG2-SIM — simulated PIII-450 MFlop/s ==");
    println!("{}", table.render());
    let avg = ratios.iter().sum::<f64>() / ratios.len().max(1) as f64;
    println!("AVG209: mean emmerald/atlas for size>100 = {avg:.2}x (paper: 2.09x)");
    println!(
        "PEAK: emmerald {:.0} MFlop/s at size {} = {:.2} x clock (paper: 890 at 320 = 1.97x)",
        peak.1,
        peak.0,
        peak.1 / machine.clock_mhz
    );
    let doc = Json::obj([
        ("bench", "fig2_sim".into()),
        ("rows", Json::Arr(rows_json)),
        ("avg_ratio_gt100", Json::Num(avg)),
        ("peak_mflops", Json::Num(peak.1)),
        ("peak_size", peak.0.into()),
    ]);
    let _ = std::fs::create_dir_all("target/bench-results");
    let _ = std::fs::write("target/bench-results/fig2_sim.json", doc.render());
    println!("[wrote target/bench-results/fig2_sim.json]");
}
