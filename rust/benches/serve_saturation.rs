//! CI guard for the serve tier: cache-hit serving (registered weights
//! against the plan/packed-weight cache) must sustain at least 1.5× the
//! throughput of repack-every-call (inline weight bytes against a
//! zero-capacity cache) on the same Zipfian shape mix, or the cache has
//! stopped paying for itself — a broken key, a stampede regression, or
//! eviction churn swallowing the hits.
//!
//! Both arms drive the identical workload (same seed, same menu, same
//! client count) through `serve::run_driver`; the only deltas are the
//! service's `cache_capacity` and the operand mode. Latency is
//! client-observed round trip, so the reported p50/p95/p99 include
//! admission queueing and the coalescing linger — the quantities a
//! serving SLO is written against.
//!
//! Emits `BENCH_serve.json` (per-arm throughput, latency percentiles,
//! cache counters) under `target/bench-results/`. Hosts with fewer than
//! 4 worker threads skip-pass: with no concurrency there is no queueing
//! and the comparison means nothing. Exit code 1 on failure so `ci.sh`
//! can gate on it.

use emmerald::bench::{BenchResult, Report};
use emmerald::gemm::GemmContext;
use emmerald::serve::{
    default_shapes, run_driver, DriverConfig, DriverReport, GemmService, ServeConfig, WeightMode,
};
use emmerald::util::stats::Summary;

/// Add one arm's numbers to the report: a result row (median request
/// latency as the timing, effective per-request flops for the MFlop/s
/// column) plus a note with the serving-facing quantities.
fn arm_row(report: &mut Report, name: &str, flops: f64, r: &DriverReport) {
    let result =
        BenchResult { name: name.to_string(), seconds: Summary::from(&r.latencies), flops };
    report.add(&[name.to_string()], result);
    report.note(format!(
        "{name}: {:.0} req/s over {:.2} s; latency p50 {:.3} / p95 {:.3} / p99 {:.3} ms; {}",
        r.throughput,
        r.elapsed,
        r.latency_p(50.0) * 1e3,
        r.latency_p(95.0) * 1e3,
        r.latency_p(99.0) * 1e3,
        r.stats,
    ));
}

fn main() {
    let threads = GemmContext::global().threads();
    if threads < 4 {
        println!(
            "SKIP-PASS: {threads} worker thread(s) — the saturation mix needs >= 4 for queueing to mean anything"
        );
        return;
    }

    let base = DriverConfig { clients: 4, requests_per_client: 96, ..DriverConfig::default() };

    // Effective per-request flops: the Zipf-weighted mean of 2mnk over
    // the menu, so both arms' MFlop/s columns are directly comparable.
    let weights: Vec<f64> =
        (0..base.shapes.len()).map(|r| 1.0 / ((r + 1) as f64).powf(base.zipf_s)).collect();
    let total: f64 = weights.iter().sum();
    let flops: f64 = base
        .shapes
        .iter()
        .zip(&weights)
        .map(|(s, w)| (2 * s.m * s.n * s.k) as f64 * w / total)
        .sum();

    // Arm 1: repack-every-call. Zero-capacity cache, weight bytes inline
    // on every request — the no-service baseline a cache must beat.
    let repack_svc = GemmService::new(
        GemmContext::global().clone(),
        ServeConfig { cache_capacity: 0, ..ServeConfig::default() },
    );
    let repack = run_driver(&repack_svc, &DriverConfig { mode: WeightMode::Inline, ..base.clone() });
    drop(repack_svc);

    // Arm 2: cache-hit serving. Default cache, weights registered once up
    // front. A short warm pass first so the measured pass is the
    // steady-state hit path, not first-touch packing.
    let cached_svc = GemmService::new(GemmContext::global().clone(), ServeConfig::default());
    let _ = run_driver(
        &cached_svc,
        &DriverConfig { mode: WeightMode::Registered, requests_per_client: 8, ..base.clone() },
    );
    let cached =
        run_driver(&cached_svc, &DriverConfig { mode: WeightMode::Registered, ..base.clone() });
    drop(cached_svc);

    let mut report = Report::new(
        "SERVE — cache-hit serving vs repack-every-call (Zipfian shape mix, client-observed latency)",
        &["arm"],
    );
    arm_row(&mut report, "repack-every-call", flops, &repack);
    arm_row(&mut report, "cache-hit", flops, &cached);

    let ratio = cached.throughput / repack.throughput.max(1e-12);
    report.note(format!(
        "cache-hit/repack throughput = {ratio:.2} ({:.0} vs {:.0} req/s, {} clients x {} requests, threshold 1.5x)",
        cached.throughput, repack.throughput, base.clients, base.requests_per_client,
    ));
    report.emit("BENCH_serve");

    let expected = base.clients * base.requests_per_client;
    if repack.failed > 0 || cached.failed > 0 || cached.completed != expected {
        println!(
            "FAIL: requests were dropped (repack {}/{}, cached {}/{}) — blocking submit must not shed load",
            repack.completed, expected, cached.completed, expected,
        );
        std::process::exit(1);
    }
    if cached.stats.pack_hits == 0 {
        println!("FAIL: the cached arm recorded zero pack hits — registered weights never hit the cache");
        std::process::exit(1);
    }
    if ratio < 1.5 {
        println!(
            "FAIL: cache-hit serving only {ratio:.2}x repack-every-call (needs >= 1.5x) — the packed-weight cache has stopped paying for itself"
        );
        std::process::exit(1);
    }
    println!("PASS: cache-hit serving {ratio:.2}x repack-every-call (threshold 1.5x)");
}
