//! CI guard for the quantized kernel tier: the int8 `maddubs` tile must
//! beat the f32 tile by a wide margin at 512³, or the `u8 × i8 → i32`
//! path has regressed to the scalar fallback (or stopped routing to the
//! AVX2 driver at all).
//!
//! The bar is deliberately conservative (≥ 2× the f32 tile — the
//! instruction budget says ~4×: `vpmaddubsw` + `vpmaddd` retire four
//! int8 MACs per lane-pair where the f32 tile's FMA does one) so the
//! guard is about wiring, not machine-to-machine variance. Hosts
//! without AVX2 skip-pass — both sides would run scalar and the ratio
//! means nothing.
//!
//! Exit code 1 on failure so `ci.sh` can gate on it.

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{Matrix, Transpose};
use emmerald::gemm::{quant, tile, ElementId, KernelId, TileParams};

fn main() {
    if !KernelId::Avx2Tile.available_for(ElementId::F32) {
        println!("SKIP-PASS: no AVX2+FMA — the int8 maddubs tile is unavailable on this host");
        return;
    }
    let n: usize = 512;

    // Deterministic operands. The i8 fill stays in [-127, 127] so the
    // packed handle keeps the vpsignb fast path (as quantized weights
    // do: the nn quantizer clamps to ±127).
    let a_q = Matrix::from_fn(n, n, |r, c| (r * 31 + c * 7) as u8);
    let b_q = Matrix::from_fn(n, n, |r, c| (((r * 13 + c * 11) % 255) as i32 - 127) as i8);
    let a_f = Matrix::<f32>::random(n, n, 1, -1.0, 1.0);
    let b_f = Matrix::<f32>::random(n, n, 2, -1.0, 1.0);
    let mut c_q = Matrix::<i32>::zeros(n, n);
    let mut c_f = Matrix::<f32>::zeros(n, n);
    let params = TileParams::avx2_6x16();

    // Correctness before speed: the driver must match the widening
    // oracle bitwise (checked at a smaller size — the oracle is O(n³)
    // scalar and 512³ of it would dominate CI time).
    let s = 96;
    let sa = a_q.view().block(0, 0, s, s);
    let sb = b_q.view().block(0, 0, s, s);
    let mut got = Matrix::<i32>::zeros(s, s);
    let mut want = Matrix::<i32>::zeros(s, s);
    quant::qgemm(Transpose::No, Transpose::No, sa, sb, &mut got.view_mut(), false);
    quant::qgemm_reference(Transpose::No, Transpose::No, sa, sb, &mut want.view_mut(), false);
    assert_eq!(got.data(), want.data(), "qgemm disagrees with the widening oracle");

    let mut report = Report::new(
        "QGEMM — int8 maddubs tile vs f32 tile at 512^3 (MFlop/s; 1 MAC = 2 ops)",
        &["size", "kernel"],
    );

    let mut bench = Bencher::new(1, 3).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
    let r_f32 = bench.run("tile-f32", gemm_flops(n, n, n), || {
        tile::gemm(&params, Transpose::No, Transpose::No, 1.0, a_f.view(), b_f.view(), 0.0, &mut c_f.view_mut());
    });
    report.add(&[n.to_string(), "tile-f32".into()], r_f32.clone());

    let mut bench = Bencher::new(1, 3).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
    let r_q = bench.run("qgemm-u8i8", gemm_flops(n, n, n), || {
        quant::qgemm(Transpose::No, Transpose::No, a_q.view(), b_q.view(), &mut c_q.view_mut(), false);
    });
    report.add(&[n.to_string(), "qgemm-u8i8".into()], r_q.clone());
    report.emit("qgemm_vs_sgemm");

    let speedup = r_q.mflops() / r_f32.mflops();
    println!(
        "int8 tile {:.1} Mop/s vs f32 tile {:.1} MFlop/s — {speedup:.2}x",
        r_q.mflops(),
        r_f32.mflops()
    );
    if speedup < 2.0 {
        println!("FAIL: int8 tile below 2x the f32 tile — the quantized vector path has regressed");
        std::process::exit(1);
    }
    println!("PASS: int8 tile ≥ 2x f32 tile");
}
