//! CLUSTER: the paper's §4 application numbers — *"196 Intel Pentium III
//! 550 MHz processors … sustained performance of 152 GFlops/s for a price
//! performance ratio of 98¢ USD/MFlop/s"* — regenerated from the cluster
//! model, plus a real mini-cluster measurement (thread-per-worker
//! training on this host) fed through the same arithmetic.

use emmerald::blas::Backend;
use emmerald::coordinator::{ClusterSpec, Coordinator, EngineFactory, NativeEngine, TrainConfig};
use emmerald::nn::{Dataset, Mlp};
use emmerald::util::table::{fnum, Table};
use std::sync::Arc;

fn main() {
    // ------------------------------------------------ paper-model numbers
    let paper = ClusterSpec::piii_cluster_1999();
    let grad_bytes = 1.0e6 * 4.0; // ~1M params, f32
    let step_flops = 8.0e9; // large local batches (ref [1])
    let gf = paper.sustained_gflops(step_flops, grad_bytes);
    let cents = paper.cents_per_mflops(gf);

    println!("== CLUSTER — §4 price/performance ==");
    println!(
        "model of the paper's cluster (196 x PIII-550, 100 Mbit ring allreduce):\n\
         sustained {gf:.0} GFlop/s, {cents:.0} c/MFlop/s  (paper: 152 GFlop/s @ 98c)\n"
    );

    // Scaling table: nodes vs sustained rate and efficiency.
    let mut table = Table::new(["nodes", "GFlop/s", "efficiency", "c/MFlop/s"]);
    for nodes in [1usize, 8, 32, 64, 128, 196] {
        let c = ClusterSpec { nodes, ..paper };
        let g = c.sustained_gflops(step_flops, grad_bytes);
        table.row([
            nodes.to_string(),
            fnum(g, 1),
            fnum(c.efficiency(step_flops, grad_bytes), 3),
            fnum(c.cents_per_mflops(g), 1),
        ]);
    }
    println!("{}", table.render());

    // ------------------------------------------- measured mini-cluster
    // Thread-per-worker training on this host; per-node rate measured,
    // then extrapolated with the same arithmetic.
    println!("measuring a real mini-cluster (4 worker threads, native SSE engine)...");
    let sizes = [64usize, 256, 256, 10];
    let mlp = Mlp::init(&sizes, 3, Backend::Auto);
    let data = Dataset::gaussian_clusters(2048, 64, 10, 0.5, 9);
    let cfg = TrainConfig { workers: 4, shard_batch: 64, steps: 30, lr: 0.2, log_every: 0 };
    let mut coord = Coordinator::new(cfg, mlp, data).expect("coordinator");
    let factory: Arc<EngineFactory> =
        Arc::new(|_| Ok(Box::new(NativeEngine::new(Backend::Auto)) as _));
    let r = coord.train_threaded(factory).expect("training");
    let per_node = r.sustained_mflops() / 4.0;
    println!(
        "measured: {:.0} MFlop/s total over 4 workers ({:.0}/node), loss {:.3} -> {:.3}\n",
        r.sustained_mflops(),
        per_node,
        r.first_loss(),
        r.final_loss
    );
    let host = ClusterSpec::host_cluster(196, per_node, 1500.0);
    let gfh = host.sustained_gflops(step_flops, grad_bytes);
    println!(
        "196 x this-host nodes at $1500: sustained {:.0} GFlop/s, {:.1} c/MFlop/s\n\
         (the 1999 -> 2026 price/perf improvement factor: ~{:.0}x)",
        gfh,
        host.cents_per_mflops(gfh),
        cents / host.cents_per_mflops(gfh)
    );

    let _ = std::fs::create_dir_all("target/bench-results");
    let doc = emmerald::util::json::Json::obj([
        ("bench", "cluster_scale".into()),
        ("paper_model_gflops", emmerald::util::json::Json::Num(gf)),
        ("paper_model_cents_per_mflops", emmerald::util::json::Json::Num(cents)),
        ("measured_per_node_mflops", emmerald::util::json::Json::Num(per_node)),
        ("host_cluster_gflops", emmerald::util::json::Json::Num(gfh)),
    ]);
    let _ = std::fs::write("target/bench-results/cluster_scale.json", doc.render());
    println!("[wrote target/bench-results/cluster_scale.json]");
}
