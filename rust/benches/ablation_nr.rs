//! NR5: the paper's inner-loop design experiment — "we … found
//! experimentally that 5 dot-products in the inner loop gave the best
//! performance" (§2, with the 8-XMM register budget of fig. 1a).
//!
//! Re-run that experiment: sweep nr = 1..8 on the host SSE kernel, and
//! nr = 1..5 on the simulated PIII (nr > 5 would spill the PIII's eight
//! XMM registers — exactly why the paper stopped at 5; the host has 16,
//! so larger nr is measurable here and shows the same diminishing-returns
//! curve the register budget truncates).

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{Matrix, Transpose};
use emmerald::gemm::{simd, BlockParams};
use emmerald::sim::piii::piii_450;
use emmerald::sim::trace::{trace_emmerald, Layout};

fn main() {
    let n = 448usize;
    let flops = gemm_flops(n, n, n);
    let a = Matrix::<f32>::random(n, n, 1, -1.0, 1.0);
    let b = Matrix::<f32>::random(n, n, 2, -1.0, 1.0);
    let mut c = Matrix::<f32>::zeros(n, n);

    let mut report = Report::new("NR5 — dot products per inner loop (paper: 5 is best)", &["nr"]);
    let mut best = (0usize, 0.0f64);
    for nr in 1..=8usize {
        let params = BlockParams { nr, ..BlockParams::emmerald_sse() };
        let mut bencher = Bencher::new(1, 4).flush_mode(FlushMode::Warm).min_sample_secs(0.02);
        let r = bencher.run(&format!("sse nr={nr}"), flops, || {
            simd::gemm(
                &params,
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c.view_mut(),
            );
        });
        if r.mflops() > best.1 {
            best = (nr, r.mflops());
        }
        report.add(&[nr.to_string()], r);
    }
    report.note(format!("host winner: nr={} at {:.0} MFlop/s", best.0, best.1));

    // Simulated PIII: the machine the paper tuned on. nr ≤ 5 is the
    // feasible set (1 A + 2 B + nr accumulators ≤ 8 XMM registers).
    let machine = piii_450();
    let mut sim_best = (0usize, 0.0f64);
    for nr in 1..=5usize {
        let mut h = machine.hierarchy();
        let lay = Layout::with_stride(700);
        trace_emmerald(&mut h, n, n, n, &lay, 336, 192, nr, true);
        let stall = h.stats().stall_cycles as f64;
        // Issue rate scales with A-register reuse: loads per 4-wide step =
        // 1 + nr serving 8·nr flops; port-2 bound ⇒ fpc ≈ 4·nr/(1+nr),
        // normalised to the paper's 2.2 at nr = 5.
        let fpc = 2.2 * ((4.0 * nr as f64 / (1.0 + nr as f64)) / (4.0 * 5.0 / 6.0));
        let cycles = flops / fpc + stall;
        let mflops = flops / (cycles / (machine.clock_mhz * 1e6)) / 1e6;
        if mflops > sim_best.1 {
            sim_best = (nr, mflops);
        }
        report.add_info(vec![
            nr.to_string(),
            "sim-piii450".into(),
            format!("{:.6e}", cycles / (machine.clock_mhz * 1e6)),
            format!("{mflops:.1}"),
            format!("{mflops:.1}"),
            "0.0".into(),
        ]);
    }
    report.note(format!(
        "simulated PIII winner: nr={} at {:.0} MFlop/s (paper found nr=5 best; nr>5 spills the 8 XMM registers)",
        sim_best.0, sim_best.1
    ));
    report.emit("ablation_nr");
}
