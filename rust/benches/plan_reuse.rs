//! Plan reuse: what does planned execution with prepacked operands buy
//! over repeated positional `sgemm` calls?
//!
//! Three tiers at the same shape:
//!
//! 1. `sgemm` — the compatibility shim: validate + select + pack B, every
//!    call.
//! 2. `plan.run` — plan built once; validation is length checks only, the
//!    kernel and geometry are already resolved, but B still re-packs.
//! 3. `plan.run_packed_b` — plan built once **and** B packed once; the
//!    per-call work is exactly the micro-kernel sweep.
//!
//! Measured at the acceptance shape 256×256×256 and at the
//! weight-stationary inference shape 8×256×256 (skinny activations ×
//! resident weight), where packing is a large fraction of the work.
//! **Guards** that prepacked planned execution beats repeated `sgemm` at
//! 256³ (exit code 1 otherwise, so CI can use this binary as a gate).

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{sgemm, Backend, GemmContext, Matrix, Transpose};

fn bench_shape(
    ctx: &GemmContext,
    report: &mut Report,
    m: usize,
    n: usize,
    k: usize,
) -> (f64, f64, f64) {
    let a = Matrix::random(m, k, 1, -1.0, 1.0);
    let b = Matrix::random(k, n, 2, -1.0, 1.0);
    let mut c = Matrix::zeros(m, n);
    let flops = gemm_flops(m, n, k);
    let label = format!("{m}x{n}x{k}");

    let mut bench = Bencher::new(3, 9).flush_mode(FlushMode::Warm).min_sample_secs(0.01);
    let positional = bench.run(&format!("sgemm/{label}"), flops, || {
        sgemm(
            Backend::Dispatch,
            Transpose::No,
            Transpose::No,
            m,
            n,
            k,
            1.0,
            a.data(),
            a.ld(),
            b.data(),
            b.ld(),
            0.0,
            c.data_mut(),
            c.ld(),
        )
        .expect("sgemm");
    });

    let plan = ctx.gemm().plan(m, n, k).expect("plan");
    let mut bench = Bencher::new(3, 9).flush_mode(FlushMode::Warm).min_sample_secs(0.01);
    let planned = bench.run(&format!("plan/{label}"), flops, || {
        plan.run(a.data(), b.data(), c.data_mut()).expect("plan.run");
    });

    let packed = ctx.pack_b(Transpose::No, k, n, b.data(), b.ld()).expect("pack_b");
    let mut bench = Bencher::new(3, 9).flush_mode(FlushMode::Warm).min_sample_secs(0.01);
    let prepacked = bench.run(&format!("plan+packedB/{label}"), flops, || {
        plan.run_packed_b(a.data(), &packed, c.data_mut()).expect("run_packed_b");
    });

    println!(
        "{label:>12}  sgemm {:>9.1}  plan {:>9.1}  plan+packedB {:>9.1} MFlop/s  (packed speedup {:>+6.2}% over sgemm)",
        positional.mflops(),
        planned.mflops(),
        prepacked.mflops(),
        (prepacked.mflops() / positional.mflops() - 1.0) * 100.0,
    );
    report.add(&[label.clone(), "sgemm".into()], positional.clone());
    report.add(&[label.clone(), "plan".into()], planned.clone());
    report.add(&[label, "plan+packedB".into()], prepacked.clone());
    (positional.mflops(), planned.mflops(), prepacked.mflops())
}

fn main() {
    let ctx = GemmContext::global();
    let mut report = Report::new(
        "Plan reuse — repeated sgemm vs planned execution vs prepacked B",
        &["shape", "path"],
    );
    println!(
        "context: thread budget {} — every tier runs inside the shared pool",
        ctx.threads()
    );

    // The acceptance shape: planned + prepacked must beat repeated sgemm.
    let (sgemm_256, _, packed_256) = bench_shape(ctx, &mut report, 256, 256, 256);
    // The weight-stationary shape: packing dominates, the win is large.
    bench_shape(ctx, &mut report, 8, 256, 256);

    report.emit("plan_reuse");
    if packed_256 <= sgemm_256 {
        eprintln!(
            "FAIL: prepacked planned execution ({packed_256:.1} MFlop/s) did not beat repeated sgemm ({sgemm_256:.1} MFlop/s) at 256x256x256"
        );
        std::process::exit(1);
    }
    println!("PASS: prepacked planned execution beats repeated sgemm at 256x256x256");
}
