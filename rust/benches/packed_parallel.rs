//! Parallel prepacked execution: what does splitting `run_packed`'s
//! row-block loop across the context pool buy over the serial sweep?
//!
//! Both contexts run the *same* prepacked panel driver on the same
//! prepacked operands (results are bit-identical — asserted before
//! timing); the only difference is the thread budget. Measured at the
//! acceptance shape 512×512×512.
//!
//! **Guards** that parallel `run_packed` beats serial `run_packed` at
//! 512³ when at least two threads are available (exit code 1 otherwise,
//! so CI can use this binary as a gate). Single-core hosts skip-pass.

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{GemmContext, Matrix, Transpose};
use emmerald::gemm::{DispatchConfig, KernelId};

fn main() {
    let ctx_par = GemmContext::global();
    if ctx_par.threads() < 2 {
        println!("SKIP-PASS: single-thread budget ({}) — nothing to parallelise", ctx_par.threads());
        return;
    }
    let ctx_ser = GemmContext::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });

    let (m, n, k) = (512usize, 512usize, 512usize);
    let a = Matrix::random(m, k, 1, -1.0, 1.0);
    let b = Matrix::random(k, n, 2, -1.0, 1.0);
    let flops = gemm_flops(m, n, k);

    let build = |ctx: &GemmContext| {
        let pa = ctx.pack_a(Transpose::No, m, k, a.data(), a.ld()).expect("pack_a");
        let pb = ctx.pack_b(Transpose::No, k, n, b.data(), b.ld()).expect("pack_b");
        let plan = ctx.gemm().plan(m, n, k).expect("plan");
        (pa, pb, plan)
    };
    let (pa_p, pb_p, plan_par) = build(ctx_par);
    let (pa_s, pb_s, plan_ser) = build(&ctx_ser);
    assert_eq!(plan_par.kernel(), KernelId::Parallel, "512^3 must resolve to the parallel tier");

    // Same driver, same split-invariant arithmetic: bit-identical outputs.
    let mut c_par = vec![0.0f32; m * n];
    let mut c_ser = vec![0.0f32; m * n];
    plan_par.run_packed(&pa_p, &pb_p, &mut c_par).expect("parallel run_packed");
    plan_ser.run_packed(&pa_s, &pb_s, &mut c_ser).expect("serial run_packed");
    assert_eq!(c_par, c_ser, "parallel run_packed must be bit-identical to serial");

    let mut report = Report::new(
        "Prepacked parallel — run_packed across the context pool vs serial",
        &["path"],
    );
    println!("context: thread budget {} (serial comparison budget 1)", ctx_par.threads());

    let mut bench = Bencher::new(2, 7).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
    let serial = bench.run("run_packed/serial", flops, || {
        plan_ser.run_packed(&pa_s, &pb_s, &mut c_ser).expect("serial run_packed");
    });
    let mut bench = Bencher::new(2, 7).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
    let parallel = bench.run("run_packed/parallel", flops, || {
        plan_par.run_packed(&pa_p, &pb_p, &mut c_par).expect("parallel run_packed");
    });

    println!(
        "512x512x512  serial {:>9.1}  parallel {:>9.1} MFlop/s  (speedup {:.2}x on {} threads)",
        serial.mflops(),
        parallel.mflops(),
        parallel.mflops() / serial.mflops(),
        ctx_par.threads(),
    );
    report.add(&["serial".into()], serial.clone());
    report.add(&["parallel".into()], parallel.clone());
    report.emit("packed_parallel");

    if parallel.mflops() <= serial.mflops() {
        eprintln!(
            "FAIL: parallel run_packed ({:.1} MFlop/s) did not beat serial run_packed ({:.1} MFlop/s) at 512x512x512 with {} threads",
            parallel.mflops(),
            serial.mflops(),
            ctx_par.threads(),
        );
        std::process::exit(1);
    }
    println!("PASS: parallel run_packed beats serial run_packed at 512x512x512");
}
