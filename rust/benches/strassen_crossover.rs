//! STRASSEN: the question the paper's first paragraph sets aside — at
//! what size does Strassen's algorithm (ref [5], Thottethodi et al.)
//! beat the flat SIMD kernel?
//!
//! Effective MFlop/s is reported in *classic* (2n³) terms so the curves
//! are directly comparable: Strassen "wins" where its effective rate
//! exceeds the kernel's flat rate, i.e. where the 7/8-multiply saving
//! outruns its extra passes over memory.

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{sgemm_matrix, Backend, Matrix, Transpose};
use emmerald::gemm::strassen::{strassen_flops, strassen_matmul, DEFAULT_CUTOFF};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: Vec<usize> = if quick { vec![256, 512, 1024] } else { vec![256, 512, 768, 1024, 1536] };
    let backend = if emmerald::blas::available_backends().contains(&Backend::Avx2) {
        Backend::Avx2
    } else {
        Backend::Simd
    };

    let mut report = Report::new(
        "STRASSEN — hybrid (ref [5]) vs flat Emmerald kernel (effective 2n^3 MFlop/s)",
        &["size"],
    );
    for &n in &sizes {
        let a = Matrix::random(n, n, 1, -1.0, 1.0);
        let b = Matrix::random(n, n, 2, -1.0, 1.0);
        let classic = gemm_flops(n, n, n);

        // Flat kernel.
        let mut c = Matrix::zeros(n, n);
        let mut bencher = Bencher::new(1, 3).flush_mode(FlushMode::Warm).min_sample_secs(0.02);
        let r = bencher.run(&format!("{} flat", backend.name()), classic, || {
            sgemm_matrix(backend, Transpose::No, Transpose::No, 1.0, &a, &b, 0.0, &mut c).unwrap();
        });
        let flat = r.mflops();
        report.add(&[n.to_string()], r);

        // Strassen hybrid (default cutoff).
        let mut bencher = Bencher::new(1, 3).flush_mode(FlushMode::Warm).min_sample_secs(0.02);
        let r = bencher.run("strassen hybrid", classic, || {
            let _ = strassen_matmul(&a, &b, DEFAULT_CUTOFF, backend);
        });
        let hybrid = r.mflops();
        report.add(&[n.to_string()], r);
        report.note(format!(
            "n={n}: hybrid/flat = {:.2} (useful flops ratio {:.3})",
            hybrid / flat,
            strassen_flops(n, DEFAULT_CUTOFF) / classic
        ));
    }
    report.note("paper: 'without resorting to the complexities of Strassen' — the flat kernel wins below the crossover; ref [5] found crossovers near ~1000 on similar memory hierarchies");
    report.emit("strassen_crossover");
}
