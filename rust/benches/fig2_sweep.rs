//! FIG2 (host): the paper's Figure 2 regenerated on this machine.
//!
//! Methodology follows §4 exactly: M = N = K swept from 16 upward, the
//! row stride fixed at 700 regardless of size, wall-clock timing, caches
//! flushed between calls. Backends: naive, the ATLAS proxy, Emmerald-SSE
//! (the paper's kernel) and Emmerald-AVX2 (modern extension).
//!
//! Summary rows reproduce the paper's headline derived statistics:
//! average Emmerald/ATLAS ratio for sizes > 100 (paper: 2.09×) and the
//! Emmerald peak (paper: 890 MFlop/s = 1.97 × clock on the PIII).

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{available_backends, sgemm, Backend, Matrix, Transpose};

fn run_square(backend: Backend, n: usize, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (lda, ldb, ldc) = (a.ld(), b.ld(), c.ld());
    sgemm(backend, Transpose::No, Transpose::No, n, n, n, 1.0, a.data(), lda, b.data(), ldb, 0.0, c.data_mut(), ldc)
        .unwrap();
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: Vec<usize> = if quick {
        vec![16, 64, 160, 320, 448]
    } else {
        vec![16, 32, 48, 64, 96, 128, 160, 224, 256, 320, 384, 448, 512, 576, 640, 700]
    };
    let stride = 700usize;
    let samples = if quick { 2 } else { 3 };

    let backends = available_backends();
    let mut report = Report::new(
        "FIG2 — MFlop/s vs size (host, stride 700, caches flushed)",
        &["size"],
    );
    // Per-(size, backend) medians for the summary statistics.
    let mut series: Vec<(usize, Backend, f64)> = Vec::new();

    for &size in &sizes {
        let a = Matrix::random_strided(size, size, stride, 1);
        let b = Matrix::random_strided(size, size, stride, 2);
        let mut c = Matrix::zeros_strided(size, size, stride);
        for &backend in &backends {
            // Skip the O(n³) naive at the top sizes in quick mode.
            if quick && backend == Backend::Naive && size > 320 {
                continue;
            }
            let mut bencher =
                Bencher::new(1, samples).flush_mode(FlushMode::Flush).min_sample_secs(0.005);
            let r = bencher.run(backend.name(), gemm_flops(size, size, size), || {
                run_square(backend, size, &a, &b, &mut c);
            });
            series.push((size, backend, r.mflops()));
            report.add(&[size.to_string()], r);
        }
    }

    // Derived statistics (the paper's numbers quoted for reference).
    let ratio_avg = {
        let mut ratios = Vec::new();
        for &size in sizes.iter().filter(|&&s| s > 100) {
            let emm = series
                .iter()
                .find(|(s, b, _)| *s == size && *b == Backend::Simd)
                .map(|(_, _, m)| *m);
            let atl = series
                .iter()
                .find(|(s, b, _)| *s == size && *b == Backend::Blocked)
                .map(|(_, _, m)| *m);
            if let (Some(e), Some(a)) = (emm, atl) {
                ratios.push(e / a);
            }
        }
        ratios.iter().sum::<f64>() / ratios.len().max(1) as f64
    };
    let (peak_size, peak) = series
        .iter()
        .filter(|(_, b, _)| *b == Backend::Simd)
        .map(|(s, _, m)| (*s, *m))
        .fold((0, 0.0), |acc, x| if x.1 > acc.1 { x } else { acc });

    report.note(format!(
        "AVG209: mean emmerald-sse / blocked for size>100 = {ratio_avg:.2}x (paper: 2.09x vs ATLAS)"
    ));
    report.note(format!(
        "emmerald-sse peak = {peak:.0} MFlop/s at size {peak_size} (paper: 890 at 320 on a 450 MHz PIII)"
    ));
    report.note("ordering expected: emmerald-avx2 > emmerald-sse > blocked > naive at every size > 64");
    report.emit("fig2_sweep");
}
