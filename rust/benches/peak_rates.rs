//! PEAK320: the paper's peak-rate claim — 890 MFlop/s (1.97–1.98 × clock)
//! at m = n = k = stride = 320 — measured three ways:
//!
//! 1. host Emmerald-SSE / AVX2 / ATLAS-proxy at the same configuration
//!    (warm caches, as the paper's peak is the steady-state rate),
//! 2. the simulated PIII-450 at the identical configuration,
//! 3. the PJRT-executed Pallas artifact (if built).

use emmerald::bench::{gemm_flops, Bencher, FlushMode, Report};
use emmerald::blas::{available_backends, sgemm, Matrix, Transpose};
use emmerald::runtime::{PjrtGemm, Runtime};
use emmerald::sim::{piii_450, simulate_gemm, Algorithm};

fn main() {
    let n = 320usize;
    let flops = gemm_flops(n, n, n);
    let a = Matrix::random(n, n, 1, -1.0, 1.0);
    let b = Matrix::random(n, n, 2, -1.0, 1.0);
    let mut c = Matrix::zeros(n, n);

    let mut report = Report::new("PEAK320 — m=n=k=stride=320 (paper: 890 MFlop/s on PIII-450)", &["path"]);
    for backend in available_backends() {
        let mut bencher = Bencher::new(2, 5).flush_mode(FlushMode::Warm).min_sample_secs(0.02);
        let r = bencher.run(backend.name(), flops, || {
            let (lda, ldb, ldc) = (a.ld(), b.ld(), c.ld());
            sgemm(backend, Transpose::No, Transpose::No, n, n, n, 1.0, a.data(), lda, b.data(), ldb, 0.0, c.data_mut(), ldc)
                .unwrap();
        });
        report.add(&["host".to_string()], r);
    }

    // Simulated PIII-450 at the paper's exact peak configuration.
    let sim = simulate_gemm(&piii_450(), Algorithm::Emmerald, n, n);
    report.add_info(vec![
        "sim-piii450".into(),
        "emmerald".into(),
        format!("{:.6e}", sim.seconds),
        format!("{:.1}", sim.mflops),
        format!("{:.1}", sim.mflops),
        "0.0".into(),
    ]);
    let sim_atlas = simulate_gemm(&piii_450(), Algorithm::Atlas, n, n);
    report.add_info(vec![
        "sim-piii450".into(),
        "atlas".into(),
        format!("{:.6e}", sim_atlas.seconds),
        format!("{:.1}", sim_atlas.mflops),
        format!("{:.1}", sim_atlas.mflops),
        "0.0".into(),
    ]);

    // PJRT path.
    if let Ok(rt) = Runtime::new("artifacts") {
        if let Ok(g) = PjrtGemm::new(&rt, "gemm_320") {
            let mut bencher = Bencher::new(1, 3);
            let r = bencher.run("pjrt/gemm_320", flops, || {
                let _ = g.matmul(a.data(), b.data()).unwrap();
            });
            report.add(&["pjrt".to_string()], r);
        }
    }

    report.note(format!(
        "sim emmerald = {:.0} MFlop/s = {:.2} x clock (paper: 890 = 1.97x); sim atlas = {:.0} = {:.2} x clock (paper: 375 = 0.83x)",
        sim.mflops,
        sim.mflops / 450.0,
        sim_atlas.mflops,
        sim_atlas.mflops / 450.0
    ));
    report.note("host rows measure this machine; the paper ratio to compare is emmerald-sse / blocked");
    report.emit("peak_rates");
}
