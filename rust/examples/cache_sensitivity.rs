//! Design-choice sensitivity study: how the simulated Emmerald rate
//! responds to the machine's cache geometry — the experiment behind the
//! paper's parameter choices (kb=336 exists *because* L1 is 16 KB; the
//! re-buffering exists *because* the DTLB has 64 entries).
//!
//! ```bash
//! cargo run --release --example cache_sensitivity
//! ```

use emmerald::sim::cache::CacheConfig;
use emmerald::sim::hierarchy::Hierarchy;
use emmerald::sim::piii::{coppermine_600, piii_450, MachineSpec};
use emmerald::sim::trace::{trace_emmerald, trace_naive, Layout};
use emmerald::util::table::{fnum, Table};

fn emmerald_mflops(machine: &MachineSpec, size: usize, stride: usize, kb: usize) -> f64 {
    let mut h = machine.hierarchy();
    let lay = Layout::with_stride(stride);
    trace_emmerald(&mut h, size, size, size, &lay, kb, 192, 5, true);
    let flops = 2.0 * (size as f64).powi(3);
    let cycles = flops / 2.2 + h.stats().stall_cycles as f64;
    flops / (cycles / (machine.clock_mhz * 1e6)) / 1e6
}

fn naive_mflops_with(mut h: Hierarchy, clock_mhz: f64, size: usize, stride: usize) -> f64 {
    let lay = Layout::with_stride(stride);
    trace_naive(&mut h, size, size, size, &lay);
    let flops = 2.0 * (size as f64).powi(3);
    let cycles = flops / 0.66 + h.stats().stall_cycles as f64;
    flops / (cycles / (clock_mhz * 1e6)) / 1e6
}

fn main() {
    let size = 320usize;
    let stride = 700usize;

    // ------------------------------------------------ L1 capacity vs kb
    // Probe at size 672 so every kb candidate is fully exercised
    // (kb_eff = min(kb, k)); panel bytes = kb × 5 × 4.
    println!("== kb (panel depth) vs L1 capacity — why the paper picked 336 ==");
    let kb_probe = 672usize;
    let mut t = Table::new(["L1", "kb=84", "kb=168", "kb=336", "kb=672"]);
    for l1_kb in [8usize, 16, 32] {
        let mut machine = piii_450();
        machine.l1 = CacheConfig { capacity: l1_kb * 1024, ways: 4, line_bytes: 32 };
        let mut row = vec![format!("{l1_kb} KB")];
        for kb in [84usize, 168, 336, 672] {
            row.push(fnum(emmerald_mflops(&machine, kb_probe, kb_probe, kb), 0));
        }
        t.row(row);
    }
    println!("{}", t.render());
    println!("expected: the best kb tracks the L1 size; at 16 KB (the PIII), 336 is near-optimal.\n");

    // ------------------------------------------------ TLB entries
    println!("== TLB size — why re-buffering minimises TLB misses ==");
    let mut t = Table::new(["DTLB entries", "emmerald", "naive"]);
    for entries in [16usize, 64, 256] {
        let mut machine = piii_450();
        machine.tlb_entries = entries;
        let emm = emmerald_mflops(&machine, size, stride, 336);
        let nai = naive_mflops_with(machine.hierarchy(), machine.clock_mhz, 160, stride);
        t.row([format!("{entries}"), fnum(emm, 0), fnum(nai, 0)]);
    }
    println!("{}", t.render());
    println!("expected: emmerald is insensitive (packed panels are page-dense);\nnaive's strided column walks live and die by the TLB.\n");

    // ------------------------------------------------ machine presets
    println!("== machine presets ==");
    let mut t = Table::new(["machine", "emmerald @320", "x clock"]);
    for machine in [piii_450(), emmerald::sim::piii_550(), coppermine_600()] {
        let m = emmerald_mflops(&machine, size, 320, 336);
        t.row([machine.name.to_string(), fnum(m, 0), fnum(m / machine.clock_mhz, 2)]);
    }
    println!("{}", t.render());
    println!("paper: 890 (1.97x) on the 450; 940 large-matrix on the 550.");
}
