//! Reproduce Fig. 2 on the simulated Pentium III — the paper's own units.
//!
//! ```bash
//! cargo run --release --example piii_sim
//! cargo run --release --example piii_sim -- --clock 550 --sizes 320,448
//! ```

use emmerald::sim::{piii_450, piii_550, simulate_gemm, Algorithm};
use emmerald::util::cli::Cli;
use emmerald::util::table::{fnum, Table};

fn main() {
    let cli = Cli::new("piii_sim", "simulated-PIII GEMM rates (Fig. 2 in paper units)")
        .opt("sizes", "16,32,64,96,128,192,256,320,448", "comma-separated sizes")
        .opt("stride", "700", "fixed row stride (paper methodology)")
        .opt("clock", "450", "450 or 550 MHz");
    let m = cli.parse();
    let machine = if m.get_u64("clock").unwrap() == 550 { piii_550() } else { piii_450() };
    let stride = m.get_usize("stride").unwrap();

    println!(
        "{} — peak SSE {} MFlop/s; paper's Emmerald peak: 890 @ size 320\n",
        machine.name,
        machine.peak_sse_mflops()
    );
    let mut table = Table::new([
        "size",
        "naive",
        "atlas",
        "emmerald",
        "emm x clock",
        "emm/atlas",
        "emm L1 hit%",
    ]);
    for tok in m.get("sizes").unwrap().split(',') {
        let size: usize = tok.trim().parse().expect("size");
        let st = stride.max(size);
        let n = simulate_gemm(&machine, Algorithm::Naive, size, st);
        let a = simulate_gemm(&machine, Algorithm::Atlas, size, st);
        let e = simulate_gemm(&machine, Algorithm::Emmerald, size, st);
        table.row([
            size.to_string(),
            fnum(n.mflops, 0),
            fnum(a.mflops, 0),
            fnum(e.mflops, 0),
            fnum(e.mflops / machine.clock_mhz, 2),
            fnum(e.mflops / a.mflops, 2),
            fnum(e.stats.l1.hit_rate() * 100.0, 1),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper: Emmerald avg (size>100) = 1.69 × clock = 2.09 × ATLAS; peak 1.97 × clock.\n\
         The simulated curves should show the same ordering, the same flat\n\
         Emmerald profile, and ATLAS ≈ 0.83 × clock."
    );
}
