//! ATLAS-style empirical search over the Emmerald kernel's parameters,
//! cross-checked against the analytic traffic model — answers the paper's
//! "determined experimentally" for this host.
//!
//! ```bash
//! cargo run --release --example autotune -- --kernel sse --probe 448
//! ```

use emmerald::autotune::{analytic_traffic, tune, TuneKernel, TuneSpec};
use emmerald::util::cli::Cli;
use emmerald::util::table::{fnum, Table};

fn main() {
    let cli = Cli::new("autotune", "empirical + analytic block-parameter search")
        .opt("kernel", "sse", "sse|avx2|blocked")
        .opt("probe", "448", "probe size (m=n=k)")
        .opt("samples", "3", "timing samples per candidate");
    let m = cli.parse();
    let probe = m.get_usize("probe").unwrap();
    let mut spec = match m.get("kernel").unwrap() {
        "blocked" => TuneSpec::blocked_default(probe),
        "avx2" => {
            let mut s = TuneSpec::sse_default(probe);
            s.kernel = TuneKernel::Avx2;
            s
        }
        _ => TuneSpec::sse_default(probe),
    };
    spec.samples = m.get_usize("samples").unwrap();

    println!(
        "searching {} candidates at probe size {probe} (kernel {:?})...\n",
        spec.candidates().len(),
        spec.kernel
    );
    let r = tune(&spec);

    let l1_bytes = 32 * 1024; // host L1d (paper's machine had 16 KB)
    let mut table = Table::new(["kb", "mb", "nr", "measured MFlop/s", "analytic B/flop"]);
    let mut log = r.log.clone();
    log.sort_by(|a, b| b.mflops.partial_cmp(&a.mflops).unwrap());
    for p in &log {
        table.row([
            p.params.kb.to_string(),
            p.params.mb.to_string(),
            p.params.nr.to_string(),
            fnum(p.mflops, 1),
            fnum(analytic_traffic(&p.params, probe, l1_bytes), 3),
        ]);
    }
    println!("{}", table.render());
    println!(
        "winner: kb={} mb={} nr={} at {:.1} MFlop/s\n\
         paper's PIII operating point: kb=336, nr=5 (16 KB L1; this host's\n\
         larger L1 may prefer deeper panels — that is the point of ATLAS's\n\
         install-time search, reproduced here).",
        r.best.kb, r.best.mb, r.best.nr, r.best_mflops
    );
}
