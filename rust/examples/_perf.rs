use emmerald::bench::{gemm_flops, Bencher, FlushMode};
use emmerald::blas::{Matrix, Transpose};
use emmerald::gemm::{avx2, simd, BlockParams};
fn main() {
    for n in [320usize, 448, 640] {
        let a = Matrix::<f32>::random(n, n, 1, -1.0, 1.0);
        let b = Matrix::<f32>::random(n, n, 2, -1.0, 1.0);
        let mut c = Matrix::<f32>::zeros(n, n);
        let flops = gemm_flops(n, n, n);
        for (name, is_avx) in [("sse", false), ("avx2", true)] {
            let p = if is_avx { BlockParams::emmerald_avx2() } else { BlockParams::emmerald_sse() };
            let mut be = Bencher::new(2, 7).flush_mode(FlushMode::Warm).min_sample_secs(0.05);
            let r = be.run(name, flops, || {
                if is_avx { avx2::gemm(&p, Transpose::No, Transpose::No, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut()); }
                else { simd::gemm(&p, Transpose::No, Transpose::No, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut()); }
            });
            println!("{name} n={n}: median {:.0} best {:.0} MFlop/s", r.mflops(), r.mflops_best());
        }
    }
}
