//! End-to-end driver (the repository's E2E validation deliverable).
//!
//! Exercises all three layers on a real small workload:
//!
//! * **L1** — the Emmerald Pallas GEMM kernel (inside the artifact),
//! * **L2** — the JAX MLP forward/backward graph lowered by `aot.py`,
//! * **L3** — the Rust coordinator: sharding, gradient averaging, SGD,
//!   flop metering,
//! * plus the native path (Rust backprop over the SSE kernel) as a
//!   cross-check, and the 1999 cluster model to put the measured rate in
//!   the paper's price/performance terms.
//!
//! ```bash
//! make artifacts && cargo run --release --example nn_training
//! ```
//!
//! The loss curve printed here is recorded in EXPERIMENTS.md §E2E.

use emmerald::blas::Backend;
use emmerald::coordinator::{
    ClusterSpec, Coordinator, EngineFactory, NativeEngine, PjrtEngine, TrainConfig,
};
use emmerald::nn::{Dataset, Mlp};
use emmerald::util::cli::Cli;
use std::sync::Arc;

fn main() {
    let cli = Cli::new("nn_training", "end-to-end distributed MLP training")
        .opt("steps", "40", "training steps")
        .opt("workers", "4", "workers (native phase)")
        .opt("artifacts", "artifacts", "artifact directory")
        .flag("skip-pjrt", "only run the native phase");
    let m = cli.parse();
    let steps = m.get_usize("steps").unwrap();
    let workers = m.get_usize("workers").unwrap();

    // ---------------------------------------------------------------- PJRT
    // Phase 1: the full three-layer stack. The artifact fixes the model
    // (256-768-768-10, ~0.8M params — the paper's "more than one million
    // adjustable parameters" scale) and batch (64).
    let mut pjrt_rate = None;
    if !m.flag("skip-pjrt") {
        match PjrtEngine::new(m.get("artifacts").unwrap()) {
            Ok(mut engine) => {
                let sizes = engine.sizes().to_vec();
                let batch = engine.batch();
                println!(
                    "== Phase 1: PJRT engine (JAX/Pallas artifact) ==\n\
                     model {:?} ({} params), batch {batch}",
                    sizes,
                    Mlp::init(&sizes, 0, Backend::Auto).param_count()
                );
                let mlp = Mlp::init(&sizes, 7, Backend::Auto);
                let data =
                    Dataset::gaussian_clusters(batch * 16, sizes[0], *sizes.last().unwrap(), 0.5, 42);
                let cfg = TrainConfig {
                    workers: 2,
                    shard_batch: batch,
                    steps,
                    lr: 0.3,
                    log_every: 5,
                };
                let mut coord = Coordinator::new(cfg, mlp, data).expect("coordinator");
                let r = coord.train_sequential(&mut engine).expect("pjrt training");
                println!(
                    "PJRT: loss {:.4} -> {:.4}, accuracy {:.1}%, sustained {:.1} MFlop/s\n",
                    r.first_loss(),
                    r.final_loss,
                    r.final_accuracy * 100.0,
                    r.sustained_mflops()
                );
                pjrt_rate = Some(r.sustained_mflops());
                assert!(r.final_loss < r.first_loss(), "PJRT loss must fall");
            }
            Err(e) => {
                eprintln!("PJRT phase skipped: {e:#}\n(run `make artifacts` to enable)\n");
            }
        }
    }

    // -------------------------------------------------------------- native
    // Phase 2: thread-per-worker cluster analogue over the native SSE
    // backprop (same model family, smaller so the run is quick).
    println!("== Phase 2: native engine, {workers} worker threads ==");
    let sizes = [64usize, 256, 256, 10];
    let mlp = Mlp::init(&sizes, 11, Backend::Auto);
    println!("model {:?} ({} params)", sizes, mlp.param_count());
    let data = Dataset::gaussian_clusters(4096, sizes[0], *sizes.last().unwrap(), 0.5, 43);
    let cfg = TrainConfig { workers, shard_batch: 64, steps, lr: 0.3, log_every: 5 };
    let mut coord = Coordinator::new(cfg, mlp, data).expect("coordinator");
    let factory: Arc<EngineFactory> =
        Arc::new(|_| Ok(Box::new(NativeEngine::new(Backend::Auto)) as _));
    let r = coord.train_threaded(factory).expect("native training");
    println!(
        "native: loss {:.4} -> {:.4}, accuracy {:.1}%, sustained {:.1} MFlop/s, rerouted {}\n",
        r.first_loss(),
        r.final_loss,
        r.final_accuracy * 100.0,
        r.sustained_mflops(),
        r.rerouted
    );
    assert!(r.final_loss < r.first_loss(), "native loss must fall");

    // ------------------------------------------------------------- cluster
    // Phase 3: put the measured per-node rate into the paper's cluster
    // arithmetic (196 nodes, ring allreduce, 1999 price book).
    println!("== Phase 3: the paper's cluster arithmetic ==");
    let paper = ClusterSpec::piii_cluster_1999();
    let step_flops = 8.0e9;
    let grad_bytes = 4.0e6;
    let gf = paper.sustained_gflops(step_flops, grad_bytes);
    println!(
        "paper cluster (196 × PIII-550): sustained {:.0} GFlop/s at {:.0} ¢/MFlop/s \
         (paper reports 152 GFlop/s @ 98¢)",
        gf,
        paper.cents_per_mflops(gf)
    );
    if let Some(rate) = pjrt_rate {
        let host = ClusterSpec::host_cluster(196, rate, 1500.0);
        let gfh = host.sustained_gflops(step_flops, grad_bytes);
        println!(
            "same arithmetic over this host's measured {:.0} MFlop/s/node: \
             {:.0} GFlop/s at {:.1} ¢/MFlop/s",
            rate,
            gfh,
            host.cents_per_mflops(gfh)
        );
    }
    println!("\nE2E OK: all three layers composed and the loss fell.");
}
