//! The paper's adoption story, demonstrated: a LAPACK-style solver whose
//! flops run through the Emmerald kernel.
//!
//! Builds an SPD system (ridge-regression normal equations, the classic
//! 1999-era NN/statistics workload), factors it with blocked Cholesky
//! (SPOTRF → SSYRK → SGEMM → Emmerald) and solves, comparing backends.
//!
//! ```bash
//! cargo run --release --example cholesky -- --size 512
//! ```

use emmerald::bench::{Bencher, FlushMode};
use emmerald::blas::{sgemm_matrix, Backend, Matrix, Transpose};
use emmerald::lapack::{cholesky_blocked, cholesky_solve};
use emmerald::util::cli::Cli;
use emmerald::util::table::{fnum, Table};

fn main() {
    let cli = Cli::new("cholesky", "SGEMM-powered blocked Cholesky solve")
        .opt("size", "512", "system size n")
        .opt("samples", "3", "timing samples");
    let m = cli.parse();
    let n = m.get_usize("size").unwrap();
    let samples = m.get_usize("samples").unwrap();

    // Normal equations A = XᵀX + λI for a random design matrix.
    let x = Matrix::random(n + 64, n, 1, -1.0, 1.0);
    let mut a = Matrix::zeros(n, n);
    sgemm_matrix(Backend::Auto, Transpose::Yes, Transpose::No, 1.0, &x, &x, 0.0, &mut a)
        .expect("normal equations");
    for i in 0..n {
        a.set(i, i, a.get(i, i) + 1.0);
    }
    let x_true = emmerald::util::prng::random_f32(7, n, -1.0, 1.0);
    let mut b = vec![0.0f32; n];
    for i in 0..n {
        b[i] = (0..n).map(|j| a.get(i, j) * x_true[j]).sum();
    }

    println!("SPD system n={n} (ridge normal equations); ~n^3/3 flops in SSYRK/SGEMM\n");
    let mut table = Table::new(["backend", "factor time (s)", "eff. MFlop/s", "max |x - x_true|"]);
    let chol_flops = (n as f64).powi(3) / 3.0;
    for backend in [Backend::Blocked, Backend::Simd, Backend::Avx2] {
        if !emmerald::blas::available_backends().contains(&backend) {
            continue;
        }
        let mut bencher = Bencher::new(1, samples).flush_mode(FlushMode::Warm).min_sample_secs(0.02);
        let r = bencher.run(backend.name(), chol_flops, || {
            let _ = cholesky_blocked(&a, backend).expect("factor");
        });
        let l = cholesky_blocked(&a, backend).expect("factor");
        let sol = cholesky_solve(&l, &b).expect("solve");
        let err = sol
            .iter()
            .zip(&x_true)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0f32, f32::max);
        table.row([
            backend.name().to_string(),
            format!("{:.4}", r.seconds.median),
            fnum(r.mflops(), 1),
            format!("{err:.2e}"),
        ]);
    }
    println!("{}", table.render());
    println!("(the factor-rate gap between backends is the paper's SGEMM gap, inherited by LAPACK)");
}
