//! Quickstart: multiply two matrices with every available backend and
//! compare rates — a miniature of the paper's Fig. 2 at one size.
//!
//! ```bash
//! cargo run --release --example quickstart -- --size 320
//! ```

use emmerald::bench::{gemm_flops, Bencher, FlushMode};
use emmerald::blas::{available_backends, sgemm, Backend, Matrix, Transpose};
use emmerald::util::cli::Cli;
use emmerald::util::table::{fnum, Table};

fn main() {
    let cli = Cli::new("quickstart", "compare SGEMM backends at one size")
        .opt("size", "320", "square matrix dimension (m = n = k)")
        .opt("samples", "5", "timing samples per backend")
        .flag("flush", "flush caches between samples (paper methodology)");
    let m = cli.parse();
    let size = m.get_usize("size").unwrap();
    let samples = m.get_usize("samples").unwrap();
    let flush = if m.flag("flush") { FlushMode::Flush } else { FlushMode::Warm };

    println!("Emmerald quickstart: SGEMM {size}x{size}x{size}, f32\n");

    let a = Matrix::random(size, size, 1, -1.0, 1.0);
    let b = Matrix::random(size, size, 2, -1.0, 1.0);

    // Correctness first: every backend must agree with naive.
    let mut c_ref = Matrix::zeros(size, size);
    sgemm(
        Backend::Naive,
        Transpose::No,
        Transpose::No,
        size,
        size,
        size,
        1.0,
        a.data(),
        size,
        b.data(),
        size,
        0.0,
        c_ref.data_mut(),
        size,
    )
    .unwrap();

    let flops = gemm_flops(size, size, size);
    let mut table = Table::new(["backend", "median MFlop/s", "best MFlop/s", "max|err|"]);
    for backend in available_backends() {
        let mut c = Matrix::zeros(size, size);
        sgemm(
            backend,
            Transpose::No,
            Transpose::No,
            size,
            size,
            size,
            1.0,
            a.data(),
            size,
            b.data(),
            size,
            0.0,
            c.data_mut(),
            size,
        )
        .unwrap();
        let err = c.max_abs_diff(&c_ref);

        let mut bencher = Bencher::new(1, samples).flush_mode(flush).min_sample_secs(0.05);
        let result = bencher.run(backend.name(), flops, || {
            let mut c = Matrix::zeros(size, size);
            sgemm(
                backend,
                Transpose::No,
                Transpose::No,
                size,
                size,
                size,
                1.0,
                a.data(),
                size,
                b.data(),
                size,
                0.0,
                c.data_mut(),
                size,
            )
            .unwrap();
        });
        table.row([
            backend.name().to_string(),
            fnum(result.mflops(), 1),
            fnum(result.mflops_best(), 1),
            format!("{err:.2e}"),
        ]);
    }
    println!("{}", table.render());
    println!("(the paper reports Emmerald ≈ 2× ATLAS; expect emmerald-sse ≈ 2× blocked here)");
}
