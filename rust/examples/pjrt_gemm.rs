//! Run the AOT-compiled Pallas GEMM artifacts from Rust via PJRT and
//! compare them with the native backends — the L1↔RT bridge in isolation.
//!
//! ```bash
//! make artifacts && cargo run --release --example pjrt_gemm
//! ```

use emmerald::bench::{gemm_flops, Bencher};
use emmerald::blas::{sgemm, Backend, Matrix, Transpose};
use emmerald::runtime::{PjrtGemm, Runtime};
use emmerald::util::cli::Cli;
use emmerald::util::table::{fnum, Table};

fn main() {
    let cli = Cli::new("pjrt_gemm", "execute Pallas GEMM artifacts through PJRT")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("samples", "3", "timing samples");
    let m = cli.parse();
    let rt = Runtime::new(m.get("artifacts").unwrap())
        .expect("artifacts missing — run `make artifacts`");
    println!("PJRT platform: {}\n", rt.platform());

    let mut table = Table::new(["artifact", "size", "max|err| vs naive", "PJRT MFlop/s"]);
    for name in rt.registry().names() {
        if !name.starts_with("gemm_") {
            continue;
        }
        let g = PjrtGemm::new(&rt, &name).expect("bind artifact");
        let n = g.n;
        let a = Matrix::random(n, n, 1, -1.0, 1.0);
        let b = Matrix::random(n, n, 2, -1.0, 1.0);

        // Correctness vs the native naive oracle.
        let mut c_ref = Matrix::zeros(n, n);
        let ldc = c_ref.ld();
        sgemm(Backend::Naive, Transpose::No, Transpose::No, n, n, n, 1.0, a.data(), n, b.data(), n, 0.0, c_ref.data_mut(), ldc)
            .unwrap();
        let out = g.matmul(a.data(), b.data()).expect("pjrt execute");
        let err = out
            .iter()
            .zip(c_ref.data())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);

        // Rate (compiled executable is cached; this times execution only).
        let mut bencher = Bencher::new(1, m.get_usize("samples").unwrap());
        let r = bencher.run(&name, gemm_flops(n, n, n), || {
            let _ = g.matmul(a.data(), b.data()).unwrap();
        });
        table.row([name.clone(), n.to_string(), format!("{err:.2e}"), fnum(r.mflops(), 1)]);
    }
    println!("{}", table.render());
    println!(
        "note: interpret-mode Pallas lowers the tile schedule to plain HLO loops —\n\
         these rates measure the artifact path end-to-end, not TPU kernel speed\n\
         (real-TPU performance is estimated in DESIGN.md §Hardware-Adaptation)."
    );
}
