//! Offline stub of the `xla` crate (xla_extension 0.5.1 PJRT bindings).
//!
//! The real bindings link the multi-hundred-megabyte XLA runtime, which is
//! not present in this build environment. This stub keeps the exact API
//! surface `emmerald::runtime` compiles against, split in two tiers:
//!
//! * **Functional**: [`Literal`] and [`ArrayShape`] — host-side tensor
//!   construction, reshape and extraction work for real, so the
//!   `Tensor ↔ Literal` conversion layer (and its tests) behaves
//!   identically to the real crate.
//! * **Unavailable**: [`PjRtClient`], compilation and execution — every
//!   entry point reports a descriptive [`Error`]. All PJRT consumers in
//!   the tree already treat "runtime not available" as a skip condition
//!   (no artifacts built ⇒ tests skip, CLI prints a hint), so swapping the
//!   real crate back in is a pure `Cargo.toml` change.

use std::fmt;
use std::path::Path;

/// Stub error type (the real crate's `Error` is also a display-able enum).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "XLA runtime unavailable in this offline build: {what} requires the \
         real xla_extension bindings"
    ))
}

/// Array dimensions of a literal.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension sizes.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side value: either a dense f32 array or a tuple of literals.
///
/// Only f32 arrays are constructible through the public API, matching the
/// SGEMM/MLP ABI (`f32` is the sole dtype in the artifact manifests).
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    /// Dense row-major f32 array.
    Array {
        /// Dimension sizes (empty = scalar).
        dims: Vec<i64>,
        /// Row-major element data.
        data: Vec<f32>,
    },
    /// Tuple of literals (produced by tuple-rooted computations).
    Tuple(Vec<Literal>),
}

impl Literal {
    /// A rank-1 literal from a slice.
    pub fn vec1(values: &[f32]) -> Literal {
        Literal::Array { dims: vec![values.len() as i64], data: values.to_vec() }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, .. } => {
                let want: i64 = dims.iter().product();
                if want < 0 || want as usize != data.len() {
                    return Err(Error(format!(
                        "reshape to {:?} ({} elements) from {} elements",
                        dims,
                        want,
                        data.len()
                    )));
                }
                Ok(Literal::Array { dims: dims.to_vec(), data: data.clone() })
            }
            Literal::Tuple(_) => Err(Error("cannot reshape a tuple literal".into())),
        }
    }

    /// Shape of an array literal (error on tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, .. } => Ok(ArrayShape { dims: dims.clone() }),
            Literal::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
        }
    }

    /// Extract the elements of an array literal.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_literal(self)
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            Literal::Array { .. } => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Element types extractable from a [`Literal`] (f32 only, like the ABI).
pub trait NativeType: Sized {
    /// Extract a flat element vector.
    fn from_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn from_literal(lit: &Literal) -> Result<Vec<f32>> {
        match lit {
            Literal::Array { data, .. } => Ok(data.clone()),
            Literal::Tuple(_) => Err(Error("tuple literal has no element data".into())),
        }
    }
}

/// Parsed HLO module (stub: parsing always reports unavailability).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file (unavailable in the stub).
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        Err(Error(format!(
            "cannot parse HLO text {}: the offline xla stub has no HLO parser",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client (unavailable in the stub).
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Backend platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation (unavailable in the stub).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable (stub: never constructible, execution fails).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments (unavailable in the stub).
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy back to a host literal (unavailable in the stub).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn scalar_reshape() {
        let lit = Literal::vec1(&[7.5]);
        let s = lit.reshape(&[]).unwrap();
        assert_eq!(s.array_shape().unwrap().dims(), &[] as &[i64]);
    }

    #[test]
    fn reshape_rejects_bad_count() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[3]).is_err());
    }

    #[test]
    fn tuple_accessors() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0])]);
        assert!(t.array_shape().is_err());
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(Literal::vec1(&[1.0]).to_tuple().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }
}
