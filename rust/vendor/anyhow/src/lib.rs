//! Offline stand-in for the `anyhow` crate.
//!
//! The crate registry for this build is empty, so the subset of `anyhow`
//! that the Emmerald runtime and coordinator actually use is implemented
//! here: [`Error`], the [`Result`] alias, the [`Context`] extension trait
//! (for both `Result` and `Option`), and the [`bail!`]/[`anyhow!`] macros.
//!
//! Semantics match upstream where it matters to callers:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`,
//! * `.context(..)` / `.with_context(..)` prepend a layer of description,
//! * `{:#}` (and plain `{}`) formatting renders the whole context chain as
//!   `outermost: ...: root cause`, which is what the test-suite greps for.
//!
//! Differences from upstream: no backtraces, no downcasting — none of the
//! in-tree consumers use either.

use std::fmt;

/// A type-erased error: the accumulated context chain, outermost first.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }

    fn wrap<C: fmt::Display, E: fmt::Display>(context: C, cause: E) -> Self {
        Self { msg: format!("{context}: {cause}") }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what keeps the blanket `From` below coherent (same trick as upstream).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;
    use std::fmt;

    /// Internal unification of "errors that can absorb a context layer":
    /// [`Error`] itself plus every standard error. Mirrors upstream's
    /// private `ext::StdError` trait.
    pub trait ContextError {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error;
    }

    impl ContextError for Error {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            Error::wrap(context, self)
        }
    }

    impl<E: std::error::Error + Send + Sync + 'static> ContextError for E {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            Error::wrap(context, self)
        }
    }
}

/// Extension trait providing `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed description.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily-built description.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: ext::ContextError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(::std::format!($($arg)*)))
    };
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        let rendered = format!("{e:#}");
        assert!(rendered.starts_with("reading manifest: "), "{rendered}");
        assert!(rendered.contains("missing thing"));
    }

    #[test]
    fn with_context_on_anyhow_result_stacks() {
        let r: Result<()> = Err(Error::msg("root"));
        let e = r.with_context(|| format!("layer {}", 2)).unwrap_err();
        assert_eq!(format!("{e}"), "layer 2: root");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(e.to_string(), "nothing here");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 3);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 3");
        let e = anyhow!("standalone {}", "msg");
        assert_eq!(e.to_string(), "standalone msg");
    }
}
