//! DGEMM conformance suite for the element-generic precision subsystem.
//!
//! Mirrors the f32 cross-backend suite in double precision:
//!
//! * every backend on a fringe-shape grid ({1, MR−1, MR+1, NR−1, NR+1}³
//!   for the 6×8 f64 tile) across all four transpose layouts with
//!   strided operands and a strided `C`, against the **f64 naive
//!   oracle**;
//! * block-boundary crossers (257) on every axis;
//! * the bit-stability contract: one problem through the serial tile
//!   driver, the thread-parallel tier, and both prepacked planned paths
//!   produces identical bits;
//! * strided-batch DGEMM against a per-item loop;
//! * the compensated-f32 accumulation mode: its error vs the f64 oracle
//!   is never worse than the plain f32 kernels' on ill-conditioned
//!   summands (property test).

use emmerald::blas::{dgemm, dgemm_batch, Backend, GemmContext, Matrix, Transpose};
use emmerald::gemm::{Accumulation, DispatchConfig, ElementId, KernelId};
use emmerald::util::testkit::{assert_allclose_f64, check, hermetic_tune_cache};

/// Independent f64 triple-loop oracle written directly against the
/// row-major storage convention (accumulates in f64 like the kernels).
#[allow(clippy::too_many_arguments)]
fn oracle(
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    beta: f64,
    a: &Matrix<f64>,
    b: &Matrix<f64>,
    c: &mut Matrix<f64>,
) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                let av = match transa {
                    Transpose::No => a.get(i, p),
                    Transpose::Yes => a.get(p, i),
                };
                let bv = match transb {
                    Transpose::No => b.get(p, j),
                    Transpose::Yes => b.get(j, p),
                };
                acc += av * bv;
            }
            let old = c.get(i, j);
            c.set(i, j, alpha * acc + beta * old);
        }
    }
}

fn layouts() -> [(Transpose, Transpose); 4] {
    [
        (Transpose::No, Transpose::No),
        (Transpose::Yes, Transpose::No),
        (Transpose::No, Transpose::Yes),
        (Transpose::Yes, Transpose::Yes),
    ]
}

/// One dgemm call through `backend`, on strided storage, vs the oracle.
#[allow(clippy::too_many_arguments)]
fn check_one(backend: Backend, transa: Transpose, transb: Transpose, m: usize, n: usize, k: usize, alpha: f64, beta: f64, seed: u64) {
    let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
    let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
    let a = Matrix::<f64>::random_strided(ar, ac.max(1), ac.max(1) + 3, seed);
    let b = Matrix::<f64>::random_strided(br, bc.max(1), bc.max(1) + 1, seed ^ 0xAB);
    let mut c_got = Matrix::<f64>::random_strided(m, n.max(1), n.max(1) + 2, seed ^ 0xCD);
    let mut c_ref = c_got.clone();
    dgemm(
        backend,
        transa,
        transb,
        m,
        n,
        k,
        alpha,
        a.data(),
        a.ld(),
        b.data(),
        b.ld(),
        beta,
        c_got.data_mut(),
        c_got.ld(),
    )
    .unwrap();
    oracle(transa, transb, m, n, k, alpha, beta, &a, &b, &mut c_ref);
    assert_allclose_f64(
        c_got.data(),
        c_ref.data(),
        1e-12,
        1e-13,
        &format!("dgemm {} m={m} n={n} k={k} ta={transa:?} tb={transb:?} α={alpha} β={beta}", backend.name()),
    );
    // Strided C: the padding sentinels must survive every backend.
    for r in 0..m {
        for p in n..n + 2 {
            assert_eq!(
                c_got.data()[r * (n.max(1) + 2) + p],
                -77.0,
                "{}: padding clobbered at ({r},{p})",
                backend.name()
            );
        }
    }
}

#[test]
fn dgemm_fringe_grid_every_backend_every_layout() {
    hermetic_tune_cache();
    // {1, MR−1, MR+1, NR−1, NR+1} for the f64 tile (MR = 6, NR = 8) —
    // the same fringe cross the f32 suite runs at its tile geometry.
    let dims = [1usize, 5, 7, 15, 17];
    let scalars = [(1.0f64, 0.0f64), (0.5, 1.5), (0.0, 0.5)];
    let backends = [
        Backend::Naive,
        Backend::Blocked,
        Backend::Simd, // f32-only tier: must degrade and still conform
        Backend::Avx2,
        Backend::Avx2Tile,
        Backend::Dispatch,
    ];
    let mut seed = 0xD64u64;
    let mut case = 0usize;
    for &m in &dims {
        for &n in &dims {
            for &k in &dims {
                for &(ta, tb) in layouts().iter() {
                    // One backend per (m,n,k) cell, all four layouts per
                    // cell: the backend index advances per cell (case/4),
                    // so every backend meets every layout across the 125
                    // cells (each backend draws ~20 cells), while the
                    // scalar pair rotates per case (gcd(3, 4) = 1 covers
                    // every (layout, scalar) pairing too).
                    let (alpha, beta) = scalars[case % scalars.len()];
                    let backend = backends[(case / layouts().len()) % backends.len()];
                    if backend.resolve_ok() {
                        check_one(backend, ta, tb, m, n, k, alpha, beta, seed);
                    }
                    seed += 1;
                    case += 1;
                }
            }
        }
    }
}

#[test]
fn dgemm_block_boundary_crossers() {
    hermetic_tune_cache();
    // 257 crosses kc/mc/nc and each fringe; spot-check per axis plus the
    // full cube, rotating layouts.
    let mut seed = 0x257u64;
    for (i, &(m, n, k)) in
        [(257usize, 17usize, 7usize), (7, 257, 17), (17, 7, 257), (257, 257, 257)].iter().enumerate()
    {
        let (ta, tb) = layouts()[i % 4];
        seed += 1;
        check_one(Backend::Dispatch, ta, tb, m, n, k, 0.75, 0.5, seed);
    }
}

#[test]
fn dgemm_bitwise_stable_across_serial_parallel_prepacked() {
    hermetic_tune_cache();
    if !KernelId::Avx2Tile.available_for(ElementId::F64) {
        // Without AVX2+FMA the f64 serial ladder is the scalar blocked
        // proxy: select_t::<f64> early-returns Blocked before the
        // parallel check, and forced-Parallel f64 calls degrade to the
        // serial ladder (run()'s no-vector guard) — there is no parallel
        // f64 execution to compare. The oracle grid covers that
        // configuration.
        eprintln!("SKIP: no AVX2+FMA — no parallel f64 tier to compare");
        return;
    }
    // The acceptance contract: one f64 problem through the serial
    // driver, the thread-parallel tier and both prepacked planned paths
    // produces identical bits — per-element accumulation is pure k
    // order, fringe writeback rounds exactly like the vector writeback,
    // and the prepacked drivers issue identical kernel calls in
    // identical k order.
    let ctx_ser = GemmContext::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });
    let ctx_par = GemmContext::new(DispatchConfig {
        threads: 3,
        parallel_min_flops: 0.0,
        ..DispatchConfig::default()
    });
    let mut seed = 0xB64u64;
    for (ta, tb) in layouts() {
        for &(m, n, k) in &[(29usize, 23usize, 31usize), (2, 40, 13), (48, 9, 7), (61, 61, 61)] {
            seed += 1;
            let (ar, ac) = if ta == Transpose::No { (m, k) } else { (k, m) };
            let (br, bc) = if tb == Transpose::No { (k, n) } else { (n, k) };
            let a = Matrix::<f64>::random(ar, ac, seed, -1.0, 1.0);
            let b = Matrix::<f64>::random(br, bc, seed ^ 0x5, -1.0, 1.0);
            let c0: Vec<f64> = Matrix::<f64>::random(m, n, seed ^ 0x9, -1.0, 1.0).data().to_vec();

            let plan_ser = ctx_ser
                .gemm_for::<f64>()
                .transpose_a(ta)
                .transpose_b(tb)
                .alpha(0.5)
                .beta(1.25)
                .plan(m, n, k)
                .unwrap();
            let plan_par = ctx_par
                .gemm_for::<f64>()
                .transpose_a(ta)
                .transpose_b(tb)
                .alpha(0.5)
                .beta(1.25)
                .plan(m, n, k)
                .unwrap();
            assert_eq!(plan_par.kernel(), KernelId::Parallel, "threads=3 + zero threshold must parallelise");

            let mut c_serial = c0.clone();
            plan_ser.run(a.data(), b.data(), &mut c_serial).unwrap();
            let mut c_par = c0.clone();
            plan_par.run(a.data(), b.data(), &mut c_par).unwrap();
            assert_eq!(c_par, c_serial, "parallel dgemm must be bit-identical to serial ({m}x{n}x{k} {ta:?}{tb:?})");

            // Prepacked B (serial and parallel), then fully prepacked.
            // The gemv-shape guard can route m < tile_min_m plans to the
            // dot kernel while pack_b emits the tile layout on AVX2
            // hosts; the packed-path plans stay consistent because both
            // paths resolve the layout from the same dispatcher.
            let pb_ser = ctx_ser.pack_b(tb, k, n, b.data(), b.ld()).unwrap();
            let mut c_pb = c0.clone();
            plan_ser.run_packed_b(a.data(), &pb_ser, &mut c_pb).unwrap();
            let pb_par = ctx_par.pack_b(tb, k, n, b.data(), b.ld()).unwrap();
            let mut c_pb_par = c0.clone();
            plan_par.run_packed_b(a.data(), &pb_par, &mut c_pb_par).unwrap();
            assert_eq!(
                c_pb_par, c_pb,
                "parallel prepacked-B dgemm must be bit-identical to serial prepacked-B"
            );

            let pa_ser = ctx_ser.pack_a(ta, m, k, a.data(), a.ld()).unwrap();
            let mut c_pab = c0.clone();
            plan_ser.run_packed(&pa_ser, &pb_ser, &mut c_pab).unwrap();
            let pa_par = ctx_par.pack_a(ta, m, k, a.data(), a.ld()).unwrap();
            let mut c_pab_par = c0.clone();
            plan_par.run_packed(&pa_par, &pb_par, &mut c_pab_par).unwrap();
            assert_eq!(
                c_pab_par, c_pab,
                "parallel fully-prepacked dgemm must be bit-identical to serial"
            );

            // And every path conforms to the oracle.
            let mut c_ref = Matrix::<f64>::from_fn(m, n, |r, j| c0[r * n + j]);
            oracle(ta, tb, m, n, k, 0.5, 1.25, &a, &b, &mut c_ref);
            assert_allclose_f64(&c_serial, c_ref.data(), 1e-12, 1e-13, "serial vs oracle");
            assert_allclose_f64(&c_pb, c_ref.data(), 1e-12, 1e-13, "prepacked-B vs oracle");
            assert_allclose_f64(&c_pab, c_ref.data(), 1e-12, 1e-13, "fully prepacked vs oracle");
        }
    }
}

#[test]
fn dgemm_plan_rerun_is_bit_identical() {
    hermetic_tune_cache();
    let ctx = GemmContext::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });
    let (m, n, k) = (23usize, 17usize, 39usize);
    let a = Matrix::<f64>::random(m, k, 1, -1.0, 1.0);
    let b = Matrix::<f64>::random(k, n, 2, -1.0, 1.0);
    let plan = ctx.gemm_for::<f64>().alpha(0.75).beta(0.25).plan(m, n, k).unwrap();
    let c0: Vec<f64> = (0..m * n).map(|i| i as f64 * 0.01).collect();
    let mut c1 = c0.clone();
    let mut c2 = c0.clone();
    plan.run(a.data(), b.data(), &mut c1).unwrap();
    plan.run(a.data(), b.data(), &mut c2).unwrap();
    assert_eq!(c1, c2, "same plan, same inputs must be bit-identical");
}

#[test]
fn dgemm_batch_matches_per_item_loop() {
    hermetic_tune_cache();
    let (m, n, k, batch) = (5usize, 7usize, 9usize, 4usize);
    let mut rng = emmerald::util::prng::Pcg32::new(0xBA7);
    let a: Vec<f64> = (0..batch * m * k).map(|_| rng.f64() * 2.0 - 1.0).collect();
    let b: Vec<f64> = (0..batch * k * n).map(|_| rng.f64() * 2.0 - 1.0).collect();
    let c0: Vec<f64> = (0..batch * m * n).map(|_| rng.f64()).collect();
    for backend in [Backend::Naive, Backend::Dispatch] {
        let mut c_got = c0.clone();
        let mut c_ref = c0.clone();
        dgemm_batch(
            backend,
            Transpose::No,
            Transpose::No,
            m,
            n,
            k,
            1.25,
            &a,
            k,
            m * k,
            &b,
            n,
            k * n,
            0.5,
            &mut c_got,
            n,
            m * n,
            batch,
        )
        .unwrap();
        for i in 0..batch {
            dgemm(
                Backend::Naive,
                Transpose::No,
                Transpose::No,
                m,
                n,
                k,
                1.25,
                &a[i * m * k..],
                k,
                &b[i * k * n..],
                n,
                0.5,
                &mut c_ref[i * m * n..],
                n,
            )
            .unwrap();
        }
        assert_allclose_f64(&c_got, &c_ref, 1e-12, 1e-13, &format!("dgemm_batch {}", backend.name()));
    }
}

#[test]
fn dgemm_shared_b_fold_matches_per_item_loop() {
    hermetic_tune_cache();
    // The shared-B fold (stride_b == 0) in f64 — the weight-stationary
    // batched shape.
    let (m, n, k, batch) = (6usize, 10usize, 8usize, 3usize);
    let mut rng = emmerald::util::prng::Pcg32::new(0x5B64);
    let a: Vec<f64> = (0..batch * m * k).map(|_| rng.f64() * 2.0 - 1.0).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.f64() * 2.0 - 1.0).collect();
    let c0: Vec<f64> = (0..batch * m * n).map(|_| rng.f64()).collect();
    let mut c_got = c0.clone();
    let mut c_ref = c0.clone();
    dgemm_batch(
        Backend::Dispatch,
        Transpose::No,
        Transpose::No,
        m,
        n,
        k,
        1.0,
        &a,
        k,
        m * k,
        &b,
        n,
        0,
        -0.5,
        &mut c_got,
        n,
        m * n,
        batch,
    )
    .unwrap();
    for i in 0..batch {
        dgemm(
            Backend::Naive,
            Transpose::No,
            Transpose::No,
            m,
            n,
            k,
            1.0,
            &a[i * m * k..],
            k,
            &b,
            n,
            -0.5,
            &mut c_ref[i * m * n..],
            n,
        )
        .unwrap();
    }
    assert_allclose_f64(&c_got, &c_ref, 1e-12, 1e-13, "dgemm shared-B fold");
}

#[test]
fn f64_selection_never_picks_f32_only_tiers() {
    hermetic_tune_cache();
    // The per-element kernel table: f64 has no SSE rung in any shape
    // regime — but unlike the old f32-only Strassen tier, the
    // fast-matmul family *is* open to f64, so the single-threaded
    // huge-square regime now selects `FastMm` for both elements.
    use emmerald::gemm::dispatch::GemmShape;
    use emmerald::gemm::{FastAlgoId, FastmmChoice, FastmmTable};
    let d = emmerald::gemm::GemmDispatch::new(DispatchConfig {
        threads: 1,
        fastmm: FastmmTable::uniform(FastmmChoice {
            algo: FastAlgoId::Strassen222,
            crossover: 256,
            min_dim: 64,
        }),
        ..DispatchConfig::default()
    });
    for &(m, n, k) in &[(8usize, 8usize, 8usize), (64, 64, 64), (300, 300, 300), (1, 512, 512)] {
        let shape = GemmShape { m, n, k, transa: Transpose::No, transb: Transpose::No };
        let picked = d.select_t::<f64>(&shape, 1.0f64);
        assert_ne!(picked, KernelId::Simd, "f64 must not select the SSE tier ({m}x{n}x{k})");
        assert!(picked.available_for(ElementId::F64), "{picked:?} unavailable for f64");
    }
    // The fast tier needs a vector base case to recurse onto; with AVX2
    // present, f64 selects it where f32 does (behaviour new in the
    // fast-matmul family — the old Strassen tier excluded f64 by type).
    let shape = GemmShape { m: 300, n: 300, k: 300, transa: Transpose::No, transb: Transpose::No };
    if KernelId::Avx2.available_for(ElementId::F64) {
        assert_eq!(d.select_t::<f64>(&shape, 1.0f64), KernelId::FastMm);
    }
    if KernelId::Simd.available_for(ElementId::F32) {
        assert_eq!(d.select_t::<f32>(&shape, 1.0f32), KernelId::FastMm);
    }
}

#[test]
fn prop_compensated_f32_no_worse_than_plain_on_ill_conditioned_sums() {
    // The compensated-accumulation acceptance property: on summands with
    // heavy cancellation, CompensatedF32's error vs the f64 oracle is
    // ≤ the plain-f32 kernels' error. Runs end-to-end through dispatch
    // (DispatchConfig::accumulation), random shapes and magnitudes.
    check("compensated ≤ plain", 25, |g| {
        let m = g.dim(12);
        let n = g.dim(10);
        let k = 64 + g.rng.range_usize(0, 1500);
        let big = [1.0e3f32, 3.0e4, 1.0e6][g.rng.range_usize(0, 2)];
        let mut a32 = Matrix::<f32>::zeros(m, k);
        for r in 0..m {
            for p in 0..k {
                let sign = if p % 2 == 0 { 1.0 } else { -1.0 };
                a32.set(r, p, sign * big + g.rng.f32_range(-1.0, 1.0));
            }
        }
        let mut b32 = Matrix::<f32>::zeros(k, n);
        for p in 0..k {
            for j in 0..n {
                b32.set(p, j, 1.0 + g.rng.f32_range(-1.0e-3, 1.0e-3));
            }
        }
        // f64 oracle of the exact same f32 inputs.
        let a64 = Matrix::<f64>::from_fn(m, k, |r, p| a32.get(r, p) as f64);
        let b64 = Matrix::<f64>::from_fn(k, n, |p, j| b32.get(p, j) as f64);
        let mut c64 = Matrix::<f64>::zeros(m, n);
        oracle(Transpose::No, Transpose::No, m, n, k, 1.0, 0.0, &a64, &b64, &mut c64);

        let plain_ctx = GemmContext::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });
        let comp_ctx = GemmContext::new(DispatchConfig {
            threads: 1,
            accumulation: Accumulation::CompensatedF32,
            ..DispatchConfig::default()
        });
        let mut c_plain = vec![0.0f32; m * n];
        plain_ctx.gemm().plan(m, n, k).unwrap().run(a32.data(), b32.data(), &mut c_plain).unwrap();
        let mut c_comp = vec![0.0f32; m * n];
        comp_ctx.gemm().plan(m, n, k).unwrap().run(a32.data(), b32.data(), &mut c_comp).unwrap();

        let mut err_plain = 0.0f64;
        let mut err_comp = 0.0f64;
        for i in 0..m * n {
            let want = c64.data()[i];
            err_plain = err_plain.max((c_plain[i] as f64 - want).abs());
            err_comp = err_comp.max((c_comp[i] as f64 - want).abs());
        }
        assert!(
            err_comp <= err_plain,
            "case {}: comp {err_comp:e} > plain {err_plain:e} (m={m} n={n} k={k} big={big})",
            g.case
        );
    });
}

#[test]
fn compensated_mode_is_bitwise_split_invariant() {
    hermetic_tune_cache();
    // Parallel compensated slices must reproduce the serial compensated
    // run exactly (per-element Dot2 is independent and k-ordered).
    let (m, n, k) = (17usize, 13usize, 700usize);
    let a = Matrix::<f32>::random(m, k, 11, -1.0, 1.0);
    let b = Matrix::<f32>::random(k, n, 12, -1.0, 1.0);
    let ser = GemmContext::new(DispatchConfig {
        threads: 1,
        accumulation: Accumulation::CompensatedF32,
        ..DispatchConfig::default()
    });
    let par = GemmContext::new(DispatchConfig {
        threads: 3,
        parallel_min_flops: 0.0,
        accumulation: Accumulation::CompensatedF32,
        ..DispatchConfig::default()
    });
    let mut c_ser = vec![0.0f32; m * n];
    ser.gemm().plan(m, n, k).unwrap().run(a.data(), b.data(), &mut c_ser).unwrap();
    let mut c_par = vec![0.0f32; m * n];
    let plan = par.gemm().plan(m, n, k).unwrap();
    plan.run(a.data(), b.data(), &mut c_par).unwrap();
    assert_eq!(c_par, c_ser, "compensated parallel run must be bit-identical to serial");
}

#[test]
fn dpotrf_agrees_with_spotrf_to_f32_accuracy() {
    hermetic_tune_cache();
    // Cross-precision sanity: factor the same SPD system in both
    // precisions; the f32 factor must match the f64 one to f32 accuracy.
    let n = 96usize;
    let x = Matrix::<f64>::random(n + 16, n, 5, -1.0, 1.0);
    let mut a64 = Matrix::<f64>::zeros(n, n);
    emmerald::blas::dgemm_matrix(Backend::Naive, Transpose::Yes, Transpose::No, 1.0, &x, &x, 0.0, &mut a64)
        .unwrap();
    for i in 0..n {
        a64.set(i, i, a64.get(i, i) + n as f64 * 0.1 + 1.0);
    }
    let a32 = Matrix::<f32>::from_fn(n, n, |r, c| a64.get(r, c) as f32);
    let l64 = emmerald::lapack::dpotrf(&a64, Backend::Auto).unwrap();
    let l32 = emmerald::lapack::cholesky_blocked(&a32, Backend::Auto).unwrap();
    for i in 0..n {
        for j in 0..=i {
            let want = l64.get(i, j);
            let got = l32.get(i, j) as f64;
            assert!(
                (got - want).abs() < 1e-2 * (1.0 + want.abs()),
                "L({i},{j}): f32 {got} vs f64 {want}"
            );
        }
    }
}

/// `Backend::resolve` is crate-private; probe availability through the
/// public surface instead.
trait ResolveOk {
    fn resolve_ok(&self) -> bool;
}

impl ResolveOk for Backend {
    fn resolve_ok(&self) -> bool {
        emmerald::blas::available_backends().contains(self) || matches!(self, Backend::Auto)
    }
}
