//! Property-based tests: every optimised backend ≍ naive over random
//! shapes, strides, transposes and scalars (the testkit substrate replaces
//! proptest in this offline build).

use emmerald::blas::{sgemm, sgemm_batch, Backend, GemmContext, Matrix, Transpose};
use emmerald::gemm::pack::{kpad_for, PackedB};
use emmerald::gemm::{Activation, BlockParams, DispatchConfig, Epilogue, TileParams, Unroll};
use emmerald::util::testkit::{assert_allclose, check, Gen};

fn random_case(g: &mut Gen, backend: Backend) {
    let m = g.dim(48);
    let n = g.dim(48);
    let k = g.dim(96);
    let transa = g.rng.chance(0.5);
    let transb = g.rng.chance(0.5);
    let (ar, ac) = if transa { (k, m) } else { (m, k) };
    let (br, bc) = if transb { (n, k) } else { (k, n) };
    let lda = ac + g.rng.range_usize(0, 5);
    let ldb = bc + g.rng.range_usize(0, 3);
    let ldc = n + g.rng.range_usize(0, 4);
    let a = Matrix::random_strided(ar, ac, lda, g.rng.next_u64());
    let b = Matrix::random_strided(br, bc, ldb, g.rng.next_u64());
    let c0 = Matrix::random_strided(m, n, ldc, g.rng.next_u64());
    let alpha = g.rng.f32_range(-2.0, 2.0);
    let beta = if g.rng.chance(0.3) { 0.0 } else { g.rng.f32_range(-1.5, 1.5) };
    let ta = if transa { Transpose::Yes } else { Transpose::No };
    let tb = if transb { Transpose::Yes } else { Transpose::No };

    let mut c_got = c0.clone();
    let mut c_ref = c0.clone();
    sgemm(backend, ta, tb, m, n, k, alpha, a.data(), lda, b.data(), ldb, beta, c_got.data_mut(), ldc)
        .unwrap();
    sgemm(Backend::Naive, ta, tb, m, n, k, alpha, a.data(), lda, b.data(), ldb, beta, c_ref.data_mut(), ldc)
        .unwrap();
    assert_allclose(
        c_got.data(),
        c_ref.data(),
        5e-4,
        1e-4,
        &format!("{} m={m} n={n} k={k} ta={transa} tb={transb} α={alpha} β={beta}", backend.name()),
    );
}

#[test]
fn prop_simd_matches_naive() {
    check("simd ≍ naive", 120, |g| random_case(g, Backend::Simd));
}

#[test]
fn prop_dispatch_matches_naive() {
    // The dispatcher is the new default (`Backend::Auto`); it must hold
    // the same contract as every explicit backend over the full random
    // shape/stride/transpose/scalar space.
    check("dispatch ≍ naive", 120, |g| random_case(g, Backend::Dispatch));
}

#[test]
fn prop_gemm_batch_matches_per_item_naive() {
    // The batched API against the obvious oracle: a per-item naive loop.
    // Random batch counts, random per-operand batch strides (minimal,
    // padded, or 0 = broadcast for A/B), random leading dimensions, and
    // `Gen::dim` edge shapes.
    check("gemm_batch ≍ per-item naive", 50, |g| {
        let batch = g.rng.range_usize(1, 5);
        let m = g.dim(20);
        let n = g.dim(20);
        let k = g.dim(32);
        let transa = if g.rng.chance(0.5) { Transpose::Yes } else { Transpose::No };
        let transb = if g.rng.chance(0.5) { Transpose::Yes } else { Transpose::No };
        let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
        let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
        let lda = ac + g.rng.range_usize(0, 4);
        let ldb = bc + g.rng.range_usize(0, 3);
        let ldc = n + g.rng.range_usize(0, 3);
        let a_item = (ar - 1) * lda + ac;
        let b_item = (br - 1) * ldb + bc;
        let c_item = (m - 1) * ldc + n;
        // Broadcast / dense / padded strides for the read-only operands;
        // dense or padded (never overlapping) for C.
        let stride_a =
            if g.rng.chance(0.25) { 0 } else { a_item + g.rng.range_usize(0, 9) };
        let stride_b =
            if g.rng.chance(0.25) { 0 } else { b_item + g.rng.range_usize(0, 7) };
        let stride_c = c_item + g.rng.range_usize(0, 8);
        let a = g.matrix(1, (batch - 1) * stride_a + a_item);
        let b = g.matrix(1, (batch - 1) * stride_b + b_item);
        let c0 = g.matrix(1, (batch - 1) * stride_c + c_item);
        let alpha = g.rng.f32_range(-2.0, 2.0);
        let beta = if g.rng.chance(0.3) { 0.0 } else { g.rng.f32_range(-1.5, 1.5) };

        let mut c_got = c0.clone();
        sgemm_batch(
            Backend::Dispatch,
            transa,
            transb,
            m,
            n,
            k,
            alpha,
            &a,
            lda,
            stride_a,
            &b,
            ldb,
            stride_b,
            beta,
            &mut c_got,
            ldc,
            stride_c,
            batch,
        )
        .unwrap();

        let mut c_ref = c0.clone();
        for i in 0..batch {
            sgemm(
                Backend::Naive,
                transa,
                transb,
                m,
                n,
                k,
                alpha,
                &a[i * stride_a..],
                lda,
                &b[i * stride_b..],
                ldb,
                beta,
                &mut c_ref[i * stride_c..],
                ldc,
            )
            .unwrap();
        }
        assert_allclose(
            &c_got,
            &c_ref,
            5e-4,
            1e-4,
            &format!(
                "batch={batch} m={m} n={n} k={k} ta={transa:?} tb={transb:?} sa={stride_a} sb={stride_b} sc={stride_c}"
            ),
        );
        // Inter-item C padding must be untouched.
        for i in 0..batch.saturating_sub(1) {
            for p in c_item..stride_c {
                let idx = i * stride_c + p;
                assert_eq!(c_got[idx], c0[idx], "batch padding clobbered at item {i} off {p}");
            }
        }
    });
}

#[test]
fn prop_blocked_matches_naive() {
    check("blocked ≍ naive", 120, |g| random_case(g, Backend::Blocked));
}

#[test]
fn prop_avx2_matches_naive() {
    if !(std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma"))
    {
        eprintln!("SKIP: no AVX2+FMA");
        return;
    }
    check("avx2 ≍ naive", 120, |g| random_case(g, Backend::Avx2));
}

#[test]
fn prop_tile_backend_matches_naive() {
    if !emmerald::gemm::KernelId::Avx2Tile.available() {
        eprintln!("SKIP: no AVX2+FMA");
        return;
    }
    check("avx2-tile ≍ naive", 120, |g| random_case(g, Backend::Avx2Tile));
}

#[test]
fn prop_tile_random_geometry_is_always_correct() {
    // The tile driver must be correct for *any* legal tile geometry (the
    // tile autotuner's safety property), across random shapes, strides,
    // transposes and scalars. Runs the AVX2 micro-kernel where available
    // and the scalar reference tile elsewhere.
    check("tile geometry", 60, |g| {
        let mr = g.rng.range_usize(1, 6);
        let p = TileParams {
            mr,
            nr: 16,
            kc: g.rng.range_usize(1, 80),
            mc: mr * g.rng.range_usize(1, 6),
            nc: 16 * g.rng.range_usize(1, 4),
            prefetch: g.rng.chance(0.5),
        };
        let m = g.dim(40);
        let n = g.dim(40);
        let k = g.dim(90);
        let transa = if g.rng.chance(0.5) { Transpose::Yes } else { Transpose::No };
        let transb = if g.rng.chance(0.5) { Transpose::Yes } else { Transpose::No };
        let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
        let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
        let a = Matrix::random_strided(ar, ac, ac + g.rng.range_usize(0, 4), g.rng.next_u64());
        let b = Matrix::random_strided(br, bc, bc + g.rng.range_usize(0, 3), g.rng.next_u64());
        let mut c_got = Matrix::random_strided(m, n, n + g.rng.range_usize(0, 4), g.rng.next_u64());
        let mut c_ref = c_got.clone();
        let alpha = g.rng.f32_range(-2.0, 2.0);
        let beta = if g.rng.chance(0.3) { 0.0 } else { g.rng.f32_range(-1.5, 1.5) };
        emmerald::gemm::tile::gemm(&p, transa, transb, alpha, a.view(), b.view(), beta, &mut c_got.view_mut());
        emmerald::gemm::naive::gemm(transa, transb, alpha, a.view(), b.view(), beta, &mut c_ref.view_mut());
        assert_allclose(c_got.data(), c_ref.data(), 5e-4, 1e-4, &format!("tile geometry {p:?}"));
    });
}

#[test]
fn prop_tile_plan_reruns_bitwise_and_matches_prepacked() {
    // Planned tile execution: re-running one plan is bit-stable, and a
    // prepacked-B run agrees bitwise with the unpacked run whenever the
    // prepack carries the tile layout (AVX2 hosts; the dot layout keeps
    // its own bitwise guarantees in plan_reuse.rs).
    check("tile plan rerun", 30, |g| {
        let ctx = emmerald::blas::GemmContext::new(emmerald::gemm::DispatchConfig {
            threads: 1,
            ..emmerald::gemm::DispatchConfig::default()
        });
        let m = g.dim(40).max(4);
        let n = g.dim(40);
        let k = g.dim(60);
        let a = Matrix::random(m, k, g.rng.next_u64(), -1.0, 1.0);
        let b = Matrix::random(k, n, g.rng.next_u64(), -1.0, 1.0);
        let plan = ctx
            .gemm()
            .kernel(emmerald::gemm::KernelId::Avx2Tile)
            .beta(0.25)
            .plan(m, n, k)
            .unwrap();
        let c0 = g.matrix(m, n);
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        plan.run(a.data(), b.data(), &mut c1).unwrap();
        plan.run(a.data(), b.data(), &mut c2).unwrap();
        assert_eq!(c1, c2, "plan rerun must be bit-identical");
        if emmerald::gemm::KernelId::Avx2Tile.available() {
            let pb = ctx.pack_b(Transpose::No, k, n, b.data(), b.ld()).unwrap();
            assert!(pb.is_tile());
            let mut c3 = c0.clone();
            plan.run_packed_b(a.data(), &pb, &mut c3).unwrap();
            assert_eq!(c3, c1, "prepacked tile B must match the packing run bitwise");
        }
    });
}

#[test]
fn prop_random_block_geometry_is_always_correct() {
    // The driver must be correct for *any* legal block geometry, not just
    // the tuned ones (this is what makes the autotuner safe).
    check("simd geometry", 60, |g| {
        let p = BlockParams {
            kb: g.rng.range_usize(1, 80),
            mb: g.rng.range_usize(1, 40),
            nr: g.rng.range_usize(1, 8),
            unroll: [Unroll::X1, Unroll::X2, Unroll::X4][g.rng.range_usize(0, 2)],
            prefetch: g.rng.chance(0.5),
            pack_b: g.rng.chance(0.8),
            pack_a: g.rng.chance(0.3),
        };
        let m = g.dim(40);
        let n = g.dim(40);
        let k = g.dim(90);
        let a = Matrix::random(m, k, g.rng.next_u64(), -1.0, 1.0);
        let b = Matrix::random(k, n, g.rng.next_u64(), -1.0, 1.0);
        let mut c_got = Matrix::zeros(m, n);
        let mut c_ref = Matrix::zeros(m, n);
        emmerald::gemm::simd::gemm(
            &p,
            Transpose::No,
            Transpose::No,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut c_got.view_mut(),
        );
        emmerald::gemm::naive::gemm(
            Transpose::No,
            Transpose::No,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut c_ref.view_mut(),
        );
        assert_allclose(c_got.data(), c_ref.data(), 5e-4, 1e-4, &format!("geometry {p:?}"));
    });
}

#[test]
fn prop_packed_b_is_a_permutation_of_the_block() {
    // Packing must copy every element of the k-block exactly once, pad
    // with zeros, and place column j at panel j/nr, lane j%nr.
    check("packB permutation", 80, |g| {
        let rows = g.dim(40);
        let cols = g.dim(30);
        let b = Matrix::<f32>::random(rows, cols, g.rng.next_u64(), -1.0, 1.0);
        let nr = g.rng.range_usize(1, 8);
        let kk = g.rng.range_usize(0, rows - 1);
        let kb_eff = g.rng.range_usize(1, rows - kk);
        let mut pb = PackedB::new(nr);
        pb.pack(b.view(), Transpose::No, kk, kb_eff, cols);
        assert_eq!(pb.kpad(), kpad_for(kb_eff));
        for j in 0..cols {
            let (panel, lane) = (j / nr, j % nr);
            let col = pb.col_ptr(panel, lane);
            for p in 0..pb.kpad() {
                let got = unsafe { *col.add(p) };
                let want = if p < kb_eff { b.get(kk + p, j) } else { 0.0 };
                assert_eq!(got, want, "col {j} p {p}");
            }
        }
    });
}

/// A random epilogue: any bias shape, any activation, optional clamp.
fn random_epilogue(g: &mut Gen, m: usize, n: usize) -> Epilogue {
    let mut ep = Epilogue::new();
    match g.rng.range_usize(0, 2) {
        0 => {}
        1 => ep = ep.bias_row((0..n).map(|_| g.rng.f32_range(-1.0, 1.0)).collect()),
        _ => ep = ep.bias_col((0..m).map(|_| g.rng.f32_range(-1.0, 1.0)).collect()),
    }
    ep = ep.activation(
        [Activation::None, Activation::Relu, Activation::Gelu, Activation::Tanh]
            [g.rng.range_usize(0, 3)],
    );
    if g.rng.chance(0.4) {
        let lo = g.rng.f32_range(-1.0, 0.0);
        let hi = g.rng.f32_range(0.0, 1.0);
        ep = ep.clamp(lo, hi);
    }
    ep
}

#[test]
fn prop_fused_epilogue_matches_post_pass() {
    // The epilogue contract over the full random space: a fused plan
    // produces exactly the bits of the same plan without an epilogue
    // followed by a separate apply pass. Bitwise — the fused writeback
    // runs the identical scalar function on the identical accumulated
    // value, so in particular the bias add is exact when β == 0.
    let ctx = GemmContext::new(DispatchConfig::default());
    check("fused epilogue ≍ post-pass", 80, |g| {
        let m = g.dim(40);
        let n = g.dim(40);
        let k = g.dim(64);
        let transa = if g.rng.chance(0.5) { Transpose::Yes } else { Transpose::No };
        let transb = if g.rng.chance(0.5) { Transpose::Yes } else { Transpose::No };
        let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
        let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
        let a = Matrix::random_strided(ar, ac, ac + g.rng.range_usize(0, 4), g.rng.next_u64());
        let b = Matrix::random_strided(br, bc, bc + g.rng.range_usize(0, 3), g.rng.next_u64());
        let c0 = Matrix::random_strided(m, n, n + g.rng.range_usize(0, 4), g.rng.next_u64());
        let alpha = g.rng.f32_range(-2.0, 2.0);
        let beta = if g.rng.chance(0.4) { 0.0 } else { g.rng.f32_range(-1.5, 1.5) };
        let ep = random_epilogue(g, m, n);

        let mut c_got = c0.clone();
        ctx.gemm()
            .transpose_a(transa)
            .transpose_b(transb)
            .alpha(alpha)
            .beta(beta)
            .lda(a.ld())
            .ldb(b.ld())
            .ldc(c_got.ld())
            .epilogue(ep.clone())
            .plan(m, n, k)
            .unwrap()
            .run(a.data(), b.data(), c_got.data_mut())
            .unwrap();

        let mut c_ref = c0.clone();
        ctx.gemm()
            .transpose_a(transa)
            .transpose_b(transb)
            .alpha(alpha)
            .beta(beta)
            .lda(a.ld())
            .ldb(b.ld())
            .ldc(c_ref.ld())
            .plan(m, n, k)
            .unwrap()
            .run(a.data(), b.data(), c_ref.data_mut())
            .unwrap();
        ep.apply(&mut c_ref.view_mut(), 0, 0);

        assert_eq!(
            c_got.data(),
            c_ref.data(),
            "fused != post-pass bits (m={m} n={n} k={k} ta={transa:?} tb={transb:?} α={alpha} β={beta})"
        );
    });
}

#[test]
fn prop_identity_epilogue_is_plain_gemm() {
    // An all-defaults epilogue must be a bitwise no-op: attaching it to a
    // plan changes nothing about the result.
    let ctx = GemmContext::new(DispatchConfig::default());
    check("identity epilogue ≍ plain plan", 40, |g| {
        let m = g.dim(32);
        let n = g.dim(32);
        let k = g.dim(48);
        let a = Matrix::random(m, k, g.rng.next_u64(), -1.0, 1.0);
        let b = Matrix::random(k, n, g.rng.next_u64(), -1.0, 1.0);
        let c0 = g.matrix(m, n);
        let beta = g.rng.f32_range(-1.0, 1.0);

        let mut c_id = c0.clone();
        ctx.gemm()
            .beta(beta)
            .epilogue(Epilogue::new())
            .plan(m, n, k)
            .unwrap()
            .run(a.data(), b.data(), &mut c_id)
            .unwrap();

        let mut c_plain = c0.clone();
        ctx.gemm()
            .beta(beta)
            .plan(m, n, k)
            .unwrap()
            .run(a.data(), b.data(), &mut c_plain)
            .unwrap();
        assert_eq!(c_id, c_plain, "identity epilogue changed bits (m={m} n={n} k={k})");
    });
}

#[test]
fn prop_fused_plan_rerun_is_bit_stable() {
    // Re-running one fused plan on the same inputs is deterministic.
    let ctx = GemmContext::new(DispatchConfig::default());
    check("fused plan rerun", 30, |g| {
        let m = g.dim(32);
        let n = g.dim(32);
        let k = g.dim(48);
        let a = Matrix::random(m, k, g.rng.next_u64(), -1.0, 1.0);
        let b = Matrix::random(k, n, g.rng.next_u64(), -1.0, 1.0);
        let c0 = g.matrix(m, n);
        let ep = random_epilogue(g, m, n);
        let plan = ctx.gemm().beta(0.25).epilogue(ep).plan(m, n, k).unwrap();
        let mut c1 = c0.clone();
        let mut c2 = c0;
        plan.run(a.data(), b.data(), &mut c1).unwrap();
        plan.run(a.data(), b.data(), &mut c2).unwrap();
        assert_eq!(c1, c2, "fused plan rerun must be bit-identical (m={m} n={n} k={k})");
    });
}

#[test]
fn prop_scale_invariance() {
    // sgemm(α·A, B) == α · sgemm(A, B) for the SIMD backend (exact for
    // powers of two).
    check("scale invariance", 40, |g| {
        let m = g.dim(24);
        let n = g.dim(24);
        let k = g.dim(48);
        let a = Matrix::random(m, k, g.rng.next_u64(), -1.0, 1.0);
        let b = Matrix::random(k, n, g.rng.next_u64(), -1.0, 1.0);
        let a2 = Matrix::from_fn(m, k, |r, c| 2.0 * a.get(r, c));
        let mut c1 = Matrix::zeros(m, n);
        let mut c2 = Matrix::zeros(m, n);
        emmerald::blas::sgemm_matrix(Backend::Simd, Transpose::No, Transpose::No, 1.0, &a2, &b, 0.0, &mut c1)
            .unwrap();
        emmerald::blas::sgemm_matrix(Backend::Simd, Transpose::No, Transpose::No, 2.0, &a, &b, 0.0, &mut c2)
            .unwrap();
        assert_allclose(c1.data(), c2.data(), 1e-6, 1e-6, "2A·B vs 2·(A·B)");
    });
}
