//! Integration tests for the public BLAS API across all backends.

use emmerald::blas::{
    available_backends, sgemm, sgemm_matrix, Backend, BlasError, Matrix, Transpose,
};
use emmerald::util::testkit::{assert_allclose, hermetic_tune_cache};

fn square(backend: Backend, n: usize, a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(n, n);
    let ldc = c.ld();
    sgemm(
        backend,
        Transpose::No,
        Transpose::No,
        n,
        n,
        n,
        1.0,
        a.data(),
        a.ld(),
        b.data(),
        b.ld(),
        0.0,
        c.data_mut(),
        ldc,
    )
    .unwrap();
    c
}

#[test]
fn all_backends_agree_at_multiple_sizes() {
    hermetic_tune_cache();
    for &n in &[1usize, 17, 64, 130, 320] {
        let a = Matrix::random(n, n, n as u64, -1.0, 1.0);
        let b = Matrix::random(n, n, (n + 1) as u64, -1.0, 1.0);
        let c_ref = square(Backend::Naive, n, &a, &b);
        for backend in available_backends() {
            let c = square(backend, n, &a, &b);
            assert_allclose(
                c.data(),
                c_ref.data(),
                2e-4,
                1e-4,
                &format!("{} at n={n}", backend.name()),
            );
        }
    }
}

#[test]
fn paper_methodology_fixed_stride_700() {
    hermetic_tune_cache();
    // The paper's benchmark layout: logical size < stride = 700.
    let (n, stride) = (96usize, 700usize);
    let a = Matrix::random_strided(n, n, stride, 1);
    let b = Matrix::random_strided(n, n, stride, 2);
    let mut c_ref = Matrix::zeros_strided(n, n, stride);
    let ld = stride;
    sgemm(Backend::Naive, Transpose::No, Transpose::No, n, n, n, 1.0, a.data(), ld, b.data(), ld, 0.0, c_ref.data_mut(), ld)
        .unwrap();
    for backend in available_backends() {
        let mut c = Matrix::zeros_strided(n, n, stride);
        sgemm(backend, Transpose::No, Transpose::No, n, n, n, 1.0, a.data(), ld, b.data(), ld, 0.0, c.data_mut(), ld)
            .unwrap();
        assert!(c.max_abs_diff(&c_ref) < 1e-3, "{} strided", backend.name());
        // Row padding must be untouched (zeros_strided starts at 0).
        assert_eq!(c.data()[n], 0.0, "{} wrote into padding", backend.name());
    }
}

#[test]
fn rectangular_and_transposed_combinations() {
    hermetic_tune_cache();
    let (m, n, k) = (33, 47, 129);
    for backend in available_backends() {
        for (ta, tb) in [
            (Transpose::No, Transpose::No),
            (Transpose::Yes, Transpose::No),
            (Transpose::No, Transpose::Yes),
            (Transpose::Yes, Transpose::Yes),
        ] {
            let a = if ta == Transpose::No {
                Matrix::random(m, k, 7, -1.0, 1.0)
            } else {
                Matrix::random(k, m, 7, -1.0, 1.0)
            };
            let b = if tb == Transpose::No {
                Matrix::random(k, n, 8, -1.0, 1.0)
            } else {
                Matrix::random(n, k, 8, -1.0, 1.0)
            };
            let mut c = Matrix::from_fn(m, n, |r, c| (r + c) as f32 * 0.1);
            let mut c_ref = c.clone();
            sgemm_matrix(backend, ta, tb, 0.7, &a, &b, 1.3, &mut c).unwrap();
            sgemm_matrix(Backend::Naive, ta, tb, 0.7, &a, &b, 1.3, &mut c_ref).unwrap();
            assert!(
                c.max_abs_diff(&c_ref) < 1e-3,
                "{} ta={ta:?} tb={tb:?}",
                backend.name()
            );
        }
    }
}

#[test]
fn error_paths_are_reported() {
    hermetic_tune_cache();
    let a = vec![0.0f32; 10];
    let b = vec![0.0f32; 10];
    let mut c = vec![0.0f32; 10];
    // Bad ld.
    let err =
        sgemm(Backend::Simd, Transpose::No, Transpose::No, 2, 2, 5, 1.0, &a, 3, &b, 2, 0.0, &mut c, 2);
    assert!(matches!(err, Err(BlasError::BadLeadingDim { .. })));
    // Short C buffer.
    let err =
        sgemm(Backend::Simd, Transpose::No, Transpose::No, 4, 4, 2, 1.0, &a, 2, &b, 4, 0.0, &mut c, 4);
    assert!(matches!(err, Err(BlasError::BufferTooSmall { operand: "C", .. })));
}

#[test]
fn beta_zero_overwrites_nan_poisoned_c() {
    hermetic_tune_cache();
    // BLAS semantics: beta = 0 must ignore (not propagate) old C contents.
    let n = 8;
    let a = Matrix::random(n, n, 3, -1.0, 1.0);
    let b = Matrix::random(n, n, 4, -1.0, 1.0);
    for backend in available_backends() {
        let mut c = Matrix::from_fn(n, n, |_, _| f32::NAN);
        let ldc = c.ld();
        sgemm(backend, Transpose::No, Transpose::No, n, n, n, 1.0, a.data(), n, b.data(), n, 0.0, c.data_mut(), ldc)
            .unwrap();
        assert!(
            c.data().iter().all(|v| v.is_finite()),
            "{} propagated NaN through beta=0",
            backend.name()
        );
    }
}

#[test]
fn accumulation_chains_compose() {
    hermetic_tune_cache();
    // C = A·B computed in two k-halves with beta=1 must equal one shot.
    let (m, n, k) = (24, 31, 64);
    let a = Matrix::random(m, k, 5, -1.0, 1.0);
    let b = Matrix::random(k, n, 6, -1.0, 1.0);
    for backend in available_backends() {
        let mut once = Matrix::zeros(m, n);
        sgemm_matrix(backend, Transpose::No, Transpose::No, 1.0, &a, &b, 0.0, &mut once).unwrap();

        // Two halves via views over the same storage.
        let a1 = Matrix::from_fn(m, k / 2, |r, c| a.get(r, c));
        let a2 = Matrix::from_fn(m, k - k / 2, |r, c| a.get(r, c + k / 2));
        let b1 = Matrix::from_fn(k / 2, n, |r, c| b.get(r, c));
        let b2 = Matrix::from_fn(k - k / 2, n, |r, c| b.get(r + k / 2, c));
        let mut twice = Matrix::zeros(m, n);
        sgemm_matrix(backend, Transpose::No, Transpose::No, 1.0, &a1, &b1, 0.0, &mut twice).unwrap();
        sgemm_matrix(backend, Transpose::No, Transpose::No, 1.0, &a2, &b2, 1.0, &mut twice).unwrap();
        assert!(once.max_abs_diff(&twice) < 1e-3, "{} split-k", backend.name());
    }
}
