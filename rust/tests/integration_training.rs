//! End-to-end training integration: the coordinator over real engines.

use emmerald::blas::Backend;
use emmerald::coordinator::{Coordinator, EngineFactory, NativeEngine, PjrtEngine, TrainConfig};
use emmerald::nn::{Dataset, Mlp};
use emmerald::util::testkit::hermetic_tune_cache;
use std::sync::Arc;

#[test]
fn threaded_native_training_converges() {
    hermetic_tune_cache();
    let sizes = [16, 32, 4];
    let mlp = Mlp::init(&sizes, 3, Backend::Simd);
    let data = Dataset::gaussian_clusters(512, 16, 4, 0.4, 17);
    let cfg = TrainConfig { workers: 4, shard_batch: 32, steps: 60, lr: 0.4, log_every: 0 };
    let mut coord = Coordinator::new(cfg, mlp, data).unwrap();
    let factory: Arc<EngineFactory> =
        Arc::new(|_| Ok(Box::new(NativeEngine::new(Backend::Simd)) as _));
    let r = coord.train_threaded(factory).unwrap();
    assert!(r.final_loss < 0.5 * r.first_loss(), "{} -> {}", r.first_loss(), r.final_loss);
    assert!(r.final_accuracy > 0.85, "accuracy {}", r.final_accuracy);
    assert!(r.total_flops > 0.0);
    // Loss curve is recorded per step (the E2E deliverable's evidence).
    assert_eq!(r.steps.len(), 60);
}

#[test]
fn native_backends_train_identically() {
    hermetic_tune_cache();
    // The loss trajectory must not depend on which SGEMM backend computes
    // it (same flops, same order of averaging).
    let run = |backend: Backend| {
        let mlp = Mlp::init(&[8, 16, 3], 5, backend);
        let data = Dataset::gaussian_clusters(128, 8, 3, 0.3, 7);
        let cfg = TrainConfig { workers: 2, shard_batch: 16, steps: 10, lr: 0.3, log_every: 0 };
        let mut coord = Coordinator::new(cfg, mlp, data).unwrap();
        let mut engine = NativeEngine::new(backend);
        coord.train_sequential(&mut engine).unwrap()
    };
    let a = run(Backend::Naive);
    let b = run(Backend::Simd);
    for (sa, sb) in a.steps.iter().zip(&b.steps) {
        assert!(
            (sa.loss - sb.loss).abs() < 2e-3 * (1.0 + sa.loss.abs()),
            "step {}: naive {} vs simd {}",
            sa.step,
            sa.loss,
            sb.loss
        );
    }
}

#[test]
fn pjrt_training_end_to_end() {
    hermetic_tune_cache();
    // The full three-layer stack: Rust coordinator → PJRT runtime → HLO
    // artifact containing the JAX MLP built on the Pallas Emmerald kernel.
    let mut engine = match PjrtEngine::new("artifacts") {
        Ok(e) => e,
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
    };
    let sizes = engine.sizes().to_vec();
    let batch = engine.batch();
    let mlp = Mlp::init(&sizes, 23, Backend::Auto);
    let data = Dataset::gaussian_clusters(batch * 8, sizes[0], *sizes.last().unwrap(), 0.5, 29);
    let cfg = TrainConfig { workers: 2, shard_batch: batch, steps: 12, lr: 0.3, log_every: 0 };
    let mut coord = Coordinator::new(cfg, mlp, data).unwrap();
    let r = coord.train_sequential(&mut engine).unwrap();
    assert!(
        r.final_loss < r.first_loss(),
        "pjrt training must reduce loss: {} -> {}",
        r.first_loss(),
        r.final_loss
    );
    assert_eq!(r.steps.len(), 12);
}
