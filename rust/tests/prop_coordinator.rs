//! Property-based tests for the coordinator's data-parallel invariants.

use emmerald::blas::{Backend, Matrix};
use emmerald::coordinator::{Coordinator, EngineFactory, GradEngine, NativeEngine, TrainConfig};
use emmerald::nn::sgd::average_grads;
use emmerald::nn::{Dataset, Mlp, MlpGrads};
use emmerald::util::testkit::check;
use std::sync::Arc;

#[test]
fn prop_sharded_gradient_equals_serial_gradient() {
    // For any random model/data/sharding, the weighted average of
    // per-shard gradients equals the full-batch gradient.
    check("sharded ≍ serial", 20, |g| {
        let features = g.rng.range_usize(3, 10);
        let classes = g.rng.range_usize(2, 5);
        let hidden = g.rng.range_usize(4, 12);
        let n = g.rng.range_usize(8, 40);
        let mlp = Mlp::init(&[features, hidden, classes], g.rng.next_u64(), Backend::Naive);
        let data = Dataset::gaussian_clusters(n, features, classes, 0.4, g.rng.next_u64());

        let (x_full, y_full) = data.slice(0, n);
        let (_, g_full) = mlp.loss_and_grad(&x_full, &y_full);

        // Random contiguous partition of the batch.
        let mut parts: Vec<(usize, MlpGrads)> = Vec::new();
        let mut start = 0;
        while start < n {
            let len = g.rng.range_usize(1, n - start);
            let (x, y) = data.slice(start, len);
            let (_, grad) = mlp.loss_and_grad(&x, &y);
            parts.push((len, grad));
            start += len;
        }
        let avg = average_grads(&parts, &mlp);
        for (a, b) in avg.d_weights.iter().zip(&g_full.d_weights) {
            assert!(a.max_abs_diff(b) < 1e-4, "sharded != serial ({} parts)", parts.len());
        }
    });
}

#[test]
fn prop_training_is_deterministic_under_fixed_seed() {
    check("deterministic training", 6, |g| {
        let seed = g.rng.next_u64();
        let run = || {
            let mlp = Mlp::init(&[6, 10, 3], seed, Backend::Naive);
            let data = Dataset::gaussian_clusters(64, 6, 3, 0.3, seed ^ 1);
            let cfg =
                TrainConfig { workers: 2, shard_batch: 8, steps: 5, lr: 0.3, log_every: 0 };
            let mut coord = Coordinator::new(cfg, mlp, data).unwrap();
            let mut engine = NativeEngine::new(Backend::Naive);
            coord.train_sequential(&mut engine).unwrap()
        };
        let a = run();
        let b = run();
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.loss, sb.loss, "divergence at step {}", sa.step);
        }
    });
}

#[test]
fn prop_every_step_processes_every_worker_shard_once() {
    // A counting engine observes exactly workers × steps shard calls, each
    // with the configured batch size.
    struct Counting {
        inner: NativeEngine,
        calls: Arc<std::sync::atomic::AtomicUsize>,
        rows: Arc<std::sync::atomic::AtomicUsize>,
    }
    impl GradEngine for Counting {
        fn loss_and_grad(
            &mut self,
            mlp: &Mlp,
            x: &Matrix,
            y: &Matrix,
        ) -> anyhow::Result<(f32, MlpGrads)> {
            self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            self.rows.fetch_add(x.rows(), std::sync::atomic::Ordering::SeqCst);
            self.inner.loss_and_grad(mlp, x, y)
        }
        fn name(&self) -> String {
            "counting".into()
        }
    }

    check("routing exactly once", 8, |g| {
        let workers = g.rng.range_usize(1, 4);
        let steps = g.rng.range_usize(1, 6);
        let batch = 8;
        let calls = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let rows = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mlp = Mlp::init(&[4, 6, 2], g.rng.next_u64(), Backend::Naive);
        let data = Dataset::gaussian_clusters(128, 4, 2, 0.4, g.rng.next_u64());
        let cfg = TrainConfig { workers, shard_batch: batch, steps, lr: 0.2, log_every: 0 };
        let mut coord = Coordinator::new(cfg, mlp, data).unwrap();
        let (c2, r2) = (Arc::clone(&calls), Arc::clone(&rows));
        let factory: Arc<EngineFactory> = Arc::new(move |_| {
            Ok(Box::new(Counting {
                inner: NativeEngine::new(Backend::Naive),
                calls: Arc::clone(&c2),
                rows: Arc::clone(&r2),
            }) as _)
        });
        coord.train_threaded(factory).unwrap();
        assert_eq!(calls.load(std::sync::atomic::Ordering::SeqCst), workers * steps);
        assert_eq!(rows.load(std::sync::atomic::Ordering::SeqCst), workers * steps * batch);
    });
}

#[test]
fn prop_single_failure_reroutes_and_completes() {
    check("failure rerouting", 5, |g| {
        struct Flaky {
            inner: NativeEngine,
            fail: bool,
        }
        impl GradEngine for Flaky {
            fn loss_and_grad(
                &mut self,
                mlp: &Mlp,
                x: &Matrix,
                y: &Matrix,
            ) -> anyhow::Result<(f32, MlpGrads)> {
                if self.fail {
                    anyhow::bail!("injected");
                }
                self.inner.loss_and_grad(mlp, x, y)
            }
            fn name(&self) -> String {
                "flaky".into()
            }
        }
        let workers = g.rng.range_usize(2, 4);
        let bad = g.rng.range_usize(0, workers - 1);
        let steps = g.rng.range_usize(2, 5);
        let mlp = Mlp::init(&[4, 6, 2], g.rng.next_u64(), Backend::Naive);
        let data = Dataset::gaussian_clusters(96, 4, 2, 0.4, g.rng.next_u64());
        let cfg = TrainConfig { workers, shard_batch: 8, steps, lr: 0.2, log_every: 0 };
        let mut coord = Coordinator::new(cfg, mlp, data).unwrap();
        let factory: Arc<EngineFactory> = Arc::new(move |wid| {
            Ok(Box::new(Flaky { inner: NativeEngine::new(Backend::Naive), fail: wid == bad }) as _)
        });
        let r = coord.train_threaded(factory).unwrap();
        assert_eq!(r.rerouted, 1, "exactly the one failed shard reroutes");
        assert_eq!(r.steps.len(), steps, "run completes");
    });
}
