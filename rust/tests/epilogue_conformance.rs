//! Fused-epilogue conformance suite.
//!
//! The epilogue contract is *bitwise*: a plan with an [`Epilogue`]
//! attached must produce exactly the bits of the same plan without one
//! followed by a separate [`Epilogue::apply`] pass — the fused writeback
//! applies the identical scalar function to the identical accumulated
//! value, on each element's final k block. That contract is exercised on
//! the tile tier's fringe grid, across the 257 block-boundary shapes,
//! across the serial / parallel / prepacked drivers, over strided `C`
//! storage, and in both precisions.

use emmerald::blas::GemmContext;
use emmerald::gemm::{
    Activation, BatchStrides, DispatchConfig, Epilogue, KernelId,
};
use emmerald::blas::{Matrix, Transpose};
use emmerald::util::testkit::{assert_allclose, hermetic_tune_cache};

/// Deterministic bias vector (no RNG plumbing needed per case).
fn bias_vec(len: usize, salt: usize) -> Vec<f32> {
    (0..len).map(|i| (((i * 7 + salt * 11) % 13) as f32 - 6.0) / 3.0).collect()
}

/// Rotating epilogue configurations covering every bias shape,
/// activation and the clamp, alone and combined.
fn ep_case(case: usize, m: usize, n: usize) -> Epilogue {
    match case % 6 {
        0 => Epilogue::new().bias_row(bias_vec(n, case)),
        1 => Epilogue::new().bias_col(bias_vec(m, case)).activation(Activation::Relu),
        2 => Epilogue::new().activation(Activation::Gelu).clamp(-0.5, 0.5),
        3 => Epilogue::new().bias_row(bias_vec(n, case)).activation(Activation::Tanh),
        4 => Epilogue::new().clamp(-0.25, 0.75),
        _ => Epilogue::new().bias_col(bias_vec(m, case)).activation(Activation::Gelu),
    }
}

/// One fused-vs-post-pass comparison on strided operands; asserts
/// bitwise equality of the full `C` buffer (padding sentinels included).
#[allow(clippy::too_many_arguments)]
fn check_fused_case(
    ctx: &GemmContext,
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    beta: f32,
    ep: &Epilogue,
    seed: u64,
    what: &str,
) {
    let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
    let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
    // Strided storage shakes out global-vs-local index bugs in the
    // fused writeback; random_strided pads rows with -77 sentinels.
    let a = Matrix::random_strided(ar, ac, ac + 3, seed);
    let b = Matrix::random_strided(br, bc, bc + 1, seed ^ 0xAB);
    let mut c_got = Matrix::random_strided(m, n, n + 2, seed ^ 0xCD);
    let mut c_ref = c_got.clone();

    let fused = ctx
        .gemm()
        .transpose_a(transa)
        .transpose_b(transb)
        .alpha(alpha)
        .beta(beta)
        .lda(a.ld())
        .ldb(b.ld())
        .ldc(c_got.ld())
        .epilogue(ep.clone())
        .plan(m, n, k)
        .unwrap();
    fused.run(a.data(), b.data(), c_got.data_mut()).unwrap();

    let plain = ctx
        .gemm()
        .transpose_a(transa)
        .transpose_b(transb)
        .alpha(alpha)
        .beta(beta)
        .lda(a.ld())
        .ldb(b.ld())
        .ldc(c_ref.ld())
        .plan(m, n, k)
        .unwrap();
    assert_eq!(fused.kernel(), plain.kernel(), "{what}: epilogue changed kernel selection");
    plain.run(a.data(), b.data(), c_ref.data_mut()).unwrap();
    ep.apply(&mut c_ref.view_mut(), 0, 0);

    assert_eq!(c_got.data(), c_ref.data(), "{what}: fused != post-pass bits");
    // Explicit sentinel check: the fused sweep must respect C's stride.
    for r in 0..m {
        for p in n..n + 2 {
            assert_eq!(c_got.data()[r * (n + 2) + p], -77.0, "{what}: padding clobbered at ({r},{p})");
        }
    }
}

#[test]
fn fused_epilogue_matches_post_pass_on_fringe_grid() {
    hermetic_tune_cache();
    // The tile tier's fringe dims (1, MR±1, NR±1) cubed, all four
    // transpose layouts, rotating alpha/beta (alpha == 0 exercises the
    // pure-scale early returns, which must still apply the epilogue) and
    // rotating epilogue configurations.
    let ctx = GemmContext::new(DispatchConfig::default());
    let dims = [1usize, 5, 7, 15, 17];
    let scalars = [(1.0f32, 0.0f32), (0.5, 2.0), (-1.0, 1.0), (0.0, 0.5)];
    let mut seed = 0xE91Du64;
    let mut case = 0usize;
    for &m in &dims {
        for &n in &dims {
            for &k in &dims {
                for transa in [Transpose::No, Transpose::Yes] {
                    for transb in [Transpose::No, Transpose::Yes] {
                        let (alpha, beta) = scalars[case % scalars.len()];
                        let ep = ep_case(case, m, n);
                        case += 1;
                        seed += 1;
                        check_fused_case(
                            &ctx,
                            transa,
                            transb,
                            m,
                            n,
                            k,
                            alpha,
                            beta,
                            &ep,
                            seed,
                            &format!(
                                "fringe m={m} n={n} k={k} ta={transa:?} tb={transb:?} α={alpha} β={beta} ep#{}",
                                (case - 1) % 6
                            ),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn fused_epilogue_matches_post_pass_across_257_boundaries() {
    hermetic_tune_cache();
    // 257 crosses every block boundary (kc, mc, nc and each fringe), so
    // these shapes prove the "last k block only" bookkeeping across
    // multi-block traversals in every loop position.
    let ctx = GemmContext::new(DispatchConfig::default());
    let layouts = [
        (Transpose::No, Transpose::No),
        (Transpose::Yes, Transpose::No),
        (Transpose::No, Transpose::Yes),
        (Transpose::Yes, Transpose::Yes),
    ];
    for (i, &(m, n, k)) in
        [(257usize, 17usize, 7usize), (7, 257, 17), (17, 7, 257), (257, 257, 257)].iter().enumerate()
    {
        let (transa, transb) = layouts[i % 4];
        let ep = Epilogue::new()
            .bias_row(bias_vec(n, i))
            .activation(Activation::Relu)
            .clamp(-0.8, 0.9);
        check_fused_case(
            &ctx,
            transa,
            transb,
            m,
            n,
            k,
            0.75,
            0.5,
            &ep,
            0x257 + i as u64,
            &format!("257-boundary m={m} n={n} k={k}"),
        );
    }
}

#[test]
fn fused_epilogue_bitwise_across_serial_parallel_prepacked() {
    hermetic_tune_cache();
    // The tentpole acceptance contract: one fused problem through the
    // serial tile driver, the thread-parallel tier and both prepacked
    // paths produces identical bits. Only meaningful where the tile
    // layout is the packed layout.
    if !KernelId::Avx2Tile.available() {
        eprintln!("SKIP: no AVX2+FMA — prepacked operands use the dot layout here");
        return;
    }
    let ctx_ser = GemmContext::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });
    let ctx_par = GemmContext::new(DispatchConfig {
        threads: 3,
        parallel_min_flops: 0.0,
        ..DispatchConfig::default()
    });
    let mut seed = 0xEB17u64;
    for (transa, transb) in [
        (Transpose::No, Transpose::No),
        (Transpose::Yes, Transpose::No),
        (Transpose::No, Transpose::Yes),
        (Transpose::Yes, Transpose::Yes),
    ] {
        for (ci, &(m, n, k)) in
            [(37usize, 29usize, 41usize), (64, 48, 16), (6, 16, 8), (61, 33, 257)].iter().enumerate()
        {
            seed += 1;
            let ep = ep_case(ci, m, n);
            let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
            let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
            let a = Matrix::random(ar, ac, seed, -1.0, 1.0);
            let b = Matrix::random(br, bc, seed ^ 0x55, -1.0, 1.0);
            let c0: Vec<f32> = Matrix::random(m, n, seed ^ 0x99, -1.0, 1.0).data().to_vec();
            let what = format!("{m}x{n}x{k} ta={transa:?} tb={transb:?} ep#{}", ci % 6);

            // Serial reference: the fused tile kernel through a forced plan.
            let plan_ser = ctx_ser
                .gemm()
                .transpose_a(transa)
                .transpose_b(transb)
                .alpha(0.75)
                .beta(0.5)
                .kernel(KernelId::Avx2Tile)
                .epilogue(ep.clone())
                .plan(m, n, k)
                .unwrap();
            let mut c_serial = c0.clone();
            plan_ser.run(a.data(), b.data(), &mut c_serial).unwrap();

            // Fused post-pass equivalence for the forced serial plan.
            let plain_ser = ctx_ser
                .gemm()
                .transpose_a(transa)
                .transpose_b(transb)
                .alpha(0.75)
                .beta(0.5)
                .kernel(KernelId::Avx2Tile)
                .plan(m, n, k)
                .unwrap();
            let mut c_two_pass = Matrix::zeros(m, n);
            c_two_pass.data_mut().copy_from_slice(&c0);
            plain_ser.run(a.data(), b.data(), c_two_pass.data_mut()).unwrap();
            ep.apply(&mut c_two_pass.view_mut(), 0, 0);
            assert_eq!(c_two_pass.data(), &c_serial[..], "{what}: fused != two-pass bits");

            // Thread-parallel execution of the same fused problem.
            let plan_par = ctx_par
                .gemm()
                .transpose_a(transa)
                .transpose_b(transb)
                .alpha(0.75)
                .beta(0.5)
                .epilogue(ep.clone())
                .plan(m, n, k)
                .unwrap();
            assert_eq!(plan_par.kernel(), KernelId::Parallel, "{what}: must take the parallel tier");
            let mut c_par = c0.clone();
            plan_par.run(a.data(), b.data(), &mut c_par).unwrap();
            assert_eq!(c_par, c_serial, "{what}: parallel != serial bits");

            // Prepacked B, and fully prepacked, serial and parallel.
            for (ctx, plan, label) in
                [(&ctx_ser, &plan_ser, "serial"), (&ctx_par, &plan_par, "parallel")]
            {
                let pb = ctx.pack_b(transb, k, n, b.data(), b.ld()).unwrap();
                assert!(pb.is_tile(), "{what}: AVX2 host must pack the tile layout");
                let mut c_pb = c0.clone();
                plan.run_packed_b(a.data(), &pb, &mut c_pb).unwrap();
                assert_eq!(c_pb, c_serial, "{what}: {label} run_packed_b != serial bits");

                let pa = ctx.pack_a(transa, m, k, a.data(), a.ld()).unwrap();
                let mut c_pab = c0.clone();
                plan.run_packed(&pa, &pb, &mut c_pab).unwrap();
                assert_eq!(c_pab, c_serial, "{what}: {label} run_packed != serial bits");
            }
        }
    }
}

#[test]
fn fused_epilogue_f64_matches_post_pass() {
    hermetic_tune_cache();
    // The epilogue subsystem is element-generic: same bitwise contract
    // through the f64 (DGEMM) ladder.
    let ctx = GemmContext::new(DispatchConfig::default());
    for (ci, &(m, n, k)) in [(17usize, 15usize, 9usize), (33, 7, 65), (5, 40, 1)].iter().enumerate()
    {
        let bias: Vec<f64> = (0..n).map(|i| (((i * 7 + ci) % 13) as f64 - 6.0) / 3.0).collect();
        let ep = Epilogue::<f64>::new()
            .bias_row(bias)
            .activation(Activation::Tanh)
            .clamp(-0.9, 0.9);
        let a = Matrix::<f64>::random_strided(m, k, k + 3, 0xF64 + ci as u64);
        let b = Matrix::<f64>::random_strided(k, n, n + 1, 0xF64 ^ 0xAB);
        let mut c_got = Matrix::<f64>::random_strided(m, n, n + 2, 0xF64 ^ 0xCD);
        let mut c_ref = c_got.clone();

        let fused = ctx
            .gemm_for::<f64>()
            .alpha(0.5)
            .beta(1.5)
            .lda(a.ld())
            .ldb(b.ld())
            .ldc(c_got.ld())
            .epilogue(ep.clone())
            .plan(m, n, k)
            .unwrap();
        fused.run(a.data(), b.data(), c_got.data_mut()).unwrap();

        let plain = ctx
            .gemm_for::<f64>()
            .alpha(0.5)
            .beta(1.5)
            .lda(a.ld())
            .ldb(b.ld())
            .ldc(c_ref.ld())
            .plan(m, n, k)
            .unwrap();
        plain.run(a.data(), b.data(), c_ref.data_mut()).unwrap();
        ep.apply(&mut c_ref.view_mut(), 0, 0);
        assert_eq!(c_got.data(), c_ref.data(), "f64 fused != post-pass bits ({m}x{n}x{k})");
    }
}

#[test]
fn batched_epilogue_matches_per_item_runs() {
    hermetic_tune_cache();
    // run_batch with an epilogue must equal per-item fused runs — in
    // particular with a per-row (Col) bias, which the shared-B fold may
    // NOT fold across stacked items (stacking would stretch the bias
    // down the whole slab).
    let ctx = GemmContext::new(DispatchConfig::default());
    let (m, n, k, batch) = (12usize, 9usize, 17usize, 4usize);
    let a = Matrix::random(batch * m, k, 0xBA7C, -1.0, 1.0);
    let b = Matrix::random(k, n, 0xBA7C ^ 0x55, -1.0, 1.0);
    for (label, ep) in [
        ("row-bias", Epilogue::new().bias_row(bias_vec(n, 1)).activation(Activation::Relu)),
        ("col-bias", Epilogue::new().bias_col(bias_vec(m, 2)).activation(Activation::Tanh)),
        ("clamp", Epilogue::new().clamp(-0.5, 0.5)),
    ] {
        let plan = ctx.gemm().epilogue(ep.clone()).plan(m, n, k).unwrap();
        let mut c_batch = vec![0.0f32; batch * m * n];
        plan.run_batch(a.data(), b.data(), &mut c_batch, batch, BatchStrides::shared_b(m, n, k))
            .unwrap();
        for i in 0..batch {
            let mut c_item = vec![0.0f32; m * n];
            plan.run(&a.data()[i * m * k..(i + 1) * m * k], b.data(), &mut c_item).unwrap();
            // Tolerance, not bits: the fold path may select a different
            // kernel for the stacked shape than the per-item plan.
            assert_allclose(
                &c_batch[i * m * n..(i + 1) * m * n],
                &c_item,
                2e-4,
                1e-5,
                &format!("{label}: batched item {i} vs per-item fused run"),
            );
        }
    }
}

#[test]
fn epilogue_validation_rejects_wrong_bias_lengths() {
    hermetic_tune_cache();
    let ctx = GemmContext::new(DispatchConfig::default());
    // Row bias must have length n, col bias length m.
    assert!(ctx.gemm().epilogue(Epilogue::new().bias_row(vec![0.0; 5])).plan(4, 6, 3).is_err());
    assert!(ctx.gemm().epilogue(Epilogue::new().bias_col(vec![0.0; 6])).plan(4, 6, 3).is_err());
    assert!(ctx.gemm().epilogue(Epilogue::new().bias_row(vec![0.0; 6])).plan(4, 6, 3).is_ok());
    assert!(ctx.gemm().epilogue(Epilogue::new().bias_col(vec![0.0; 4])).plan(4, 6, 3).is_ok());
}
