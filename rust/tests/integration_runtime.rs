//! Integration tests against the real AOT artifacts (the cross-language
//! correctness signal: Python/JAX/Pallas lowering vs the native Rust
//! implementations).
//!
//! These tests require `make artifacts` to have been run; they skip with a
//! note otherwise so `cargo test` stays green on a fresh checkout.

use emmerald::blas::{Backend, Matrix};
use emmerald::coordinator::{GradEngine, NativeEngine, PjrtEngine};
use emmerald::nn::{Dataset, Mlp};
use emmerald::runtime::{PjrtGemm, Runtime, Tensor};
use emmerald::util::testkit::{assert_allclose, hermetic_tune_cache};

fn runtime() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    hermetic_tune_cache();
    let Some(rt) = runtime() else { return };
    let names = rt.registry().names();
    for expect in ["gemm_64", "gemm_320", "gemm_512", "gemm_naive_320", "mlp_forward", "mlp_grad"]
    {
        assert!(names.iter().any(|n| n == expect), "missing artifact {expect}");
    }
}

#[test]
fn pallas_gemm_matches_native_naive_at_every_size() {
    hermetic_tune_cache();
    let Some(rt) = runtime() else { return };
    for name in rt.registry().names() {
        if !name.starts_with("gemm_") || name.contains("naive") {
            continue;
        }
        let g = PjrtGemm::new(&rt, &name).unwrap();
        let n = g.n;
        let a = Matrix::random(n, n, 11, -1.0, 1.0);
        let b = Matrix::random(n, n, 12, -1.0, 1.0);
        let got = g.matmul(a.data(), b.data()).unwrap();
        let mut c_ref = Matrix::zeros(n, n);
        emmerald::gemm::naive::gemm(
            emmerald::blas::Transpose::No,
            emmerald::blas::Transpose::No,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut c_ref.view_mut(),
        );
        assert_allclose(&got, c_ref.data(), 5e-4, 1e-4, &format!("pjrt {name} vs naive"));
    }
}

#[test]
fn naive_pallas_artifact_agrees_with_emmerald_pallas_artifact() {
    hermetic_tune_cache();
    let Some(rt) = runtime() else { return };
    let e = PjrtGemm::new(&rt, "gemm_320").unwrap();
    let n = PjrtGemm::new(&rt, "gemm_naive_320").unwrap();
    let a = Matrix::random(320, 320, 21, -1.0, 1.0);
    let b = Matrix::random(320, 320, 22, -1.0, 1.0);
    let ce = e.matmul(a.data(), b.data()).unwrap();
    let cn = n.matmul(a.data(), b.data()).unwrap();
    assert_allclose(&ce, &cn, 5e-4, 1e-4, "emmerald vs naive pallas artifacts");
}

#[test]
fn execute_validates_input_shapes() {
    hermetic_tune_cache();
    let Some(rt) = runtime() else { return };
    let bad = vec![Tensor::zeros(vec![2, 2]), Tensor::zeros(vec![2, 2])];
    let err = rt.execute("gemm_64", &bad).unwrap_err();
    assert!(format!("{err:#}").contains("expected shape"), "{err:#}");
    let too_few = vec![Tensor::zeros(vec![64, 64])];
    let err = rt.execute("gemm_64", &too_few).unwrap_err();
    assert!(format!("{err:#}").contains("expects 2 inputs"), "{err:#}");
}

#[test]
fn compile_cache_reuses_executables() {
    hermetic_tune_cache();
    let Some(rt) = runtime() else { return };
    rt.ensure_compiled("gemm_64").unwrap();
    // Second call is a cache hit (observable as being much faster, but we
    // assert only that it succeeds and execution works repeatedly).
    rt.ensure_compiled("gemm_64").unwrap();
    let g = PjrtGemm::new(&rt, "gemm_64").unwrap();
    let a = vec![1.0f32; 64 * 64];
    let b = vec![0.5f32; 64 * 64];
    let c1 = g.matmul(&a, &b).unwrap();
    let c2 = g.matmul(&a, &b).unwrap();
    assert_eq!(c1, c2);
    assert!((c1[0] - 32.0).abs() < 1e-3); // 64 × 1·0.5
}

/// The decisive cross-language test: the JAX-autodiff gradient artifact
/// (wrapping the Pallas kernel) must agree with the hand-derived Rust
/// backprop on identical parameters and data.
#[test]
fn pjrt_grad_matches_native_backprop() {
    hermetic_tune_cache();
    let Some(_) = runtime() else { return };
    let mut pjrt = match PjrtEngine::new("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            return;
        }
    };
    let sizes = pjrt.sizes().to_vec();
    let batch = pjrt.batch();
    let mlp = Mlp::init(&sizes, 99, Backend::Simd);
    let data = Dataset::gaussian_clusters(batch, sizes[0], *sizes.last().unwrap(), 0.4, 5);
    let (x, y) = data.slice(0, batch);

    let (loss_pjrt, g_pjrt) = pjrt.loss_and_grad(&mlp, &x, &y).unwrap();
    let mut native = NativeEngine::new(Backend::Simd);
    let (loss_native, g_native) = native.loss_and_grad(&mlp, &x, &y).unwrap();

    assert!(
        (loss_pjrt - loss_native).abs() < 2e-3 * (1.0 + loss_native.abs()),
        "loss: pjrt {loss_pjrt} vs native {loss_native}"
    );
    for (l, (a, b)) in g_pjrt.d_weights.iter().zip(&g_native.d_weights).enumerate() {
        assert_allclose(a.data(), b.data(), 5e-2, 2e-4, &format!("dW[{l}] pjrt vs native"));
    }
    for (l, (a, b)) in g_pjrt.d_biases.iter().zip(&g_native.d_biases).enumerate() {
        assert_allclose(a, b, 5e-2, 2e-4, &format!("db[{l}] pjrt vs native"));
    }
}
