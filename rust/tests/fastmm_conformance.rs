//! Fast-matmul conformance suite.
//!
//! The `gemm/fastmm` recursion — ⟨m,k,n⟩ base-case factorizations over
//! strided views with dynamically peeled fringes — driven end-to-end
//! through the public [`GemmDispatch::gemm_with`] forcing API, for both
//! elements and both certified algorithms:
//!
//! * conformance vs the naive oracle on odd / rectangular / fringe
//!   shapes (level-scaled tolerances: multi-level f32 loses ~1 bit per
//!   ⟨2,2,2⟩ level, a little more for ⟨3,3,3⟩);
//! * bitwise run-to-run determinism, *including* serial ≡ parallel —
//!   the BFS fan-out writes back in the same ascending product order
//!   the DFS arm uses, so the pool size must not change a single bit;
//! * a selection property: the fast tier never fires below the tuned
//!   per-(element, shape-class) minimum dimension.

use emmerald::blas::{dgemm, sgemm_matrix, Backend, Matrix, Transpose};
use emmerald::gemm::dispatch::GemmShape;
use emmerald::gemm::{
    DispatchConfig, FastAlgoId, FastmmChoice, FastmmTable, GemmDispatch, KernelId,
};
use emmerald::util::testkit::{assert_allclose, assert_allclose_f64, check, hermetic_tune_cache};

/// Odd, rectangular and fringe-heavy shapes: every one leaves a
/// remainder against both the ⟨2,2,2⟩ and ⟨3,3,3⟩ block grids at some
/// recursion level, and the gemv-shaped rows exercise the degenerate
/// base-case path.
const SHAPES: [(usize, usize, usize); 8] = [
    (33, 35, 37),
    (65, 64, 63),
    (70, 31, 129),
    (96, 96, 96),
    (100, 41, 128),
    (81, 81, 81),
    (1, 65, 64),
    (64, 1, 65),
];

/// A dispatcher with the fast tier forced on everywhere: tiny minimum
/// dimension, crossover at the floor so even the grid shapes recurse.
fn forced(algo: FastAlgoId, threads: usize) -> GemmDispatch {
    GemmDispatch::new(DispatchConfig {
        fastmm: FastmmTable::uniform(FastmmChoice { algo, crossover: 32, min_dim: 32 }),
        threads,
        ..DispatchConfig::default()
    })
}

#[test]
fn fastmm_f32_conforms_on_odd_rect_fringe_shapes() {
    hermetic_tune_cache();
    for algo in FastAlgoId::ALL {
        let d = forced(algo, 4);
        let mut seed = 0xFA57u64;
        for &(m, n, k) in &SHAPES {
            for &(alpha, beta) in &[(1.0f32, 0.0f32), (0.5, 1.5)] {
                seed += 1;
                let a = Matrix::random(m, k, seed, -1.0, 1.0);
                let b = Matrix::random(k, n, seed ^ 0xB, -1.0, 1.0);
                let mut c_got = Matrix::random(m, n, seed ^ 0xC, -1.0, 1.0);
                let mut c_ref = c_got.clone();
                let ran = d.gemm_with(
                    KernelId::FastMm,
                    Transpose::No,
                    Transpose::No,
                    alpha,
                    a.view(),
                    b.view(),
                    beta,
                    &mut c_got.view_mut(),
                );
                assert!(ran.available(), "{algo:?} degraded to unavailable {ran:?}");
                sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, alpha, &a, &b, beta, &mut c_ref)
                    .unwrap();
                assert_allclose(
                    c_got.data(),
                    c_ref.data(),
                    1e-2,
                    5e-3,
                    &format!("fastmm f32 {} m={m} n={n} k={k} α={alpha} β={beta}", algo.name()),
                );
            }
        }
    }
}

#[test]
fn fastmm_f64_conforms_on_odd_rect_fringe_shapes() {
    hermetic_tune_cache();
    for algo in FastAlgoId::ALL {
        let d = forced(algo, 4);
        let mut seed = 0xD0B1u64;
        for &(m, n, k) in &SHAPES {
            for &(alpha, beta) in &[(1.0f64, 0.0f64), (-0.5, 2.0)] {
                seed += 1;
                let a = Matrix::<f64>::random(m, k, seed, -1.0, 1.0);
                let b = Matrix::<f64>::random(k, n, seed ^ 0xB, -1.0, 1.0);
                let mut c_got = Matrix::<f64>::random(m, n, seed ^ 0xC, -1.0, 1.0);
                let mut c_ref = c_got.clone();
                let ran = d.gemm_with(
                    KernelId::FastMm,
                    Transpose::No,
                    Transpose::No,
                    alpha,
                    a.view(),
                    b.view(),
                    beta,
                    &mut c_got.view_mut(),
                );
                assert!(ran.available(), "{algo:?} degraded to unavailable {ran:?}");
                dgemm(
                    Backend::Naive,
                    Transpose::No,
                    Transpose::No,
                    m,
                    n,
                    k,
                    alpha,
                    a.data(),
                    a.ld(),
                    b.data(),
                    b.ld(),
                    beta,
                    c_ref.data_mut(),
                    c_ref.ld(),
                )
                .unwrap();
                // f64 keeps ~11 more mantissa bits through the same
                // recursion depth, so the bars tighten accordingly.
                assert_allclose_f64(
                    c_got.data(),
                    c_ref.data(),
                    1e-10,
                    1e-11,
                    &format!("fastmm f64 {} m={m} n={n} k={k} α={alpha} β={beta}", algo.name()),
                );
            }
        }
    }
}

#[test]
fn fastmm_is_bitwise_deterministic_serial_vs_parallel_and_run_to_run() {
    hermetic_tune_cache();
    for algo in FastAlgoId::ALL {
        let serial = forced(algo, 1);
        let pooled = forced(algo, 4);
        for &(m, n, k) in &[(160usize, 160usize, 160usize), (150, 130, 141)] {
            let a = Matrix::random(m, k, 7, -1.0, 1.0);
            let b = Matrix::random(k, n, 8, -1.0, 1.0);
            let run = |d: &GemmDispatch| {
                let mut c = Matrix::from_fn(m, n, |r, col| (r + col) as f32 * 0.01);
                d.gemm_with(
                    KernelId::FastMm,
                    Transpose::No,
                    Transpose::No,
                    0.75,
                    a.view(),
                    b.view(),
                    0.25,
                    &mut c.view_mut(),
                );
                c
            };
            let c_serial = run(&serial);
            let c_pooled_1 = run(&pooled);
            let c_pooled_2 = run(&pooled);
            assert_eq!(
                c_serial.data(),
                c_pooled_1.data(),
                "{} serial vs pooled differ at {m}x{n}x{k}",
                algo.name()
            );
            assert_eq!(
                c_pooled_1.data(),
                c_pooled_2.data(),
                "{} pooled run-to-run differ at {m}x{n}x{k}",
                algo.name()
            );
        }
    }
}

#[test]
fn prop_selection_never_fires_below_min_dim() {
    // The tuned minimum dimension is a hard floor for *selection*: any
    // shape whose smallest dimension sits below it must route to the
    // classical tiers, for both elements, whatever the transposes.
    const MIN_DIM: usize = 64;
    check("fastmm selection floor", 60, |g| {
        let d = GemmDispatch::new(DispatchConfig {
            fastmm: FastmmTable::uniform(FastmmChoice {
                algo: FastAlgoId::Strassen222,
                crossover: 64,
                min_dim: MIN_DIM,
            }),
            threads: 4,
            ..DispatchConfig::default()
        });
        let m = g.dim(200);
        let n = g.dim(200);
        let k = g.dim(200);
        for (ta, tb) in [
            (Transpose::No, Transpose::No),
            (Transpose::Yes, Transpose::No),
            (Transpose::No, Transpose::Yes),
        ] {
            let shape = GemmShape { m, n, k, transa: ta, transb: tb };
            if m.min(n).min(k) < MIN_DIM {
                assert_ne!(
                    d.select_t::<f32>(&shape, 1.0f32),
                    KernelId::FastMm,
                    "f32 selected fastmm below min_dim ({m}x{n}x{k})"
                );
                assert_ne!(
                    d.select_t::<f64>(&shape, 1.0f64),
                    KernelId::FastMm,
                    "f64 selected fastmm below min_dim ({m}x{n}x{k})"
                );
            }
        }
    });
}
