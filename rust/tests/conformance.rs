//! Cross-backend conformance suite.
//!
//! Every kernel in the dispatch registry — naive, blocked, SSE, AVX2,
//! parallel, fast-matmul — is driven through the *same* shape/transpose/
//! alpha-beta grid against the naive oracle, via the public
//! [`GemmDispatch::gemm_with`] forcing API. A kernel that cannot express a
//! case (vector ISA missing, transposed operands for the whole-problem
//! drivers) must degrade and still produce the right answer, so the whole
//! grid runs for every registry entry unconditionally.

use emmerald::blas::{sgemm, Backend, Matrix, Transpose};
use emmerald::gemm::dispatch::GemmShape;
use emmerald::gemm::{registry, BatchStrides, DispatchConfig, GemmDispatch, KernelId};
use emmerald::util::testkit::{assert_allclose, check, hermetic_tune_cache, Gen};

/// The conformance grid: shapes crossing block, panel and vector-width
/// boundaries, all four transpose combinations, four alpha/beta pairs.
const SHAPES: [(usize, usize, usize); 12] = [
    (1, 1, 1),
    (1, 5, 4),
    (2, 3, 1),
    (5, 5, 5),
    (7, 11, 13),
    (8, 10, 16),
    (16, 16, 16),
    (17, 19, 23),
    (32, 6, 40),
    (3, 64, 7),
    (33, 34, 35),
    (64, 64, 64),
];

fn oracle(
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    beta: f32,
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
) {
    sgemm(
        Backend::Naive,
        transa,
        transb,
        m,
        n,
        k,
        alpha,
        a.data(),
        a.ld(),
        b.data(),
        b.ld(),
        beta,
        c.data_mut(),
        c.ld(),
    )
    .unwrap();
}

fn run_grid_for(d: &GemmDispatch, id: KernelId) {
    let mut seed = 0xC0F0u64;
    for &(m, n, k) in &SHAPES {
        for transa in [Transpose::No, Transpose::Yes] {
            for transb in [Transpose::No, Transpose::Yes] {
                for &(alpha, beta) in &[(1.0f32, 0.0f32), (0.5, 2.0), (-1.0, 1.0), (0.0, 0.5)] {
                    seed += 1;
                    let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
                    let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
                    // Strided storage shakes out indexing bugs.
                    let a = Matrix::random_strided(ar, ac, ac + 3, seed);
                    let b = Matrix::random_strided(br, bc, bc + 1, seed ^ 0xAB);
                    let mut c_got = Matrix::random_strided(m, n, n + 2, seed ^ 0xCD);
                    let mut c_ref = c_got.clone();
                    let ran = d.gemm_with(
                        id,
                        transa,
                        transb,
                        alpha,
                        a.view(),
                        b.view(),
                        beta,
                        &mut c_got.view_mut(),
                    );
                    assert!(ran.available(), "{id:?} degraded to unavailable {ran:?}");
                    oracle(transa, transb, m, n, k, alpha, beta, &a, &b, &mut c_ref);
                    assert_allclose(
                        c_got.data(),
                        c_ref.data(),
                        2e-4,
                        1e-5,
                        &format!(
                            "conformance {} m={m} n={n} k={k} ta={transa:?} tb={transb:?} α={alpha} β={beta}",
                            id.name()
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn every_registry_kernel_conforms_on_the_grid() {
    hermetic_tune_cache();
    let d = GemmDispatch::default();
    for info in registry() {
        run_grid_for(&d, info.id);
    }
}

#[test]
fn auto_selection_conforms_across_heuristic_boundaries() {
    hermetic_tune_cache();
    // Thresholds tuned so the grid itself crosses naive→vector→parallel
    // boundaries; every selected kernel must agree with the oracle — now
    // for all four layouts, since the parallel tier is layout-complete.
    let cfg = DispatchConfig {
        tiny_dim: 4,
        parallel_min_flops: 2.0 * 24.0 * 24.0 * 24.0,
        fastmm: emmerald::gemm::FastmmTable::disabled(), // multi-level f32 error needs looser bars
        threads: 3,
        ..DispatchConfig::default()
    };
    let d = GemmDispatch::new(cfg);
    let mut seed = 0x51D3u64;
    for &(m, n, k) in &SHAPES {
        for transa in [Transpose::No, Transpose::Yes] {
            for transb in [Transpose::No, Transpose::Yes] {
                seed += 1;
                let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
                let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
                let a = Matrix::random(ar, ac, seed, -1.0, 1.0);
                let b = Matrix::random(br, bc, seed ^ 0x9, -1.0, 1.0);
                let mut c_got = Matrix::zeros(m, n);
                let mut c_ref = Matrix::zeros(m, n);
                let shape = GemmShape { m, n, k, transa, transb };
                let picked = d.select(&shape, 1.0);
                assert!(picked.available(), "picked unavailable {picked:?} for {m}x{n}x{k}");
                let ran = d.gemm(transa, transb, 1.0, a.view(), b.view(), 0.0, &mut c_got.view_mut());
                assert_eq!(ran, picked, "gemm must run what select reports");
                oracle(transa, transb, m, n, k, 1.0, 0.0, &a, &b, &mut c_ref);
                assert_allclose(
                    c_got.data(),
                    c_ref.data(),
                    2e-4,
                    1e-5,
                    &format!("auto {m}x{n}x{k} ta={transa:?} tb={transb:?}"),
                );
            }
        }
    }
}

#[test]
fn parallel_kernel_runs_transposed_and_skinny_layouts_without_degrading() {
    hermetic_tune_cache();
    if !KernelId::Parallel.available() {
        eprintln!("SKIP: no SSE — parallel tier unavailable");
        return;
    }
    let d = GemmDispatch::new(DispatchConfig { threads: 3, ..DispatchConfig::default() });
    let mut seed = 0x9A11u64;
    // Row-split shapes, column-split shapes (m == 1 and m < threads).
    for &(m, n, k) in &[(48usize, 37usize, 29usize), (1, 64, 33), (2, 96, 17)] {
        for transa in [Transpose::No, Transpose::Yes] {
            for transb in [Transpose::No, Transpose::Yes] {
                seed += 1;
                let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
                let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
                let a = Matrix::random_strided(ar, ac, ac + 3, seed);
                let b = Matrix::random_strided(br, bc, bc + 1, seed ^ 0xAB);
                let mut c_got = Matrix::random_strided(m, n, n + 2, seed ^ 0xCD);
                let mut c_ref = c_got.clone();
                let ran = d.gemm_with(
                    KernelId::Parallel,
                    transa,
                    transb,
                    0.75,
                    a.view(),
                    b.view(),
                    0.5,
                    &mut c_got.view_mut(),
                );
                assert_eq!(
                    ran,
                    KernelId::Parallel,
                    "parallel must not degrade for {m}x{n}x{k} ta={transa:?} tb={transb:?}"
                );
                oracle(transa, transb, m, n, k, 0.75, 0.5, &a, &b, &mut c_ref);
                assert_allclose(
                    c_got.data(),
                    c_ref.data(),
                    5e-4,
                    1e-4,
                    &format!("parallel layout {m}x{n}x{k} ta={transa:?} tb={transb:?}"),
                );
                // Strided-C padding sentinels survive every split.
                for r in 0..m {
                    for p in n..n + 2 {
                        assert_eq!(
                            c_got.data()[r * (n + 2) + p],
                            -77.0,
                            "padding clobbered at ({r},{p}) ta={transa:?} tb={transb:?}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn parallel_beta_scale_conforms_and_respects_padding() {
    hermetic_tune_cache();
    if !KernelId::Parallel.available() {
        eprintln!("SKIP: no SSE — parallel tier unavailable");
        return;
    }
    // Low scale threshold so a test-sized C takes the pool sweep.
    let d = GemmDispatch::new(DispatchConfig {
        threads: 3,
        parallel_min_scale: 32,
        ..DispatchConfig::default()
    });
    let (m, n, k) = (11usize, 9usize, 7usize);
    let shape = GemmShape { m, n, k, transa: Transpose::No, transb: Transpose::No };
    assert_eq!(d.select(&shape, 0.0), KernelId::Parallel, "alpha == 0 above threshold must parallelise");
    let a = Matrix::random(m, k, 1, -1.0, 1.0);
    let b = Matrix::random(k, n, 2, -1.0, 1.0);
    let mut c_got = Matrix::random_strided(m, n, n + 3, 3);
    let c_before = c_got.clone();
    let ran = d.gemm(Transpose::No, Transpose::No, 0.0, a.view(), b.view(), -0.5, &mut c_got.view_mut());
    assert_eq!(ran, KernelId::Parallel);
    for r in 0..m {
        for j in 0..n {
            assert_eq!(c_got.get(r, j), c_before.get(r, j) * -0.5, "scale at ({r},{j})");
        }
        for p in n..n + 3 {
            assert_eq!(c_got.data()[r * (n + 3) + p], -77.0, "padding clobbered at ({r},{p})");
        }
    }
}

#[test]
fn avx2_tile_fringe_grid_conforms() {
    hermetic_tune_cache();
    // The tile tier's fringe grid: every m/n/k combination of 1, MR−1,
    // MR+1, NR−1, NR+1 (MR = 6, NR = 16) across all four transpose
    // layouts, with strided operands and rotating alpha/beta pairs. On
    // hosts without AVX2+FMA the forced call degrades (and still must
    // match the oracle), which keeps the grid meaningful everywhere.
    let d = GemmDispatch::default();
    let dims = [1usize, 5, 7, 15, 17];
    let scalars = [(1.0f32, 0.0f32), (0.5, 2.0), (-1.0, 1.0), (0.0, 0.5)];
    let mut seed = 0x711Eu64;
    let mut case = 0usize;
    for &m in &dims {
        for &n in &dims {
            for &k in &dims {
                for transa in [Transpose::No, Transpose::Yes] {
                    for transb in [Transpose::No, Transpose::Yes] {
                        let (alpha, beta) = scalars[case % scalars.len()];
                        case += 1;
                        seed += 1;
                        let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
                        let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
                        let a = Matrix::random_strided(ar, ac, ac + 3, seed);
                        let b = Matrix::random_strided(br, bc, bc + 1, seed ^ 0xAB);
                        let mut c_got = Matrix::random_strided(m, n, n + 2, seed ^ 0xCD);
                        let mut c_ref = c_got.clone();
                        d.gemm_with(
                            KernelId::Avx2Tile,
                            transa,
                            transb,
                            alpha,
                            a.view(),
                            b.view(),
                            beta,
                            &mut c_got.view_mut(),
                        );
                        oracle(transa, transb, m, n, k, alpha, beta, &a, &b, &mut c_ref);
                        assert_allclose(
                            c_got.data(),
                            c_ref.data(),
                            2e-4,
                            1e-5,
                            &format!("tile fringe m={m} n={n} k={k} ta={transa:?} tb={transb:?} α={alpha} β={beta}"),
                        );
                        for r in 0..m {
                            for p in n..n + 2 {
                                assert_eq!(
                                    c_got.data()[r * (n + 2) + p],
                                    -77.0,
                                    "tile clobbered C padding at ({r},{p})"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
    // 257 crosses every block boundary (kc, mc, nc and the fringe of
    // each): spot-check it on every axis, plus the full cube once.
    let mut seed = 0x257u64;
    for (i, &(m, n, k)) in
        [(257usize, 17usize, 7usize), (7, 257, 17), (17, 7, 257), (257, 257, 257)].iter().enumerate()
    {
        let (transa, transb) = [
            (Transpose::No, Transpose::No),
            (Transpose::Yes, Transpose::No),
            (Transpose::No, Transpose::Yes),
            (Transpose::Yes, Transpose::Yes),
        ][i % 4];
        seed += 1;
        let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
        let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
        let a = Matrix::random_strided(ar, ac, ac + 3, seed);
        let b = Matrix::random_strided(br, bc, bc + 1, seed ^ 0xAB);
        let mut c_got = Matrix::random_strided(m, n, n + 2, seed ^ 0xCD);
        let mut c_ref = c_got.clone();
        d.gemm_with(KernelId::Avx2Tile, transa, transb, 0.75, a.view(), b.view(), 0.5, &mut c_got.view_mut());
        oracle(transa, transb, m, n, k, 0.75, 0.5, &a, &b, &mut c_ref);
        assert_allclose(
            c_got.data(),
            c_ref.data(),
            5e-4,
            1e-4,
            &format!("tile 257-boundary m={m} n={n} k={k} ta={transa:?} tb={transb:?}"),
        );
    }
}

#[test]
fn avx2_tile_bitwise_stable_across_serial_parallel_prepacked() {
    hermetic_tune_cache();
    // The acceptance contract: one problem, executed through the serial
    // tile driver, the thread-parallel tier and both prepacked paths,
    // must produce identical bits (per-element accumulation is pure k
    // order; fringe writeback rounds exactly like the vector writeback).
    // The prepacked layout is only the tile layout on AVX2+FMA hosts.
    if !KernelId::Avx2Tile.available() {
        eprintln!("SKIP: no AVX2+FMA — prepacked operands use the dot layout here");
        return;
    }
    let ctx_ser = emmerald::blas::GemmContext::new(DispatchConfig {
        threads: 1,
        ..DispatchConfig::default()
    });
    let ctx_par = emmerald::blas::GemmContext::new(DispatchConfig {
        threads: 3,
        parallel_min_flops: 0.0,
        ..DispatchConfig::default()
    });
    let mut seed = 0xB17u64;
    for (transa, transb) in [
        (Transpose::No, Transpose::No),
        (Transpose::Yes, Transpose::No),
        (Transpose::No, Transpose::Yes),
        (Transpose::Yes, Transpose::Yes),
    ] {
        for &(m, n, k) in &[(37usize, 29usize, 41usize), (64, 48, 16), (6, 16, 8), (61, 33, 257)] {
            seed += 1;
            let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
            let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
            let a = Matrix::random(ar, ac, seed, -1.0, 1.0);
            let b = Matrix::random(br, bc, seed ^ 0x55, -1.0, 1.0);
            let c0: Vec<f32> = Matrix::random(m, n, seed ^ 0x99, -1.0, 1.0).data().to_vec();
            let what = format!("{m}x{n}x{k} ta={transa:?} tb={transb:?}");

            // Serial reference: the tile kernel through a forced plan.
            let plan_ser = ctx_ser
                .gemm()
                .transpose_a(transa)
                .transpose_b(transb)
                .alpha(0.75)
                .beta(0.5)
                .kernel(KernelId::Avx2Tile)
                .plan(m, n, k)
                .unwrap();
            let mut c_serial = c0.clone();
            plan_ser.run(a.data(), b.data(), &mut c_serial).unwrap();

            // Thread-parallel execution of the same problem.
            let plan_par = ctx_par
                .gemm()
                .transpose_a(transa)
                .transpose_b(transb)
                .alpha(0.75)
                .beta(0.5)
                .plan(m, n, k)
                .unwrap();
            assert_eq!(plan_par.kernel(), KernelId::Parallel, "{what}: must take the parallel tier");
            let mut c_par = c0.clone();
            plan_par.run(a.data(), b.data(), &mut c_par).unwrap();
            assert_eq!(c_par, c_serial, "{what}: parallel != serial bits");

            // Prepacked B, serial and parallel.
            for (ctx, plan, label) in
                [(&ctx_ser, &plan_ser, "serial"), (&ctx_par, &plan_par, "parallel")]
            {
                let pb = ctx.pack_b(transb, k, n, b.data(), b.ld()).unwrap();
                assert!(pb.is_tile(), "{what}: AVX2 host must pack the tile layout");
                let mut c_pb = c0.clone();
                plan.run_packed_b(a.data(), &pb, &mut c_pb).unwrap();
                assert_eq!(c_pb, c_serial, "{what}: {label} run_packed_b != serial bits");

                let pa = ctx.pack_a(transa, m, k, a.data(), a.ld()).unwrap();
                let mut c_pab = c0.clone();
                plan.run_packed(&pa, &pb, &mut c_pab).unwrap();
                assert_eq!(c_pab, c_serial, "{what}: {label} run_packed != serial bits");
            }
        }
    }
}

#[test]
fn prop_dispatch_selection_is_stable_and_conformant() {
    // Random shapes/scalars: selection is deterministic (same shape →
    // same kernel), the selected kernel is available, and the result
    // matches the oracle.
    let d = GemmDispatch::default();
    check("dispatch selection conformance", 60, |g: &mut Gen| {
        let m = g.dim(48);
        let n = g.dim(48);
        let k = g.dim(64);
        let alpha = g.rng.f32_range(-2.0, 2.0);
        let shape = GemmShape { m, n, k, transa: Transpose::No, transb: Transpose::No };
        let id1 = d.select(&shape, alpha);
        let id2 = d.select(&shape, alpha);
        assert_eq!(id1, id2, "selection must be deterministic");
        assert!(id1.available());
        let a = Matrix::random(m, k, g.rng.next_u64(), -1.0, 1.0);
        let b = Matrix::random(k, n, g.rng.next_u64(), -1.0, 1.0);
        let mut c_got = Matrix::zeros(m, n);
        let mut c_ref = Matrix::zeros(m, n);
        d.gemm(Transpose::No, Transpose::No, alpha, a.view(), b.view(), 0.0, &mut c_got.view_mut());
        oracle(Transpose::No, Transpose::No, m, n, k, alpha, 0.0, &a, &b, &mut c_ref);
        assert_allclose(c_got.data(), c_ref.data(), 5e-4, 1e-4, "prop dispatch");
    });
}

#[test]
fn batched_fold_and_fanout_agree_with_each_other() {
    hermetic_tune_cache();
    // The same batch computed through the fold fast path (shared B,
    // contiguous items) and through the general fan-out (forced by a
    // padded C stride) must agree. parallel_min_flops = 0 makes the
    // fan-out genuinely threaded even at test sizes.
    let d = GemmDispatch::new(DispatchConfig {
        threads: 2,
        parallel_min_flops: 0.0,
        ..DispatchConfig::default()
    });
    let (m, n, k, batch) = (12usize, 9usize, 17usize, 6usize);
    let a: Vec<f32> = (0..batch * m * k).map(|i| ((i * 37 % 100) as f32 - 50.0) / 50.0).collect();
    let b: Vec<f32> = (0..k * n).map(|i| ((i * 53 % 100) as f32 - 50.0) / 50.0).collect();

    let mut c_fold = vec![0.25f32; batch * m * n];
    emmerald::gemm::gemm_batch(
        &d,
        Transpose::No,
        Transpose::No,
        m,
        n,
        k,
        1.5,
        &a,
        k,
        &b,
        n,
        0.5,
        &mut c_fold,
        n,
        batch,
        BatchStrides::shared_b(m, n, k),
    )
    .unwrap();

    let pad = 5usize;
    let mut c_pad = vec![0.25f32; batch * (m * n + pad)];
    emmerald::gemm::gemm_batch(
        &d,
        Transpose::No,
        Transpose::No,
        m,
        n,
        k,
        1.5,
        &a,
        k,
        &b,
        n,
        0.5,
        &mut c_pad,
        n,
        batch,
        BatchStrides { a: m * k, b: 0, c: m * n + pad },
    )
    .unwrap();

    for i in 0..batch {
        let fold = &c_fold[i * m * n..(i + 1) * m * n];
        let fan = &c_pad[i * (m * n + pad)..i * (m * n + pad) + m * n];
        assert_allclose(fan, fold, 5e-4, 1e-4, &format!("fold vs fan-out item {i}"));
        // Inter-item padding untouched by the fan-out path.
        for p in 0..pad {
            assert_eq!(c_pad[i * (m * n + pad) + m * n + p], 0.25, "padding clobbered");
        }
    }
}
