//! The Miri / undefined-behaviour check tier.
//!
//! `cargo +nightly miri test --test miri_scalar` interprets this file
//! under Miri, where CPU-feature detection reports no vector ISA (see
//! `gemm::dispatch::detect_sse`), so every GEMM call routes through the
//! scalar tiers — naive, blocked, packing, epilogues, the planner and
//! the thread pool — and Miri checks each raw-pointer access, borrow
//! and thread interaction for UB. The same file runs as a plain
//! integration test on every `cargo test`, so the cases themselves are
//! continuously exercised even where no nightly toolchain exists.
//!
//! Shapes are deliberately tiny: Miri executes ~100x slower than native.

use emmerald::blas::{GemmContext, Matrix, Transpose};
use emmerald::gemm::{
    Activation, DispatchConfig, Epilogue, GemmDispatch, KernelId,
};
use emmerald::util::testkit::hermetic_tune_cache;
use emmerald::util::threadpool::ThreadPool;

/// Independent triple-loop reference (not the crate's naive kernel, so
/// the oracle itself is under test too).
fn reference(
    transa: Transpose,
    transb: Transpose,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &Matrix,
) -> Matrix {
    let (m, n) = (c.rows(), c.cols());
    let k = if transa == Transpose::No { a.cols() } else { a.rows() };
    let at = |r: usize, p: usize| {
        if transa == Transpose::No {
            a.get(r, p)
        } else {
            a.get(p, r)
        }
    };
    let bt = |p: usize, col: usize| {
        if transb == Transpose::No {
            b.get(p, col)
        } else {
            b.get(col, p)
        }
    };
    Matrix::from_fn(m, n, |r, col| {
        let mut acc = 0.0f32;
        for p in 0..k {
            acc += at(r, p) * bt(p, col);
        }
        alpha * acc + beta * c.get(r, col)
    })
}

/// Fringe-shape grid: dimensions straddling 1, the register-tile edges
/// (MR = 6, NR = 16) and the packing panel width, all four transpose
/// layouts, strided storage. Small enough for Miri, sharp enough to hit
/// every packing fringe.
const DIMS: [usize; 4] = [1, 5, 7, 17];

fn run_scalar_grid(id: KernelId) {
    hermetic_tune_cache();
    let d = GemmDispatch::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });
    let mut seed = 0x31A5u64;
    for &m in &DIMS {
        for &n in &DIMS {
            let k = (m + n) % 9 + 1; // vary k without cubing the grid
            for transa in [Transpose::No, Transpose::Yes] {
                for transb in [Transpose::No, Transpose::Yes] {
                    seed += 1;
                    let (alpha, beta) = if seed % 2 == 0 { (1.0, 0.0) } else { (0.5, 2.0) };
                    let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
                    let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
                    let a = Matrix::random_strided(ar, ac, ac + 3, seed);
                    let b = Matrix::random_strided(br, bc, bc + 1, seed ^ 0xAB);
                    let mut c = Matrix::random_strided(m, n, n + 2, seed ^ 0xCD);
                    let want = reference(transa, transb, alpha, &a, &b, beta, &c);
                    d.gemm_with(id, transa, transb, alpha, a.view(), b.view(), beta, &mut c.view_mut());
                    for r in 0..m {
                        for col in 0..n {
                            let (got, exp) = (c.get(r, col), want.get(r, col));
                            assert!(
                                (got - exp).abs() <= 1e-4 * (1.0 + exp.abs()),
                                "{id:?} m={m} n={n} k={k} ta={transa:?} tb={transb:?} ({r},{col}): {got} vs {exp}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn naive_kernel_is_ub_free_on_fringe_grid() {
    run_scalar_grid(KernelId::Naive);
}

#[test]
fn blocked_kernel_is_ub_free_on_fringe_grid() {
    run_scalar_grid(KernelId::Blocked);
}

#[test]
fn auto_dispatch_routes_scalar_under_miri() {
    hermetic_tune_cache();
    // Under Miri the feature probes report no vector ISA, so even the
    // vector registry entries must degrade to the scalar tiers and the
    // whole dispatch ladder stays interpretable.
    if cfg!(miri) {
        assert!(!KernelId::Simd.available(), "Miri must hide SSE");
        assert!(!KernelId::Avx2.available(), "Miri must hide AVX2");
    }
    let d = GemmDispatch::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });
    let a = Matrix::random_strided(7, 5, 8, 0xA1);
    let b = Matrix::random_strided(5, 6, 7, 0xB2);
    let mut c = Matrix::random_strided(7, 6, 8, 0xC3);
    let want = reference(Transpose::No, Transpose::No, 1.5, &a, &b, -0.5, &c);
    for id in [KernelId::Simd, KernelId::Avx2, KernelId::Avx2Tile, KernelId::Parallel] {
        let mut c_got = c.clone();
        let ran =
            d.gemm_with(id, Transpose::No, Transpose::No, 1.5, a.view(), b.view(), -0.5, &mut c_got.view_mut());
        if cfg!(miri) {
            assert!(
                matches!(ran, KernelId::Naive | KernelId::Blocked),
                "{id:?} ran vector tier {ran:?} under Miri"
            );
        }
        for r in 0..7 {
            for col in 0..6 {
                let (got, exp) = (c_got.get(r, col), want.get(r, col));
                assert!(
                    (got - exp).abs() <= 1e-4 * (1.0 + exp.abs()),
                    "forced {id:?} ({r},{col}): {got} vs {exp}"
                );
            }
        }
    }
    let _ = d.gemm(Transpose::No, Transpose::No, 1.5, a.view(), b.view(), -0.5, &mut c.view_mut());
}

#[test]
fn fused_epilogue_matches_post_pass_on_scalar_tier() {
    hermetic_tune_cache();
    // Bitwise contract on the scalar tiers: a planned GEMM with a fused
    // epilogue produces exactly the bits of the plain plan plus a
    // separate apply pass. (The scalar tiers apply epilogues as a
    // post-pass internally, so this doubles as a Miri sweep over the
    // planner, the epilogue algebra and the strided writeback.)
    let ctx = GemmContext::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });
    let mut seed = 0x5EEDu64;
    for &(m, n, k) in &[(1usize, 5usize, 3usize), (6, 7, 4), (17, 5, 2)] {
        for case in 0..3usize {
            seed += 1;
            let bias_row: Vec<f32> = (0..n).map(|i| (i as f32 - 1.0) / 3.0).collect();
            let bias_col: Vec<f32> = (0..m).map(|i| (i as f32) / 5.0 - 0.5).collect();
            let ep = match case {
                0 => Epilogue::new().bias_row(bias_row).activation(Activation::Relu),
                1 => Epilogue::new().bias_col(bias_col).clamp(-0.5, 0.5),
                _ => Epilogue::new().activation(Activation::Gelu),
            };
            let a = Matrix::random_strided(m, k, k + 2, seed);
            let b = Matrix::random_strided(k, n, n + 1, seed ^ 0x77);
            let mut c_got = Matrix::random_strided(m, n, n + 2, seed ^ 0x99);
            let mut c_ref = c_got.clone();

            let fused = ctx
                .gemm()
                .alpha(0.75)
                .beta(0.25)
                .lda(a.ld())
                .ldb(b.ld())
                .ldc(c_got.ld())
                .epilogue(ep.clone())
                .plan(m, n, k)
                .unwrap();
            fused.run(a.data(), b.data(), c_got.data_mut()).unwrap();

            let plain = ctx
                .gemm()
                .alpha(0.75)
                .beta(0.25)
                .lda(a.ld())
                .ldb(b.ld())
                .ldc(c_ref.ld())
                .plan(m, n, k)
                .unwrap();
            plain.run(a.data(), b.data(), c_ref.data_mut()).unwrap();
            ep.apply(&mut c_ref.view_mut(), 0, 0);

            assert_eq!(
                c_got.data(),
                c_ref.data(),
                "fused != post-pass bits (m={m} n={n} k={k} case={case})"
            );
        }
    }
}

/// Independent widening integer reference for the quantized tier
/// (wrapping i32, written from scratch like [`reference`] so the
/// crate's own oracle is under test too).
fn qreference(a: &Matrix<u8>, b: &Matrix<i8>, c0: &Matrix<i32>, accumulate: bool) -> Matrix<i32> {
    let (m, n) = (c0.rows(), c0.cols());
    let k = a.cols();
    Matrix::from_fn(m, n, |r, col| {
        let mut acc = if accumulate { c0.get(r, col) } else { 0 };
        for p in 0..k {
            acc = acc.wrapping_add(i32::from(a.get(r, p)) * i32::from(b.get(p, col)));
        }
        acc
    })
}

#[test]
fn quantized_scalar_path_is_ub_free_on_fringe_grid() {
    hermetic_tune_cache();
    // Under Miri detect_avx2() reports false, so quant::qgemm routes to
    // the scalar fallback — packing (XOR-0x80 A strips, k-grouped B
    // panels, wrapping column sums), the dot loop and the zero-point
    // writeback all run interpreted. Exactness means bitwise equality.
    use emmerald::gemm::quant;
    for &m in &DIMS {
        for &n in &DIMS {
            let k = (m * 2 + n) % 9 + 1;
            for accumulate in [false, true] {
                let a = Matrix::from_fn(m, k, |r, c| (r * 37 + c * 11) as u8);
                // Full i8 range including −128: the scalar tier has no
                // vpsignb hazard, so nothing is special-cased here.
                let b = Matrix::from_fn(k, n, |r, c| ((r * 29 + c * 13) % 256) as u8 as i8);
                let c0 = Matrix::from_fn(m, n, |r, c| (r as i32) - 2 * (c as i32));
                let want = qreference(&a, &b, &c0, accumulate);
                let mut got = c0.clone();
                quant::qgemm(Transpose::No, Transpose::No, a.view(), b.view(), &mut got.view_mut(), accumulate);
                assert_eq!(got.data(), want.data(), "qgemm m={m} n={n} k={k} acc={accumulate}");
                // The generic naive triple (the crate oracle) must agree too.
                let mut nv = c0.clone();
                emmerald::gemm::naive::gemm_triple::<emmerald::gemm::Qu8i8>(
                    Transpose::No,
                    Transpose::No,
                    a.view(),
                    b.view(),
                    &mut nv.view_mut(),
                    accumulate,
                );
                assert_eq!(nv.data(), want.data(), "naive triple m={m} n={n} k={k}");
            }
        }
    }
}

#[test]
fn quantized_requant_writeback_is_ub_free() {
    hermetic_tune_cache();
    // The fused requant writeback (zero-point correction + scales + bias
    // + activation) through the scalar driver, plus the context's
    // prepacked-B route — covers QPackedB packing and the row-sliced
    // plan entry under the interpreter.
    use emmerald::gemm::{quant, Requant};
    let (m, n, k) = (6, 17, 9);
    let a = Matrix::from_fn(m, k, |r, c| (r * 41 + c * 7) as u8);
    let b = Matrix::from_fn(k, n, |r, c| (((r * 23 + c * 5) % 255) as i32 - 127) as i8);
    let rq = Requant::per_row(
        (0..m).map(|r| 0.01 + r as f32 * 0.002).collect(),
        (0..m).map(|r| (r % 5) as i32).collect(),
        (0..n).map(|c| 0.2 + c as f32 * 0.01).collect(),
    )
    .bias((0..n).map(|c| c as f32 / 8.0 - 1.0).collect())
    .activation(Activation::Relu);

    let mut serial = Matrix::<f32>::zeros(m, n);
    quant::qgemm_requant(Transpose::No, Transpose::No, a.view(), b.view(), &mut serial.view_mut(), &rq);

    // Scalar reference: raw wrapping sums through Requant::apply_scalar.
    let raw = qreference(&a, &b, &Matrix::<i32>::zeros(m, n), false);
    for r in 0..m {
        for col in 0..n {
            let mut colsum = 0i32;
            for p in 0..k {
                colsum = colsum.wrapping_add(i32::from(b.get(p, col)));
            }
            let want = rq.apply_scalar(raw.get(r, col), colsum, r, col);
            assert_eq!(serial.get(r, col).to_bits(), want.to_bits(), "requant ({r},{col})");
        }
    }

    let ctx = GemmContext::new(DispatchConfig { threads: 2, ..DispatchConfig::default() });
    let pb = ctx.qpack_b(Transpose::No, k, n, b.data(), b.ld()).unwrap();
    let mut prepacked = Matrix::<f32>::zeros(m, n);
    ctx.qgemm_requant_packed_b(Transpose::No, a.view(), &pb, prepacked.view_mut(), &rq).unwrap();
    assert_eq!(prepacked.data(), serial.data(), "prepacked requant != serial bits");
}

#[test]
fn threadpool_contains_and_rethrows_job_panics() {
    hermetic_tune_cache();
    // run_borrowed is the unsafe heart of the parallel tier (it
    // transmutes borrowed closures to 'static for the worker queue);
    // Miri checks that the borrow really does end before the call
    // returns, including on the panic path.
    use std::sync::atomic::{AtomicUsize, Ordering};
    let pool = ThreadPool::new(2);
    let completed = AtomicUsize::new(0);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
        Box::new(|| {
            completed.fetch_add(1, Ordering::SeqCst);
        }),
        Box::new(|| panic!("seeded job panic")),
        Box::new(|| {
            completed.fetch_add(1, Ordering::SeqCst);
        }),
        Box::new(|| {
            completed.fetch_add(1, Ordering::SeqCst);
        }),
    ];
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.run_borrowed(jobs);
    }));
    let payload = caught.expect_err("job panic must re-raise on the caller");
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
        .unwrap_or("<non-string payload>");
    assert!(msg.contains("seeded job panic"), "unexpected payload: {msg}");
    // The group ran to completion before the re-raise: every
    // non-panicking job finished (the panic was contained to its job).
    assert_eq!(completed.load(Ordering::SeqCst), 3);

    // And the pool is still usable afterwards.
    let after = AtomicUsize::new(0);
    pool.run_borrowed(vec![
        Box::new(|| {
            after.fetch_add(1, Ordering::SeqCst);
        }),
        Box::new(|| {
            after.fetch_add(1, Ordering::SeqCst);
        }),
    ]);
    assert_eq!(after.load(Ordering::SeqCst), 2);
}
