//! Conformance suite for the GEMM service (`emmerald::serve`).
//!
//! The service's contract is that caching and coalescing are pure
//! plumbing: a request answered through a cached plan, a cached packed
//! weight, or as a member of a coalesced batch returns **bitwise** the
//! same bytes as the equivalent one-shot call — f32 whenever both paths
//! run the same kernel (the prepacked-vs-unpacked caveat below), and
//! unconditionally for the exact integer quantized tier. On top of the
//! value contract, the cache must behave like a cache: LRU eviction
//! under pressure, one packer per stampede, and stale entries dropped
//! when a weight ID is re-registered.
//!
//! f32 caveat (same as `tests/plan_reuse.rs`): gemv-shaped problems
//! (`m < tile_min_m` on AVX2 hosts) run the dot kernel unpacked but the
//! tile layout prepacked, so service-vs-positional bit-identity is
//! asserted only when both sides run the layout's own kernel. Service
//! paths against each other (cached vs coalesced vs repeated) share one
//! plan and one pack, so those comparisons are unconditional.

use std::sync::Arc;

use emmerald::blas::{
    qgemm, qgemm_served, sgemm, sgemm_served, Backend, GemmContext, Matrix, Transpose,
};
use emmerald::gemm::KernelId;
use emmerald::nn::{Linear, Mlp};
use emmerald::serve::{
    FOperand, GemmService, PlanCache, PlanSpec, QOperand, QgemmOut, QgemmRequest, ServeConfig,
    ServeStats, SgemmRequest, WeightId, WeightKey,
};
use emmerald::util::prng::Pcg32;
use emmerald::util::testkit::{assert_allclose, hermetic_tune_cache};

fn rand_vec(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    let mut v = vec![0.0f32; len];
    rng.fill_f32(&mut v, -1.0, 1.0);
    v
}

/// A service over the same context the positional `Backend::Dispatch`
/// entry points use, so both sides resolve identical plans.
fn service_over_global() -> GemmService {
    GemmService::new(GemmContext::global().clone(), ServeConfig::default())
}

/// Whether prepacked and unpacked drivers run the same kernel for an
/// `m`-row problem on this host (see the module docs).
fn tile_consistent(m: usize) -> bool {
    let snap = GemmContext::global().snapshot();
    KernelId::Simd.available()
        && (snap.best_serial_vector() != KernelId::Avx2Tile || m >= snap.config().tile_min_m)
}

#[test]
fn served_sgemm_matches_one_shot_and_repeats_hit_the_cache() {
    hermetic_tune_cache();
    let svc = service_over_global();
    let (m, n, k) = (32usize, 24, 16);
    let b = rand_vec(0x51, k * n);
    svc.register_weight(7, b.clone(), n);

    let mut replies = Vec::new();
    for round in 0..3 {
        let a = rand_vec(0x60, m * k); // same A every round: replies must agree bitwise
        let got = svc
            .submit(SgemmRequest::new(m, n, k, a, FOperand::Registered(WeightId(7))))
            .unwrap()
            .wait()
            .unwrap();
        if round == 0 {
            let a = rand_vec(0x60, m * k);
            let mut want = vec![0.0f32; m * n];
            sgemm(Backend::Dispatch, Transpose::No, Transpose::No, m, n, k, 1.0, &a, k, &b, n, 0.0, &mut want, n)
                .unwrap();
            if tile_consistent(m) {
                assert_eq!(got, want, "service answer vs one-shot sgemm must be bit-identical");
            }
            assert_allclose(&got, &want, 5e-4, 1e-4, "service answer vs one-shot sgemm");
        }
        replies.push(got);
    }
    assert_eq!(replies[0], replies[1], "cached-plan repeat must be bit-identical");
    assert_eq!(replies[1], replies[2], "cached-pack repeat must be bit-identical");

    let s = svc.stats();
    assert_eq!(s.plan_misses, 1, "one plan build for three same-spec requests");
    assert!(s.plan_hits >= 2, "repeats must hit the plan cache (got {})", s.plan_hits);
    assert_eq!(s.pack_misses, 1, "one packing for three requests against one weight");
    assert!(s.pack_hits >= 2, "repeats must hit the pack cache (got {})", s.pack_hits);
}

#[test]
fn coalesced_batch_is_bitwise_identical_to_one_shot_service_calls() {
    hermetic_tune_cache();
    let (m, n, k) = (16usize, 12, 10);
    let b = rand_vec(0x71, k * n);
    let activations: Vec<Vec<f32>> = (0..4).map(|i| rand_vec(0x80 + i, m * k)).collect();

    // Arm 1: staged coalesced batch — pause, queue all four, release.
    let svc = service_over_global();
    svc.register_weight(3, b.clone(), n);
    svc.pause();
    let tickets: Vec<_> = activations
        .iter()
        .map(|a| {
            svc.submit(SgemmRequest::new(m, n, k, a.clone(), FOperand::Registered(WeightId(3))))
                .unwrap()
        })
        .collect();
    svc.resume();
    let coalesced: Vec<Vec<f32>> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    let s = svc.stats();
    assert_eq!(s.coalesced_batches, 1, "four same-key requests must fold into one batch");
    assert_eq!(s.coalesced_requests, 3);

    // Arm 2: the same traffic one request at a time on a fresh service.
    let one_shot = service_over_global();
    one_shot.register_weight(3, b.clone(), n);
    for (i, a) in activations.iter().enumerate() {
        let got = one_shot
            .submit(SgemmRequest::new(m, n, k, a.clone(), FOperand::Registered(WeightId(3))))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(
            coalesced[i], got,
            "coalesced member {i} must be bit-identical to its one-shot run"
        );
    }
}

#[test]
fn qgemm_service_paths_are_exact() {
    hermetic_tune_cache();
    let svc = service_over_global();
    let (m, n, k) = (9usize, 13, 17);
    let a: Vec<u8> = (0..m * k).map(|i| (i * 37 % 251) as u8).collect();
    let b: Vec<i8> = (0..k * n).map(|i| ((i * 29 % 255) as i32 - 127) as i8).collect();

    // The integer tier accumulates mod 2^32 — exact on every path, so
    // the service must agree with the one-shot driver bitwise,
    // registered or inline, cached or not.
    let mut want = vec![0i32; m * n];
    qgemm(Transpose::No, Transpose::No, m, n, k, &a, k, &b, n, &mut want, n, false).unwrap();

    svc.register_qweight(11, b.clone(), n);
    // Registered twice (second ride hits the cached pack), then inline
    // (its own content-hash key, so its own packing).
    let ops = [
        QOperand::Registered(WeightId(11)),
        QOperand::Registered(WeightId(11)),
        QOperand::Inline(b.clone()),
    ];
    for bop in ops {
        let out = svc
            .submit_q(QgemmRequest::new(m, n, k, a.clone(), bop))
            .unwrap()
            .wait()
            .unwrap();
        match out {
            QgemmOut::I32(got) => assert_eq!(got, want, "service qgemm must be exact"),
            QgemmOut::F32(_) => panic!("accumulator request answered f32"),
        }
    }
    let s = svc.stats();
    assert_eq!(s.pack_misses, 2, "one packing per weight key (registered id, content hash)");
    assert!(s.pack_hits >= 1, "the repeated registered request must hit the cached pack");
}

#[test]
fn served_shims_match_their_positional_counterparts() {
    hermetic_tune_cache();
    let (m, n, k) = (32usize, 10, 14);
    let a = rand_vec(0x91, m * k);
    let b = rand_vec(0x92, k * n);
    let mut got = vec![0.0f32; m * n];
    let mut want = vec![0.0f32; m * n];
    sgemm_served(Transpose::No, Transpose::No, m, n, k, 1.0, &a, k, &b, n, 0.0, &mut got, n)
        .unwrap();
    sgemm(Backend::Dispatch, Transpose::No, Transpose::No, m, n, k, 1.0, &a, k, &b, n, 0.0, &mut want, n)
        .unwrap();
    if tile_consistent(m) {
        assert_eq!(got, want, "sgemm_served vs sgemm must be bit-identical");
    }
    assert_allclose(&got, &want, 5e-4, 1e-4, "sgemm_served vs sgemm");

    let qa: Vec<u8> = (0..m * k).map(|i| (i * 13 % 256) as u8).collect();
    let qb: Vec<i8> = (0..k * n).map(|i| ((i * 7 % 255) as i32 - 127) as i8).collect();
    let ldc = n + 2;
    let mut qgot = vec![-7i32; m * ldc];
    let mut qwant = qgot.clone();
    qgemm_served(Transpose::No, Transpose::No, m, n, k, &qa, k, &qb, n, &mut qgot, ldc).unwrap();
    qgemm(Transpose::No, Transpose::No, m, n, k, &qa, k, &qb, n, &mut qwant, ldc, false).unwrap();
    assert_eq!(qgot, qwant, "qgemm_served vs qgemm must be exact, padding included");
}

#[test]
fn lru_eviction_under_pressure_and_stale_keys_on_reregistration() {
    hermetic_tune_cache();
    let ctx = GemmContext::global().clone();
    let svc = GemmService::new(ctx, ServeConfig { cache_capacity: 4, ..ServeConfig::default() });
    let (m, n, k) = (8usize, 8, 8);
    let a = rand_vec(0xA0, m * k);

    // More distinct inline weights than the cache holds (each request
    // caches a plan + a pack, so 6 distinct weights overflow 4 slots).
    for i in 0..6u64 {
        let b = rand_vec(0xB0 + i, k * n);
        svc.submit(SgemmRequest::new(m, n, k, a.clone(), FOperand::Inline(b)))
            .unwrap()
            .wait()
            .unwrap();
    }
    assert!(svc.stats().evictions > 0, "capacity 4 under 6 weights must evict");
    assert!(svc.cache().len() <= 4, "cache must stay within capacity");

    // Re-registering an ID must drop entries packed from the old bytes:
    // the next answer reflects the new weight, not a stale pack.
    let b_old = rand_vec(0xC0, k * n);
    let b_new = rand_vec(0xC1, k * n);
    svc.register_weight(5, b_old, n);
    svc.submit(SgemmRequest::new(m, n, k, a.clone(), FOperand::Registered(WeightId(5))))
        .unwrap()
        .wait()
        .unwrap();
    svc.register_weight(5, b_new.clone(), n);
    assert!(svc.stats().invalidations > 0, "replacing a live weight must invalidate its packs");
    let got = svc
        .submit(SgemmRequest::new(m, n, k, a.clone(), FOperand::Registered(WeightId(5))))
        .unwrap()
        .wait()
        .unwrap();
    let mut want = vec![0.0f32; m * n];
    sgemm(Backend::Dispatch, Transpose::No, Transpose::No, m, n, k, 1.0, &a, k, &b_new, n, 0.0, &mut want, n)
        .unwrap();
    assert_allclose(&got, &want, 5e-4, 1e-4, "post-re-registration answer must use the new bytes");
}

#[test]
fn pack_stampede_elects_one_packer_and_every_handle_shares_storage() {
    hermetic_tune_cache();
    let ctx = GemmContext::global();
    let stats = Arc::new(ServeStats::default());
    let cache = PlanCache::new(8, Arc::clone(&stats));
    let (k, n) = (24usize, 20);
    let b = rand_vec(0xD0, k * n);
    let key = WeightKey { id: WeightId(1), transb: false, k, n };

    let clients = 8usize;
    let mut handles = Vec::new();
    std::thread::scope(|scope| {
        let spawned: Vec<_> = (0..clients)
            .map(|_| {
                let (cache, b) = (&cache, &b);
                scope.spawn(move || {
                    cache.get_or_pack_b(key, || ctx.pack_b(Transpose::No, k, n, b, n)).unwrap()
                })
            })
            .collect();
        for h in spawned {
            handles.push(h.join().expect("stampede client panicked"));
        }
    });
    let s = stats.snapshot();
    assert_eq!(s.pack_misses, 1, "exactly one thread may pack under a stampede");
    assert_eq!(s.pack_hits, clients as u64 - 1, "every other thread rides the winner's pack");
    for h in &handles[1..] {
        assert!(handles[0].shares_storage(h), "stampede handles must share one allocation");
    }

    // The shared handle computes the same bytes as a fresh pack.
    let m = 8usize;
    let a = rand_vec(0xD1, m * k);
    let plan = ctx.gemm().plan(m, n, k).unwrap();
    let fresh = ctx.pack_b(Transpose::No, k, n, &b, n).unwrap();
    let mut c_shared = vec![0.0f32; m * n];
    let mut c_fresh = vec![0.0f32; m * n];
    plan.run_packed_b(&a, &handles[0], &mut c_shared).unwrap();
    plan.run_packed_b(&a, &fresh, &mut c_fresh).unwrap();
    assert_eq!(c_shared, c_fresh, "shared cached pack vs fresh pack must be bit-identical");
}

#[test]
fn direct_cache_doorways_share_plans_and_packs() {
    hermetic_tune_cache();
    let svc = service_over_global();
    let (m, n, k) = (8usize, 12, 10);
    let b = rand_vec(0xE0, k * n);

    // Two threads resolve the same inline weight through the synchronous
    // doorway (the nn forward path): one packing, shared storage.
    let (p1, p2) = std::thread::scope(|scope| {
        let h1 = scope.spawn(|| svc.cached_pack_b(Transpose::No, k, n, &b, n).unwrap());
        let h2 = scope.spawn(|| svc.cached_pack_b(Transpose::No, k, n, &b, n).unwrap());
        (h1.join().unwrap(), h2.join().unwrap())
    });
    assert_eq!(p1.0, p2.0, "same bytes must hash to the same weight id");
    assert!(p1.1.shares_storage(&p2.1), "cached packs of one weight must share storage");
    assert_eq!(svc.stats().pack_misses, 1);

    let plan_a = svc.cached_plan(&PlanSpec::new(m, n, k)).unwrap();
    let plan_b = svc.cached_plan(&PlanSpec::new(m, n, k)).unwrap();
    let a = rand_vec(0xE1, m * k);
    let mut c1 = vec![0.0f32; m * n];
    let mut c2 = vec![0.0f32; m * n];
    plan_a.run_packed_b(&a, &p1.1, &mut c1).unwrap();
    plan_b.run_packed_b(&a, &p2.1, &mut c2).unwrap();
    assert_eq!(c1, c2, "cached plan + cached pack must reproduce bitwise");
    assert_eq!(svc.stats().plan_misses, 1, "equal specs share one cached plan");
    assert!(svc.stats().plan_hits >= 1);
}

#[test]
fn mlp_forward_served_is_bitwise_identical_to_forward_packed() {
    hermetic_tune_cache();
    let svc = service_over_global();
    let mlp = Mlp::init(&[6, 10, 4], 42, Backend::Dispatch);
    let x = Matrix::random(9, 6, 7, -1.0, 1.0);

    let packed = mlp.pack_weights(svc.context());
    let want = mlp.forward_packed(&packed, &x);
    let got = mlp.forward_served(&svc, &x);
    assert_eq!(
        got.data(),
        want.data(),
        "forward_served must run the same plans over the same packed panels as forward_packed"
    );

    // Second call hits both tiers for every layer.
    let before = svc.stats();
    let again = mlp.forward_served(&svc, &x);
    assert_eq!(again.data(), want.data());
    let after = svc.stats();
    assert_eq!(after.plan_misses, before.plan_misses, "repeat forward builds no new plans");
    assert_eq!(after.pack_misses, before.pack_misses, "repeat forward packs nothing");
    assert!(after.plan_hits >= before.plan_hits + 2);
    assert!(after.pack_hits >= before.pack_hits + 2);
}

#[test]
fn quantize_weights_served_shares_one_packing_across_instances() {
    hermetic_tune_cache();
    use emmerald::gemm::Activation;
    let svc = service_over_global();
    let layer = Linear::init(12, 8, 3, Activation::Relu);
    let q_direct = layer.quantize_weights(svc.context());
    let q1 = layer.quantize_weights_served(&svc);
    let q2 = layer.quantize_weights_served(&svc);
    assert!(
        q1.packed().shares_storage(q2.packed()),
        "two served quantizations of one layer must share the packed panels"
    );
    assert_eq!(svc.stats().pack_misses, 1, "the second quantization must not repack");

    // Identical packed content ⇒ identical (exact integer) forward.
    let x = Matrix::random(5, 12, 9, -1.0, 1.0);
    let y_direct = q_direct.forward(&x).unwrap();
    let y_served = q1.forward(&x).unwrap();
    assert_eq!(
        y_served.data(),
        y_direct.data(),
        "served quantized forward must match the direct packing bitwise"
    );
}
