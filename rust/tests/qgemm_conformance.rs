//! Quantized-GEMM conformance suite.
//!
//! The `u8 × i8 → i32` tier's contract is **exactness**: integer
//! accumulation wraps mod 2³², which is associative, so every driver —
//! the AVX2 `maddubs` tile, its scalar fallback, the parallel row split
//! and the prepacked-B path — must agree *bitwise* with the widening
//! naive oracle ([`emmerald::gemm::quant::qgemm_reference`]), not merely
//! to a tolerance. That contract is exercised on the tile tier's fringe
//! grid, across 257-dimension block boundaries, at the u8/i8 saturation
//! extremes, through the `−128` scalar fallback, and through the fused
//! [`Requant`] writeback against its scalar reference.

use emmerald::blas::{GemmContext, MatMut, MatRef, Matrix, Transpose};
use emmerald::gemm::quant;
use emmerald::gemm::{Activation, DispatchConfig, Requant};
use emmerald::util::testkit::hermetic_tune_cache;

/// Sentinel painted into the padding tail of strided `C` rows.
const PAD_I32: i32 = -7777;
const PAD_F32: f32 = -77.0;

/// Deterministic full-range u8 fill.
fn a_mat(transa: Transpose, m: usize, k: usize, seed: u64) -> Matrix<u8> {
    let (ar, ac) = match transa {
        Transpose::No => (m, k),
        Transpose::Yes => (k, m),
    };
    Matrix::from_fn(ar, ac, |r, c| {
        let x = (r as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((c as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add(seed);
        (x >> 56) as u8
    })
}

/// Deterministic i8 fill over `[-127, 127]` — avoids `−128` so the AVX2
/// `vpsignb` fast path stays eligible (the hazard gets its own test).
fn b_mat(transb: Transpose, k: usize, n: usize, seed: u64) -> Matrix<i8> {
    let (br, bc) = match transb {
        Transpose::No => (k, n),
        Transpose::Yes => (n, k),
    };
    Matrix::from_fn(br, bc, |r, c| {
        let x = (r as u64)
            .wrapping_mul(0xD605_0B53_86D5_2BAD)
            .wrapping_add((c as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
            .wrapping_add(seed ^ 0xABCD);
        (((x >> 40) % 255) as i32 - 127) as i8
    })
}

/// Strided `C` buffer: logical `m × n` at leading dimension `ld`, data
/// filled from `(r, c)`, padding tail painted with [`PAD_I32`].
fn c_buf(m: usize, n: usize, ld: usize, f: impl Fn(usize, usize) -> i32) -> Vec<i32> {
    let mut buf = vec![PAD_I32; m * ld];
    for r in 0..m {
        for c in 0..n {
            buf[r * ld + c] = f(r, c);
        }
    }
    buf
}

fn assert_padding(buf: &[i32], m: usize, n: usize, ld: usize, what: &str) {
    for r in 0..m {
        for p in n..ld {
            assert_eq!(buf[r * ld + p], PAD_I32, "{what}: padding clobbered at ({r},{p})");
        }
    }
}

/// One exactness check: `quant::qgemm` (serial, AVX2 or scalar as
/// detected) against the widening naive oracle, on strided `C`.
fn check_exact(transa: Transpose, transb: Transpose, m: usize, n: usize, k: usize, accumulate: bool, seed: u64) {
    let what = format!("qgemm m={m} n={n} k={k} ta={transa:?} tb={transb:?} acc={accumulate}");
    let a = a_mat(transa, m, k, seed);
    let b = b_mat(transb, k, n, seed);
    let ld = n + 3;
    let prefill = |r: usize, c: usize| (r * 3 + c) as i32 - 11;
    let mut got = c_buf(m, n, ld, prefill);
    let mut expect = got.clone();

    let mut cg = MatMut::new(&mut got, m, n, ld).unwrap();
    quant::qgemm(transa, transb, a.view(), b.view(), &mut cg, accumulate);
    let mut ce = MatMut::new(&mut expect, m, n, ld).unwrap();
    quant::qgemm_reference(transa, transb, a.view(), b.view(), &mut ce, accumulate);

    assert_eq!(got, expect, "{what}: driver != widening oracle");
    assert_padding(&got, m, n, ld, &what);
}

#[test]
fn qgemm_matches_widening_oracle_on_fringe_grid() {
    hermetic_tune_cache();
    // The int8 tile's fringe dims (1, MR±1, NR±1) cubed, all four
    // transpose layouts, alternating accumulate — every (m % MR, n % NR,
    // k % 4) fringe combination crosses the masked-writeback path.
    let dims = [1usize, 5, 7, 15, 17];
    let mut case = 0u64;
    for &m in &dims {
        for &n in &dims {
            for &k in &dims {
                for transa in [Transpose::No, Transpose::Yes] {
                    for transb in [Transpose::No, Transpose::Yes] {
                        case += 1;
                        check_exact(transa, transb, m, n, k, case % 2 == 0, case);
                    }
                }
            }
        }
    }
}

#[test]
fn qgemm_exact_across_257_block_boundaries() {
    hermetic_tune_cache();
    // 257 = one past a power of two, crossing every internal boundary:
    // m=257 spans three 96-row A blocks (default qtile mc) with a 5-row fringe,
    // n=257 spans 17 B panels (NR=16) with a 1-column fringe, and k=257
    // spans 65 k-groups (4) with a 1-deep fringe.
    for (m, n, k, ta, tb) in [
        (257, 16, 64, Transpose::No, Transpose::No),
        (6, 257, 32, Transpose::No, Transpose::Yes),
        (5, 16, 257, Transpose::Yes, Transpose::No),
        (257, 17, 96, Transpose::Yes, Transpose::Yes),
    ] {
        check_exact(ta, tb, m, n, k, true, (m + n + k) as u64);
    }
}

#[test]
fn qgemm_exact_at_saturation_extremes() {
    hermetic_tune_cache();
    // Worst-case magnitudes: every a = 255 (u8 max) against b = ±127
    // (the i8 extremes the weight quantizer emits). k=64 keeps the true
    // sums inside i32, so exactness means bit-equality with the plain
    // widening sum — no hidden i16 saturation in the maddubs pipeline.
    let (m, n, k) = (8, 32, 64);
    let a = Matrix::from_fn(m, k, |_, _| 255u8);
    let b = Matrix::from_fn(k, n, |r, c| if (r + c) % 2 == 0 { 127i8 } else { -127 });
    let ld = n + 1;
    let mut got = c_buf(m, n, ld, |_, _| 0);
    let mut cg = MatMut::new(&mut got, m, n, ld).unwrap();
    quant::qgemm(Transpose::No, Transpose::No, a.view(), b.view(), &mut cg, false);
    for r in 0..m {
        for c in 0..n {
            let mut want = 0i64;
            for p in 0..k {
                want += 255 * i64::from(b.data()[p * n + c]);
            }
            assert_eq!(i64::from(got[r * ld + c]), want, "saturation case at ({r},{c})");
        }
    }
    assert_padding(&got, m, n, ld, "saturation");
}

#[test]
fn neg128_weights_take_scalar_fallback_and_stay_exact() {
    hermetic_tune_cache();
    let ctx = GemmContext::new(DispatchConfig::default());
    let (m, n, k) = (9, 18, 21);
    // One −128 anywhere in B poisons vpsignb; the packed handle must
    // flag it and every driver must still be exact via the fallback.
    let b = Matrix::from_fn(k, n, |r, c| if (r, c) == (k - 1, n - 1) { -128i8 } else { (r as i8) - (c as i8) });
    let pb = ctx.qpack_b(Transpose::No, k, n, b.data(), b.ld()).unwrap();
    assert!(pb.has_neg128(), "the −128 byte must be screened at pack time");
    check_exact(Transpose::No, Transpose::No, m, n, k, false, 0x128);
    // And through the context path with the flagged handle:
    let a = a_mat(Transpose::No, m, k, 0x128);
    let mut got = Matrix::<i32>::zeros(m, n);
    ctx.qgemm_packed_b(Transpose::No, a.view(), &pb, got.view_mut(), false).unwrap();
    let mut expect = Matrix::<i32>::zeros(m, n);
    quant::qgemm_reference(Transpose::No, Transpose::No, a.view(), b.view(), &mut expect.view_mut(), false);
    assert_eq!(got.data(), expect.data(), "−128 fallback diverged from oracle");
}

#[test]
fn serial_parallel_and_prepacked_agree_bitwise() {
    hermetic_tune_cache();
    let par = GemmContext::new(DispatchConfig { threads: 4, ..DispatchConfig::default() });
    for (m, n, k) in [(64, 33, 48), (97, 16, 257), (17, 64, 5)] {
        for transa in [Transpose::No, Transpose::Yes] {
            let what = format!("drivers m={m} n={n} k={k} ta={transa:?}");
            let a = a_mat(transa, m, k, (m * n + k) as u64);
            let b = b_mat(Transpose::No, k, n, (m + n * k) as u64);
            let prefill = |r: usize, c: usize| (r as i32) - (c as i32) * 5;

            let mut serial = Matrix::from_fn(m, n, prefill);
            quant::qgemm(transa, Transpose::No, a.view(), b.view(), &mut serial.view_mut(), true);

            let mut parallel = Matrix::from_fn(m, n, prefill);
            par.qgemm(transa, Transpose::No, a.view(), b.view(), parallel.view_mut(), true).unwrap();
            assert_eq!(serial.data(), parallel.data(), "{what}: serial != parallel");

            let pb = par.qpack_b(Transpose::No, k, n, b.data(), b.ld()).unwrap();
            let mut prepacked = Matrix::from_fn(m, n, prefill);
            par.qgemm_packed_b(transa, a.view(), &pb, prepacked.view_mut(), true).unwrap();
            assert_eq!(serial.data(), prepacked.data(), "{what}: serial != prepacked");
        }
    }
}

/// The scalar requant reference (untransposed operands): raw wrapping
/// sums from the widening oracle, each funnelled once through
/// [`Requant::apply_scalar`] with the exact wrapping column sum —
/// precisely the fused writeback's definition, computed the slow way.
fn requant_reference(a: &Matrix<u8>, b: &Matrix<i8>, m: usize, n: usize, k: usize, rq: &Requant) -> Matrix<f32> {
    let mut raw = Matrix::<i32>::zeros(m, n);
    quant::qgemm_reference(Transpose::No, Transpose::No, a.view(), b.view(), &mut raw.view_mut(), false);
    let bv = b.view();
    let colsum = |c: usize| -> i32 {
        let mut s = 0i32;
        for p in 0..k {
            s = s.wrapping_add(i32::from(bv.get(p, c)));
        }
        s
    };
    Matrix::from_fn(m, n, |r, c| rq.apply_scalar(raw.data()[r * n + c], colsum(c), r, c))
}

#[test]
fn requant_writeback_matches_scalar_reference_bitwise() {
    hermetic_tune_cache();
    let par = GemmContext::new(DispatchConfig { threads: 3, ..DispatchConfig::default() });
    for (case, (m, n, k)) in [(0usize, (1, 1, 1)), (1, (7, 17, 23)), (2, (64, 16, 40)), (3, (33, 19, 257))].into_iter() {
        let what = format!("requant m={m} n={n} k={k} case={case}");
        let a = a_mat(Transpose::No, m, k, case as u64 + 9);
        let b = b_mat(Transpose::No, k, n, case as u64 + 90);
        let rq = match case % 3 {
            0 => Requant::uniform(0.02, 3, 0.5),
            1 => Requant::per_row(
                (0..m).map(|r| 0.01 + r as f32 * 0.003).collect(),
                (0..m).map(|r| (r % 7) as i32).collect(),
                (0..n).map(|c| 0.25 + c as f32 * 0.01).collect(),
            )
            .bias((0..n).map(|c| c as f32 * 0.125 - 1.0).collect())
            .activation(Activation::Relu),
            _ => Requant::uniform(0.004, 128, 0.75).activation(Activation::Tanh),
        };
        let expect = requant_reference(&a, &b, m, n, k, &rq);

        // Serial one-shot, parallel context, and prepacked context paths
        // must all hit the reference bits (the writeback is a pure
        // per-element function of the exact wrapping sum).
        let mut serial = Matrix::<f32>::zeros(m, n);
        quant::qgemm_requant(Transpose::No, Transpose::No, a.view(), b.view(), &mut serial.view_mut(), &rq);
        let mut parallel = Matrix::<f32>::zeros(m, n);
        par.qgemm_requant(Transpose::No, Transpose::No, a.view(), b.view(), parallel.view_mut(), &rq).unwrap();
        let pb = par.qpack_b(Transpose::No, k, n, b.data(), b.ld()).unwrap();
        let mut prepacked = Matrix::<f32>::zeros(m, n);
        par.qgemm_requant_packed_b(Transpose::No, a.view(), &pb, prepacked.view_mut(), &rq).unwrap();

        for (name, got) in [("serial", &serial), ("parallel", &parallel), ("prepacked", &prepacked)] {
            for i in 0..m * n {
                assert_eq!(
                    got.data()[i].to_bits(),
                    expect.data()[i].to_bits(),
                    "{what}: {name} diverged at flat index {i} ({} vs {})",
                    got.data()[i],
                    expect.data()[i],
                );
            }
        }
    }
}

#[test]
fn requant_strided_c_keeps_padding() {
    hermetic_tune_cache();
    let (m, n, k) = (6, 10, 12);
    let a = a_mat(Transpose::No, m, k, 5);
    let b = b_mat(Transpose::No, k, n, 6);
    let rq = Requant::uniform(0.1, 7, 0.3);
    let ld = n + 4;
    let mut buf = vec![PAD_F32; m * ld];
    let mut c = MatMut::new(&mut buf, m, n, ld).unwrap();
    quant::qgemm_requant(Transpose::No, Transpose::No, a.view(), b.view(), &mut c, &rq);
    let expect = requant_reference(&a, &b, m, n, k, &rq);
    for r in 0..m {
        for col in 0..n {
            assert_eq!(buf[r * ld + col].to_bits(), expect.data()[r * n + col].to_bits());
        }
        for p in n..ld {
            assert_eq!(buf[r * ld + p], PAD_F32, "padding clobbered at ({r},{p})");
        }
    }
}

#[test]
fn degenerate_dims_are_handled() {
    hermetic_tune_cache();
    let ctx = GemmContext::new(DispatchConfig::default());
    // k == 0: overwrite zeroes C, accumulate leaves it untouched.
    let a = Matrix::<u8>::zeros(3, 0);
    let b = Matrix::<i8>::zeros(0, 4);
    let mut c = Matrix::from_fn(3, 4, |r, c| (r + c) as i32 + 1);
    let keep = c.clone();
    ctx.qgemm(Transpose::No, Transpose::No, a.view(), b.view(), c.view_mut(), true).unwrap();
    assert_eq!(c.data(), keep.data(), "k=0 accumulate must be a no-op");
    ctx.qgemm(Transpose::No, Transpose::No, a.view(), b.view(), c.view_mut(), false).unwrap();
    assert!(c.data().iter().all(|&v| v == 0), "k=0 overwrite must zero C");
    // m == 0 / n == 0: nothing to do, must not panic.
    let e = Matrix::<i8>::zeros(5, 0);
    let mut empty = Matrix::<i32>::zeros(0, 0);
    ctx.qgemm(Transpose::No, Transpose::No, Matrix::<u8>::zeros(0, 5).view(), e.view(), empty.view_mut(), false)
        .unwrap();

    // MatRef::new over an empty slice with rows*cols == 0 is fine; the
    // positional API routes the same dims through validation.
    emmerald::blas::qgemm(Transpose::No, Transpose::No, 0, 0, 5, &[], 5, &[], 1, &mut [], 1, false).unwrap();
}
