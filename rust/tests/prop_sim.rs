//! Property-based tests for the PIII simulator substrate.

use emmerald::sim::cache::{Cache, CacheConfig};
use emmerald::sim::piii::{piii_450, piii_550};
use emmerald::sim::timing::{simulate_gemm, Algorithm};
use emmerald::sim::tlb::Tlb;
use emmerald::util::testkit::{check, Gen};

fn random_cache(g: &mut Gen) -> Cache {
    let ways = 1 << g.rng.range_usize(0, 3); // 1..8
    let sets = 1 << g.rng.range_usize(1, 6); // 2..64
    let line = 1 << g.rng.range_usize(4, 6); // 16..64
    Cache::new(CacheConfig { capacity: sets * ways * line, ways, line_bytes: line })
}

#[test]
fn prop_cache_accounting_invariants() {
    check("hits+misses=accesses", 60, |g| {
        let mut c = random_cache(g);
        let n = g.rng.range_usize(100, 3000);
        for _ in 0..n {
            c.access(g.rng.next_u32() as u64 % 65536, g.rng.chance(0.3));
        }
        let s = c.stats();
        assert_eq!(s.hits + s.misses, s.accesses);
        assert_eq!(s.accesses, n as u64);
        assert!(s.writebacks <= s.misses, "writebacks only on evictions");
    });
}

#[test]
fn prop_repeat_access_always_hits() {
    check("temporal locality", 60, |g| {
        let mut c = random_cache(g);
        let addr = g.rng.next_u32() as u64 % 65536;
        c.access(addr, false);
        assert!(c.access(addr, false), "immediate re-access must hit");
        assert!(c.probe(addr));
    });
}

#[test]
fn prop_bigger_cache_never_misses_more() {
    // Monotonicity (same ways/line, more sets) on a random trace — LRU
    // set-associative caches with identical indexing granularity.
    check("capacity monotone", 30, |g| {
        let line = 32;
        let ways = 4;
        let small_sets = 8usize;
        let big_sets = 32usize;
        let mut small = Cache::new(CacheConfig { capacity: small_sets * ways * line, ways, line_bytes: line });
        let mut big = Cache::new(CacheConfig { capacity: big_sets * ways * line, ways, line_bytes: line });
        // Sequential+strided mix keeps this within LRU's stack property.
        let n = g.rng.range_usize(200, 2000);
        let stride = g.rng.range_usize(1, 512) as u64;
        for i in 0..n {
            let addr = (i as u64 * stride) % 131072;
            small.access(addr, false);
            big.access(addr, false);
        }
        assert!(
            big.stats().misses <= small.stats().misses,
            "bigger cache missed more: {} vs {}",
            big.stats().misses,
            small.stats().misses
        );
    });
}

#[test]
fn prop_tlb_accounting() {
    check("tlb", 60, |g| {
        let entries = g.rng.range_usize(1, 64);
        let mut t = Tlb::new(entries, 4096);
        let n = g.rng.range_usize(50, 1000);
        for _ in 0..n {
            t.access(g.rng.next_u32() as u64);
        }
        let s = t.stats();
        assert_eq!(s.accesses, n as u64);
        assert!(s.misses <= s.accesses);
        // Re-touching the last page must hit.
        let page = 0xABC000u64;
        t.access(page);
        assert!(t.access(page + 100));
    });
}

#[test]
fn prop_sim_results_are_deterministic_and_consistent() {
    check("sim determinism", 6, |g| {
        let size = [16, 24, 32, 48][g.rng.range_usize(0, 3)];
        let stride = size + g.rng.range_usize(0, 64);
        let algo = [Algorithm::Naive, Algorithm::Atlas, Algorithm::Emmerald][g.rng.range_usize(0, 2)];
        let r1 = simulate_gemm(&piii_450(), algo, size, stride);
        let r2 = simulate_gemm(&piii_450(), algo, size, stride);
        assert_eq!(r1.stats.stall_cycles, r2.stats.stall_cycles, "simulation must be deterministic");
        assert!((r1.mflops - r2.mflops).abs() < 1e-9);
        // Consistency: mflops = flops / seconds / 1e6, cycles add up.
        assert!((r1.flops - 2.0 * (size as f64).powi(3)).abs() < 1.0);
        assert!(r1.mflops > 0.0 && r1.seconds > 0.0);
        // Clock scaling: 550 is faster in wall-clock for the same trace.
        let r550 = simulate_gemm(&piii_550(), algo, size, stride);
        assert!(r550.mflops >= r1.mflops * 0.95);
    });
}

#[test]
fn prop_stall_cycles_bounded_by_worst_case() {
    check("stall bound", 8, |g| {
        let size = [16, 32, 48][g.rng.range_usize(0, 2)];
        let algo = [Algorithm::Naive, Algorithm::Atlas, Algorithm::Emmerald][g.rng.range_usize(0, 2)];
        let r = simulate_gemm(&piii_450(), algo, size, size + 4);
        let worst_per_access =
            (piii_450().latencies.memory + piii_450().latencies.tlb_miss) as u64;
        assert!(
            r.stats.stall_cycles <= r.stats.accesses * worst_per_access,
            "stalls exceed worst case"
        );
    });
}
