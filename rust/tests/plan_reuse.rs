//! Conformance suite for the planned-execution API: a `GemmPlan` executed
//! repeatedly — and `PackedA`/`PackedB` handles reused across shapes and
//! batch items — must match fresh positional `sgemm` calls bit-for-bit
//! (same kernels, same arithmetic order) and the naive oracle within
//! tolerance, including fringe m/n/k and strided C.

use emmerald::blas::{sgemm, sgemm_batch, Backend, GemmContext, Matrix, Transpose};
use emmerald::gemm::{DispatchConfig, KernelId};
use emmerald::util::prng::Pcg32;
use emmerald::util::testkit::{assert_allclose, hermetic_tune_cache};

fn rand_vec(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    let mut v = vec![0.0f32; len];
    rng.fill_f32(&mut v, -1.0, 1.0);
    v
}

/// Naive triple-loop oracle over flat row-major buffers with explicit lds.
#[allow(clippy::too_many_arguments)]
fn oracle(
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                let av = match transa {
                    Transpose::No => a[i * lda + p],
                    Transpose::Yes => a[p * lda + i],
                };
                let bv = match transb {
                    Transpose::No => b[p * ldb + j],
                    Transpose::Yes => b[j * ldb + p],
                };
                acc += (av as f64) * (bv as f64);
            }
            c[i * ldc + j] = alpha * acc as f32 + beta * c[i * ldc + j];
        }
    }
}

#[test]
fn plan_executed_twice_is_bitwise_identical_and_matches_fresh_sgemm() {
    hermetic_tune_cache();
    let ctx = GemmContext::global();
    // Fringe and non-fringe shapes, including strided C.
    for &(m, n, k, ldc_pad, seed) in &[
        (1usize, 1usize, 1usize, 0usize, 0x10u64),
        (5, 7, 13, 0, 0x11),
        (7, 5, 13, 3, 0x12),
        (33, 17, 40, 2, 0x13),
        (64, 64, 64, 0, 0x14),
    ] {
        let ldc = n + ldc_pad;
        let a = rand_vec(seed, m * k);
        let b = rand_vec(seed ^ 0xB, k * n);
        let c0 = rand_vec(seed ^ 0xC, m * ldc);
        let plan = ctx.gemm().alpha(1.25).beta(-0.5).ldc(ldc).plan(m, n, k).unwrap();

        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        plan.run(&a, &b, &mut c1).unwrap();
        plan.run(&a, &b, &mut c2).unwrap();
        assert_eq!(c1, c2, "plan re-run must be bit-identical ({m}x{n}x{k})");

        // A fresh positional call resolves the same kernel from the same
        // context, so it must agree bit-for-bit.
        let mut c3 = c0.clone();
        sgemm(Backend::Dispatch, Transpose::No, Transpose::No, m, n, k, 1.25, &a, k, &b, n, -0.5, &mut c3, ldc)
            .unwrap();
        assert_eq!(c1, c3, "plan vs fresh sgemm must be bit-identical ({m}x{n}x{k})");

        let mut c_ref = c0.clone();
        oracle(Transpose::No, Transpose::No, m, n, k, 1.25, &a, k, &b, n, -0.5, &mut c_ref, ldc);
        assert_allclose(&c1, &c_ref, 5e-4, 1e-4, &format!("plan vs oracle {m}x{n}x{k}"));
    }
}

#[test]
fn packed_b_reused_across_shapes_matches_oracle_and_plain_plan() {
    hermetic_tune_cache();
    let ctx = GemmContext::global();
    // Fringe k (padding granule) and fringe n (partial last panel).
    let (n, k) = (11usize, 21usize);
    let b = rand_vec(0xB0, k * n);
    let packed = ctx.pack_b(Transpose::No, k, n, &b, n).unwrap();
    for &(m, seed) in &[(1usize, 0x20u64), (3, 0x21), (16, 0x22), (33, 0x23)] {
        let a = rand_vec(seed, m * k);
        let plan = ctx.gemm().beta(0.25).plan(m, n, k).unwrap();
        let c0 = rand_vec(seed ^ 0xF, m * n);
        let mut c_packed = c0.clone();
        plan.run_packed_b(&a, &packed, &mut c_packed).unwrap();

        let mut c_ref = c0.clone();
        oracle(Transpose::No, Transpose::No, m, n, k, 1.0, &a, k, &b, n, 0.25, &mut c_ref, n);
        assert_allclose(&c_packed, &c_ref, 5e-4, 1e-4, &format!("packed m={m} vs oracle"));

        // Same kernel family, same geometry, same arithmetic order: the
        // prepacked run is bit-identical to the packing run — whenever
        // the unpacked plan runs the layout's own kernel. Gemv-shaped
        // plans (`m < tile_min_m` on AVX2 hosts) intentionally stay on
        // the dot kernel while the prepack carries the tile layout, so
        // only the oracle claim holds there.
        let snap = ctx.snapshot();
        let tile_consistent = snap.best_serial_vector() != KernelId::Avx2Tile
            || m >= snap.config().tile_min_m;
        if KernelId::Simd.available() && tile_consistent {
            let mut c_plain = c0.clone();
            plan.run(&a, &b, &mut c_plain).unwrap();
            assert_eq!(c_packed, c_plain, "packed vs plain plan m={m}");
        }
    }
}

#[test]
fn packed_b_reused_across_batch_items_matches_sgemm_batch() {
    hermetic_tune_cache();
    let ctx = GemmContext::global();
    let (m, n, k, batch) = (6usize, 9usize, 14usize, 5usize);
    let a = rand_vec(1, batch * m * k);
    let b = rand_vec(2, k * n);
    let c0 = rand_vec(3, batch * m * n);

    // One PackedB shared by every batch item, via per-item planned runs.
    let packed = ctx.pack_b(Transpose::No, k, n, &b, n).unwrap();
    let plan = ctx.gemm().alpha(0.75).beta(0.5).plan(m, n, k).unwrap();
    let mut c_packed = c0.clone();
    for i in 0..batch {
        plan.run_packed_b(&a[i * m * k..(i + 1) * m * k], &packed, &mut c_packed[i * m * n..(i + 1) * m * n])
            .unwrap();
    }

    // Reference 1: the batched driver's shared-B fold.
    let mut c_fold = c0.clone();
    sgemm_batch(
        Backend::Dispatch,
        Transpose::No,
        Transpose::No,
        m,
        n,
        k,
        0.75,
        &a,
        k,
        m * k,
        &b,
        n,
        0,
        0.5,
        &mut c_fold,
        n,
        m * n,
        batch,
    )
    .unwrap();
    assert_allclose(&c_packed, &c_fold, 5e-4, 1e-4, "packed items vs shared-B fold");

    // Reference 2: per-item naive oracle.
    let mut c_ref = c0.clone();
    for i in 0..batch {
        oracle(
            Transpose::No,
            Transpose::No,
            m,
            n,
            k,
            0.75,
            &a[i * m * k..],
            k,
            &b,
            n,
            0.5,
            &mut c_ref[i * m * n..],
            n,
        );
    }
    assert_allclose(&c_packed, &c_ref, 5e-4, 1e-4, "packed items vs oracle");
}

#[test]
fn packed_runs_leave_strided_c_padding_untouched() {
    hermetic_tune_cache();
    let ctx = GemmContext::global();
    let (m, n, k) = (9usize, 7usize, 12usize);
    let ldc = n + 3;
    let a = rand_vec(0x51, m * k);
    let b = rand_vec(0x52, k * n);
    let packed = ctx.pack_b(Transpose::No, k, n, &b, n).unwrap();
    let plan = ctx.gemm().ldc(ldc).plan(m, n, k).unwrap();
    let mut c = vec![-77.0f32; m * ldc];
    plan.run_packed_b(&a, &packed, &mut c).unwrap();
    for r in 0..m {
        for p in n..ldc {
            assert_eq!(c[r * ldc + p], -77.0, "padding clobbered at row {r} col {p}");
        }
        for j in 0..n {
            assert_ne!(c[r * ldc + j], -77.0, "logical element untouched at row {r} col {j}");
        }
    }
}

#[test]
fn packed_a_and_b_match_transposed_oracle() {
    hermetic_tune_cache();
    let ctx = GemmContext::global();
    let (m, n, k) = (14usize, 10usize, 17usize);
    // A stored k×m (transa=Yes), B stored n×k (transb=Yes).
    let a = rand_vec(0x61, k * m);
    let b = rand_vec(0x62, n * k);
    let packed_a = ctx.pack_a(Transpose::Yes, m, k, &a, m).unwrap();
    let packed_b = ctx.pack_b(Transpose::Yes, k, n, &b, k).unwrap();
    let plan = ctx
        .gemm()
        .transpose_a(Transpose::Yes)
        .transpose_b(Transpose::Yes)
        .alpha(2.0)
        .plan(m, n, k)
        .unwrap();
    let mut c1 = vec![0.0f32; m * n];
    let mut c2 = vec![0.0f32; m * n];
    plan.run_packed(&packed_a, &packed_b, &mut c1).unwrap();
    plan.run_packed(&packed_a, &packed_b, &mut c2).unwrap();
    assert_eq!(c1, c2, "packed re-run must be bit-identical");
    let mut c_ref = vec![0.0f32; m * n];
    oracle(Transpose::Yes, Transpose::Yes, m, n, k, 2.0, &a, m, &b, k, 0.0, &mut c_ref, n);
    assert_allclose(&c1, &c_ref, 5e-4, 1e-4, "packed A+B TT vs oracle");
}

/// Contexts for the parallel-vs-serial prepacked comparisons: identical
/// geometry, different thread budgets, a zero flop threshold so
/// test-sized problems genuinely take the parallel tier.
fn par_and_serial_ctx() -> (GemmContext, GemmContext) {
    let par = GemmContext::new(DispatchConfig {
        threads: 3,
        parallel_min_flops: 0.0,
        ..DispatchConfig::default()
    });
    let ser = GemmContext::new(DispatchConfig { threads: 1, ..DispatchConfig::default() });
    (par, ser)
}

#[test]
fn parallel_run_packed_matches_serial_run_packed_bitwise() {
    hermetic_tune_cache();
    let (ctx_par, ctx_ser) = par_and_serial_ctx();
    // Row-split and column-split shapes, all four layouts.
    for &(m, n, k) in &[(67usize, 45usize, 53usize), (1, 83, 29), (2, 90, 31)] {
        for transa in [Transpose::No, Transpose::Yes] {
            for transb in [Transpose::No, Transpose::Yes] {
                let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
                let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
                let a = rand_vec(0x90 ^ (m as u64), ar * ac);
                let b = rand_vec(0x91 ^ (n as u64), br * bc);
                let c0 = rand_vec(0x92, m * n);

                let build = |ctx: &GemmContext| {
                    let pa = ctx.pack_a(transa, m, k, &a, ac).unwrap();
                    let pb = ctx.pack_b(transb, k, n, &b, bc).unwrap();
                    let plan = ctx
                        .gemm()
                        .transpose_a(transa)
                        .transpose_b(transb)
                        .alpha(0.75)
                        .beta(0.5)
                        .plan(m, n, k)
                        .unwrap();
                    (pa, pb, plan)
                };
                let (pa_p, pb_p, plan_par) = build(&ctx_par);
                let (pa_s, pb_s, plan_ser) = build(&ctx_ser);
                if KernelId::Parallel.available() {
                    assert_eq!(
                        plan_par.kernel(),
                        KernelId::Parallel,
                        "parallel ctx must resolve Parallel for {m}x{n}x{k} ta={transa:?} tb={transb:?}"
                    );
                }
                let mut c_par = c0.clone();
                let mut c_ser = c0.clone();
                plan_par.run_packed(&pa_p, &pb_p, &mut c_par).unwrap();
                plan_ser.run_packed(&pa_s, &pb_s, &mut c_ser).unwrap();
                assert_eq!(
                    c_par, c_ser,
                    "parallel run_packed must be bit-identical to serial ({m}x{n}x{k} ta={transa:?} tb={transb:?})"
                );
                // And both agree with the naive oracle.
                let mut c_ref = c0.clone();
                oracle(transa, transb, m, n, k, 0.75, &a, ac, &b, bc, 0.5, &mut c_ref, n);
                assert_allclose(
                    &c_par,
                    &c_ref,
                    5e-4,
                    1e-4,
                    &format!("run_packed vs oracle {m}x{n}x{k} ta={transa:?} tb={transb:?}"),
                );
            }
        }
    }
}

#[test]
fn parallel_run_packed_b_matches_packing_parallel_driver_bitwise() {
    hermetic_tune_cache();
    let (ctx_par, _) = par_and_serial_ctx();
    // The prepacked-B parallel run shares row boundaries with the packing
    // parallel driver, so it must be bit-identical to plan.run on the
    // same parallel context — including transposed A (pack-on-split) and
    // the skinny column split.
    for &(m, n, k) in &[(41usize, 27usize, 33usize), (1, 61, 24), (2, 70, 19)] {
        for transa in [Transpose::No, Transpose::Yes] {
            for transb in [Transpose::No, Transpose::Yes] {
                let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
                let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
                let a = rand_vec(0xA0, ar * ac);
                let b = rand_vec(0xA1, br * bc);
                let c0 = rand_vec(0xA2, m * n);
                let packed = ctx_par.pack_b(transb, k, n, &b, bc).unwrap();
                let plan = ctx_par
                    .gemm()
                    .transpose_a(transa)
                    .transpose_b(transb)
                    .beta(1.0)
                    .plan(m, n, k)
                    .unwrap();
                let mut c_packed = c0.clone();
                let mut c_plain = c0.clone();
                plan.run_packed_b(&a, &packed, &mut c_packed).unwrap();
                plan.run(&a, &b, &mut c_plain).unwrap();
                // Bit-identity requires both paths to run the layout's
                // kernel: gemv-shaped problems (`m < tile_min_m`) run the
                // dot kernel unpacked but the tile layout prepacked on
                // AVX2 hosts, and keep only the oracle claim.
                let snap = ctx_par.snapshot();
                let tile_consistent = snap.best_serial_vector() != KernelId::Avx2Tile
                    || m >= snap.config().tile_min_m;
                if KernelId::Simd.available() && tile_consistent {
                    assert_eq!(
                        c_packed, c_plain,
                        "prepacked-B parallel run must be bit-identical to the packing driver ({m}x{n}x{k} ta={transa:?} tb={transb:?})"
                    );
                }
                let mut c_ref = c0.clone();
                oracle(transa, transb, m, n, k, 1.0, &a, ac, &b, bc, 1.0, &mut c_ref, n);
                assert_allclose(
                    &c_packed,
                    &c_ref,
                    5e-4,
                    1e-4,
                    &format!("run_packed_b vs oracle {m}x{n}x{k} ta={transa:?} tb={transb:?}"),
                );
            }
        }
    }
}

#[test]
fn parallel_packed_runs_leave_strided_c_padding_untouched() {
    hermetic_tune_cache();
    let (ctx_par, _) = par_and_serial_ctx();
    // Row split (tall) and column split (skinny) both write through
    // interleaved strided views; the -77 sentinels must survive.
    for &(m, n, k) in &[(33usize, 18usize, 21usize), (1, 40, 16), (2, 44, 13)] {
        let ldc = n + 3;
        let a = rand_vec(0xB0, m * k);
        let b = rand_vec(0xB1, k * n);
        let packed_a = ctx_par.pack_a(Transpose::No, m, k, &a, k).unwrap();
        let packed_b = ctx_par.pack_b(Transpose::No, k, n, &b, n).unwrap();
        let plan = ctx_par.gemm().ldc(ldc).plan(m, n, k).unwrap();
        for variant in ["run_packed_b", "run_packed"] {
            let mut c = vec![-77.0f32; m * ldc];
            match variant {
                "run_packed_b" => plan.run_packed_b(&a, &packed_b, &mut c).unwrap(),
                _ => plan.run_packed(&packed_a, &packed_b, &mut c).unwrap(),
            }
            for r in 0..m {
                for p in n..ldc {
                    assert_eq!(c[r * ldc + p], -77.0, "{variant}: padding clobbered at ({r},{p}) {m}x{n}x{k}");
                }
                for j in 0..n {
                    assert_ne!(c[r * ldc + j], -77.0, "{variant}: logical element untouched at ({r},{j})");
                }
            }
        }
    }
}

#[test]
fn plan_run_batch_matches_looped_plan_runs() {
    hermetic_tune_cache();
    let ctx = GemmContext::global();
    let (m, n, k, batch) = (4usize, 6usize, 8usize, 3usize);
    let strides = emmerald::gemm::BatchStrides::contiguous(m, n, k);
    let a = rand_vec(0x71, batch * m * k);
    let b = rand_vec(0x72, batch * k * n);
    let c0 = rand_vec(0x73, batch * m * n);
    let plan = ctx.gemm().alpha(1.5).beta(-1.0).plan(m, n, k).unwrap();
    let mut c_batch = c0.clone();
    plan.run_batch(&a, &b, &mut c_batch, batch, strides).unwrap();
    let mut c_loop = c0.clone();
    for i in 0..batch {
        plan.run(
            &a[i * m * k..(i + 1) * m * k],
            &b[i * k * n..(i + 1) * k * n],
            &mut c_loop[i * m * n..(i + 1) * m * n],
        )
        .unwrap();
    }
    assert_allclose(&c_batch, &c_loop, 5e-4, 1e-4, "run_batch vs looped runs");
}

#[test]
fn forced_kernel_plans_match_their_backend() {
    hermetic_tune_cache();
    let ctx = GemmContext::global();
    let (m, n, k) = (13usize, 9usize, 15usize);
    let a = rand_vec(0x81, m * k);
    let b = rand_vec(0x82, k * n);
    for (kernel, backend) in [
        (KernelId::Naive, Backend::Naive),
        (KernelId::Blocked, Backend::Blocked),
        (KernelId::Simd, Backend::Simd),
        (KernelId::Avx2, Backend::Avx2),
        (KernelId::Avx2Tile, Backend::Avx2Tile),
    ] {
        if !kernel.available() {
            continue;
        }
        let plan = ctx.gemm().kernel(kernel).plan(m, n, k).unwrap();
        assert_eq!(plan.kernel(), kernel);
        let mut c_plan = vec![0.5f32; m * n];
        let mut c_pos = vec![0.5f32; m * n];
        plan.run(&a, &b, &mut c_plan).unwrap();
        sgemm(backend, Transpose::No, Transpose::No, m, n, k, 1.0, &a, k, &b, n, 0.0, &mut c_pos, n)
            .unwrap();
        assert_eq!(c_plan, c_pos, "forced {kernel:?} vs positional backend");
    }
}

#[test]
fn matrix_helper_still_works_through_shims() {
    // The Matrix convenience wrapper rides the same one-shot plan path.
    let a = Matrix::from_fn(3, 2, |i, j| (i + j) as f32);
    let b = Matrix::from_fn(2, 4, |i, j| (i * 4 + j) as f32);
    let mut c = Matrix::zeros(3, 4);
    emmerald::blas::sgemm_matrix(Backend::Auto, Transpose::No, Transpose::No, 1.0, &a, &b, 0.0, &mut c)
        .unwrap();
    assert_eq!(c.get(1, 2), 14.0);
}
