//! Repo-local static-analysis pass over `rust/src`: the unsafe-code
//! policy checker (`cargo run -p lint`).
//!
//! The crate's safety story (README: "Safety & verification") confines
//! raw-pointer work to a small set of modules and requires every escape
//! hatch to be justified in place. `cargo`/`clippy` enforce the
//! language-level half (`unsafe_op_in_unsafe_fn`,
//! `undocumented_unsafe_blocks`); this binary enforces the repo-level
//! half, which no stock lint expresses:
//!
//! 1. **Every `unsafe` block carries a `// SAFETY:` comment** within the
//!    few lines above it (production code only — `#[cfg(test)] mod`
//!    tails are exempt: their unsafe exercises checked APIs).
//! 2. **Raw-pointer idioms stay in the allowlist.** `from_raw_parts`,
//!    `.add(` and `get_unchecked` may appear only in `util/ptr.rs` (the
//!    checked raw-handle core) and the ISA kernel modules
//!    (`gemm/microkernel.rs`, `gemm/tile.rs`, `gemm/quant.rs`,
//!    `blas/level1.rs`). Everything else goes through `util::ptr`
//!    handles or safe slices. (`wrapping_add` is fine anywhere: it
//!    never asserts in-bounds.) Allowlisted files still owe every
//!    unsafe block its SAFETY comment — the allowlist relaxes rule 2
//!    only, never rule 1.
//! 3. **No `static mut`**, anywhere, tests included.
//! 4. **Declared-safe modules contain no `unsafe` at all**: the API
//!    surface (`blas/api.rs`), the planners and dispatch
//!    (`gemm/plan.rs`, `gemm/dispatch.rs`), the epilogue algebra
//!    (`gemm/epilogue.rs`), and the application layers (`nn/`,
//!    `coordinator/`, `serve/`).
//!
//! Matching runs on comment- and string-stripped source so prose like
//! "the unsafe kernels" never trips a rule. `--self-test` seeds one
//! violation of each rule through the checker and fails unless every one
//! is caught — run it first in CI so a silently broken checker cannot
//! green-light the tree.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files (relative to `src/`, `/`-separated) allowed to use raw-pointer
/// idioms: the checked core plus the ISA kernel modules it backstops
/// (`gemm/quant.rs` hosts the int8 `maddubs` drivers' kernel calls).
const RAW_ALLOWLIST: &[&str] =
    &["util/ptr.rs", "gemm/microkernel.rs", "gemm/tile.rs", "gemm/quant.rs", "blas/level1.rs"];

/// Modules that must stay entirely safe. A directory entry (trailing
/// `/`) covers every file under it.
const DECLARED_SAFE: &[&str] = &[
    "blas/api.rs",
    "gemm/plan.rs",
    "gemm/dispatch.rs",
    "gemm/epilogue.rs",
    "nn/",
    "coordinator/",
    "serve/",
];

/// How many lines above an `unsafe` block may hold its SAFETY comment
/// (covers a multi-line statement between comment and block).
const SAFETY_LOOKBACK: usize = 8;

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--self-test") {
        return self_test();
    }
    let src_root = match args.first() {
        Some(p) => PathBuf::from(p),
        None => Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src"),
    };
    let src_root = match src_root.canonicalize() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("lint: cannot resolve source root {}: {e}", src_root.display());
            return ExitCode::FAILURE;
        }
    };
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files);
    files.sort();
    let mut violations = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("lint: cannot read {}: {e}", path.display());
                return ExitCode::FAILURE;
            }
        };
        violations.extend(check_file(&rel, &text));
    }
    for v in &violations {
        eprintln!("{v}");
    }
    if violations.is_empty() {
        println!("lint: {} files clean", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("lint: {} violation(s) in {} files", violations.len(), files.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Run every rule against one file's text. `rel` is the path relative to
/// the source root, `/`-separated.
fn check_file(rel: &str, text: &str) -> Vec<Violation> {
    let raw_lines: Vec<&str> = text.lines().collect();
    let code_lines = strip_comments_and_strings(text);
    debug_assert_eq!(raw_lines.len(), code_lines.len());
    let test_tail = test_tail_start(&raw_lines);
    let in_allowlist = RAW_ALLOWLIST.contains(&rel);
    let declared_safe = DECLARED_SAFE
        .iter()
        .any(|m| if m.ends_with('/') { rel.starts_with(m) } else { rel == *m });

    let mut out = Vec::new();
    for (i, code) in code_lines.iter().enumerate() {
        let lineno = i + 1;
        let in_tests = i >= test_tail;

        // Rule 3: no mutable global state, tests included.
        if code.contains("static mut") {
            out.push(Violation {
                file: rel.to_string(),
                line: lineno,
                rule: "static-mut",
                message: "`static mut` is banned; use atomics, locks or OnceLock".into(),
            });
        }
        if in_tests {
            continue;
        }

        // Rule 4: declared-safe modules carry no unsafe of any kind.
        if declared_safe && contains_word(code, "unsafe") {
            out.push(Violation {
                file: rel.to_string(),
                line: lineno,
                rule: "declared-safe",
                message: format!(
                    "`unsafe` in declared-safe module {rel}; route through util::ptr \
                     handles or the safe kernel-call wrappers"
                ),
            });
        }

        // Rule 2: raw-pointer idioms outside the allowlist.
        if !in_allowlist {
            for idiom in ["from_raw_parts", ".add(", "get_unchecked"] {
                if code.contains(idiom) {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: lineno,
                        rule: "raw-idiom",
                        message: format!(
                            "`{idiom}` outside the raw-pointer allowlist; use util::ptr \
                             handles or safe slicing"
                        ),
                    });
                }
            }
        }

        // Rule 1: every unsafe block is justified in place.
        if find_unsafe_block(code).is_some() {
            let from = i.saturating_sub(SAFETY_LOOKBACK);
            let documented = raw_lines[from..=i].iter().any(|l| l.contains("SAFETY:"));
            if !documented {
                out.push(Violation {
                    file: rel.to_string(),
                    line: lineno,
                    rule: "undocumented-unsafe",
                    message: format!(
                        "unsafe block without a `// SAFETY:` comment within \
                         {SAFETY_LOOKBACK} lines above"
                    ),
                });
            }
        }
    }
    out
}

/// Index of the first line of the file's `#[cfg(test)] mod` tail (module
/// convention: test modules close the file), or `lines.len()` if none.
fn test_tail_start(lines: &[&str]) -> usize {
    for (i, line) in lines.iter().enumerate() {
        if line.contains("#[cfg(test)]") {
            // The attribute must introduce a module (not a helper fn).
            for follow in lines.iter().skip(i + 1).take(3) {
                let t = follow.trim_start();
                if t.starts_with("mod ") || t.starts_with("pub mod ") {
                    return i;
                }
                if !t.is_empty() && !t.starts_with("#[") && !t.starts_with("//") {
                    break;
                }
            }
        }
    }
    lines.len()
}

/// Column of an `unsafe` keyword introducing a *block* (`unsafe {`), or
/// `None`. `unsafe fn` / `unsafe impl` / `unsafe trait` declarations are
/// rule-1-exempt: their obligations live in `# Safety` docs, and their
/// bodies' blocks are checked individually (`unsafe_op_in_unsafe_fn`).
fn find_unsafe_block(code: &str) -> Option<usize> {
    let mut start = 0;
    while let Some(pos) = code[start..].find("unsafe") {
        let at = start + pos;
        let before_ok = at == 0
            || !code.as_bytes()[at - 1].is_ascii_alphanumeric() && code.as_bytes()[at - 1] != b'_';
        let rest = &code[at + "unsafe".len()..];
        if before_ok && rest.trim_start().starts_with('{') {
            return Some(at);
        }
        start = at + "unsafe".len();
    }
    None
}

/// Does `code` contain `word` delimited by non-identifier characters?
fn contains_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let end = at + word.len();
        let left = at == 0 || !bytes[at - 1].is_ascii_alphanumeric() && bytes[at - 1] != b'_';
        let right =
            end == bytes.len() || !bytes[end].is_ascii_alphanumeric() && bytes[end] != b'_';
        if left && right {
            return true;
        }
        start = end;
    }
    false
}

/// Replace comments and string/char-literal contents with spaces,
/// preserving the line structure, so rules never match prose. Handles
/// nested block comments, escapes, and `r#"…"#` raw strings; a char
/// literal is distinguished from a lifetime by its closing quote.
fn strip_comments_and_strings(text: &str) -> Vec<String> {
    #[derive(PartialEq, Clone, Copy)]
    enum St {
        Code,
        LineComment,
        BlockComment(usize),
        Str,
        RawStr(usize),
        Char,
    }
    let mut st = St::Code;
    let mut out = Vec::new();
    let mut cur = String::new();
    let chars: Vec<char> = text.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            out.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => match c {
                '/' if chars.get(i + 1) == Some(&'/') => {
                    st = St::LineComment;
                    cur.push(' ');
                    i += 1;
                }
                '/' if chars.get(i + 1) == Some(&'*') => {
                    st = St::BlockComment(1);
                    cur.push_str("  ");
                    i += 2;
                    continue;
                }
                '"' => {
                    st = St::Str;
                    cur.push('"');
                }
                'r' if matches!(chars.get(i + 1), Some('"') | Some('#')) => {
                    // Possible raw string: r"…" or r#"…"#.
                    let mut hashes = 0;
                    let mut j = i + 1;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        st = St::RawStr(hashes);
                        for _ in i..=j {
                            cur.push(' ');
                        }
                        i = j + 1;
                        continue;
                    }
                    cur.push(c);
                }
                '\'' => {
                    // Char literal ('x', '\n', '\u{…}') vs lifetime ('a).
                    let is_char = match chars.get(i + 1) {
                        Some('\\') => true,
                        Some(_) => chars.get(i + 2) == Some(&'\''),
                        None => false,
                    };
                    if is_char {
                        st = St::Char;
                    }
                    cur.push('\'');
                }
                _ => cur.push(c),
            },
            St::LineComment => cur.push(' '),
            St::BlockComment(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if depth == 1 { St::Code } else { St::BlockComment(depth - 1) };
                    cur.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(depth + 1);
                    cur.push_str("  ");
                    i += 2;
                    continue;
                }
                cur.push(' ');
            }
            St::Str => match c {
                '\\' => {
                    // Skip the escaped character — unless it is a line
                    // continuation, whose newline must keep its line.
                    cur.push(' ');
                    i += 1;
                    if chars.get(i).is_some_and(|&e| e != '\n') {
                        cur.push(' ');
                        i += 1;
                    }
                    continue;
                }
                '"' => {
                    st = St::Code;
                    cur.push('"');
                }
                _ => cur.push(' '),
            },
            St::RawStr(hashes) => {
                if c == '"' {
                    let closed = (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'));
                    if closed {
                        st = St::Code;
                        for _ in 0..=hashes {
                            cur.push(' ');
                        }
                        i += 1 + hashes;
                        continue;
                    }
                }
                cur.push(' ');
            }
            St::Char => match c {
                '\\' => {
                    cur.push_str("  ");
                    i += 2;
                    continue;
                }
                '\'' => {
                    st = St::Code;
                    cur.push('\'');
                }
                _ => cur.push(' '),
            },
        }
        i += 1;
    }
    if !cur.is_empty() || st == St::LineComment {
        out.push(cur);
    }
    out
}

/// Seed one violation of every rule through the checker and fail unless
/// each is caught (and a clean snippet stays clean). CI runs this before
/// the tree pass so a broken checker fails loudly instead of passing
/// everything.
fn self_test() -> ExitCode {
    let cases: &[(&str, &str, &str)] = &[
        (
            "undocumented-unsafe",
            "gemm/blocked.rs",
            "fn f(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n",
        ),
        (
            "raw-idiom",
            "gemm/simd.rs",
            "// SAFETY: seeded violation.\nfn f(p: *const f32) -> f32 {\n    unsafe { *p.add(1) }\n}\n",
        ),
        (
            "static-mut",
            "util/scratch.rs",
            "static mut COUNTER: usize = 0;\n",
        ),
        (
            "declared-safe",
            "gemm/plan.rs",
            "// SAFETY: seeded violation.\nfn f(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n",
        ),
        (
            "declared-safe",
            "nn/train.rs",
            "pub unsafe fn f() {}\n",
        ),
        // The raw-idiom allowlist must not waive rule 1: an unsafe
        // kernel call in the int8 driver still owes its SAFETY comment.
        (
            "undocumented-unsafe",
            "gemm/quant.rs",
            "fn qtile(p: *const u8) -> i32 {\n    unsafe { i32::from(*p) }\n}\n",
        ),
        // And the quantized nn surface is declared safe like the rest of nn/.
        (
            "declared-safe",
            "nn/linear.rs",
            "// SAFETY: seeded violation.\nfn f(p: *const i8) -> i8 {\n    unsafe { *p }\n}\n",
        ),
    ];
    let mut failed = false;
    for (rule, rel, text) in cases {
        let got = check_file(rel, text);
        if !got.iter().any(|v| v.rule == *rule) {
            eprintln!("self-test: seeded `{rule}` violation in {rel} was NOT caught");
            failed = true;
        }
    }
    // A compliant snippet must stay clean: documented unsafe, raw idiom
    // inside the allowlist, prose mentioning unsafe in a comment, and a
    // test-tail unsafe without SAFETY.
    let clean_cases: &[(&str, &str)] = &[
        (
            "gemm/blocked.rs",
            "// the unsafe kernels are documented\nfn f(p: *const f32) -> f32 {\n    \
             // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n\
             #[cfg(test)]\nmod tests {\n    fn g(p: *const f32) -> f32 {\n        \
             unsafe { *p }\n    }\n}\n",
        ),
        ("gemm/microkernel.rs", "// SAFETY: allowlisted module.\nfn f(p: *const f32) -> f32 {\n    unsafe { *p.add(1) }\n}\n"),
        ("gemm/quant.rs", "// SAFETY: allowlisted int8 kernel module.\nfn f(p: *const i8) -> i8 {\n    unsafe { *p.add(1) }\n}\n"),
        ("gemm/pack.rs", "fn f(x: usize) -> usize {\n    x.wrapping_add(1)\n}\n"),
        ("gemm/plan.rs", "// unsafe is banned here, and this comment is fine.\nfn f() {}\n"),
    ];
    for (rel, text) in clean_cases {
        let got = check_file(rel, text);
        if !got.is_empty() {
            for v in &got {
                eprintln!("self-test: clean snippet in {rel} was flagged: {v}");
            }
            failed = true;
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        println!("lint: self-test passed ({} seeded violations caught)", cases.len());
        ExitCode::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripping_preserves_line_count() {
        let text = "a\n/* b\nc */\nd \"e\nf\"\n";
        let lines = strip_comments_and_strings(text);
        assert_eq!(lines.len(), text.lines().count());
    }

    #[test]
    fn wrapping_add_is_not_a_raw_idiom() {
        assert!(check_file("gemm/simd.rs", "fn f(x: usize) -> usize { x.wrapping_add(1) }\n")
            .is_empty());
    }

    #[test]
    fn unsafe_fn_declaration_is_not_a_block() {
        assert_eq!(find_unsafe_block("pub unsafe fn f() "), None);
        assert!(find_unsafe_block("let x = unsafe { *p };").is_some());
    }

    #[test]
    fn prose_does_not_trip_declared_safe() {
        let text = "// the unsafe kernels live elsewhere\nfn f() {}\n";
        assert!(check_file("gemm/dispatch.rs", text).is_empty());
    }
}
