//! Level-2 BLAS: SGEMV on the Emmerald dot-product kernel.
//!
//! `y = alpha · op(A) x + beta · y`. The no-transpose case runs each row
//! of `A` through the same SSE dot-product kernel as the GEMM (width-1
//! panels); the transpose case is an SAXPY sweep, which is the canonical
//! column-major-friendly formulation.

use super::level1::{saxpy, sscal};
use super::matrix::MatRef;
use super::{BlasError, Transpose};

/// `y = alpha * op(A) x + beta * y` (SGEMV).
///
/// `a` is the stored matrix (row-major, leading dimension `ld`); when
/// `trans == Yes`, `op(A) = Aᵀ` so `x` has `a.rows()` entries and `y` has
/// `a.cols()`.
pub fn sgemv(
    trans: Transpose,
    alpha: f32,
    a: MatRef<'_>,
    x: &[f32],
    beta: f32,
    y: &mut [f32],
) -> Result<(), BlasError> {
    let (xn, yn) = match trans {
        Transpose::No => (a.cols(), a.rows()),
        Transpose::Yes => (a.rows(), a.cols()),
    };
    if x.len() != xn {
        return Err(BlasError::ShapeMismatch { what: "x", expect: (xn, 1), got: (x.len(), 1) });
    }
    if y.len() != yn {
        return Err(BlasError::ShapeMismatch { what: "y", expect: (yn, 1), got: (y.len(), 1) });
    }
    sscal(beta, y);
    if alpha == 0.0 || xn == 0 {
        return Ok(());
    }
    match trans {
        Transpose::No => {
            // One kernel dot product per row of A.
            for r in 0..a.rows() {
                #[cfg(all(target_arch = "x86_64", not(miri)))]
                // SAFETY: row r is readable for cols() elements (the view
                // invariant `(rows-1)*ld + cols <= data.len()`), x has
                // cols() elements by the shape check; SSE baseline.
                let dot = unsafe {
                    let mut out = [0.0f32; 1];
                    crate::gemm::microkernel::sse_dot_panel_dyn(
                        a.row_ptr(r),
                        a.cols(),
                        &[x.as_ptr()],
                        crate::gemm::Unroll::X4,
                        false,
                        &mut out,
                    );
                    out[0]
                };
                #[cfg(not(all(target_arch = "x86_64", not(miri))))]
                let dot: f32 = (0..a.cols()).map(|c| a.get(r, c) * x[c]).sum();
                y[r] += alpha * dot;
            }
        }
        Transpose::Yes => {
            // y += alpha * Σ_r x[r] · A[r, :]  (row-major-friendly SAXPYs).
            for r in 0..a.rows() {
                let row = &a.data()[r * a.ld()..][..a.cols()];
                saxpy(alpha * x[r], row, y);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::Matrix;
    use crate::util::testkit::assert_allclose;

    fn gemv_ref(trans: Transpose, alpha: f32, a: &Matrix, x: &[f32], beta: f32, y: &[f32]) -> Vec<f32> {
        let (rows, cols) = (a.rows(), a.cols());
        match trans {
            Transpose::No => (0..rows)
                .map(|r| {
                    alpha * (0..cols).map(|c| a.get(r, c) * x[c]).sum::<f32>() + beta * y[r]
                })
                .collect(),
            Transpose::Yes => (0..cols)
                .map(|c| {
                    alpha * (0..rows).map(|r| a.get(r, c) * x[r]).sum::<f32>() + beta * y[c]
                })
                .collect(),
        }
    }

    #[test]
    fn matches_reference_both_transposes() {
        for &(m, n) in &[(1usize, 1usize), (5, 7), (16, 16), (33, 20)] {
            let a = Matrix::random(m, n, 1, -1.0, 1.0);
            for trans in [Transpose::No, Transpose::Yes] {
                let (xn, yn) = if trans == Transpose::No { (n, m) } else { (m, n) };
                let x = crate::util::prng::random_f32(2, xn, -1.0, 1.0);
                let y0 = crate::util::prng::random_f32(3, yn, -1.0, 1.0);
                let want = gemv_ref(trans, 0.5, &a, &x, 1.5, &y0);
                let mut y = y0.clone();
                sgemv(trans, 0.5, a.view(), &x, 1.5, &mut y).unwrap();
                assert_allclose(&y, &want, 1e-4, 1e-5, &format!("gemv {m}x{n} {trans:?}"));
            }
        }
    }

    #[test]
    fn strided_a() {
        let a = Matrix::random_strided(6, 4, 9, 7);
        let x = vec![1.0f32; 4];
        let mut y = vec![0.0f32; 6];
        sgemv(Transpose::No, 1.0, a.view(), &x, 0.0, &mut y).unwrap();
        for r in 0..6 {
            let want: f32 = (0..4).map(|c| a.get(r, c)).sum();
            assert!((y[r] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn shape_errors() {
        let a = Matrix::zeros(3, 4);
        let mut y = vec![0.0f32; 3];
        assert!(sgemv(Transpose::No, 1.0, a.view(), &[0.0; 3], 0.0, &mut y).is_err());
        let mut y_bad = vec![0.0f32; 2];
        assert!(sgemv(Transpose::No, 1.0, a.view(), &[0.0; 4], 0.0, &mut y_bad).is_err());
    }

    #[test]
    fn alpha_zero_is_beta_scale() {
        let a = Matrix::from_fn(2, 2, |_, _| f32::NAN);
        let mut y = vec![2.0f32, 4.0];
        sgemv(Transpose::No, 0.0, a.view(), &[1.0, 1.0], 0.5, &mut y).unwrap();
        assert_eq!(y, vec![1.0, 2.0]);
    }
}
