//! Backend selection and dispatch.
//!
//! Four native implementations are provided, mirroring the paper's
//! evaluation line-up plus a modern extension:
//!
//! * [`Backend::Naive`] — the paper's "naive 3-loop matrix multiply".
//! * [`Backend::Blocked`] — the ATLAS proxy: empirically-tuned register +
//!   cache blocking *without* SIMD (ATLAS on the PIII did not use SSE).
//! * [`Backend::Simd`] — Emmerald: the paper's SSE micro-kernel with five
//!   concurrent dot products, B re-buffering, prefetch and L1/L2 blocking.
//! * [`Backend::Avx2`] — the same algorithm re-tuned for 8-wide AVX2+FMA
//!   (the "what Emmerald becomes on a modern core" extension).

use super::error::BlasError;
use super::matrix::{MatMut, MatRef};
use super::Transpose;
use crate::gemm::{self, BlockParams};

/// Implementation selector for [`super::sgemm`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Three nested loops, no blocking (paper's lower baseline).
    Naive,
    /// Cache-blocked scalar GEMM (ATLAS proxy — no SIMD).
    Blocked,
    /// Emmerald: SSE 4-wide micro-kernel (the paper's contribution).
    Simd,
    /// Emmerald re-tuned for AVX2 + FMA (extension).
    Avx2,
    /// Pick the fastest backend available on this CPU.
    Auto,
}

impl Backend {
    /// Parse a backend name (`naive|blocked|simd|avx2|auto`).
    pub fn parse(s: &str) -> Result<Self, BlasError> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(Backend::Naive),
            "blocked" | "atlas" => Ok(Backend::Blocked),
            "simd" | "sse" | "emmerald" => Ok(Backend::Simd),
            "avx2" => Ok(Backend::Avx2),
            "auto" => Ok(Backend::Auto),
            _ => Err(BlasError::BackendUnavailable("unknown backend name")),
        }
    }

    /// Human-readable name.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Naive => "naive",
            Backend::Blocked => "blocked",
            Backend::Simd => "emmerald-sse",
            Backend::Avx2 => "emmerald-avx2",
            Backend::Auto => "auto",
        }
    }

    /// Resolve to a concrete implementation, checking CPU features.
    pub(crate) fn resolve(self) -> Result<Resolved, BlasError> {
        match self {
            Backend::Naive => Ok(Resolved::Naive),
            Backend::Blocked => Ok(Resolved::Blocked),
            Backend::Simd => {
                if cfg!(target_arch = "x86_64") && std::arch::is_x86_feature_detected!("sse") {
                    Ok(Resolved::Simd)
                } else {
                    Err(BlasError::BackendUnavailable("emmerald-sse (needs SSE)"))
                }
            }
            Backend::Avx2 => {
                if cfg!(target_arch = "x86_64")
                    && std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
                {
                    Ok(Resolved::Avx2)
                } else {
                    Err(BlasError::BackendUnavailable("emmerald-avx2 (needs AVX2+FMA)"))
                }
            }
            Backend::Auto => {
                for candidate in [Backend::Avx2, Backend::Simd] {
                    if let Ok(r) = candidate.resolve() {
                        return Ok(r);
                    }
                }
                Ok(Resolved::Blocked)
            }
        }
    }
}

/// All backends executable on this CPU.
pub fn available_backends() -> Vec<Backend> {
    [Backend::Naive, Backend::Blocked, Backend::Simd, Backend::Avx2]
        .into_iter()
        .filter(|b| b.resolve().is_ok())
        .collect()
}

/// A concrete, feature-checked implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Resolved {
    Naive,
    Blocked,
    Simd,
    Avx2,
}

impl Resolved {
    /// Run the GEMM on validated views.
    pub(crate) fn dispatch(
        self,
        transa: Transpose,
        transb: Transpose,
        alpha: f32,
        a: MatRef<'_>,
        b: MatRef<'_>,
        beta: f32,
        mut c: MatMut<'_>,
    ) {
        match self {
            Resolved::Naive => gemm::naive::gemm(transa, transb, alpha, a, b, beta, &mut c),
            Resolved::Blocked => gemm::blocked::gemm(
                &BlockParams::atlas_proxy(),
                transa,
                transb,
                alpha,
                a,
                b,
                beta,
                &mut c,
            ),
            Resolved::Simd => gemm::simd::gemm(
                &BlockParams::emmerald_sse(),
                transa,
                transb,
                alpha,
                a,
                b,
                beta,
                &mut c,
            ),
            Resolved::Avx2 => gemm::avx2::gemm(
                &BlockParams::emmerald_avx2(),
                transa,
                transb,
                alpha,
                a,
                b,
                beta,
                &mut c,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Backend::parse("naive").unwrap(), Backend::Naive);
        assert_eq!(Backend::parse("ATLAS").unwrap(), Backend::Blocked);
        assert_eq!(Backend::parse("emmerald").unwrap(), Backend::Simd);
        assert_eq!(Backend::parse("avx2").unwrap(), Backend::Avx2);
        assert_eq!(Backend::parse("auto").unwrap(), Backend::Auto);
        assert!(Backend::parse("gpu").is_err());
    }

    #[test]
    fn auto_resolves_to_something() {
        assert!(Backend::Auto.resolve().is_ok());
    }

    #[test]
    fn naive_and_blocked_always_available() {
        let av = available_backends();
        assert!(av.contains(&Backend::Naive));
        assert!(av.contains(&Backend::Blocked));
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse_available_on_x86_64() {
        // SSE is part of the x86-64 baseline.
        assert!(Backend::Simd.resolve().is_ok());
    }
}
