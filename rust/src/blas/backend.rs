//! Backend selection and dispatch.
//!
//! Four native implementations are provided, mirroring the paper's
//! evaluation line-up plus a modern extension:
//!
//! * [`Backend::Naive`] — the paper's "naive 3-loop matrix multiply".
//! * [`Backend::Blocked`] — the ATLAS proxy: empirically-tuned register +
//!   cache blocking *without* SIMD (ATLAS on the PIII did not use SSE).
//! * [`Backend::Simd`] — Emmerald: the paper's SSE micro-kernel with five
//!   concurrent dot products, B re-buffering, prefetch and L1/L2 blocking.
//! * [`Backend::Avx2`] — the same algorithm re-tuned for 8-wide AVX2+FMA
//!   (the "what Emmerald becomes on a modern core" extension).

use super::error::BlasError;
use crate::gemm;

/// Implementation selector for [`super::sgemm`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Three nested loops, no blocking (paper's lower baseline).
    Naive,
    /// Cache-blocked scalar GEMM (ATLAS proxy — no SIMD).
    Blocked,
    /// Emmerald: SSE 4-wide micro-kernel (the paper's contribution).
    Simd,
    /// Emmerald re-tuned for AVX2 + FMA (extension).
    Avx2,
    /// Outer-product register-tiled AVX2+FMA kernel: an MR×NR tile of `C`
    /// resident in registers (the fastest serial tier; what dispatch
    /// picks on modern cores).
    Avx2Tile,
    /// Route through the [`crate::gemm::dispatch`] registry: runtime
    /// CPU-feature detection plus shape heuristics over *every* kernel in
    /// the crate (including the parallel and fast-matmul drivers).
    Dispatch,
    /// The default: an alias for [`Backend::Dispatch`].
    Auto,
}

impl Backend {
    /// Parse a backend name (`naive|blocked|simd|avx2|tile|dispatch|auto`).
    pub fn parse(s: &str) -> Result<Self, BlasError> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(Backend::Naive),
            "blocked" | "atlas" => Ok(Backend::Blocked),
            "simd" | "sse" | "emmerald" => Ok(Backend::Simd),
            "avx2" => Ok(Backend::Avx2),
            "tile" | "avx2-tile" => Ok(Backend::Avx2Tile),
            "dispatch" => Ok(Backend::Dispatch),
            "auto" => Ok(Backend::Auto),
            _ => Err(BlasError::BackendUnavailable("unknown backend name")),
        }
    }

    /// Human-readable name.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Naive => "naive",
            Backend::Blocked => "blocked",
            Backend::Simd => "emmerald-sse",
            Backend::Avx2 => "emmerald-avx2",
            Backend::Avx2Tile => "avx2-tile",
            Backend::Dispatch => "dispatch",
            Backend::Auto => "auto",
        }
    }

    /// Resolve to a concrete implementation, checking CPU features.
    pub(crate) fn resolve(self) -> Result<Resolved, BlasError> {
        match self {
            Backend::Naive => Ok(Resolved::Naive),
            Backend::Blocked => Ok(Resolved::Blocked),
            Backend::Simd => {
                if gemm::dispatch::detect_sse() {
                    Ok(Resolved::Simd)
                } else {
                    Err(BlasError::BackendUnavailable("emmerald-sse (needs SSE)"))
                }
            }
            Backend::Avx2 => {
                if gemm::dispatch::detect_avx2() {
                    Ok(Resolved::Avx2)
                } else {
                    Err(BlasError::BackendUnavailable("emmerald-avx2 (needs AVX2+FMA)"))
                }
            }
            Backend::Avx2Tile => {
                if gemm::dispatch::detect_avx2() {
                    Ok(Resolved::Avx2Tile)
                } else {
                    Err(BlasError::BackendUnavailable("avx2-tile (needs AVX2+FMA)"))
                }
            }
            // The dispatcher is always available: it degrades to the best
            // kernel the CPU actually has.
            Backend::Dispatch | Backend::Auto => Ok(Resolved::Dispatch),
        }
    }
}

/// All backends executable on this CPU.
pub fn available_backends() -> Vec<Backend> {
    [
        Backend::Naive,
        Backend::Blocked,
        Backend::Simd,
        Backend::Avx2,
        Backend::Avx2Tile,
        Backend::Dispatch,
    ]
    .into_iter()
    .filter(|b| b.resolve().is_ok())
    .collect()
}

/// A concrete, feature-checked implementation.
///
/// The `sgemm`/`sgemm_batch` shims map each variant onto a forced
/// [`gemm::KernelId`] (or the dispatch heuristics for `Dispatch`) and run
/// it through a one-shot [`gemm::plan::GemmPlan`], so explicit backends,
/// planned execution and the dispatcher all share one execution path and
/// one (possibly autotuned) geometry table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Resolved {
    Naive,
    Blocked,
    Simd,
    Avx2,
    Avx2Tile,
    Dispatch,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Backend::parse("naive").unwrap(), Backend::Naive);
        assert_eq!(Backend::parse("ATLAS").unwrap(), Backend::Blocked);
        assert_eq!(Backend::parse("emmerald").unwrap(), Backend::Simd);
        assert_eq!(Backend::parse("avx2").unwrap(), Backend::Avx2);
        assert_eq!(Backend::parse("dispatch").unwrap(), Backend::Dispatch);
        assert_eq!(Backend::parse("auto").unwrap(), Backend::Auto);
        assert!(Backend::parse("gpu").is_err());
    }

    #[test]
    fn auto_resolves_to_the_dispatcher() {
        assert_eq!(Backend::Auto.resolve().unwrap(), Resolved::Dispatch);
        assert_eq!(Backend::Dispatch.resolve().unwrap(), Resolved::Dispatch);
    }

    #[test]
    fn naive_blocked_dispatch_always_available() {
        let av = available_backends();
        assert!(av.contains(&Backend::Naive));
        assert!(av.contains(&Backend::Blocked));
        assert!(av.contains(&Backend::Dispatch));
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse_available_on_x86_64() {
        // SSE is part of the x86-64 baseline.
        assert!(Backend::Simd.resolve().is_ok());
    }
}
