//! Positional BLAS entry points, implemented as thin compatibility shims
//! over the planned-execution API.
//!
//! [`sgemm`] and [`sgemm_batch`] keep the classic 14/18-argument BLAS
//! signatures for drop-in use, but each call now builds and runs a
//! **one-shot [`crate::gemm::plan::GemmPlan`]** on the shared
//! [`GemmContext`]: validation, kernel selection and the worker-thread
//! split all happen in the context, and parallel work draws from its
//! single process-wide thread budget. Repeated-shape workloads should
//! build the plan once (`ctx.gemm()...plan(m, n, k)?`) and call
//! [`crate::gemm::plan::GemmPlan::run`] instead — same kernels, none of
//! the per-call setup — and weight-like operands should be prepacked with
//! [`GemmContext::pack_b`].

use super::backend::{Backend, Resolved};
use super::error::BlasError;
use super::matrix::{MatMut, MatRef, Matrix};
use super::Transpose;
use crate::gemm::batch::BatchStrides;
use crate::gemm::element::Element;
use crate::gemm::epilogue::Requant;
use crate::gemm::plan::GemmContext;
use crate::gemm::KernelId;

/// Map an explicit backend onto a forced registry kernel (`None` = let
/// the dispatch heuristics choose), checking CPU features.
fn forced_kernel(backend: Backend) -> Result<Option<KernelId>, BlasError> {
    Ok(match backend.resolve()? {
        Resolved::Naive => Some(KernelId::Naive),
        Resolved::Blocked => Some(KernelId::Blocked),
        Resolved::Simd => Some(KernelId::Simd),
        Resolved::Avx2 => Some(KernelId::Avx2),
        Resolved::Avx2Tile => Some(KernelId::Avx2Tile),
        Resolved::Dispatch => None,
    })
}

/// General matrix-matrix multiply: `C = alpha * op(A) * op(B) + beta * C`.
///
/// * `op(A)` is `m × k`, `op(B)` is `k × n`, `C` is `m × n`.
/// * `a` stores `A` row-major with leading dimension `lda` (so `A` is
///   `m × k` storage when `transa == No`, `k × m` when `Yes`); same for `b`.
/// * Degenerate dimensions (`m`, `n` or `k` = 0) are valid: `k == 0`
///   scales `C` by `beta`; `m == 0` or `n == 0` is a no-op.
///
/// This is the crate's compatibility entry point; `backend` selects the
/// implementation ([`Backend::Auto`] picks the fastest available). It
/// builds and runs a one-shot plan on the shared [`GemmContext`]; see the
/// module docs for the planned alternative when shapes repeat.
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
) -> Result<(), BlasError> {
    gemm(backend, transa, transb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// Double-precision GEMM (`DGEMM`): exactly [`sgemm`]'s contract in f64.
///
/// Runs the element-generic kernel ladder — the f64 outer-product tile
/// kernel (6×8) or 4-wide AVX2 dot kernel where available, the scalar
/// blocked proxy otherwise, thread-parallel above the flop threshold —
/// through a one-shot plan on the shared [`GemmContext`]. The SSE tier
/// is f32-only and never selected for f64; the fast-matmul family
/// (Strassen–Winograd, Laderman) is element-generic and *is* open to
/// f64 above its tuned per-shape-class threshold.
#[allow(clippy::too_many_arguments)]
pub fn dgemm(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) -> Result<(), BlasError> {
    gemm(backend, transa, transb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// The element-generic positional GEMM behind [`sgemm`] and [`dgemm`]
/// (use those for the classic BLAS names, this for generic code).
#[allow(clippy::too_many_arguments)]
pub fn gemm<T: Element>(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) -> Result<(), BlasError> {
    let forced = forced_kernel(backend)?;
    let mut builder = GemmContext::global()
        .gemm_for::<T>()
        .transpose_a(transa)
        .transpose_b(transb)
        .alpha(alpha)
        .beta(beta)
        .lda(lda)
        .ldb(ldb)
        .ldc(ldc);
    if let Some(id) = forced {
        builder = builder.kernel(id);
    }
    builder.plan(m, n, k)?.run(a, b, c)
}

/// Strided-batch SGEMM: `C_i = alpha · op(A_i) op(B_i) + beta · C_i` for
/// `i in 0..batch`, with `X_i = x[i * stride_x ..]` (stride 0 broadcasts a
/// read-only operand — the cuBLAS `gemmStridedBatched` convention).
///
/// A one-shot plan on the shared [`GemmContext`]:
/// [`Backend::Dispatch`]/[`Backend::Auto`] run the full batched driver
/// (shared-B folding, per-worker packing scratch, fan-out over the
/// context's thread budget — see [`crate::gemm::batch`]); explicit
/// backends run their kernel per item with the same validation and
/// amortised packing buffers.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_batch(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    stride_a: usize,
    b: &[f32],
    ldb: usize,
    stride_b: usize,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
    stride_c: usize,
    batch: usize,
) -> Result<(), BlasError> {
    gemm_batch(
        backend, transa, transb, m, n, k, alpha, a, lda, stride_a, b, ldb, stride_b, beta, c,
        ldc, stride_c, batch,
    )
}

/// Strided-batch DGEMM: [`sgemm_batch`]'s contract in f64 (shared-B
/// folding, per-worker packing scratch and the thread fan-out all run
/// the f64 kernel ladder).
#[allow(clippy::too_many_arguments)]
pub fn dgemm_batch(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    stride_a: usize,
    b: &[f64],
    ldb: usize,
    stride_b: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
    stride_c: usize,
    batch: usize,
) -> Result<(), BlasError> {
    gemm_batch(
        backend, transa, transb, m, n, k, alpha, a, lda, stride_a, b, ldb, stride_b, beta, c,
        ldc, stride_c, batch,
    )
}

/// The element-generic strided-batch GEMM behind [`sgemm_batch`] and
/// [`dgemm_batch`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_batch<T: Element>(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    stride_a: usize,
    b: &[T],
    ldb: usize,
    stride_b: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
    stride_c: usize,
    batch: usize,
) -> Result<(), BlasError> {
    let forced = forced_kernel(backend)?;
    let mut builder = GemmContext::global()
        .gemm_for::<T>()
        .transpose_a(transa)
        .transpose_b(transb)
        .alpha(alpha)
        .beta(beta)
        .lda(lda)
        .ldb(ldb)
        .ldc(ldc);
    if let Some(id) = forced {
        builder = builder.kernel(id);
    }
    let strides = BatchStrides { a: stride_a, b: stride_b, c: stride_c };
    builder.plan(m, n, k)?.run_batch(a, b, c, batch, strides)
}

/// Quantized GEMM (`u8 × i8 → i32`, exact): `C ⟵ op(A)·op(B)`, or
/// `C += op(A)·op(B)` (wrapping) with `accumulate`.
///
/// The integer tier has no `alpha`/`beta` (integer scaling would
/// overflow or lose exactness) and no backend argument: dispatch is the
/// AVX2 `maddubs` tile when the CPU has it and the weights avoid the
/// `−128` edge case, the exact scalar loop otherwise — both bitwise
/// identical, serial or parallel. Runs on the shared [`GemmContext`];
/// for weight-stationary workloads pack `B` once with
/// [`GemmContext::qpack_b`] and call
/// [`GemmContext::qgemm_packed_b`] instead.
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    a: &[u8],
    lda: usize,
    b: &[i8],
    ldb: usize,
    c: &mut [i32],
    ldc: usize,
    accumulate: bool,
) -> Result<(), BlasError> {
    let (ar, ac) = match transa {
        Transpose::No => (m, k),
        Transpose::Yes => (k, m),
    };
    let (br, bc) = match transb {
        Transpose::No => (k, n),
        Transpose::Yes => (n, k),
    };
    let av = MatRef::new(a, ar, ac, lda).map_err(|e| e.operand("A"))?;
    let bv = MatRef::new(b, br, bc, ldb).map_err(|e| e.operand("B"))?;
    let cv = MatMut::new(c, m, n, ldc).map_err(|e| e.operand("C"))?;
    GemmContext::global().qgemm(transa, transb, av, bv, cv, accumulate)
}

/// Quantized GEMM with the fused [`Requant`] writeback:
/// `C_f32 ⟵ requant(op(A)·op(B))` — zero-point correction, per-row ×
/// per-channel scales, optional bias and activation applied per element
/// as the exact i32 sums leave the kernel. Always overwrites `C`.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_requant(
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    a: &[u8],
    lda: usize,
    b: &[i8],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    rq: &Requant,
) -> Result<(), BlasError> {
    let (ar, ac) = match transa {
        Transpose::No => (m, k),
        Transpose::Yes => (k, m),
    };
    let (br, bc) = match transb {
        Transpose::No => (k, n),
        Transpose::Yes => (n, k),
    };
    let av = MatRef::new(a, ar, ac, lda).map_err(|e| e.operand("A"))?;
    let bv = MatRef::new(b, br, bc, ldb).map_err(|e| e.operand("B"))?;
    let cv = MatMut::new(c, m, n, ldc).map_err(|e| e.operand("C"))?;
    GemmContext::global().qgemm_requant(transa, transb, av, bv, cv, rq)
}

/// Convenience wrapper over [`sgemm`] for owned [`Matrix`] values
/// (`C = alpha * op(A) op(B) + beta * C`).
pub fn sgemm_matrix(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &mut Matrix,
) -> Result<(), BlasError> {
    gemm_matrix(backend, transa, transb, alpha, a, b, beta, c)
}

/// Convenience wrapper over [`dgemm`] for owned `Matrix<f64>` values.
pub fn dgemm_matrix(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    alpha: f64,
    a: &Matrix<f64>,
    b: &Matrix<f64>,
    beta: f64,
    c: &mut Matrix<f64>,
) -> Result<(), BlasError> {
    gemm_matrix(backend, transa, transb, alpha, a, b, beta, c)
}

/// The element-generic [`Matrix`] wrapper behind [`sgemm_matrix`] and
/// [`dgemm_matrix`].
pub fn gemm_matrix<T: Element>(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: &Matrix<T>,
    b: &Matrix<T>,
    beta: T,
    c: &mut Matrix<T>,
) -> Result<(), BlasError> {
    let (m, ka) = match transa {
        Transpose::No => (a.rows(), a.cols()),
        Transpose::Yes => (a.cols(), a.rows()),
    };
    let (kb, n) = match transb {
        Transpose::No => (b.rows(), b.cols()),
        Transpose::Yes => (b.cols(), b.rows()),
    };
    if ka != kb {
        return Err(BlasError::DimMismatch { m, n, k: ka, other_k: kb });
    }
    if c.rows() != m || c.cols() != n {
        return Err(BlasError::ShapeMismatch {
            what: "C",
            expect: (m, n),
            got: (c.rows(), c.cols()),
        });
    }
    let (lda, ldb, ldc) = (a.ld(), b.ld(), c.ld());
    gemm(
        backend,
        transa,
        transb,
        m,
        n,
        ka,
        alpha,
        a.data(),
        lda,
        b.data(),
        ldb,
        beta,
        c.data_mut(),
        ldc,
    )
}

/// [`sgemm`]'s contract routed through the process-wide GEMM service
/// ([`crate::serve::GemmService::global`]): the call is admitted under
/// the service's backpressure, may coalesce with concurrent identical
/// requests, and answers from the shape-keyed plan / packed-weight
/// cache on repeat traffic. Results are bitwise identical to [`sgemm`]
/// on the dispatch backend (the service executes the same plan through
/// the prepacked driver). Copy-in/copy-out: operands are snapshotted at
/// the call, `c` is written back on completion.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_served(
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
) -> Result<(), crate::serve::ServeError> {
    let mut spec = crate::serve::PlanSpec::new(m, n, k);
    spec.transa = transa;
    spec.transb = transb;
    spec.alpha = alpha;
    spec.beta = beta;
    spec.lda = lda;
    spec.ldb = ldb;
    spec.ldc = ldc;
    let req = crate::serve::SgemmRequest {
        spec,
        a: a.to_vec(),
        b: crate::serve::FOperand::Inline(b.to_vec()),
        c: Some(c.to_vec()),
    };
    let out = crate::serve::GemmService::global().submit(req)?.wait()?;
    c.copy_from_slice(&out);
    Ok(())
}

/// [`qgemm`]'s non-accumulating contract routed through the GEMM
/// service (see [`sgemm_served`] for the admission/coalescing/caching
/// semantics). Exact `u8 × i8 → i32`, bitwise identical to [`qgemm`].
#[allow(clippy::too_many_arguments)]
pub fn qgemm_served(
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    a: &[u8],
    lda: usize,
    b: &[i8],
    ldb: usize,
    c: &mut [i32],
    ldc: usize,
) -> Result<(), crate::serve::ServeError> {
    // Validate the output view up front (the service answers a
    // contiguous m × n buffer that is copied back row by row).
    MatMut::new(&mut c[..], m, n, ldc)
        .map_err(|e| crate::serve::ServeError::Blas(e.operand("C")))?;
    let mut req = crate::serve::QgemmRequest::new(
        m,
        n,
        k,
        a.to_vec(),
        crate::serve::QOperand::Inline(b.to_vec()),
    );
    req.transa = transa;
    req.transb = transb;
    req.lda = lda;
    req.ldb = ldb;
    match crate::serve::GemmService::global().submit_q(req)?.wait()? {
        crate::serve::QgemmOut::I32(out) => {
            for r in 0..m {
                c[r * ldc..r * ldc + n].copy_from_slice(&out[r * n..r * n + n]);
            }
            Ok(())
        }
        // A request without a requant descriptor always answers i32.
        crate::serve::QgemmOut::F32(_) => unreachable!("requant-free request answered f32"),
    }
}
