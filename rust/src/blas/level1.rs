//! Level-1 BLAS on the Emmerald micro-kernel machinery.
//!
//! The paper positions Emmerald as a BLAS building block ("may be used
//! immediately to improve the performance of single-precision libraries
//! based on BLAS"); these are the Level-1 routines a consumer library
//! expects, vectorised with the same SSE primitives as the GEMM kernel.
//!
//! Under Miri the SSE paths are compiled out (`not(miri)`) and the scalar
//! fallbacks run instead, so the Level-1 surface is interpretable in the
//! `miri_scalar` UB-check tier.

#[cfg(all(target_arch = "x86_64", not(miri)))]
use std::arch::x86_64::*;

/// Dot product `xᵀ y` (SDOT).
pub fn sdot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len(), "sdot length mismatch");
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        // SAFETY: SSE is part of the x86-64 baseline; one column, width 1.
        unsafe {
            let mut out = [0.0f32; 1];
            crate::gemm::microkernel::sse_dot_panel_dyn(
                x.as_ptr(),
                x.len(),
                &[y.as_ptr()],
                crate::gemm::Unroll::X4,
                false,
                &mut out,
            );
            return out[0];
        }
    }
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// `y += alpha * x` (SAXPY).
pub fn saxpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "saxpy length mismatch");
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        // SAFETY: SSE baseline; in-bounds by the length assert.
        unsafe {
            let n = x.len();
            let va = _mm_set1_ps(alpha);
            let mut i = 0;
            while i + 4 <= n {
                let vy = _mm_loadu_ps(y.as_ptr().add(i));
                let vx = _mm_loadu_ps(x.as_ptr().add(i));
                _mm_storeu_ps(y.as_mut_ptr().add(i), _mm_add_ps(vy, _mm_mul_ps(va, vx)));
                i += 4;
            }
            while i < n {
                y[i] += alpha * x[i];
                i += 1;
            }
            return;
        }
    }
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha` (SSCAL).
pub fn sscal(alpha: f32, x: &mut [f32]) {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        // SAFETY: SSE baseline.
        unsafe {
            let n = x.len();
            let va = _mm_set1_ps(alpha);
            let mut i = 0;
            while i + 4 <= n {
                let vx = _mm_loadu_ps(x.as_ptr().add(i));
                _mm_storeu_ps(x.as_mut_ptr().add(i), _mm_mul_ps(va, vx));
                i += 4;
            }
            while i < n {
                x[i] *= alpha;
                i += 1;
            }
            return;
        }
    }
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Euclidean norm ‖x‖₂ (SNRM2), with f64 accumulation for stability.
pub fn snrm2(x: &[f32]) -> f32 {
    (x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()).sqrt() as f32
}

/// Index of the element with the largest absolute value (ISAMAX);
/// `None` on empty input.
pub fn isamax(x: &[f32]) -> Option<usize> {
    x.iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.abs().partial_cmp(&b.abs()).expect("NaN in isamax"))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn rv(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        let mut v = vec![0.0; n];
        rng.fill_f32(&mut v, -1.0, 1.0);
        v
    }

    #[test]
    fn sdot_matches_scalar_all_lengths() {
        for n in [0usize, 1, 3, 4, 5, 17, 100, 337] {
            let x = rv(1, n);
            let y = rv(2, n);
            let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((sdot(&x, &y) - want).abs() < 1e-4 * (1.0 + want.abs()), "n={n}");
        }
    }

    #[test]
    fn saxpy_matches_scalar() {
        for n in [1usize, 4, 7, 33] {
            let x = rv(3, n);
            let mut y = rv(4, n);
            let mut want = y.clone();
            for i in 0..n {
                want[i] += 0.75 * x[i];
            }
            saxpy(0.75, &x, &mut y);
            crate::util::testkit::assert_allclose(&y, &want, 1e-6, 1e-7, "saxpy");
        }
    }

    #[test]
    fn sscal_matches_scalar() {
        let mut x = rv(5, 19);
        let want: Vec<f32> = x.iter().map(|v| v * -2.5).collect();
        sscal(-2.5, &mut x);
        crate::util::testkit::assert_allclose(&x, &want, 1e-6, 1e-7, "sscal");
    }

    #[test]
    fn snrm2_known() {
        assert!((snrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert_eq!(snrm2(&[]), 0.0);
    }

    #[test]
    fn isamax_picks_largest_abs() {
        assert_eq!(isamax(&[1.0, -5.0, 3.0]), Some(1));
        assert_eq!(isamax(&[]), None);
    }
}
