//! SSYRK: symmetric rank-k update, `C = alpha · A Aᵀ + beta · C` (lower
//! triangle), built on the Emmerald GEMM — the Level-3 sibling LAPACK's
//! Cholesky factorisation consumes.
//!
//! The update is computed block-wise: diagonal blocks via a small direct
//! kernel that touches only the lower triangle, off-diagonal blocks as
//! plain SGEMM tiles (where all the flops are), so the heavy work runs at
//! full kernel speed.

use super::matrix::{MatMut, MatRef};
use super::{Backend, BlasError, Transpose};
use crate::gemm::element::Element;

/// Block size for the tiled update.
const NB: usize = 64;

/// `C = alpha * A * Aᵀ + beta * C` in f32 (`SSYRK`): the monomorphic
/// shim over [`syrk_lower`].
pub fn ssyrk_lower(
    backend: Backend,
    alpha: f32,
    a: MatRef<'_>,
    beta: f32,
    c: &mut MatMut<'_>,
) -> Result<(), BlasError> {
    syrk_lower(backend, alpha, a, beta, c)
}

/// `C = alpha * A * Aᵀ + beta * C` in f64 (`DSYRK`) — the update the
/// double-precision Cholesky tier (`dpotrf`) consumes.
pub fn dsyrk_lower(
    backend: Backend,
    alpha: f64,
    a: MatRef<'_, f64>,
    beta: f64,
    c: &mut MatMut<'_, f64>,
) -> Result<(), BlasError> {
    syrk_lower(backend, alpha, a, beta, c)
}

/// `C = alpha * A * Aᵀ + beta * C`, updating only the lower triangle of
/// the `n × n` matrix `C` (`A` is `n × k`). The strict upper triangle is
/// left untouched. Generic over the element precision.
pub fn syrk_lower<T: Element>(
    backend: Backend,
    alpha: T,
    a: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
) -> Result<(), BlasError> {
    let n = a.rows();
    let k = a.cols();
    if c.rows() != n || c.cols() != n {
        return Err(BlasError::ShapeMismatch { what: "C", expect: (n, n), got: (c.rows(), c.cols()) });
    }
    let mut i0 = 0;
    while i0 < n {
        let ib = NB.min(n - i0);
        // Diagonal block: direct lower-triangle dot products over safe
        // row slices (one bounds check per row pair, not per element).
        for i in i0..i0 + ib {
            let row_i = &a.data()[i * a.ld()..][..k];
            for j in i0..=i {
                let row_j = &a.data()[j * a.ld()..][..k];
                let mut acc = T::ZERO;
                for (&ai, &aj) in row_i.iter().zip(row_j) {
                    acc += ai * aj;
                }
                let old = c.get(i, j);
                c.set(i, j, alpha * acc + beta * old);
            }
        }
        // Off-diagonal row panel: C[i0+ib.., i0..i0+ib] — one GEMM.
        if i0 + ib < n {
            let rows = n - (i0 + ib);
            let a_lo = a.block(i0 + ib, 0, rows, k);
            let a_diag = a.block(i0, 0, ib, k);
            let mut c_panel = c.block_mut(i0 + ib, i0, rows, ib);
            let ld = c_panel.ld();
            // C_panel = alpha * A_lo · A_diagᵀ + beta * C_panel.
            // SAFETY: c_panel is the only live view over C while the
            // slice exists (&mut c is exclusively borrowed and the
            // diagonal pass above has finished), so it owns its entire
            // backing range for the duration of the call.
            let panel_slice = unsafe { c_panel.flat_mut() };
            super::api::gemm(
                backend,
                Transpose::No,
                Transpose::Yes,
                rows,
                ib,
                k,
                alpha,
                a_lo.data(),
                a_lo.ld(),
                a_diag.data(),
                a_diag.ld(),
                beta,
                panel_slice,
                ld,
            )?;
        }
        i0 += ib;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::Matrix;

    fn syrk_ref(alpha: f32, a: &Matrix, beta: f32, c0: &Matrix) -> Matrix {
        let n = a.rows();
        let mut out = c0.clone();
        for i in 0..n {
            for j in 0..=i {
                let mut acc = 0.0f32;
                for p in 0..a.cols() {
                    acc += a.get(i, p) * a.get(j, p);
                }
                out.set(i, j, alpha * acc + beta * c0.get(i, j));
            }
        }
        out
    }

    #[test]
    fn matches_reference_lower_triangle() {
        for &(n, k) in &[(1usize, 3usize), (8, 8), (65, 40), (130, 70)] {
            let a = Matrix::random(n, k, 1, -1.0, 1.0);
            let c0 = Matrix::random(n, n, 2, -1.0, 1.0);
            let want = syrk_ref(0.7, &a, 1.3, &c0);
            let mut c = c0.clone();
            ssyrk_lower(Backend::Simd, 0.7, a.view(), 1.3, &mut c.view_mut()).unwrap();
            for i in 0..n {
                for j in 0..=i {
                    assert!(
                        (c.get(i, j) - want.get(i, j)).abs() < 1e-3,
                        "({i},{j}) n={n} k={k}: {} vs {}",
                        c.get(i, j),
                        want.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn upper_triangle_untouched() {
        let n = 70;
        let a = Matrix::random(n, 20, 3, -1.0, 1.0);
        let mut c = Matrix::from_fn(n, n, |_, _| 42.0);
        ssyrk_lower(Backend::Simd, 1.0, a.view(), 0.0, &mut c.view_mut()).unwrap();
        for i in 0..n {
            for j in i + 1..n {
                assert_eq!(c.get(i, j), 42.0, "upper ({i},{j}) was written");
            }
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Matrix::zeros(4, 3);
        let mut c = Matrix::zeros(5, 5);
        assert!(ssyrk_lower(Backend::Naive, 1.0, a.view(), 0.0, &mut c.view_mut()).is_err());
    }
}
