//! Strided matrix storage and views, generic over the element precision.
//!
//! All Emmerald matrices are **row-major** with an explicit leading
//! dimension (`ld`): element `(r, c)` lives at `data[r * ld + c]` and
//! `ld >= cols`. The paper's benchmark methodology fixes the stride at 700
//! for every size, so strided views (rows longer than their logical width)
//! are first-class throughout.
//!
//! Since the element-generic precision subsystem
//! ([`crate::gemm::element`]), every type here carries an element
//! parameter with **`f32` as the default** — `Matrix`, `MatRef<'_>` and
//! `MatMut<'_>` written without a parameter mean exactly what they always
//! did, and `Matrix<f64>` is the DGEMM storage type. The kernel-triple
//! refactor relaxed the storage bound from `Element` to
//! [`crate::gemm::Scalar`], so the same types also hold the quantized
//! triple's sides: `Matrix<u8>` activations, `Matrix<i8>` weights and
//! `Matrix<i32>` accumulator outputs. Only the helpers that need float
//! algebra (`random*`, `max_abs_diff`) stay `Element`-bound.
//!
//! Raw access: `MatMut` is built on the checked raw-pointer core
//! ([`crate::util::ptr::RawMatMut`]) — the pointer arithmetic for row
//! splits, column splits and sub-windows lives there, verified under
//! `debug_assertions`/`checked-ptr`, and the kernel drivers obtain
//! length-carrying spans ([`MatRef::row_span`], [`MatRef::tail_span`])
//! instead of bare pointers.

use super::error::BlasError;
use crate::gemm::element::{Element, Scalar};
use crate::util::ptr::{RawMat, RawMatMut, RawSlice};

/// Immutable strided view over element data.
#[derive(Clone, Copy, Debug)]
pub struct MatRef<'a, T = f32> {
    data: &'a [T],
    rows: usize,
    cols: usize,
    ld: usize,
}

impl<'a, T: Scalar> MatRef<'a, T> {
    /// Construct a view, validating `ld` and the backing length.
    pub fn new(data: &'a [T], rows: usize, cols: usize, ld: usize) -> Result<Self, BlasError> {
        validate(rows, cols, ld, data.len())?;
        Ok(Self { data, rows, cols, ld })
    }

    /// Rows of the stored matrix.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns of the stored matrix.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Leading dimension (row stride, in elements).
    pub fn ld(&self) -> usize {
        self.ld
    }

    /// Raw backing slice.
    pub fn data(&self) -> &'a [T] {
        self.data
    }

    /// Bounds-checked element access.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> T {
        assert!(r < self.rows && c < self.cols, "({r},{c}) out of {}x{}", self.rows, self.cols);
        self.data[r * self.ld + c]
    }

    /// Checked raw handle over this view — what the packing routines read
    /// through (debug/`checked-ptr` verified, bare pointer in release).
    #[inline]
    pub(crate) fn raw(&self) -> RawMat<T> {
        RawMat::from_slice(self.data, self.rows, self.cols, self.ld)
    }

    /// Pointer to the start of row `r`.
    #[inline(always)]
    pub fn row_ptr(&self, r: usize) -> *const T {
        debug_assert!(r < self.rows);
        self.data[r * self.ld..].as_ptr()
    }

    /// Length-carrying span over `len` elements of row `r` starting at
    /// column `c0` — the dot drivers' contiguous `A`-row window.
    #[inline]
    pub(crate) fn row_span(&self, r: usize, c0: usize, len: usize) -> RawSlice<T> {
        assert!(r < self.rows && c0 + len <= self.cols, "row span ({r}, {c0}+{len}) out of {}x{}", self.rows, self.cols);
        let start = r * self.ld + c0;
        RawSlice::from_slice(&self.data[start..start + len])
    }

    /// Length-carrying span from `(r, c0)` to the end of the backing
    /// storage — the strided-`B` ablation path walks this across rows
    /// with an explicit stride.
    #[inline]
    pub(crate) fn tail_span(&self, r: usize, c0: usize) -> RawSlice<T> {
        assert!(r < self.rows && c0 <= self.cols, "tail span ({r}, {c0}) out of {}x{}", self.rows, self.cols);
        RawSlice::from_slice(&self.data[r * self.ld + c0..])
    }

    /// Sub-view of `nr × nc` starting at `(r0, c0)` (same stride).
    pub fn block(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> MatRef<'a, T> {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols, "block out of range");
        MatRef {
            data: &self.data[r0 * self.ld + c0..],
            rows: nr,
            cols: nc,
            ld: self.ld,
        }
    }
}

/// Mutable strided view over element data.
///
/// Built on a raw handle ([`RawMatMut`]) rather than `&mut [T]` so the
/// view can be split along *either* axis: two column slices of a strided
/// matrix interleave in storage (every row of the left slice is followed
/// by the right slice's part of that row), which two `&mut [T]` halves
/// cannot express. The invariant is that a `MatMut` grants exclusive
/// access to its **logical** elements (`(r, c)` with `r < rows`,
/// `c < cols`) only; sibling views produced by
/// [`split_rows`](Self::split_rows) / [`split_cols`](Self::split_cols)
/// may share a backing range but never a logical element, so the
/// accessors below never race.
#[derive(Debug)]
pub struct MatMut<'a, T = f32> {
    raw: RawMatMut<T>,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: a MatMut carries the exclusive capability to touch its logical
// elements (it is created from a `&mut [T]` and siblings are logically
// disjoint), exactly like the `&mut [T]` it used to wrap — sending that
// capability to another thread is sound. Not `Sync`: `&MatMut` exposes
// `as_ref`, which must not observe a sibling's concurrent writes.
unsafe impl<T: Send> Send for MatMut<'_, T> {}

impl<'a, T: Scalar> MatMut<'a, T> {
    /// Construct a view, validating `ld` and the backing length.
    pub fn new(data: &'a mut [T], rows: usize, cols: usize, ld: usize) -> Result<Self, BlasError> {
        validate(rows, cols, ld, data.len())?;
        Ok(Self::from_raw(RawMatMut::from_slice(data, rows, cols, ld)))
    }

    /// Wrap an already-validated raw handle (module-internal: the handle
    /// must have come from an exclusive borrow).
    #[inline]
    fn from_raw(raw: RawMatMut<T>) -> Self {
        Self { raw, _marker: std::marker::PhantomData }
    }

    /// Rows of the stored matrix.
    pub fn rows(&self) -> usize {
        self.raw.rows()
    }

    /// Columns of the stored matrix.
    pub fn cols(&self) -> usize {
        self.raw.cols()
    }

    /// Leading dimension (row stride, in elements).
    pub fn ld(&self) -> usize {
        self.raw.ld()
    }

    /// Bounds-checked element read.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> T {
        assert!(r < self.rows() && c < self.cols());
        // SAFETY: logical indices validated against the view's extent,
        // and &self pauses this view's own writes.
        unsafe { self.raw.get(r, c) }
    }

    /// Bounds-checked element write.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        assert!(r < self.rows() && c < self.cols());
        // SAFETY: logical indices validated against the view's extent,
        // and &mut self guarantees exclusivity over them.
        unsafe { self.raw.set(r, c, v) }
    }

    /// Mutable pointer to the start of row `r`.
    #[inline(always)]
    pub fn row_ptr_mut(&mut self, r: usize) -> *mut T {
        self.raw.row_ptr(r)
    }

    /// Checked pointer to the top-left corner of the `h × w` writeback
    /// window at `(r0, c0)` — the tile tier's anchor. The whole window is
    /// verified against the view's extent under
    /// `debug_assertions`/`checked-ptr`.
    #[inline]
    pub(crate) fn window_ptr(&mut self, r0: usize, c0: usize, h: usize, w: usize) -> *mut T {
        self.raw.window_ptr(r0, c0, h, w)
    }

    /// Copy of the underlying checked raw handle (crate-internal; the
    /// caller inherits the exclusivity discipline of `&mut self` for as
    /// long as it uses the handle).
    #[inline]
    pub(crate) fn raw_mut(&mut self) -> RawMatMut<T> {
        self.raw
    }

    /// Reborrow as an immutable view.
    ///
    /// Must not be called while a sibling view (from
    /// [`split_cols`](Self::split_cols)) is being written on another
    /// thread: the returned slice spans the full backing range, padding
    /// columns included.
    pub fn as_ref(&self) -> MatRef<'_, T> {
        // SAFETY: the backing range was a valid &mut [T] at construction
        // and `&self` pauses this view's own writes for the borrow.
        let data = unsafe { self.raw.flat() };
        MatRef { data, rows: self.rows(), cols: self.cols(), ld: self.ld() }
    }

    /// Reconstruct the full backing range as one mutable slice (stride
    /// padding included) — the column-panel feed for slice-based APIs.
    ///
    /// # Safety
    /// This view must own its *entire* backing range exclusively — true
    /// for views over a whole matrix or a [`block_mut`](Self::block_mut)
    /// of one, never for a [`split_cols`](Self::split_cols) half (whose
    /// backing range interleaves with its sibling's logical elements).
    pub(crate) unsafe fn flat_mut(&mut self) -> &mut [T] {
        // SAFETY: whole-range exclusivity is the caller's contract;
        // lifetime is tied to &mut self by the signature.
        unsafe { self.raw.flat_mut() }
    }

    /// Reborrow as a shorter-lived mutable view.
    pub fn reborrow(&mut self) -> MatMut<'_, T> {
        MatMut::from_raw(self.raw)
    }

    /// Split into two disjoint row ranges at row `r` (the matrix analogue
    /// of `split_at_mut`); used by the thread-parallel GEMM driver. The
    /// halves' backing ranges cannot overlap (the top half's length is
    /// clamped to the split offset — see [`RawMatMut::split_rows`]).
    pub fn split_rows(self, r: usize) -> (MatMut<'a, T>, MatMut<'a, T>) {
        let (top, bottom) = self.raw.split_rows(r);
        (MatMut::from_raw(top), MatMut::from_raw(bottom))
    }

    /// Split into two disjoint column ranges at column `c` (left keeps
    /// columns `0..c`, right gets `c..cols`); used by the thread-parallel
    /// GEMM driver's column split for skinny row spaces. The halves
    /// interleave in storage (same rows, same stride) but their logical
    /// elements are disjoint — the raw-handle representation exists for
    /// exactly this split.
    pub fn split_cols(self, c: usize) -> (MatMut<'a, T>, MatMut<'a, T>) {
        let (left, right) = self.raw.split_cols(c);
        (MatMut::from_raw(left), MatMut::from_raw(right))
    }

    /// Reborrow a mutable sub-view of `nr × nc` starting at `(r0, c0)`.
    pub fn block_mut(&mut self, r0: usize, c0: usize, nr: usize, nc: usize) -> MatMut<'_, T> {
        MatMut::from_raw(self.raw.window(r0, c0, nr, nc))
    }

    /// Scale every element of the logical matrix by `beta`
    /// (`beta == 0` writes zeros, discarding any NaN/Inf in C, matching
    /// BLAS semantics).
    pub fn scale(&mut self, beta: T) {
        if beta == T::ONE {
            return;
        }
        for r in 0..self.rows() {
            // SAFETY: row r's logical elements are in bounds (r < rows)
            // and &mut self holds off every other access to them for the
            // duration of the borrow.
            let row = unsafe { self.raw.row_slice_mut(r) };
            if beta == T::ZERO {
                row.fill(T::ZERO);
            } else {
                for v in row {
                    *v *= beta;
                }
            }
        }
    }
}

fn validate(rows: usize, cols: usize, ld: usize, len: usize) -> Result<(), BlasError> {
    if rows == 0 || cols == 0 {
        return Ok(()); // empty views never touch memory
    }
    if ld < cols {
        return Err(BlasError::BadLeadingDim { operand: "?", ld, cols });
    }
    let need = (rows - 1) * ld + cols;
    if len < need {
        return Err(BlasError::BufferTooSmall { operand: "?", need, got: len });
    }
    Ok(())
}

/// Owned row-major matrix (contiguous or padded to a stride).
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix<T = f32> {
    data: Vec<T>,
    rows: usize,
    cols: usize,
    ld: usize,
}

impl<T: Scalar> Matrix<T> {
    /// Zero-filled `rows × cols` matrix with `ld == cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![T::ZERO; rows * cols], rows, cols, ld: cols }
    }

    /// Zero-filled matrix with an explicit stride (`ld >= cols`), matching
    /// the paper's fixed-stride benchmarking layout.
    pub fn zeros_strided(rows: usize, cols: usize, ld: usize) -> Self {
        assert!(ld >= cols, "ld {ld} < cols {cols}");
        Self { data: vec![T::ZERO; rows.max(1) * ld], rows, cols, ld }
    }

    /// Build from a function of (row, col).
    pub fn from_fn<F: FnMut(usize, usize) -> T>(rows: usize, cols: usize, mut f: F) -> Self {
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    /// Rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Leading dimension.
    pub fn ld(&self) -> usize {
        self.ld
    }

    /// Backing storage.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    /// Mutable backing storage.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Element read.
    pub fn get(&self, r: usize, c: usize) -> T {
        assert!(r < self.rows && c < self.cols);
        self.data[r * self.ld + c]
    }

    /// Element write.
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        assert!(r < self.rows && c < self.cols);
        self.data[r * self.ld + c] = v;
    }

    /// Immutable view of the whole matrix.
    pub fn view(&self) -> MatRef<'_, T> {
        MatRef { data: &self.data, rows: self.rows, cols: self.cols, ld: self.ld }
    }

    /// Mutable view of the whole matrix.
    pub fn view_mut(&mut self) -> MatMut<'_, T> {
        MatMut::from_raw(RawMatMut::from_slice(&mut self.data, self.rows, self.cols, self.ld))
    }

    /// Logical transpose (materialised copy).
    pub fn transposed(&self) -> Matrix<T> {
        Matrix::from_fn(self.cols, self.rows, |r, c| self.get(c, r))
    }
}

/// Helpers needing float algebra (sampling, sentinels, |·| distance) keep
/// the [`Element`] bound; everything storage-shaped above is [`Scalar`].
impl<T: Element> Matrix<T> {
    /// Uniform-random matrix in `[lo, hi)` from a seed (deterministic;
    /// the f32 instantiation draws exactly the pre-refactor bit stream).
    pub fn random(rows: usize, cols: usize, seed: u64, lo: T, hi: T) -> Self {
        let mut rng = crate::util::prng::Pcg32::new(seed);
        let mut m = Self::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = T::sample(&mut rng, lo, hi);
        }
        m
    }

    /// Uniform-random matrix with explicit stride; the padding tail of each
    /// row is filled with a sentinel so tests can detect stray writes.
    pub fn random_strided(rows: usize, cols: usize, ld: usize, seed: u64) -> Self {
        let mut m = Self::zeros_strided(rows, cols, ld);
        let mut rng = crate::util::prng::Pcg32::new(seed);
        let (lo, hi) = (T::from_f64(-1.0), T::from_f64(1.0));
        let sentinel = T::from_f64(-77.0);
        for r in 0..rows {
            for c in 0..ld {
                m.data[r * ld + c] = if c < cols { T::sample(&mut rng, lo, hi) } else { sentinel };
            }
        }
        m
    }

    /// Maximum absolute element difference over the logical area.
    pub fn max_abs_diff(&self, other: &Matrix<T>) -> T {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut worst = T::ZERO;
        for r in 0..self.rows {
            for c in 0..self.cols {
                worst = worst.max((self.get(r, c) - other.get(r, c)).abs());
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn views_validate() {
        let d = vec![0.0f32; 10];
        assert!(MatRef::new(&d, 2, 5, 5).is_ok());
        assert!(MatRef::new(&d, 2, 5, 4).is_err()); // ld < cols
        assert!(MatRef::new(&d, 3, 5, 5).is_err()); // too short
        assert!(MatRef::new(&d, 2, 4, 6).is_ok()); // (2-1)*6+4 = 10 fits exactly
        assert!(MatRef::<f32>::new(&[], 0, 5, 5).is_ok()); // empty is fine
    }

    #[test]
    fn get_set_strided() {
        let mut m = Matrix::<f32>::zeros_strided(3, 2, 4);
        m.set(2, 1, 9.0);
        assert_eq!(m.get(2, 1), 9.0);
        assert_eq!(m.data()[2 * 4 + 1], 9.0);
        assert_eq!(m.ld(), 4);
    }

    #[test]
    fn block_views() {
        let m = Matrix::from_fn(4, 5, |r, c| (r * 10 + c) as f32);
        let b = m.view().block(1, 2, 2, 3);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.cols(), 3);
        assert_eq!(b.get(0, 0), 12.0);
        assert_eq!(b.get(1, 2), 24.0);
    }

    #[test]
    fn block_mut_writes_through() {
        let mut m = Matrix::<f32>::zeros(4, 4);
        {
            let mut b = m.view_mut();
            let mut b = b.block_mut(2, 2, 2, 2);
            b.set(0, 0, 5.0);
            b.set(1, 1, 6.0);
        }
        assert_eq!(m.get(2, 2), 5.0);
        assert_eq!(m.get(3, 3), 6.0);
        assert_eq!(m.get(0, 0), 0.0);
    }

    #[test]
    fn scale_semantics() {
        let mut m = Matrix::<f32>::from_fn(2, 2, |_, _| 3.0);
        m.view_mut().scale(2.0);
        assert_eq!(m.get(0, 0), 6.0);
        // beta = 0 must overwrite even NaN.
        m.set(1, 1, f32::NAN);
        m.view_mut().scale(0.0);
        assert_eq!(m.get(1, 1), 0.0);
    }

    #[test]
    fn scale_respects_padding() {
        let mut m = Matrix::<f32>::random_strided(2, 3, 5, 1);
        let pad_before = m.data()[3]; // sentinel -77
        m.view_mut().scale(0.0);
        assert_eq!(m.data()[3], pad_before, "padding must not be scaled");
        assert_eq!(m.get(0, 0), 0.0);
    }

    #[test]
    fn split_rows_disjoint_and_complete() {
        let mut m = Matrix::from_fn(6, 3, |r, c| (r * 10 + c) as f32);
        {
            let v = m.view_mut();
            let (mut top, mut bottom) = v.split_rows(2);
            assert_eq!(top.rows(), 2);
            assert_eq!(bottom.rows(), 4);
            assert_eq!(top.get(1, 2), 12.0);
            assert_eq!(bottom.get(0, 0), 20.0);
            top.set(0, 0, -1.0);
            bottom.set(3, 2, -2.0);
        }
        assert_eq!(m.get(0, 0), -1.0);
        assert_eq!(m.get(5, 2), -2.0);
    }

    #[test]
    fn split_rows_edges() {
        let mut m = Matrix::<f32>::zeros(3, 2);
        let (top, bottom) = m.view_mut().split_rows(0);
        assert_eq!(top.rows(), 0);
        assert_eq!(bottom.rows(), 3);
        let (top, bottom) = m.view_mut().split_rows(3);
        assert_eq!(top.rows(), 3);
        assert_eq!(bottom.rows(), 0);
    }

    #[test]
    fn split_cols_disjoint_and_complete() {
        let mut m = Matrix::from_fn(4, 6, |r, c| (r * 10 + c) as f32);
        {
            let v = m.view_mut();
            let (mut left, mut right) = v.split_cols(2);
            assert_eq!((left.rows(), left.cols()), (4, 2));
            assert_eq!((right.rows(), right.cols()), (4, 4));
            assert_eq!(left.get(3, 1), 31.0);
            assert_eq!(right.get(0, 0), 2.0);
            assert_eq!(right.get(3, 3), 35.0);
            left.set(0, 0, -1.0);
            right.set(3, 3, -2.0);
        }
        assert_eq!(m.get(0, 0), -1.0);
        assert_eq!(m.get(3, 5), -2.0);
        // Every other element untouched.
        assert_eq!(m.get(2, 1), 21.0);
        assert_eq!(m.get(2, 4), 24.0);
    }

    #[test]
    fn split_cols_edges_and_strided() {
        let mut m = Matrix::<f32>::zeros(3, 4);
        let (left, right) = m.view_mut().split_cols(0);
        assert_eq!(left.cols(), 0);
        assert_eq!(right.cols(), 4);
        let (left, right) = m.view_mut().split_cols(4);
        assert_eq!(left.cols(), 4);
        assert_eq!(right.cols(), 0);
        // Strided storage: the padding sentinel between logical columns
        // and the stride tail must survive writes through both halves.
        let mut s = Matrix::<f32>::random_strided(3, 4, 7, 9);
        {
            let v = s.view_mut();
            let (mut left, mut right) = v.split_cols(2);
            for r in 0..3 {
                left.set(r, 0, 1.0);
                right.set(r, 1, 2.0);
            }
        }
        for r in 0..3 {
            assert_eq!(s.get(r, 0), 1.0);
            assert_eq!(s.get(r, 3), 2.0);
            for p in 4..7 {
                assert_eq!(s.data()[r * 7 + p], -77.0, "stride padding clobbered");
            }
        }
    }

    #[test]
    fn reborrow_shares_storage() {
        let mut m = Matrix::<f32>::zeros(2, 2);
        {
            let mut v = m.view_mut();
            let mut r = v.reborrow();
            r.set(1, 1, 5.0);
        }
        assert_eq!(m.get(1, 1), 5.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::<f32>::random(3, 5, 7, -1.0, 1.0);
        let tt = m.transposed().transposed();
        assert_eq!(m, tt);
    }

    #[test]
    fn random_is_deterministic() {
        let a = Matrix::<f32>::random(4, 4, 42, -1.0, 1.0);
        let b = Matrix::<f32>::random(4, 4, 42, -1.0, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn f64_matrix_roundtrips_and_differs_in_width() {
        let a = Matrix::<f64>::random(4, 4, 42, -1.0, 1.0);
        let b = Matrix::<f64>::random(4, 4, 42, -1.0, 1.0);
        assert_eq!(a, b);
        let tt = a.transposed().transposed();
        assert_eq!(a, tt);
        // The strided f64 variant carries the same sentinel discipline.
        let s = Matrix::<f64>::random_strided(2, 3, 5, 7);
        assert_eq!(s.data()[3], -77.0);
        assert!(s.get(1, 2).abs() <= 1.0);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_get_panics() {
        let m = Matrix::<f32>::zeros(2, 2);
        let _ = m.get(2, 0);
    }

    #[test]
    fn row_and_tail_spans_carry_lengths() {
        let m = Matrix::from_fn(3, 4, |r, c| (r * 10 + c) as f32);
        let v = m.view();
        let row = v.row_span(1, 1, 3);
        assert_eq!(row.len(), 3);
        // SAFETY: indices < 3, backing matrix alive for the reads.
        unsafe {
            assert_eq!(row.get(0), 11.0);
            assert_eq!(row.get(2), 13.0);
        }
        let tail = v.tail_span(2, 2);
        assert_eq!(tail.len(), 2);
        // SAFETY: index < 2.
        unsafe {
            assert_eq!(tail.get(0), 22.0);
        }
    }

    #[test]
    #[should_panic]
    fn row_span_rejects_overlong_window() {
        let m = Matrix::<f32>::zeros(3, 4);
        let _ = m.view().row_span(0, 2, 3); // 2 + 3 > 4 cols
    }
}
