//! BLAS argument-validation errors.

use std::fmt;

/// Errors surfaced by the SGEMM entry points before any compute happens.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BlasError {
    /// Leading dimension smaller than the stored row length.
    BadLeadingDim {
        /// Which operand ("A", "B", "C" or "?" inside view construction).
        operand: &'static str,
        /// The offending leading dimension.
        ld: usize,
        /// The stored column count it must cover.
        cols: usize,
    },
    /// The slice is too short for the described matrix.
    BufferTooSmall {
        /// Which operand.
        operand: &'static str,
        /// Required element count `(rows-1)*ld + cols`.
        need: usize,
        /// Actual slice length.
        got: usize,
    },
    /// `op(A)`'s k and `op(B)`'s k disagree (matrix-wrapper API only).
    DimMismatch {
        /// Output rows.
        m: usize,
        /// Output cols.
        n: usize,
        /// k from `op(A)`.
        k: usize,
        /// k from `op(B)`.
        other_k: usize,
    },
    /// An operand has the wrong shape (matrix-wrapper API only).
    ShapeMismatch {
        /// Which operand.
        what: &'static str,
        /// Expected (rows, cols).
        expect: (usize, usize),
        /// Actual (rows, cols).
        got: (usize, usize),
    },
    /// Invalid BLAS transpose character.
    BadTranspose(char),
    /// The requested backend is not available on this CPU.
    BackendUnavailable(&'static str),
    /// Batched output items overlap: the batch stride does not cover one
    /// item's extent (batched API only).
    BadBatchStride {
        /// Which operand.
        operand: &'static str,
        /// The offending batch stride.
        stride: usize,
        /// Minimum stride: one item's element extent `(rows-1)*ld + cols`.
        need: usize,
    },
    /// A prepacked operand was built under a different kernel geometry
    /// than the plan resolved to (planned API only).
    PlanMismatch(&'static str),
}

impl BlasError {
    /// Re-tag a view-construction error with the operand name.
    pub(crate) fn operand(self, name: &'static str) -> Self {
        match self {
            BlasError::BadLeadingDim { ld, cols, .. } => {
                BlasError::BadLeadingDim { operand: name, ld, cols }
            }
            BlasError::BufferTooSmall { need, got, .. } => {
                BlasError::BufferTooSmall { operand: name, need, got }
            }
            other => other,
        }
    }
}

impl fmt::Display for BlasError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BlasError::BadLeadingDim { operand, ld, cols } => {
                write!(f, "operand {operand}: leading dimension {ld} < stored columns {cols}")
            }
            BlasError::BufferTooSmall { operand, need, got } => {
                write!(f, "operand {operand}: buffer holds {got} elements, needs {need}")
            }
            BlasError::DimMismatch { m, n, k, other_k } => {
                write!(f, "inner dimensions disagree: op(A) is {m}x{k}, op(B) is {other_k}x{n}")
            }
            BlasError::ShapeMismatch { what, expect, got } => {
                write!(f, "operand {what}: expected {}x{}, got {}x{}", expect.0, expect.1, got.0, got.1)
            }
            BlasError::BadTranspose(c) => {
                write!(f, "invalid transpose flag '{c}' (want n/N, t/T or c/C)")
            }
            BlasError::BackendUnavailable(b) => {
                write!(f, "backend {b} is not available on this CPU")
            }
            BlasError::BadBatchStride { operand, stride, need } => {
                write!(
                    f,
                    "operand {operand}: batch stride {stride} overlaps items needing {need} elements"
                )
            }
            BlasError::PlanMismatch(msg) => write!(f, "plan mismatch: {msg}"),
        }
    }
}

impl std::error::Error for BlasError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = BlasError::BadLeadingDim { operand: "A", ld: 2, cols: 5 };
        assert!(e.to_string().contains("leading dimension 2"));
        let e = BlasError::DimMismatch { m: 1, n: 2, k: 3, other_k: 4 };
        assert!(e.to_string().contains("1x3"));
        let e = BlasError::BadTranspose('x');
        assert!(e.to_string().contains('x'));
    }

    #[test]
    fn operand_retag() {
        let e = BlasError::BufferTooSmall { operand: "?", need: 10, got: 5 };
        match e.operand("B") {
            BlasError::BufferTooSmall { operand, .. } => assert_eq!(operand, "B"),
            _ => panic!(),
        }
    }
}
