//! Level-3 BLAS `SGEMM` public interface.
//!
//! Emmerald implements the `SGEMM` interface of Level-3 BLAS (paper §1) so
//! it can drop into BLAS-based libraries. This module is the public API:
//!
//! ```
//! use emmerald::blas::{sgemm, Backend, Transpose};
//!
//! // C = alpha * A*B + beta * C  with row-major storage and explicit
//! // leading dimensions (row strides), exactly like the paper's fixed
//! // stride-700 benchmark methodology.
//! let (m, n, k) = (3, 4, 5);
//! let a: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
//! let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32).collect();
//! let mut c = vec![0.0f32; m * n];
//! sgemm(Backend::Auto, Transpose::No, Transpose::No,
//!       m, n, k, 1.0, &a, k, &b, n, 0.0, &mut c, n).unwrap();
//! ```
//!
//! Storage is **row-major** with a leading dimension (`ld*`) giving the
//! distance in elements between consecutive rows; `ld >= cols` of the
//! stored matrix. Transposition is expressed logically via [`Transpose`] —
//! no data is moved.

mod backend;
mod error;
pub mod level1;
pub mod level2;
mod matrix;
pub mod syrk;

pub use backend::{available_backends, Backend};
pub use level1::{isamax, saxpy, sdot, snrm2, sscal};
pub use level2::sgemv;
pub use syrk::ssyrk_lower;
pub use error::BlasError;
pub use matrix::{MatMut, MatRef, Matrix};

/// Logical transposition of an operand (`op(X) = X` or `Xᵀ`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transpose {
    /// `op(X) = X`
    No,
    /// `op(X) = Xᵀ`
    Yes,
}

impl Transpose {
    /// Parse from the BLAS character convention ('n'/'N' or 't'/'T').
    pub fn from_char(c: char) -> Result<Self, BlasError> {
        match c {
            'n' | 'N' => Ok(Transpose::No),
            't' | 'T' => Ok(Transpose::Yes),
            other => Err(BlasError::BadTranspose(other)),
        }
    }
}

/// General matrix-matrix multiply: `C = alpha * op(A) * op(B) + beta * C`.
///
/// * `op(A)` is `m × k`, `op(B)` is `k × n`, `C` is `m × n`.
/// * `a` stores `A` row-major with leading dimension `lda` (so `A` is
///   `m × k` storage when `transa == No`, `k × m` when `Yes`); same for `b`.
/// * Degenerate dimensions (`m`, `n` or `k` = 0) are valid: `k == 0`
///   scales `C` by `beta`; `m == 0` or `n == 0` is a no-op.
///
/// This is the crate's primary entry point; `backend` selects the
/// implementation ([`Backend::Auto`] picks the fastest available).
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
) -> Result<(), BlasError> {
    // Stored shapes of A and B.
    let (ar, ac) = match transa {
        Transpose::No => (m, k),
        Transpose::Yes => (k, m),
    };
    let (br, bc) = match transb {
        Transpose::No => (k, n),
        Transpose::Yes => (n, k),
    };
    let a = MatRef::new(a, ar, ac, lda).map_err(|e| e.operand("A"))?;
    let b = MatRef::new(b, br, bc, ldb).map_err(|e| e.operand("B"))?;
    let c = MatMut::new(c, m, n, ldc).map_err(|e| e.operand("C"))?;

    if m == 0 || n == 0 {
        return Ok(());
    }

    backend.resolve()?.dispatch(transa, transb, alpha, a, b, beta, c);
    Ok(())
}

/// Strided-batch SGEMM: `C_i = alpha · op(A_i) op(B_i) + beta · C_i` for
/// `i in 0..batch`, with `X_i = x[i * stride_x ..]` (stride 0 broadcasts a
/// read-only operand — the cuBLAS `gemmStridedBatched` convention).
///
/// [`Backend::Dispatch`]/[`Backend::Auto`] run the full batched driver
/// (shared-B folding, per-worker packing scratch, thread fan-out — see
/// [`crate::gemm::batch`]); explicit backends run their kernel per item
/// with the same validation and amortised packing buffers.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_batch(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    stride_a: usize,
    b: &[f32],
    ldb: usize,
    stride_b: usize,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
    stride_c: usize,
    batch: usize,
) -> Result<(), BlasError> {
    use crate::gemm::batch::{gemm_batch_impl, BatchStrides};
    use crate::gemm::dispatch::{with_global, KernelId};

    let forced = match backend.resolve()? {
        backend::Resolved::Naive => Some(KernelId::Naive),
        backend::Resolved::Blocked => Some(KernelId::Blocked),
        backend::Resolved::Simd => Some(KernelId::Simd),
        backend::Resolved::Avx2 => Some(KernelId::Avx2),
        backend::Resolved::Dispatch => None,
    };
    let strides = BatchStrides { a: stride_a, b: stride_b, c: stride_c };
    with_global(|d| {
        gemm_batch_impl(d, forced, transa, transb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc, batch, strides)
    })
}

/// Convenience wrapper over [`sgemm`] for owned [`Matrix`] values
/// (`C = alpha * op(A) op(B) + beta * C`).
pub fn sgemm_matrix(
    backend: Backend,
    transa: Transpose,
    transb: Transpose,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &mut Matrix,
) -> Result<(), BlasError> {
    let (m, ka) = match transa {
        Transpose::No => (a.rows(), a.cols()),
        Transpose::Yes => (a.cols(), a.rows()),
    };
    let (kb, n) = match transb {
        Transpose::No => (b.rows(), b.cols()),
        Transpose::Yes => (b.cols(), b.rows()),
    };
    if ka != kb {
        return Err(BlasError::DimMismatch { m, n, k: ka, other_k: kb });
    }
    if c.rows() != m || c.cols() != n {
        return Err(BlasError::ShapeMismatch {
            what: "C",
            expect: (m, n),
            got: (c.rows(), c.cols()),
        });
    }
    let (lda, ldb, ldc) = (a.ld(), b.ld(), c.ld());
    sgemm(
        backend,
        transa,
        transb,
        m,
        n,
        ka,
        alpha,
        a.data(),
        lda,
        b.data(),
        ldb,
        beta,
        c.data_mut(),
        ldc,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_oracle(
        transa: Transpose,
        transb: Transpose,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: &[f32],
        lda: usize,
        b: &[f32],
        ldb: usize,
        beta: f32,
        c: &mut [f32],
        ldc: usize,
    ) {
        // Independent triple loop written directly against the docs'
        // storage convention, used to validate the public entry point.
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    let av = match transa {
                        Transpose::No => a[i * lda + p],
                        Transpose::Yes => a[p * lda + i],
                    };
                    let bv = match transb {
                        Transpose::No => b[p * ldb + j],
                        Transpose::Yes => b[j * ldb + p],
                    };
                    acc += (av as f64) * (bv as f64);
                }
                c[i * ldc + j] = alpha * acc as f32 + beta * c[i * ldc + j];
            }
        }
    }

    #[test]
    fn sgemm_matches_inline_oracle() {
        let (m, n, k) = (5, 7, 3);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32) * 0.25 - 1.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| 2.0 - (i as f32) * 0.125).collect();
        let mut c1: Vec<f32> = (0..m * n).map(|i| i as f32).collect();
        let mut c2 = c1.clone();
        sgemm(Backend::Naive, Transpose::No, Transpose::No, m, n, k, 1.5, &a, k, &b, n, 0.5, &mut c1, n)
            .unwrap();
        naive_oracle(Transpose::No, Transpose::No, m, n, k, 1.5, &a, k, &b, n, 0.5, &mut c2, n);
        crate::util::testkit::assert_allclose(&c1, &c2, 1e-5, 1e-6, "sgemm vs oracle");
    }

    #[test]
    fn transposed_operands() {
        let (m, n, k) = (4, 3, 6);
        // A stored k×m (transa=Yes), B stored n×k (transb=Yes).
        let a: Vec<f32> = (0..k * m).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..n * k).map(|i| (i as f32).cos()).collect();
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        sgemm(Backend::Naive, Transpose::Yes, Transpose::Yes, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c1, n)
            .unwrap();
        naive_oracle(Transpose::Yes, Transpose::Yes, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c2, n);
        crate::util::testkit::assert_allclose(&c1, &c2, 1e-5, 1e-6, "tt");
    }

    #[test]
    fn strided_storage() {
        // Paper methodology: stride fixed to 700 regardless of row length.
        let (m, n, k) = (3, 4, 2);
        let (lda, ldb, ldc) = (10, 11, 12);
        let a: Vec<f32> = (0..m * lda).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..k * ldb).map(|i| i as f32 * 0.2).collect();
        let mut c1 = vec![7.0f32; m * ldc];
        let mut c2 = c1.clone();
        sgemm(Backend::Naive, Transpose::No, Transpose::No, m, n, k, 1.0, &a, lda, &b, ldb, 2.0, &mut c1, ldc)
            .unwrap();
        naive_oracle(Transpose::No, Transpose::No, m, n, k, 1.0, &a, lda, &b, ldb, 2.0, &mut c2, ldc);
        assert_eq!(c1, c2);
        // Padding between rows untouched.
        assert_eq!(c1[n], 7.0);
    }

    #[test]
    fn k_zero_scales_by_beta() {
        let mut c = vec![2.0f32; 4];
        sgemm(Backend::Naive, Transpose::No, Transpose::No, 2, 2, 0, 1.0, &[], 1, &[], 1, 0.5, &mut c, 2)
            .unwrap();
        assert_eq!(c, vec![1.0; 4]);
    }

    #[test]
    fn m_zero_is_noop() {
        let mut c: Vec<f32> = vec![];
        sgemm(Backend::Naive, Transpose::No, Transpose::No, 0, 5, 3, 1.0, &[], 3, &[1.0; 15], 5, 0.0, &mut c, 5)
            .unwrap();
    }

    #[test]
    fn rejects_bad_ld() {
        let a = vec![0.0f32; 6];
        let b = vec![0.0f32; 6];
        let mut c = vec![0.0f32; 4];
        // lda=1 < k=3 for a 2x3 A.
        let err = sgemm(Backend::Naive, Transpose::No, Transpose::No, 2, 2, 3, 1.0, &a, 1, &b, 2, 0.0, &mut c, 2);
        assert!(matches!(err, Err(BlasError::BadLeadingDim { .. })));
    }

    #[test]
    fn rejects_short_buffer() {
        let a = vec![0.0f32; 5]; // needs 2*3=6
        let b = vec![0.0f32; 6];
        let mut c = vec![0.0f32; 4];
        let err = sgemm(Backend::Naive, Transpose::No, Transpose::No, 2, 2, 3, 1.0, &a, 3, &b, 2, 0.0, &mut c, 2);
        assert!(matches!(err, Err(BlasError::BufferTooSmall { .. })));
    }

    #[test]
    fn transpose_from_char() {
        assert_eq!(Transpose::from_char('n').unwrap(), Transpose::No);
        assert_eq!(Transpose::from_char('T').unwrap(), Transpose::Yes);
        assert!(Transpose::from_char('q').is_err());
    }

    #[test]
    fn sgemm_batch_matches_looped_sgemm() {
        let (m, n, k, batch) = (3usize, 4usize, 5usize, 3usize);
        let a: Vec<f32> = (0..batch * m * k).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..batch * k * n).map(|i| (i as f32).cos()).collect();
        let c0: Vec<f32> = (0..batch * m * n).map(|i| i as f32 * 0.1).collect();
        for backend in [Backend::Naive, Backend::Dispatch] {
            let mut c_got = c0.clone();
            let mut c_ref = c0.clone();
            sgemm_batch(
                backend,
                Transpose::No,
                Transpose::No,
                m,
                n,
                k,
                1.25,
                &a,
                k,
                m * k,
                &b,
                n,
                k * n,
                0.5,
                &mut c_got,
                n,
                m * n,
                batch,
            )
            .unwrap();
            for i in 0..batch {
                sgemm(
                    Backend::Naive,
                    Transpose::No,
                    Transpose::No,
                    m,
                    n,
                    k,
                    1.25,
                    &a[i * m * k..],
                    k,
                    &b[i * k * n..],
                    n,
                    0.5,
                    &mut c_ref[i * m * n..],
                    n,
                )
                .unwrap();
            }
            crate::util::testkit::assert_allclose(
                &c_got,
                &c_ref,
                5e-4,
                1e-4,
                &format!("sgemm_batch {}", backend.name()),
            );
        }
    }

    #[test]
    fn sgemm_batch_rejects_overlapping_c() {
        let mut c = vec![0.0f32; 16];
        let err = sgemm_batch(
            Backend::Naive,
            Transpose::No,
            Transpose::No,
            2,
            2,
            2,
            1.0,
            &[0.0; 16],
            2,
            4,
            &[0.0; 16],
            2,
            4,
            0.0,
            &mut c,
            2,
            1, // < item extent 4
            2,
        );
        assert!(matches!(err, Err(BlasError::BadBatchStride { .. })));
    }

    #[test]
    fn sgemm_matrix_wrapper() {
        let a = Matrix::from_fn(3, 2, |i, j| (i + j) as f32);
        let b = Matrix::from_fn(2, 4, |i, j| (i * 4 + j) as f32);
        let mut c = Matrix::zeros(3, 4);
        sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, 1.0, &a, &b, 0.0, &mut c).unwrap();
        // spot check c[1][2] = sum_p a[1][p] * b[p][2] = 1*2 + 2*6 = 14
        assert_eq!(c.get(1, 2), 14.0);
    }

    #[test]
    fn sgemm_matrix_dim_mismatch() {
        let a = Matrix::zeros(3, 2);
        let b = Matrix::zeros(3, 4); // k mismatch: 2 vs 3
        let mut c = Matrix::zeros(3, 4);
        assert!(matches!(
            sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, 1.0, &a, &b, 0.0, &mut c),
            Err(BlasError::DimMismatch { .. })
        ));
    }
}
