//! Level-3 BLAS `SGEMM` public interface.
//!
//! Emmerald implements the `SGEMM` interface of Level-3 BLAS (paper §1) so
//! it can drop into BLAS-based libraries. Since the planned-execution
//! redesign, the positional entry points in this module ([`sgemm`],
//! [`sgemm_batch`], [`sgemm_matrix`] — see [`mod@api`]) are **thin
//! compatibility shims**: each call builds and runs a one-shot
//! [`GemmPlan`] on the shared [`GemmContext`], which owns the kernel
//! registry, the process-wide worker-thread budget and the autotune
//! state. New code with repeated shapes or reusable weight operands
//! should use the planned API directly:
//!
//! ```
//! use emmerald::blas::{GemmContext, Transpose};
//!
//! let ctx = GemmContext::global();
//! let (m, n, k) = (3, 4, 5);
//! let a: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
//! let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32).collect();
//! let mut c = vec![0.0f32; m * n];
//!
//! // Plan once (kernel, geometry and thread split resolved here) ...
//! let plan = ctx.gemm().plan(m, n, k).unwrap();
//! // ... execute many times; pack B once and reuse it across runs.
//! let packed = ctx.pack_b(Transpose::No, k, n, &b, n).unwrap();
//! plan.run(&a, &b, &mut c).unwrap();
//! plan.run_packed_b(&a, &packed, &mut c).unwrap();
//! ```
//!
//! The classic positional call keeps working unchanged:
//!
//! ```
//! use emmerald::blas::{sgemm, Backend, Transpose};
//!
//! // C = alpha * A*B + beta * C  with row-major storage and explicit
//! // leading dimensions (row strides), exactly like the paper's fixed
//! // stride-700 benchmark methodology.
//! let (m, n, k) = (3, 4, 5);
//! let a: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
//! let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32).collect();
//! let mut c = vec![0.0f32; m * n];
//! sgemm(Backend::Auto, Transpose::No, Transpose::No,
//!       m, n, k, 1.0, &a, k, &b, n, 0.0, &mut c, n).unwrap();
//! ```
//!
//! Storage is **row-major** with a leading dimension (`ld*`) giving the
//! distance in elements between consecutive rows; `ld >= cols` of the
//! stored matrix. Transposition is expressed logically via [`Transpose`] —
//! no data is moved.
//!
//! ## Precision / migration note
//!
//! Since the element-generic subsystem ([`crate::gemm::element`]) every
//! entry point also exists in **double precision**: [`dgemm`],
//! [`dgemm_batch`], [`dgemm_matrix`], [`dsyrk_lower`], and
//! `GemmContext::gemm_for::<f64>()` for planned execution. The classic
//! `sgemm*` signatures are unchanged (they are now thin monomorphic
//! shims over the generic [`gemm`]/[`gemm_batch`]/[`gemm_matrix`] — call
//! the generic names from generic code). `Matrix`, `MatRef` and `MatMut`
//! carry an element parameter with `f32` as the default, so existing
//! code compiles and computes bit-identically; `Matrix<f64>` is the
//! DGEMM storage type.
//!
//! Since the kernel-triple refactor there is also a **quantized
//! inference tier** (`u8 × i8 → i32`, exact integers): [`qgemm`] for raw
//! i32 output and [`qgemm_requant`] for the fused dequantizing writeback
//! ([`Requant`]: zero-point correction + scales + bias + activation →
//! f32). It takes no `alpha`/`beta` and no backend argument — integer
//! accumulation is exact and wrapping, so every execution path (scalar,
//! AVX2 `maddubs` tile, parallel, prepacked via
//! [`GemmContext::qpack_b`]) produces identical bits.

pub mod api;
mod backend;
mod error;
pub mod level1;
pub mod level2;
mod matrix;
pub mod syrk;

pub use api::{dgemm, dgemm_batch, dgemm_matrix, gemm, gemm_batch, gemm_matrix, qgemm, qgemm_requant, qgemm_served, sgemm, sgemm_batch, sgemm_matrix, sgemm_served};
pub use backend::{available_backends, Backend};
pub use level1::{isamax, saxpy, sdot, snrm2, sscal};
pub use level2::sgemv;
pub use syrk::{dsyrk_lower, ssyrk_lower, syrk_lower};
pub use error::BlasError;
pub use matrix::{MatMut, MatRef, Matrix};
// The planned-execution API lives in `gemm::plan`; re-exported here
// because it is the public surface most callers should reach for.
pub use crate::gemm::plan::{GemmBuilder, GemmContext, GemmPlan, PackedA, PackedB};
pub use crate::gemm::epilogue::{Activation, Bias, Epilogue, Requant};
pub use crate::gemm::quant::QPackedB;

/// Logical transposition of an operand (`op(X) = X` or `Xᵀ`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transpose {
    /// `op(X) = X`
    No,
    /// `op(X) = Xᵀ`
    Yes,
}

impl Transpose {
    /// Parse from the BLAS character convention: 'n'/'N' (no transpose),
    /// 't'/'T' (transpose), or 'c'/'C' (conjugate transpose — identical
    /// to 'T' for real single precision).
    pub fn from_char(c: char) -> Result<Self, BlasError> {
        match c {
            'n' | 'N' => Ok(Transpose::No),
            't' | 'T' | 'c' | 'C' => Ok(Transpose::Yes),
            other => Err(BlasError::BadTranspose(other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_oracle(
        transa: Transpose,
        transb: Transpose,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: &[f32],
        lda: usize,
        b: &[f32],
        ldb: usize,
        beta: f32,
        c: &mut [f32],
        ldc: usize,
    ) {
        // Independent triple loop written directly against the docs'
        // storage convention, used to validate the public entry point.
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    let av = match transa {
                        Transpose::No => a[i * lda + p],
                        Transpose::Yes => a[p * lda + i],
                    };
                    let bv = match transb {
                        Transpose::No => b[p * ldb + j],
                        Transpose::Yes => b[j * ldb + p],
                    };
                    acc += (av as f64) * (bv as f64);
                }
                c[i * ldc + j] = alpha * acc as f32 + beta * c[i * ldc + j];
            }
        }
    }

    #[test]
    fn sgemm_matches_inline_oracle() {
        let (m, n, k) = (5, 7, 3);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32) * 0.25 - 1.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| 2.0 - (i as f32) * 0.125).collect();
        let mut c1: Vec<f32> = (0..m * n).map(|i| i as f32).collect();
        let mut c2 = c1.clone();
        sgemm(Backend::Naive, Transpose::No, Transpose::No, m, n, k, 1.5, &a, k, &b, n, 0.5, &mut c1, n)
            .unwrap();
        naive_oracle(Transpose::No, Transpose::No, m, n, k, 1.5, &a, k, &b, n, 0.5, &mut c2, n);
        crate::util::testkit::assert_allclose(&c1, &c2, 1e-5, 1e-6, "sgemm vs oracle");
    }

    #[test]
    fn transposed_operands() {
        let (m, n, k) = (4, 3, 6);
        // A stored k×m (transa=Yes), B stored n×k (transb=Yes).
        let a: Vec<f32> = (0..k * m).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..n * k).map(|i| (i as f32).cos()).collect();
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        sgemm(Backend::Naive, Transpose::Yes, Transpose::Yes, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c1, n)
            .unwrap();
        naive_oracle(Transpose::Yes, Transpose::Yes, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c2, n);
        crate::util::testkit::assert_allclose(&c1, &c2, 1e-5, 1e-6, "tt");
    }

    #[test]
    fn strided_storage() {
        // Paper methodology: stride fixed to 700 regardless of row length.
        let (m, n, k) = (3, 4, 2);
        let (lda, ldb, ldc) = (10, 11, 12);
        let a: Vec<f32> = (0..m * lda).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..k * ldb).map(|i| i as f32 * 0.2).collect();
        let mut c1 = vec![7.0f32; m * ldc];
        let mut c2 = c1.clone();
        sgemm(Backend::Naive, Transpose::No, Transpose::No, m, n, k, 1.0, &a, lda, &b, ldb, 2.0, &mut c1, ldc)
            .unwrap();
        naive_oracle(Transpose::No, Transpose::No, m, n, k, 1.0, &a, lda, &b, ldb, 2.0, &mut c2, ldc);
        assert_eq!(c1, c2);
        // Padding between rows untouched.
        assert_eq!(c1[n], 7.0);
    }

    #[test]
    fn k_zero_scales_by_beta() {
        let mut c = vec![2.0f32; 4];
        sgemm(Backend::Naive, Transpose::No, Transpose::No, 2, 2, 0, 1.0, &[], 1, &[], 1, 0.5, &mut c, 2)
            .unwrap();
        assert_eq!(c, vec![1.0; 4]);
    }

    #[test]
    fn m_zero_is_noop() {
        let mut c: Vec<f32> = vec![];
        sgemm(Backend::Naive, Transpose::No, Transpose::No, 0, 5, 3, 1.0, &[], 3, &[1.0; 15], 5, 0.0, &mut c, 5)
            .unwrap();
    }

    #[test]
    fn rejects_bad_ld() {
        let a = vec![0.0f32; 6];
        let b = vec![0.0f32; 6];
        let mut c = vec![0.0f32; 4];
        // lda=1 < k=3 for a 2x3 A.
        let err = sgemm(Backend::Naive, Transpose::No, Transpose::No, 2, 2, 3, 1.0, &a, 1, &b, 2, 0.0, &mut c, 2);
        assert!(matches!(err, Err(BlasError::BadLeadingDim { .. })));
    }

    #[test]
    fn rejects_short_buffer() {
        let a = vec![0.0f32; 5]; // needs 2*3=6
        let b = vec![0.0f32; 6];
        let mut c = vec![0.0f32; 4];
        let err = sgemm(Backend::Naive, Transpose::No, Transpose::No, 2, 2, 3, 1.0, &a, 3, &b, 2, 0.0, &mut c, 2);
        assert!(matches!(err, Err(BlasError::BufferTooSmall { .. })));
    }

    #[test]
    fn transpose_from_char() {
        assert_eq!(Transpose::from_char('n').unwrap(), Transpose::No);
        assert_eq!(Transpose::from_char('N').unwrap(), Transpose::No);
        assert_eq!(Transpose::from_char('t').unwrap(), Transpose::Yes);
        assert_eq!(Transpose::from_char('T').unwrap(), Transpose::Yes);
        assert!(Transpose::from_char('q').is_err());
        assert!(Transpose::from_char(' ').is_err());
    }

    #[test]
    fn transpose_from_char_accepts_conjugate() {
        // BLAS 'C' (conjugate transpose) equals 'T' for real f32.
        assert_eq!(Transpose::from_char('c').unwrap(), Transpose::Yes);
        assert_eq!(Transpose::from_char('C').unwrap(), Transpose::Yes);
    }

    #[test]
    fn conjugate_transpose_computes_like_t() {
        let (m, n, k) = (3usize, 4usize, 5usize);
        let a: Vec<f32> = (0..k * m).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32).cos()).collect();
        let mut c_t = vec![0.0f32; m * n];
        let mut c_c = vec![0.0f32; m * n];
        let tc = Transpose::from_char('C').unwrap();
        sgemm(Backend::Naive, Transpose::Yes, Transpose::No, m, n, k, 1.0, &a, m, &b, n, 0.0, &mut c_t, n)
            .unwrap();
        sgemm(Backend::Naive, tc, Transpose::No, m, n, k, 1.0, &a, m, &b, n, 0.0, &mut c_c, n)
            .unwrap();
        assert_eq!(c_t, c_c);
    }

    #[test]
    fn sgemm_batch_matches_looped_sgemm() {
        let (m, n, k, batch) = (3usize, 4usize, 5usize, 3usize);
        let a: Vec<f32> = (0..batch * m * k).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..batch * k * n).map(|i| (i as f32).cos()).collect();
        let c0: Vec<f32> = (0..batch * m * n).map(|i| i as f32 * 0.1).collect();
        for backend in [Backend::Naive, Backend::Dispatch] {
            let mut c_got = c0.clone();
            let mut c_ref = c0.clone();
            sgemm_batch(
                backend,
                Transpose::No,
                Transpose::No,
                m,
                n,
                k,
                1.25,
                &a,
                k,
                m * k,
                &b,
                n,
                k * n,
                0.5,
                &mut c_got,
                n,
                m * n,
                batch,
            )
            .unwrap();
            for i in 0..batch {
                sgemm(
                    Backend::Naive,
                    Transpose::No,
                    Transpose::No,
                    m,
                    n,
                    k,
                    1.25,
                    &a[i * m * k..],
                    k,
                    &b[i * k * n..],
                    n,
                    0.5,
                    &mut c_ref[i * m * n..],
                    n,
                )
                .unwrap();
            }
            crate::util::testkit::assert_allclose(
                &c_got,
                &c_ref,
                5e-4,
                1e-4,
                &format!("sgemm_batch {}", backend.name()),
            );
        }
    }

    #[test]
    fn sgemm_batch_rejects_overlapping_c() {
        let mut c = vec![0.0f32; 16];
        let err = sgemm_batch(
            Backend::Naive,
            Transpose::No,
            Transpose::No,
            2,
            2,
            2,
            1.0,
            &[0.0; 16],
            2,
            4,
            &[0.0; 16],
            2,
            4,
            0.0,
            &mut c,
            2,
            1, // < item extent 4
            2,
        );
        assert!(matches!(err, Err(BlasError::BadBatchStride { .. })));
    }

    #[test]
    fn qgemm_positional_matches_inline_oracle() {
        let (m, n, k) = (4usize, 5usize, 6usize);
        let a: Vec<u8> = (0..m * k).map(|i| (i * 19 % 256) as u8).collect();
        let b: Vec<i8> = (0..k * n).map(|i| ((i * 23 % 255) as i16 - 127) as i8).collect();
        let mut c = vec![1i32; m * n];
        qgemm(Transpose::No, Transpose::No, m, n, k, &a, k, &b, n, &mut c, n, true).unwrap();
        for i in 0..m {
            for j in 0..n {
                let mut want = 1i32;
                for p in 0..k {
                    want = want.wrapping_add(a[i * k + p] as i32 * b[p * n + j] as i32);
                }
                assert_eq!(c[i * n + j], want, "({i},{j})");
            }
        }
        // Bad leading dimension surfaces with the operand tag.
        let mut c2 = vec![0i32; m * n];
        let err = qgemm(Transpose::No, Transpose::No, m, n, k, &a, 1, &b, n, &mut c2, n, false);
        assert!(matches!(err, Err(BlasError::BadLeadingDim { operand: "A", .. })), "{err:?}");
    }

    #[test]
    fn sgemm_matrix_wrapper() {
        let a = Matrix::from_fn(3, 2, |i, j| (i + j) as f32);
        let b = Matrix::from_fn(2, 4, |i, j| (i * 4 + j) as f32);
        let mut c = Matrix::zeros(3, 4);
        sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, 1.0, &a, &b, 0.0, &mut c).unwrap();
        // spot check c[1][2] = sum_p a[1][p] * b[p][2] = 1*2 + 2*6 = 14
        assert_eq!(c.get(1, 2), 14.0);
    }

    #[test]
    fn sgemm_matrix_dim_mismatch() {
        let a = Matrix::zeros(3, 2);
        let b = Matrix::zeros(3, 4); // k mismatch: 2 vs 3
        let mut c = Matrix::zeros(3, 4);
        assert!(matches!(
            sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, 1.0, &a, &b, 0.0, &mut c),
            Err(BlasError::DimMismatch { .. })
        ));
    }
}
