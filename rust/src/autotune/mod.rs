//! ATLAS-style empirical parameter search.
//!
//! ATLAS — the paper's comparator — is defined by its methodology:
//! *Automatically Tuned* Linear Algebra Software empirically searches the
//! blocking-parameter space on the install machine and keeps the fastest
//! kernel. This module reproduces that methodology over our kernels, both
//! because the baseline deserves a faithful implementation and because it
//! answers the paper's own open question (kb "was determined
//! experimentally"; nr = 5 "gave the best performance"): the
//! `ablation_nr` bench re-runs that experiment.
//!
//! Two rankers are provided: wall-clock measurement (ATLAS's way) and an
//! [`analytic_traffic`] model (PHiPAC's way) that estimates memory traffic
//! per flop from the block geometry — useful as a cross-check and for
//! pruning the search space.

pub mod cache;

use crate::bench::{gemm_flops, Bencher, FlushMode};
use crate::blas::{Matrix, Transpose};
use crate::gemm::dispatch::{DispatchConfig, GemmDispatch};
use crate::gemm::{
    avx2, blocked, quant, simd, tile, BlockParams, ElementId, FastAlgoId, FastmmChoice,
    FastmmTable, KernelId, ShapeClass, TileParams, TripleId, Unroll,
};

/// Which kernel family to tune.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneKernel {
    /// Emmerald SSE (f32).
    Sse,
    /// Emmerald AVX2 (f32, if available).
    Avx2,
    /// ATLAS-proxy scalar kernel (f32).
    Blocked,
    /// Emmerald AVX2 in f64 — the DGEMM dot tier (`emmerald autotune
    /// --element f64 --kernel avx2`).
    Avx2F64,
}

impl TuneKernel {
    /// One probe GEMM through the kernel family under tune, in any
    /// element precision (the drivers are element-generic; the variant
    /// only picks the family).
    fn run<T: crate::gemm::Element>(&self, p: &BlockParams, a: &Matrix<T>, b: &Matrix<T>, c: &mut Matrix<T>) {
        let mut cv = c.view_mut();
        match self {
            TuneKernel::Sse => {
                simd::gemm(p, Transpose::No, Transpose::No, T::ONE, a.view(), b.view(), T::ZERO, &mut cv)
            }
            TuneKernel::Avx2 | TuneKernel::Avx2F64 => {
                avx2::gemm(p, Transpose::No, Transpose::No, T::ONE, a.view(), b.view(), T::ZERO, &mut cv)
            }
            TuneKernel::Blocked => {
                blocked::gemm(p, Transpose::No, Transpose::No, T::ONE, a.view(), b.view(), T::ZERO, &mut cv)
            }
        }
    }

    /// Which element this search probes (and which table the winner is
    /// installed into).
    pub fn element(&self) -> ElementId {
        match self {
            TuneKernel::Avx2F64 => ElementId::F64,
            _ => ElementId::F32,
        }
    }
}

/// Search-space specification.
#[derive(Clone, Debug)]
pub struct TuneSpec {
    /// Kernel family under tuning.
    pub kernel: TuneKernel,
    /// Probe problem size (m = n = k); ATLAS tunes at an L2-busting size.
    pub probe_size: usize,
    /// Timing samples per candidate (median taken).
    pub samples: usize,
    /// Candidate k-block depths.
    pub kbs: Vec<usize>,
    /// Candidate row blocks.
    pub mbs: Vec<usize>,
    /// Candidate inner-loop dot-product counts.
    pub nrs: Vec<usize>,
    /// Candidate unroll factors.
    pub unrolls: Vec<Unroll>,
}

impl TuneSpec {
    /// The default grid for the Emmerald SSE kernel (25-ish candidates
    /// around the paper's operating point, like ATLAS's pruned search).
    pub fn sse_default(probe_size: usize) -> Self {
        Self {
            kernel: TuneKernel::Sse,
            probe_size,
            samples: 3,
            kbs: vec![128, 224, 336, 448, 672],
            mbs: vec![64, 128, 256],
            nrs: vec![4, 5, 6],
            unrolls: vec![Unroll::X4],
        }
    }

    /// Grid for the scalar ATLAS proxy.
    pub fn blocked_default(probe_size: usize) -> Self {
        Self {
            kernel: TuneKernel::Blocked,
            probe_size,
            samples: 3,
            kbs: vec![128, 256, 336, 512],
            mbs: vec![64, 128, 256],
            nrs: vec![2], // the scalar tile is fixed at 2×2
            unrolls: vec![Unroll::X2],
        }
    }

    /// All candidate parameter sets.
    pub fn candidates(&self) -> Vec<BlockParams> {
        let mut out = Vec::new();
        for &kb in &self.kbs {
            for &mb in &self.mbs {
                for &nr in &self.nrs {
                    for &unroll in &self.unrolls {
                        out.push(BlockParams {
                            kb,
                            mb,
                            nr,
                            unroll,
                            ..BlockParams::emmerald_sse()
                        });
                    }
                }
            }
        }
        out
    }
}

/// One measured candidate.
#[derive(Clone, Debug)]
pub struct TunePoint {
    /// The parameters measured.
    pub params: BlockParams,
    /// Median MFlop/s.
    pub mflops: f64,
}

/// Search outcome: the winner plus the full log (for the ablation bench).
#[derive(Clone, Debug)]
pub struct TuneResult {
    /// Fastest parameters found.
    pub best: BlockParams,
    /// MFlop/s of the winner.
    pub best_mflops: f64,
    /// Every candidate with its measured rate, in search order.
    pub log: Vec<TunePoint>,
}

/// Map a tuned kernel family onto its dispatch-registry identity.
impl TuneKernel {
    /// The [`KernelId`] whose geometry this search tunes.
    pub fn kernel_id(&self) -> crate::gemm::KernelId {
        match self {
            TuneKernel::Sse => crate::gemm::KernelId::Simd,
            TuneKernel::Avx2 | TuneKernel::Avx2F64 => crate::gemm::KernelId::Avx2,
            TuneKernel::Blocked => crate::gemm::KernelId::Blocked,
        }
    }
}

/// Run the empirical search and install the winner into the process-wide
/// [`crate::gemm::dispatch`] heuristic table, so every subsequent
/// [`crate::blas::Backend::Dispatch`] call runs the tuned geometry —
/// ATLAS's install-time loop feeding the production hot path.
///
/// Use [`tune_install_and_persist`] to additionally record the winner in
/// the on-disk cache for future processes.
pub fn tune_and_install(spec: &TuneSpec) -> TuneResult {
    let result = tune(spec);
    crate::gemm::dispatch::install_tuned_for(spec.kernel.element(), spec.kernel.kernel_id(), result.best)
        .expect("tuned parameters come from a validated candidate grid");
    result
}

/// As [`tune_and_install`], and also persist the winner to the on-disk
/// cache (see [`cache`]) so future processes on this machine start tuned.
/// Returns the cache path written, if persistence is enabled and the
/// write succeeded (the cache is best-effort and never fails tuning).
pub fn tune_install_and_persist(spec: &TuneSpec) -> (TuneResult, Option<std::path::PathBuf>) {
    let result = tune_and_install(spec);
    let path = cache::save_host_entry(spec.kernel.element(), spec.kernel.kernel_id(), &result.best);
    (result, path)
}

/// Run the empirical search (ATLAS's install-time loop).
pub fn tune(spec: &TuneSpec) -> TuneResult {
    match spec.kernel.element() {
        ElementId::F32 => tune_probe::<f32>(spec),
        ElementId::F64 => tune_probe::<f64>(spec),
    }
}

/// The search loop proper, monomorphised per probed element (operands
/// are allocated in the element under tune only).
fn tune_probe<T: crate::gemm::Element>(spec: &TuneSpec) -> TuneResult {
    let n = spec.probe_size;
    let flops = gemm_flops(n, n, n);
    let (lo, hi) = (T::from_f64(-1.0), T::from_f64(1.0));
    let a = Matrix::<T>::random(n, n, 0xA77A5, lo, hi);
    let b = Matrix::<T>::random(n, n, 0xB00B5, lo, hi);
    let mut c = Matrix::<T>::zeros(n, n);

    let mut log = Vec::new();
    let mut best: Option<TunePoint> = None;
    for params in spec.candidates() {
        let mut bencher =
            Bencher::new(1, spec.samples).flush_mode(FlushMode::Warm).min_sample_secs(0.01);
        let r = bencher.run("candidate", flops, || {
            spec.kernel.run(&params, &a, &b, &mut c);
        });
        let point = TunePoint { params, mflops: r.mflops() };
        if best.as_ref().map(|b| point.mflops > b.mflops).unwrap_or(true) {
            best = Some(point.clone());
        }
        log.push(point);
    }
    let best = best.expect("nonempty candidate grid");
    TuneResult { best: best.params, best_mflops: best.mflops, log }
}

/// Search space for the outer-product tile tier ([`crate::gemm::tile`]).
/// The tile's geometry is (MR, kc, mc, nc) — NR is pinned by the ISA —
/// so it gets its own spec rather than abusing [`TuneSpec`]'s dot-kernel
/// fields.
#[derive(Clone, Debug)]
pub struct TileTuneSpec {
    /// Element precision under tune (picks the 6×16 f32 or 6×8 f64
    /// kernel family and the dispatch table the winner lands in).
    pub element: ElementId,
    /// Probe problem size (m = n = k).
    pub probe_size: usize,
    /// Timing samples per candidate (median taken).
    pub samples: usize,
    /// Candidate tile heights (MR).
    pub mrs: Vec<usize>,
    /// Candidate k-block depths.
    pub kcs: Vec<usize>,
    /// Candidate row-block heights (rounded up to a multiple of each MR).
    pub mcs: Vec<usize>,
    /// Candidate column-block widths (must be multiples of NR).
    pub ncs: Vec<usize>,
}

impl TileTuneSpec {
    /// The default pruned grid around the 6×16 operating point.
    pub fn avx2_default(probe_size: usize) -> Self {
        Self {
            element: ElementId::F32,
            probe_size,
            samples: 3,
            mrs: vec![4, 6],
            kcs: vec![128, 256, 384],
            mcs: vec![48, 72, 120],
            ncs: vec![256, 480, 960],
        }
    }

    /// The default pruned f64 grid around the 6×8 operating point (same
    /// cache footprints as the f32 grid — elements twice as wide, panels
    /// half as many columns).
    pub fn avx2_f64_default(probe_size: usize) -> Self {
        Self { element: ElementId::F64, ..Self::avx2_default(probe_size) }
    }

    /// The element's base geometry (fixes NR).
    fn base(&self) -> TileParams {
        match self.element {
            ElementId::F32 => TileParams::avx2_6x16(),
            ElementId::F64 => TileParams::avx2_6x8_f64(),
        }
    }

    /// All candidate parameter sets (mc snapped up to a multiple of mr,
    /// nc to a multiple of the element's NR, deduplicated).
    pub fn candidates(&self) -> Vec<TileParams> {
        let base = self.base();
        let mut out: Vec<TileParams> = Vec::new();
        for &mr in &self.mrs {
            for &kc in &self.kcs {
                for &mc in &self.mcs {
                    for &nc in &self.ncs {
                        let p = TileParams {
                            mr,
                            mc: mc.div_ceil(mr) * mr,
                            kc,
                            nc: nc.div_ceil(base.nr) * base.nr,
                            ..base
                        };
                        if p.validate().is_ok() && !out.contains(&p) {
                            out.push(p);
                        }
                    }
                }
            }
        }
        out
    }
}

/// One measured tile candidate.
#[derive(Clone, Debug)]
pub struct TileTunePoint {
    /// The parameters measured.
    pub params: TileParams,
    /// Median MFlop/s.
    pub mflops: f64,
}

/// Tile search outcome.
#[derive(Clone, Debug)]
pub struct TileTuneResult {
    /// Fastest parameters found.
    pub best: TileParams,
    /// MFlop/s of the winner.
    pub best_mflops: f64,
    /// Every candidate with its measured rate, in search order.
    pub log: Vec<TileTunePoint>,
}

/// Run the empirical tile search (same methodology as [`tune`], over the
/// tile tier's geometry, in the spec's element precision).
pub fn tune_tile(spec: &TileTuneSpec) -> TileTuneResult {
    match spec.element {
        ElementId::F32 => tune_tile_probe::<f32>(spec),
        ElementId::F64 => tune_tile_probe::<f64>(spec),
    }
}

/// The tile search loop proper, monomorphised per probed element.
fn tune_tile_probe<T: crate::gemm::Element>(spec: &TileTuneSpec) -> TileTuneResult {
    let n = spec.probe_size;
    let flops = gemm_flops(n, n, n);
    let (lo, hi) = (T::from_f64(-1.0), T::from_f64(1.0));
    let a = Matrix::<T>::random(n, n, 0xA77A5, lo, hi);
    let b = Matrix::<T>::random(n, n, 0xB00B5, lo, hi);
    let mut c = Matrix::<T>::zeros(n, n);

    let mut log = Vec::new();
    let mut best: Option<TileTunePoint> = None;
    for params in spec.candidates() {
        let mut bencher =
            Bencher::new(1, spec.samples).flush_mode(FlushMode::Warm).min_sample_secs(0.01);
        let r = bencher.run("tile candidate", flops, || {
            tile::gemm(&params, Transpose::No, Transpose::No, T::ONE, a.view(), b.view(), T::ZERO, &mut c.view_mut());
        });
        let point = TileTunePoint { params, mflops: r.mflops() };
        if best.as_ref().map(|b| point.mflops > b.mflops).unwrap_or(true) {
            best = Some(point.clone());
        }
        log.push(point);
    }
    let best = best.expect("nonempty tile candidate grid");
    TileTuneResult { best: best.params, best_mflops: best.mflops, log }
}

/// Run the tile search and install the winner into the process-wide
/// dispatcher (freshly packed operands pick up the new layout).
pub fn tune_tile_and_install(spec: &TileTuneSpec) -> TileTuneResult {
    let result = tune_tile(spec);
    crate::gemm::dispatch::install_tuned_tile_for(spec.element, result.best)
        .expect("tile winner comes from a validated candidate grid");
    result
}

/// As [`tune_tile_and_install`], also persisting the winner to the
/// on-disk cache. Returns the cache path written, if any.
pub fn tune_tile_install_and_persist(spec: &TileTuneSpec) -> (TileTuneResult, Option<std::path::PathBuf>) {
    let result = tune_tile_and_install(spec);
    let path = cache::save_host_tile_entry(spec.element.triple(), &result.best);
    (result, path)
}

/// Probe plan for the fast-matmul selection measurement: which
/// (element, shape class) cell to tune, the scale sweep (ascending), the
/// candidate algorithms and the recursion crossover used while probing —
/// the old `strassen_crossover` bench's methodology generalised to the
/// per-shape, per-element [`crate::gemm::fastmm`] framework.
#[derive(Clone, Debug)]
pub struct FastmmSpec {
    /// Element precision under tune.
    pub element: ElementId,
    /// Shape class under tune (fixes the probe aspect ratio).
    pub class: ShapeClass,
    /// Sweep scales, ascending (the largest problem dimension).
    pub sizes: Vec<usize>,
    /// Timing samples per point (median taken).
    pub samples: usize,
    /// Candidate base-case factorizations.
    pub algos: Vec<FastAlgoId>,
    /// Recursion cutoff probed (and installed with the winner).
    pub crossover: usize,
}

impl FastmmSpec {
    /// The default sweep for one (element, class) cell.
    pub fn default_for(element: ElementId, class: ShapeClass) -> Self {
        Self {
            element,
            class,
            sizes: vec![256, 512, 768, 1024],
            samples: 3,
            algos: FastAlgoId::ALL.to_vec(),
            crossover: crate::gemm::fastmm::DEFAULT_CROSSOVER,
        }
    }

    /// The probe `(m, n, k)` at one sweep scale: square, wide-output
    /// (`k` a quarter of the output edge) or deep (`k` dominating).
    pub fn shape(&self, n: usize) -> (usize, usize, usize) {
        match self.class {
            ShapeClass::Square => (n, n, n),
            ShapeClass::Flat => (n, n, (n / 4).max(1)),
            ShapeClass::Deep => ((n / 4).max(1), (n / 4).max(1), n),
        }
    }
}

/// One measured sweep point: classical-tier vs fast-tier rates for one
/// algorithm, both in *classic* (2mnk) effective MFlop/s, directly
/// comparable.
#[derive(Clone, Debug)]
pub struct FastmmPoint {
    /// Sweep scale (largest problem dimension).
    pub size: usize,
    /// Algorithm measured at this point.
    pub algo: FastAlgoId,
    /// Classical (parallel-tile) rate.
    pub classical_mflops: f64,
    /// Fast-tier effective rate.
    pub fast_mflops: f64,
}

/// Fast-matmul measurement outcome for one (element, class) cell.
#[derive(Clone, Debug)]
pub struct FastmmResult {
    /// The cell tuned.
    pub element: ElementId,
    /// The shape class tuned.
    pub class: ShapeClass,
    /// The derived choice: the algorithm whose trailing-win run starts
    /// earliest (ties broken by the higher rate at the sweep top), its
    /// `min_dim` at the start of that run — or twice the largest probed
    /// scale when no algorithm won at the top of the sweep (the
    /// crossover, if it exists, lies beyond it).
    pub choice: FastmmChoice,
    /// Whether any fast algorithm actually won inside the sweep.
    pub observed: bool,
    /// Every measured point, in (algorithm, sweep) order.
    pub log: Vec<FastmmPoint>,
}

/// Measure where each fast algorithm starts beating the classical tier
/// for one (element, shape class) cell and derive the [`FastmmChoice`]
/// to install. Both sides run through the same dispatcher entry
/// ([`GemmDispatch::gemm_with`]) on the process pool, so the comparison
/// is end-to-end, packing and scheduling included.
pub fn tune_fastmm(spec: &FastmmSpec) -> FastmmResult {
    match spec.element {
        ElementId::F32 => tune_fastmm_probe::<f32>(spec),
        ElementId::F64 => tune_fastmm_probe::<f64>(spec),
    }
}

/// The sweep loop proper, monomorphised per probed element.
fn tune_fastmm_probe<T: crate::gemm::Element>(spec: &FastmmSpec) -> FastmmResult {
    assert!(!spec.sizes.is_empty(), "fastmm sweep needs at least one size");
    assert!(!spec.algos.is_empty(), "fastmm sweep needs at least one algorithm");
    let classical = GemmDispatch::new(DispatchConfig::default());
    let (lo, hi) = (T::from_f64(-1.0), T::from_f64(1.0));
    let mut log = Vec::new();
    // (start of trailing-win run, rate at sweep top) per algorithm.
    let mut winners: Vec<(FastAlgoId, Option<usize>, f64)> = Vec::new();
    for &algo in &spec.algos {
        let forced = DispatchConfig {
            fastmm: FastmmTable::uniform(FastmmChoice {
                algo,
                crossover: spec.crossover,
                min_dim: 1,
            }),
            ..DispatchConfig::default()
        };
        let fast_d = GemmDispatch::new(forced);
        let mut algo_log = Vec::new();
        for &n in &spec.sizes {
            let (m, nn, k) = spec.shape(n);
            let a = Matrix::<T>::random(m, k, 1, lo, hi);
            let b = Matrix::<T>::random(k, nn, 2, lo, hi);
            let classic = gemm_flops(m, nn, k);
            let mut c = Matrix::<T>::zeros(m, nn);
            let mut bencher =
                Bencher::new(1, spec.samples).flush_mode(FlushMode::Warm).min_sample_secs(0.02);
            let flat = bencher
                .run("classical", classic, || {
                    classical.gemm_with(
                        KernelId::Parallel,
                        Transpose::No,
                        Transpose::No,
                        T::ONE,
                        a.view(),
                        b.view(),
                        T::ZERO,
                        &mut c.view_mut(),
                    );
                })
                .mflops();
            let mut bencher =
                Bencher::new(1, spec.samples).flush_mode(FlushMode::Warm).min_sample_secs(0.02);
            let fast = bencher
                .run("fastmm", classic, || {
                    fast_d.gemm_with(
                        KernelId::FastMm,
                        Transpose::No,
                        Transpose::No,
                        T::ONE,
                        a.view(),
                        b.view(),
                        T::ZERO,
                        &mut c.view_mut(),
                    );
                })
                .mflops();
            algo_log.push(FastmmPoint { size: n, algo, classical_mflops: flat, fast_mflops: fast });
        }
        // The install threshold is the start of the *trailing* run of
        // fast-tier wins: a single noisy win below scales where the
        // classical tier still clearly dominates must not become the
        // permanent routing threshold.
        let mut min_dim = None;
        for point in algo_log.iter().rev() {
            if point.fast_mflops > point.classical_mflops {
                min_dim = Some(point.size);
            } else {
                break;
            }
        }
        let top_rate = algo_log.last().map(|p| p.fast_mflops).unwrap_or(0.0);
        winners.push((algo, min_dim, top_rate));
        log.extend(algo_log);
    }
    // Prefer the algorithm that wins earliest; among equals (including
    // "never won"), the one fastest at the top of the sweep.
    let best = winners
        .iter()
        .min_by(|a, b| {
            let ka = a.1.unwrap_or(usize::MAX);
            let kb = b.1.unwrap_or(usize::MAX);
            ka.cmp(&kb).then(b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal))
        })
        .expect("nonempty algorithm list");
    let observed = best.1.is_some();
    FastmmResult {
        element: spec.element,
        class: spec.class,
        choice: FastmmChoice {
            algo: best.0,
            crossover: spec.crossover,
            min_dim: best.1.unwrap_or(spec.sizes.last().unwrap() * 2),
        },
        observed,
        log,
    }
}

/// Measure one (element, class) cell, install the derived choice into
/// the process-wide dispatcher and persist it in the tuned cache (like
/// block sizes). Returns the result and the cache path written, if any.
pub fn tune_fastmm_install_and_persist(
    spec: &FastmmSpec,
) -> (FastmmResult, Option<std::path::PathBuf>) {
    let result = tune_fastmm(spec);
    crate::gemm::plan::GemmContext::global()
        .install_fastmm_choice(spec.element, spec.class, result.choice)
        .expect("derived fastmm choice has positive thresholds");
    let path = cache::save_host_fastmm_entry(spec.element, spec.class, &result.choice);
    (result, path)
}

/// Search space for the quantized `maddubs` tile
/// ([`crate::gemm::quant`]). Geometry is (MR, kc, mc) — NR is pinned by
/// the two-YMM accumulator layout, and nc is irrelevant (B is packed
/// whole-width) — and any candidate produces identical bits, so this is
/// a pure wall-clock race like the float tile search.
#[derive(Clone, Debug)]
pub struct QTileTuneSpec {
    /// Probe problem size (m = n = k).
    pub probe_size: usize,
    /// Timing samples per candidate (median taken).
    pub samples: usize,
    /// Candidate strip heights (MR).
    pub mrs: Vec<usize>,
    /// Candidate k-chunk depths (snapped down to whole 4-k groups).
    pub kcs: Vec<usize>,
    /// Candidate row-block heights (snapped up to a multiple of each MR).
    pub mcs: Vec<usize>,
}

impl QTileTuneSpec {
    /// The default pruned grid around the PR-8 operating point
    /// (mr 6, whole-k, 96-row blocks).
    pub fn avx2_default(probe_size: usize) -> Self {
        Self {
            probe_size,
            samples: 3,
            mrs: vec![4, 6],
            kcs: vec![512, 1024, 4096],
            mcs: vec![48, 96, 192],
        }
    }

    /// All candidate parameter sets (deduplicated, each validating).
    pub fn candidates(&self) -> Vec<TileParams> {
        let base = TileParams::qtile_default();
        let mut out: Vec<TileParams> = Vec::new();
        for &mr in &self.mrs {
            for &kc in &self.kcs {
                for &mc in &self.mcs {
                    let p = TileParams { mr, kc, mc: mc.div_ceil(mr) * mr, ..base };
                    if p.validate().is_ok() && !out.contains(&p) {
                        out.push(p);
                    }
                }
            }
        }
        out
    }
}

/// One measured quantized-tile candidate.
#[derive(Clone, Debug)]
pub struct QTileTunePoint {
    /// The parameters measured.
    pub params: TileParams,
    /// Median effective MFlop/s (2mnk integer macs counted as 2 ops).
    pub mflops: f64,
}

/// Quantized-tile search outcome.
#[derive(Clone, Debug)]
pub struct QTileTuneResult {
    /// Fastest parameters found.
    pub best: TileParams,
    /// MFlop/s of the winner.
    pub best_mflops: f64,
    /// Every candidate with its measured rate, in search order.
    pub log: Vec<QTileTunePoint>,
}

/// Run the empirical quantized-tile search (same methodology as
/// [`tune_tile`], over the `u8 × i8 → i32` driver with a prepacked B).
pub fn tune_qtile(spec: &QTileTuneSpec) -> QTileTuneResult {
    let n = spec.probe_size;
    let flops = gemm_flops(n, n, n);
    // Fixed pseudo-random operands; B avoids −128 so the AVX2 path (the
    // one under tune) is actually exercised.
    let a = Matrix::<u8>::from_fn(n, n, |r, c| ((r * 31 + c * 7) % 251) as u8);
    let b = Matrix::<i8>::from_fn(n, n, |r, c| (((r * 13 + c * 5) % 240) as i32 - 120) as i8);
    let pb = quant::QPackedB::pack(b.view(), Transpose::No, n, n);
    let mut c = Matrix::<i32>::zeros(n, n);
    let mut log = Vec::new();
    let mut best: Option<QTileTunePoint> = None;
    for params in spec.candidates() {
        let mut bencher =
            Bencher::new(1, spec.samples).flush_mode(FlushMode::Warm).min_sample_secs(0.01);
        let r = bencher.run("qtile candidate", flops, || {
            quant::qgemm_packed(a.view(), Transpose::No, &pb, &params, &mut c.view_mut(), false);
        });
        let point = QTileTunePoint { params, mflops: r.mflops() };
        if best.as_ref().map(|b| point.mflops > b.mflops).unwrap_or(true) {
            best = Some(point.clone());
        }
        log.push(point);
    }
    let best = best.expect("nonempty qtile candidate grid");
    QTileTuneResult { best: best.params, best_mflops: best.mflops, log }
}

/// Run the quantized-tile search and install the winner into the
/// process-wide dispatcher.
pub fn tune_qtile_and_install(spec: &QTileTuneSpec) -> QTileTuneResult {
    let result = tune_qtile(spec);
    crate::gemm::plan::GemmContext::global()
        .install_tuned_qtile(result.best)
        .expect("qtile winner comes from a validated candidate grid");
    result
}

/// As [`tune_qtile_and_install`], also persisting the winner to the
/// on-disk cache under the `u8i8i32` triple. Returns the cache path
/// written, if any.
pub fn tune_qtile_install_and_persist(
    spec: &QTileTuneSpec,
) -> (QTileTuneResult, Option<std::path::PathBuf>) {
    let result = tune_qtile_and_install(spec);
    let path = cache::save_host_tile_entry(TripleId::QU8I8, &result.best);
    (result, path)
}

/// PHiPAC-style analytic model: estimated memory-hierarchy traffic in
/// bytes per useful flop for an `n × n × n` problem, given an L1 budget.
///
/// Counts, per k-block: B packed once (`read + write`), the packed panel
/// re-streamed per row block, A streamed once per panel pass, C touched
/// once. Panels that overflow the L1 budget are charged an L1-spill
/// factor. Lower is better; the empirical winner should rank near the
/// analytic top (tested below, and reported by the `autotune` example).
pub fn analytic_traffic(p: &BlockParams, n: usize, l1_bytes: usize) -> f64 {
    let nf = n as f64;
    let kb = p.kb.min(n) as f64;
    let mb = p.mb.min(n) as f64;
    let nr = p.nr as f64;
    let elem = 4.0;

    // Panel bytes in L1: kb × nr plus the streaming A row chunk.
    let panel_bytes = kb * nr * elem + kb * elem;
    let spill = if panel_bytes > l1_bytes as f64 { 4.0 } else { 1.0 };

    let kblocks = (nf / kb).ceil();
    // B: packed once per k-block (read strided + write packed).
    let b_traffic = 2.0 * nf * nf * elem;
    // Packed panels: re-read once per row-block per k-block.
    let row_blocks = (nf / mb).ceil();
    let panel_traffic = row_blocks * nf * kb * kblocks * elem * spill / row_blocks.max(1.0);
    // A: streamed once per panel column-group.
    let panel_count = (nf / nr).ceil();
    let a_traffic_per_kblock = if mb * kb * elem <= 256.0 * 1024.0 {
        // A block resident in L2: read once per k-block.
        nf * kb * elem
    } else {
        // Re-streamed per panel.
        nf * kb * elem * panel_count.min(8.0)
    };
    let a_traffic = a_traffic_per_kblock * kblocks;
    // C: read+write once per k-block.
    let c_traffic = 2.0 * nf * nf * elem * kblocks;

    let flops = 2.0 * nf * nf * nf;
    (b_traffic + panel_traffic + a_traffic + c_traffic) / flops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_grid_size() {
        let spec = TuneSpec::sse_default(64);
        assert_eq!(spec.candidates().len(), 5 * 3 * 3);
    }

    #[test]
    fn tune_returns_a_winner_from_the_grid() {
        // Tiny grid + tiny probe so the test is fast.
        let spec = TuneSpec {
            kernel: TuneKernel::Sse,
            probe_size: 96,
            samples: 1,
            kbs: vec![32, 96],
            mbs: vec![32],
            nrs: vec![2, 5],
            unrolls: vec![Unroll::X2],
        };
        let r = tune(&spec);
        assert_eq!(r.log.len(), 4);
        assert!(r.best_mflops > 0.0);
        assert!(r.log.iter().all(|p| p.mflops <= r.best_mflops));
        assert!(spec.candidates().contains(&r.best));
    }

    #[test]
    fn tuned_blocked_also_works() {
        let spec = TuneSpec {
            probe_size: 64,
            samples: 1,
            kbs: vec![64],
            mbs: vec![32, 64],
            ..TuneSpec::blocked_default(64)
        };
        let r = tune(&spec);
        assert_eq!(r.log.len(), 2);
    }

    #[test]
    fn tune_and_install_feeds_the_global_dispatcher() {
        use crate::gemm::dispatch::{global_snapshot, install_tuned};
        // This test mutates process-global state; any candidate geometry
        // is *correct* for concurrent tests (only performance differs),
        // and the prior geometry is restored below to keep the suite
        // order-independent.
        let before = *global_snapshot().params_sse();
        let spec = TuneSpec {
            kernel: TuneKernel::Sse,
            probe_size: 64,
            samples: 1,
            kbs: vec![48],
            mbs: vec![24],
            nrs: vec![5],
            unrolls: vec![Unroll::X2],
        };
        let r = tune_and_install(&spec);
        assert_eq!(r.best.kb, 48);
        let snap = global_snapshot();
        assert_eq!(snap.params_sse(), &r.best, "winner must land in the dispatch table");
        assert_eq!(spec.kernel.kernel_id(), crate::gemm::KernelId::Simd);
        install_tuned(crate::gemm::KernelId::Simd, before).expect("restore prior geometry");
    }

    #[test]
    fn tile_candidates_align_and_dedupe() {
        let spec = TileTuneSpec::avx2_default(64);
        let cands = spec.candidates();
        assert!(!cands.is_empty());
        for p in &cands {
            assert!(p.validate().is_ok(), "candidate {p:?} must validate");
            assert_eq!(p.mc % p.mr, 0);
        }
        // mc = 48/72/120 are multiples of both 4 and 6, so the snapped
        // grid has no duplicates: 2 * 3 * 3 * 3 candidates.
        assert_eq!(cands.len(), 2 * 3 * 3 * 3);
    }

    #[test]
    fn tune_tile_returns_a_winner_from_the_grid() {
        let spec = TileTuneSpec {
            element: ElementId::F32,
            probe_size: 64,
            samples: 1,
            mrs: vec![2, 6],
            kcs: vec![32],
            mcs: vec![12],
            ncs: vec![32],
        };
        let r = tune_tile(&spec);
        assert_eq!(r.log.len(), 2);
        assert!(r.best_mflops > 0.0);
        assert!(spec.candidates().contains(&r.best));
    }

    #[test]
    fn tune_tile_f64_probes_the_6x8_family() {
        let spec = TileTuneSpec {
            element: ElementId::F64,
            probe_size: 48,
            samples: 1,
            mrs: vec![2, 6],
            kcs: vec![32],
            mcs: vec![12],
            ncs: vec![16],
        };
        let cands = spec.candidates();
        assert!(cands.iter().all(|p| p.nr == 8), "f64 candidates carry nr = 8");
        let r = tune_tile(&spec);
        assert_eq!(r.log.len(), cands.len());
        assert!(r.best_mflops > 0.0);
        assert_eq!(r.best.nr, 8);
    }

    #[test]
    fn tune_f64_dot_kernel_runs_and_installs() {
        if !crate::gemm::dispatch::detect_avx2() {
            eprintln!("SKIP: no AVX2+FMA — the f64 dot kernel has no probe target");
            return;
        }
        crate::util::testkit::hermetic_tune_cache();
        let spec = TuneSpec {
            kernel: TuneKernel::Avx2F64,
            probe_size: 64,
            samples: 1,
            kbs: vec![48, 96],
            mbs: vec![24],
            nrs: vec![5],
            unrolls: vec![Unroll::X2],
        };
        assert_eq!(spec.kernel.element(), ElementId::F64);
        let r = tune_and_install(&spec);
        assert_eq!(r.log.len(), 2);
        let snap = crate::gemm::dispatch::global_snapshot();
        assert_eq!(snap.params_avx2_f64(), &r.best, "winner must land in the f64 table");
        // Restore the default so the suite stays order-independent.
        crate::gemm::dispatch::install_tuned_for(
            ElementId::F64,
            crate::gemm::KernelId::Avx2,
            BlockParams::emmerald_avx2(),
        )
        .unwrap();
    }

    #[test]
    fn fastmm_sweep_derives_a_choice() {
        // A tiny sweep (scales far below any real crossover): the
        // derived min_dim must be one of the probed scales or the
        // 2×-beyond fallback, the winning algorithm must come from the
        // candidate list, and the log must carry both rates for every
        // (algorithm, scale) pair.
        let spec = FastmmSpec {
            sizes: vec![48, 64],
            samples: 1,
            crossover: 32,
            ..FastmmSpec::default_for(ElementId::F32, ShapeClass::Square)
        };
        let r = tune_fastmm(&spec);
        assert_eq!(r.log.len(), 2 * FastAlgoId::ALL.len());
        assert!(r.log.iter().all(|p| p.classical_mflops > 0.0 && p.fast_mflops > 0.0));
        assert!(spec.algos.contains(&r.choice.algo));
        assert_eq!(r.choice.crossover, 32);
        if r.observed {
            assert!(spec.sizes.contains(&r.choice.min_dim));
        } else {
            assert_eq!(r.choice.min_dim, 128);
        }
    }

    #[test]
    fn fastmm_spec_shapes_land_in_their_class() {
        for class in ShapeClass::ALL {
            let spec = FastmmSpec::default_for(ElementId::F64, class);
            for &n in &[64usize, 256, 1024] {
                let (m, nn, k) = spec.shape(n);
                assert_eq!(ShapeClass::of(m, nn, k), class, "scale {n}");
            }
        }
    }

    #[test]
    fn qtile_candidates_align_and_dedupe() {
        let spec = QTileTuneSpec::avx2_default(64);
        let cands = spec.candidates();
        assert!(!cands.is_empty());
        for p in &cands {
            assert!(p.validate().is_ok(), "candidate {p:?} must validate");
            assert_eq!(p.mc % p.mr, 0);
            assert_eq!(p.nr, 16, "qtile NR is pinned by the kernel");
        }
        // mc 48/96/192 are multiples of both 4 and 6: no duplicates.
        assert_eq!(cands.len(), 2 * 3 * 3);
    }

    #[test]
    fn tune_qtile_returns_a_winner_from_the_grid() {
        let spec = QTileTuneSpec {
            probe_size: 64,
            samples: 1,
            mrs: vec![3, 6],
            kcs: vec![32],
            mcs: vec![24],
        };
        let r = tune_qtile(&spec);
        assert_eq!(r.log.len(), 2);
        assert!(r.best_mflops > 0.0);
        assert!(spec.candidates().contains(&r.best));
    }

    #[test]
    fn analytic_model_prefers_l1_resident_panels() {
        // A panel that blows L1 must cost more than the paper's geometry.
        let good = BlockParams::emmerald_piii(); // 336×5 ≈ 6.7 KB
        let bad = BlockParams { kb: 2048, nr: 8, ..good }; // 64 KB panel
        let l1 = 16 * 1024;
        assert!(
            analytic_traffic(&good, 512, l1) < analytic_traffic(&bad, 512, l1),
            "L1-resident panel should win the analytic ranking"
        );
    }

    #[test]
    fn analytic_model_penalises_tiny_kb() {
        // kb=8 means C is re-touched n/8 times: traffic explodes.
        let good = BlockParams::emmerald_piii();
        let tiny = BlockParams { kb: 8, ..good };
        assert!(analytic_traffic(&good, 512, 16 * 1024) < analytic_traffic(&tiny, 512, 16 * 1024));
    }
}
