//! Persistent autotune cache: tuned [`BlockParams`] per (CPU model,
//! kernel), serialised to disk ATLAS-install style.
//!
//! [`super::tune_and_install`] feeds the in-process dispatch table, but
//! winners used to die with the process. This module persists them as
//! JSON (via [`crate::util::json`]) so the next process starts with the
//! machine's tuned geometry: [`crate::gemm::plan::GemmContext::global`]
//! calls [`load_host_entries`] at init.
//!
//! Default location: `~/.cache/emmerald/tuned.json`. The
//! `EMMERALD_TUNE_CACHE` environment variable overrides the path (tests
//! point it at a temp file); the values `off` / `0` / empty disable
//! persistence entirely.

use crate::gemm::{BlockParams, KernelId, Unroll};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Environment variable overriding the cache file path.
pub const ENV_PATH: &str = "EMMERALD_TUNE_CACHE";

/// Process-local path override, taking precedence over `EMMERALD_TUNE_CACHE`
/// and the home-directory default. First call wins; set via
/// [`set_path_override`] (mutating the environment at runtime is not
/// thread-safe, so the test harness pins the path through this instead).
static PATH_OVERRIDE: std::sync::OnceLock<Option<PathBuf>> = std::sync::OnceLock::new();

/// Install a process-local cache path (`None` disables persistence).
/// Only the first call has any effect; returns whether it took. Used by
/// `util::testkit::hermetic_tune_cache` to keep test runs from inheriting
/// a developer's `~/.cache/emmerald/tuned.json`.
pub fn set_path_override(path: Option<PathBuf>) -> bool {
    PATH_OVERRIDE.set(path).is_ok()
}

/// Resolve the cache file path (`None` = persistence disabled).
pub fn cache_path() -> Option<PathBuf> {
    if let Some(over) = PATH_OVERRIDE.get() {
        return over.clone();
    }
    if let Ok(p) = std::env::var(ENV_PATH) {
        if p.is_empty() || p == "off" || p == "0" {
            return None;
        }
        return Some(PathBuf::from(p));
    }
    std::env::var_os("HOME")
        .map(|home| PathBuf::from(home).join(".cache").join("emmerald").join("tuned.json"))
}

/// A stable identifier for the machine the parameters were tuned on.
/// Block geometry is cache-hierarchy-specific, so entries are keyed by
/// CPU model and only replayed on a matching host.
pub fn cpu_model() -> String {
    if let Ok(info) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in info.lines() {
            if let Some(rest) = line.strip_prefix("model name") {
                if let Some((_, v)) = rest.split_once(':') {
                    return v.trim().to_string();
                }
            }
        }
    }
    format!("unknown-{}", std::env::consts::ARCH)
}

fn entry_to_json(cpu: &str, kernel: KernelId, p: &BlockParams) -> Json {
    Json::obj([
        ("cpu", cpu.into()),
        ("kernel", kernel.name().into()),
        ("kb", p.kb.into()),
        ("mb", p.mb.into()),
        ("nr", p.nr.into()),
        ("unroll", p.unroll.factor().into()),
        ("prefetch", p.prefetch.into()),
        ("pack_b", p.pack_b.into()),
        ("pack_a", p.pack_a.into()),
    ])
}

fn entry_from_json(j: &Json) -> Option<(String, KernelId, BlockParams)> {
    let cpu = j.get("cpu")?.as_str()?.to_string();
    let kernel = KernelId::from_name(j.get("kernel")?.as_str()?)?;
    let params = BlockParams {
        kb: j.get("kb")?.as_usize()?,
        mb: j.get("mb")?.as_usize()?,
        nr: j.get("nr")?.as_usize()?,
        unroll: Unroll::from_factor(j.get("unroll")?.as_usize()?)?,
        prefetch: j.get("prefetch")?.as_bool()?,
        pack_b: j.get("pack_b")?.as_bool()?,
        pack_a: j.get("pack_a")?.as_bool()?,
    };
    params.validate().ok()?;
    Some((cpu, kernel, params))
}

/// Load every well-formed entry from a cache file (missing or corrupt
/// files yield an empty list — the cache is strictly best-effort).
pub fn load_entries(path: &Path) -> Vec<(String, KernelId, BlockParams)> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    let Ok(doc) = Json::parse(&text) else {
        return Vec::new();
    };
    doc.get("entries")
        .and_then(Json::as_arr)
        .map(|items| items.iter().filter_map(entry_from_json).collect())
        .unwrap_or_default()
}

/// Entries from the configured cache file that match this host's CPU
/// model — what the global [`crate::gemm::plan::GemmContext`] installs at
/// init.
pub fn load_host_entries() -> Vec<(KernelId, BlockParams)> {
    let Some(path) = cache_path() else {
        return Vec::new();
    };
    let host = cpu_model();
    load_entries(&path)
        .into_iter()
        .filter(|(cpu, _, _)| *cpu == host)
        .map(|(_, id, p)| (id, p))
        .collect()
}

/// Insert-or-replace one `(cpu, kernel)` entry in a cache file.
///
/// Read-modify-write with an atomic publish: the new document is written
/// to a process-unique temp file in the same directory and renamed over
/// the cache, so concurrent readers never observe a torn file. (Two
/// simultaneous writers can still last-write-win a whole document — an
/// acceptable loss for a best-effort cache.)
pub fn save_entry(
    path: &Path,
    cpu: &str,
    kernel: KernelId,
    params: &BlockParams,
) -> std::io::Result<()> {
    let mut entries = load_entries(path);
    entries.retain(|(c, id, _)| !(c == cpu && *id == kernel));
    entries.push((cpu.to_string(), kernel, *params));
    let doc = Json::obj([
        ("version", 1usize.into()),
        (
            "entries",
            Json::arr(entries.iter().map(|(c, id, p)| entry_to_json(c, *id, p))),
        ),
    ]);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, doc.render())?;
    std::fs::rename(&tmp, path)
}

/// Persist a tuning winner for this host under the configured cache path.
/// Returns the path written, or `None` when persistence is disabled or
/// the write failed (the cache never blocks tuning).
pub fn save_host_entry(kernel: KernelId, params: &BlockParams) -> Option<PathBuf> {
    let path = cache_path()?;
    save_entry(&path, &cpu_model(), kernel, params).ok()?;
    Some(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_file(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "emmerald-tune-cache-{}-{}.json",
            std::process::id(),
            tag
        ))
    }

    #[test]
    fn save_load_roundtrip_and_replace() {
        let path = temp_file("roundtrip");
        let _ = std::fs::remove_file(&path);
        let p1 = BlockParams { kb: 128, mb: 64, nr: 4, ..BlockParams::emmerald_sse() };
        save_entry(&path, "cpu-a", KernelId::Simd, &p1).unwrap();
        let p2 = BlockParams { kb: 256, ..p1 };
        save_entry(&path, "cpu-b", KernelId::Simd, &p2).unwrap();
        let p3 = BlockParams { kb: 336, ..p1 };
        save_entry(&path, "cpu-a", KernelId::Avx2, &p3).unwrap();
        // Replacing an existing (cpu, kernel) pair keeps one entry.
        let p4 = BlockParams { kb: 448, ..p1 };
        save_entry(&path, "cpu-a", KernelId::Simd, &p4).unwrap();
        let entries = load_entries(&path);
        assert_eq!(entries.len(), 3);
        let a_simd: Vec<_> = entries
            .iter()
            .filter(|(c, id, _)| c == "cpu-a" && *id == KernelId::Simd)
            .collect();
        assert_eq!(a_simd.len(), 1);
        assert_eq!(a_simd[0].2.kb, 448);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_or_missing_files_load_empty() {
        let path = temp_file("corrupt");
        let _ = std::fs::remove_file(&path);
        assert!(load_entries(&path).is_empty());
        std::fs::write(&path, "{not json").unwrap();
        assert!(load_entries(&path).is_empty());
        // Well-formed JSON with a bogus entry: the entry is skipped.
        std::fs::write(
            &path,
            r#"{"version":1,"entries":[{"cpu":"x","kernel":"emmerald-sse","kb":0,"mb":1,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}]}"#,
        )
        .unwrap();
        assert!(load_entries(&path).is_empty(), "invalid kb=0 must not load");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cpu_model_is_nonempty_and_stable() {
        let a = cpu_model();
        assert!(!a.is_empty());
        assert_eq!(a, cpu_model());
    }
}
