//! Persistent autotune cache: tuned [`BlockParams`] per (CPU model,
//! kernel, element triple), serialised to disk ATLAS-install style.
//!
//! [`super::tune_and_install`] feeds the in-process dispatch table, but
//! winners used to die with the process. This module persists them as
//! JSON (via [`crate::util::json`]) so the next process starts with the
//! machine's tuned geometry: [`crate::gemm::plan::GemmContext::global`]
//! calls [`load_host_entries`] at init.
//!
//! Default location: `~/.cache/emmerald/tuned.json`. The
//! `EMMERALD_TUNE_CACHE` environment variable overrides the path (tests
//! point it at a temp file); the values `off` / `0` / empty disable
//! persistence entirely.

use crate::gemm::{BlockParams, ElementId, KernelId, TileParams, TripleId, Unroll};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// On-disk schema version. **v4** renamed the per-entry `element` key to
/// `triple` (the [`crate::gemm::TripleId`] name — `"f32"`, `"f64"`,
/// `"u8i8i32"`), following the kernel-triple refactor: entries are keyed
/// `(cpu, kernel, triple)`. Files with a missing, older or unknown
/// version are **discarded wholesale** — never a parse error — so
/// upgrading the crate silently re-tunes rather than replaying geometry
/// under the wrong key. Entries naming a triple this build has no tuned
/// float tier for (e.g. the quantized `u8i8i32`, whose geometry is fixed
/// by the maddubs tile) are skipped individually on load, same policy.
pub const SCHEMA_VERSION: usize = 4;

/// Environment variable overriding the cache file path.
pub const ENV_PATH: &str = "EMMERALD_TUNE_CACHE";

/// Process-local path override, taking precedence over `EMMERALD_TUNE_CACHE`
/// and the home-directory default. First call wins; set via
/// [`set_path_override`] (mutating the environment at runtime is not
/// thread-safe, so the test harness pins the path through this instead).
static PATH_OVERRIDE: std::sync::OnceLock<Option<PathBuf>> = std::sync::OnceLock::new();

/// Install a process-local cache path (`None` disables persistence).
/// Only the first call has any effect; returns whether it took. Used by
/// `util::testkit::hermetic_tune_cache` to keep test runs from inheriting
/// a developer's `~/.cache/emmerald/tuned.json`.
pub fn set_path_override(path: Option<PathBuf>) -> bool {
    PATH_OVERRIDE.set(path).is_ok()
}

/// Resolve the cache file path (`None` = persistence disabled).
pub fn cache_path() -> Option<PathBuf> {
    if let Some(over) = PATH_OVERRIDE.get() {
        return over.clone();
    }
    if let Ok(p) = std::env::var(ENV_PATH) {
        if p.is_empty() || p == "off" || p == "0" {
            return None;
        }
        return Some(PathBuf::from(p));
    }
    std::env::var_os("HOME")
        .map(|home| PathBuf::from(home).join(".cache").join("emmerald").join("tuned.json"))
}

/// A stable identifier for the machine the parameters were tuned on.
/// Block geometry is cache-hierarchy-specific, so entries are keyed by
/// CPU model and only replayed on a matching host.
pub fn cpu_model() -> String {
    if let Ok(info) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in info.lines() {
            if let Some(rest) = line.strip_prefix("model name") {
                if let Some((_, v)) = rest.split_once(':') {
                    return v.trim().to_string();
                }
            }
        }
    }
    format!("unknown-{}", std::env::consts::ARCH)
}

/// Everything one cache file holds: dot-kernel block geometries, the
/// tile tier's geometry and the measured Strassen crossover, each keyed
/// by CPU model. Kept as one document so every save preserves the other
/// sections (read-modify-write over the whole file).
#[derive(Debug, Default)]
struct CacheDoc {
    entries: Vec<(String, ElementId, KernelId, BlockParams)>,
    tile_entries: Vec<(String, ElementId, TileParams)>,
    strassen_entries: Vec<(String, usize)>,
}

fn entry_to_json(cpu: &str, element: ElementId, kernel: KernelId, p: &BlockParams) -> Json {
    Json::obj([
        ("cpu", cpu.into()),
        ("triple", element.triple().name().into()),
        ("kernel", kernel.name().into()),
        ("kb", p.kb.into()),
        ("mb", p.mb.into()),
        ("nr", p.nr.into()),
        ("unroll", p.unroll.factor().into()),
        ("prefetch", p.prefetch.into()),
        ("pack_b", p.pack_b.into()),
        ("pack_a", p.pack_a.into()),
    ])
}

fn entry_from_json(j: &Json) -> Option<(String, ElementId, KernelId, BlockParams)> {
    let cpu = j.get("cpu")?.as_str()?.to_string();
    // Unknown triple names and triples without a tuned float tier (the
    // quantized `u8i8i32`) are skipped, not errors.
    let element = TripleId::from_name(j.get("triple")?.as_str()?)?.element()?;
    let kernel = KernelId::from_name(j.get("kernel")?.as_str()?)?;
    let params = BlockParams {
        kb: j.get("kb")?.as_usize()?,
        mb: j.get("mb")?.as_usize()?,
        nr: j.get("nr")?.as_usize()?,
        unroll: Unroll::from_factor(j.get("unroll")?.as_usize()?)?,
        prefetch: j.get("prefetch")?.as_bool()?,
        pack_b: j.get("pack_b")?.as_bool()?,
        pack_a: j.get("pack_a")?.as_bool()?,
    };
    params.validate().ok()?;
    Some((cpu, element, kernel, params))
}

fn tile_entry_to_json(cpu: &str, element: ElementId, p: &TileParams) -> Json {
    Json::obj([
        ("cpu", cpu.into()),
        ("triple", element.triple().name().into()),
        ("mr", p.mr.into()),
        ("nr", p.nr.into()),
        ("kc", p.kc.into()),
        ("mc", p.mc.into()),
        ("nc", p.nc.into()),
        ("prefetch", p.prefetch.into()),
    ])
}

fn tile_entry_from_json(j: &Json) -> Option<(String, ElementId, TileParams)> {
    let cpu = j.get("cpu")?.as_str()?.to_string();
    let element = TripleId::from_name(j.get("triple")?.as_str()?)?.element()?;
    let params = TileParams {
        mr: j.get("mr")?.as_usize()?,
        nr: j.get("nr")?.as_usize()?,
        kc: j.get("kc")?.as_usize()?,
        mc: j.get("mc")?.as_usize()?,
        nc: j.get("nc")?.as_usize()?,
        prefetch: j.get("prefetch")?.as_bool()?,
    };
    params.validate().ok()?;
    Some((cpu, element, params))
}

fn strassen_entry_from_json(j: &Json) -> Option<(String, usize)> {
    let cpu = j.get("cpu")?.as_str()?.to_string();
    let min_dim = j.get("min_dim")?.as_usize()?;
    (min_dim > 0).then_some((cpu, min_dim))
}

/// Parse a whole cache file (missing or corrupt files yield an empty
/// document — the cache is strictly best-effort; unknown sections and
/// malformed entries are skipped). Files written by an **older or
/// unknown schema version are discarded wholesale** (see
/// [`SCHEMA_VERSION`]): v3 entries carry an `element` key where v4 keys
/// by `triple`, and pre-v3 entries carry neither — neither may be
/// replayed under a guessed key; the next autotune run simply rewrites
/// the file at the current version.
fn load_doc(path: &Path) -> CacheDoc {
    let Ok(text) = std::fs::read_to_string(path) else {
        return CacheDoc::default();
    };
    let Ok(doc) = Json::parse(&text) else {
        return CacheDoc::default();
    };
    if doc.get("version").and_then(Json::as_usize) != Some(SCHEMA_VERSION) {
        return CacheDoc::default();
    }
    CacheDoc {
        entries: doc
            .get("entries")
            .and_then(Json::as_arr)
            .map(|items| items.iter().filter_map(entry_from_json).collect())
            .unwrap_or_default(),
        tile_entries: doc
            .get("tile_entries")
            .and_then(Json::as_arr)
            .map(|items| items.iter().filter_map(tile_entry_from_json).collect())
            .unwrap_or_default(),
        strassen_entries: doc
            .get("strassen_entries")
            .and_then(Json::as_arr)
            .map(|items| items.iter().filter_map(strassen_entry_from_json).collect())
            .unwrap_or_default(),
    }
}

/// Atomically publish a whole cache document (temp file + rename, so
/// concurrent readers never observe a torn file).
fn save_doc(path: &Path, doc: &CacheDoc) -> std::io::Result<()> {
    let json = Json::obj([
        ("version", SCHEMA_VERSION.into()),
        (
            "entries",
            Json::arr(doc.entries.iter().map(|(c, e, id, p)| entry_to_json(c, *e, *id, p))),
        ),
        (
            "tile_entries",
            Json::arr(doc.tile_entries.iter().map(|(c, e, p)| tile_entry_to_json(c, *e, p))),
        ),
        (
            "strassen_entries",
            Json::arr(doc.strassen_entries.iter().map(|(c, d)| {
                Json::obj([("cpu", c.as_str().into()), ("min_dim", (*d).into())])
            })),
        ),
    ]);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, json.render())?;
    std::fs::rename(&tmp, path)
}

/// Load every well-formed dot-kernel entry from a cache file (missing,
/// corrupt or old-versioned files yield an empty list — the cache is
/// strictly best-effort).
pub fn load_entries(path: &Path) -> Vec<(String, ElementId, KernelId, BlockParams)> {
    load_doc(path).entries
}

/// Entries from the configured cache file that match this host's CPU
/// model — what the global [`crate::gemm::plan::GemmContext`] installs at
/// init.
pub fn load_host_entries() -> Vec<(ElementId, KernelId, BlockParams)> {
    let Some(path) = cache_path() else {
        return Vec::new();
    };
    let host = cpu_model();
    load_entries(&path)
        .into_iter()
        .filter(|(cpu, _, _, _)| *cpu == host)
        .map(|(_, e, id, p)| (e, id, p))
        .collect()
}

/// Insert-or-replace one `(cpu, kernel, element)` dot-geometry entry in
/// a cache file.
///
/// Read-modify-write with an atomic publish (see [`save_doc`]); the tile
/// and Strassen sections ride along untouched. (Two simultaneous writers
/// can still last-write-win a whole document — an acceptable loss for a
/// best-effort cache.)
pub fn save_entry(
    path: &Path,
    cpu: &str,
    element: ElementId,
    kernel: KernelId,
    params: &BlockParams,
) -> std::io::Result<()> {
    let mut doc = load_doc(path);
    doc.entries.retain(|(c, e, id, _)| !(c == cpu && *e == element && *id == kernel));
    doc.entries.push((cpu.to_string(), element, kernel, *params));
    save_doc(path, &doc)
}

/// Insert-or-replace the tile-tier geometry for one `(cpu, element)`.
pub fn save_tile_entry(
    path: &Path,
    cpu: &str,
    element: ElementId,
    params: &TileParams,
) -> std::io::Result<()> {
    let mut doc = load_doc(path);
    doc.tile_entries.retain(|(c, e, _)| !(c == cpu && *e == element));
    doc.tile_entries.push((cpu.to_string(), element, *params));
    save_doc(path, &doc)
}

/// Insert-or-replace the measured Strassen crossover for one CPU.
pub fn save_strassen_entry(path: &Path, cpu: &str, min_dim: usize) -> std::io::Result<()> {
    let mut doc = load_doc(path);
    doc.strassen_entries.retain(|(c, _)| c != cpu);
    doc.strassen_entries.push((cpu.to_string(), min_dim));
    save_doc(path, &doc)
}

/// Persist a tuning winner for this host under the configured cache path.
/// Returns the path written, or `None` when persistence is disabled or
/// the write failed (the cache never blocks tuning).
pub fn save_host_entry(element: ElementId, kernel: KernelId, params: &BlockParams) -> Option<PathBuf> {
    let path = cache_path()?;
    save_entry(&path, &cpu_model(), element, kernel, params).ok()?;
    Some(path)
}

/// Persist this host's tuned tile geometry (best-effort, like
/// [`save_host_entry`]).
pub fn save_host_tile_entry(element: ElementId, params: &TileParams) -> Option<PathBuf> {
    let path = cache_path()?;
    save_tile_entry(&path, &cpu_model(), element, params).ok()?;
    Some(path)
}

/// Persist this host's measured Strassen crossover (best-effort).
pub fn save_host_strassen_entry(min_dim: usize) -> Option<PathBuf> {
    let path = cache_path()?;
    save_strassen_entry(&path, &cpu_model(), min_dim).ok()?;
    Some(path)
}

/// Everything cached for this host, grouped for one-shot install at
/// [`crate::gemm::plan::GemmContext::global`] init.
#[derive(Debug, Default)]
pub struct HostTuned {
    /// Dot-kernel geometries, keyed `(element, kernel)`.
    pub entries: Vec<(ElementId, KernelId, BlockParams)>,
    /// Tile-tier geometries, one per element.
    pub tiles: Vec<(ElementId, TileParams)>,
    /// Measured Strassen crossover (f32-only tier).
    pub strassen: Option<usize>,
}

/// Everything cached for this host in **one** file read + parse: the
/// dot-kernel entries, the tile geometries and the Strassen crossover —
/// what [`crate::gemm::plan::GemmContext::global`] installs at init.
pub fn load_host_tuned() -> HostTuned {
    let Some(path) = cache_path() else {
        return HostTuned::default();
    };
    let host = cpu_model();
    let doc = load_doc(&path);
    HostTuned {
        entries: doc
            .entries
            .into_iter()
            .filter(|(c, _, _, _)| *c == host)
            .map(|(_, e, id, p)| (e, id, p))
            .collect(),
        tiles: doc
            .tile_entries
            .into_iter()
            .filter(|(c, _, _)| *c == host)
            .map(|(_, e, p)| (e, p))
            .collect(),
        strassen: doc.strassen_entries.into_iter().find(|(c, _)| *c == host).map(|(_, d)| d),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_file(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "emmerald-tune-cache-{}-{}.json",
            std::process::id(),
            tag
        ))
    }

    #[test]
    fn save_load_roundtrip_and_replace() {
        let path = temp_file("roundtrip");
        let _ = std::fs::remove_file(&path);
        let p1 = BlockParams { kb: 128, mb: 64, nr: 4, ..BlockParams::emmerald_sse() };
        save_entry(&path, "cpu-a", ElementId::F32, KernelId::Simd, &p1).unwrap();
        let p2 = BlockParams { kb: 256, ..p1 };
        save_entry(&path, "cpu-b", ElementId::F32, KernelId::Simd, &p2).unwrap();
        let p3 = BlockParams { kb: 336, ..p1 };
        save_entry(&path, "cpu-a", ElementId::F32, KernelId::Avx2, &p3).unwrap();
        // The same (cpu, kernel) under a different element is a distinct
        // entry — the v4 key is (cpu, kernel, triple).
        let p64 = BlockParams { kb: 224, ..p1 };
        save_entry(&path, "cpu-a", ElementId::F64, KernelId::Avx2, &p64).unwrap();
        // Replacing an existing (cpu, element, kernel) triple keeps one.
        let p4 = BlockParams { kb: 448, ..p1 };
        save_entry(&path, "cpu-a", ElementId::F32, KernelId::Simd, &p4).unwrap();
        let entries = load_entries(&path);
        assert_eq!(entries.len(), 4);
        let a_simd: Vec<_> = entries
            .iter()
            .filter(|(c, e, id, _)| c == "cpu-a" && *e == ElementId::F32 && *id == KernelId::Simd)
            .collect();
        assert_eq!(a_simd.len(), 1);
        assert_eq!(a_simd[0].3.kb, 448);
        let a_avx2_f64: Vec<_> = entries
            .iter()
            .filter(|(c, e, id, _)| c == "cpu-a" && *e == ElementId::F64 && *id == KernelId::Avx2)
            .collect();
        assert_eq!(a_avx2_f64.len(), 1);
        assert_eq!(a_avx2_f64[0].3.kb, 224);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_or_missing_files_load_empty() {
        let path = temp_file("corrupt");
        let _ = std::fs::remove_file(&path);
        assert!(load_entries(&path).is_empty());
        std::fs::write(&path, "{not json").unwrap();
        assert!(load_entries(&path).is_empty());
        // Well-formed current-version JSON with a bogus entry: skipped.
        std::fs::write(
            &path,
            r#"{"version":4,"entries":[{"cpu":"x","triple":"f32","kernel":"emmerald-sse","kb":0,"mb":1,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}]}"#,
        )
        .unwrap();
        assert!(load_entries(&path).is_empty(), "invalid kb=0 must not load");
        // Entries naming an unknown triple, or the quantized triple (no
        // tuned float tier), are skipped individually — not errors, and
        // they must not take the valid neighbours down with them.
        std::fs::write(
            &path,
            r#"{"version":4,"entries":[{"cpu":"x","triple":"u8i8i32","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false},{"cpu":"x","triple":"bf16","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false},{"cpu":"x","triple":"f64","kernel":"emmerald-avx2","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}]}"#,
        )
        .unwrap();
        let entries = load_entries(&path);
        assert_eq!(entries.len(), 1, "only the f64 entry is loadable");
        assert_eq!(entries[0].1, ElementId::F64);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn old_or_unknown_schema_versions_are_discarded_not_errors() {
        let path = temp_file("migrate");
        // A perfectly valid v3 document (the pre-triple schema, entries
        // keyed by `element`): every section is discarded wholesale —
        // the tuned numbers would be replayed under the wrong key space
        // if we guessed `triple` from `element`.
        std::fs::write(
            &path,
            r#"{"version":3,"entries":[{"cpu":"x","element":"f32","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}],"tile_entries":[{"cpu":"x","element":"f32","mr":6,"nr":16,"kc":256,"mc":72,"nc":480,"prefetch":true}],"strassen_entries":[{"cpu":"x","min_dim":768}]}"#,
        )
        .unwrap();
        let doc = load_doc(&path);
        assert!(doc.entries.is_empty(), "v3 entries must be discarded");
        assert!(doc.tile_entries.is_empty(), "v3 tile entries must be discarded");
        assert!(doc.strassen_entries.is_empty(), "v3 strassen entries must be discarded");
        // The even older v2 document (no element key at all) likewise.
        std::fs::write(
            &path,
            r#"{"version":2,"entries":[{"cpu":"x","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}]}"#,
        )
        .unwrap();
        assert!(load_doc(&path).entries.is_empty(), "v2 entries must be discarded");
        // Missing and future versions likewise.
        std::fs::write(&path, r#"{"entries":[]}"#).unwrap();
        assert!(load_entries(&path).is_empty());
        std::fs::write(&path, r#"{"version":99,"entries":[]}"#).unwrap();
        assert!(load_entries(&path).is_empty());
        // And a save over an old file migrates it to the current version
        // (old content dropped, new entry present).
        std::fs::write(
            &path,
            r#"{"version":3,"entries":[{"cpu":"x","element":"f32","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}]}"#,
        )
        .unwrap();
        let p = BlockParams { kb: 96, mb: 32, nr: 4, ..BlockParams::emmerald_sse() };
        save_entry(&path, "cpu-m", ElementId::F64, KernelId::Avx2, &p).unwrap();
        let entries = load_entries(&path);
        assert_eq!(entries.len(), 1, "old-version content must not survive migration");
        assert_eq!(entries[0].0, "cpu-m");
        assert_eq!(entries[0].1, ElementId::F64);
        // The rewritten file is v4: entries carry `triple`, not `element`.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""version":4"#) || text.contains(r#""version": 4"#), "{text}");
        assert!(text.contains("triple"), "v4 entries must be keyed by triple: {text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tile_and_strassen_sections_roundtrip_and_coexist() {
        let path = temp_file("tile-strassen");
        let _ = std::fs::remove_file(&path);
        // A dot entry first; the tile/strassen saves must preserve it.
        let dot = BlockParams { kb: 128, mb: 64, nr: 4, ..BlockParams::emmerald_sse() };
        save_entry(&path, "cpu-a", ElementId::F32, KernelId::Simd, &dot).unwrap();
        let tile = TileParams { mr: 4, kc: 128, mc: 48, nc: 160, ..TileParams::avx2_6x16() };
        save_tile_entry(&path, "cpu-a", ElementId::F32, &tile).unwrap();
        save_tile_entry(&path, "cpu-b", ElementId::F32, &TileParams::avx2_6x16()).unwrap();
        // An f64 tile entry for the same cpu coexists with the f32 one.
        save_tile_entry(&path, "cpu-a", ElementId::F64, &TileParams::avx2_6x8_f64()).unwrap();
        save_strassen_entry(&path, "cpu-a", 768).unwrap();
        // Replace: one entry per (cpu, element) survives.
        let tile2 = TileParams { kc: 192, ..tile };
        save_tile_entry(&path, "cpu-a", ElementId::F32, &tile2).unwrap();
        save_strassen_entry(&path, "cpu-a", 1536).unwrap();
        let doc = load_doc(&path);
        assert_eq!(doc.entries.len(), 1, "dot entry must survive tile/strassen saves");
        assert_eq!(doc.tile_entries.len(), 3);
        let a_tile = doc
            .tile_entries
            .iter()
            .find(|(c, e, _)| c == "cpu-a" && *e == ElementId::F32)
            .unwrap();
        assert_eq!(a_tile.2.kc, 192);
        let a_tile64 = doc
            .tile_entries
            .iter()
            .find(|(c, e, _)| c == "cpu-a" && *e == ElementId::F64)
            .unwrap();
        assert_eq!(a_tile64.2.nr, 8);
        assert_eq!(doc.strassen_entries, vec![("cpu-a".to_string(), 1536)]);
        // And a dot save preserves the other sections in turn.
        save_entry(&path, "cpu-b", ElementId::F32, KernelId::Avx2, &dot).unwrap();
        let doc = load_doc(&path);
        assert_eq!(doc.tile_entries.len(), 3);
        assert_eq!(doc.strassen_entries.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn invalid_tile_and_strassen_entries_are_skipped() {
        let path = temp_file("tile-bad");
        std::fs::write(
            &path,
            r#"{"version":4,"entries":[],"tile_entries":[{"cpu":"x","triple":"f32","mr":9,"nr":16,"kc":256,"mc":72,"nc":480,"prefetch":true}],"strassen_entries":[{"cpu":"x","min_dim":0}]}"#,
        )
        .unwrap();
        let doc = load_doc(&path);
        assert!(doc.tile_entries.is_empty(), "mr=9 must not load");
        assert!(doc.strassen_entries.is_empty(), "min_dim=0 must not load");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cpu_model_is_nonempty_and_stable() {
        let a = cpu_model();
        assert!(!a.is_empty());
        assert_eq!(a, cpu_model());
    }
}
