//! Persistent autotune cache: tuned [`BlockParams`] per (CPU model,
//! kernel, element triple), serialised to disk ATLAS-install style.
//!
//! [`super::tune_and_install`] feeds the in-process dispatch table, but
//! winners used to die with the process. This module persists them as
//! JSON (via [`crate::util::json`]) so the next process starts with the
//! machine's tuned geometry: [`crate::gemm::plan::GemmContext::global`]
//! calls [`load_host_tuned`] at init.
//!
//! Default location: `~/.cache/emmerald/tuned.json`. The
//! `EMMERALD_TUNE_CACHE` environment variable overrides the path (tests
//! point it at a temp file); the values `off` / `0` / empty disable
//! persistence entirely.

use crate::gemm::fastmm::DEFAULT_CROSSOVER;
use crate::gemm::{
    BlockParams, ElementId, FastAlgoId, FastmmChoice, KernelId, ShapeClass, TileParams, TripleId,
    Unroll,
};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// On-disk schema version. **v5** made two changes on top of v4's
/// `(cpu, kernel, triple)` entry keys:
///
/// * `tile_entries` are keyed by the full [`TripleId`] — including the
///   quantized `"u8i8i32"`, whose `maddubs` tile geometry became tunable
///   — instead of only the float elements;
/// * the square-only `strassen_entries` section was replaced by
///   `fastmm_entries`, keying a whole [`FastmmChoice`] (algorithm,
///   crossover, minimum dimension) by `(cpu, element, shape class)`.
///
/// **v4 files migrate on load**: tile entries carry over unchanged, and
/// each `(cpu, min_dim)` Strassen crossover becomes f32
/// [`FastAlgoId::Strassen222`] choices across every shape class with the
/// default crossover — the closest v5 reading of the old measurement.
/// Files with a missing, pre-v4 or unknown version are **discarded
/// wholesale** — never a parse error — so upgrading the crate silently
/// re-tunes rather than replaying geometry under the wrong key.
pub const SCHEMA_VERSION: usize = 5;

/// Environment variable overriding the cache file path.
pub const ENV_PATH: &str = "EMMERALD_TUNE_CACHE";

/// Process-local path override, taking precedence over `EMMERALD_TUNE_CACHE`
/// and the home-directory default. First call wins; set via
/// [`set_path_override`] (mutating the environment at runtime is not
/// thread-safe, so the test harness pins the path through this instead).
static PATH_OVERRIDE: std::sync::OnceLock<Option<PathBuf>> = std::sync::OnceLock::new();

/// Install a process-local cache path (`None` disables persistence).
/// Only the first call has any effect; returns whether it took. Used by
/// `util::testkit::hermetic_tune_cache` to keep test runs from inheriting
/// a developer's `~/.cache/emmerald/tuned.json`.
pub fn set_path_override(path: Option<PathBuf>) -> bool {
    PATH_OVERRIDE.set(path).is_ok()
}

/// Resolve the cache file path (`None` = persistence disabled).
pub fn cache_path() -> Option<PathBuf> {
    if let Some(over) = PATH_OVERRIDE.get() {
        return over.clone();
    }
    if let Ok(p) = std::env::var(ENV_PATH) {
        if p.is_empty() || p == "off" || p == "0" {
            return None;
        }
        return Some(PathBuf::from(p));
    }
    std::env::var_os("HOME")
        .map(|home| PathBuf::from(home).join(".cache").join("emmerald").join("tuned.json"))
}

/// A stable identifier for the machine the parameters were tuned on.
/// Block geometry is cache-hierarchy-specific, so entries are keyed by
/// CPU model and only replayed on a matching host.
pub fn cpu_model() -> String {
    if let Ok(info) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in info.lines() {
            if let Some(rest) = line.strip_prefix("model name") {
                if let Some((_, v)) = rest.split_once(':') {
                    return v.trim().to_string();
                }
            }
        }
    }
    format!("unknown-{}", std::env::consts::ARCH)
}

/// Everything one cache file holds: dot-kernel block geometries, the
/// tile tiers' geometries (triple-keyed — the quantized tile tunes too)
/// and the fast-matmul choices, each keyed by CPU model. Kept as one
/// document so every save preserves the other sections
/// (read-modify-write over the whole file).
#[derive(Debug, Default)]
struct CacheDoc {
    entries: Vec<(String, ElementId, KernelId, BlockParams)>,
    tile_entries: Vec<(String, TripleId, TileParams)>,
    fastmm_entries: Vec<(String, ElementId, ShapeClass, FastmmChoice)>,
}

fn entry_to_json(cpu: &str, element: ElementId, kernel: KernelId, p: &BlockParams) -> Json {
    Json::obj([
        ("cpu", cpu.into()),
        ("triple", element.triple().name().into()),
        ("kernel", kernel.name().into()),
        ("kb", p.kb.into()),
        ("mb", p.mb.into()),
        ("nr", p.nr.into()),
        ("unroll", p.unroll.factor().into()),
        ("prefetch", p.prefetch.into()),
        ("pack_b", p.pack_b.into()),
        ("pack_a", p.pack_a.into()),
    ])
}

fn entry_from_json(j: &Json) -> Option<(String, ElementId, KernelId, BlockParams)> {
    let cpu = j.get("cpu")?.as_str()?.to_string();
    // Unknown triple names and triples without a tuned float tier (the
    // quantized `u8i8i32`) are skipped, not errors.
    let element = TripleId::from_name(j.get("triple")?.as_str()?)?.element()?;
    let kernel = KernelId::from_name(j.get("kernel")?.as_str()?)?;
    let params = BlockParams {
        kb: j.get("kb")?.as_usize()?,
        mb: j.get("mb")?.as_usize()?,
        nr: j.get("nr")?.as_usize()?,
        unroll: Unroll::from_factor(j.get("unroll")?.as_usize()?)?,
        prefetch: j.get("prefetch")?.as_bool()?,
        pack_b: j.get("pack_b")?.as_bool()?,
        pack_a: j.get("pack_a")?.as_bool()?,
    };
    params.validate().ok()?;
    Some((cpu, element, kernel, params))
}

fn tile_entry_to_json(cpu: &str, triple: TripleId, p: &TileParams) -> Json {
    Json::obj([
        ("cpu", cpu.into()),
        ("triple", triple.name().into()),
        ("mr", p.mr.into()),
        ("nr", p.nr.into()),
        ("kc", p.kc.into()),
        ("mc", p.mc.into()),
        ("nc", p.nc.into()),
        ("prefetch", p.prefetch.into()),
    ])
}

fn tile_entry_from_json(j: &Json) -> Option<(String, TripleId, TileParams)> {
    let cpu = j.get("cpu")?.as_str()?.to_string();
    let triple = TripleId::from_name(j.get("triple")?.as_str()?)?;
    let params = TileParams {
        mr: j.get("mr")?.as_usize()?,
        nr: j.get("nr")?.as_usize()?,
        kc: j.get("kc")?.as_usize()?,
        mc: j.get("mc")?.as_usize()?,
        nc: j.get("nc")?.as_usize()?,
        prefetch: j.get("prefetch")?.as_bool()?,
    };
    params.validate().ok()?;
    Some((cpu, triple, params))
}

fn fastmm_entry_to_json(cpu: &str, element: ElementId, class: ShapeClass, c: &FastmmChoice) -> Json {
    Json::obj([
        ("cpu", cpu.into()),
        ("element", element.name().into()),
        ("class", class.name().into()),
        ("algo", c.algo.name().into()),
        ("crossover", c.crossover.into()),
        ("min_dim", c.min_dim.into()),
    ])
}

fn fastmm_entry_from_json(j: &Json) -> Option<(String, ElementId, ShapeClass, FastmmChoice)> {
    let cpu = j.get("cpu")?.as_str()?.to_string();
    let element = ElementId::from_name(j.get("element")?.as_str()?)?;
    let class = ShapeClass::from_name(j.get("class")?.as_str()?)?;
    let choice = FastmmChoice {
        algo: FastAlgoId::from_name(j.get("algo")?.as_str()?)?,
        crossover: j.get("crossover")?.as_usize()?,
        min_dim: j.get("min_dim")?.as_usize()?,
    };
    // The same guard the install path enforces: degenerate thresholds
    // must not load (a choice with crossover 0 would recurse forever).
    (choice.crossover > 0 && choice.min_dim > 0).then_some((cpu, element, class, choice))
}

/// The v4 `strassen_entries` shape, kept only for migration.
fn strassen_entry_from_json(j: &Json) -> Option<(String, usize)> {
    let cpu = j.get("cpu")?.as_str()?.to_string();
    let min_dim = j.get("min_dim")?.as_usize()?;
    (min_dim > 0).then_some((cpu, min_dim))
}

fn section<'a>(doc: &'a Json, key: &str) -> &'a [Json] {
    doc.get(key).and_then(Json::as_arr).unwrap_or(&[])
}

/// Parse a whole cache file (missing or corrupt files yield an empty
/// document — the cache is strictly best-effort; unknown sections and
/// malformed entries are skipped). **v4 files migrate in place** (see
/// [`SCHEMA_VERSION`]); files written by any other non-current schema
/// version are discarded wholesale — pre-v4 entries carry an `element`
/// key where v4+ keys by `triple`, and may not be replayed under a
/// guessed key; the next autotune run simply rewrites the file at the
/// current version.
fn load_doc(path: &Path) -> CacheDoc {
    let Ok(text) = std::fs::read_to_string(path) else {
        return CacheDoc::default();
    };
    let Ok(doc) = Json::parse(&text) else {
        return CacheDoc::default();
    };
    let version = doc.get("version").and_then(Json::as_usize);
    if version != Some(SCHEMA_VERSION) && version != Some(4) {
        return CacheDoc::default();
    }
    let mut out = CacheDoc {
        // The dot and tile sections are shape-compatible across v4/v5
        // (v4 already wrote triple-named tile keys; it just never held a
        // "u8i8i32" one).
        entries: section(&doc, "entries").iter().filter_map(entry_from_json).collect(),
        tile_entries: section(&doc, "tile_entries")
            .iter()
            .filter_map(tile_entry_from_json)
            .collect(),
        fastmm_entries: section(&doc, "fastmm_entries")
            .iter()
            .filter_map(fastmm_entry_from_json)
            .collect(),
    };
    if version == Some(4) {
        // Migrate each measured Strassen crossover to its nearest v5
        // meaning: the ⟨2,2,2⟩ algorithm for every f32 shape class, the
        // measured min_dim preserved, the recursion crossover at the
        // built-in default (v4 never measured one).
        for j in section(&doc, "strassen_entries") {
            if let Some((cpu, min_dim)) = strassen_entry_from_json(j) {
                for class in ShapeClass::ALL {
                    out.fastmm_entries.push((
                        cpu.clone(),
                        ElementId::F32,
                        class,
                        FastmmChoice {
                            algo: FastAlgoId::Strassen222,
                            crossover: DEFAULT_CROSSOVER,
                            min_dim,
                        },
                    ));
                }
            }
        }
    }
    out
}

/// Atomically publish a whole cache document (temp file + rename, so
/// concurrent readers never observe a torn file).
fn save_doc(path: &Path, doc: &CacheDoc) -> std::io::Result<()> {
    let json = Json::obj([
        ("version", SCHEMA_VERSION.into()),
        (
            "entries",
            Json::arr(doc.entries.iter().map(|(c, e, id, p)| entry_to_json(c, *e, *id, p))),
        ),
        (
            "tile_entries",
            Json::arr(doc.tile_entries.iter().map(|(c, t, p)| tile_entry_to_json(c, *t, p))),
        ),
        (
            "fastmm_entries",
            Json::arr(
                doc.fastmm_entries
                    .iter()
                    .map(|(c, e, cl, ch)| fastmm_entry_to_json(c, *e, *cl, ch)),
            ),
        ),
    ]);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, json.render())?;
    std::fs::rename(&tmp, path)
}

/// Load every well-formed dot-kernel entry from a cache file (missing,
/// corrupt or old-versioned files yield an empty list — the cache is
/// strictly best-effort).
pub fn load_entries(path: &Path) -> Vec<(String, ElementId, KernelId, BlockParams)> {
    load_doc(path).entries
}

/// Entries from the configured cache file that match this host's CPU
/// model — what the global [`crate::gemm::plan::GemmContext`] installs at
/// init.
pub fn load_host_entries() -> Vec<(ElementId, KernelId, BlockParams)> {
    let Some(path) = cache_path() else {
        return Vec::new();
    };
    let host = cpu_model();
    load_entries(&path)
        .into_iter()
        .filter(|(cpu, _, _, _)| *cpu == host)
        .map(|(_, e, id, p)| (e, id, p))
        .collect()
}

/// Insert-or-replace one `(cpu, kernel, element)` dot-geometry entry in
/// a cache file.
///
/// Read-modify-write with an atomic publish (see [`save_doc`]); the tile
/// and fast-matmul sections ride along untouched. (Two simultaneous
/// writers can still last-write-win a whole document — an acceptable
/// loss for a best-effort cache.)
pub fn save_entry(
    path: &Path,
    cpu: &str,
    element: ElementId,
    kernel: KernelId,
    params: &BlockParams,
) -> std::io::Result<()> {
    let mut doc = load_doc(path);
    doc.entries.retain(|(c, e, id, _)| !(c == cpu && *e == element && *id == kernel));
    doc.entries.push((cpu.to_string(), element, kernel, *params));
    save_doc(path, &doc)
}

/// Insert-or-replace the tile-tier geometry for one `(cpu, triple)` —
/// the float outer-product tiers and the quantized `maddubs` tile share
/// this section.
pub fn save_tile_entry(
    path: &Path,
    cpu: &str,
    triple: TripleId,
    params: &TileParams,
) -> std::io::Result<()> {
    let mut doc = load_doc(path);
    doc.tile_entries.retain(|(c, t, _)| !(c == cpu && *t == triple));
    doc.tile_entries.push((cpu.to_string(), triple, *params));
    save_doc(path, &doc)
}

/// Insert-or-replace the fast-matmul choice for one
/// `(cpu, element, shape class)` cell.
pub fn save_fastmm_entry(
    path: &Path,
    cpu: &str,
    element: ElementId,
    class: ShapeClass,
    choice: &FastmmChoice,
) -> std::io::Result<()> {
    let mut doc = load_doc(path);
    doc.fastmm_entries.retain(|(c, e, cl, _)| !(c == cpu && *e == element && *cl == class));
    doc.fastmm_entries.push((cpu.to_string(), element, class, *choice));
    save_doc(path, &doc)
}

/// Persist a tuning winner for this host under the configured cache path.
/// Returns the path written, or `None` when persistence is disabled or
/// the write failed (the cache never blocks tuning).
pub fn save_host_entry(element: ElementId, kernel: KernelId, params: &BlockParams) -> Option<PathBuf> {
    let path = cache_path()?;
    save_entry(&path, &cpu_model(), element, kernel, params).ok()?;
    Some(path)
}

/// Persist this host's tuned tile geometry for one triple (best-effort,
/// like [`save_host_entry`]).
pub fn save_host_tile_entry(triple: TripleId, params: &TileParams) -> Option<PathBuf> {
    let path = cache_path()?;
    save_tile_entry(&path, &cpu_model(), triple, params).ok()?;
    Some(path)
}

/// Persist this host's measured fast-matmul choice for one
/// `(element, shape class)` cell (best-effort).
pub fn save_host_fastmm_entry(
    element: ElementId,
    class: ShapeClass,
    choice: &FastmmChoice,
) -> Option<PathBuf> {
    let path = cache_path()?;
    save_fastmm_entry(&path, &cpu_model(), element, class, choice).ok()?;
    Some(path)
}

/// Everything cached for this host, grouped for one-shot install at
/// [`crate::gemm::plan::GemmContext::global`] init.
#[derive(Debug, Default)]
pub struct HostTuned {
    /// Dot-kernel geometries, keyed `(element, kernel)`.
    pub entries: Vec<(ElementId, KernelId, BlockParams)>,
    /// Tile-tier geometries, one per triple (floats + the quantized tile).
    pub tiles: Vec<(TripleId, TileParams)>,
    /// Fast-matmul choices, keyed `(element, shape class)`.
    pub fastmm: Vec<(ElementId, ShapeClass, FastmmChoice)>,
}

/// Everything cached for this host in **one** file read + parse: the
/// dot-kernel entries, the tile geometries and the fast-matmul choices —
/// what [`crate::gemm::plan::GemmContext::global`] installs at init.
pub fn load_host_tuned() -> HostTuned {
    let Some(path) = cache_path() else {
        return HostTuned::default();
    };
    let host = cpu_model();
    let doc = load_doc(&path);
    HostTuned {
        entries: doc
            .entries
            .into_iter()
            .filter(|(c, _, _, _)| *c == host)
            .map(|(_, e, id, p)| (e, id, p))
            .collect(),
        tiles: doc
            .tile_entries
            .into_iter()
            .filter(|(c, _, _)| *c == host)
            .map(|(_, t, p)| (t, p))
            .collect(),
        fastmm: doc
            .fastmm_entries
            .into_iter()
            .filter(|(c, _, _, _)| *c == host)
            .map(|(_, e, cl, ch)| (e, cl, ch))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_file(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "emmerald-tune-cache-{}-{}.json",
            std::process::id(),
            tag
        ))
    }

    #[test]
    fn save_load_roundtrip_and_replace() {
        let path = temp_file("roundtrip");
        let _ = std::fs::remove_file(&path);
        let p1 = BlockParams { kb: 128, mb: 64, nr: 4, ..BlockParams::emmerald_sse() };
        save_entry(&path, "cpu-a", ElementId::F32, KernelId::Simd, &p1).unwrap();
        let p2 = BlockParams { kb: 256, ..p1 };
        save_entry(&path, "cpu-b", ElementId::F32, KernelId::Simd, &p2).unwrap();
        let p3 = BlockParams { kb: 336, ..p1 };
        save_entry(&path, "cpu-a", ElementId::F32, KernelId::Avx2, &p3).unwrap();
        // The same (cpu, kernel) under a different element is a distinct
        // entry — the key is (cpu, kernel, triple).
        let p64 = BlockParams { kb: 224, ..p1 };
        save_entry(&path, "cpu-a", ElementId::F64, KernelId::Avx2, &p64).unwrap();
        // Replacing an existing (cpu, element, kernel) triple keeps one.
        let p4 = BlockParams { kb: 448, ..p1 };
        save_entry(&path, "cpu-a", ElementId::F32, KernelId::Simd, &p4).unwrap();
        let entries = load_entries(&path);
        assert_eq!(entries.len(), 4);
        let a_simd: Vec<_> = entries
            .iter()
            .filter(|(c, e, id, _)| c == "cpu-a" && *e == ElementId::F32 && *id == KernelId::Simd)
            .collect();
        assert_eq!(a_simd.len(), 1);
        assert_eq!(a_simd[0].3.kb, 448);
        let a_avx2_f64: Vec<_> = entries
            .iter()
            .filter(|(c, e, id, _)| c == "cpu-a" && *e == ElementId::F64 && *id == KernelId::Avx2)
            .collect();
        assert_eq!(a_avx2_f64.len(), 1);
        assert_eq!(a_avx2_f64[0].3.kb, 224);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_or_missing_files_load_empty() {
        let path = temp_file("corrupt");
        let _ = std::fs::remove_file(&path);
        assert!(load_entries(&path).is_empty());
        std::fs::write(&path, "{not json").unwrap();
        assert!(load_entries(&path).is_empty());
        // Well-formed current-version JSON with a bogus entry: skipped.
        std::fs::write(
            &path,
            r#"{"version":5,"entries":[{"cpu":"x","triple":"f32","kernel":"emmerald-sse","kb":0,"mb":1,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}]}"#,
        )
        .unwrap();
        assert!(load_entries(&path).is_empty(), "invalid kb=0 must not load");
        // Entries naming an unknown triple, or the quantized triple (no
        // tuned float tier), are skipped individually — not errors, and
        // they must not take the valid neighbours down with them.
        std::fs::write(
            &path,
            r#"{"version":5,"entries":[{"cpu":"x","triple":"u8i8i32","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false},{"cpu":"x","triple":"bf16","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false},{"cpu":"x","triple":"f64","kernel":"emmerald-avx2","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}]}"#,
        )
        .unwrap();
        let entries = load_entries(&path);
        assert_eq!(entries.len(), 1, "only the f64 entry is loadable");
        assert_eq!(entries[0].1, ElementId::F64);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pre_v4_schemas_are_discarded_not_errors() {
        let path = temp_file("discard");
        // A perfectly valid v3 document (the pre-triple schema, entries
        // keyed by `element`): every section is discarded wholesale —
        // the tuned numbers would be replayed under the wrong key space
        // if we guessed `triple` from `element`.
        std::fs::write(
            &path,
            r#"{"version":3,"entries":[{"cpu":"x","element":"f32","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}],"tile_entries":[{"cpu":"x","element":"f32","mr":6,"nr":16,"kc":256,"mc":72,"nc":480,"prefetch":true}],"strassen_entries":[{"cpu":"x","min_dim":768}]}"#,
        )
        .unwrap();
        let doc = load_doc(&path);
        assert!(doc.entries.is_empty(), "v3 entries must be discarded");
        assert!(doc.tile_entries.is_empty(), "v3 tile entries must be discarded");
        assert!(doc.fastmm_entries.is_empty(), "v3 strassen entries must not migrate");
        // The even older v2 document (no element key at all) likewise.
        std::fs::write(
            &path,
            r#"{"version":2,"entries":[{"cpu":"x","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}]}"#,
        )
        .unwrap();
        assert!(load_doc(&path).entries.is_empty(), "v2 entries must be discarded");
        // Missing and future versions likewise.
        std::fs::write(&path, r#"{"entries":[]}"#).unwrap();
        assert!(load_entries(&path).is_empty());
        std::fs::write(&path, r#"{"version":99,"entries":[]}"#).unwrap();
        assert!(load_entries(&path).is_empty());
        // And a save over an old file migrates it to the current version
        // (old content dropped, new entry present).
        std::fs::write(
            &path,
            r#"{"version":3,"entries":[{"cpu":"x","element":"f32","kernel":"emmerald-sse","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}]}"#,
        )
        .unwrap();
        let p = BlockParams { kb: 96, mb: 32, nr: 4, ..BlockParams::emmerald_sse() };
        save_entry(&path, "cpu-m", ElementId::F64, KernelId::Avx2, &p).unwrap();
        let entries = load_entries(&path);
        assert_eq!(entries.len(), 1, "old-version content must not survive migration");
        assert_eq!(entries[0].0, "cpu-m");
        assert_eq!(entries[0].1, ElementId::F64);
        // The rewritten file is v5.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""version":5"#) || text.contains(r#""version": 5"#), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn v4_files_migrate_tiles_and_strassen_to_v5() {
        let path = temp_file("v4-migrate");
        // A full v4 document: dot entry + two tile entries + one measured
        // Strassen crossover.
        std::fs::write(
            &path,
            r#"{"version":4,"entries":[{"cpu":"x","triple":"f64","kernel":"emmerald-avx2","kb":128,"mb":64,"nr":5,"unroll":4,"prefetch":true,"pack_b":true,"pack_a":false}],"tile_entries":[{"cpu":"x","triple":"f32","mr":6,"nr":16,"kc":192,"mc":72,"nc":480,"prefetch":true},{"cpu":"y","triple":"f64","mr":6,"nr":8,"kc":256,"mc":72,"nc":480,"prefetch":false}],"strassen_entries":[{"cpu":"x","min_dim":768}]}"#,
        )
        .unwrap();
        let doc = load_doc(&path);
        // Dot + tile sections carry over unchanged.
        assert_eq!(doc.entries.len(), 1);
        assert_eq!(doc.entries[0].1, ElementId::F64);
        assert_eq!(doc.tile_entries.len(), 2);
        let f32_tile =
            doc.tile_entries.iter().find(|(c, t, _)| c == "x" && *t == TripleId::F32).unwrap();
        assert_eq!(f32_tile.2.kc, 192);
        // The Strassen crossover becomes an f32 Strassen-⟨2,2,2⟩ choice
        // in every shape class, min_dim preserved, default crossover.
        assert_eq!(doc.fastmm_entries.len(), ShapeClass::ALL.len());
        for class in ShapeClass::ALL {
            let (_, e, _, ch) = doc
                .fastmm_entries
                .iter()
                .find(|(c, _, cl, _)| c == "x" && *cl == class)
                .unwrap_or_else(|| panic!("migrated entry for {}", class.name()));
            assert_eq!(*e, ElementId::F32);
            assert_eq!(ch.algo, FastAlgoId::Strassen222);
            assert_eq!(ch.crossover, DEFAULT_CROSSOVER);
            assert_eq!(ch.min_dim, 768);
        }
        // A save rewrites the migrated content at v5, and the migrated
        // fastmm entries survive the round trip.
        save_tile_entry(&path, "x", TripleId::QU8I8, &TileParams::qtile_default()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""version":5"#) || text.contains(r#""version": 5"#), "{text}");
        assert!(!text.contains("strassen_entries"), "v4 section must not be rewritten");
        let doc = load_doc(&path);
        assert_eq!(doc.tile_entries.len(), 3);
        assert_eq!(doc.fastmm_entries.len(), ShapeClass::ALL.len());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tile_and_fastmm_sections_roundtrip_and_coexist() {
        let path = temp_file("tile-fastmm");
        let _ = std::fs::remove_file(&path);
        // A dot entry first; the tile/fastmm saves must preserve it.
        let dot = BlockParams { kb: 128, mb: 64, nr: 4, ..BlockParams::emmerald_sse() };
        save_entry(&path, "cpu-a", ElementId::F32, KernelId::Simd, &dot).unwrap();
        let tile = TileParams { mr: 4, kc: 128, mc: 48, nc: 160, ..TileParams::avx2_6x16() };
        save_tile_entry(&path, "cpu-a", TripleId::F32, &tile).unwrap();
        save_tile_entry(&path, "cpu-b", TripleId::F32, &TileParams::avx2_6x16()).unwrap();
        // f64 and quantized tile entries for the same cpu coexist with
        // the f32 one — the v5 key is the full triple.
        save_tile_entry(&path, "cpu-a", TripleId::F64, &TileParams::avx2_6x8_f64()).unwrap();
        let qtile = TileParams { mr: 4, mc: 64, ..TileParams::qtile_default() };
        save_tile_entry(&path, "cpu-a", TripleId::QU8I8, &qtile).unwrap();
        let choice = FastmmChoice {
            algo: FastAlgoId::Laderman333,
            crossover: 128,
            min_dim: 600,
        };
        save_fastmm_entry(&path, "cpu-a", ElementId::F32, ShapeClass::Square, &choice).unwrap();
        save_fastmm_entry(&path, "cpu-a", ElementId::F64, ShapeClass::Square, &choice).unwrap();
        // Replace: one entry per (cpu, triple) / (cpu, element, class).
        let tile2 = TileParams { kc: 192, ..tile };
        save_tile_entry(&path, "cpu-a", TripleId::F32, &tile2).unwrap();
        let choice2 = FastmmChoice { min_dim: 900, ..choice };
        save_fastmm_entry(&path, "cpu-a", ElementId::F32, ShapeClass::Square, &choice2).unwrap();
        let doc = load_doc(&path);
        assert_eq!(doc.entries.len(), 1, "dot entry must survive tile/fastmm saves");
        assert_eq!(doc.tile_entries.len(), 4);
        let a_tile =
            doc.tile_entries.iter().find(|(c, t, _)| c == "cpu-a" && *t == TripleId::F32).unwrap();
        assert_eq!(a_tile.2.kc, 192);
        let a_qtile = doc
            .tile_entries
            .iter()
            .find(|(c, t, _)| c == "cpu-a" && *t == TripleId::QU8I8)
            .unwrap();
        assert_eq!((a_qtile.2.mr, a_qtile.2.mc), (4, 64));
        assert_eq!(doc.fastmm_entries.len(), 2);
        let a_sq = doc
            .fastmm_entries
            .iter()
            .find(|(c, e, cl, _)| c == "cpu-a" && *e == ElementId::F32 && *cl == ShapeClass::Square)
            .unwrap();
        assert_eq!(a_sq.3.min_dim, 900);
        assert_eq!(a_sq.3.algo, FastAlgoId::Laderman333);
        // And a dot save preserves the other sections in turn.
        save_entry(&path, "cpu-b", ElementId::F32, KernelId::Avx2, &dot).unwrap();
        let doc = load_doc(&path);
        assert_eq!(doc.tile_entries.len(), 4);
        assert_eq!(doc.fastmm_entries.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn invalid_tile_and_fastmm_entries_are_skipped() {
        let path = temp_file("tile-bad");
        std::fs::write(
            &path,
            r#"{"version":5,"entries":[],"tile_entries":[{"cpu":"x","triple":"f32","mr":9,"nr":16,"kc":256,"mc":72,"nc":480,"prefetch":true}],"fastmm_entries":[{"cpu":"x","element":"f32","class":"square","algo":"strassen222","crossover":0,"min_dim":512},{"cpu":"x","element":"f32","class":"square","algo":"winograd444","crossover":128,"min_dim":512},{"cpu":"x","element":"f64","class":"deep","algo":"laderman333","crossover":96,"min_dim":640}]}"#,
        )
        .unwrap();
        let doc = load_doc(&path);
        assert!(doc.tile_entries.is_empty(), "mr=9 must not load");
        assert_eq!(doc.fastmm_entries.len(), 1, "crossover=0 and unknown algos must not load");
        assert_eq!(doc.fastmm_entries[0].1, ElementId::F64);
        assert_eq!(doc.fastmm_entries[0].3.algo, FastAlgoId::Laderman333);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cpu_model_is_nonempty_and_stable() {
        let a = cpu_model();
        assert!(!a.is_empty());
        assert_eq!(a, cpu_model());
    }
}
