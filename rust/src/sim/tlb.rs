//! Data-TLB model (fully associative, LRU — the PIII's 64-entry DTLB).
//!
//! The paper's re-buffering explicitly targets TLB behaviour: "By also
//! re-ordering B to enforce optimal memory access patterns we minimise
//! translation look-aside buffer misses" (§3). Walking a column of a
//! stride-700 matrix touches a new 4 KB page every ~1.5 rows, blowing a
//! 64-entry TLB for any sizable matrix; the packed panel touches pages
//! sequentially.

/// TLB counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TlbStats {
    /// Total translations.
    pub accesses: u64,
    /// Translations that missed (page walk).
    pub misses: u64,
}

impl TlbStats {
    /// Miss rate in [0,1].
    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

/// Fully-associative LRU TLB.
#[derive(Clone, Debug)]
pub struct Tlb {
    entries: Vec<(u64, u64)>, // (page, stamp)
    capacity: usize,
    page_shift: u32,
    clock: u64,
    stats: TlbStats,
}

impl Tlb {
    /// `entries` translations of `page_bytes` pages.
    pub fn new(entries: usize, page_bytes: usize) -> Self {
        assert!(entries > 0 && page_bytes.is_power_of_two());
        Self {
            entries: Vec::with_capacity(entries),
            capacity: entries,
            page_shift: page_bytes.trailing_zeros(),
            clock: 0,
            stats: TlbStats::default(),
        }
    }

    /// Translate one address; returns `true` on TLB hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        self.stats.accesses += 1;
        let page = addr >> self.page_shift;
        if let Some(e) = self.entries.iter_mut().find(|(p, _)| *p == page) {
            e.1 = self.clock;
            return true;
        }
        self.stats.misses += 1;
        if self.entries.len() == self.capacity {
            // Evict LRU.
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(i, _)| i)
                .expect("nonempty");
            self.entries.swap_remove(lru);
        }
        self.entries.push((page, self.clock));
        false
    }

    /// Counters so far.
    pub fn stats(&self) -> TlbStats {
        self.stats
    }

    /// Reset contents and counters.
    pub fn flush(&mut self) {
        self.entries.clear();
        self.clock = 0;
        self.stats = TlbStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_page_hits() {
        let mut t = Tlb::new(4, 4096);
        assert!(!t.access(0));
        assert!(t.access(100));
        assert!(t.access(4095));
        assert!(!t.access(4096)); // next page
        assert_eq!(t.stats().misses, 2);
    }

    #[test]
    fn lru_eviction() {
        let mut t = Tlb::new(2, 4096);
        t.access(0); // page 0
        t.access(4096); // page 1
        t.access(0); // page 0 MRU
        t.access(8192); // evicts page 1
        assert!(t.access(0), "page 0 must survive");
        assert!(!t.access(4096), "page 1 must have been evicted");
    }

    #[test]
    fn strided_walk_misses_capacity() {
        // 128 distinct pages through a 64-entry TLB, twice: all miss.
        let mut t = Tlb::new(64, 4096);
        for pass in 0..2 {
            for p in 0..128u64 {
                let hit = t.access(p * 4096);
                if pass == 1 {
                    assert!(!hit);
                }
            }
        }
        assert_eq!(t.stats().miss_rate(), 1.0);
    }

    #[test]
    fn flush_resets() {
        let mut t = Tlb::new(4, 4096);
        t.access(0);
        t.flush();
        assert_eq!(t.stats(), TlbStats::default());
        assert!(!t.access(0));
    }
}
