//! Set-associative LRU cache model.

/// Cache geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheConfig {
    /// Total capacity in bytes.
    pub capacity: usize,
    /// Associativity (ways per set).
    pub ways: usize,
    /// Line size in bytes (power of two).
    pub line_bytes: usize,
}

impl CacheConfig {
    /// Number of sets.
    pub fn sets(&self) -> usize {
        self.capacity / (self.ways * self.line_bytes)
    }

    /// Validate the geometry.
    pub fn validate(&self) -> Result<(), String> {
        if !self.line_bytes.is_power_of_two() {
            return Err(format!("line_bytes {} not a power of two", self.line_bytes));
        }
        if self.ways == 0 || self.capacity == 0 {
            return Err("zero ways or capacity".into());
        }
        if self.capacity % (self.ways * self.line_bytes) != 0 {
            return Err(format!(
                "capacity {} not divisible by ways*line ({}*{})",
                self.capacity, self.ways, self.line_bytes
            ));
        }
        if !self.sets().is_power_of_two() {
            return Err(format!("sets {} not a power of two", self.sets()));
        }
        Ok(())
    }
}

/// Access counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Total lookups.
    pub accesses: u64,
    /// Lookups that hit.
    pub hits: u64,
    /// Lookups that missed.
    pub misses: u64,
    /// Misses that evicted a dirty line (write-back traffic).
    pub writebacks: u64,
}

impl CacheStats {
    /// Hit rate in [0,1] (1.0 when empty).
    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            1.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp: larger = more recently used.
    stamp: u64,
}

const EMPTY: Line = Line { tag: 0, valid: false, dirty: false, stamp: 0 };

/// A set-associative, write-back, write-allocate cache with LRU
/// replacement — the PIII's L1D and L2 policies.
#[derive(Clone, Debug)]
pub struct Cache {
    cfg: CacheConfig,
    lines: Vec<Line>, // sets × ways
    set_mask: u64,
    line_shift: u32,
    clock: u64,
    stats: CacheStats,
}

impl Cache {
    /// Build a cache from a validated geometry.
    pub fn new(cfg: CacheConfig) -> Self {
        cfg.validate().expect("invalid cache config");
        let sets = cfg.sets();
        Self {
            cfg,
            lines: vec![EMPTY; sets * cfg.ways],
            set_mask: sets as u64 - 1,
            line_shift: cfg.line_bytes.trailing_zeros(),
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    /// Geometry.
    pub fn config(&self) -> CacheConfig {
        self.cfg
    }

    /// Counters so far.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Reset contents and counters.
    pub fn flush(&mut self) {
        self.lines.fill(EMPTY);
        self.clock = 0;
        self.stats = CacheStats::default();
    }

    /// Access one byte address. Returns `true` on hit. On miss the line is
    /// allocated (evicting LRU; dirty evictions count as writebacks).
    pub fn access(&mut self, addr: u64, write: bool) -> bool {
        self.clock += 1;
        self.stats.accesses += 1;
        let line_addr = addr >> self.line_shift;
        let set = (line_addr & self.set_mask) as usize;
        let tag = line_addr >> self.set_mask.count_ones();
        let base = set * self.cfg.ways;
        let ways = &mut self.lines[base..base + self.cfg.ways];

        for l in ways.iter_mut() {
            if l.valid && l.tag == tag {
                l.stamp = self.clock;
                l.dirty |= write;
                self.stats.hits += 1;
                return true;
            }
        }
        self.stats.misses += 1;
        // Victim: invalid way first, else LRU.
        let victim = ways
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| if l.valid { l.stamp } else { 0 })
            .map(|(i, _)| i)
            .expect("ways nonempty");
        if ways[victim].valid && ways[victim].dirty {
            self.stats.writebacks += 1;
        }
        ways[victim] = Line { tag, valid: true, dirty: write, stamp: self.clock };
        false
    }

    /// True if the address is currently resident (no state change).
    pub fn probe(&self, addr: u64) -> bool {
        let line_addr = addr >> self.line_shift;
        let set = (line_addr & self.set_mask) as usize;
        let tag = line_addr >> self.set_mask.count_ones();
        let base = set * self.cfg.ways;
        self.lines[base..base + self.cfg.ways].iter().any(|l| l.valid && l.tag == tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cache {
        // 4 sets × 2 ways × 32B = 256B.
        Cache::new(CacheConfig { capacity: 256, ways: 2, line_bytes: 32 })
    }

    #[test]
    fn geometry() {
        let c = tiny();
        assert_eq!(c.config().sets(), 4);
        assert!(CacheConfig { capacity: 255, ways: 2, line_bytes: 32 }.validate().is_err());
        assert!(CacheConfig { capacity: 256, ways: 2, line_bytes: 33 }.validate().is_err());
    }

    #[test]
    fn spatial_locality_hits_within_line() {
        let mut c = tiny();
        assert!(!c.access(0, false)); // cold miss
        for b in 1..32 {
            assert!(c.access(b, false), "byte {b} same line");
        }
        assert_eq!(c.stats().misses, 1);
        assert_eq!(c.stats().hits, 31);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = tiny();
        // Three lines mapping to set 0: line addresses 0, 4, 8 (set = line & 3).
        let a0 = 0u64;
        let a1 = 4 * 32;
        let a2 = 8 * 32;
        c.access(a0, false);
        c.access(a1, false);
        c.access(a0, false); // a0 now MRU
        c.access(a2, false); // evicts a1 (LRU)
        assert!(c.probe(a0));
        assert!(!c.probe(a1));
        assert!(c.probe(a2));
    }

    #[test]
    fn hits_plus_misses_equals_accesses() {
        let mut c = tiny();
        let mut rng = crate::util::prng::Pcg32::new(1);
        for _ in 0..10_000 {
            c.access(rng.next_u32() as u64 % 4096, rng.chance(0.3));
        }
        let s = c.stats();
        assert_eq!(s.hits + s.misses, s.accesses);
        assert_eq!(s.accesses, 10_000);
    }

    #[test]
    fn writeback_only_for_dirty_victims() {
        let mut c = tiny();
        // Fill set 0 with clean lines, then evict: no writeback.
        c.access(0, false);
        c.access(4 * 32, false);
        c.access(8 * 32, false);
        assert_eq!(c.stats().writebacks, 0);
        // Dirty a line, then evict it: one writeback.
        let mut c = tiny();
        c.access(0, true);
        c.access(4 * 32, false);
        c.access(8 * 32, false); // evicts line 0 (dirty)
        assert_eq!(c.stats().writebacks, 1);
    }

    #[test]
    fn capacity_miss_when_working_set_exceeds_cache() {
        let mut c = tiny(); // 256 B
        // Stream 1 KiB twice: second pass still misses (LRU streaming).
        for pass in 0..2 {
            for line in 0..32u64 {
                let hit = c.access(line * 32, false);
                if pass == 1 {
                    assert!(!hit, "line {line} should have been evicted");
                }
            }
        }
    }

    #[test]
    fn working_set_within_capacity_all_hits_second_pass() {
        let mut c = tiny();
        for line in 0..8u64 {
            c.access(line * 32, false);
        }
        let before = c.stats().hits;
        for line in 0..8u64 {
            assert!(c.access(line * 32, false));
        }
        assert_eq!(c.stats().hits, before + 8);
    }

    #[test]
    fn flush_resets() {
        let mut c = tiny();
        c.access(0, true);
        c.flush();
        assert_eq!(c.stats(), CacheStats::default());
        assert!(!c.probe(0));
    }
}
