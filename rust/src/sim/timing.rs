//! The cycle/timing model: compute issue rate + simulated stalls → MFlop/s.
//!
//! `cycles = flops / issue_rate + stall_cycles`, where `stall_cycles` come
//! from the trace-driven hierarchy simulation and `issue_rate` is a
//! per-algorithm calibrated constant (below). MFlop/s = flops · clock /
//! cycles. The *shape* of every curve — where naive collapses, where
//! Emmerald peaks, how ATLAS tracks — emerges from the simulated memory
//! system; the issue rates only set the flat ceilings.

use super::piii::MachineSpec;
use super::trace::{self, Layout};
use crate::sim::hierarchy::HierarchyStats;

/// Which GEMM algorithm to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Three-loop scalar multiply.
    Naive,
    /// ATLAS proxy (blocked scalar).
    Atlas,
    /// Emmerald (SSE, packed, prefetched).
    Emmerald,
}

impl Algorithm {
    /// Display name matching the paper's Fig. 2 legend.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Naive => "naive",
            Algorithm::Atlas => "atlas",
            Algorithm::Emmerald => "emmerald",
        }
    }

    /// Sustained issue rate on the PIII, in flops/cycle, assuming no
    /// memory stalls (calibration constants, *not* fitted to Fig. 2):
    ///
    /// * **Emmerald 2.2** — per 4-element step over 5 columns the kernel
    ///   issues 6 × 128-bit loads (12 µops on port 2), 5 `mulps` (10 µops,
    ///   port 0) and 5 `addps` (10 µops, port 1) for 40 flops: load-port
    ///   bound at ~3.3 flops/cycle before loop overhead, C write-back and
    ///   panel switching, giving ~2.2 sustained. (The paper measures
    ///   1.97–1.98 × clock at the L1-resident sweet spot — this ceiling
    ///   minus the residual stalls the simulator charges.)
    /// * **ATLAS 1.5** — the P6 x87 has separate pipelined FADD and FMUL
    ///   units (up to 2 flops/cycle); ATLAS's register-tiled, fxch-scheduled
    ///   kernels sustain ~75% of that before memory stalls. Its measured
    ///   0.83 × clock *includes* the memory effects we simulate separately
    ///   (the simulated total at the paper's peak size lands at ~0.82 ×
    ///   clock, matching the paper's 375 MFlop/s).
    /// * **Naive 0.66** — a single dependent x87 accumulation chain
    ///   (3-cycle add latency, 2 flops per iteration).
    pub fn compute_model(&self) -> ComputeModel {
        match self {
            Algorithm::Naive => ComputeModel { flops_per_cycle: 0.66 },
            Algorithm::Atlas => ComputeModel { flops_per_cycle: 1.5 },
            Algorithm::Emmerald => ComputeModel { flops_per_cycle: 2.2 },
        }
    }
}

/// Issue-rate model for an algorithm.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    /// Sustained useful flops per core cycle with an ideal memory system.
    pub flops_per_cycle: f64,
}

/// Result of one simulated GEMM.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Algorithm simulated.
    pub algorithm: Algorithm,
    /// Problem size (m = n = k).
    pub size: usize,
    /// Useful flops (2·m·n·k).
    pub flops: f64,
    /// Compute cycles (flops / issue rate).
    pub compute_cycles: f64,
    /// Simulated stall cycles.
    pub stall_cycles: f64,
    /// Simulated wall-clock seconds on the machine.
    pub seconds: f64,
    /// Simulated MFlop/s.
    pub mflops: f64,
    /// Raw hierarchy counters.
    pub stats: HierarchyStats,
}

/// Block geometry used by the simulated optimised algorithms (the paper's
/// values; mb sized so an mb×kb A block occupies half the 512 KB L2).
pub mod geometry {
    /// Emmerald L1 block depth (paper: 336).
    pub const EMMERALD_KB: usize = 336;
    /// Emmerald L2 row block.
    pub const EMMERALD_MB: usize = 192;
    /// Emmerald dot products per inner loop (paper: 5).
    pub const EMMERALD_NR: usize = 5;
    /// ATLAS-proxy k block.
    pub const ATLAS_KB: usize = 256;
    /// ATLAS-proxy row block.
    pub const ATLAS_MB: usize = 128;
}

/// Simulate one square GEMM (`m = n = k = size`) with the paper's
/// methodology: fixed `stride`, cold caches (the hierarchy starts flushed).
pub fn simulate_gemm(
    machine: &MachineSpec,
    algorithm: Algorithm,
    size: usize,
    stride: usize,
) -> SimResult {
    assert!(stride >= size, "stride {stride} < size {size}");
    let lay = Layout::with_stride(stride);
    let mut h = machine.hierarchy();
    match algorithm {
        Algorithm::Naive => trace::trace_naive(&mut h, size, size, size, &lay),
        Algorithm::Atlas => trace::trace_atlas(
            &mut h,
            size,
            size,
            size,
            &lay,
            geometry::ATLAS_KB,
            geometry::ATLAS_MB,
        ),
        Algorithm::Emmerald => trace::trace_emmerald(
            &mut h,
            size,
            size,
            size,
            &lay,
            geometry::EMMERALD_KB,
            geometry::EMMERALD_MB,
            geometry::EMMERALD_NR,
            true,
        ),
    }
    let stats = h.stats();
    let flops = 2.0 * (size as f64).powi(3);
    let compute_cycles = flops / algorithm.compute_model().flops_per_cycle;
    let stall_cycles = stats.stall_cycles as f64;
    let cycles = compute_cycles + stall_cycles;
    let seconds = cycles / (machine.clock_mhz * 1e6);
    SimResult {
        algorithm,
        size,
        flops,
        compute_cycles,
        stall_cycles,
        seconds,
        mflops: flops / seconds / 1e6,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::piii::{piii_450, piii_550};

    #[test]
    fn emmerald_peaks_near_paper_at_320() {
        // Paper: 890 MFlop/s at m=n=k=stride=320 on the PIII-450
        // (1.97 × clock). The simulated machine must land in that band.
        let r = simulate_gemm(&piii_450(), Algorithm::Emmerald, 320, 320);
        assert!(
            (800.0..950.0).contains(&r.mflops),
            "simulated peak {:.0} MFlop/s (paper: 890)",
            r.mflops
        );
    }

    #[test]
    fn ordering_matches_fig2() {
        // At a mid-size point with the paper's stride-700 methodology:
        // emmerald > atlas > naive, decisively.
        for &(algo_hi, algo_lo) in
            &[(Algorithm::Emmerald, Algorithm::Atlas), (Algorithm::Atlas, Algorithm::Naive)]
        {
            let hi = simulate_gemm(&piii_450(), algo_hi, 240, 700);
            let lo = simulate_gemm(&piii_450(), algo_lo, 240, 700);
            assert!(
                hi.mflops > lo.mflops * 1.3,
                "{} ({:.0}) should beat {} ({:.0})",
                hi.algorithm.name(),
                hi.mflops,
                lo.algorithm.name(),
                lo.mflops
            );
        }
    }

    #[test]
    fn emmerald_rate_survives_l2_spill() {
        // Paper: "peak rates can be maintained as long as A, B and C fit
        // into main memory" — the 550 MHz machine ran 3696³ at 940 MFlop/s.
        // Check the rate at an L2-spilling size is within ~15% of the
        // L2-resident rate (full 3696 is too slow to simulate in a unit
        // test; the large_matrix bench covers a bigger point).
        let resident = simulate_gemm(&piii_450(), Algorithm::Emmerald, 256, 448);
        let spilled = simulate_gemm(&piii_450(), Algorithm::Emmerald, 448, 448);
        assert!(
            spilled.mflops > resident.mflops * 0.85,
            "spilled {:.0} vs resident {:.0}",
            spilled.mflops,
            resident.mflops
        );
    }

    #[test]
    fn naive_is_order_of_magnitude_below_emmerald() {
        let e = simulate_gemm(&piii_450(), Algorithm::Emmerald, 320, 700);
        let n = simulate_gemm(&piii_450(), Algorithm::Naive, 320, 700);
        assert!(e.mflops > 4.0 * n.mflops, "emmerald {:.0} naive {:.0}", e.mflops, n.mflops);
    }

    #[test]
    fn faster_clock_gives_higher_peak() {
        let a = simulate_gemm(&piii_450(), Algorithm::Emmerald, 320, 320);
        let b = simulate_gemm(&piii_550(), Algorithm::Emmerald, 320, 320);
        assert!(b.mflops > a.mflops);
    }

    #[test]
    fn result_accounting_consistent() {
        let r = simulate_gemm(&piii_450(), Algorithm::Atlas, 96, 128);
        assert_eq!(r.flops, 2.0 * 96f64.powi(3));
        let cycles = r.compute_cycles + r.stall_cycles;
        let expect_secs = cycles / (450.0 * 1e6);
        assert!((r.seconds - expect_secs).abs() < 1e-12);
        assert!((r.mflops - r.flops / r.seconds / 1e6).abs() < 1e-6);
    }
}
