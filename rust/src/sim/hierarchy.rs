//! The composed memory hierarchy: L1D → L2 → DRAM, with a DTLB in front.
//!
//! Every data access is translated (TLB), then looked up in L1, then L2.
//! The returned cost is the *stall* contribution in CPU cycles beyond a
//! pipelined L1 hit (whose latency the PIII hides under independent work,
//! as do our micro-kernels' independent accumulator chains).

use super::cache::{Cache, CacheConfig, CacheStats};
use super::tlb::{Tlb, TlbStats};

/// Stall latencies (CPU cycles) for each miss level.
#[derive(Clone, Copy, Debug)]
pub struct Latencies {
    /// Extra cycles for an L1 miss that hits L2.
    pub l2_hit: u64,
    /// Extra cycles for a *random* access that misses L2 (DRAM row miss).
    pub memory: u64,
    /// Extra cycles for a DRAM miss on the line directly following the
    /// previous DRAM miss: SDRAM bursts + page hits pipeline sequential
    /// streams far below the random-access latency.
    pub memory_seq: u64,
    /// Page-walk penalty for a DTLB miss (PDE/PTE usually hit L2 on P6).
    pub tlb_miss: u64,
}

/// Aggregate counters for a simulation run.
#[derive(Clone, Copy, Debug, Default)]
pub struct HierarchyStats {
    /// L1 counters.
    pub l1: CacheStats,
    /// L2 counters.
    pub l2: CacheStats,
    /// TLB counters.
    pub tlb: TlbStats,
    /// Total stall cycles charged.
    pub stall_cycles: u64,
    /// Total element accesses.
    pub accesses: u64,
}

/// L1 + L2 + TLB with stall accounting.
#[derive(Clone, Debug)]
pub struct Hierarchy {
    l1: Cache,
    l2: Cache,
    tlb: Tlb,
    lat: Latencies,
    stall_cycles: u64,
    accesses: u64,
    /// Recent DRAM-miss line addresses (one per open SDRAM bank/stream):
    /// a miss on `line+1` of any tracked stream is a sequential burst.
    mem_streams: [u64; 8],
    mem_stream_next: usize,
}

impl Hierarchy {
    /// Build from geometries + latencies.
    pub fn new(
        l1: CacheConfig,
        l2: CacheConfig,
        tlb_entries: usize,
        page_bytes: usize,
        lat: Latencies,
    ) -> Self {
        Self {
            l1: Cache::new(l1),
            l2: Cache::new(l2),
            tlb: Tlb::new(tlb_entries, page_bytes),
            lat,
            stall_cycles: 0,
            accesses: 0,
            mem_streams: [u64::MAX - 1; 8],
            mem_stream_next: 0,
        }
    }

    /// Access one byte address; returns the stall cycles charged.
    #[inline]
    pub fn access(&mut self, addr: u64, write: bool) -> u64 {
        self.accesses += 1;
        let mut stall = 0;
        if !self.tlb.access(addr) {
            stall += self.lat.tlb_miss;
        }
        if !self.l1.access(addr, write) {
            if self.l2.access(addr, write) {
                stall += self.lat.l2_hit;
            } else {
                let line = addr >> 5; // 32-byte lines throughout
                if let Some(s) = self.mem_streams.iter_mut().find(|s| line == **s + 1) {
                    stall += self.lat.memory_seq;
                    *s = line; // stream advances
                } else {
                    stall += self.lat.memory;
                    self.mem_streams[self.mem_stream_next] = line;
                    self.mem_stream_next = (self.mem_stream_next + 1) % self.mem_streams.len();
                }
            }
        }
        self.stall_cycles += stall;
        stall
    }

    /// A 16-byte SSE vector load/store: one access per element address but
    /// charged as a single lookup at the leading address (the PIII splits
    /// 128-bit ops into two 64-bit µops within one line; modelling the
    /// leading address is accurate for aligned streams).
    #[inline]
    pub fn access_vec4(&mut self, addr: u64, write: bool) -> u64 {
        self.access(addr, write)
    }

    /// Simulate a software prefetch of `addr`: the line is brought into
    /// L1/L2 *without* charging stall cycles (the paper's `prefetchnta`
    /// overlaps the fetch with compute).
    pub fn prefetch(&mut self, addr: u64) {
        let _ = self.tlb.access(addr);
        if !self.l1.access(addr, false) {
            let _ = self.l2.access(addr, false);
        }
    }

    /// Counters so far.
    pub fn stats(&self) -> HierarchyStats {
        HierarchyStats {
            l1: self.l1.stats(),
            l2: self.l2.stats(),
            tlb: self.tlb.stats(),
            stall_cycles: self.stall_cycles,
            accesses: self.accesses,
        }
    }

    /// Flush caches, TLB and counters (the paper flushes caches between
    /// timed calls).
    pub fn flush(&mut self) {
        self.l1.flush();
        self.l2.flush();
        self.tlb.flush();
        self.stall_cycles = 0;
        self.accesses = 0;
        self.mem_streams = [u64::MAX - 1; 8];
        self.mem_stream_next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Hierarchy {
        Hierarchy::new(
            CacheConfig { capacity: 256, ways: 2, line_bytes: 32 },
            CacheConfig { capacity: 1024, ways: 4, line_bytes: 32 },
            4,
            4096,
            Latencies { l2_hit: 10, memory: 50, memory_seq: 50, tlb_miss: 20 },
        )
    }

    #[test]
    fn first_touch_charges_memory_plus_tlb() {
        let mut h = tiny();
        let stall = h.access(0, false);
        assert_eq!(stall, 50 + 20);
    }

    #[test]
    fn l1_hit_is_free() {
        let mut h = tiny();
        h.access(0, false);
        assert_eq!(h.access(4, false), 0);
    }

    #[test]
    fn l2_hit_charges_l2_latency() {
        let mut h = tiny();
        // Fill L1 set 0 (2 ways) with three conflicting lines; the first
        // line falls out of L1 but stays in the bigger L2.
        h.access(0, false);
        h.access(4 * 32, false);
        h.access(8 * 32, false);
        let stall = h.access(0, false); // L1 miss, L2 hit, TLB hit
        assert_eq!(stall, 10);
    }

    #[test]
    fn prefetch_fills_without_stall() {
        let mut h = tiny();
        h.prefetch(64);
        let before = h.stats().stall_cycles;
        let stall = h.access(64, false);
        assert_eq!(stall, 0, "prefetched line must hit");
        assert_eq!(h.stats().stall_cycles, before);
    }

    #[test]
    fn stats_accumulate() {
        let mut h = tiny();
        for i in 0..100u64 {
            h.access(i * 8, false);
        }
        let s = h.stats();
        assert_eq!(s.accesses, 100);
        assert_eq!(s.l1.accesses, 100);
        assert_eq!(s.l1.hits + s.l1.misses, 100);
        assert!(s.stall_cycles > 0);
        // Inclusion-ish: L2 sees exactly the L1 misses.
        assert_eq!(s.l2.accesses, s.l1.misses);
    }

    #[test]
    fn flush_resets_everything() {
        let mut h = tiny();
        h.access(0, true);
        h.flush();
        let s = h.stats();
        assert_eq!(s.accesses, 0);
        assert_eq!(s.stall_cycles, 0);
        // And the next access is cold again.
        assert_eq!(h.access(0, false), 70);
    }
}
