//! Pentium III machine presets.
//!
//! Geometry from Intel's Katmai documentation (the paper's 450 MHz part):
//! 16 KB 4-way L1D with 32-byte lines, 512 KB 4-way off-die L2 at half
//! clock, 64-entry DTLB over 4 KB pages. Latencies are in core cycles and
//! follow contemporary lmbench-style measurements for the platform (L2
//! ≈ 15 cycles load-to-use, PC100 SDRAM ≈ 110 ns ≈ 50 cycles at 450 MHz,
//! page walk ≈ 25 cycles).

use super::cache::CacheConfig;
use super::hierarchy::{Hierarchy, Latencies};

/// A simulated machine: clock + memory system.
#[derive(Clone, Copy, Debug)]
pub struct MachineSpec {
    /// Human-readable name.
    pub name: &'static str,
    /// Core clock in MHz.
    pub clock_mhz: f64,
    /// L1 data cache geometry.
    pub l1: CacheConfig,
    /// L2 cache geometry.
    pub l2: CacheConfig,
    /// DTLB entries.
    pub tlb_entries: usize,
    /// Page size in bytes.
    pub page_bytes: usize,
    /// Stall latencies.
    pub latencies: Latencies,
}

impl MachineSpec {
    /// Build a fresh (cold) memory hierarchy for this machine.
    pub fn hierarchy(&self) -> Hierarchy {
        Hierarchy::new(self.l1, self.l2, self.tlb_entries, self.page_bytes, self.latencies)
    }

    /// Peak SSE MFlop/s (4 single-precision flops per cycle).
    pub fn peak_sse_mflops(&self) -> f64 {
        self.clock_mhz * 4.0
    }
}

/// The paper's benchmark machine: PIII (Katmai) at 450 MHz.
pub fn piii_450() -> MachineSpec {
    MachineSpec {
        name: "PIII-450 (Katmai)",
        clock_mhz: 450.0,
        l1: CacheConfig { capacity: 16 * 1024, ways: 4, line_bytes: 32 },
        l2: CacheConfig { capacity: 512 * 1024, ways: 4, line_bytes: 32 },
        tlb_entries: 64,
        page_bytes: 4096,
        latencies: Latencies { l2_hit: 15, memory: 50, memory_seq: 18, tlb_miss: 15 },
    }
}

/// The paper's large-matrix / cluster machine: PIII at 550 MHz (same
/// memory system, faster core — so memory latencies cost more cycles).
pub fn piii_550() -> MachineSpec {
    MachineSpec {
        name: "PIII-550 (Katmai)",
        clock_mhz: 550.0,
        latencies: Latencies { l2_hit: 18, memory: 61, memory_seq: 22, tlb_miss: 18 },
        ..piii_450()
    }
}

/// The Katmai's successor: PIII "Coppermine" at 600 MHz — 256 KB *on-die*
/// L2 at full clock (much lower latency, half the capacity). Included as a
/// what-if preset: the paper's kb=336 panel choice is L1-driven and should
/// carry over, while ATLAS's L2-blocking assumptions shift.
pub fn coppermine_600() -> MachineSpec {
    MachineSpec {
        name: "PIII-600 (Coppermine)",
        clock_mhz: 600.0,
        l2: CacheConfig { capacity: 256 * 1024, ways: 8, line_bytes: 32 },
        latencies: Latencies { l2_hit: 7, memory: 66, memory_seq: 24, tlb_miss: 15 },
        ..piii_450()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let m = piii_450();
        assert_eq!(m.l1.capacity, 16 * 1024);
        assert_eq!(m.l1.sets(), 128);
        assert_eq!(m.l2.capacity, 512 * 1024);
        assert_eq!(m.tlb_entries, 64);
        // Peak = 1800 MFlop/s; the paper's 890 peak is ~0.49 of this,
        // i.e. ~1.98 flops/cycle as reported.
        assert_eq!(m.peak_sse_mflops(), 1800.0);
    }

    #[test]
    fn hierarchy_builds_cold() {
        let mut h = piii_450().hierarchy();
        // Cold access: random DRAM miss + page walk.
        assert_eq!(h.access(0, false), 50 + 15);
        // The adjacent line is a sequential DRAM burst.
        assert_eq!(h.access(32, false), 18);
    }

    #[test]
    fn coppermine_differs_in_l2_only_plus_clock() {
        let c = coppermine_600();
        assert_eq!(c.l1, piii_450().l1);
        assert_eq!(c.l2.capacity, 256 * 1024);
        assert!(c.latencies.l2_hit < piii_450().latencies.l2_hit);
        // On-die L2 at 600 MHz: an Emmerald multiply should be faster than
        // on the 450 in absolute MFlop/s.
        let a = crate::sim::timing::simulate_gemm(
            &c,
            crate::sim::timing::Algorithm::Emmerald,
            256,
            320,
        );
        let b = crate::sim::timing::simulate_gemm(
            &piii_450(),
            crate::sim::timing::Algorithm::Emmerald,
            256,
            320,
        );
        assert!(a.mflops > b.mflops);
    }

    #[test]
    fn faster_clock_same_caches() {
        let a = piii_450();
        let b = piii_550();
        assert_eq!(a.l1, b.l1);
        assert!(b.clock_mhz > a.clock_mhz);
        assert!(b.latencies.memory > a.latencies.memory);
    }
}
