//! Trace-driven Pentium III memory-hierarchy simulator.
//!
//! The paper's numbers were measured on hardware that no longer exists
//! (PIII at 450/550 MHz, 16 KB L1D, 512 KB L2, 64-entry DTLB). Per the
//! substitution rule this module rebuilds the *machine*: set-associative
//! [`cache`]s, a [`tlb`], the composed [`hierarchy`] with Katmai-era
//! latencies, address-exact [`trace`] generators for the three GEMM
//! algorithms of Fig. 2, and a [`timing`] model that combines simulated
//! stall cycles with issue-rate-calibrated compute cycles to produce
//! MFlop/s *in the paper's own units*.
//!
//! The memory behaviour (hit/miss/TLB counts) is simulated exactly; only
//! the per-algorithm sustained issue rates are calibrated constants
//! (documented in [`timing::ComputeModel`]) — i.e. the simulator derives
//! *where the curves bend* from first principles, not from the paper.

pub mod cache;
pub mod hierarchy;
pub mod piii;
pub mod timing;
pub mod tlb;
pub mod trace;

pub use cache::{Cache, CacheConfig, CacheStats};
pub use hierarchy::{Hierarchy, HierarchyStats};
pub use piii::{coppermine_600, piii_450, piii_550, MachineSpec};
pub use timing::{simulate_gemm, Algorithm, ComputeModel, SimResult};
pub use tlb::{Tlb, TlbStats};
