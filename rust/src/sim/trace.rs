//! Address-exact memory-trace generators for the three GEMM algorithms.
//!
//! Each generator replays the *exact* access schedule of its Rust
//! counterpart (same loop order, same packing, same vector widths) against
//! a simulated [`Hierarchy`], so the hit/miss/TLB behaviour is that of the
//! real algorithm on the modelled machine. Operands are placed at disjoint
//! bases with the benchmark's row stride, reproducing the paper's
//! fixed-stride-700 methodology.

use super::hierarchy::Hierarchy;

/// Byte layout of the operands in simulated memory.
#[derive(Clone, Copy, Debug)]
pub struct Layout {
    /// Base address of A.
    pub a_base: u64,
    /// Base address of B.
    pub b_base: u64,
    /// Base address of C.
    pub c_base: u64,
    /// Base address of the packed-B scratch.
    pub pack_b_base: u64,
    /// Base address of the packed-A scratch.
    pub pack_a_base: u64,
    /// Row stride of A, B and C in *elements* (the paper fixes this at 700).
    pub stride: usize,
}

impl Layout {
    /// Default disjoint placement with the given element stride.
    pub fn with_stride(stride: usize) -> Self {
        Self {
            a_base: 0x1000_0000,
            b_base: 0x2000_0000,
            c_base: 0x3000_0000,
            pack_b_base: 0x0800_0000,
            pack_a_base: 0x0C00_0000,
            stride,
        }
    }

    #[inline(always)]
    fn a(&self, r: usize, c: usize) -> u64 {
        self.a_base + ((r * self.stride + c) as u64) * 4
    }

    #[inline(always)]
    fn b(&self, r: usize, c: usize) -> u64 {
        self.b_base + ((r * self.stride + c) as u64) * 4
    }

    #[inline(always)]
    fn c(&self, r: usize, c: usize) -> u64 {
        self.c_base + ((r * self.stride + c) as u64) * 4
    }

    /// Packed B: column-contiguous panels (column j's block at j*kb + p).
    #[inline(always)]
    fn pb(&self, j: usize, p: usize, kb: usize) -> u64 {
        self.pack_b_base + ((j * kb + p) as u64) * 4
    }

    /// Packed A: row-contiguous block rows.
    #[inline(always)]
    fn pa(&self, i: usize, p: usize, kb: usize) -> u64 {
        self.pack_a_base + ((i * kb + p) as u64) * 4
    }
}

/// Naive three-loop ijk: for each (i, j), a scalar dot product reading a
/// row of A and a *strided column* of B, then one C write.
pub fn trace_naive(h: &mut Hierarchy, m: usize, n: usize, k: usize, lay: &Layout) {
    for i in 0..m {
        for j in 0..n {
            for p in 0..k {
                h.access(lay.a(i, p), false);
                h.access(lay.b(p, j), false);
            }
            h.access(lay.c(i, j), true);
        }
    }
}

/// ATLAS proxy: packed operands, scalar 2×2 register tile, L1/L2 blocking.
/// Mirrors `gemm::blocked` (kb-deep k-blocks, mb-row A blocks, width-2
/// panels; every load is a scalar element).
pub fn trace_atlas(
    h: &mut Hierarchy,
    m: usize,
    n: usize,
    k: usize,
    lay: &Layout,
    kb: usize,
    mb: usize,
) {
    let mut kk = 0;
    while kk < k {
        let kb_eff = kb.min(k - kk);
        // Pack the whole B k-block (read strided B, write contiguous).
        for j in 0..n {
            for p in 0..kb_eff {
                h.access(lay.b(kk + p, j), false);
                h.access(lay.pb(j, p, kb_eff), true);
            }
        }
        let mut ii = 0;
        while ii < m {
            let mb_eff = mb.min(m - ii);
            // Pack the A block.
            for i in 0..mb_eff {
                for p in 0..kb_eff {
                    h.access(lay.a(ii + i, kk + p), false);
                    h.access(lay.pa(i, p, kb_eff), true);
                }
            }
            let mut j0 = 0;
            while j0 < n {
                let w = 2.min(n - j0);
                let mut i = 0;
                while i < mb_eff {
                    let hgt = 2.min(mb_eff - i);
                    // 2×2 scalar tile: per k step, hgt A loads + w B loads.
                    for p in 0..kb_eff {
                        for di in 0..hgt {
                            h.access(lay.pa(i + di, p, kb_eff), false);
                        }
                        for dj in 0..w {
                            h.access(lay.pb(j0 + dj, p, kb_eff), false);
                        }
                    }
                    // C tile read-modify-write.
                    for di in 0..hgt {
                        for dj in 0..w {
                            h.access(lay.c(ii + i + di, j0 + dj), false);
                            h.access(lay.c(ii + i + di, j0 + dj), true);
                        }
                    }
                    i += hgt;
                }
                j0 += w;
            }
            ii += mb_eff;
        }
        kk += kb_eff;
    }
}

/// Emmerald: packed-B panels, SSE vector loads (one lookup per 4 floats),
/// `nr` simultaneous dot products re-using each A vector, software
/// prefetch of the streaming A row. Mirrors `gemm::simd`.
#[allow(clippy::too_many_arguments)]
pub fn trace_emmerald(
    h: &mut Hierarchy,
    m: usize,
    n: usize,
    k: usize,
    lay: &Layout,
    kb: usize,
    mb: usize,
    nr: usize,
    prefetch: bool,
) {
    let pf_dist = 64; // elements ahead, as in the micro-kernel
    let mut kk = 0;
    while kk < k {
        let kb_eff = kb.min(k - kk);
        // Re-buffering: pack the B k-block into column-contiguous panels.
        for j in 0..n {
            for p in 0..kb_eff {
                h.access(lay.b(kk + p, j), false);
                h.access(lay.pb(j, p, kb_eff), true);
            }
        }
        let mut ii = 0;
        while ii < m {
            let mb_eff = mb.min(m - ii);
            let mut j0 = 0;
            while j0 < n {
                let w = nr.min(n - j0);
                for i in ii..ii + mb_eff {
                    if prefetch {
                        // The kernel prefetches the head of the next row
                        // while draining the current one; at the trace
                        // level that means a row's first `pf_dist`
                        // elements are already in flight when the
                        // dot-product loop reaches them.
                        let mut q = 0;
                        while q < pf_dist.min(kb_eff) {
                            h.prefetch(lay.a(i, kk + q));
                            q += 8;
                        }
                    }
                    // The dot-product loop: one A vector re-used w times
                    // against w packed columns (fig. 1a).
                    let mut p = 0;
                    while p < kb_eff {
                        if prefetch && p % 8 == 0 && p + pf_dist < kb_eff {
                            h.prefetch(lay.a(i, kk + p + pf_dist));
                        }
                        h.access_vec4(lay.a(i, kk + p), false);
                        for dj in 0..w {
                            h.access_vec4(lay.pb(j0 + dj, p, kb_eff), false);
                        }
                        p += 4;
                    }
                    // Write back w dot products (C accumulate).
                    for dj in 0..w {
                        h.access(lay.c(i, j0 + dj), false);
                        h.access(lay.c(i, j0 + dj), true);
                    }
                }
                j0 += w;
            }
            ii += mb_eff;
        }
        kk += kb_eff;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::piii::piii_450;

    #[test]
    fn naive_access_count() {
        let mut h = piii_450().hierarchy();
        let lay = Layout::with_stride(64);
        trace_naive(&mut h, 8, 8, 8, &lay);
        // 2 loads per MAC + 1 store per output.
        assert_eq!(h.stats().accesses, 2 * 8 * 8 * 8 + 8 * 8);
    }

    #[test]
    fn emmerald_issues_fewer_accesses_than_naive() {
        let size = 64;
        let lay = Layout::with_stride(size);
        let mut h1 = piii_450().hierarchy();
        trace_naive(&mut h1, size, size, size, &lay);
        let mut h2 = piii_450().hierarchy();
        trace_emmerald(&mut h2, size, size, size, &lay, 336, 192, 5, true);
        // Vector loads + A re-use: ≥4× fewer lookups.
        assert!(
            h2.stats().accesses * 4 < h1.stats().accesses,
            "emmerald {} vs naive {}",
            h2.stats().accesses,
            h1.stats().accesses
        );
    }

    #[test]
    fn emmerald_l1_hit_rate_is_high_at_paper_peak_size() {
        // At m=n=k=stride=320 everything is L2-resident and the packed
        // panel is L1-resident: the paper hits its 890 MFlop/s peak here.
        let lay = Layout::with_stride(320);
        let mut h = piii_450().hierarchy();
        trace_emmerald(&mut h, 320, 320, 320, &lay, 336, 192, 5, true);
        let s = h.stats();
        assert!(s.l1.hit_rate() > 0.88, "L1 hit rate {:.3}", s.l1.hit_rate());
        // The decisive invariant: residual stall cycles are a small
        // fraction of the compute cycles (≈2.2 flops/cycle ⇒ ~3e7).
        let flops = 2.0 * 320f64.powi(3);
        let stall_per_flop = s.stall_cycles as f64 / flops;
        assert!(stall_per_flop < 0.1, "stalls/flop {stall_per_flop:.3}");
    }

    #[test]
    fn naive_thrashes_at_large_stride() {
        // Column walks at stride 700 blow L1 and the TLB.
        let lay = Layout::with_stride(700);
        let mut h = piii_450().hierarchy();
        trace_naive(&mut h, 128, 128, 128, &lay);
        let s = h.stats();
        assert!(s.tlb.miss_rate() > 0.01, "tlb miss rate {:.4}", s.tlb.miss_rate());
    }

    #[test]
    fn packing_reduces_tlb_misses() {
        let lay = Layout::with_stride(700);
        let size = 160;
        let mut h_nopack = piii_450().hierarchy();
        trace_naive(&mut h_nopack, size, size, size, &lay);
        let mut h_pack = piii_450().hierarchy();
        trace_emmerald(&mut h_pack, size, size, size, &lay, 336, 192, 5, true);
        assert!(
            h_pack.stats().tlb.miss_rate() < h_nopack.stats().tlb.miss_rate(),
            "packed {:.4} vs naive {:.4}",
            h_pack.stats().tlb.miss_rate(),
            h_nopack.stats().tlb.miss_rate()
        );
    }

    #[test]
    fn atlas_trace_runs_and_packs() {
        let lay = Layout::with_stride(100);
        let mut h = piii_450().hierarchy();
        trace_atlas(&mut h, 33, 35, 37, &lay, 32, 16);
        let s = h.stats();
        // The 2×2 register tile needs one load per MAC (vs naive's two),
        // plus packing traffic and C read-modify-writes.
        assert!(s.accesses as usize > 33 * 35 * 37);
        assert!((s.accesses as usize) < 2 * 33 * 35 * 37);
    }
}
