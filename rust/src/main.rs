//! The `emmerald` command-line tool.
//!
//! Subcommands:
//!
//! * `gemm`      — run one SGEMM on the host, verify against naive.
//! * `sweep`     — Fig. 2 on the host: MFlop/s vs size for all backends.
//! * `sim`       — Fig. 2 on the simulated PIII (the paper's units).
//! * `train`     — distributed MLP training (the §4 application).
//! * `autotune`  — ATLAS-style parameter search for the host kernels
//!                 (winners feed the dispatch heuristics).
//! * `dispatch`  — show the kernel registry and what the dispatcher would
//!                 pick for a given shape (plus live serve-cache counters
//!                 when the service is up).
//! * `serve`     — drive the GEMM service with a Zipfian multi-client
//!                 saturation workload; report throughput, p50/p95/p99
//!                 latency and the cache counters.
//! * `artifacts` — list the AOT artifacts and their metadata.
//! * `verify`    — cross-check every backend (and PJRT if artifacts are
//!                 built) against the naive oracle.

use emmerald::bench::{gemm_flops, Bencher, FlushMode};
use emmerald::blas::{available_backends, sgemm, Backend, Matrix, Transpose};
use emmerald::coordinator::{Coordinator, NativeEngine, PjrtEngine, TrainConfig};
use emmerald::nn::{Dataset, Mlp};
use emmerald::runtime::Runtime;
use emmerald::sim::{piii_450, piii_550, simulate_gemm, Algorithm};
use emmerald::util::cli::Cli;
use emmerald::util::table::{fnum, Table};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let cmd = args.get(1).map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = std::iter::once(format!("emmerald-{cmd}"))
        .chain(args.iter().skip(2).cloned())
        .collect();
    let code = match cmd {
        "gemm" => cmd_gemm(rest),
        "sweep" => cmd_sweep(rest),
        "sim" => cmd_sim(rest),
        "train" => cmd_train(rest),
        "autotune" => cmd_autotune(rest),
        "dispatch" => cmd_dispatch(rest),
        "serve" => cmd_serve(rest),
        "artifacts" => cmd_artifacts(rest),
        "verify" => cmd_verify(rest),
        _ => {
            println!(
                "emmerald {} — SGEMM reproduction (Aberdeen & Baxter)\n\n\
                 USAGE: emmerald <gemm|sweep|sim|train|autotune|dispatch|serve|artifacts|verify> [options]\n\
                 Run a subcommand with --help for its options.",
                emmerald::VERSION
            );
            0
        }
    };
    std::process::exit(code);
}

fn parse(cli: &Cli, argv: Vec<String>) -> emmerald::util::cli::Matches {
    cli.parse_from(argv).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    })
}

fn run_square(backend: Backend, n: usize, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (lda, ldb, ldc) = (a.ld(), b.ld(), c.ld());
    sgemm(
        backend,
        Transpose::No,
        Transpose::No,
        n,
        n,
        n,
        1.0,
        a.data(),
        lda,
        b.data(),
        ldb,
        0.0,
        c.data_mut(),
        ldc,
    )
    .expect("sgemm");
}

fn cmd_gemm(argv: Vec<String>) -> i32 {
    let cli = Cli::new("emmerald gemm", "run one SGEMM and verify against naive")
        .opt("size", "320", "square size (m=n=k)")
        .opt("backend", "auto", "naive|blocked|simd|avx2|dispatch|auto")
        .opt("samples", "5", "timing samples");
    let m = parse(&cli, argv);
    let n = m.get_usize("size").unwrap();
    let backend = Backend::parse(m.get("backend").unwrap()).expect("backend");
    let a = Matrix::random(n, n, 1, -1.0, 1.0);
    let b = Matrix::random(n, n, 2, -1.0, 1.0);
    let mut c = Matrix::zeros(n, n);
    let mut c_ref = Matrix::zeros(n, n);
    run_square(backend, n, &a, &b, &mut c);
    run_square(Backend::Naive, n, &a, &b, &mut c_ref);
    let err = c.max_abs_diff(&c_ref);
    let mut bencher = Bencher::new(1, m.get_usize("samples").unwrap()).min_sample_secs(0.02);
    let r = bencher.run(backend.name(), gemm_flops(n, n, n), || {
        run_square(backend, n, &a, &b, &mut c);
    });
    println!(
        "{} {}x{}x{}: {:.1} MFlop/s (best {:.1}), max|err| {err:.2e}",
        backend.name(),
        n,
        n,
        n,
        r.mflops(),
        r.mflops_best()
    );
    0
}

fn cmd_sweep(argv: Vec<String>) -> i32 {
    let cli = Cli::new("emmerald sweep", "host Fig. 2: MFlop/s vs size, all backends")
        .opt("max", "700", "largest size")
        .opt("step", "64", "size step")
        .opt("stride", "700", "fixed row stride (paper methodology)")
        .flag("no-flush", "keep caches warm between calls");
    let m = parse(&cli, argv);
    let max = m.get_usize("max").unwrap();
    let step = m.get_usize("step").unwrap().max(1);
    let stride = m.get_usize("stride").unwrap().max(max);
    let flush = if m.flag("no-flush") { FlushMode::Warm } else { FlushMode::Flush };
    let backends = available_backends();
    let mut table = Table::new(
        std::iter::once("size".to_string()).chain(backends.iter().map(|b| b.name().to_string())),
    );
    let mut size = 16;
    while size <= max {
        let a = Matrix::random_strided(size, size, stride, 1);
        let b = Matrix::random_strided(size, size, stride, 2);
        let mut c = Matrix::zeros_strided(size, size, stride);
        let mut row = vec![size.to_string()];
        for &backend in &backends {
            let mut bencher = Bencher::new(1, 3).flush_mode(flush).min_sample_secs(0.01);
            let r = bencher.run(backend.name(), gemm_flops(size, size, size), || {
                run_square(backend, size, &a, &b, &mut c);
            });
            row.push(fnum(r.mflops(), 1));
        }
        table.row(row);
        size += step;
    }
    println!("{}", table.render());
    0
}

fn cmd_sim(argv: Vec<String>) -> i32 {
    let cli = Cli::new("emmerald sim", "Fig. 2 on the simulated PIII")
        .opt("sizes", "16,32,64,96,128,192,256,320,448", "comma-separated sizes")
        .opt("stride", "700", "fixed row stride")
        .opt("clock", "450", "PIII clock (450 or 550)");
    let m = parse(&cli, argv);
    let machine = if m.get_u64("clock").unwrap() == 550 { piii_550() } else { piii_450() };
    let stride = m.get_usize("stride").unwrap();
    let mut table = Table::new(["size", "naive", "atlas", "emmerald", "emm/atlas"]);
    for tok in m.get("sizes").unwrap().split(',') {
        let size: usize = tok.trim().parse().expect("size");
        let st = stride.max(size);
        let n = simulate_gemm(&machine, Algorithm::Naive, size, st);
        let a = simulate_gemm(&machine, Algorithm::Atlas, size, st);
        let e = simulate_gemm(&machine, Algorithm::Emmerald, size, st);
        table.row([
            size.to_string(),
            fnum(n.mflops, 0),
            fnum(a.mflops, 0),
            fnum(e.mflops, 0),
            fnum(e.mflops / a.mflops, 2),
        ]);
    }
    println!("{} @ {} MHz (simulated MFlop/s)", machine.name, machine.clock_mhz);
    println!("{}", table.render());
    0
}

fn cmd_train(argv: Vec<String>) -> i32 {
    let cli = Cli::new("emmerald train", "distributed MLP training (§4 application)")
        .opt("workers", "4", "worker count")
        .opt("steps", "60", "training steps")
        .opt("batch", "64", "samples per worker per step")
        .opt("lr", "0.2", "learning rate")
        .opt("engine", "native", "native|pjrt")
        .opt("backend", "auto", "native engine SGEMM backend")
        .opt("artifacts", "artifacts", "artifact dir (pjrt engine)")
        .opt("sizes", "64-128-10", "layer sizes (native engine)")
        .opt("samples", "4096", "dataset size");
    let m = parse(&cli, argv);
    let workers = m.get_usize("workers").unwrap();
    let steps = m.get_usize("steps").unwrap();
    let batch = m.get_usize("batch").unwrap();
    let lr = m.get_f64("lr").unwrap() as f32;
    let engine_kind = m.get("engine").unwrap().to_string();

    let (sizes, pjrt): (Vec<usize>, Option<PjrtEngine>) = if engine_kind == "pjrt" {
        let e = PjrtEngine::new(m.get("artifacts").unwrap())
            .expect("pjrt engine (run `make artifacts`)");
        (e.sizes().to_vec(), Some(e))
    } else {
        let sizes: Vec<usize> =
            m.get("sizes").unwrap().split('-').map(|s| s.parse().expect("size")).collect();
        (sizes, None)
    };

    let mlp = Mlp::init(&sizes, 7, Backend::Auto);
    println!(
        "training {}-layer MLP ({} params) with {workers} workers × batch {batch}, engine {engine_kind}",
        mlp.n_layers(),
        mlp.param_count(),
    );
    let data = Dataset::gaussian_clusters(
        m.get_usize("samples").unwrap(),
        sizes[0],
        *sizes.last().unwrap(),
        0.5,
        42,
    );
    let cfg = TrainConfig { workers, shard_batch: batch, steps, lr, log_every: 10 };
    let mut coord = Coordinator::new(cfg, mlp, data).expect("coordinator");
    let report = match pjrt {
        Some(mut engine) => coord.train_sequential(&mut engine).expect("train"),
        None => {
            let backend = Backend::parse(m.get("backend").unwrap()).expect("backend");
            let factory: std::sync::Arc<emmerald::coordinator::EngineFactory> =
                std::sync::Arc::new(move |_| Ok(Box::new(NativeEngine::new(backend)) as _));
            coord.train_threaded(factory).expect("train")
        }
    };
    println!(
        "done: loss {:.4} -> {:.4}, accuracy {:.1}%, sustained {:.1} MFlop/s, rerouted {}",
        report.first_loss(),
        report.final_loss,
        report.final_accuracy * 100.0,
        report.sustained_mflops(),
        report.rerouted
    );
    0
}

fn cmd_autotune(argv: Vec<String>) -> i32 {
    let cli = Cli::new("emmerald autotune", "ATLAS-style block-size search")
        .opt("kernel", "sse", "sse|avx2|tile|qtile|blocked|fastmm")
        .opt("element", "f32", "f32|f64 — element precision to tune (f64: avx2|tile|fastmm)")
        .opt("probe", "448", "probe problem size");
    let m = parse(&cli, argv);
    let probe = m.get_usize("probe").unwrap();
    let element = match emmerald::gemm::ElementId::from_name(m.get("element").unwrap()) {
        Some(e) => e,
        None => {
            eprintln!("unknown element '{}' (use f32 or f64)", m.get("element").unwrap());
            return 2;
        }
    };
    if m.get("kernel").unwrap() == "avx2" && !emmerald::gemm::KernelId::Avx2.available() {
        // The AVX2 probe executes target_feature kernels directly;
        // running it without the ISA would be an illegal instruction.
        eprintln!("--kernel avx2 needs AVX2+FMA on this host");
        return 2;
    }
    match (m.get("kernel").unwrap(), element) {
        ("tile", _) => return autotune_tile(probe, element),
        ("qtile", _) => return autotune_qtile(probe),
        ("fastmm", _) => return autotune_fastmm(probe, element),
        ("strassen", _) => {
            eprintln!("the Strassen tier became the fast-matmul family; use --kernel fastmm");
            return 2;
        }
        _ => {}
    }
    let mut spec = match (m.get("kernel").unwrap(), element) {
        (_, emmerald::gemm::ElementId::F64) => {
            // The f64 dot tier has one tunable kernel family: AVX2.
            if m.get("kernel").unwrap() != "avx2" {
                eprintln!("--element f64 supports --kernel avx2 or tile (no f64 SSE/blocked grid)");
                return 2;
            }
            let mut s = emmerald::autotune::TuneSpec::sse_default(probe);
            s.kernel = emmerald::autotune::TuneKernel::Avx2F64;
            s
        }
        ("blocked", _) => emmerald::autotune::TuneSpec::blocked_default(probe),
        ("avx2", _) => {
            let mut s = emmerald::autotune::TuneSpec::sse_default(probe);
            s.kernel = emmerald::autotune::TuneKernel::Avx2;
            s
        }
        _ => emmerald::autotune::TuneSpec::sse_default(probe),
    };
    spec.samples = 3;
    let (r, cached) = emmerald::autotune::tune_install_and_persist(&spec);
    let mut table = Table::new(["kb", "mb", "nr", "MFlop/s"]);
    for p in &r.log {
        table.row([
            p.params.kb.to_string(),
            p.params.mb.to_string(),
            p.params.nr.to_string(),
            fnum(p.mflops, 1),
        ]);
    }
    println!("{}", table.render());
    println!(
        "winner: kb={} mb={} nr={} at {:.1} MFlop/s (paper: kb=336, nr=5) — installed into the {} {} dispatch table",
        r.best.kb,
        r.best.mb,
        r.best.nr,
        r.best_mflops,
        spec.kernel.element().name(),
        spec.kernel.kernel_id().name()
    );
    match cached {
        Some(path) => println!("persisted to {} (loaded automatically at next start)", path.display()),
        None => println!("persistence disabled or failed (set {} to a writable path)", emmerald::autotune::cache::ENV_PATH),
    }
    0
}

/// `emmerald autotune --kernel tile [--element f64]`: search
/// (MR, kc, mc, nc) for the outer-product tile tier and persist the
/// winner under the element's cache key.
fn autotune_tile(probe: usize, element: emmerald::gemm::ElementId) -> i32 {
    let spec = match element {
        emmerald::gemm::ElementId::F32 => emmerald::autotune::TileTuneSpec::avx2_default(probe),
        emmerald::gemm::ElementId::F64 => emmerald::autotune::TileTuneSpec::avx2_f64_default(probe),
    };
    let (r, cached) = emmerald::autotune::tune_tile_install_and_persist(&spec);
    let mut table = Table::new(["mr", "kc", "mc", "nc", "MFlop/s"]);
    for p in &r.log {
        table.row([
            p.params.mr.to_string(),
            p.params.kc.to_string(),
            p.params.mc.to_string(),
            p.params.nc.to_string(),
            fnum(p.mflops, 1),
        ]);
    }
    println!("{}", table.render());
    println!(
        "winner: {}x{} tile, kc={} mc={} nc={} at {:.1} MFlop/s — installed into the {} avx2-tile dispatch table",
        r.best.mr, r.best.nr, r.best.kc, r.best.mc, r.best.nc, r.best_mflops, element.name()
    );
    match cached {
        Some(path) => println!("persisted to {} (loaded automatically at next start)", path.display()),
        None => println!("persistence disabled or failed (set {} to a writable path)", emmerald::autotune::cache::ENV_PATH),
    }
    0
}

/// `emmerald autotune --kernel fastmm [--element f64]`: race every fast
/// ⟨m,k,n⟩ algorithm against the classical parallel tier for each shape
/// class and install/persist the per-class winner. `--probe` adds a
/// sweep point (so `--probe 2048` extends the default 256..1024 ladder).
fn autotune_fastmm(probe: usize, element: emmerald::gemm::ElementId) -> i32 {
    let mut last_cached = None;
    for class in emmerald::gemm::ShapeClass::ALL {
        let mut spec = emmerald::autotune::FastmmSpec::default_for(element, class);
        if !spec.sizes.contains(&probe) {
            spec.sizes.push(probe);
            spec.sizes.sort_unstable();
        }
        let (r, cached) = emmerald::autotune::tune_fastmm_install_and_persist(&spec);
        let mut table = Table::new(["size", "algo", "classical MFlop/s", "fast MFlop/s", "fast/classical"]);
        for p in &r.log {
            table.row([
                p.size.to_string(),
                p.algo.name().to_string(),
                fnum(p.classical_mflops, 1),
                fnum(p.fast_mflops, 1),
                fnum(p.fast_mflops / p.classical_mflops, 2),
            ]);
        }
        println!("[{} {}]", element.name(), class.name());
        println!("{}", table.render());
        println!(
            "{} {}: {} min_dim={} crossover={} ({}) — installed",
            element.name(),
            class.name(),
            r.choice.algo.name(),
            r.choice.min_dim,
            r.choice.crossover,
            if r.observed { "measured win" } else { "no win in sweep; 2x largest probe" }
        );
        last_cached = cached;
    }
    match last_cached {
        Some(path) => println!("persisted to {} (loaded automatically at next start)", path.display()),
        None => println!("persistence disabled or failed (set {} to a writable path)", emmerald::autotune::cache::ENV_PATH),
    }
    0
}

/// `emmerald autotune --kernel qtile`: search (MR, kc, mc) for the
/// quantized `maddubs` tile and persist the winner under the
/// `u8i8i32` triple. Any geometry is bitwise identical, so this is a
/// pure wall-clock race.
fn autotune_qtile(probe: usize) -> i32 {
    let spec = emmerald::autotune::QTileTuneSpec::avx2_default(probe);
    let (r, cached) = emmerald::autotune::tune_qtile_install_and_persist(&spec);
    let mut table = Table::new(["mr", "kc", "mc", "MFlop/s"]);
    for p in &r.log {
        table.row([
            p.params.mr.to_string(),
            p.params.kc.to_string(),
            p.params.mc.to_string(),
            fnum(p.mflops, 1),
        ]);
    }
    println!("{}", table.render());
    println!(
        "winner: mr={} kc={} mc={} at {:.1} MFlop/s — installed into the u8i8i32 dispatch table",
        r.best.mr, r.best.kc, r.best.mc, r.best_mflops
    );
    match cached {
        Some(path) => println!("persisted to {} (loaded automatically at next start)", path.display()),
        None => println!("persistence disabled or failed (set {} to a writable path)", emmerald::autotune::cache::ENV_PATH),
    }
    0
}

fn cmd_dispatch(argv: Vec<String>) -> i32 {
    let cli = Cli::new("emmerald dispatch", "kernel registry + selection preview")
        .opt("m", "512", "output rows")
        .opt("n", "512", "output cols")
        .opt("k", "512", "dot-product length")
        .opt("element", "f32", "f32|f64 — element precision previewed");
    let matches = parse(&cli, argv);
    let element = match emmerald::gemm::ElementId::from_name(matches.get("element").unwrap()) {
        Some(e) => e,
        None => {
            eprintln!("unknown element '{}' (use f32 or f64)", matches.get("element").unwrap());
            return 2;
        }
    };
    let mut table = Table::new(["kernel", "requires", "available"]);
    for info in emmerald::gemm::registry_for(element) {
        table.row([
            info.name.to_string(),
            info.requires.to_string(),
            if info.available { "yes".into() } else { "no".into() },
        ]);
    }
    println!("element: {}", element.name());
    println!("{}", table.render());
    let d = emmerald::gemm::dispatch::global_snapshot();
    let (m, n, k) =
        (matches.get_usize("m").unwrap(), matches.get_usize("n").unwrap(), matches.get_usize("k").unwrap());
    for (ta, tb, label) in [
        (Transpose::No, Transpose::No, "NN"),
        (Transpose::Yes, Transpose::No, "TN"),
        (Transpose::No, Transpose::Yes, "NT"),
    ] {
        let shape = emmerald::gemm::dispatch::GemmShape { m, n, k, transa: ta, transb: tb };
        let picked = match element {
            emmerald::gemm::ElementId::F32 => d.select_t::<f32>(&shape, 1.0f32),
            emmerald::gemm::ElementId::F64 => d.select_t::<f64>(&shape, 1.0f64),
        };
        println!("{m}x{n}x{k} {label} → {}", picked.name());
    }
    match element {
        emmerald::gemm::ElementId::F32 => println!(
            "threads={} sse(kb={},nr={}) avx2(kb={},nr={})",
            d.threads(),
            d.params_sse().kb,
            d.params_sse().nr,
            d.params_avx2().kb,
            d.params_avx2().nr
        ),
        emmerald::gemm::ElementId::F64 => println!(
            "threads={} avx2-f64(kb={},nr={}) [no f64 SSE tier]",
            d.threads(),
            d.params_avx2_f64().kb,
            d.params_avx2_f64().nr
        ),
    }
    let tp = match element {
        emmerald::gemm::ElementId::F32 => d.params_tile(),
        emmerald::gemm::ElementId::F64 => d.params_tile_f64(),
    };
    println!(
        "tile tier: {} — {}x{} tile, tuned (mr={}, kc={}, mc={}, nc={})",
        if emmerald::gemm::KernelId::Avx2Tile.available_for(element) { "available (avx2+fma)" } else { "unavailable on this CPU" },
        tp.mr,
        tp.nr,
        tp.mr,
        tp.kc,
        tp.mc,
        tp.nc,
    );
    let mut fm = Table::new(["class", "algo", "crossover", "min_dim", "flops @ shape"]);
    let class_here = emmerald::gemm::ShapeClass::of(m, n, k);
    for class in emmerald::gemm::ShapeClass::ALL {
        match d.config().fastmm.choice(element, class) {
            Some(c) => fm.row([
                format!("{}{}", class.name(), if class == class_here { " *" } else { "" }),
                c.algo.name().to_string(),
                c.crossover.to_string(),
                c.min_dim.to_string(),
                format!(
                    "{:.3e} (classical {:.3e})",
                    emmerald::gemm::fastmm::flops(c.algo, m, k, n, c.crossover),
                    2.0 * m as f64 * n as f64 * k as f64
                ),
            ]),
            None => fm.row([
                format!("{}{}", class.name(), if class == class_here { " *" } else { "" }),
                "off".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    println!("fast-matmul table ({}; * = this shape's class):", element.name());
    println!("{}", fm.render());
    let ctx = emmerald::gemm::GemmContext::global();
    println!(
        "context: shared thread budget {} (caller + {} pool workers); tune cache: {}",
        ctx.threads(),
        ctx.threads().saturating_sub(1),
        emmerald::autotune::cache::cache_path()
            .map(|p| p.display().to_string())
            .unwrap_or_else(|| "disabled".into())
    );
    match emmerald::serve::GemmService::global_started() {
        Some(svc) => {
            println!(
                "serve: {} cached entries ({} KiB packed), capacity {}",
                svc.cache().len(),
                svc.cache().bytes() / 1024,
                svc.cache().capacity()
            );
            println!("{}", svc.stats());
        }
        None => println!("serve: service not started in this process (see `emmerald serve`)"),
    }
    0
}

fn cmd_serve(argv: Vec<String>) -> i32 {
    let cli = Cli::new("emmerald serve", "saturate the GEMM service with a Zipfian shape mix")
        .opt("clients", "4", "concurrent client threads")
        .opt("requests", "128", "requests per client")
        .opt("zipf", "1.2", "Zipf skew exponent over the shape menu")
        .opt("seed", "24091", "workload seed")
        .opt("window-us", "100", "coalesce window, microseconds")
        .opt("cache", "64", "plan/packed-weight cache capacity in entries (0 = disabled)")
        .flag("inline", "ship weight bytes with every request instead of registering them");
    let m = parse(&cli, argv);
    let cfg = emmerald::serve::ServeConfig {
        coalesce_window: std::time::Duration::from_micros(m.get_u64("window-us").unwrap()),
        cache_capacity: m.get_usize("cache").unwrap(),
        ..Default::default()
    };
    let svc =
        emmerald::serve::GemmService::new(emmerald::gemm::GemmContext::global().clone(), cfg);
    let dcfg = emmerald::serve::DriverConfig {
        clients: m.get_usize("clients").unwrap(),
        requests_per_client: m.get_usize("requests").unwrap(),
        zipf_s: m.get_f64("zipf").unwrap(),
        seed: m.get_u64("seed").unwrap(),
        mode: if m.flag("inline") {
            emmerald::serve::WeightMode::Inline
        } else {
            emmerald::serve::WeightMode::Registered
        },
        ..Default::default()
    };
    let report = emmerald::serve::run_driver(&svc, &dcfg);
    println!(
        "{} requests ({} clients × {}), {} failed, {} shapes (zipf s={})",
        report.completed + report.failed,
        dcfg.clients,
        dcfg.requests_per_client,
        report.failed,
        dcfg.shapes.len(),
        dcfg.zipf_s
    );
    println!(
        "elapsed {:.3} s, throughput {:.1} req/s",
        report.elapsed, report.throughput
    );
    println!(
        "latency p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  max {:.3} ms",
        report.latency_p(50.0) * 1e3,
        report.latency_p(95.0) * 1e3,
        report.latency_p(99.0) * 1e3,
        report.latency_p(100.0) * 1e3
    );
    println!(
        "cache: {} entries ({} KiB packed), capacity {}",
        svc.cache().len(),
        svc.cache().bytes() / 1024,
        svc.cache().capacity()
    );
    println!("{}", report.stats);
    0
}

fn cmd_artifacts(argv: Vec<String>) -> i32 {
    let cli = Cli::new("emmerald artifacts", "list AOT artifacts")
        .opt("dir", "artifacts", "artifact directory");
    let m = parse(&cli, argv);
    match Runtime::new(m.get("dir").unwrap()) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            let mut table = Table::new(["artifact", "inputs", "flops"]);
            for name in rt.registry().names() {
                let meta = rt.registry().get(&name).unwrap();
                table.row([
                    name.clone(),
                    meta.inputs.len().to_string(),
                    format!("{:.3e}", meta.flops),
                ]);
            }
            println!("{}", table.render());
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}\nhint: run `make artifacts` first");
            1
        }
    }
}

fn cmd_verify(argv: Vec<String>) -> i32 {
    let cli = Cli::new("emmerald verify", "cross-check all backends vs naive")
        .opt("size", "130", "square size")
        .opt("artifacts", "artifacts", "artifact dir for the PJRT check");
    let m = parse(&cli, argv);
    let n = m.get_usize("size").unwrap();
    let a = Matrix::random(n, n, 3, -1.0, 1.0);
    let b = Matrix::random(n, n, 4, -1.0, 1.0);
    let mut c_ref = Matrix::zeros(n, n);
    run_square(Backend::Naive, n, &a, &b, &mut c_ref);
    let mut failures = 0;
    for backend in available_backends() {
        let mut c = Matrix::zeros(n, n);
        run_square(backend, n, &a, &b, &mut c);
        let err = c.max_abs_diff(&c_ref);
        let ok = err < 1e-3;
        println!("{:<14} max|err| {err:.2e} {}", backend.name(), if ok { "OK" } else { "FAIL" });
        failures += i32::from(!ok);
    }
    // PJRT path (artifact sizes only).
    if let Ok(rt) = Runtime::new(m.get("artifacts").unwrap()) {
        if rt.registry().names().iter().any(|n| n == "gemm_320") {
            let gm = emmerald::runtime::PjrtGemm::new(&rt, "gemm_320").expect("bind gemm_320");
            let n = gm.n;
            let a = Matrix::random(n, n, 5, -1.0, 1.0);
            let b = Matrix::random(n, n, 6, -1.0, 1.0);
            let mut c_ref = Matrix::zeros(n, n);
            run_square(Backend::Naive, n, &a, &b, &mut c_ref);
            let out = gm.matmul(a.data(), b.data()).expect("pjrt matmul");
            let err = out
                .iter()
                .zip(c_ref.data())
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            let ok = err < 1e-2;
            println!("{:<14} max|err| {err:.2e} {}", "pjrt/gemm_320", if ok { "OK" } else { "FAIL" });
            failures += i32::from(!ok);
        }
    } else {
        println!("pjrt          skipped (no artifacts; run `make artifacts`)");
    }
    failures
}
