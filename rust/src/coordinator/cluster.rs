//! The 1999 cluster model: nodes, network, sustained rate, price/perf.
//!
//! Paper §4: *"By distributing training over 196 Intel Pentium III 550 MHz
//! processors, and using Emmerald as the kernel of the training procedure,
//! we achieved a sustained performance of 152 GFlops/s for a price
//! performance ratio of 98 ¢ USD/MFlop/s."*
//!
//! The original cluster ("Bunyip", ref [1]) is long gone; this model
//! reproduces its arithmetic from first principles: per-node kernel rate
//! (measured by our benches, or the paper's PIII numbers), ring-allreduce
//! gradient synchronisation over 100 Mbit Ethernet, and the 1999 price
//! book. The `cluster_scale` bench feeds measured single-node rates in and
//! checks the sustained-GFlop/s and ¢/MFlop/s outputs against the paper.

/// One cluster node.
#[derive(Clone, Copy, Debug)]
pub struct NodeSpec {
    /// CPU clock in MHz.
    pub clock_mhz: f64,
    /// Sustained single-node compute rate in MFlop/s while training.
    pub sustained_mflops: f64,
    /// Node price in USD (1999 price book; includes its share of switches).
    pub price_usd: f64,
}

/// Interconnect model (flat switched Ethernet, ring allreduce).
#[derive(Clone, Copy, Debug)]
pub struct NetworkSpec {
    /// Per-message latency in seconds.
    pub latency_s: f64,
    /// Per-link bandwidth in bytes/second.
    pub bandwidth_bps: f64,
}

/// A homogeneous cluster.
#[derive(Clone, Copy, Debug)]
pub struct ClusterSpec {
    /// Node count.
    pub nodes: usize,
    /// Node description.
    pub node: NodeSpec,
    /// Interconnect description.
    pub network: NetworkSpec,
}

impl ClusterSpec {
    /// The paper's cluster: 196 × PIII-550. Per-node sustained rate uses
    /// the paper's own large-matrix measurement (940 MFlop/s at 550 MHz,
    /// §4) derated by the training procedure's non-GEMM work; the price
    /// book is ref [1]'s (AUD ~$250k ≈ USD ~$149k for the full machine).
    pub fn piii_cluster_1999() -> Self {
        Self {
            nodes: 196,
            node: NodeSpec {
                clock_mhz: 550.0,
                // 940 MFlop/s kernel peak × ~0.87 training efficiency.
                sustained_mflops: 820.0,
                price_usd: 760.0,
            },
            network: NetworkSpec {
                // 100 Mbit switched Ethernet, MPI-ish latency.
                latency_s: 100e-6,
                bandwidth_bps: 100e6 / 8.0,
            },
        }
    }

    /// A cluster of `nodes` copies of *this host*, given a measured
    /// single-node sustained rate (from the training bench) and a modern
    /// price per node.
    pub fn host_cluster(nodes: usize, sustained_mflops: f64, price_usd: f64) -> Self {
        Self {
            nodes,
            node: NodeSpec { clock_mhz: 2100.0, sustained_mflops, price_usd },
            network: NetworkSpec { latency_s: 20e-6, bandwidth_bps: 10e9 / 8.0 },
        }
    }

    /// Ring-allreduce time for `bytes` of gradients: `2(n-1)/n · bytes/bw`
    /// transfer plus `2(n-1)` latency hops.
    pub fn allreduce_seconds(&self, bytes: f64) -> f64 {
        if self.nodes <= 1 {
            return 0.0;
        }
        let n = self.nodes as f64;
        2.0 * (n - 1.0) / n * bytes / self.network.bandwidth_bps
            + 2.0 * (n - 1.0) * self.network.latency_s
    }

    /// Wall-clock seconds for one synchronous step: per-node compute plus
    /// gradient allreduce.
    pub fn step_seconds(&self, flops_per_node: f64, grad_bytes: f64) -> f64 {
        let compute = flops_per_node / (self.node.sustained_mflops * 1e6);
        compute + self.allreduce_seconds(grad_bytes)
    }

    /// Parallel efficiency of a step (compute / (compute + comm)).
    pub fn efficiency(&self, flops_per_node: f64, grad_bytes: f64) -> f64 {
        let compute = flops_per_node / (self.node.sustained_mflops * 1e6);
        compute / self.step_seconds(flops_per_node, grad_bytes)
    }

    /// Sustained cluster rate in GFlop/s for a steady stream of steps.
    pub fn sustained_gflops(&self, flops_per_node: f64, grad_bytes: f64) -> f64 {
        let per_step = flops_per_node * self.nodes as f64;
        per_step / self.step_seconds(flops_per_node, grad_bytes) / 1e9
    }

    /// Total cluster price (USD).
    pub fn total_price_usd(&self) -> f64 {
        self.nodes as f64 * self.node.price_usd
    }

    /// The paper's headline metric: US cents per sustained MFlop/s.
    pub fn cents_per_mflops(&self, sustained_gflops: f64) -> f64 {
        self.total_price_usd() * 100.0 / (sustained_gflops * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Gradient bytes for the paper's ~1M-parameter network (f32).
    const GRAD_BYTES: f64 = 1.0e6 * 4.0;
    /// Per-node flops between gradient syncs. Ref [1] trained with very
    /// large local batches (a ~million-example corpus sharded over 196
    /// nodes), so each sync amortises several seconds of GEMM work:
    /// batch_per_node ≈ 1300 × 3 × 2 × 1M-param ≈ 8 GFlop.
    const STEP_FLOPS: f64 = 8.0e9;

    #[test]
    fn paper_cluster_reproduces_headline_numbers() {
        let c = ClusterSpec::piii_cluster_1999();
        let gf = c.sustained_gflops(STEP_FLOPS, GRAD_BYTES);
        // Paper: 152 GFlop/s sustained. Our model must land in the band.
        assert!(
            (130.0..170.0).contains(&gf),
            "sustained {gf:.1} GFlop/s outside the paper's band"
        );
        let cents = c.cents_per_mflops(gf);
        // Paper: 98 ¢/MFlop/s.
        assert!((80.0..120.0).contains(&cents), "price/perf {cents:.0} ¢/MFlop/s");
    }

    #[test]
    fn allreduce_scales_with_bytes_and_nodes() {
        let c = ClusterSpec::piii_cluster_1999();
        assert!(c.allreduce_seconds(8e6) > c.allreduce_seconds(4e6));
        let small = ClusterSpec { nodes: 2, ..c };
        assert!(small.allreduce_seconds(4e6) < c.allreduce_seconds(4e6));
        let single = ClusterSpec { nodes: 1, ..c };
        assert_eq!(single.allreduce_seconds(4e6), 0.0);
    }

    #[test]
    fn efficiency_in_unit_interval_and_monotone_in_compute() {
        let c = ClusterSpec::piii_cluster_1999();
        let e_small = c.efficiency(1e8, GRAD_BYTES);
        let e_large = c.efficiency(4e9, GRAD_BYTES);
        assert!(e_small > 0.0 && e_small < 1.0);
        assert!(e_large > e_small, "bigger local batches amortise comm");
    }

    #[test]
    fn sustained_rate_saturates_at_node_sum() {
        let c = ClusterSpec::piii_cluster_1999();
        let gf = c.sustained_gflops(1e12, GRAD_BYTES); // comm-negligible
        let peak = c.nodes as f64 * c.node.sustained_mflops / 1e3;
        assert!(gf <= peak * 1.001);
        assert!(gf > peak * 0.99);
    }

    #[test]
    fn host_cluster_constructor() {
        let c = ClusterSpec::host_cluster(16, 20_000.0, 2_000.0);
        assert_eq!(c.nodes, 16);
        assert_eq!(c.total_price_usd(), 32_000.0);
        let gf = c.sustained_gflops(1e9, GRAD_BYTES);
        assert!(gf > 0.0);
    }
}
