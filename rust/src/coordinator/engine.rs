//! Gradient engines: how a worker computes `(loss, grads)` for its shard.

use anyhow::{bail, Context, Result};

use crate::blas::{Backend, Matrix};
use crate::nn::mlp::{Mlp, MlpGrads};
use crate::runtime::{Runtime, Tensor};

/// A worker's compute engine. Engines are constructed *inside* the worker
/// thread (see [`EngineFactory`]), so implementations need not be `Send`.
pub trait GradEngine {
    /// Compute loss and gradients of `mlp` on one batch shard.
    fn loss_and_grad(&mut self, mlp: &Mlp, x: &Matrix, y: &Matrix) -> Result<(f32, MlpGrads)>;

    /// Compute loss and gradients for several shards at once. The default
    /// loops [`loss_and_grad`](Self::loss_and_grad); engines that can
    /// batch (the native engine's shared-weight `sgemm_batch` backprop)
    /// override this — the sequential trainer calls it with the whole
    /// step's shard list.
    fn loss_and_grad_multi(
        &mut self,
        mlp: &Mlp,
        shards: &[(Matrix, Matrix)],
    ) -> Result<Vec<(f32, MlpGrads)>> {
        shards.iter().map(|(x, y)| self.loss_and_grad(mlp, x, y)).collect()
    }

    /// Engine label for logs.
    fn name(&self) -> String;

    /// Fixed batch size required by the engine (None = any).
    fn required_batch(&self) -> Option<usize> {
        None
    }
}

/// Constructs a fresh engine for worker `id` on the worker's own thread.
pub type EngineFactory = dyn Fn(usize) -> Result<Box<dyn GradEngine>> + Send + Sync;

/// Stack equal-shaped matrices vertically into one contiguous row-major
/// matrix (bulk row copies, not per-element access).
fn stack_rows<'a>(mats: impl ExactSizeIterator<Item = &'a Matrix>) -> Matrix {
    let mut parts = mats.peekable();
    let (rows, cols) = {
        let first = parts.peek().expect("at least one matrix to stack");
        (first.rows(), first.cols())
    };
    let count = parts.len();
    let mut out = Matrix::zeros(rows * count, cols);
    let mut dst = 0usize;
    for m in parts {
        assert_eq!((m.rows(), m.cols()), (rows, cols), "ragged stack");
        for r in 0..rows {
            let src = r * m.ld();
            out.data_mut()[dst..dst + cols].copy_from_slice(&m.data()[src..src + cols]);
            dst += cols;
        }
    }
    out
}

/// Native engine: Rust backprop with a selectable SGEMM backend.
pub struct NativeEngine {
    backend: Backend,
}

impl NativeEngine {
    /// New native engine over the given backend.
    pub fn new(backend: Backend) -> Self {
        Self { backend }
    }

    /// Inference through the process-wide GEMM service
    /// ([`crate::serve::GemmService::global`]): every layer's plan and
    /// packed weight panel comes from the service's shared cache, so
    /// concurrent evaluators of the same snapshot share one packing and
    /// repeat calls skip all planning/packing work. Logits are bitwise
    /// identical to [`Mlp::forward`] on the dispatch backend (same plans,
    /// same prepacked drivers).
    pub fn infer(&self, mlp: &Mlp, x: &Matrix) -> Matrix {
        mlp.forward_served(crate::serve::GemmService::global(), x)
    }
}

impl Default for NativeEngine {
    /// The production default: every SGEMM in the worker's backprop goes
    /// through the [`crate::gemm::dispatch`] registry, and all parallel
    /// work draws from the shared
    /// [`crate::gemm::plan::GemmContext`] thread budget — nesting
    /// threaded training above the parallel GEMM tier no longer
    /// oversubscribes the host (each fork-join shares the one pool, with
    /// the calling worker participating).
    fn default() -> Self {
        Self::new(Backend::Dispatch)
    }
}

impl GradEngine for NativeEngine {
    fn loss_and_grad(&mut self, mlp: &Mlp, x: &Matrix, y: &Matrix) -> Result<(f32, MlpGrads)> {
        // Re-target the snapshot at this engine's backend (cheap relative
        // to the GEMMs; parameters are already a per-step snapshot).
        let mut local = mlp.clone();
        local.backend = self.backend;
        Ok(local.loss_and_grad(x, y))
    }

    /// Batched backprop: equal-sized shards are stacked into one matrix
    /// pair and routed through
    /// [`Mlp::loss_and_grad_sharded`] — the forward and `dh` passes fold
    /// over the shared weights and the per-shard `dW`s run as one
    /// `sgemm_batch` per layer, instead of per-shard serial SGEMMs.
    fn loss_and_grad_multi(
        &mut self,
        mlp: &Mlp,
        shards: &[(Matrix, Matrix)],
    ) -> Result<Vec<(f32, MlpGrads)>> {
        let uniform = shards
            .first()
            .map(|(x0, _)| {
                x0.rows() > 0 && shards.iter().all(|(x, _)| x.rows() == x0.rows())
            })
            .unwrap_or(false);
        if !uniform {
            // Ragged shard sizes fall back to the serial loop.
            return shards.iter().map(|(x, y)| self.loss_and_grad(mlp, x, y)).collect();
        }
        let x_all = stack_rows(shards.iter().map(|(x, _)| x));
        let y_all = stack_rows(shards.iter().map(|(_, y)| y));
        let mut local = mlp.clone();
        local.backend = self.backend;
        Ok(local.loss_and_grad_sharded(&x_all, &y_all, shards.len()))
    }

    fn name(&self) -> String {
        format!("native/{}", self.backend.name())
    }
}

/// PJRT engine: executes the AOT-compiled `mlp_grad` artifact (JAX graph
/// wrapping the Emmerald Pallas kernel). Python is *not* involved — the
/// artifact was lowered at build time.
pub struct PjrtEngine {
    runtime: Runtime,
    artifact: String,
    sizes: Vec<usize>,
    batch: usize,
}

impl PjrtEngine {
    /// Load the `mlp_grad` artifact from `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::with_artifact(artifact_dir, "mlp_grad")
    }

    /// Load a specific grad artifact by name.
    pub fn with_artifact(
        artifact_dir: impl AsRef<std::path::Path>,
        artifact: &str,
    ) -> Result<Self> {
        let runtime = Runtime::new(artifact_dir)?;
        let meta = runtime.registry().get(artifact)?.clone();
        let sizes: Vec<usize> = meta
            .extra
            .get("sizes")
            .context("mlp artifact missing sizes extra")?
            .split('-')
            .map(|s| s.parse::<usize>().context("bad size"))
            .collect::<Result<_>>()?;
        let batch: usize =
            meta.extra.get("batch").context("mlp artifact missing batch extra")?.parse()?;
        runtime.ensure_compiled(artifact)?;
        Ok(Self { runtime, artifact: artifact.to_string(), sizes, batch })
    }

    /// Layer sizes baked into the artifact.
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Batch size baked into the artifact.
    pub fn batch(&self) -> usize {
        self.batch
    }

    fn params_to_tensors(mlp: &Mlp) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(mlp.weights.len() * 2);
        for (w, b) in mlp.weights.iter().zip(&mlp.biases) {
            // Matrix data may be strided; weights are created contiguous.
            if w.ld() != w.cols() {
                bail!("strided weight matrices are not supported by the PJRT ABI");
            }
            out.push(Tensor::new(vec![w.rows(), w.cols()], w.data().to_vec())?);
            out.push(Tensor::new(vec![b.len()], b.clone())?);
        }
        Ok(out)
    }
}

impl GradEngine for PjrtEngine {
    fn loss_and_grad(&mut self, mlp: &Mlp, x: &Matrix, y: &Matrix) -> Result<(f32, MlpGrads)> {
        if mlp.sizes != self.sizes {
            bail!(
                "artifact '{}' was lowered for sizes {:?}, model has {:?}",
                self.artifact,
                self.sizes,
                mlp.sizes
            );
        }
        if x.rows() != self.batch {
            bail!("artifact batch is {}, shard has {} rows", self.batch, x.rows());
        }
        let mut inputs = Self::params_to_tensors(mlp)?;
        inputs.push(Tensor::new(vec![x.rows(), x.cols()], x.data().to_vec())?);
        inputs.push(Tensor::new(vec![y.rows(), y.cols()], y.data().to_vec())?);
        let outputs = self.runtime.execute(&self.artifact, &inputs)?;
        if outputs.len() != 1 + 2 * mlp.n_layers() {
            bail!("mlp_grad returned {} outputs, expected {}", outputs.len(), 1 + 2 * mlp.n_layers());
        }
        let loss = outputs[0].item()?;
        let mut d_weights = Vec::with_capacity(mlp.n_layers());
        let mut d_biases = Vec::with_capacity(mlp.n_layers());
        for l in 0..mlp.n_layers() {
            let dw = &outputs[1 + 2 * l];
            let (r, c) = dw.as_2d()?;
            let mut m = Matrix::zeros(r, c);
            m.data_mut().copy_from_slice(dw.data());
            d_weights.push(m);
            d_biases.push(outputs[2 + 2 * l].data().to_vec());
        }
        Ok((loss, MlpGrads { d_weights, d_biases }))
    }

    fn name(&self) -> String {
        format!("pjrt/{}", self.artifact)
    }

    fn required_batch(&self) -> Option<usize> {
        Some(self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::data::Dataset;

    #[test]
    fn native_engine_matches_direct_backprop() {
        let mlp = Mlp::init(&[6, 10, 3], 3, Backend::Naive);
        let d = Dataset::gaussian_clusters(16, 6, 3, 0.2, 4);
        let (x, y) = d.slice(0, 16);
        let (l_direct, g_direct) = mlp.loss_and_grad(&x, &y);
        let mut engine = NativeEngine::new(Backend::Simd);
        let (l_eng, g_eng) = engine.loss_and_grad(&mlp, &x, &y).unwrap();
        assert!((l_direct - l_eng).abs() < 1e-4);
        for (a, b) in g_direct.d_weights.iter().zip(&g_eng.d_weights) {
            assert!(a.max_abs_diff(b) < 1e-4);
        }
        assert!(engine.name().contains("emmerald-sse"));
        assert_eq!(engine.required_batch(), None);
    }

    #[test]
    fn pjrt_engine_requires_artifacts() {
        assert!(PjrtEngine::new("/definitely/not/here").is_err());
    }

    #[test]
    fn default_engine_dispatches_and_matches_naive_backprop() {
        let mlp = Mlp::init(&[5, 8, 2], 9, Backend::Naive);
        let d = Dataset::gaussian_clusters(8, 5, 2, 0.3, 6);
        let (x, y) = d.slice(0, 8);
        let (l_ref, g_ref) = mlp.loss_and_grad(&x, &y);
        let mut engine = NativeEngine::default();
        assert!(engine.name().contains("dispatch"));
        let (l_got, g_got) = engine.loss_and_grad(&mlp, &x, &y).unwrap();
        assert!((l_ref - l_got).abs() < 1e-4);
        for (a, b) in g_ref.d_weights.iter().zip(&g_got.d_weights) {
            assert!(a.max_abs_diff(b) < 1e-4);
        }
    }
}
