//! The distributed training coordinator — the paper's §4 application.
//!
//! Ref [1] of the paper ("98¢/MFlop: ultra-large-scale neural-network
//! training on a PIII cluster") distributed synchronous SGD over 196
//! Pentium III nodes with Emmerald as the compute kernel. This module
//! rebuilds that system at process scale:
//!
//! * [`engine`] — the per-worker gradient engine. Two implementations:
//!   native Rust backprop over [`crate::blas`] (pick any backend), and the
//!   PJRT engine executing the AOT-lowered JAX/Pallas `mlp_grad` artifact —
//!   the full three-layer stack on the hot path.
//! * [`leader`] — the synchronous data-parallel loop: shard batches,
//!   broadcast parameters, collect gradients, average ([`crate::nn::sgd`]),
//!   update, and meter flops. Thread-per-worker (the cluster analogue) or
//!   sequential (single-process) execution; worker failures are rerouted.
//! * [`cluster`] — the 1999 cluster model: node price book, ring-allreduce
//!   communication cost, sustained-GFlop/s and ¢/MFlop/s accounting that
//!   regenerates the paper's 152 GFlop/s @ 98¢ figures.

pub mod cluster;
pub mod engine;
pub mod leader;

pub use cluster::ClusterSpec;
pub use engine::{EngineFactory, GradEngine, NativeEngine, PjrtEngine};
pub use leader::{Coordinator, StepStats, TrainConfig, TrainReport};
