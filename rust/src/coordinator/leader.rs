//! The synchronous data-parallel training loop (leader + workers).
//!
//! Per step: the leader snapshots the parameters, dispatches one batch
//! shard per worker, collects per-shard gradients, averages them weighted
//! by shard size (so the result equals the serial full-batch gradient —
//! tested in `nn::sgd`), applies SGD, and meters flops.
//!
//! Workers run on their own threads with engines constructed in-thread
//! (see [`super::engine::EngineFactory`]); a worker whose engine fails has
//! its shard rerouted to a healthy worker, mirroring the restartable
//! training of the paper's cluster application.

use anyhow::{bail, Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::engine::{EngineFactory, GradEngine};
use crate::blas::Matrix;
use crate::nn::data::Dataset;
use crate::nn::mlp::{Mlp, MlpGrads};
use crate::nn::sgd::{average_grads, Sgd};
use crate::util::timer::Stopwatch;

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Number of workers (cluster nodes).
    pub workers: usize,
    /// Samples per worker per step.
    pub shard_batch: usize,
    /// Training steps.
    pub steps: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// Print a progress line every `log_every` steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { workers: 4, shard_batch: 64, steps: 50, lr: 0.2, log_every: 10 }
    }
}

/// Per-step record.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    /// Step index.
    pub step: usize,
    /// Batch-weighted mean loss across shards.
    pub loss: f32,
    /// Wall-clock seconds for the step (dispatch → update).
    pub seconds: f64,
    /// Aggregate MFlop/s across all workers for this step.
    pub mflops: f64,
}

/// Full training-run record.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Per-step stats (loss curve).
    pub steps: Vec<StepStats>,
    /// Loss at the final step.
    pub final_loss: f32,
    /// Accuracy over the training set at the end.
    pub final_accuracy: f32,
    /// Total useful flops executed.
    pub total_flops: f64,
    /// Total wall-clock seconds.
    pub wall_seconds: f64,
    /// Number of shards rerouted due to worker failure.
    pub rerouted: usize,
}

impl TrainReport {
    /// Sustained MFlop/s over the whole run.
    pub fn sustained_mflops(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            self.total_flops / self.wall_seconds / 1e6
        }
    }

    /// Loss of the first recorded step.
    pub fn first_loss(&self) -> f32 {
        self.steps.first().map(|s| s.loss).unwrap_or(f32::NAN)
    }
}

enum WorkMsg {
    Step { step: usize, params: Arc<Mlp>, x: Matrix, y: Matrix },
    Stop,
}

enum ResultMsg {
    Done { n: usize, loss: f32, grads: MlpGrads },
    Failed { worker: usize, step: usize, x: Matrix, y: Matrix, error: String },
}

/// The leader: owns parameters, the dataset and the step loop.
pub struct Coordinator {
    cfg: TrainConfig,
    mlp: Mlp,
    data: Dataset,
}

impl Coordinator {
    /// New coordinator over an initialised model and dataset.
    pub fn new(cfg: TrainConfig, mlp: Mlp, data: Dataset) -> Result<Self> {
        if cfg.workers == 0 || cfg.shard_batch == 0 || cfg.steps == 0 {
            bail!("workers, shard_batch and steps must all be positive");
        }
        if data.len() < cfg.shard_batch {
            bail!("dataset ({} samples) smaller than one shard ({})", data.len(), cfg.shard_batch);
        }
        if data.x.cols() != mlp.sizes[0] {
            bail!("dataset features {} != model input {}", data.x.cols(), mlp.sizes[0]);
        }
        Ok(Self { cfg, mlp, data })
    }

    /// Current parameters (e.g. for evaluation after training).
    pub fn model(&self) -> &Mlp {
        &self.mlp
    }

    /// Deterministic shard for (step, worker): contiguous `shard_batch`
    /// rows, round-robin over the dataset.
    fn shard(&self, step: usize, worker: usize) -> (Matrix, Matrix) {
        let n = self.data.len();
        let b = self.cfg.shard_batch;
        let nshards = n / b; // full shards only (fixed-size engine ABI)
        let idx = (step * self.cfg.workers + worker) % nshards.max(1);
        self.data.slice(idx * b, b)
    }

    /// Train with one engine on the caller's thread (shards processed
    /// sequentially; the aggregation logic is identical to threaded mode).
    pub fn train_sequential(&mut self, engine: &mut dyn GradEngine) -> Result<TrainReport> {
        if let Some(rb) = engine.required_batch() {
            if rb != self.cfg.shard_batch {
                bail!("engine requires batch {rb}, config has {}", self.cfg.shard_batch);
            }
        }
        let sgd = Sgd::new(self.cfg.lr);
        let wall = Stopwatch::start();
        let mut steps = Vec::with_capacity(self.cfg.steps);
        let mut total_flops = 0.0;
        for step in 0..self.cfg.steps {
            let t = Stopwatch::start();
            // One multi-shard call per step: batching engines (the native
            // one) fold the shared-weight GEMMs across the whole step.
            let shards: Vec<(Matrix, Matrix)> =
                (0..self.cfg.workers).map(|w| self.shard(step, w)).collect();
            let results = engine
                .loss_and_grad_multi(&self.mlp, &shards)
                .with_context(|| format!("step {step}"))?;
            if results.len() != shards.len() {
                bail!("engine returned {} results for {} shards", results.len(), shards.len());
            }
            let mut parts = Vec::with_capacity(self.cfg.workers);
            let mut loss_sum = 0.0f64;
            for ((x, _), (loss, grads)) in shards.iter().zip(results) {
                loss_sum += loss as f64 * x.rows() as f64;
                parts.push((x.rows(), grads));
            }
            let total_n: usize = parts.iter().map(|(n, _)| n).sum();
            let avg = average_grads(&parts, &self.mlp);
            sgd.apply(&mut self.mlp, &avg);
            let seconds = t.seconds();
            let flops =
                self.mlp.train_step_flops(self.cfg.shard_batch) * self.cfg.workers as f64;
            total_flops += flops;
            let stats = StepStats {
                step,
                loss: (loss_sum / total_n as f64) as f32,
                seconds,
                mflops: flops / seconds / 1e6,
            };
            self.log(&stats);
            steps.push(stats);
        }
        self.finish(steps, total_flops, wall.seconds(), 0)
    }

    /// Train with one thread per worker (engines built in-thread by the
    /// factory — the process-scale analogue of the paper's cluster).
    pub fn train_threaded(&mut self, factory: Arc<EngineFactory>) -> Result<TrainReport> {
        let workers = self.cfg.workers;
        let (res_tx, res_rx): (Sender<ResultMsg>, Receiver<ResultMsg>) = channel();
        let mut work_txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let (tx, rx) = channel::<WorkMsg>();
            work_txs.push(tx);
            let res_tx = res_tx.clone();
            let factory = Arc::clone(&factory);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("emmerald-trainer-{wid}"))
                    .spawn(move || worker_loop(wid, rx, res_tx, factory))
                    .expect("spawn trainer"),
            );
        }
        drop(res_tx);

        let sgd = Sgd::new(self.cfg.lr);
        let wall = Stopwatch::start();
        let mut steps = Vec::with_capacity(self.cfg.steps);
        let mut total_flops = 0.0;
        let mut rerouted = 0usize;
        let mut alive: Vec<bool> = vec![true; workers];

        let run = (|| -> Result<()> {
            for step in 0..self.cfg.steps {
                let t = Stopwatch::start();
                let params = Arc::new(self.mlp.clone());
                let mut outstanding = 0usize;
                for w in 0..workers {
                    if !alive[w] {
                        continue;
                    }
                    let (x, y) = self.shard(step, w);
                    work_txs[w]
                        .send(WorkMsg::Step { step, params: Arc::clone(&params), x, y })
                        .with_context(|| format!("worker {w} hung up"))?;
                    outstanding += 1;
                }
                if outstanding == 0 {
                    bail!("all workers failed");
                }
                let mut parts = Vec::with_capacity(outstanding);
                let mut loss_sum = 0.0f64;
                while outstanding > 0 {
                    match res_rx.recv().context("all workers disconnected")? {
                        ResultMsg::Done { n, loss, grads } => {
                            loss_sum += loss as f64 * n as f64;
                            parts.push((n, grads));
                            outstanding -= 1;
                        }
                        ResultMsg::Failed { worker, step: s, x, y, error } => {
                            // Mark dead and reroute the shard to a healthy
                            // worker (paper's cluster survives node loss).
                            eprintln!("[leader] worker {worker} failed at step {s}: {error}");
                            alive[worker] = false;
                            rerouted += 1;
                            let target = alive
                                .iter()
                                .position(|&a| a)
                                .context("no healthy workers left")?;
                            work_txs[target]
                                .send(WorkMsg::Step {
                                    step: s,
                                    params: Arc::clone(&params),
                                    x,
                                    y,
                                })
                                .context("reroute send failed")?;
                        }
                    }
                }
                let total_n: usize = parts.iter().map(|(n, _)| n).sum();
                let avg = average_grads(&parts, &self.mlp);
                sgd.apply(&mut self.mlp, &avg);
                let seconds = t.seconds();
                let flops = self.mlp.train_step_flops(self.cfg.shard_batch) * parts.len() as f64;
                total_flops += flops;
                let stats = StepStats {
                    step,
                    loss: (loss_sum / total_n as f64) as f32,
                    seconds,
                    mflops: flops / seconds / 1e6,
                };
                self.log(&stats);
                steps.push(stats);
            }
            Ok(())
        })();

        for tx in &work_txs {
            let _ = tx.send(WorkMsg::Stop);
        }
        for h in handles {
            let _ = h.join();
        }
        run?;
        self.finish(steps, total_flops, wall.seconds(), rerouted)
    }

    fn log(&self, s: &StepStats) {
        if self.cfg.log_every > 0 && s.step % self.cfg.log_every == 0 {
            println!(
                "[leader] step {:>4}  loss {:.4}  {:.1} MFlop/s  ({:.1} ms)",
                s.step,
                s.loss,
                s.mflops,
                s.seconds * 1e3
            );
        }
    }

    fn finish(
        &self,
        steps: Vec<StepStats>,
        total_flops: f64,
        wall_seconds: f64,
        rerouted: usize,
    ) -> Result<TrainReport> {
        let final_loss = steps.last().map(|s| s.loss).context("no steps recorded")?;
        // Evaluate on (up to) the first 512 samples.
        let n_eval = self.data.len().min(512);
        let (x, y) = self.data.slice(0, n_eval);
        let final_accuracy = Mlp::accuracy(&self.mlp.forward(&x), &y);
        Ok(TrainReport { steps, final_loss, final_accuracy, total_flops, wall_seconds, rerouted })
    }
}

fn worker_loop(
    wid: usize,
    rx: Receiver<WorkMsg>,
    tx: Sender<ResultMsg>,
    factory: Arc<EngineFactory>,
) {
    let mut engine: Box<dyn GradEngine> = match factory(wid) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[worker {wid}] engine construction failed: {e:#}");
            return; // leader sees the hangup on first send
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkMsg::Step { step, params, x, y } => {
                let n = x.rows();
                match engine.loss_and_grad(&params, &x, &y) {
                    Ok((loss, grads)) => {
                        if tx.send(ResultMsg::Done { n, loss, grads }).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(ResultMsg::Failed {
                            worker: wid,
                            step,
                            x,
                            y,
                            error: format!("{e:#}"),
                        });
                        return; // engine is considered dead
                    }
                }
            }
            WorkMsg::Stop => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::Backend;
    use crate::coordinator::engine::NativeEngine;

    fn setup(workers: usize, steps: usize) -> Coordinator {
        let mlp = Mlp::init(&[8, 16, 3], 1, Backend::Simd);
        let data = Dataset::gaussian_clusters(256, 8, 3, 0.3, 2);
        let cfg = TrainConfig { workers, shard_batch: 16, steps, lr: 0.5, log_every: 0 };
        Coordinator::new(cfg, mlp, data).unwrap()
    }

    #[test]
    fn sequential_training_reduces_loss() {
        let mut c = setup(2, 40);
        let mut engine = NativeEngine::new(Backend::Simd);
        let r = c.train_sequential(&mut engine).unwrap();
        assert!(r.final_loss < r.first_loss() * 0.5, "{} -> {}", r.first_loss(), r.final_loss);
        assert!(r.final_accuracy > 0.8);
        assert_eq!(r.steps.len(), 40);
        assert!(r.sustained_mflops() > 0.0);
    }

    #[test]
    fn threaded_training_matches_structure() {
        let mut c = setup(3, 20);
        let factory: Arc<EngineFactory> =
            Arc::new(|_wid| Ok(Box::new(NativeEngine::new(Backend::Simd)) as Box<dyn GradEngine>));
        let r = c.train_threaded(factory).unwrap();
        assert_eq!(r.steps.len(), 20);
        assert!(r.final_loss < r.first_loss());
        assert_eq!(r.rerouted, 0);
    }

    #[test]
    fn threaded_equals_sequential_given_same_seeds() {
        // Synchronous SGD must be deterministic: threaded and sequential
        // runs see the same shards and average the same gradients.
        let mut c1 = setup(2, 8);
        let mut c2 = setup(2, 8);
        let mut engine = NativeEngine::new(Backend::Naive);
        let r1 = c1.train_sequential(&mut engine).unwrap();
        let factory: Arc<EngineFactory> =
            Arc::new(|_| Ok(Box::new(NativeEngine::new(Backend::Naive)) as Box<dyn GradEngine>));
        let r2 = c2.train_threaded(factory).unwrap();
        for (a, b) in r1.steps.iter().zip(&r2.steps) {
            assert!((a.loss - b.loss).abs() < 1e-5, "step {}: {} vs {}", a.step, a.loss, b.loss);
        }
        assert!(c1.model().weights[0].max_abs_diff(&c2.model().weights[0]) < 1e-5);
    }

    #[test]
    fn failed_worker_is_rerouted() {
        struct Flaky {
            inner: NativeEngine,
            fail: bool,
        }
        impl GradEngine for Flaky {
            fn loss_and_grad(
                &mut self,
                mlp: &Mlp,
                x: &Matrix,
                y: &Matrix,
            ) -> Result<(f32, MlpGrads)> {
                if self.fail {
                    bail!("injected failure");
                }
                self.inner.loss_and_grad(mlp, x, y)
            }
            fn name(&self) -> String {
                "flaky".into()
            }
        }
        let mut c = setup(3, 5);
        let factory: Arc<EngineFactory> = Arc::new(|wid| {
            Ok(Box::new(Flaky { inner: NativeEngine::new(Backend::Naive), fail: wid == 1 })
                as Box<dyn GradEngine>)
        });
        let r = c.train_threaded(factory).unwrap();
        assert_eq!(r.rerouted, 1, "exactly one shard rerouted");
        assert_eq!(r.steps.len(), 5, "training completed despite the failure");
    }

    #[test]
    fn config_validation() {
        let mlp = Mlp::init(&[4, 4, 2], 1, Backend::Naive);
        let data = Dataset::gaussian_clusters(8, 4, 2, 0.1, 1);
        let bad = TrainConfig { workers: 0, ..TrainConfig::default() };
        assert!(Coordinator::new(bad, mlp.clone(), data.clone()).is_err());
        let bad = TrainConfig { shard_batch: 999, ..TrainConfig::default() };
        assert!(Coordinator::new(bad, mlp.clone(), data.clone()).is_err());
        let mismatched = Dataset::gaussian_clusters(64, 7, 2, 0.1, 1);
        assert!(Coordinator::new(TrainConfig { shard_batch: 8, ..Default::default() }, mlp, mismatched).is_err());
    }
}
