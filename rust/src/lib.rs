//! # Emmerald
//!
//! A reproduction of *"General Matrix-Matrix Multiplication using SIMD
//! features of the PIII"* (Aberdeen & Baxter, ANU) as a production-shaped
//! Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! * [`blas`] — a Level-3 BLAS `SGEMM`/`DGEMM` interface with selectable
//!   backends, generic over **kernel triples**
//!   ([`gemm::element::GemmTriple`]: the homogeneous f32 and f64 triples
//!   through the whole kernel ladder via [`gemm::element::Element`],
//!   plus a compensated-f32 accumulation mode, plus the quantized
//!   `u8 × i8 → i32` inference tier — [`blas::qgemm`] /
//!   [`blas::qgemm_requant`], exact and bitwise-reproducible across
//!   serial/parallel/prepacked drivers). The production
//!   surface is the planned-execution API
//!   ([`blas::GemmContext`] / [`blas::GemmPlan`]: resolve kernel, block
//!   geometry and thread split once, execute many times, with
//!   [`blas::PackedA`]/[`blas::PackedB`] prepacked-operand handles);
//!   [`blas::sgemm`] / [`blas::dgemm`] remain as positional
//!   compatibility shims over it.
//! * [`gemm`] — the paper's contribution: the Emmerald SSE micro-kernel
//!   (five concurrent dot products in eight XMM registers), B re-buffering,
//!   L1/L2 cache blocking, prefetching and full inner-loop unrolling,
//!   together with the naive and ATLAS-proxy baselines it is evaluated
//!   against — plus [`gemm::tile`], the outer-product register-tiled
//!   AVX2+FMA tier (a 6×16 tile of `C` resident in registers) that heads
//!   the serial ladder on modern cores, [`gemm::dispatch`], the
//!   production entry point that picks a kernel per call from CPU
//!   features and shape heuristics, and [`gemm::batch`], the
//!   strided-batch GEMM driver behind [`blas::sgemm_batch`] and the
//!   tensor/conv batched paths.
//! * [`sim`] — a trace-driven Pentium III memory-hierarchy simulator
//!   (L1/L2/TLB + 4-wide SIMD timing model) used to reproduce the paper's
//!   figures in the paper's own units (MFlop/s on a 450 MHz PIII).
//! * [`autotune`] — an ATLAS-style empirical block-size tuner (the
//!   baseline methodology the paper compares against).
//! * [`nn`] + [`coordinator`] — the paper's §4 application: data-parallel
//!   neural-network training with SGEMM as the kernel, including the
//!   196-node cluster price/performance accounting.
//! * [`runtime`] — the PJRT execution path that loads the AOT-compiled
//!   JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and runs them from Rust.
//! * [`serve`] — GEMM-as-a-service: a process-wide [`serve::GemmService`]
//!   front end that admits concurrent GEMM/QGEMM requests under the
//!   thread budget, coalesces same-shape/same-weight requests into
//!   batches, and answers repeat traffic from a shape-keyed LRU cache of
//!   plans and packed weights ([`serve::PlanCache`]).
//! * [`bench`] + [`util`] — benchmarking and library substrates (the
//!   offline build carries no criterion/clap/proptest, so these are
//!   first-class modules here).
//!
//! ## Quick start
//!
//! ```
//! use emmerald::blas::{sgemm, Backend, Transpose};
//!
//! let (m, n, k) = (4, 3, 2);
//! let a = vec![1.0f32; m * k];
//! let b = vec![1.0f32; k * n];
//! let mut c = vec![0.0f32; m * n];
//! sgemm(
//!     Backend::Simd,
//!     Transpose::No,
//!     Transpose::No,
//!     m, n, k,
//!     1.0, &a, k, &b, n,
//!     0.0, &mut c, n,
//! )
//! .unwrap();
//! assert!(c.iter().all(|&x| (x - 2.0).abs() < 1e-6));
//! ```
//!
//! ## Safety & verification
//!
//! The kernel tiers are `unsafe` by necessity (raw-pointer hot loops,
//! vendor intrinsics); everything around them is not. The crate's
//! verification layer ([`util::ptr`], the `checked-ptr` feature, the
//! repo lint under `tools/lint`, and the Miri tier in `tests/miri_scalar.rs`)
//! is documented in the README's "Safety & verification" section.

// Every unsafe operation inside an `unsafe fn` must sit in an explicit
// `unsafe { }` block with its own justification — the 2024-edition rule,
// enforced today.
#![deny(unsafe_op_in_unsafe_fn)]
// Production code documents every unsafe block with a `// SAFETY:`
// comment (promoted to an error by CI's `-D warnings`); test code is
// exempt — its unsafe is exercising checked APIs, not upholding subtle
// invariants.
#![cfg_attr(not(test), warn(clippy::undocumented_unsafe_blocks))]

pub mod autotune;
pub mod bench;
pub mod blas;
pub mod coordinator;
pub mod gemm;
pub mod lapack;
pub mod nn;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
