//! Deterministic synthetic classification data.
//!
//! The paper's application (ref [1]) trained on a proprietary corpus we do
//! not have; per the substitution rule we use a synthetic-but-learnable
//! stand-in: Gaussian clusters, one per class, with configurable spread.
//! The task is easy enough that a falling loss curve demonstrates the
//! training loop works end-to-end, and generation is pure PRNG (no files).

use crate::blas::Matrix;
use crate::util::prng::Pcg32;

/// A synthetic classification dataset in one-hot form.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Inputs, `n × features`.
    pub x: Matrix,
    /// One-hot targets, `n × classes`.
    pub y: Matrix,
    /// Integer labels (argmax of `y`).
    pub labels: Vec<usize>,
    /// Number of classes.
    pub classes: usize,
}

impl Dataset {
    /// Gaussian-cluster data: class `c`'s mean is a fixed random vector;
    /// samples are mean + `noise`·N(0,1).
    pub fn gaussian_clusters(
        n: usize,
        features: usize,
        classes: usize,
        noise: f32,
        seed: u64,
    ) -> Self {
        assert!(classes >= 2 && features > 0 && n > 0);
        let mut rng = Pcg32::new(seed);
        // Class means on the unit sphere-ish.
        let means: Vec<Vec<f32>> = (0..classes)
            .map(|_| (0..features).map(|_| rng.normal()).collect())
            .collect();
        let mut x = Matrix::zeros(n, features);
        let mut y = Matrix::zeros(n, classes);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = rng.range_usize(0, classes - 1);
            labels.push(c);
            for f in 0..features {
                x.set(i, f, means[c][f] + noise * rng.normal());
            }
            y.set(i, c, 1.0);
        }
        Self { x, y, labels, classes }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.x.rows()
    }

    /// True if empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy a contiguous sample range into new matrices (a batch shard).
    pub fn slice(&self, start: usize, count: usize) -> (Matrix, Matrix) {
        assert!(start + count <= self.len(), "slice out of range");
        let x = Matrix::from_fn(count, self.x.cols(), |r, c| self.x.get(start + r, c));
        let y = Matrix::from_fn(count, self.y.cols(), |r, c| self.y.get(start + r, c));
        (x, y)
    }

    /// Batch iterator boundaries: `(start, len)` pairs covering the set.
    pub fn batches(&self, batch: usize) -> Vec<(usize, usize)> {
        assert!(batch > 0);
        let mut out = Vec::new();
        let mut s = 0;
        while s < self.len() {
            let len = batch.min(self.len() - s);
            out.push((s, len));
            s += len;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let a = Dataset::gaussian_clusters(50, 8, 3, 0.1, 42);
        let b = Dataset::gaussian_clusters(50, 8, 3, 0.1, 42);
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.len(), 50);
        assert_eq!(a.x.cols(), 8);
        assert_eq!(a.y.cols(), 3);
    }

    #[test]
    fn onehot_is_consistent() {
        let d = Dataset::gaussian_clusters(30, 4, 5, 0.2, 7);
        for i in 0..d.len() {
            let mut ones = 0;
            for c in 0..5 {
                if d.y.get(i, c) == 1.0 {
                    ones += 1;
                    assert_eq!(c, d.labels[i]);
                }
            }
            assert_eq!(ones, 1);
        }
    }

    #[test]
    fn all_classes_appear() {
        let d = Dataset::gaussian_clusters(200, 4, 4, 0.1, 3);
        for c in 0..4 {
            assert!(d.labels.iter().any(|&l| l == c), "class {c} missing");
        }
    }

    #[test]
    fn slice_and_batches() {
        let d = Dataset::gaussian_clusters(10, 3, 2, 0.1, 1);
        let (x, y) = d.slice(4, 3);
        assert_eq!(x.rows(), 3);
        assert_eq!(y.rows(), 3);
        assert_eq!(x.get(0, 0), d.x.get(4, 0));
        let b = d.batches(4);
        assert_eq!(b, vec![(0, 4), (4, 4), (8, 2)]);
    }

    #[test]
    fn clusters_are_separable_at_low_noise() {
        // Nearest-mean classification should be near-perfect at noise 0.05.
        let d = Dataset::gaussian_clusters(100, 16, 3, 0.05, 9);
        let mut means = vec![vec![0.0f32; 16]; 3];
        let mut counts = [0usize; 3];
        for i in 0..d.len() {
            counts[d.labels[i]] += 1;
            for f in 0..16 {
                means[d.labels[i]][f] += d.x.get(i, f);
            }
        }
        for c in 0..3 {
            for f in 0..16 {
                means[c][f] /= counts[c] as f32;
            }
        }
        let mut correct = 0;
        for i in 0..d.len() {
            let mut best = (f32::INFINITY, 0);
            for c in 0..3 {
                let dist: f32 =
                    (0..16).map(|f| (d.x.get(i, f) - means[c][f]).powi(2)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            correct += usize::from(best.1 == d.labels[i]);
        }
        assert!(correct as f32 / d.len() as f32 > 0.95);
    }
}
