//! A single dense layer with a quantized-inference path.
//!
//! [`Linear`] is the standalone `y = act(x·W + b)` building block (the
//! MLP in [`super::mlp`] keeps its own fused training path; this type is
//! the inference-oriented surface the quantized tier plugs into). The
//! interesting part is [`Linear::quantize_weights`] /
//! [`Linear::forward_quantized`]:
//!
//! * **Weights** are quantized per output channel, symmetric i8:
//!   `scale_j = max|W[:,j]| / 127`, `q = round(w / scale_j)` clamped to
//!   `[−127, 127]`. The clamp deliberately excludes `−128` — the AVX2
//!   kernel's `vpsignb` cannot negate it, so quantized weights always
//!   stay on the fast path (see [`crate::gemm::quant`]).
//! * **Activations** are quantized per row at forward time, affine u8:
//!   the row's `[min, 0] ∪ [0, max]` range maps onto `[0, 255]` with a
//!   zero point, so the layer input never needs to be centred.
//! * The GEMM runs exactly in i32 and dequantizes in the writeback via
//!   [`Requant`] — zero-point correction, `a_scale[r]·w_scale[c]`, bias
//!   and activation in one per-element pass, bitwise identical across
//!   scalar/AVX2/parallel/prepacked drivers.
//!
//! Weight packing happens once ([`QuantizedLinear`] owns the packed
//! panels and column sums); each forward only quantizes the activations
//! and runs the integer GEMM — the weight-stationary inference shape.

use crate::blas::{BlasError, GemmContext, Matrix, Transpose};
use crate::gemm::epilogue::{Activation, Epilogue, Requant};
use crate::gemm::quant::QPackedB;
use crate::util::prng::Pcg32;

/// Dense layer parameters: `weight` is `fan_in × fan_out`, the optional
/// bias has `fan_out` entries, and `activation` applies element-wise to
/// the output.
#[derive(Clone, Debug, PartialEq)]
pub struct Linear {
    /// Weight matrix, `fan_in × fan_out`.
    pub weight: Matrix,
    /// Per-output-channel bias (length `fan_out`), if any.
    pub bias: Option<Vec<f32>>,
    /// Element-wise output activation.
    pub activation: Activation,
}

impl Linear {
    /// Wrap existing parameters.
    pub fn new(weight: Matrix, bias: Option<Vec<f32>>, activation: Activation) -> Self {
        if let Some(b) = &bias {
            assert_eq!(b.len(), weight.cols(), "bias length vs fan_out");
        }
        Self { weight, bias, activation }
    }

    /// Glorot-ish random init (deterministic in `seed`), zero bias.
    pub fn init(fan_in: usize, fan_out: usize, seed: u64, activation: Activation) -> Self {
        let mut rng = Pcg32::new(seed);
        let scale = (2.0 / (fan_in + fan_out) as f32).sqrt();
        let mut w = Matrix::zeros(fan_in, fan_out);
        for v in w.data_mut() {
            *v = rng.normal() * scale;
        }
        Self { weight: w, bias: Some(vec![0.0; fan_out]), activation }
    }

    /// Input width.
    pub fn fan_in(&self) -> usize {
        self.weight.rows()
    }

    /// Output width.
    pub fn fan_out(&self) -> usize {
        self.weight.cols()
    }

    /// The layer's fused epilogue (bias + activation).
    fn epilogue(&self) -> Epilogue {
        let mut ep = Epilogue::new().activation(self.activation);
        if let Some(b) = &self.bias {
            ep = ep.bias_row(b.clone());
        }
        ep
    }

    /// Full-precision forward: `act(x·W + b)` through a planned f32 GEMM
    /// on `ctx` with the bias/activation fused into the writeback.
    pub fn forward(&self, ctx: &GemmContext, x: &Matrix) -> Result<Matrix, BlasError> {
        assert_eq!(x.cols(), self.fan_in(), "input width mismatch");
        let mut y = Matrix::zeros(x.rows(), self.fan_out());
        let plan = ctx
            .gemm()
            .lda(x.ld())
            .ldb(self.weight.ld())
            .epilogue(self.epilogue())
            .plan(x.rows(), self.fan_out(), self.fan_in())?;
        plan.run(x.data(), self.weight.data(), y.data_mut())?;
        Ok(y)
    }

    /// Quantize the weights per output channel (symmetric i8, clamped to
    /// `±127`) and pack them for the quantized kernel. The handle stays
    /// valid while the weights are unchanged — quantize once, run many.
    pub fn quantize_weights(&self, ctx: &GemmContext) -> QuantizedLinear {
        let (fan_in, fan_out) = (self.fan_in(), self.fan_out());
        let mut w_scale = vec![1.0f32; fan_out];
        for (j, s) in w_scale.iter_mut().enumerate() {
            let mut amax = 0.0f32;
            for i in 0..fan_in {
                amax = amax.max(self.weight.get(i, j).abs());
            }
            if amax > 0.0 {
                *s = amax / 127.0;
            }
        }
        let q = Matrix::<i8>::from_fn(fan_in, fan_out, |i, j| {
            (self.weight.get(i, j) / w_scale[j]).round().clamp(-127.0, 127.0) as i8
        });
        let packed = ctx
            .qpack_b(Transpose::No, fan_in, fan_out, q.data(), q.ld())
            .expect("weight matrix is a valid view");
        QuantizedLinear {
            ctx: ctx.clone(),
            packed,
            w_scale,
            bias: self.bias.clone(),
            activation: self.activation,
            fan_in,
        }
    }

    /// Like [`quantize_weights`](Self::quantize_weights), but the packed
    /// integer panels come from the GEMM service's shared cache (keyed
    /// by the quantized bytes' content hash): two instances of the same
    /// layer — or the same model loaded twice — share **one** packing
    /// process-wide, and a cache hit is an `Arc` bump, not a repack.
    /// The quantization itself is identical, so forwards through the
    /// returned handle are bitwise equal to the uncached path.
    pub fn quantize_weights_served(&self, svc: &crate::serve::GemmService) -> QuantizedLinear {
        let (fan_in, fan_out) = (self.fan_in(), self.fan_out());
        let mut w_scale = vec![1.0f32; fan_out];
        for (j, s) in w_scale.iter_mut().enumerate() {
            let mut amax = 0.0f32;
            for i in 0..fan_in {
                amax = amax.max(self.weight.get(i, j).abs());
            }
            if amax > 0.0 {
                *s = amax / 127.0;
            }
        }
        let q = Matrix::<i8>::from_fn(fan_in, fan_out, |i, j| {
            (self.weight.get(i, j) / w_scale[j]).round().clamp(-127.0, 127.0) as i8
        });
        let (_, packed) = svc
            .cached_qpack_b(Transpose::No, fan_in, fan_out, q.data(), q.ld())
            .expect("weight matrix is a valid view");
        QuantizedLinear {
            ctx: svc.context().clone(),
            packed,
            w_scale,
            bias: self.bias.clone(),
            activation: self.activation,
            fan_in,
        }
    }

    /// Quantized forward: per-row affine u8 quantization of `x`, the
    /// exact integer GEMM against the prepacked weights, and the fused
    /// dequantizing writeback. `q` must come from this layer's
    /// [`quantize_weights`](Self::quantize_weights).
    pub fn forward_quantized(&self, q: &QuantizedLinear, x: &Matrix) -> Result<Matrix, BlasError> {
        assert_eq!(q.fan_in, self.fan_in(), "quantized weights are for a different layer");
        q.forward(x)
    }
}

/// Quantized, packed form of a [`Linear`] layer's weights (plus the
/// layer's bias/activation, which ride the [`Requant`] writeback).
pub struct QuantizedLinear {
    ctx: GemmContext,
    packed: QPackedB,
    w_scale: Vec<f32>,
    bias: Option<Vec<f32>>,
    activation: Activation,
    fan_in: usize,
}

impl QuantizedLinear {
    /// Per-output-channel weight scales.
    pub fn weight_scales(&self) -> &[f32] {
        &self.w_scale
    }

    /// Bytes held by the packed integer panels (diagnostic).
    pub fn bytes(&self) -> usize {
        self.packed.bytes()
    }

    /// The packed integer panels (diagnostic; lets callers verify cache
    /// sharing via [`QPackedB::shares_storage`]).
    pub fn packed(&self) -> &QPackedB {
        &self.packed
    }

    /// Quantized forward pass (see [`Linear::forward_quantized`]).
    pub fn forward(&self, x: &Matrix) -> Result<Matrix, BlasError> {
        assert_eq!(x.cols(), self.fan_in, "input width mismatch");
        let (xq, a_scale, a_zp) = quantize_rows(x);
        let mut rq = Requant::per_row(a_scale, a_zp, self.w_scale.clone());
        if let Some(b) = &self.bias {
            rq = rq.bias(b.clone());
        }
        rq = rq.activation(self.activation);
        let mut y = Matrix::zeros(x.rows(), self.packed.n());
        self.ctx
            .qgemm_requant_packed_b(Transpose::No, xq.view(), &self.packed, y.view_mut(), &rq)?;
        Ok(y)
    }
}

/// Per-row affine u8 quantization: row `r` maps `[min(0, min_r),
/// max(0, max_r)]` onto `[0, 255]`, so `x ≈ a_scale[r] · (q − a_zp[r])`
/// with the zero point always representable. Returns the quantized
/// matrix and the per-row scales/zero points [`Requant`] consumes.
pub fn quantize_rows(x: &Matrix) -> (Matrix<u8>, Vec<f32>, Vec<i32>) {
    let (m, n) = (x.rows(), x.cols());
    let mut a_scale = vec![1.0f32; m];
    let mut a_zp = vec![0i32; m];
    for r in 0..m {
        let (mut lo, mut hi) = (0.0f32, 0.0f32);
        for c in 0..n {
            let v = x.get(r, c);
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi > lo {
            let scale = (hi - lo) / 255.0;
            a_scale[r] = scale;
            a_zp[r] = (-lo / scale).round().clamp(0.0, 255.0) as i32;
        }
    }
    let q = Matrix::<u8>::from_fn(m, n, |r, c| {
        ((x.get(r, c) / a_scale[r]).round() as i32 + a_zp[r]).clamp(0, 255) as u8
    });
    (q, a_scale, a_zp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_serial() -> GemmContext {
        GemmContext::new(crate::gemm::DispatchConfig {
            threads: 1,
            ..crate::gemm::DispatchConfig::default()
        })
    }

    #[test]
    fn quantize_rows_roundtrips_within_one_step() {
        let x = Matrix::from_fn(4, 9, |r, c| ((r * 9 + c) as f32 * 0.37).sin() * (r + 1) as f32);
        let (q, s, zp) = quantize_rows(&x);
        for r in 0..4 {
            for c in 0..9 {
                let deq = s[r] * (q.get(r, c) as i32 - zp[r]) as f32;
                assert!(
                    (deq - x.get(r, c)).abs() <= s[r] * 0.75,
                    "({r},{c}): {} vs {}",
                    deq,
                    x.get(r, c)
                );
            }
        }
        // All-zero rows quantize to exactly zero.
        let z = Matrix::zeros(2, 5);
        let (qz, sz, zpz) = quantize_rows(&z);
        assert!(qz.data().iter().all(|&v| v == 0));
        assert_eq!((sz[0], zpz[0]), (1.0, 0));
    }

    #[test]
    fn quantized_weights_avoid_neg128() {
        let ctx = ctx_serial();
        // Weights with a dominant negative entry per channel: symmetric
        // quantization must clamp at −127, never −128.
        let layer = Linear::new(
            Matrix::from_fn(16, 8, |i, j| if i == j { -3.0 } else { 0.01 * (i as f32 - 8.0) }),
            None,
            Activation::None,
        );
        let q = layer.quantize_weights(&ctx);
        assert!(!q.packed.has_neg128(), "symmetric clamp must keep the fast path");
    }

    #[test]
    fn quantized_forward_matches_manual_dequant_bitwise() {
        let ctx = ctx_serial();
        let layer = Linear::init(12, 7, 0xA11CE, Activation::Relu);
        let q = layer.quantize_weights(&ctx);
        let x = Matrix::from_fn(5, 12, |r, c| ((r * 12 + c) as f32 * 0.21).cos());
        let got = layer.forward_quantized(&q, &x).unwrap();
        // Manual reference: same quantization, naive widening integer
        // GEMM, same Requant scalar function — must agree bitwise.
        let (xq, a_scale, a_zp) = quantize_rows(&x);
        let mut wq = Matrix::<i8>::zeros(12, 7);
        for j in 0..7 {
            for i in 0..12 {
                let v = (layer.weight.get(i, j) / q.w_scale[j]).round().clamp(-127.0, 127.0);
                wq.set(i, j, v as i8);
            }
        }
        let mut raw = Matrix::<i32>::zeros(5, 7);
        crate::gemm::quant::qgemm_reference(
            Transpose::No,
            Transpose::No,
            xq.view(),
            wq.view(),
            &mut raw.view_mut(),
            false,
        );
        let mut rq = Requant::per_row(a_scale, a_zp, q.w_scale.clone());
        rq = rq.bias(layer.bias.clone().unwrap()).activation(Activation::Relu);
        for r in 0..5 {
            for c in 0..7 {
                let colsum: i32 = (0..12).map(|p| wq.get(p, c) as i32).sum();
                let want = rq.apply_scalar(raw.get(r, c), colsum, r, c);
                assert_eq!(got.get(r, c).to_bits(), want.to_bits(), "({r},{c})");
            }
        }
    }

    #[test]
    fn quantized_forward_approximates_f32_forward() {
        let ctx = ctx_serial();
        let layer = Linear::init(64, 10, 0xBEEF, Activation::None);
        let q = layer.quantize_weights(&ctx);
        let x = Matrix::from_fn(8, 64, |r, c| ((r * 64 + c) as f32 * 0.113).sin());
        let full = layer.forward(&ctx, &x).unwrap();
        let quantized = layer.forward_quantized(&q, &x).unwrap();
        let amax = full.data().iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        for (g, w) in quantized.data().iter().zip(full.data()) {
            assert!(
                (g - w).abs() <= 0.05 * amax,
                "quantization error too large: {g} vs {w} (amax {amax})"
            );
        }
    }
}
