//! A tanh MLP with SGEMM-powered forward and backward passes.
//!
//! Layer l computes `h_{l+1} = tanh(h_l W_l + b_l)` (linear on the output
//! layer); the loss is mean softmax cross-entropy. Backprop is hand-derived
//! and expressed as SGEMMs:
//!
//! ```text
//! dW_l = h_lᵀ · dz_l          (sgemm, transa = Yes)
//! dh_l = dz_l · W_lᵀ          (sgemm, transb = Yes)
//! dz_{l-1} = dh_l ⊙ (1 - h_l²)
//! ```
//!
//! which matches the paper's application: *all* heavy math is GEMM.
//!
//! On the default backend the per-layer bias add and tanh ride the GEMM
//! itself as a fused [`Epilogue`] (row bias + [`Activation::Tanh`] on
//! hidden layers, bias only on the output layer): the kernels apply them
//! inside the `C` writeback, so the forward pass makes one traversal of
//! each activation matrix instead of two. Explicit kernel backends keep
//! the separate bias/activation pass — the ablation route.

use crate::blas::{
    sgemm, sgemm_batch, Activation, Backend, Epilogue, GemmContext, Matrix, PackedB, Transpose,
};
use crate::util::prng::Pcg32;

/// MLP parameters: per layer a weight matrix (fan_in × fan_out) and bias.
#[derive(Clone, Debug, PartialEq)]
pub struct Mlp {
    /// Layer sizes, e.g. `[256, 768, 768, 10]`.
    pub sizes: Vec<usize>,
    /// Weights, one per layer.
    pub weights: Vec<Matrix>,
    /// Biases, one per layer.
    pub biases: Vec<Vec<f32>>,
    /// Backend used for all SGEMM calls.
    pub backend: Backend,
}

/// Gradients with the same structure as the parameters.
#[derive(Clone, Debug)]
pub struct MlpGrads {
    /// dL/dW per layer.
    pub d_weights: Vec<Matrix>,
    /// dL/db per layer.
    pub d_biases: Vec<Vec<f32>>,
}

impl Mlp {
    /// Glorot-ish random init (deterministic in `seed`).
    pub fn init(sizes: &[usize], seed: u64, backend: Backend) -> Self {
        assert!(sizes.len() >= 2, "need at least input and output sizes");
        let mut rng = Pcg32::new(seed);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for (&fan_in, &fan_out) in sizes.iter().zip(&sizes[1..]) {
            let scale = (2.0 / (fan_in + fan_out) as f32).sqrt();
            let mut w = Matrix::zeros(fan_in, fan_out);
            for v in w.data_mut() {
                *v = rng.normal() * scale;
            }
            weights.push(w);
            biases.push(vec![0.0; fan_out]);
        }
        Self { sizes: sizes.to_vec(), weights, biases, backend }
    }

    /// Number of layers.
    pub fn n_layers(&self) -> usize {
        self.weights.len()
    }

    /// Total adjustable parameters.
    pub fn param_count(&self) -> usize {
        self.weights.iter().map(|w| w.rows() * w.cols()).sum::<usize>()
            + self.biases.iter().map(|b| b.len()).sum::<usize>()
    }

    /// The fused epilogue of layer `l`: row bias plus tanh on hidden
    /// layers, bias only on the output layer. `f32::tanh` backs both this
    /// and [`bias_activate`](Self::bias_activate), so the fused and
    /// separate-pass routes produce identical activations.
    fn layer_epilogue(&self, l: usize) -> Epilogue {
        let act = if l == self.n_layers() - 1 { Activation::None } else { Activation::Tanh };
        Epilogue::new().bias_row(self.biases[l].clone()).activation(act)
    }

    /// Bias + activation for layer `l`, in place (tanh on hidden layers,
    /// linear on the output layer) — the separate-pass twin of
    /// [`layer_epilogue`](Self::layer_epilogue), used by the explicit
    /// kernel backends.
    fn bias_activate(&self, z: &mut Matrix, l: usize) {
        let last = l == self.n_layers() - 1;
        let cols = z.cols();
        for r in 0..z.rows() {
            for c in 0..cols {
                let mut v = z.get(r, c) + self.biases[l][c];
                if !last {
                    v = v.tanh();
                }
                z.set(r, c, v);
            }
        }
    }

    /// Forward pass: returns per-layer activations, `acts[0] = x`,
    /// `acts[n] = logits` (length `n_layers + 1`).
    pub fn forward_all(&self, x: &Matrix) -> Vec<Matrix> {
        assert_eq!(x.cols(), self.sizes[0], "input width mismatch");
        let batch = x.rows();
        let fused = matches!(self.backend, Backend::Dispatch | Backend::Auto);
        let mut acts = vec![x.clone()];
        for l in 0..self.n_layers() {
            let w = &self.weights[l];
            let mut z = Matrix::zeros(batch, w.cols());
            if fused {
                // Bias + activation fused into the GEMM writeback.
                let plan = GemmContext::global()
                    .gemm()
                    .lda(acts[l].ld())
                    .ldb(w.ld())
                    .epilogue(self.layer_epilogue(l))
                    .plan(batch, w.cols(), w.rows())
                    .expect("validated shapes");
                plan.run(acts[l].data(), w.data(), z.data_mut()).expect("validated shapes");
            } else {
                sgemm(
                    self.backend,
                    Transpose::No,
                    Transpose::No,
                    batch,
                    w.cols(),
                    w.rows(),
                    1.0,
                    acts[l].data(),
                    acts[l].ld(),
                    w.data(),
                    w.ld(),
                    0.0,
                    z.data_mut(),
                    w.cols(),
                )
                .expect("forward sgemm");
                self.bias_activate(&mut z, l);
            }
            acts.push(z);
        }
        acts
    }

    /// Logits only.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.forward_all(x).pop().expect("nonempty activations")
    }

    /// Pre-pack every layer's weight matrix on `ctx` (paper §3
    /// re-buffering, hoisted out of the forward pass). The handle stays
    /// valid while the weights are unchanged — the inference /
    /// evaluation case — and is reused across every subsequent
    /// [`forward_packed`](Self::forward_packed) call and batch.
    pub fn pack_weights(&self, ctx: &GemmContext) -> PackedMlpWeights {
        let layers = self
            .weights
            .iter()
            .map(|w| {
                ctx.pack_b(Transpose::No, w.rows(), w.cols(), w.data(), w.ld())
                    .expect("weight matrices are valid views")
            })
            .collect();
        PackedMlpWeights { ctx: ctx.clone(), layers, sizes: self.sizes.clone() }
    }

    /// Forward pass through prepacked weights: each layer runs a planned
    /// GEMM with its weight panel already re-buffered, so repeated
    /// forward calls (inference, evaluation loops) skip all packing work.
    /// Bias and activation ride each layer's GEMM as its fused epilogue —
    /// the prepacked drivers apply them in the writeback, bit-identical
    /// to the packing path.
    ///
    /// If the context's tuned geometry changed since
    /// [`pack_weights`](Self::pack_weights) (an autotune install landed in
    /// between), the stale pack is bypassed and the layer falls back to
    /// the plain packing path — always correct, just without the
    /// prepacking win until the caller repacks.
    pub fn forward_packed(&self, packed: &PackedMlpWeights, x: &Matrix) -> Matrix {
        assert_eq!(packed.sizes, self.sizes, "packed weights are for a different architecture");
        assert_eq!(x.cols(), self.sizes[0], "input width mismatch");
        let batch = x.rows();
        let mut h = x.clone();
        for l in 0..self.n_layers() {
            let w = &self.weights[l];
            let plan = packed
                .ctx
                .gemm()
                .lda(h.ld())
                .ldb(w.ld())
                .epilogue(self.layer_epilogue(l))
                .plan(batch, w.cols(), w.rows())
                .expect("validated shapes");
            let mut z = Matrix::zeros(batch, w.cols());
            if plan.run_packed_b(h.data(), &packed.layers[l], z.data_mut()).is_err() {
                plan.run(h.data(), w.data(), z.data_mut()).expect("validated shapes");
            }
            h = z;
        }
        h
    }

    /// Forward pass through the GEMM service's shared caches
    /// ([`crate::serve::GemmService`]): each layer's plan comes from the
    /// plan cache and each weight panel from the packed-weight cache
    /// (keyed by content hash), so concurrent model instances — and
    /// repeated calls — share one packing of every weight process-wide.
    /// Executes the same plans over the same packed panels as
    /// [`forward_packed`](Self::forward_packed), so the logits are
    /// bitwise identical to it (and to the packing path).
    pub fn forward_served(&self, svc: &crate::serve::GemmService, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.sizes[0], "input width mismatch");
        let batch = x.rows();
        let mut h = x.clone();
        for l in 0..self.n_layers() {
            let w = &self.weights[l];
            let mut spec = crate::serve::PlanSpec::new(batch, w.cols(), w.rows());
            spec.lda = h.ld();
            spec.ldb = w.ld();
            spec.epilogue = Some(self.layer_epilogue(l));
            let plan = svc.cached_plan(&spec).expect("validated shapes");
            let (_, pb) = svc
                .cached_pack_b(Transpose::No, w.rows(), w.cols(), w.data(), w.ld())
                .expect("weight matrices are valid views");
            let mut z = Matrix::zeros(batch, w.cols());
            if plan.run_packed_b(h.data(), &pb, z.data_mut()).is_err() {
                plan.run(h.data(), w.data(), z.data_mut()).expect("validated shapes");
            }
            h = z;
        }
        h
    }

    /// Mean softmax cross-entropy over the row range `[r0, r1)` — the
    /// shared core of [`loss_from_logits`](Self::loss_from_logits) and the
    /// per-shard losses of
    /// [`loss_and_grad_sharded`](Self::loss_and_grad_sharded).
    fn loss_rows(logits: &Matrix, y_onehot: &Matrix, r0: usize, r1: usize) -> f32 {
        let mut total = 0.0f64;
        for r in r0..r1 {
            let mut maxv = f32::NEG_INFINITY;
            for c in 0..logits.cols() {
                maxv = maxv.max(logits.get(r, c));
            }
            let mut lse = 0.0f64;
            for c in 0..logits.cols() {
                lse += ((logits.get(r, c) - maxv) as f64).exp();
            }
            let lse = lse.ln() as f32 + maxv;
            for c in 0..logits.cols() {
                if y_onehot.get(r, c) != 0.0 {
                    total += (y_onehot.get(r, c) * (lse - logits.get(r, c))) as f64;
                }
            }
        }
        (total / (r1 - r0) as f64) as f32
    }

    /// Mean softmax cross-entropy of logits vs one-hot targets.
    pub fn loss_from_logits(logits: &Matrix, y_onehot: &Matrix) -> f32 {
        assert_eq!(logits.rows(), y_onehot.rows());
        assert_eq!(logits.cols(), y_onehot.cols());
        Self::loss_rows(logits, y_onehot, 0, logits.rows())
    }

    /// Loss + full gradients for a batch (one-hot targets). The whole
    /// batch is one shard of
    /// [`loss_and_grad_sharded`](Self::loss_and_grad_sharded), so the
    /// serial and batched backprop paths share one implementation.
    pub fn loss_and_grad(&self, x: &Matrix, y_onehot: &Matrix) -> (f32, MlpGrads) {
        self.loss_and_grad_sharded(x, y_onehot, 1).pop().expect("exactly one shard")
    }

    /// Loss + gradients for `shards` equal row-slices of one stacked
    /// batch, computed with **batched** GEMMs (ROADMAP "batched
    /// backprop"): the forward pass and the `dh` backward pass run once
    /// over the stacked rows (the shared-weight fold — every shard
    /// multiplies the same `W`), and the per-shard `dW = h_sᵀ·dz_s`
    /// gradients run as a single strided [`sgemm_batch`] per layer
    /// instead of `shards` serial SGEMMs.
    ///
    /// Shard `s` covers rows `[s·r, (s+1)·r)` with `r = x.rows()/shards`
    /// (`x.rows()` must divide evenly); the result matches calling
    /// [`loss_and_grad`](Self::loss_and_grad) on each slice.
    pub fn loss_and_grad_sharded(
        &self,
        x: &Matrix,
        y_onehot: &Matrix,
        shards: usize,
    ) -> Vec<(f32, MlpGrads)> {
        assert!(shards >= 1, "need at least one shard");
        let batch = x.rows();
        assert_eq!(y_onehot.rows(), batch);
        assert_eq!(
            y_onehot.cols(),
            *self.sizes.last().expect("at least two layer sizes"),
            "target width mismatch"
        );
        assert_eq!(
            batch % shards,
            0,
            "batch of {batch} rows does not split into {shards} equal shards"
        );
        let rows = batch / shards;
        let acts = self.forward_all(x);
        let logits = &acts[self.n_layers()];

        // Per-shard losses, and dz at the output normalised by the
        // *shard* size (each shard is its own backprop problem).
        let losses: Vec<f32> = (0..shards)
            .map(|s| Self::loss_rows(logits, y_onehot, s * rows, (s + 1) * rows))
            .collect();
        let mut dz = Matrix::zeros(batch, logits.cols());
        for r in 0..batch {
            let mut maxv = f32::NEG_INFINITY;
            for c in 0..logits.cols() {
                maxv = maxv.max(logits.get(r, c));
            }
            let mut denom = 0.0f32;
            for c in 0..logits.cols() {
                denom += (logits.get(r, c) - maxv).exp();
            }
            for c in 0..logits.cols() {
                let sm = (logits.get(r, c) - maxv).exp() / denom;
                dz.set(r, c, (sm - y_onehot.get(r, c)) / rows as f32);
            }
        }

        let mut grads: Vec<MlpGrads> = (0..shards).map(|_| MlpGrads::zeros_like(self)).collect();
        for l in (0..self.n_layers()).rev() {
            let h = &acts[l];
            let w = &self.weights[l];
            let (fan_in, fan_out) = (w.rows(), w.cols());
            // dW_s = h_sᵀ · dz_s for every shard in one strided batch:
            // item s's A is rows [s·r, (s+1)·r) of the stacked h (stored
            // r × fan_in at element offset s·r·ld), likewise for dz. The
            // single-shard case (the plain loss_and_grad path) writes
            // straight into the final gradient matrix; multi-shard output
            // goes through one staging slab (batched C must be one slab).
            let mut single = if shards == 1 { Matrix::zeros(fan_in, fan_out) } else { Matrix::zeros(0, 0) };
            let mut staged = if shards > 1 { vec![0.0f32; shards * fan_in * fan_out] } else { Vec::new() };
            let c_slab: &mut [f32] = if shards == 1 { single.data_mut() } else { &mut staged };
            sgemm_batch(
                self.backend,
                Transpose::Yes,
                Transpose::No,
                fan_in,
                fan_out,
                rows,
                1.0,
                h.data(),
                h.ld(),
                rows * h.ld(),
                dz.data(),
                dz.ld(),
                rows * dz.ld(),
                0.0,
                c_slab,
                fan_out,
                fan_in * fan_out,
                shards,
            )
            .expect("dW sgemm_batch");
            for (s, g) in grads.iter_mut().enumerate() {
                if shards == 1 {
                    g.d_weights[l] = std::mem::replace(&mut single, Matrix::zeros(0, 0));
                } else {
                    let mut dw = Matrix::zeros(fan_in, fan_out);
                    dw.data_mut()
                        .copy_from_slice(&staged[s * fan_in * fan_out..(s + 1) * fan_in * fan_out]);
                    g.d_weights[l] = dw;
                }
                let mut db = vec![0.0f32; fan_out];
                for r in s * rows..(s + 1) * rows {
                    for c in 0..fan_out {
                        db[c] += dz.get(r, c);
                    }
                }
                g.d_biases[l] = db;
            }
            if l > 0 {
                // dh = dz · Wᵀ over the whole stack at once (shared
                // weight; rows are independent), then tanh'. The NT layout
                // rides the dispatcher's parallel tier for big stacks —
                // each row slice packs its own Wᵀ panels (pack-on-split).
                let mut dh = Matrix::zeros(batch, fan_in);
                sgemm(
                    self.backend,
                    Transpose::No,
                    Transpose::Yes,
                    batch,
                    fan_in,
                    fan_out,
                    1.0,
                    dz.data(),
                    dz.ld(),
                    w.data(),
                    w.ld(),
                    0.0,
                    dh.data_mut(),
                    fan_in,
                )
                .expect("dh sgemm");
                for r in 0..batch {
                    for c in 0..fan_in {
                        let hv = acts[l].get(r, c);
                        dh.set(r, c, dh.get(r, c) * (1.0 - hv * hv));
                    }
                }
                dz = dh;
            }
        }
        losses.into_iter().zip(grads).collect()
    }

    /// Classification accuracy of logits vs one-hot targets.
    pub fn accuracy(logits: &Matrix, y_onehot: &Matrix) -> f32 {
        let batch = logits.rows();
        let mut correct = 0usize;
        for r in 0..batch {
            let (mut arg_l, mut max_l) = (0, f32::NEG_INFINITY);
            let (mut arg_y, mut max_y) = (0, f32::NEG_INFINITY);
            for c in 0..logits.cols() {
                if logits.get(r, c) > max_l {
                    max_l = logits.get(r, c);
                    arg_l = c;
                }
                if y_onehot.get(r, c) > max_y {
                    max_y = y_onehot.get(r, c);
                    arg_y = c;
                }
            }
            correct += usize::from(arg_l == arg_y);
        }
        correct as f32 / batch as f32
    }

    /// Flops for one forward+backward over `batch` rows (3 × forward GEMM
    /// flops — the same formula as `model.train_step_flops` in Python).
    pub fn train_step_flops(&self, batch: usize) -> f64 {
        let fwd: f64 = self
            .sizes
            .iter()
            .zip(&self.sizes[1..])
            .map(|(&i, &o)| 2.0 * batch as f64 * i as f64 * o as f64)
            .sum();
        3.0 * fwd
    }
}

/// Per-layer prepacked weight panels bound to the [`GemmContext`] that
/// packed them (created by [`Mlp::pack_weights`], consumed by
/// [`Mlp::forward_packed`]). Weight-stationary: pack once, run many.
pub struct PackedMlpWeights {
    ctx: GemmContext,
    layers: Vec<PackedB>,
    sizes: Vec<usize>,
}

impl PackedMlpWeights {
    /// Layer sizes the pack was built for.
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Total bytes held by the packed panels (diagnostic).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(PackedB::bytes).sum()
    }
}

impl MlpGrads {
    /// Element-wise sum with another gradient set.
    pub fn add_assign(&mut self, other: &MlpGrads) {
        assert_eq!(self.d_weights.len(), other.d_weights.len());
        for (a, b) in self.d_weights.iter_mut().zip(&other.d_weights) {
            for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
                *x += *y;
            }
        }
        for (a, b) in self.d_biases.iter_mut().zip(&other.d_biases) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += *y;
            }
        }
    }

    /// Scale all gradients by `s`.
    pub fn scale(&mut self, s: f32) {
        for w in &mut self.d_weights {
            for v in w.data_mut() {
                *v *= s;
            }
        }
        for b in &mut self.d_biases {
            for v in b.iter_mut() {
                *v *= s;
            }
        }
    }

    /// Zero-valued gradients matching a parameter structure.
    pub fn zeros_like(mlp: &Mlp) -> Self {
        Self {
            d_weights: mlp.weights.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect(),
            d_biases: mlp.biases.iter().map(|b| vec![0.0; b.len()]).collect(),
        }
    }

    /// Max absolute component (for tests / divergence watchdogs).
    pub fn max_abs(&self) -> f32 {
        let mut m = 0.0f32;
        for w in &self.d_weights {
            for v in w.data() {
                m = m.max(v.abs());
            }
        }
        for b in &self.d_biases {
            for v in b {
                m = m.max(v.abs());
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::Backend;

    fn onehot(labels: &[usize], classes: usize) -> Matrix {
        Matrix::from_fn(labels.len(), classes, |r, c| if labels[r] == c { 1.0 } else { 0.0 })
    }

    #[test]
    fn forward_shapes() {
        let mlp = Mlp::init(&[6, 8, 3], 1, Backend::Naive);
        let x = Matrix::random(5, 6, 2, -1.0, 1.0);
        let acts = mlp.forward_all(&x);
        assert_eq!(acts.len(), 3);
        assert_eq!((acts[1].rows(), acts[1].cols()), (5, 8));
        assert_eq!((acts[2].rows(), acts[2].cols()), (5, 3));
    }

    #[test]
    fn param_count() {
        let mlp = Mlp::init(&[4, 8, 2], 1, Backend::Naive);
        assert_eq!(mlp.param_count(), 4 * 8 + 8 + 8 * 2 + 2);
    }

    #[test]
    fn loss_at_init_is_log_nclasses() {
        let mlp = Mlp::init(&[10, 16, 7], 3, Backend::Naive);
        let x = Matrix::random(64, 10, 4, -1.0, 1.0);
        let y = onehot(&(0..64).map(|i| i % 7).collect::<Vec<_>>(), 7);
        let loss = Mlp::loss_from_logits(&mlp.forward(&x), &y);
        assert!((loss - (7.0f32).ln()).abs() < 0.5, "loss {loss}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut mlp = Mlp::init(&[5, 6, 3], 7, Backend::Naive);
        let x = Matrix::random(4, 5, 8, -1.0, 1.0);
        let y = onehot(&[0, 2, 1, 2], 3);
        let (_, grads) = mlp.loss_and_grad(&x, &y);
        let eps = 1e-3f32;
        // Spot-check several weight coordinates in both layers and a bias.
        for &(l, r, c) in &[(0usize, 0usize, 0usize), (0, 4, 5), (1, 3, 2), (1, 0, 1)] {
            let orig = mlp.weights[l].get(r, c);
            mlp.weights[l].set(r, c, orig + eps);
            let lp = Mlp::loss_from_logits(&mlp.forward(&x), &y);
            mlp.weights[l].set(r, c, orig - eps);
            let lm = Mlp::loss_from_logits(&mlp.forward(&x), &y);
            mlp.weights[l].set(r, c, orig);
            let fd = (lp - lm) / (2.0 * eps);
            let an = grads.d_weights[l].get(r, c);
            assert!(
                (fd - an).abs() < 5e-3 * (1.0 + fd.abs()),
                "W[{l}][{r},{c}]: fd={fd} analytic={an}"
            );
        }
        // A bias coordinate.
        let orig = mlp.biases[0][2];
        mlp.biases[0][2] = orig + eps;
        let lp = Mlp::loss_from_logits(&mlp.forward(&x), &y);
        mlp.biases[0][2] = orig - eps;
        let lm = Mlp::loss_from_logits(&mlp.forward(&x), &y);
        mlp.biases[0][2] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        assert!((fd - grads.d_biases[0][2]).abs() < 5e-3);
    }

    #[test]
    fn grads_identical_across_backends() {
        let mlp_n = Mlp::init(&[8, 12, 4], 11, Backend::Naive);
        let mut mlp_s = mlp_n.clone();
        mlp_s.backend = Backend::Simd;
        let x = Matrix::random(6, 8, 12, -1.0, 1.0);
        let y = onehot(&[0, 1, 2, 3, 0, 1], 4);
        let (l1, g1) = mlp_n.loss_and_grad(&x, &y);
        let (l2, g2) = mlp_s.loss_and_grad(&x, &y);
        assert!((l1 - l2).abs() < 1e-4);
        for (a, b) in g1.d_weights.iter().zip(&g2.d_weights) {
            assert!(a.max_abs_diff(b) < 1e-4);
        }
    }

    #[test]
    fn grad_utilities() {
        let mlp = Mlp::init(&[3, 4, 2], 5, Backend::Naive);
        let x = Matrix::random(2, 3, 6, -1.0, 1.0);
        let y = onehot(&[0, 1], 2);
        let (_, g) = mlp.loss_and_grad(&x, &y);
        let mut sum = MlpGrads::zeros_like(&mlp);
        sum.add_assign(&g);
        sum.add_assign(&g);
        sum.scale(0.5);
        // sum should now equal g.
        for (a, b) in sum.d_weights.iter().zip(&g.d_weights) {
            assert!(a.max_abs_diff(b) < 1e-6);
        }
        assert!(g.max_abs() > 0.0);
    }

    #[test]
    fn sharded_backprop_matches_per_shard_serial() {
        let mlp = Mlp::init(&[7, 10, 4], 21, Backend::Dispatch);
        let (shards, rows) = (3usize, 5usize);
        let batch = shards * rows;
        let x = Matrix::random(batch, 7, 22, -1.0, 1.0);
        let y = onehot(&(0..batch).map(|i| i % 4).collect::<Vec<_>>(), 4);
        let got = mlp.loss_and_grad_sharded(&x, &y, shards);
        assert_eq!(got.len(), shards);
        for s in 0..shards {
            let xs = Matrix::from_fn(rows, 7, |r, c| x.get(s * rows + r, c));
            let ys = Matrix::from_fn(rows, 4, |r, c| y.get(s * rows + r, c));
            let (loss_ref, grads_ref) = mlp.loss_and_grad(&xs, &ys);
            let (loss_got, grads_got) = &got[s];
            assert!(
                (loss_got - loss_ref).abs() < 1e-4,
                "shard {s}: loss {loss_got} vs {loss_ref}"
            );
            for (a, b) in grads_got.d_weights.iter().zip(&grads_ref.d_weights) {
                assert!(a.max_abs_diff(b) < 1e-4, "shard {s} dW mismatch");
            }
            for (a, b) in grads_got.d_biases.iter().zip(&grads_ref.d_biases) {
                for (x1, x2) in a.iter().zip(b) {
                    assert!((x1 - x2).abs() < 1e-4, "shard {s} db mismatch");
                }
            }
        }
    }

    #[test]
    fn sharded_backprop_single_shard_equals_loss_and_grad() {
        let mlp = Mlp::init(&[5, 9, 3], 31, Backend::Naive);
        let x = Matrix::random(6, 5, 32, -1.0, 1.0);
        let y = onehot(&[0, 1, 2, 0, 1, 2], 3);
        let (l_ref, g_ref) = mlp.loss_and_grad(&x, &y);
        let mut got = mlp.loss_and_grad_sharded(&x, &y, 1);
        assert_eq!(got.len(), 1);
        let (l_got, g_got) = got.pop().unwrap();
        assert!((l_got - l_ref).abs() < 1e-5);
        for (a, b) in g_got.d_weights.iter().zip(&g_ref.d_weights) {
            assert!(a.max_abs_diff(b) < 1e-5);
        }
    }

    #[test]
    fn packed_forward_matches_plain_forward() {
        // Local context: immune to concurrent global install_tuned calls.
        let ctx = crate::blas::GemmContext::new(crate::gemm::DispatchConfig {
            threads: 1,
            ..crate::gemm::DispatchConfig::default()
        });
        let mlp = Mlp::init(&[6, 12, 5], 41, Backend::Dispatch);
        let packed = mlp.pack_weights(&ctx);
        assert_eq!(packed.sizes(), &[6, 12, 5]);
        assert!(packed.bytes() > 0);
        // Reused across several batches (the evaluation-loop pattern).
        for (seed, batch) in [(42u64, 1usize), (43, 4), (44, 9)] {
            let x = Matrix::random(batch, 6, seed, -1.0, 1.0);
            let plain = mlp.forward(&x);
            let fast = mlp.forward_packed(&packed, &x);
            assert_eq!((fast.rows(), fast.cols()), (batch, 5));
            assert!(plain.max_abs_diff(&fast) < 1e-4, "batch {batch}");
        }
    }

    #[test]
    fn accuracy_counts_argmax() {
        let logits = Matrix::from_fn(3, 2, |r, c| if (r + c) % 2 == 0 { 1.0 } else { 0.0 });
        // argmax rows: [0, 1, 0]
        let y = onehot(&[0, 1, 1], 2);
        assert!((Mlp::accuracy(&logits, &y) - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn flops_formula() {
        let mlp = Mlp::init(&[10, 20, 5], 1, Backend::Naive);
        let fwd = 2.0 * 4.0 * 10.0 * 20.0 + 2.0 * 4.0 * 20.0 * 5.0;
        assert_eq!(mlp.train_step_flops(4), 3.0 * fwd);
    }
}
