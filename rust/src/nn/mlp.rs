//! A tanh MLP with SGEMM-powered forward and backward passes.
//!
//! Layer l computes `h_{l+1} = tanh(h_l W_l + b_l)` (linear on the output
//! layer); the loss is mean softmax cross-entropy. Backprop is hand-derived
//! and expressed as SGEMMs:
//!
//! ```text
//! dW_l = h_lᵀ · dz_l          (sgemm, transa = Yes)
//! dh_l = dz_l · W_lᵀ          (sgemm, transb = Yes)
//! dz_{l-1} = dh_l ⊙ (1 - h_l²)
//! ```
//!
//! which matches the paper's application: *all* heavy math is GEMM.

use crate::blas::{sgemm, Backend, Matrix, Transpose};
use crate::util::prng::Pcg32;

/// MLP parameters: per layer a weight matrix (fan_in × fan_out) and bias.
#[derive(Clone, Debug, PartialEq)]
pub struct Mlp {
    /// Layer sizes, e.g. `[256, 768, 768, 10]`.
    pub sizes: Vec<usize>,
    /// Weights, one per layer.
    pub weights: Vec<Matrix>,
    /// Biases, one per layer.
    pub biases: Vec<Vec<f32>>,
    /// Backend used for all SGEMM calls.
    pub backend: Backend,
}

/// Gradients with the same structure as the parameters.
#[derive(Clone, Debug)]
pub struct MlpGrads {
    /// dL/dW per layer.
    pub d_weights: Vec<Matrix>,
    /// dL/db per layer.
    pub d_biases: Vec<Vec<f32>>,
}

impl Mlp {
    /// Glorot-ish random init (deterministic in `seed`).
    pub fn init(sizes: &[usize], seed: u64, backend: Backend) -> Self {
        assert!(sizes.len() >= 2, "need at least input and output sizes");
        let mut rng = Pcg32::new(seed);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for (&fan_in, &fan_out) in sizes.iter().zip(&sizes[1..]) {
            let scale = (2.0 / (fan_in + fan_out) as f32).sqrt();
            let mut w = Matrix::zeros(fan_in, fan_out);
            for v in w.data_mut() {
                *v = rng.normal() * scale;
            }
            weights.push(w);
            biases.push(vec![0.0; fan_out]);
        }
        Self { sizes: sizes.to_vec(), weights, biases, backend }
    }

    /// Number of layers.
    pub fn n_layers(&self) -> usize {
        self.weights.len()
    }

    /// Total adjustable parameters.
    pub fn param_count(&self) -> usize {
        self.weights.iter().map(|w| w.rows() * w.cols()).sum::<usize>()
            + self.biases.iter().map(|b| b.len()).sum::<usize>()
    }

    /// Forward pass: returns per-layer activations, `acts[0] = x`,
    /// `acts[n] = logits` (length `n_layers + 1`).
    pub fn forward_all(&self, x: &Matrix) -> Vec<Matrix> {
        assert_eq!(x.cols(), self.sizes[0], "input width mismatch");
        let batch = x.rows();
        let mut acts = vec![x.clone()];
        for l in 0..self.n_layers() {
            let w = &self.weights[l];
            let mut z = Matrix::zeros(batch, w.cols());
            sgemm(
                self.backend,
                Transpose::No,
                Transpose::No,
                batch,
                w.cols(),
                w.rows(),
                1.0,
                acts[l].data(),
                acts[l].ld(),
                w.data(),
                w.ld(),
                0.0,
                z.data_mut(),
                w.cols(),
            )
            .expect("forward sgemm");
            // Bias + activation.
            let last = l == self.n_layers() - 1;
            for r in 0..batch {
                for c in 0..w.cols() {
                    let mut v = z.get(r, c) + self.biases[l][c];
                    if !last {
                        v = v.tanh();
                    }
                    z.set(r, c, v);
                }
            }
            acts.push(z);
        }
        acts
    }

    /// Logits only.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.forward_all(x).pop().expect("nonempty activations")
    }

    /// Mean softmax cross-entropy of logits vs one-hot targets.
    pub fn loss_from_logits(logits: &Matrix, y_onehot: &Matrix) -> f32 {
        assert_eq!(logits.rows(), y_onehot.rows());
        assert_eq!(logits.cols(), y_onehot.cols());
        let batch = logits.rows();
        let mut total = 0.0f64;
        for r in 0..batch {
            let mut maxv = f32::NEG_INFINITY;
            for c in 0..logits.cols() {
                maxv = maxv.max(logits.get(r, c));
            }
            let mut lse = 0.0f64;
            for c in 0..logits.cols() {
                lse += ((logits.get(r, c) - maxv) as f64).exp();
            }
            let lse = lse.ln() as f32 + maxv;
            for c in 0..logits.cols() {
                if y_onehot.get(r, c) != 0.0 {
                    total += (y_onehot.get(r, c) * (lse - logits.get(r, c))) as f64;
                }
            }
        }
        (total / batch as f64) as f32
    }

    /// Loss + full gradients for a batch (one-hot targets).
    pub fn loss_and_grad(&self, x: &Matrix, y_onehot: &Matrix) -> (f32, MlpGrads) {
        let acts = self.forward_all(x);
        let logits = &acts[self.n_layers()];
        let loss = Self::loss_from_logits(logits, y_onehot);
        let batch = x.rows();

        // dz at the output: (softmax(logits) - y) / batch.
        let mut dz = Matrix::zeros(batch, logits.cols());
        for r in 0..batch {
            let mut maxv = f32::NEG_INFINITY;
            for c in 0..logits.cols() {
                maxv = maxv.max(logits.get(r, c));
            }
            let mut denom = 0.0f32;
            for c in 0..logits.cols() {
                denom += (logits.get(r, c) - maxv).exp();
            }
            for c in 0..logits.cols() {
                let sm = (logits.get(r, c) - maxv).exp() / denom;
                dz.set(r, c, (sm - y_onehot.get(r, c)) / batch as f32);
            }
        }

        let mut d_weights = vec![Matrix::zeros(0, 0); self.n_layers()];
        let mut d_biases = vec![Vec::new(); self.n_layers()];
        for l in (0..self.n_layers()).rev() {
            let h = &acts[l]; // input to layer l
            let w = &self.weights[l];
            // dW = hᵀ dz  (fan_in × fan_out)
            let mut dw = Matrix::zeros(w.rows(), w.cols());
            sgemm(
                self.backend,
                Transpose::Yes,
                Transpose::No,
                w.rows(),
                w.cols(),
                batch,
                1.0,
                h.data(),
                h.ld(),
                dz.data(),
                dz.ld(),
                0.0,
                dw.data_mut(),
                w.cols(),
            )
            .expect("dW sgemm");
            // db = column sums of dz.
            let mut db = vec![0.0f32; w.cols()];
            for r in 0..batch {
                for c in 0..w.cols() {
                    db[c] += dz.get(r, c);
                }
            }
            d_weights[l] = dw;
            d_biases[l] = db;
            if l > 0 {
                // dh = dz Wᵀ  (batch × fan_in), then dz_{l-1} = dh ⊙ tanh'.
                let mut dh = Matrix::zeros(batch, w.rows());
                sgemm(
                    self.backend,
                    Transpose::No,
                    Transpose::Yes,
                    batch,
                    w.rows(),
                    w.cols(),
                    1.0,
                    dz.data(),
                    dz.ld(),
                    w.data(),
                    w.ld(),
                    0.0,
                    dh.data_mut(),
                    w.rows(),
                )
                .expect("dh sgemm");
                for r in 0..batch {
                    for c in 0..w.rows() {
                        let hv = acts[l].get(r, c); // = tanh(z_{l-1})
                        dh.set(r, c, dh.get(r, c) * (1.0 - hv * hv));
                    }
                }
                dz = dh;
            }
        }
        (loss, MlpGrads { d_weights, d_biases })
    }

    /// Classification accuracy of logits vs one-hot targets.
    pub fn accuracy(logits: &Matrix, y_onehot: &Matrix) -> f32 {
        let batch = logits.rows();
        let mut correct = 0usize;
        for r in 0..batch {
            let (mut arg_l, mut max_l) = (0, f32::NEG_INFINITY);
            let (mut arg_y, mut max_y) = (0, f32::NEG_INFINITY);
            for c in 0..logits.cols() {
                if logits.get(r, c) > max_l {
                    max_l = logits.get(r, c);
                    arg_l = c;
                }
                if y_onehot.get(r, c) > max_y {
                    max_y = y_onehot.get(r, c);
                    arg_y = c;
                }
            }
            correct += usize::from(arg_l == arg_y);
        }
        correct as f32 / batch as f32
    }

    /// Flops for one forward+backward over `batch` rows (3 × forward GEMM
    /// flops — the same formula as `model.train_step_flops` in Python).
    pub fn train_step_flops(&self, batch: usize) -> f64 {
        let fwd: f64 = self
            .sizes
            .iter()
            .zip(&self.sizes[1..])
            .map(|(&i, &o)| 2.0 * batch as f64 * i as f64 * o as f64)
            .sum();
        3.0 * fwd
    }
}

impl MlpGrads {
    /// Element-wise sum with another gradient set.
    pub fn add_assign(&mut self, other: &MlpGrads) {
        assert_eq!(self.d_weights.len(), other.d_weights.len());
        for (a, b) in self.d_weights.iter_mut().zip(&other.d_weights) {
            for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
                *x += *y;
            }
        }
        for (a, b) in self.d_biases.iter_mut().zip(&other.d_biases) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += *y;
            }
        }
    }

    /// Scale all gradients by `s`.
    pub fn scale(&mut self, s: f32) {
        for w in &mut self.d_weights {
            for v in w.data_mut() {
                *v *= s;
            }
        }
        for b in &mut self.d_biases {
            for v in b.iter_mut() {
                *v *= s;
            }
        }
    }

    /// Zero-valued gradients matching a parameter structure.
    pub fn zeros_like(mlp: &Mlp) -> Self {
        Self {
            d_weights: mlp.weights.iter().map(|w| Matrix::zeros(w.rows(), w.cols())).collect(),
            d_biases: mlp.biases.iter().map(|b| vec![0.0; b.len()]).collect(),
        }
    }

    /// Max absolute component (for tests / divergence watchdogs).
    pub fn max_abs(&self) -> f32 {
        let mut m = 0.0f32;
        for w in &self.d_weights {
            for v in w.data() {
                m = m.max(v.abs());
            }
        }
        for b in &self.d_biases {
            for v in b {
                m = m.max(v.abs());
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::Backend;

    fn onehot(labels: &[usize], classes: usize) -> Matrix {
        Matrix::from_fn(labels.len(), classes, |r, c| if labels[r] == c { 1.0 } else { 0.0 })
    }

    #[test]
    fn forward_shapes() {
        let mlp = Mlp::init(&[6, 8, 3], 1, Backend::Naive);
        let x = Matrix::random(5, 6, 2, -1.0, 1.0);
        let acts = mlp.forward_all(&x);
        assert_eq!(acts.len(), 3);
        assert_eq!((acts[1].rows(), acts[1].cols()), (5, 8));
        assert_eq!((acts[2].rows(), acts[2].cols()), (5, 3));
    }

    #[test]
    fn param_count() {
        let mlp = Mlp::init(&[4, 8, 2], 1, Backend::Naive);
        assert_eq!(mlp.param_count(), 4 * 8 + 8 + 8 * 2 + 2);
    }

    #[test]
    fn loss_at_init_is_log_nclasses() {
        let mlp = Mlp::init(&[10, 16, 7], 3, Backend::Naive);
        let x = Matrix::random(64, 10, 4, -1.0, 1.0);
        let y = onehot(&(0..64).map(|i| i % 7).collect::<Vec<_>>(), 7);
        let loss = Mlp::loss_from_logits(&mlp.forward(&x), &y);
        assert!((loss - (7.0f32).ln()).abs() < 0.5, "loss {loss}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut mlp = Mlp::init(&[5, 6, 3], 7, Backend::Naive);
        let x = Matrix::random(4, 5, 8, -1.0, 1.0);
        let y = onehot(&[0, 2, 1, 2], 3);
        let (_, grads) = mlp.loss_and_grad(&x, &y);
        let eps = 1e-3f32;
        // Spot-check several weight coordinates in both layers and a bias.
        for &(l, r, c) in &[(0usize, 0usize, 0usize), (0, 4, 5), (1, 3, 2), (1, 0, 1)] {
            let orig = mlp.weights[l].get(r, c);
            mlp.weights[l].set(r, c, orig + eps);
            let lp = Mlp::loss_from_logits(&mlp.forward(&x), &y);
            mlp.weights[l].set(r, c, orig - eps);
            let lm = Mlp::loss_from_logits(&mlp.forward(&x), &y);
            mlp.weights[l].set(r, c, orig);
            let fd = (lp - lm) / (2.0 * eps);
            let an = grads.d_weights[l].get(r, c);
            assert!(
                (fd - an).abs() < 5e-3 * (1.0 + fd.abs()),
                "W[{l}][{r},{c}]: fd={fd} analytic={an}"
            );
        }
        // A bias coordinate.
        let orig = mlp.biases[0][2];
        mlp.biases[0][2] = orig + eps;
        let lp = Mlp::loss_from_logits(&mlp.forward(&x), &y);
        mlp.biases[0][2] = orig - eps;
        let lm = Mlp::loss_from_logits(&mlp.forward(&x), &y);
        mlp.biases[0][2] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        assert!((fd - grads.d_biases[0][2]).abs() < 5e-3);
    }

    #[test]
    fn grads_identical_across_backends() {
        let mlp_n = Mlp::init(&[8, 12, 4], 11, Backend::Naive);
        let mut mlp_s = mlp_n.clone();
        mlp_s.backend = Backend::Simd;
        let x = Matrix::random(6, 8, 12, -1.0, 1.0);
        let y = onehot(&[0, 1, 2, 3, 0, 1], 4);
        let (l1, g1) = mlp_n.loss_and_grad(&x, &y);
        let (l2, g2) = mlp_s.loss_and_grad(&x, &y);
        assert!((l1 - l2).abs() < 1e-4);
        for (a, b) in g1.d_weights.iter().zip(&g2.d_weights) {
            assert!(a.max_abs_diff(b) < 1e-4);
        }
    }

    #[test]
    fn grad_utilities() {
        let mlp = Mlp::init(&[3, 4, 2], 5, Backend::Naive);
        let x = Matrix::random(2, 3, 6, -1.0, 1.0);
        let y = onehot(&[0, 1], 2);
        let (_, g) = mlp.loss_and_grad(&x, &y);
        let mut sum = MlpGrads::zeros_like(&mlp);
        sum.add_assign(&g);
        sum.add_assign(&g);
        sum.scale(0.5);
        // sum should now equal g.
        for (a, b) in sum.d_weights.iter().zip(&g.d_weights) {
            assert!(a.max_abs_diff(b) < 1e-6);
        }
        assert!(g.max_abs() > 0.0);
    }

    #[test]
    fn accuracy_counts_argmax() {
        let logits = Matrix::from_fn(3, 2, |r, c| if (r + c) % 2 == 0 { 1.0 } else { 0.0 });
        // argmax rows: [0, 1, 0]
        let y = onehot(&[0, 1, 1], 2);
        assert!((Mlp::accuracy(&logits, &y) - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn flops_formula() {
        let mlp = Mlp::init(&[10, 20, 5], 1, Backend::Naive);
        let fwd = 2.0 * 4.0 * 10.0 * 20.0 + 2.0 * 4.0 * 20.0 * 5.0;
        assert_eq!(mlp.train_step_flops(4), 3.0 * fwd);
    }
}
