//! Convolution via GEMM (im2col) — the other workload the paper's intro
//! motivates ("a range of applications such as artificial neural networks
//! benefit from GEMM").
//!
//! A 2-D convolution over NCHW input is lowered to one SGEMM:
//!
//! ```text
//! patches = im2col(input)         # (N·OH·OW) × (C·KH·KW)
//! output  = patches · kernelsᵀ    # (N·OH·OW) × F   — one Emmerald GEMM
//! ```
//!
//! which is exactly how 1999-era (and many current) frameworks spent
//! their convolution flops in SGEMM.

use crate::blas::{sgemm_matrix, Backend, GemmContext, Matrix, PackedB, Transpose};

/// Convolution geometry (valid padding, unit dilation).
#[derive(Clone, Copy, Debug)]
pub struct Conv2d {
    /// Input channels.
    pub in_channels: usize,
    /// Output channels (filters).
    pub out_channels: usize,
    /// Kernel height/width.
    pub kernel: usize,
    /// Stride.
    pub stride: usize,
}

impl Conv2d {
    /// Output spatial size for an `h × w` input.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        assert!(h >= self.kernel && w >= self.kernel, "input smaller than kernel");
        ((h - self.kernel) / self.stride + 1, (w - self.kernel) / self.stride + 1)
    }

    /// im2col: lower an NCHW batch (`n × c × h × w`, flat slice) into the
    /// patch matrix of shape `(n·oh·ow) × (c·k·k)`.
    pub fn im2col(&self, input: &[f32], n: usize, h: usize, w: usize) -> Matrix {
        let c = self.in_channels;
        assert_eq!(input.len(), n * c * h * w, "input length mismatch");
        let (oh, ow) = self.out_hw(h, w);
        let k = self.kernel;
        let mut out = Matrix::zeros(n * oh * ow, c * k * k);
        for img in 0..n {
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = (img * oh + oy) * ow + ox;
                    for ch in 0..c {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * self.stride + ky;
                                let ix = ox * self.stride + kx;
                                let v = input[((img * c + ch) * h + iy) * w + ix];
                                out.set(row, (ch * k + ky) * k + kx, v);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Forward convolution: `kernels` is `F × (C·K·K)` row-major, output
    /// is `(n·oh·ow) × F` (one GEMM through the selected backend).
    pub fn forward(
        &self,
        input: &[f32],
        n: usize,
        h: usize,
        w: usize,
        kernels: &Matrix,
        backend: Backend,
    ) -> Matrix {
        assert_eq!(kernels.rows(), self.out_channels);
        assert_eq!(kernels.cols(), self.in_channels * self.kernel * self.kernel);
        let patches = self.im2col(input, n, h, w);
        let mut out = Matrix::zeros(patches.rows(), self.out_channels);
        sgemm_matrix(backend, Transpose::No, Transpose::Yes, 1.0, &patches, kernels, 0.0, &mut out)
            .expect("conv sgemm");
        out
    }

    /// Forward convolution through the batched dispatch subsystem.
    ///
    /// Equivalent to [`forward`](Self::forward), but expressed as a
    /// shared-B batch: each image's `oh·ow` patch rows form one batch item
    /// and every item multiplies the same (materialised-transpose) kernel
    /// matrix. The batched driver folds this into a single GEMM, so the
    /// kernel panel is re-buffered once for the whole batch and the
    /// parallel backend sees the full `n·oh·ow` row space — the
    /// weight-stationary layout every GEMM-based framework uses.
    pub fn forward_batched(
        &self,
        input: &[f32],
        n: usize,
        h: usize,
        w: usize,
        kernels: &Matrix,
    ) -> Matrix {
        assert_eq!(kernels.rows(), self.out_channels);
        assert_eq!(kernels.cols(), self.in_channels * self.kernel * self.kernel);
        let patches = self.im2col(input, n, h, w);
        let kt = kernels.transposed(); // (C·K·K) × F, contiguous
        let (oh, ow) = self.out_hw(h, w);
        let rows_per_item = oh * ow;
        let ckk = kernels.cols();
        let f = self.out_channels;
        let mut out = Matrix::zeros(patches.rows(), f);
        crate::gemm::dispatch::with_global(|d| {
            crate::gemm::gemm_batch(
                d,
                Transpose::No,
                Transpose::No,
                rows_per_item,
                f,
                ckk,
                1.0,
                patches.data(),
                ckk,
                kt.data(),
                f,
                0.0,
                out.data_mut(),
                f,
                n,
                crate::gemm::BatchStrides { a: rows_per_item * ckk, b: 0, c: rows_per_item * f },
            )
        })
        .expect("conv gemm_batch");
        out
    }

    /// Pre-pack the kernel matrix for repeated forward calls: the
    /// materialised-transpose weight (`(C·K·K) × F`) is re-buffered into
    /// panel-major form **once** on `ctx` and then reused by every
    /// [`forward_packed`](Self::forward_packed) call — the
    /// weight-stationary inference layout (frozen weights, streaming
    /// activations).
    pub fn pack_kernels(&self, kernels: &Matrix, ctx: &GemmContext) -> PackedConvKernels {
        assert_eq!(kernels.rows(), self.out_channels);
        assert_eq!(kernels.cols(), self.in_channels * self.kernel * self.kernel);
        let kt = kernels.transposed(); // (C·K·K) × F, contiguous
        let packed = ctx
            .pack_b(Transpose::No, kt.rows(), kt.cols(), kt.data(), kt.ld())
            .expect("kernel matrix is a valid view");
        PackedConvKernels {
            ctx: ctx.clone(),
            packed,
            kt,
            ckk: kernels.cols(),
            f: self.out_channels,
        }
    }

    /// Forward convolution through prepacked kernels: equivalent to
    /// [`forward`](Self::forward), but the weight panel re-buffering is
    /// already done, so only im2col and the planned GEMM run per call.
    ///
    /// If the context's tuned geometry changed since
    /// [`pack_kernels`](Self::pack_kernels), the stale pack is bypassed
    /// and the call falls back to the plain packing path (the handle
    /// keeps the raw transposed kernels for exactly this) — always
    /// correct, just without the prepacking win until repacked.
    pub fn forward_packed(
        &self,
        input: &[f32],
        n: usize,
        h: usize,
        w: usize,
        kernels: &PackedConvKernels,
    ) -> Matrix {
        assert_eq!(kernels.f, self.out_channels, "packed kernels are for a different geometry");
        assert_eq!(kernels.ckk, self.in_channels * self.kernel * self.kernel);
        let patches = self.im2col(input, n, h, w);
        let mut out = Matrix::zeros(patches.rows(), kernels.f);
        let plan = kernels
            .ctx
            .gemm()
            .ldb(kernels.kt.ld())
            .plan(patches.rows(), kernels.f, kernels.ckk)
            .expect("validated shapes");
        if plan.run_packed_b(patches.data(), &kernels.packed, out.data_mut()).is_err() {
            plan.run(patches.data(), kernels.kt.data(), out.data_mut()).expect("validated shapes");
        }
        out
    }

    /// GEMM flops of one forward call.
    pub fn flops(&self, n: usize, h: usize, w: usize) -> f64 {
        let (oh, ow) = self.out_hw(h, w);
        2.0 * (n * oh * ow) as f64
            * (self.in_channels * self.kernel * self.kernel) as f64
            * self.out_channels as f64
    }
}

/// Kernel weights prepacked for [`Conv2d::forward_packed`]: holds the
/// panel-major buffer and the [`GemmContext`] it was packed on.
pub struct PackedConvKernels {
    ctx: GemmContext,
    packed: PackedB,
    /// Raw transposed kernels, kept for the stale-geometry fallback.
    kt: Matrix,
    ckk: usize,
    f: usize,
}

impl PackedConvKernels {
    /// Bytes held by the packed weight panels (diagnostic).
    pub fn bytes(&self) -> usize {
        self.packed.bytes()
    }
}

/// Direct (nested-loop) convolution used as the oracle in tests.
pub fn conv2d_direct(
    cfg: &Conv2d,
    input: &[f32],
    n: usize,
    h: usize,
    w: usize,
    kernels: &Matrix,
) -> Matrix {
    let (oh, ow) = cfg.out_hw(h, w);
    let c = cfg.in_channels;
    let k = cfg.kernel;
    let mut out = Matrix::zeros(n * oh * ow, cfg.out_channels);
    for img in 0..n {
        for f in 0..cfg.out_channels {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ch in 0..c {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * cfg.stride + ky;
                                let ix = ox * cfg.stride + kx;
                                acc += input[((img * c + ch) * h + iy) * w + ix]
                                    * kernels.get(f, (ch * k + ky) * k + kx);
                            }
                        }
                    }
                    out.set((img * oh + oy) * ow + ox, f, acc);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::testkit::assert_allclose;

    fn rand_input(seed: u64, len: usize) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        let mut v = vec![0.0; len];
        rng.fill_f32(&mut v, -1.0, 1.0);
        v
    }

    #[test]
    fn output_geometry() {
        let cfg = Conv2d { in_channels: 3, out_channels: 8, kernel: 3, stride: 1 };
        assert_eq!(cfg.out_hw(8, 10), (6, 8));
        let cfg2 = Conv2d { kernel: 3, stride: 2, ..cfg };
        assert_eq!(cfg2.out_hw(9, 9), (4, 4));
    }

    #[test]
    fn im2col_identity_kernel_1x1() {
        // 1×1 kernel, stride 1: patches are just the channel values.
        let cfg = Conv2d { in_channels: 2, out_channels: 2, kernel: 1, stride: 1 };
        let input: Vec<f32> = (0..2 * 2 * 2 * 2).map(|i| i as f32).collect(); // n=2,c=2,h=2,w=2
        let p = cfg.im2col(&input, 2, 2, 2);
        assert_eq!((p.rows(), p.cols()), (8, 2));
        // First patch row = pixel (0,0) of both channels of image 0.
        assert_eq!(p.get(0, 0), 0.0);
        assert_eq!(p.get(0, 1), 4.0);
    }

    #[test]
    fn gemm_conv_matches_direct_all_backends() {
        let cfg = Conv2d { in_channels: 3, out_channels: 5, kernel: 3, stride: 1 };
        let (n, h, w) = (2usize, 7usize, 9usize);
        let input = rand_input(1, n * 3 * h * w);
        let kernels = Matrix::random(5, 3 * 3 * 3, 2, -1.0, 1.0);
        let want = conv2d_direct(&cfg, &input, n, h, w, &kernels);
        for backend in crate::blas::available_backends() {
            let got = cfg.forward(&input, n, h, w, &kernels, backend);
            assert_allclose(
                got.data(),
                want.data(),
                2e-4,
                1e-4,
                &format!("conv {}", backend.name()),
            );
        }
    }

    #[test]
    fn batched_forward_matches_direct_and_serial_forward() {
        let cfg = Conv2d { in_channels: 3, out_channels: 6, kernel: 3, stride: 1 };
        let (n, h, w) = (4usize, 8usize, 9usize);
        let input = rand_input(7, n * 3 * h * w);
        let kernels = Matrix::random(6, 3 * 9, 8, -1.0, 1.0);
        let want = conv2d_direct(&cfg, &input, n, h, w, &kernels);
        let got = cfg.forward_batched(&input, n, h, w, &kernels);
        assert_allclose(got.data(), want.data(), 2e-4, 1e-4, "batched conv vs direct");
        let serial = cfg.forward(&input, n, h, w, &kernels, Backend::Dispatch);
        assert_allclose(got.data(), serial.data(), 2e-4, 1e-4, "batched conv vs serial");
    }

    #[test]
    fn packed_kernels_reused_across_batches_match_direct() {
        // Local context: immune to concurrent global install_tuned calls.
        let ctx = crate::blas::GemmContext::new(crate::gemm::DispatchConfig {
            threads: 1,
            ..crate::gemm::DispatchConfig::default()
        });
        let cfg = Conv2d { in_channels: 2, out_channels: 5, kernel: 3, stride: 1 };
        let kernels = Matrix::random(5, 2 * 9, 9, -1.0, 1.0);
        let packed = cfg.pack_kernels(&kernels, &ctx);
        assert!(packed.bytes() > 0);
        // One pack, several forward calls with different batch sizes and
        // spatial dims (the inference-serving pattern).
        for (seed, n, h, w) in [(11u64, 1usize, 6usize, 6usize), (12, 3, 8, 7), (13, 2, 5, 9)] {
            let input = rand_input(seed, n * 2 * h * w);
            let want = conv2d_direct(&cfg, &input, n, h, w, &kernels);
            let got = cfg.forward_packed(&input, n, h, w, &packed);
            assert_allclose(
                got.data(),
                want.data(),
                2e-4,
                1e-4,
                &format!("packed conv n={n} {h}x{w}"),
            );
        }
    }

    #[test]
    fn strided_conv_matches_direct() {
        let cfg = Conv2d { in_channels: 2, out_channels: 4, kernel: 3, stride: 2 };
        let (n, h, w) = (1usize, 11usize, 11usize);
        let input = rand_input(3, n * 2 * h * w);
        let kernels = Matrix::random(4, 2 * 9, 4, -1.0, 1.0);
        let want = conv2d_direct(&cfg, &input, n, h, w, &kernels);
        let got = cfg.forward(&input, n, h, w, &kernels, Backend::Simd);
        assert_allclose(got.data(), want.data(), 2e-4, 1e-4, "strided conv");
    }

    #[test]
    fn flops_formula() {
        let cfg = Conv2d { in_channels: 3, out_channels: 8, kernel: 3, stride: 1 };
        let (oh, ow) = cfg.out_hw(8, 8);
        assert_eq!(cfg.flops(2, 8, 8), 2.0 * (2 * oh * ow) as f64 * 27.0 * 8.0);
    }
}
