//! Convolution via GEMM (im2col) — the other workload the paper's intro
//! motivates ("a range of applications such as artificial neural networks
//! benefit from GEMM").
//!
//! A 2-D convolution over NCHW input is lowered to one SGEMM. The classic
//! lowering *materialises* the patch matrix first:
//!
//! ```text
//! patches = im2col(input)         # (N·OH·OW) × (C·KH·KW)
//! output  = patches · kernelsᵀ    # (N·OH·OW) × F   — one Emmerald GEMM
//! ```
//!
//! which is exactly how 1999-era (and many current) frameworks spent
//! their convolution flops in SGEMM — at the cost of an intermediate
//! `(N·OH·OW) × (C·K·K)` allocation that can dwarf the input.
//!
//! The default path here fuses that lowering into the GEMM's own packing
//! stage instead: [`Im2ColRef`] presents the patch matrix as a virtual
//! [`PanelSource`] and the tile driver packs convolution patches straight
//! into its L1-resident `B` panels, resolving padding, stride and
//! dilation per element *while packing*. The full patch matrix is never
//! allocated — only the driver's existing `kc × nc` packed block exists
//! at any time.

use crate::blas::{sgemm_matrix, Backend, GemmContext, Matrix, Transpose};
use crate::gemm::pack::{BSource, PanelSource, Scratch};
use crate::gemm::{tile, TileParams};

/// Convolution geometry (zero padding, arbitrary stride and dilation).
#[derive(Clone, Copy, Debug)]
pub struct Conv2d {
    /// Input channels.
    pub in_channels: usize,
    /// Output channels (filters).
    pub out_channels: usize,
    /// Kernel height/width.
    pub kernel: usize,
    /// Stride.
    pub stride: usize,
    /// Implicit zero padding on every spatial edge.
    pub padding: usize,
    /// Dilation: spacing between kernel taps (1 = dense kernel).
    pub dilation: usize,
}

impl Conv2d {
    /// Output spatial size for an `h × w` input.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        assert!(
            self.kernel >= 1 && self.stride >= 1 && self.dilation >= 1,
            "degenerate conv geometry"
        );
        let eff = self.dilation * (self.kernel - 1) + 1;
        assert!(
            h + 2 * self.padding >= eff && w + 2 * self.padding >= eff,
            "padded input smaller than dilated kernel"
        );
        (
            (h + 2 * self.padding - eff) / self.stride + 1,
            (w + 2 * self.padding - eff) / self.stride + 1,
        )
    }

    /// Input coordinate read by output position `o`, kernel tap `kq`,
    /// along an axis of extent `limit`; `None` when the tap lands in the
    /// zero-padding border.
    #[inline]
    fn in_coord(&self, o: usize, kq: usize, limit: usize) -> Option<usize> {
        let i = (o * self.stride + kq * self.dilation) as isize - self.padding as isize;
        if i >= 0 && (i as usize) < limit {
            Some(i as usize)
        } else {
            None
        }
    }

    /// im2col: lower an NCHW batch (`n × c × h × w`, flat slice) into the
    /// patch matrix of shape `(n·oh·ow) × (c·k·k)`. Padding taps are
    /// stored as explicit zeros.
    ///
    /// This is the *materialising* lowering — kept as the oracle for the
    /// fused [`Im2ColRef`] path and for the explicit-backend ablation
    /// route in [`forward`](Self::forward).
    pub fn im2col(&self, input: &[f32], n: usize, h: usize, w: usize) -> Matrix {
        let c = self.in_channels;
        assert_eq!(input.len(), n * c * h * w, "input length mismatch");
        let (oh, ow) = self.out_hw(h, w);
        let k = self.kernel;
        let mut out = Matrix::zeros(n * oh * ow, c * k * k);
        for img in 0..n {
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = (img * oh + oy) * ow + ox;
                    for ch in 0..c {
                        for ky in 0..k {
                            for kx in 0..k {
                                let v = match (self.in_coord(oy, ky, h), self.in_coord(ox, kx, w))
                                {
                                    (Some(iy), Some(ix)) => {
                                        input[((img * c + ch) * h + iy) * w + ix]
                                    }
                                    _ => 0.0,
                                };
                                out.set(row, (ch * k + ky) * k + kx, v);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The fused forward: one serial tile-driver GEMM whose `B` operand
    /// is an [`Im2ColRef`] — patches are packed straight from the input,
    /// never materialised. Natural fused orientation is
    /// `outᵗ = kernels · patchesᵗ` (`F × N·OH·OW`); one transpose-copy
    /// restores the public `(N·OH·OW) × F` layout.
    fn forward_fused(
        &self,
        input: &[f32],
        n: usize,
        h: usize,
        w: usize,
        kernels: &Matrix,
        params: &TileParams,
    ) -> Matrix {
        let src = Im2ColRef::new(self, input, n, h, w);
        let cols = src.cols();
        let mut out_t = Matrix::zeros(self.out_channels, cols);
        let mut scratch = Scratch::new();
        tile::gemm_scratch_ep(
            params,
            Transpose::No,
            1.0,
            kernels.view(),
            BSource::Virtual(&src),
            0.0,
            &mut out_t.view_mut(),
            &mut scratch,
            None,
        );
        let mut out = Matrix::zeros(cols, self.out_channels);
        for f in 0..self.out_channels {
            for p in 0..cols {
                out.set(p, f, out_t.get(f, p));
            }
        }
        out
    }

    /// Forward convolution: `kernels` is `F × (C·K·K)` row-major, output
    /// is `(n·oh·ow) × F`.
    ///
    /// [`Backend::Dispatch`]/[`Backend::Auto`] take the fused-im2col path
    /// (no patch matrix is allocated). An explicit kernel backend forces
    /// the classic materialised lowering through that backend — the
    /// ablation route the benches compare against.
    pub fn forward(
        &self,
        input: &[f32],
        n: usize,
        h: usize,
        w: usize,
        kernels: &Matrix,
        backend: Backend,
    ) -> Matrix {
        assert_eq!(kernels.rows(), self.out_channels);
        assert_eq!(kernels.cols(), self.in_channels * self.kernel * self.kernel);
        match backend {
            Backend::Dispatch | Backend::Auto => {
                let params = crate::gemm::dispatch::with_global(|d| *d.params_tile_t::<f32>());
                self.forward_fused(input, n, h, w, kernels, &params)
            }
            _ => {
                let patches = self.im2col(input, n, h, w);
                let mut out = Matrix::zeros(patches.rows(), self.out_channels);
                sgemm_matrix(
                    backend,
                    Transpose::No,
                    Transpose::Yes,
                    1.0,
                    &patches,
                    kernels,
                    0.0,
                    &mut out,
                )
                .expect("conv sgemm");
                out
            }
        }
    }

    /// Forward convolution over a whole batch.
    ///
    /// Equivalent to [`forward`](Self::forward) with the default backend:
    /// the fused path already presents the full `n·oh·ow` patch-column
    /// space to one GEMM (the weight-stationary layout the old shared-B
    /// batch fold existed to recover), so the batch *is* the single fused
    /// GEMM — no im2col matrix, no per-item dispatch.
    pub fn forward_batched(
        &self,
        input: &[f32],
        n: usize,
        h: usize,
        w: usize,
        kernels: &Matrix,
    ) -> Matrix {
        assert_eq!(kernels.rows(), self.out_channels);
        assert_eq!(kernels.cols(), self.in_channels * self.kernel * self.kernel);
        let params = crate::gemm::dispatch::with_global(|d| *d.params_tile_t::<f32>());
        self.forward_fused(input, n, h, w, kernels, &params)
    }

    /// Capture the kernel matrix for repeated forward calls.
    ///
    /// In the fused-im2col layout the weights are the GEMM's **A**
    /// operand, used in their natural `F × (C·K·K)` orientation — the
    /// weight transpose and panel prepack the old path needed per handle
    /// are gone, and the tile driver re-buffers the (small) weight block
    /// per k block on the fly. The handle owns a copy of the weights and
    /// pins the [`GemmContext`] whose tuned tile geometry every
    /// [`forward_packed`](Self::forward_packed) call runs with.
    pub fn pack_kernels(&self, kernels: &Matrix, ctx: &GemmContext) -> PackedConvKernels {
        assert_eq!(kernels.rows(), self.out_channels);
        assert_eq!(kernels.cols(), self.in_channels * self.kernel * self.kernel);
        PackedConvKernels {
            ctx: ctx.clone(),
            kernels: kernels.clone(),
            ckk: kernels.cols(),
            f: self.out_channels,
        }
    }

    /// Forward convolution through a captured kernel handle: the fused
    /// im2col GEMM on the handle's context — only the streamed-packing
    /// GEMM runs per call; no patch matrix, no weight transpose.
    pub fn forward_packed(
        &self,
        input: &[f32],
        n: usize,
        h: usize,
        w: usize,
        kernels: &PackedConvKernels,
    ) -> Matrix {
        assert_eq!(kernels.f, self.out_channels, "packed kernels are for a different geometry");
        assert_eq!(kernels.ckk, self.in_channels * self.kernel * self.kernel);
        let params = *kernels.ctx.snapshot().params_tile_t::<f32>();
        self.forward_fused(input, n, h, w, &kernels.kernels, &params)
    }

    /// GEMM flops of one forward call.
    pub fn flops(&self, n: usize, h: usize, w: usize) -> f64 {
        let (oh, ow) = self.out_hw(h, w);
        2.0 * (n * oh * ow) as f64
            * (self.in_channels * self.kernel * self.kernel) as f64
            * self.out_channels as f64
    }
}

/// A zero-materialisation view of the im2col patch matrix, shaped
/// `(C·K·K) × (N·OH·OW)` — the transpose of [`Conv2d::im2col`]'s output.
///
/// Implements [`PanelSource`], so the tile driver's `B`-pack pulls
/// convolution patches straight out of the NCHW input while building its
/// L1-resident panels: padding, stride and dilation are resolved per
/// element at pack time, and out-of-bounds taps read as the implicit
/// zero border.
pub struct Im2ColRef<'a> {
    cfg: Conv2d,
    input: &'a [f32],
    n: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
}

impl<'a> Im2ColRef<'a> {
    /// View `input` (NCHW, flat) as the `(C·K·K) × (n·oh·ow)` patch
    /// matrix of `cfg`.
    pub fn new(cfg: &Conv2d, input: &'a [f32], n: usize, h: usize, w: usize) -> Self {
        assert_eq!(input.len(), n * cfg.in_channels * h * w, "input length mismatch");
        let (oh, ow) = cfg.out_hw(h, w);
        Im2ColRef { cfg: *cfg, input, n, h, w, oh, ow }
    }
}

impl PanelSource<f32> for Im2ColRef<'_> {
    fn rows(&self) -> usize {
        self.cfg.in_channels * self.cfg.kernel * self.cfg.kernel
    }

    fn cols(&self) -> usize {
        self.n * self.oh * self.ow
    }

    #[inline]
    fn get(&self, r: usize, col: usize) -> f32 {
        let k = self.cfg.kernel;
        let ch = r / (k * k);
        let ky = (r / k) % k;
        let kx = r % k;
        let img = col / (self.oh * self.ow);
        let oy = (col / self.ow) % self.oh;
        let ox = col % self.ow;
        match (self.cfg.in_coord(oy, ky, self.h), self.cfg.in_coord(ox, kx, self.w)) {
            (Some(iy), Some(ix)) => {
                self.input[((img * self.cfg.in_channels + ch) * self.h + iy) * self.w + ix]
            }
            _ => 0.0,
        }
    }
}

/// Kernel weights captured for [`Conv2d::forward_packed`]: the fused
/// im2col path uses the raw `F × (C·K·K)` weights as the GEMM's `A`
/// operand, so the handle owns a copy plus the [`GemmContext`] whose
/// tuned tile geometry the fused GEMM runs with.
pub struct PackedConvKernels {
    ctx: GemmContext,
    kernels: Matrix,
    ckk: usize,
    f: usize,
}

impl PackedConvKernels {
    /// Bytes held by the owned weight matrix (diagnostic).
    pub fn bytes(&self) -> usize {
        self.kernels.data().len() * std::mem::size_of::<f32>()
    }
}

/// Direct (nested-loop) convolution used as the oracle in tests.
pub fn conv2d_direct(
    cfg: &Conv2d,
    input: &[f32],
    n: usize,
    h: usize,
    w: usize,
    kernels: &Matrix,
) -> Matrix {
    let (oh, ow) = cfg.out_hw(h, w);
    let c = cfg.in_channels;
    let k = cfg.kernel;
    let mut out = Matrix::zeros(n * oh * ow, cfg.out_channels);
    for img in 0..n {
        for f in 0..cfg.out_channels {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ch in 0..c {
                        for ky in 0..k {
                            for kx in 0..k {
                                if let (Some(iy), Some(ix)) =
                                    (cfg.in_coord(oy, ky, h), cfg.in_coord(ox, kx, w))
                                {
                                    acc += input[((img * c + ch) * h + iy) * w + ix]
                                        * kernels.get(f, (ch * k + ky) * k + kx);
                                }
                            }
                        }
                    }
                    out.set((img * oh + oy) * ow + ox, f, acc);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::testkit::assert_allclose;

    fn rand_input(seed: u64, len: usize) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        let mut v = vec![0.0; len];
        rng.fill_f32(&mut v, -1.0, 1.0);
        v
    }

    #[test]
    fn output_geometry() {
        let cfg =
            Conv2d { in_channels: 3, out_channels: 8, kernel: 3, stride: 1, padding: 0, dilation: 1 };
        assert_eq!(cfg.out_hw(8, 10), (6, 8));
        let cfg2 = Conv2d { kernel: 3, stride: 2, ..cfg };
        assert_eq!(cfg2.out_hw(9, 9), (4, 4));
        // "Same" padding for a dense 3×3 stride-1 kernel.
        let cfg3 = Conv2d { padding: 1, ..cfg };
        assert_eq!(cfg3.out_hw(8, 10), (8, 10));
        // Dilation 2 stretches the 3×3 kernel to an effective 5×5.
        let cfg4 = Conv2d { dilation: 2, ..cfg };
        assert_eq!(cfg4.out_hw(8, 10), (4, 6));
    }

    #[test]
    fn im2col_identity_kernel_1x1() {
        // 1×1 kernel, stride 1: patches are just the channel values.
        let cfg =
            Conv2d { in_channels: 2, out_channels: 2, kernel: 1, stride: 1, padding: 0, dilation: 1 };
        let input: Vec<f32> = (0..2 * 2 * 2 * 2).map(|i| i as f32).collect(); // n=2,c=2,h=2,w=2
        let p = cfg.im2col(&input, 2, 2, 2);
        assert_eq!((p.rows(), p.cols()), (8, 2));
        // First patch row = pixel (0,0) of both channels of image 0.
        assert_eq!(p.get(0, 0), 0.0);
        assert_eq!(p.get(0, 1), 4.0);
    }

    #[test]
    fn im2col_ref_is_transpose_of_materialised_im2col() {
        // The virtual panel source must agree with the materialised
        // lowering entry-for-entry, padding zeros included.
        for (pad, stride, dil) in [(0usize, 1usize, 1usize), (1, 1, 1), (2, 2, 1), (1, 1, 2), (1, 2, 2)]
        {
            let cfg = Conv2d {
                in_channels: 2,
                out_channels: 3,
                kernel: 3,
                stride,
                padding: pad,
                dilation: dil,
            };
            let (n, h, w) = (2usize, 6usize, 7usize);
            let input =
                rand_input(40 + (pad * 25 + stride * 5 + dil) as u64, n * 2 * h * w);
            let dense = cfg.im2col(&input, n, h, w);
            let view = Im2ColRef::new(&cfg, &input, n, h, w);
            assert_eq!(view.rows(), dense.cols());
            assert_eq!(view.cols(), dense.rows());
            for r in 0..view.rows() {
                for col in 0..view.cols() {
                    assert_eq!(
                        view.get(r, col),
                        dense.get(col, r),
                        "im2col_ref ({r},{col}) pad={pad} s={stride} d={dil}"
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_conv_matches_direct_all_backends() {
        let cfg =
            Conv2d { in_channels: 3, out_channels: 5, kernel: 3, stride: 1, padding: 0, dilation: 1 };
        let (n, h, w) = (2usize, 7usize, 9usize);
        let input = rand_input(1, n * 3 * h * w);
        let kernels = Matrix::random(5, 3 * 3 * 3, 2, -1.0, 1.0);
        let want = conv2d_direct(&cfg, &input, n, h, w, &kernels);
        for backend in crate::blas::available_backends() {
            let got = cfg.forward(&input, n, h, w, &kernels, backend);
            assert_allclose(
                got.data(),
                want.data(),
                2e-4,
                1e-4,
                &format!("conv {}", backend.name()),
            );
        }
    }

    #[test]
    fn padded_dilated_strided_conv_matches_direct() {
        // The fused path and the materialised ablation path against the
        // nested-loop oracle across padding / stride / dilation / 1×1
        // edge cases.
        for (i, &(pad, stride, dil, k)) in
            [(1usize, 1usize, 1usize, 3usize), (2, 2, 1, 3), (1, 1, 2, 3), (0, 2, 2, 3), (2, 1, 1, 1), (0, 1, 2, 2)]
                .iter()
                .enumerate()
        {
            let cfg = Conv2d {
                in_channels: 2,
                out_channels: 4,
                kernel: k,
                stride,
                padding: pad,
                dilation: dil,
            };
            let (n, h, w) = (2usize, 8usize, 9usize);
            let input = rand_input(60 + i as u64, n * 2 * h * w);
            let kernels = Matrix::random(4, 2 * k * k, 70 + i as u64, -1.0, 1.0);
            let want = conv2d_direct(&cfg, &input, n, h, w, &kernels);
            let label = format!("conv pad={pad} s={stride} d={dil} k={k}");
            let fused = cfg.forward(&input, n, h, w, &kernels, Backend::Dispatch);
            assert_allclose(fused.data(), want.data(), 2e-4, 1e-4, &format!("{label} fused"));
            let legacy = cfg.forward(&input, n, h, w, &kernels, Backend::Blocked);
            assert_allclose(legacy.data(), want.data(), 2e-4, 1e-4, &format!("{label} im2col"));
        }
    }

    #[test]
    fn batched_forward_matches_direct_and_serial_forward() {
        let cfg =
            Conv2d { in_channels: 3, out_channels: 6, kernel: 3, stride: 1, padding: 0, dilation: 1 };
        let (n, h, w) = (4usize, 8usize, 9usize);
        let input = rand_input(7, n * 3 * h * w);
        let kernels = Matrix::random(6, 3 * 9, 8, -1.0, 1.0);
        let want = conv2d_direct(&cfg, &input, n, h, w, &kernels);
        let got = cfg.forward_batched(&input, n, h, w, &kernels);
        assert_allclose(got.data(), want.data(), 2e-4, 1e-4, "batched conv vs direct");
        let serial = cfg.forward(&input, n, h, w, &kernels, Backend::Dispatch);
        assert_allclose(got.data(), serial.data(), 2e-4, 1e-4, "batched conv vs serial");
    }

    #[test]
    fn packed_kernels_reused_across_batches_match_direct() {
        // Local context: immune to concurrent global install_tuned calls.
        let ctx = crate::blas::GemmContext::new(crate::gemm::DispatchConfig {
            threads: 1,
            ..crate::gemm::DispatchConfig::default()
        });
        let cfg =
            Conv2d { in_channels: 2, out_channels: 5, kernel: 3, stride: 1, padding: 1, dilation: 1 };
        let kernels = Matrix::random(5, 2 * 9, 9, -1.0, 1.0);
        let packed = cfg.pack_kernels(&kernels, &ctx);
        assert!(packed.bytes() > 0);
        // One pack, several forward calls with different batch sizes and
        // spatial dims (the inference-serving pattern).
        for (seed, n, h, w) in [(11u64, 1usize, 6usize, 6usize), (12, 3, 8, 7), (13, 2, 5, 9)] {
            let input = rand_input(seed, n * 2 * h * w);
            let want = conv2d_direct(&cfg, &input, n, h, w, &kernels);
            let got = cfg.forward_packed(&input, n, h, w, &packed);
            assert_allclose(
                got.data(),
                want.data(),
                2e-4,
                1e-4,
                &format!("packed conv n={n} {h}x{w}"),
            );
        }
    }

    #[test]
    fn strided_conv_matches_direct() {
        let cfg =
            Conv2d { in_channels: 2, out_channels: 4, kernel: 3, stride: 2, padding: 0, dilation: 1 };
        let (n, h, w) = (1usize, 11usize, 11usize);
        let input = rand_input(3, n * 2 * h * w);
        let kernels = Matrix::random(4, 2 * 9, 4, -1.0, 1.0);
        let want = conv2d_direct(&cfg, &input, n, h, w, &kernels);
        let got = cfg.forward(&input, n, h, w, &kernels, Backend::Simd);
        assert_allclose(got.data(), want.data(), 2e-4, 1e-4, "strided conv");
    }

    #[test]
    fn flops_formula() {
        let cfg =
            Conv2d { in_channels: 3, out_channels: 8, kernel: 3, stride: 1, padding: 0, dilation: 1 };
        let (oh, ow) = cfg.out_hw(8, 8);
        assert_eq!(cfg.flops(2, 8, 8), 2.0 * (2 * oh * ow) as f64 * 27.0 * 8.0);
    }
}
