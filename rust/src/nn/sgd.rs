//! Plain SGD and the gradient averaging used by data parallelism.
//!
//! The paper's cluster (ref [1]) runs synchronous data-parallel SGD: each
//! node computes gradients on its batch shard, gradients are averaged, and
//! every node applies the same update. The averaging here is exactly what
//! the leader performs after collecting worker results.

use super::mlp::{Mlp, MlpGrads};

/// SGD configuration.
#[derive(Clone, Copy, Debug)]
pub struct Sgd {
    /// Learning rate.
    pub lr: f32,
}

impl Sgd {
    /// New optimiser.
    pub fn new(lr: f32) -> Self {
        assert!(lr > 0.0, "learning rate must be positive");
        Self { lr }
    }

    /// In-place parameter update: `p -= lr * g`.
    pub fn apply(&self, mlp: &mut Mlp, grads: &MlpGrads) {
        assert_eq!(mlp.weights.len(), grads.d_weights.len());
        for (w, dw) in mlp.weights.iter_mut().zip(&grads.d_weights) {
            for (p, g) in w.data_mut().iter_mut().zip(dw.data()) {
                *p -= self.lr * g;
            }
        }
        for (b, db) in mlp.biases.iter_mut().zip(&grads.d_biases) {
            for (p, g) in b.iter_mut().zip(db) {
                *p -= self.lr * g;
            }
        }
    }
}

/// Weighted average of per-shard gradients (weights = shard sizes, so the
/// result equals the gradient of the concatenated batch).
pub fn average_grads(parts: &[(usize, MlpGrads)], template: &Mlp) -> MlpGrads {
    assert!(!parts.is_empty(), "no gradients to average");
    let total: usize = parts.iter().map(|(n, _)| n).sum();
    assert!(total > 0);
    let mut acc = MlpGrads::zeros_like(template);
    for (n, g) in parts {
        let mut weighted = g.clone();
        weighted.scale(*n as f32 / total as f32);
        acc.add_assign(&weighted);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{Backend, Matrix};

    #[test]
    fn apply_moves_against_gradient() {
        let mut mlp = Mlp::init(&[2, 3, 2], 1, Backend::Naive);
        let before = mlp.weights[0].get(0, 0);
        let mut g = MlpGrads::zeros_like(&mlp);
        g.d_weights[0].set(0, 0, 2.0);
        Sgd::new(0.1).apply(&mut mlp, &g);
        assert!((mlp.weights[0].get(0, 0) - (before - 0.2)).abs() < 1e-6);
    }

    #[test]
    fn sharded_average_equals_full_batch_gradient() {
        // Gradient averaging over shards must equal the serial gradient of
        // the whole batch — the core data-parallel invariant.
        let mlp = Mlp::init(&[6, 8, 3], 5, Backend::Naive);
        let d = crate::nn::data::Dataset::gaussian_clusters(24, 6, 3, 0.3, 11);
        let (x_full, y_full) = d.slice(0, 24);
        let (_, g_full) = mlp.loss_and_grad(&x_full, &y_full);

        let mut parts = Vec::new();
        for (s, n) in [(0usize, 10usize), (10, 8), (18, 6)] {
            let (x, y) = d.slice(s, n);
            let (_, g) = mlp.loss_and_grad(&x, &y);
            parts.push((n, g));
        }
        let g_avg = average_grads(&parts, &mlp);
        for (a, b) in g_avg.d_weights.iter().zip(&g_full.d_weights) {
            assert!(a.max_abs_diff(b) < 1e-5, "sharded avg != full gradient");
        }
        for (a, b) in g_avg.d_biases.iter().zip(&g_full.d_biases) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let mut mlp = Mlp::init(&[8, 16, 3], 2, Backend::Simd);
        let d = crate::nn::data::Dataset::gaussian_clusters(128, 8, 3, 0.3, 13);
        let sgd = Sgd::new(0.5);
        let (x, y) = d.slice(0, 128);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            let (loss, g) = mlp.loss_and_grad(&x, &y);
            sgd.apply(&mut mlp, &g);
            first.get_or_insert(loss);
            last = loss;
        }
        let first = first.unwrap();
        assert!(last < first * 0.5, "loss did not fall: {first} -> {last}");
        // And accuracy should be high on this easy task.
        let acc = Mlp::accuracy(&mlp.forward(&x), &y);
        assert!(acc > 0.9, "accuracy {acc}");
        let _ = Matrix::zeros(1, 1); // keep import used in all cfg combinations
    }

    #[test]
    #[should_panic]
    fn zero_lr_rejected() {
        let _ = Sgd::new(0.0);
    }
}
