//! Neural-network substrate for the paper's §4 application.
//!
//! The paper's motivating workload is large-scale neural-network training
//! with SGEMM as the kernel (ref [1]: "98¢/MFlop ultra-large-scale neural
//! network training on a PIII cluster"). This module provides the network:
//! a tanh MLP whose forward *and* backward passes are expressed entirely
//! as SGEMM calls through [`crate::blas`], so every training flop goes
//! through the Emmerald kernel — natively here, or through the AOT Pallas
//! artifact via [`crate::runtime`].
//!
//! On the default backend both workloads lean on the GEMM engine's fused
//! paths: the MLP's per-layer bias + tanh ride each GEMM as a fused
//! [`crate::gemm::Epilogue`] (one traversal of the activations instead of
//! two), and [`conv::Conv2d`] never materialises its im2col patch matrix —
//! [`conv::Im2ColRef`] packs convolution patches straight into the tile
//! driver's `B` panels.
//!
//! * [`mlp`] — parameters, forward, softmax cross-entropy, full backprop.
//! * [`conv`] — convolution lowered onto GEMM (fused or materialised
//!   im2col).
//! * [`linear`] — a standalone dense layer with the **quantized
//!   inference** path: per-channel i8 weights + per-row affine u8
//!   activations through the exact `u8 × i8 → i32` GEMM tier
//!   ([`crate::gemm::quant`]), dequantized in the fused
//!   [`crate::gemm::Requant`] writeback.
//! * [`data`] — deterministic synthetic classification data (Gaussian
//!   clusters) so training runs are reproducible without external files.
//! * [`sgd`] — plain SGD and gradient averaging for data parallelism.

pub mod conv;
pub mod data;
pub mod linear;
pub mod mlp;
pub mod sgd;

pub use conv::{Conv2d, Im2ColRef, PackedConvKernels};
pub use data::Dataset;
pub use linear::{quantize_rows, Linear, QuantizedLinear};
pub use mlp::{Mlp, MlpGrads};
