//! Neural-network substrate for the paper's §4 application.
//!
//! The paper's motivating workload is large-scale neural-network training
//! with SGEMM as the kernel (ref [1]: "98¢/MFlop ultra-large-scale neural
//! network training on a PIII cluster"). This module provides the network:
//! a tanh MLP whose forward *and* backward passes are expressed entirely
//! as SGEMM calls through [`crate::blas`], so every training flop goes
//! through the Emmerald kernel — natively here, or through the AOT Pallas
//! artifact via [`crate::runtime`].
//!
//! * [`mlp`] — parameters, forward, softmax cross-entropy, full backprop.
//! * [`data`] — deterministic synthetic classification data (Gaussian
//!   clusters) so training runs are reproducible without external files.
//! * [`sgd`] — plain SGD and gradient averaging for data parallelism.

pub mod conv;
pub mod data;
pub mod mlp;
pub mod sgd;

pub use data::Dataset;
pub use mlp::{Mlp, MlpGrads};
