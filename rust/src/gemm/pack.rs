//! Re-buffering (paper §3): copying blocks of `A` and `B` into contiguous,
//! padded scratch buffers.
//!
//! The paper deliberately buffers `B'` (the `kb × nr` panel) into L1 and
//! *reorders* it "to enforce optimal memory access patterns [and] minimise
//! translation look-aside buffer misses". [`PackedB`] implements exactly
//! that layout: the k-block of `op(B)` is stored panel-major — `nr`
//! columns per panel, each column contiguous in `k` and zero-padded to a
//! SIMD-friendly length — so the micro-kernel's five column streams are
//! unit-stride and TLB-dense.
//!
//! [`PackedA`] packs a row block of `op(A)` the same way; the paper does
//! not pack `A` (it streams rows with prefetch), but packing becomes
//! necessary when `A` is logically transposed (its rows are then strided
//! in memory) and is exposed as an ablation toggle otherwise.

use crate::blas::{MatRef, Transpose};

/// Columns are padded to a multiple of this many f32 lanes so both the
/// 4-wide SSE and 8-wide AVX2 kernels can run their full-vector loop on
/// the same buffer.
pub const K_PAD_LANES: usize = 8;

/// Round `k` up to the padding granule.
pub fn kpad_for(k: usize) -> usize {
    k.div_ceil(K_PAD_LANES) * K_PAD_LANES
}

/// A k-block of `op(B)` packed panel-major (see module docs).
///
/// Layout: panel `p` starts at `p * nr * kpad`; within a panel, column `j`
/// (logical column `p*nr + j`) occupies `kpad` consecutive floats, the
/// first `kb_eff` holding data and the rest zeros.
#[derive(Debug)]
pub struct PackedB {
    buf: Vec<f32>,
    nr: usize,
    kpad: usize,
    kb_eff: usize,
    n: usize,
}

impl PackedB {
    /// An empty packed buffer for panels of `nr` columns.
    pub fn new(nr: usize) -> Self {
        assert!((1..=8).contains(&nr));
        Self { buf: Vec::new(), nr, kpad: 0, kb_eff: 0, n: 0 }
    }

    /// Pack rows `kk .. kk+kb_eff` of `op(B)` (all `n` columns).
    ///
    /// `b` is the *stored* matrix; `transb` says whether `op(B) = B` or
    /// `Bᵀ`. The buffer is reused across calls (no allocation once warm).
    pub fn pack(&mut self, b: MatRef<'_>, transb: Transpose, kk: usize, kb_eff: usize, n: usize) {
        let kpad = kpad_for(kb_eff);
        let panels = n.div_ceil(self.nr).max(1);
        let need = panels * self.nr * kpad;
        self.buf.clear();
        self.buf.resize(need, 0.0);
        self.kpad = kpad;
        self.kb_eff = kb_eff;
        self.n = n;
        for j in 0..n {
            let panel = j / self.nr;
            let lane = j % self.nr;
            let base = panel * self.nr * kpad + lane * kpad;
            match transb {
                Transpose::No => {
                    // Column j of B: strided by ldb in storage.
                    for p in 0..kb_eff {
                        // SAFETY: kk+p < b.rows(), j < b.cols() — caller
                        // guarantees the block is in range.
                        self.buf[base + p] = unsafe { b.get_unchecked(kk + p, j) };
                    }
                }
                Transpose::Yes => {
                    // Column j of Bᵀ = row j of B: contiguous in storage.
                    for p in 0..kb_eff {
                        self.buf[base + p] = unsafe { b.get_unchecked(j, kk + p) };
                    }
                }
            }
        }
    }

    /// Number of panels currently packed.
    pub fn panels(&self) -> usize {
        self.n.div_ceil(self.nr)
    }

    /// Logical width of panel `p` (last panel may be narrower than `nr`).
    pub fn panel_width(&self, p: usize) -> usize {
        let j0 = p * self.nr;
        debug_assert!(j0 < self.n.max(1));
        self.nr.min(self.n - j0)
    }

    /// Pointer to the packed column `j` (0-based within panel `p`).
    #[inline(always)]
    pub fn col_ptr(&self, p: usize, j: usize) -> *const f32 {
        debug_assert!(j < self.panel_width(p));
        unsafe { self.buf.as_ptr().add((p * self.nr + j) * self.kpad) }
    }

    /// Padded column length.
    pub fn kpad(&self) -> usize {
        self.kpad
    }

    /// Unpadded (logical) column length.
    pub fn kb_eff(&self) -> usize {
        self.kb_eff
    }

    /// Bytes currently held (diagnostic; the L1-residency argument).
    pub fn bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<f32>()
    }
}

/// A row block of `op(A)` packed row-major with zero-padded rows.
#[derive(Debug)]
pub struct PackedA {
    buf: Vec<f32>,
    kpad: usize,
    rows: usize,
}

impl PackedA {
    /// An empty packed buffer.
    pub fn new() -> Self {
        Self { buf: Vec::new(), kpad: 0, rows: 0 }
    }

    /// Pack the `mb_eff × kb_eff` block of `op(A)` at `(ii, kk)`.
    pub fn pack(
        &mut self,
        a: MatRef<'_>,
        transa: Transpose,
        ii: usize,
        mb_eff: usize,
        kk: usize,
        kb_eff: usize,
    ) {
        let kpad = kpad_for(kb_eff);
        self.buf.clear();
        self.buf.resize(mb_eff.max(1) * kpad, 0.0);
        self.kpad = kpad;
        self.rows = mb_eff;
        for i in 0..mb_eff {
            let base = i * kpad;
            match transa {
                Transpose::No => {
                    for p in 0..kb_eff {
                        // SAFETY: block range guaranteed by caller.
                        self.buf[base + p] = unsafe { a.get_unchecked(ii + i, kk + p) };
                    }
                }
                Transpose::Yes => {
                    for p in 0..kb_eff {
                        self.buf[base + p] = unsafe { a.get_unchecked(kk + p, ii + i) };
                    }
                }
            }
        }
    }

    /// Pointer to packed row `i` (length `kpad`, zero-padded tail).
    #[inline(always)]
    pub fn row_ptr(&self, i: usize) -> *const f32 {
        debug_assert!(i < self.rows);
        unsafe { self.buf.as_ptr().add(i * self.kpad) }
    }

    /// Padded row length.
    pub fn kpad(&self) -> usize {
        self.kpad
    }
}

impl Default for PackedA {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::Matrix;

    #[test]
    fn kpad_rounds_up() {
        assert_eq!(kpad_for(1), 8);
        assert_eq!(kpad_for(8), 8);
        assert_eq!(kpad_for(9), 16);
        assert_eq!(kpad_for(336), 336);
    }

    #[test]
    fn packs_b_columns_contiguously() {
        // B is 6x7; pack rows 1..5 (kb_eff=4) with nr=3.
        let b = Matrix::from_fn(6, 7, |r, c| (r * 10 + c) as f32);
        let mut pb = PackedB::new(3);
        pb.pack(b.view(), Transpose::No, 1, 4, 7);
        assert_eq!(pb.panels(), 3);
        assert_eq!(pb.panel_width(0), 3);
        assert_eq!(pb.panel_width(2), 1);
        assert_eq!(pb.kpad(), 8);
        // Column 4 lives in panel 1, lane 1: values B[1..5][4].
        let col = pb.col_ptr(1, 1);
        let vals: Vec<f32> = (0..8).map(|p| unsafe { *col.add(p) }).collect();
        assert_eq!(&vals[..4], &[14.0, 24.0, 34.0, 44.0]);
        assert_eq!(&vals[4..], &[0.0; 4], "padding must be zero");
    }

    #[test]
    fn packs_transposed_b() {
        // op(B) = Bᵀ where B is stored 5x6; op(B) is 6x5.
        let b = Matrix::from_fn(5, 6, |r, c| (r * 10 + c) as f32);
        let mut pb = PackedB::new(2);
        pb.pack(b.view(), Transpose::Yes, 2, 3, 5);
        // op(B)[k][j] = B[j][k]; column j=3 over k=2..5 → B[3][2..5].
        let col = pb.col_ptr(1, 1);
        let vals: Vec<f32> = (0..3).map(|p| unsafe { *col.add(p) }).collect();
        assert_eq!(vals, vec![32.0, 33.0, 34.0]);
    }

    #[test]
    fn packs_a_rows() {
        let a = Matrix::from_fn(4, 9, |r, c| (r * 100 + c) as f32);
        let mut pa = PackedA::new();
        pa.pack(a.view(), Transpose::No, 1, 2, 3, 5);
        let r0: Vec<f32> = (0..8).map(|p| unsafe { *pa.row_ptr(0).add(p) }).collect();
        assert_eq!(&r0[..5], &[103.0, 104.0, 105.0, 106.0, 107.0]);
        assert_eq!(&r0[5..], &[0.0; 3]);
        let r1: Vec<f32> = (0..5).map(|p| unsafe { *pa.row_ptr(1).add(p) }).collect();
        assert_eq!(r1, vec![203.0, 204.0, 205.0, 206.0, 207.0]);
    }

    #[test]
    fn packs_transposed_a() {
        // op(A) = Aᵀ with A stored 6x3; block rows 0..2 of op(A), k 1..4.
        let a = Matrix::from_fn(6, 3, |r, c| (r * 10 + c) as f32);
        let mut pa = PackedA::new();
        pa.pack(a.view(), Transpose::Yes, 0, 2, 1, 3);
        // op(A)[i][p] = A[p][i]; row 1, k=1..4 → A[1..4][1] = 11, 21, 31.
        let r1: Vec<f32> = (0..3).map(|p| unsafe { *pa.row_ptr(1).add(p) }).collect();
        assert_eq!(r1, vec![11.0, 21.0, 31.0]);
    }

    #[test]
    fn reuse_shrinks_and_grows() {
        let b = Matrix::from_fn(20, 20, |r, c| (r + c) as f32);
        let mut pb = PackedB::new(5);
        pb.pack(b.view(), Transpose::No, 0, 16, 20);
        let big = pb.bytes();
        pb.pack(b.view(), Transpose::No, 0, 2, 3);
        assert!(pb.bytes() < big);
        assert_eq!(pb.panels(), 1);
        assert_eq!(pb.kb_eff(), 2);
    }

    #[test]
    fn paper_panel_footprint() {
        // The paper's B' (336 × 5 f32) must land at ≈6.7 KB — the L1
        // residency argument of fig. 1(b).
        let b = Matrix::zeros(336, 5);
        let mut pb = PackedB::new(5);
        pb.pack(b.view(), Transpose::No, 0, 336, 5);
        assert_eq!(pb.bytes(), 336 * 5 * 4);
    }
}
