//! Re-buffering (paper §3): copying blocks of `A` and `B` into contiguous,
//! padded scratch buffers.
//!
//! The paper deliberately buffers `B'` (the `kb × nr` panel) into L1 and
//! *reorders* it "to enforce optimal memory access patterns [and] minimise
//! translation look-aside buffer misses". [`PackedB`] implements exactly
//! that layout: the k-block of `op(B)` is stored panel-major — `nr`
//! columns per panel, each column contiguous in `k` and zero-padded to a
//! SIMD-friendly length — so the micro-kernel's five column streams are
//! unit-stride and TLB-dense.
//!
//! [`PackedA`] packs a row block of `op(A)` the same way; the paper does
//! not pack `A` (it streams rows with prefetch), but packing becomes
//! necessary when `A` is logically transposed (its rows are then strided
//! in memory) and is exposed as an ablation toggle otherwise.
//!
//! All packers are generic over the storage scalar
//! ([`crate::gemm::Scalar`]), not the float kernel trait: the kernel
//! triple's A side packs `K::Lhs` and its B side packs `K::Rhs`, so the
//! same layouts serve f32/f64 GEMM and the quantized `u8`/`i8` tier (the
//! latter's 4-k-group re-ordering lives in [`crate::gemm::quant`], built
//! on the same principles).

use super::element::Scalar;
use crate::blas::{MatRef, Transpose};
use crate::util::ptr::RawSlice;

/// A *virtual* `op(B)` operand: anything that can hand the packers one
/// logical element per `(row, col)` index. The packers stream such a
/// source panel-by-panel into the normal packed layouts, so a producer
/// that can compute its elements on demand — the fused-im2col conv view
/// ([`crate::nn::conv::Im2ColRef`]) is the motivating case — never has
/// to materialise the full matrix: only the packed k-block scratch
/// (`kc × nc` elements) ever exists in memory.
///
/// Indices are in the *logical* (already-transposed) orientation: `get(r,
/// c)` is `op(B)[r][c]`, with `r < rows()` and `c < cols()`.
pub trait PanelSource<T> {
    /// Logical row count of `op(B)` (the GEMM `k`).
    fn rows(&self) -> usize;
    /// Logical column count of `op(B)` (the GEMM `n`).
    fn cols(&self) -> usize;
    /// The logical element `op(B)[r][c]`.
    fn get(&self, r: usize, c: usize) -> T;
}

/// A `B` operand as the tile driver sees it: a stored matrix plus its
/// transpose flag, or a virtual [`PanelSource`] packed on demand.
#[derive(Clone, Copy)]
pub(crate) enum BSource<'s, T = f32> {
    /// A stored matrix (the normal GEMM path).
    Mat(MatRef<'s, T>, Transpose),
    /// A virtual source; elements are computed during packing.
    Virtual(&'s dyn PanelSource<T>),
}

impl<T: Scalar> BSource<'_, T> {
    /// Pack a k-block of this source into `tb`'s NR-panel layout.
    pub(crate) fn pack_tile(
        &self,
        tb: &mut TilePackedB<T>,
        kk: usize,
        kb_eff: usize,
        j0: usize,
        nb_eff: usize,
        nr: usize,
    ) {
        match *self {
            BSource::Mat(b, transb) => tb.pack(b, transb, kk, kb_eff, j0, nb_eff, nr),
            BSource::Virtual(src) => tb.pack_from(src, kk, kb_eff, j0, nb_eff, nr),
        }
    }
}

/// Columns are padded to a multiple of this many f32 lanes so both the
/// 4-wide SSE and 8-wide AVX2 kernels can run their full-vector loop on
/// the same buffer.
pub const K_PAD_LANES: usize = 8;

/// Round `k` up to the padding granule.
pub fn kpad_for(k: usize) -> usize {
    k.div_ceil(K_PAD_LANES) * K_PAD_LANES
}

/// A k-block of `op(B)` packed panel-major (see module docs).
///
/// Layout: panel `p` starts at `p * nr * kpad`; within a panel, column `j`
/// (logical column `p*nr + j`) occupies `kpad` consecutive floats, the
/// first `kb_eff` holding data and the rest zeros.
#[derive(Debug)]
pub struct PackedB<T = f32> {
    buf: Vec<T>,
    nr: usize,
    kpad: usize,
    kb_eff: usize,
    n: usize,
}

impl<T: Scalar> PackedB<T> {
    /// An empty packed buffer for panels of `nr` columns.
    pub fn new(nr: usize) -> Self {
        assert!((1..=8).contains(&nr));
        Self { buf: Vec::new(), nr, kpad: 0, kb_eff: 0, n: 0 }
    }

    /// Re-target the buffer at a different panel width, keeping the
    /// allocation. Lets one scratch buffer serve GEMMs with different
    /// `nr` (the batched driver reuses a per-worker buffer across items).
    pub fn ensure_nr(&mut self, nr: usize) {
        assert!((1..=8).contains(&nr));
        if self.nr != nr {
            self.nr = nr;
            // Invalidate the logical contents; the allocation survives.
            self.kpad = 0;
            self.kb_eff = 0;
            self.n = 0;
        }
    }

    /// Pack rows `kk .. kk+kb_eff` of `op(B)` (all `n` columns).
    ///
    /// `b` is the *stored* matrix; `transb` says whether `op(B) = B` or
    /// `Bᵀ`. The buffer is reused across calls (no allocation once warm).
    pub fn pack(&mut self, b: MatRef<'_, T>, transb: Transpose, kk: usize, kb_eff: usize, n: usize) {
        // Block-range invariant: the requested k-block and column window
        // must lie inside op(B).
        match transb {
            Transpose::No => debug_assert!(kk + kb_eff <= b.rows() && n <= b.cols()),
            Transpose::Yes => debug_assert!(kk + kb_eff <= b.cols() && n <= b.rows()),
        }
        let kpad = kpad_for(kb_eff);
        let panels = n.div_ceil(self.nr).max(1);
        let need = panels * self.nr * kpad;
        self.buf.clear();
        self.buf.resize(need, T::ZERO);
        // Layout invariant: every panel's nr columns of kpad elements fit.
        debug_assert!(panels * self.nr * kpad <= self.buf.len());
        self.kpad = kpad;
        self.kb_eff = kb_eff;
        self.n = n;
        let braw = b.raw();
        for j in 0..n {
            let panel = j / self.nr;
            let lane = j % self.nr;
            let base = panel * self.nr * kpad + lane * kpad;
            match transb {
                Transpose::No => {
                    // Column j of B: strided by ldb in storage.
                    for p in 0..kb_eff {
                        // SAFETY: kk+p < b.rows() and j < b.cols() by the
                        // block-range invariant asserted above (verified
                        // again inside the checked handle in debug).
                        self.buf[base + p] = unsafe { braw.get(kk + p, j) };
                    }
                }
                Transpose::Yes => {
                    // Column j of Bᵀ = row j of B: contiguous in storage.
                    for p in 0..kb_eff {
                        // SAFETY: j < b.rows() and kk+p < b.cols() by the
                        // block-range invariant asserted above.
                        self.buf[base + p] = unsafe { braw.get(j, kk + p) };
                    }
                }
            }
        }
    }

    /// Number of panels currently packed.
    pub fn panels(&self) -> usize {
        self.n.div_ceil(self.nr)
    }

    /// Logical width of panel `p` (last panel may be narrower than `nr`).
    pub fn panel_width(&self, p: usize) -> usize {
        let j0 = p * self.nr;
        debug_assert!(j0 < self.n.max(1));
        self.nr.min(self.n - j0)
    }

    /// Pointer to the packed column `j` (0-based within panel `p`).
    /// The column's `kpad` elements are verified against the buffer
    /// length, so the pointer is good for `kpad` reads.
    #[inline(always)]
    pub fn col_ptr(&self, p: usize, j: usize) -> *const T {
        debug_assert!(j < self.panel_width(p));
        let off = (p * self.nr + j) * self.kpad;
        debug_assert!(off + self.kpad <= self.buf.len());
        self.buf[off..].as_ptr()
    }

    /// Length-carrying span of the packed column `j` in panel `p`:
    /// exactly the column's `kpad` elements (data then zero padding).
    /// This is what the safe kernel-call wrappers in [`super::simd`]
    /// consume — the span proves the kernel's read extent at the call
    /// site instead of trusting a bare pointer.
    #[inline(always)]
    pub(crate) fn col_span(&self, p: usize, j: usize) -> RawSlice<T> {
        assert!(j < self.panel_width(p), "col_span: column {j} out of panel {p}");
        let off = (p * self.nr + j) * self.kpad;
        RawSlice::from_slice(&self.buf[off..off + self.kpad])
    }

    /// Safe value view of global column `j` (`0..n`): the column's `kpad`
    /// elements, the first `kb_eff` holding data. Panels are laid out so
    /// that global column `j` starts exactly at `j * kpad` — used by the
    /// planned compensated path to reconstruct operand values from a
    /// packed handle without touching raw pointers.
    #[inline]
    pub(crate) fn col(&self, j: usize) -> &[T] {
        assert!(j < self.n, "col: column {j} out of {}", self.n);
        &self.buf[j * self.kpad..(j + 1) * self.kpad]
    }

    /// Padded column length.
    pub fn kpad(&self) -> usize {
        self.kpad
    }

    /// Unpadded (logical) column length.
    pub fn kb_eff(&self) -> usize {
        self.kb_eff
    }

    /// Bytes currently held (diagnostic; the L1-residency argument).
    pub fn bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<T>()
    }
}

/// A row block of `op(A)` packed row-major with zero-padded rows.
#[derive(Debug)]
pub struct PackedA<T = f32> {
    buf: Vec<T>,
    kpad: usize,
    rows: usize,
}

impl<T: Scalar> PackedA<T> {
    /// An empty packed buffer.
    pub fn new() -> Self {
        Self { buf: Vec::new(), kpad: 0, rows: 0 }
    }

    /// Pack the `mb_eff × kb_eff` block of `op(A)` at `(ii, kk)`.
    pub fn pack(
        &mut self,
        a: MatRef<'_, T>,
        transa: Transpose,
        ii: usize,
        mb_eff: usize,
        kk: usize,
        kb_eff: usize,
    ) {
        // Block-range invariant: the mb_eff × kb_eff block at (ii, kk)
        // must lie inside op(A).
        match transa {
            Transpose::No => debug_assert!(ii + mb_eff <= a.rows() && kk + kb_eff <= a.cols()),
            Transpose::Yes => debug_assert!(ii + mb_eff <= a.cols() && kk + kb_eff <= a.rows()),
        }
        let kpad = kpad_for(kb_eff);
        self.buf.clear();
        self.buf.resize(mb_eff.max(1) * kpad, T::ZERO);
        // Layout invariant: mb_eff rows of kpad elements fit the buffer.
        debug_assert!(mb_eff * kpad <= self.buf.len());
        self.kpad = kpad;
        self.rows = mb_eff;
        let araw = a.raw();
        for i in 0..mb_eff {
            let base = i * kpad;
            match transa {
                Transpose::No => {
                    for p in 0..kb_eff {
                        // SAFETY: ii+i < a.rows(), kk+p < a.cols() by the
                        // block-range invariant asserted above.
                        self.buf[base + p] = unsafe { araw.get(ii + i, kk + p) };
                    }
                }
                Transpose::Yes => {
                    for p in 0..kb_eff {
                        // SAFETY: kk+p < a.rows(), ii+i < a.cols() by the
                        // block-range invariant asserted above.
                        self.buf[base + p] = unsafe { araw.get(kk + p, ii + i) };
                    }
                }
            }
        }
    }

    /// Pointer to packed row `i` (length `kpad`, zero-padded tail).
    #[inline(always)]
    pub fn row_ptr(&self, i: usize) -> *const T {
        debug_assert!(i < self.rows);
        let off = i * self.kpad;
        debug_assert!(off + self.kpad <= self.buf.len());
        self.buf[off..].as_ptr()
    }

    /// Length-carrying span of packed row `i`: exactly the row's `kpad`
    /// elements (data then zero padding). Consumed by the safe
    /// kernel-call wrappers in [`super::simd`].
    #[inline(always)]
    pub(crate) fn row_span(&self, i: usize) -> RawSlice<T> {
        assert!(i < self.rows, "row_span: row {i} out of {}", self.rows);
        let off = i * self.kpad;
        RawSlice::from_slice(&self.buf[off..off + self.kpad])
    }

    /// Safe value view of packed row `i`: the row's `kpad` elements, the
    /// leading portion holding data (zero tail). Companion of
    /// [`PackedB::col`] for the planned compensated reconstruction.
    #[inline]
    pub(crate) fn row(&self, i: usize) -> &[T] {
        assert!(i < self.rows, "row: row {i} out of {}", self.rows);
        &self.buf[i * self.kpad..(i + 1) * self.kpad]
    }

    /// Padded row length.
    pub fn kpad(&self) -> usize {
        self.kpad
    }
}

impl<T: Scalar> Default for PackedA<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// A block of `op(A)` packed in **MR-row strips** for the outer-product
/// tile kernel ([`crate::gemm::tile`]).
///
/// Layout: strip `s` covers rows `s*mr .. s*mr+mr` of the block and
/// occupies `mr * kc_eff` consecutive floats; within a strip the data is
/// k-major — offset `p*mr + l` holds `op(A)[row s*mr+l][kk+p]`. The
/// micro-kernel broadcasts `mr` consecutive values per k step. Rows past
/// the block's edge are zero-filled so fringe strips run the full-MR
/// kernel (the padded lanes are masked out at writeback).
#[derive(Debug)]
pub struct TilePackedA<T = f32> {
    buf: Vec<T>,
    mr: usize,
    kc_eff: usize,
    rows: usize,
}

impl<T: Scalar> TilePackedA<T> {
    /// An empty packed buffer.
    pub fn new() -> Self {
        Self { buf: Vec::new(), mr: 1, kc_eff: 0, rows: 0 }
    }

    /// Pack the `mb_eff × kb_eff` block of `op(A)` at `(ii, kk)` into
    /// `mr`-row strips.
    #[allow(clippy::too_many_arguments)]
    pub fn pack(
        &mut self,
        a: MatRef<'_, T>,
        transa: Transpose,
        ii: usize,
        mb_eff: usize,
        kk: usize,
        kb_eff: usize,
        mr: usize,
    ) {
        assert!(mr >= 1);
        // Block-range invariant: the mb_eff × kb_eff block at (ii, kk)
        // must lie inside op(A).
        match transa {
            Transpose::No => debug_assert!(ii + mb_eff <= a.rows() && kk + kb_eff <= a.cols()),
            Transpose::Yes => debug_assert!(ii + mb_eff <= a.cols() && kk + kb_eff <= a.rows()),
        }
        let strips = mb_eff.div_ceil(mr).max(1);
        self.buf.clear();
        self.buf.resize(strips * mr * kb_eff.max(1), T::ZERO);
        // k-major layout invariant: strips × mr × kc must fit the buffer.
        debug_assert!(strips * mr * kb_eff <= self.buf.len());
        self.mr = mr;
        self.kc_eff = kb_eff;
        self.rows = mb_eff;
        let araw = a.raw();
        for s in 0..strips {
            let base = s * mr * kb_eff;
            let h = mr.min(mb_eff.saturating_sub(s * mr));
            for p in 0..kb_eff {
                for l in 0..h {
                    let i = s * mr + l;
                    // SAFETY: i < mb_eff (h clamps to the block edge) and
                    // p < kb_eff, so both indices are inside op(A) by the
                    // block-range invariant asserted above.
                    self.buf[base + p * mr + l] = unsafe {
                        match transa {
                            Transpose::No => araw.get(ii + i, kk + p),
                            Transpose::Yes => araw.get(kk + p, ii + i),
                        }
                    };
                }
                // Rows h..mr stay zero (buf was zero-filled).
            }
        }
    }

    /// Number of strips currently packed.
    pub fn strips(&self) -> usize {
        self.rows.div_ceil(self.mr).max(1)
    }

    /// Logical height of strip `s` (the last strip may be shorter).
    pub fn strip_height(&self, s: usize) -> usize {
        self.mr.min(self.rows - s * self.mr)
    }

    /// Pointer to packed strip `s` (`mr * kc_eff` elements, k-major).
    #[inline(always)]
    pub fn strip_ptr(&self, s: usize) -> *const T {
        debug_assert!(s < self.strips());
        let off = s * self.mr * self.kc_eff;
        debug_assert!(off + self.mr * self.kc_eff <= self.buf.len());
        self.buf[off..].as_ptr()
    }

    /// Unpadded k depth of the packed block.
    pub fn kc_eff(&self) -> usize {
        self.kc_eff
    }

    /// Safe value read of `op(A)[strip s, lane l][k = p]` from the k-major
    /// strip layout (compensated reconstruction; bounds-checked).
    #[inline]
    pub(crate) fn at(&self, s: usize, p: usize, l: usize) -> T {
        assert!(s < self.strips() && p < self.kc_eff && l < self.mr);
        self.buf[s * self.mr * self.kc_eff + p * self.mr + l]
    }

    /// Bytes currently held (diagnostic).
    pub fn bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<T>()
    }
}

impl<T: Scalar> Default for TilePackedA<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// A block of `op(B)` packed in **NR-column panels** for the outer-product
/// tile kernel — the paper's re-buffering generalised to the tile's NR
/// and re-ordered k-major.
///
/// Layout: panel `q` covers columns `j0 + q*nr ..` and occupies
/// `nr * kc_eff` consecutive floats; offset `p*nr + l` holds
/// `op(B)[kk+p][j0 + q*nr + l]`. One k step of the micro-kernel loads the
/// panel's `nr` consecutive values as two full vectors. Columns past the
/// block's edge are zero-filled (masked out at writeback).
#[derive(Debug)]
pub struct TilePackedB<T = f32> {
    buf: Vec<T>,
    nr: usize,
    kc_eff: usize,
    cols: usize,
}

impl<T: Scalar> TilePackedB<T> {
    /// An empty packed buffer.
    pub fn new() -> Self {
        Self { buf: Vec::new(), nr: 1, kc_eff: 0, cols: 0 }
    }

    /// Pack rows `kk .. kk+kb_eff` of `op(B)`, columns `j0 .. j0+nb_eff`,
    /// into `nr`-column panels.
    #[allow(clippy::too_many_arguments)]
    pub fn pack(
        &mut self,
        b: MatRef<'_, T>,
        transb: Transpose,
        kk: usize,
        kb_eff: usize,
        j0: usize,
        nb_eff: usize,
        nr: usize,
    ) {
        assert!(nr >= 1);
        // Block-range invariant: the kb_eff × nb_eff window at (kk, j0)
        // must lie inside op(B).
        match transb {
            Transpose::No => debug_assert!(kk + kb_eff <= b.rows() && j0 + nb_eff <= b.cols()),
            Transpose::Yes => debug_assert!(kk + kb_eff <= b.cols() && j0 + nb_eff <= b.rows()),
        }
        let panels = nb_eff.div_ceil(nr).max(1);
        self.buf.clear();
        self.buf.resize(panels * nr * kb_eff.max(1), T::ZERO);
        // k-major layout invariant: panels × nr × kc must fit the buffer.
        debug_assert!(panels * nr * kb_eff <= self.buf.len());
        self.nr = nr;
        self.kc_eff = kb_eff;
        self.cols = nb_eff;
        let braw = b.raw();
        for q in 0..panels {
            let base = q * nr * kb_eff;
            let w = nr.min(nb_eff.saturating_sub(q * nr));
            for p in 0..kb_eff {
                for l in 0..w {
                    let j = j0 + q * nr + l;
                    // SAFETY: j < j0 + nb_eff (w clamps to the window
                    // edge) and p < kb_eff, so both indices are inside
                    // op(B) by the block-range invariant asserted above.
                    self.buf[base + p * nr + l] = unsafe {
                        match transb {
                            Transpose::No => braw.get(kk + p, j),
                            Transpose::Yes => braw.get(j, kk + p),
                        }
                    };
                }
            }
        }
    }

    /// [`pack`](Self::pack) from a virtual [`PanelSource`] instead of a
    /// stored matrix: identical layout, elements pulled on demand (the
    /// fused-im2col conv path packs patch windows straight into panels
    /// without ever materialising the im2col matrix).
    #[allow(clippy::too_many_arguments)]
    pub fn pack_from(
        &mut self,
        src: &dyn PanelSource<T>,
        kk: usize,
        kb_eff: usize,
        j0: usize,
        nb_eff: usize,
        nr: usize,
    ) {
        assert!(nr >= 1);
        // Block-range invariant, same as `pack`: the window must lie
        // inside the virtual op(B).
        debug_assert!(kk + kb_eff <= src.rows() && j0 + nb_eff <= src.cols());
        let panels = nb_eff.div_ceil(nr).max(1);
        self.buf.clear();
        self.buf.resize(panels * nr * kb_eff.max(1), T::ZERO);
        // k-major layout invariant: panels × nr × kc must fit the buffer.
        debug_assert!(panels * nr * kb_eff <= self.buf.len());
        self.nr = nr;
        self.kc_eff = kb_eff;
        self.cols = nb_eff;
        for q in 0..panels {
            let base = q * nr * kb_eff;
            let w = nr.min(nb_eff.saturating_sub(q * nr));
            for p in 0..kb_eff {
                for l in 0..w {
                    self.buf[base + p * nr + l] = src.get(kk + p, j0 + q * nr + l);
                }
            }
        }
    }

    /// Number of panels currently packed.
    pub fn panels(&self) -> usize {
        self.cols.div_ceil(self.nr).max(1)
    }

    /// Logical width of panel `q` (the last panel may be narrower).
    pub fn panel_width(&self, q: usize) -> usize {
        self.nr.min(self.cols - q * self.nr)
    }

    /// Pointer to packed panel `q` (`nr * kc_eff` elements, k-major).
    #[inline(always)]
    pub fn panel_ptr(&self, q: usize) -> *const T {
        debug_assert!(q < self.panels());
        let off = q * self.nr * self.kc_eff;
        debug_assert!(off + self.nr * self.kc_eff <= self.buf.len());
        self.buf[off..].as_ptr()
    }

    /// Unpadded k depth of the packed block.
    pub fn kc_eff(&self) -> usize {
        self.kc_eff
    }

    /// Safe value read of `op(B)[k = p][panel q, lane l]` from the k-major
    /// panel layout (compensated reconstruction; bounds-checked).
    #[inline]
    pub(crate) fn at(&self, q: usize, p: usize, l: usize) -> T {
        assert!(q < self.panels() && p < self.kc_eff && l < self.nr);
        self.buf[q * self.nr * self.kc_eff + p * self.nr + l]
    }

    /// Bytes currently held (diagnostic).
    pub fn bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<T>()
    }
}

impl<T: Scalar> Default for TilePackedB<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Reusable packing scratch for the blocked drivers.
///
/// The serial entry points allocate one of these per call; the batched
/// driver ([`crate::gemm::batch`]) keeps one per worker thread so the
/// packing buffers are allocated once and reused across every GEMM in the
/// batch — the paper's re-buffering cost amortised over the whole batch.
#[derive(Debug)]
pub struct Scratch<T = f32> {
    pub(crate) a: PackedA<T>,
    pub(crate) b: PackedB<T>,
    /// Tile-layout buffers for the outer-product tier (empty until the
    /// tile driver first runs through this scratch).
    pub(crate) ta: TilePackedA<T>,
    pub(crate) tb: TilePackedB<T>,
}

impl<T: Scalar> Scratch<T> {
    /// Fresh, empty scratch buffers.
    pub fn new() -> Self {
        Self { a: PackedA::new(), b: PackedB::new(1), ta: TilePackedA::new(), tb: TilePackedB::new() }
    }
}

impl<T: Scalar> Default for Scratch<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::Matrix;

    #[test]
    fn kpad_rounds_up() {
        assert_eq!(kpad_for(1), 8);
        assert_eq!(kpad_for(8), 8);
        assert_eq!(kpad_for(9), 16);
        assert_eq!(kpad_for(336), 336);
    }

    #[test]
    fn packs_b_columns_contiguously() {
        // B is 6x7; pack rows 1..5 (kb_eff=4) with nr=3.
        let b = Matrix::from_fn(6, 7, |r, c| (r * 10 + c) as f32);
        let mut pb = PackedB::new(3);
        pb.pack(b.view(), Transpose::No, 1, 4, 7);
        assert_eq!(pb.panels(), 3);
        assert_eq!(pb.panel_width(0), 3);
        assert_eq!(pb.panel_width(2), 1);
        assert_eq!(pb.kpad(), 8);
        // Column 4 lives in panel 1, lane 1: values B[1..5][4].
        let col = pb.col_ptr(1, 1);
        let vals: Vec<f32> = (0..8).map(|p| unsafe { *col.add(p) }).collect();
        assert_eq!(&vals[..4], &[14.0, 24.0, 34.0, 44.0]);
        assert_eq!(&vals[4..], &[0.0; 4], "padding must be zero");
    }

    #[test]
    fn packs_transposed_b() {
        // op(B) = Bᵀ where B is stored 5x6; op(B) is 6x5.
        let b = Matrix::from_fn(5, 6, |r, c| (r * 10 + c) as f32);
        let mut pb = PackedB::new(2);
        pb.pack(b.view(), Transpose::Yes, 2, 3, 5);
        // op(B)[k][j] = B[j][k]; column j=3 over k=2..5 → B[3][2..5].
        let col = pb.col_ptr(1, 1);
        let vals: Vec<f32> = (0..3).map(|p| unsafe { *col.add(p) }).collect();
        assert_eq!(vals, vec![32.0, 33.0, 34.0]);
    }

    #[test]
    fn packs_a_rows() {
        let a = Matrix::from_fn(4, 9, |r, c| (r * 100 + c) as f32);
        let mut pa = PackedA::new();
        pa.pack(a.view(), Transpose::No, 1, 2, 3, 5);
        let r0: Vec<f32> = (0..8).map(|p| unsafe { *pa.row_ptr(0).add(p) }).collect();
        assert_eq!(&r0[..5], &[103.0, 104.0, 105.0, 106.0, 107.0]);
        assert_eq!(&r0[5..], &[0.0; 3]);
        let r1: Vec<f32> = (0..5).map(|p| unsafe { *pa.row_ptr(1).add(p) }).collect();
        assert_eq!(r1, vec![203.0, 204.0, 205.0, 206.0, 207.0]);
    }

    #[test]
    fn packs_transposed_a() {
        // op(A) = Aᵀ with A stored 6x3; block rows 0..2 of op(A), k 1..4.
        let a = Matrix::from_fn(6, 3, |r, c| (r * 10 + c) as f32);
        let mut pa = PackedA::new();
        pa.pack(a.view(), Transpose::Yes, 0, 2, 1, 3);
        // op(A)[i][p] = A[p][i]; row 1, k=1..4 → A[1..4][1] = 11, 21, 31.
        let r1: Vec<f32> = (0..3).map(|p| unsafe { *pa.row_ptr(1).add(p) }).collect();
        assert_eq!(r1, vec![11.0, 21.0, 31.0]);
    }

    #[test]
    fn reuse_shrinks_and_grows() {
        let b = Matrix::from_fn(20, 20, |r, c| (r + c) as f32);
        let mut pb = PackedB::new(5);
        pb.pack(b.view(), Transpose::No, 0, 16, 20);
        let big = pb.bytes();
        pb.pack(b.view(), Transpose::No, 0, 2, 3);
        assert!(pb.bytes() < big);
        assert_eq!(pb.panels(), 1);
        assert_eq!(pb.kb_eff(), 2);
    }

    #[test]
    fn ensure_nr_retargets_and_invalidates() {
        let b = Matrix::from_fn(10, 10, |r, c| (r + c) as f32);
        let mut pb = PackedB::new(5);
        pb.pack(b.view(), Transpose::No, 0, 8, 10);
        assert_eq!(pb.panels(), 2);
        pb.ensure_nr(3);
        pb.pack(b.view(), Transpose::No, 0, 8, 10);
        assert_eq!(pb.panels(), 4);
        assert_eq!(pb.panel_width(3), 1);
        // Same nr is a no-op: contents stay valid.
        pb.ensure_nr(3);
        assert_eq!(pb.kb_eff(), 8);
    }

    #[test]
    fn k_not_a_multiple_of_pad_granule() {
        // kb_eff = 13 pads to 16; every padded tail element must be zero
        // for every lane, or the SIMD full-vector loop reads garbage.
        let b = Matrix::from_fn(13, 6, |r, c| (r * 10 + c) as f32 + 1.0);
        let mut pb = PackedB::new(4);
        pb.pack(b.view(), Transpose::No, 0, 13, 6);
        assert_eq!(pb.kpad(), 16);
        for j in 0..6 {
            let col = pb.col_ptr(j / 4, j % 4);
            for p in 0..16 {
                let got = unsafe { *col.add(p) };
                let want = if p < 13 { b.get(p, j) } else { 0.0 };
                assert_eq!(got, want, "col {j} p {p}");
            }
        }
    }

    #[test]
    fn n_not_a_multiple_of_panel_width() {
        // 7 columns at nr = 5: one full panel + a 2-wide fringe panel whose
        // unused lanes stay zero.
        let b = Matrix::from_fn(4, 7, |r, c| (r * 7 + c) as f32 + 1.0);
        let mut pb = PackedB::new(5);
        pb.pack(b.view(), Transpose::No, 0, 4, 7);
        assert_eq!(pb.panels(), 2);
        assert_eq!(pb.panel_width(0), 5);
        assert_eq!(pb.panel_width(1), 2);
        // Fringe panel, in-range lane.
        let col = pb.col_ptr(1, 1);
        let vals: Vec<f32> = (0..4).map(|p| unsafe { *col.add(p) }).collect();
        assert_eq!(vals, vec![7.0, 14.0, 21.0, 28.0]);
    }

    #[test]
    fn single_column_matrix_packs() {
        let b = Matrix::from_fn(5, 1, |r, _| (r + 1) as f32);
        let mut pb = PackedB::new(5);
        pb.pack(b.view(), Transpose::No, 0, 5, 1);
        assert_eq!(pb.panels(), 1);
        assert_eq!(pb.panel_width(0), 1);
        let col = pb.col_ptr(0, 0);
        let vals: Vec<f32> = (0..5).map(|p| unsafe { *col.add(p) }).collect();
        assert_eq!(vals, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn single_row_a_block_packs() {
        // mb_eff = 1 with a k-fringe (kb_eff = 3 → kpad = 8).
        let a = Matrix::from_fn(1, 5, |_, c| (c + 1) as f32);
        let mut pa = PackedA::new();
        pa.pack(a.view(), Transpose::No, 0, 1, 2, 3);
        let r0: Vec<f32> = (0..8).map(|p| unsafe { *pa.row_ptr(0).add(p) }).collect();
        assert_eq!(&r0[..3], &[3.0, 4.0, 5.0]);
        assert_eq!(&r0[3..], &[0.0; 5]);
    }

    #[test]
    fn strided_source_roundtrips_logical_values_only() {
        // Source stride wider than the logical width: the pack must read
        // the logical elements and never the -77 padding sentinels.
        let b = Matrix::<f32>::random_strided(9, 4, 9, 0xFACE);
        let mut pb = PackedB::new(3);
        pb.pack(b.view(), Transpose::No, 2, 6, 4);
        for j in 0..4 {
            let col = pb.col_ptr(j / 3, j % 3);
            for p in 0..6 {
                let got = unsafe { *col.add(p) };
                assert_eq!(got, b.get(2 + p, j), "col {j} p {p}");
                assert_ne!(got, -77.0, "sentinel leaked into packed panel");
            }
        }
        // Same property for transposed packing from a strided source.
        let mut pt = PackedB::new(2);
        pt.pack(b.view(), Transpose::Yes, 1, 3, 5);
        for j in 0..5 {
            let col = pt.col_ptr(j / 2, j % 2);
            for p in 0..3 {
                // op(B)[kk+p][j] = B[j][kk+p]
                assert_eq!(unsafe { *col.add(p) }, b.get(j, 1 + p), "T col {j} p {p}");
            }
        }
        // PackedA from the same strided source.
        let mut pa = PackedA::new();
        pa.pack(b.view(), Transpose::No, 3, 2, 1, 3);
        for i in 0..2 {
            for p in 0..3 {
                assert_eq!(unsafe { *pa.row_ptr(i).add(p) }, b.get(3 + i, 1 + p), "A row {i} p {p}");
            }
        }
    }

    #[test]
    fn tile_a_strips_are_k_major_and_zero_padded() {
        // 5 rows at mr = 2: strips [0,1], [2,3], [4,pad].
        let a = Matrix::from_fn(6, 9, |r, c| (r * 10 + c) as f32 + 1.0);
        let mut ta = TilePackedA::new();
        ta.pack(a.view(), Transpose::No, 1, 5, 2, 3, 2);
        assert_eq!(ta.strips(), 3);
        assert_eq!(ta.strip_height(0), 2);
        assert_eq!(ta.strip_height(2), 1);
        assert_eq!(ta.kc_eff(), 3);
        // Strip 1 covers block rows 2..4 = stored rows 3..5, k = 2..5.
        // k-major: [A[3][2], A[4][2], A[3][3], A[4][3], A[3][4], A[4][4]].
        let s1: Vec<f32> = (0..6).map(|p| unsafe { *ta.strip_ptr(1).add(p) }).collect();
        assert_eq!(s1, vec![33.0, 43.0, 34.0, 44.0, 35.0, 45.0]);
        // Fringe strip: real row 5 interleaved with zero padding.
        let s2: Vec<f32> = (0..6).map(|p| unsafe { *ta.strip_ptr(2).add(p) }).collect();
        assert_eq!(s2, vec![53.0, 0.0, 54.0, 0.0, 55.0, 0.0]);
    }

    #[test]
    fn tile_a_transposed_reads_columns() {
        // op(A) = Aᵀ with A stored 6x4; block rows 1..3 of op(A), k 2..5.
        let a = Matrix::from_fn(6, 4, |r, c| (r * 10 + c) as f32);
        let mut ta = TilePackedA::new();
        ta.pack(a.view(), Transpose::Yes, 1, 2, 2, 3, 2);
        // op(A)[i][p] = A[p][i]; strip 0, k-major pairs (rows 1,2 of op(A)):
        // p=2: A[2][1], A[2][2]; p=3: A[3][1], A[3][2]; p=4: ...
        let s0: Vec<f32> = (0..6).map(|p| unsafe { *ta.strip_ptr(0).add(p) }).collect();
        assert_eq!(s0, vec![21.0, 22.0, 31.0, 32.0, 41.0, 42.0]);
    }

    #[test]
    fn tile_b_panels_are_k_major_and_zero_padded() {
        // 7 columns at nr = 4: panel 0 full, panel 1 is 3 wide + padding.
        let b = Matrix::from_fn(5, 9, |r, c| (r * 10 + c) as f32 + 1.0);
        let mut tb = TilePackedB::new();
        tb.pack(b.view(), Transpose::No, 1, 2, 2, 7, 4);
        assert_eq!(tb.panels(), 2);
        assert_eq!(tb.panel_width(0), 4);
        assert_eq!(tb.panel_width(1), 3);
        // Panel 0, k-major: row kk+p of B, columns 2..6.
        let p0: Vec<f32> = (0..8).map(|p| unsafe { *tb.panel_ptr(0).add(p) }).collect();
        assert_eq!(p0, vec![13.0, 14.0, 15.0, 16.0, 23.0, 24.0, 25.0, 26.0]);
        // Panel 1: columns 6..9 + one zero lane.
        let p1: Vec<f32> = (0..8).map(|p| unsafe { *tb.panel_ptr(1).add(p) }).collect();
        assert_eq!(p1, vec![17.0, 18.0, 19.0, 0.0, 27.0, 28.0, 29.0, 0.0]);
    }

    #[test]
    fn tile_b_transposed_reads_rows() {
        // op(B) = Bᵀ with B stored 5x6; op(B) is 6x5. Columns 1..4 of
        // op(B) are rows 1..4 of B.
        let b = Matrix::from_fn(5, 6, |r, c| (r * 10 + c) as f32);
        let mut tb = TilePackedB::new();
        tb.pack(b.view(), Transpose::Yes, 2, 2, 1, 3, 4);
        // op(B)[kk+p][j] = B[j][kk+p]: p=0 → B[1][2], B[2][2], B[3][2], pad.
        let p0: Vec<f32> = (0..8).map(|p| unsafe { *tb.panel_ptr(0).add(p) }).collect();
        assert_eq!(p0, vec![12.0, 22.0, 32.0, 0.0, 13.0, 23.0, 33.0, 0.0]);
    }

    #[test]
    fn tile_buffers_reuse_without_stale_data() {
        let b = Matrix::from_fn(20, 20, |r, c| (r + c) as f32 + 1.0);
        let mut tb = TilePackedB::new();
        tb.pack(b.view(), Transpose::No, 0, 16, 0, 20, 16);
        let big = tb.bytes();
        // Repack smaller with a fringe panel: padding must be zero, not
        // stale values from the larger pack.
        tb.pack(b.view(), Transpose::No, 0, 2, 0, 3, 16);
        assert!(tb.bytes() < big);
        for p in 0..2 {
            for l in 3..16 {
                assert_eq!(unsafe { *tb.panel_ptr(0).add(p * 16 + l) }, 0.0, "stale lane {l} at k {p}");
            }
        }
    }

    /// A [`PanelSource`] view over a stored matrix — the trivial virtual
    /// source used to pin `pack_from` to `pack`.
    struct MatSource<'a>(&'a Matrix);

    impl PanelSource<f32> for MatSource<'_> {
        fn rows(&self) -> usize {
            self.0.rows()
        }
        fn cols(&self) -> usize {
            self.0.cols()
        }
        fn get(&self, r: usize, c: usize) -> f32 {
            self.0.get(r, c)
        }
    }

    #[test]
    fn pack_from_matches_pack_including_fringes() {
        // Same k-block + column window, panel fringe and all: the virtual
        // pack must produce byte-identical panels to the matrix pack.
        let b = Matrix::from_fn(9, 11, |r, c| (r * 13 + c) as f32 + 0.5);
        let mut direct = TilePackedB::new();
        let mut virt = TilePackedB::new();
        for &(kk, kb_eff, j0, nb_eff, nr) in
            &[(0, 9, 0, 11, 4), (2, 5, 3, 7, 4), (1, 3, 8, 3, 16), (0, 1, 0, 1, 1)]
        {
            direct.pack(b.view(), Transpose::No, kk, kb_eff, j0, nb_eff, nr);
            virt.pack_from(&MatSource(&b), kk, kb_eff, j0, nb_eff, nr);
            assert_eq!(direct.panels(), virt.panels());
            assert_eq!(direct.kc_eff(), virt.kc_eff());
            for q in 0..direct.panels() {
                for o in 0..nr * kb_eff {
                    let d = unsafe { *direct.panel_ptr(q).add(o) };
                    let v = unsafe { *virt.panel_ptr(q).add(o) };
                    assert_eq!(d, v, "kk={kk} kb={kb_eff} j0={j0} nb={nb_eff} nr={nr} q={q} o={o}");
                }
            }
        }
    }

    #[test]
    fn bsource_variants_pack_identically() {
        let b = Matrix::from_fn(6, 9, |r, c| (r * 9 + c) as f32 - 20.0);
        let src = MatSource(&b);
        let mut from_mat = TilePackedB::new();
        let mut from_virt = TilePackedB::new();
        BSource::Mat(b.view(), Transpose::No).pack_tile(&mut from_mat, 1, 4, 2, 7, 4);
        BSource::<f32>::Virtual(&src).pack_tile(&mut from_virt, 1, 4, 2, 7, 4);
        for q in 0..from_mat.panels() {
            for o in 0..4 * 4 {
                assert_eq!(
                    unsafe { *from_mat.panel_ptr(q).add(o) },
                    unsafe { *from_virt.panel_ptr(q).add(o) },
                    "q={q} o={o}"
                );
            }
        }
    }

    #[test]
    fn paper_panel_footprint() {
        // The paper's B' (336 × 5 f32) must land at ≈6.7 KB — the L1
        // residency argument of fig. 1(b).
        let b = Matrix::<f32>::zeros(336, 5);
        let mut pb = PackedB::new(5);
        pb.pack(b.view(), Transpose::No, 0, 336, 5);
        assert_eq!(pb.bytes(), 336 * 5 * 4);
    }
}
