//! The Emmerald driver: L1/L2 blocking around the SIMD micro-kernel.
//!
//! Structure (paper fig. 1b):
//!
//! ```text
//! for each k-block kk (depth kb, paper: 336):          // L1 blocking
//!     re-buffer B' = op(B)[kk.., :] into packed panels  // §3 re-buffering
//!     for each row-block ii (height mb):                // L2 blocking
//!         for each panel (nr columns, paper: 5):
//!             for each row i in the block:
//!                 C'[i, j0..j0+nr] += A'[i, kk..] · B'-panel   // micro-kernel
//! ```
//!
//! The B panel (`kb × nr` ≈ 6.7 KB) stays L1-resident across all `mb`
//! rows; the `A` row streams through with prefetch; `C` accumulates in
//! registers inside the micro-kernel and is written once per panel.

use super::element::Element;
use super::pack::Scratch;
use super::params::BlockParams;
use super::tile::EpRef;
use crate::blas::{MatMut, MatRef, Transpose};

/// Which vector ISA the shared driver dispatches to. Kernel selection per
/// element goes through [`Element::dot_panel_dyn`]: f32 has SSE and AVX2
/// instantiations, f64 has AVX2 (4-wide YMM) with a scalar panel standing
/// in for SSE.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VecIsa {
    /// 4-wide SSE (the paper's kernel).
    Sse,
    /// 8-wide AVX2 + FMA (modern extension).
    Avx2,
}

/// Emmerald GEMM on the SSE tier: `C = alpha * op(A) op(B) + beta * C`.
pub fn gemm<T: Element>(
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
) {
    gemm_vec(VecIsa::Sse, params, transa, transb, alpha, a, b, beta, c);
}

/// As [`gemm`], but reusing caller-provided packing buffers — the batched
/// driver calls this so packing allocation is amortised across a batch.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_scratch<T: Element>(
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    scratch: &mut Scratch<T>,
) {
    gemm_vec_scratch(VecIsa::Sse, params, transa, transb, alpha, a, b, beta, c, scratch);
}

/// Shared blocked driver over the per-element micro-kernels.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_vec<T: Element>(
    isa: VecIsa,
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
) {
    let mut scratch = Scratch::new();
    gemm_vec_scratch(isa, params, transa, transb, alpha, a, b, beta, c, &mut scratch);
}

/// As [`gemm_vec`], with a fused epilogue (fresh scratch) — the dispatch
/// and parallel tiers' entry.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_vec_ep<T: Element>(
    isa: VecIsa,
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    ep: EpRef<'_, T>,
) {
    let mut scratch = Scratch::new();
    gemm_vec_scratch_ep(isa, params, transa, transb, alpha, a, b, beta, c, &mut scratch, ep);
}

/// The driver proper, parameterised over reusable packing scratch.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_vec_scratch<T: Element>(
    isa: VecIsa,
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    scratch: &mut Scratch<T>,
) {
    gemm_vec_scratch_ep(isa, params, transa, transb, alpha, a, b, beta, c, scratch, None);
}

/// The full dot-tier driver, with an optional fused epilogue applied to
/// each `C` element as its **last k block**'s dot products are written
/// back (the element's value is complete there; earlier k blocks write
/// plain partial sums).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_vec_scratch_ep<T: Element>(
    isa: VecIsa,
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    scratch: &mut Scratch<T>,
    ep: EpRef<'_, T>,
) {
    params.validate().expect("invalid block parameters");
    let m = c.rows();
    let n = c.cols();
    let k = match transa {
        Transpose::No => a.cols(),
        Transpose::Yes => a.rows(),
    };
    c.scale(beta);
    if alpha == T::ZERO || k == 0 || m == 0 || n == 0 {
        // No product to accumulate, but the epilogue still applies to
        // the beta-scaled output.
        if let Some((e, ro, co)) = ep {
            e.apply(c, ro, co);
        }
        return;
    }

    // The paper streams rows of A unpacked (prefetch covers the latency);
    // packing becomes mandatory when op(A)'s rows are strided in storage.
    let need_pack_a = params.pack_a || transa == Transpose::Yes;

    scratch.b.ensure_nr(params.nr);
    let (packed_a, packed_b) = (&mut scratch.a, &mut scratch.b);
    let mut sums = [T::ZERO; 8];
    let mut sums2 = [T::ZERO; 8];
    let mut cols: Vec<*const T> = Vec::with_capacity(params.nr);
    let mut cols_strided: Vec<(*const T, usize)> = Vec::with_capacity(params.nr);

    let mut kk = 0;
    while kk < k {
        let kb_eff = params.kb_eff(k, kk);
        // Fuse the epilogue into the writeback of each element's final
        // k block only (its accumulated value is complete there).
        let fused = if kk + kb_eff == k { ep } else { None };
        if params.pack_b {
            packed_b.pack(b, transb, kk, kb_eff, n);
        }
        let mut ii = 0;
        while ii < m {
            let mb_eff = params.mb.min(m - ii);
            if need_pack_a {
                packed_a.pack(a, transa, ii, mb_eff, kk, kb_eff);
            }
            let npanels = n.div_ceil(params.nr);
            for p in 0..npanels {
                let j0 = p * params.nr;
                let w = params.nr.min(n - j0);
                if params.pack_b {
                    cols.clear();
                    for j in 0..w {
                        cols.push(packed_b.col_ptr(p, j));
                    }
                } else {
                    // Ablation path: read op(B) through its stored layout.
                    cols_strided.clear();
                    for j in 0..w {
                        let (ptr, stride) = match transb {
                            Transpose::No => (b.row_ptr(kk).wrapping_add(j0 + j), b.ld()),
                            Transpose::Yes => (b.row_ptr(j0 + j).wrapping_add(kk), 1),
                        };
                        cols_strided.push((ptr, stride));
                    }
                }
                let mut i = 0;
                while i < mb_eff {
                    let arow: *const T = if need_pack_a {
                        packed_a.row_ptr(i)
                    } else {
                        // Row ii+i of A, offset kk: contiguous kb_eff f32s.
                        a.row_ptr(ii + i).wrapping_add(kk)
                    };
                    // AVX2 fast path: two A rows per pass re-use every B
                    // vector (see microkernel::avx2_dot_panel2).
                    if isa == VecIsa::Avx2 && params.pack_b && i + 1 < mb_eff {
                        let arow1: *const T = if need_pack_a {
                            packed_a.row_ptr(i + 1)
                        } else {
                            a.row_ptr(ii + i + 1).wrapping_add(kk)
                        };
                        // SAFETY: same bounds argument as the single-row
                        // path, applied to rows i and i+1.
                        unsafe {
                            T::dot_panel2_dyn(
                                arow,
                                arow1,
                                kb_eff,
                                &cols,
                                params.unroll,
                                params.prefetch,
                                &mut sums,
                                &mut sums2,
                            );
                            for j in 0..w {
                                let o0 = c.get_unchecked(ii + i, j0 + j);
                                let mut v0 = o0 + alpha * sums[j];
                                let o1 = c.get_unchecked(ii + i + 1, j0 + j);
                                let mut v1 = o1 + alpha * sums2[j];
                                if let Some((e, ro, co)) = fused {
                                    v0 = e.apply_scalar(v0, ro + ii + i, co + j0 + j);
                                    v1 = e.apply_scalar(v1, ro + ii + i + 1, co + j0 + j);
                                }
                                c.set_unchecked(ii + i, j0 + j, v0);
                                c.set_unchecked(ii + i + 1, j0 + j, v1);
                            }
                        }
                        i += 2;
                        continue;
                    }
                    // SAFETY: arow is readable for kb_eff elements (packed
                    // rows are kpad >= kb_eff long; unpacked rows have
                    // kk + kb_eff <= k <= a.cols()). Packed columns are
                    // kpad long; strided columns were validated by the
                    // MatRef bounds. w <= 8 and sums has 8 slots.
                    unsafe {
                        if params.pack_b {
                            T::dot_panel_dyn(
                                isa,
                                arow,
                                kb_eff,
                                &cols,
                                params.unroll,
                                params.prefetch,
                                &mut sums,
                            );
                        } else {
                            T::dot_panel_strided(arow, kb_eff, &cols_strided, &mut sums);
                        }
                    }
                    for j in 0..w {
                        // SAFETY: ii+i < m, j0+j < n.
                        unsafe {
                            let old = c.get_unchecked(ii + i, j0 + j);
                            let mut v = old + alpha * sums[j];
                            if let Some((e, ro, co)) = fused {
                                v = e.apply_scalar(v, ro + ii + i, co + j0 + j);
                            }
                            c.set_unchecked(ii + i, j0 + j, v);
                        }
                    }
                    i += 1;
                }
            }
            ii += mb_eff;
        }
        kk += kb_eff;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::params::Unroll;
    use crate::gemm::testutil::check_grid;

    #[test]
    fn matches_naive_on_grid() {
        check_grid(
            &|ta, tb, alpha, a, b, beta, c| {
                gemm(&BlockParams::emmerald_sse(), ta, tb, alpha, a, b, beta, c)
            },
            "simd",
        );
    }

    #[test]
    fn matches_naive_with_tiny_blocks() {
        // Tiny blocks force every fringe path (k fringe, m fringe, panels).
        let p = BlockParams {
            kb: 3,
            mb: 2,
            nr: 5,
            unroll: Unroll::X2,
            prefetch: false,
            pack_b: true,
            pack_a: false,
        };
        check_grid(&move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c), "simd-tiny");
    }

    #[test]
    fn matches_naive_without_packing() {
        let p = BlockParams { pack_b: false, ..BlockParams::emmerald_sse() };
        check_grid(
            &move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c),
            "simd-nopack",
        );
    }

    #[test]
    fn matches_naive_with_forced_a_packing() {
        let p = BlockParams { pack_a: true, ..BlockParams::emmerald_sse() };
        check_grid(
            &move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c),
            "simd-packa",
        );
    }

    #[test]
    fn scratch_reuse_across_shapes_and_widths() {
        // One Scratch must serve a sequence of GEMMs with different
        // shapes and panel widths (the batched-driver usage pattern).
        use crate::blas::Matrix;
        use crate::util::testkit::assert_allclose;
        let mut scratch = crate::gemm::pack::Scratch::new();
        for (i, &(m, n, k, nr)) in
            [(17usize, 9usize, 23usize, 5usize), (4, 4, 4, 2), (33, 15, 40, 7), (1, 1, 1, 5)]
                .iter()
                .enumerate()
        {
            let p = BlockParams { nr, kb: 16, mb: 8, ..BlockParams::emmerald_sse() };
            let a = Matrix::random(m, k, i as u64, -1.0, 1.0);
            let b = Matrix::random(k, n, 100 + i as u64, -1.0, 1.0);
            let mut c_got = Matrix::zeros(m, n);
            let mut c_ref = Matrix::zeros(m, n);
            gemm_with_scratch(
                &p,
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c_got.view_mut(),
                &mut scratch,
            );
            crate::gemm::naive::gemm(
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c_ref.view_mut(),
            );
            assert_allclose(c_got.data(), c_ref.data(), 2e-4, 1e-5, &format!("scratch reuse {i}"));
        }
    }

    #[test]
    fn all_nr_widths_correct() {
        for nr in 1..=8 {
            let p = BlockParams { nr, kb: 16, mb: 8, ..BlockParams::emmerald_sse() };
            check_grid(
                &move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c),
                &format!("simd-nr{nr}"),
            );
        }
    }
}
