//! The Emmerald driver: L1/L2 blocking around the SIMD micro-kernel.
//!
//! Structure (paper fig. 1b):
//!
//! ```text
//! for each k-block kk (depth kb, paper: 336):          // L1 blocking
//!     re-buffer B' = op(B)[kk.., :] into packed panels  // §3 re-buffering
//!     for each row-block ii (height mb):                // L2 blocking
//!         for each panel (nr columns, paper: 5):
//!             for each row i in the block:
//!                 C'[i, j0..j0+nr] += A'[i, kk..] · B'-panel   // micro-kernel
//! ```
//!
//! The B panel (`kb × nr` ≈ 6.7 KB) stays L1-resident across all `mb`
//! rows; the `A` row streams through with prefetch; `C` accumulates in
//! registers inside the micro-kernel and is written once per panel.
//!
//! Unsafe policy: the blocking driver itself is safe code. Kernel
//! invocation goes through the three *pass* wrappers below
//! ([`dot_panel_pass`], [`dot_panel2_pass`], [`dot_panel_strided_pass`]),
//! which take length-carrying [`RawSlice`] spans instead of bare
//! pointers, assert every kernel read extent at the call site, and
//! contain the only `unsafe` blocks in this module. The prepacked
//! planned path ([`super::plan`]) drives the same wrappers.

use super::element::Element;
use super::pack::Scratch;
use super::params::{BlockParams, Unroll};
use super::tile::EpRef;
use crate::blas::{MatMut, MatRef, Transpose};
use crate::util::ptr::RawSlice;

/// Which vector ISA the shared driver dispatches to. Kernel selection per
/// element goes through [`Element::dot_panel_dyn`]: f32 has SSE and AVX2
/// instantiations, f64 has AVX2 (4-wide YMM) with a scalar panel standing
/// in for SSE.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VecIsa {
    /// 4-wide SSE (the paper's kernel).
    Sse,
    /// 8-wide AVX2 + FMA (modern extension).
    Avx2,
}

/// Assert that the requested ISA is actually available before any kernel
/// with `#[target_feature]` is entered (on non-x86_64 hosts the element
/// hooks fall back to scalar kernels, so any `isa` value is fine).
#[inline(always)]
fn assert_isa_available(isa: VecIsa) {
    #[cfg(target_arch = "x86_64")]
    match isa {
        VecIsa::Sse => assert!(super::dispatch::detect_sse(), "SSE kernel selected without SSE"),
        VecIsa::Avx2 => {
            assert!(super::dispatch::detect_avx2(), "AVX2 kernel selected without AVX2+FMA")
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = isa;
}

/// Safe dot-panel invocation: `cols.len()` simultaneous dot products of
/// length `len` against one row span of `A'`, written to `out[..w]`.
///
/// Every extent the kernel relies on is asserted here (always, in every
/// build — a handful of integer compares ahead of `O(w·len)` kernel
/// work), so the wrapped call cannot read out of bounds.
pub(crate) fn dot_panel_pass<T: Element>(
    isa: VecIsa,
    a: RawSlice<T>,
    len: usize,
    cols: &[RawSlice<T>],
    unroll: Unroll,
    prefetch: bool,
    out: &mut [T; 8],
) {
    let w = cols.len();
    assert!(w >= 1 && w <= 8, "panel width {w} out of 1..=8");
    assert!(a.len() >= len, "A row span {} shorter than k-depth {len}", a.len());
    let mut ptrs = [std::ptr::null::<T>(); 8];
    for (j, col) in cols.iter().enumerate() {
        assert!(col.len() >= len, "B column {j} span {} shorter than k-depth {len}", col.len());
        ptrs[j] = col.as_ptr();
    }
    assert_isa_available(isa);
    // SAFETY: the kernels read exactly `len` elements through each
    // pointer; the asserts above prove every span is at least that long,
    // `out` has 8 >= w slots, and the ISA was runtime-verified.
    unsafe { T::dot_panel_dyn(isa, a.as_ptr(), len, &ptrs[..w], unroll, prefetch, out) }
}

/// Safe two-row dot-panel invocation (the AVX2 fast path: every `B`
/// vector re-used against two `A` rows). Same extent discipline as
/// [`dot_panel_pass`], applied to both row spans.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dot_panel2_pass<T: Element>(
    a0: RawSlice<T>,
    a1: RawSlice<T>,
    len: usize,
    cols: &[RawSlice<T>],
    unroll: Unroll,
    prefetch: bool,
    out0: &mut [T; 8],
    out1: &mut [T; 8],
) {
    let w = cols.len();
    assert!(w >= 1 && w <= 8, "panel width {w} out of 1..=8");
    assert!(a0.len() >= len, "A row 0 span {} shorter than k-depth {len}", a0.len());
    assert!(a1.len() >= len, "A row 1 span {} shorter than k-depth {len}", a1.len());
    let mut ptrs = [std::ptr::null::<T>(); 8];
    for (j, col) in cols.iter().enumerate() {
        assert!(col.len() >= len, "B column {j} span {} shorter than k-depth {len}", col.len());
        ptrs[j] = col.as_ptr();
    }
    assert_isa_available(VecIsa::Avx2);
    // SAFETY: the two-row kernel reads exactly `len` elements through
    // each pointer; the asserts above prove every span is at least that
    // long, both outs have 8 >= w slots, and AVX2+FMA was verified.
    unsafe { T::dot_panel2_dyn(a0.as_ptr(), a1.as_ptr(), len, &ptrs[..w], unroll, prefetch, out0, out1) }
}

/// Safe strided dot-panel invocation (the "no re-buffering" ablation):
/// each `B` column is a `(span, stride)` stream read at offsets
/// `p * stride` for `p < len`; the span must cover that last offset.
pub(crate) fn dot_panel_strided_pass<T: Element>(
    a: RawSlice<T>,
    len: usize,
    cols: &[(RawSlice<T>, usize)],
    out: &mut [T; 8],
) {
    let w = cols.len();
    assert!(w >= 1 && w <= 8, "panel width {w} out of 1..=8");
    assert!(a.len() >= len, "A row span {} shorter than k-depth {len}", a.len());
    let mut ptrs = [(std::ptr::null::<T>(), 0usize); 8];
    for (j, &(col, stride)) in cols.iter().enumerate() {
        assert!(
            len == 0 || (len - 1) * stride < col.len(),
            "B stream {j}: last offset {} outside span {}",
            (len - 1) * stride,
            col.len()
        );
        ptrs[j] = (col.as_ptr(), stride);
    }
    // Strided kernels use the baseline ISA (SSE gather / scalar): no
    // feature check needed beyond the x86-64 baseline.
    // SAFETY: the strided kernels read `a` at offsets < len and each
    // stream at offsets p * stride for p < len; the asserts above prove
    // every such offset is inside its span, and out has 8 >= w slots.
    unsafe { T::dot_panel_strided(a.as_ptr(), len, &ptrs[..w], out) }
}

/// Safe scalar dot-panel invocation — the no-vector-ISA arm of the
/// prepacked driver (and the only panel kernel Miri executes). Same
/// extent discipline as [`dot_panel_pass`], no feature requirement.
pub(crate) fn scalar_dot_panel_pass<T: Element>(
    a: RawSlice<T>,
    len: usize,
    cols: &[RawSlice<T>],
    out: &mut [T; 8],
) {
    let w = cols.len();
    assert!(w >= 1 && w <= 8, "panel width {w} out of 1..=8");
    assert!(a.len() >= len, "A row span {} shorter than k-depth {len}", a.len());
    let mut ptrs = [std::ptr::null::<T>(); 8];
    for (j, col) in cols.iter().enumerate() {
        assert!(col.len() >= len, "B column {j} span {} shorter than k-depth {len}", col.len());
        ptrs[j] = col.as_ptr();
    }
    // SAFETY: the scalar panel reads exactly `len` elements through each
    // pointer; the asserts above prove every span is at least that long,
    // and out has 8 >= w slots.
    unsafe { super::microkernel::scalar_dot_panel(a.as_ptr(), len, &ptrs[..w], out) }
}

/// Emmerald GEMM on the SSE tier: `C = alpha * op(A) op(B) + beta * C`.
pub fn gemm<T: Element>(
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
) {
    gemm_vec(VecIsa::Sse, params, transa, transb, alpha, a, b, beta, c);
}

/// As [`gemm`], but reusing caller-provided packing buffers — the batched
/// driver calls this so packing allocation is amortised across a batch.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_scratch<T: Element>(
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    scratch: &mut Scratch<T>,
) {
    gemm_vec_scratch(VecIsa::Sse, params, transa, transb, alpha, a, b, beta, c, scratch);
}

/// Shared blocked driver over the per-element micro-kernels.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_vec<T: Element>(
    isa: VecIsa,
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
) {
    let mut scratch = Scratch::new();
    gemm_vec_scratch(isa, params, transa, transb, alpha, a, b, beta, c, &mut scratch);
}

/// As [`gemm_vec`], with a fused epilogue (fresh scratch) — the dispatch
/// and parallel tiers' entry.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_vec_ep<T: Element>(
    isa: VecIsa,
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    ep: EpRef<'_, T>,
) {
    let mut scratch = Scratch::new();
    gemm_vec_scratch_ep(isa, params, transa, transb, alpha, a, b, beta, c, &mut scratch, ep);
}

/// The driver proper, parameterised over reusable packing scratch.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_vec_scratch<T: Element>(
    isa: VecIsa,
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    scratch: &mut Scratch<T>,
) {
    gemm_vec_scratch_ep(isa, params, transa, transb, alpha, a, b, beta, c, scratch, None);
}

/// The full dot-tier driver, with an optional fused epilogue applied to
/// each `C` element as its **last k block**'s dot products are written
/// back (the element's value is complete there; earlier k blocks write
/// plain partial sums).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_vec_scratch_ep<T: Element>(
    isa: VecIsa,
    params: &BlockParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    scratch: &mut Scratch<T>,
    ep: EpRef<'_, T>,
) {
    params.validate().expect("invalid block parameters");
    let m = c.rows();
    let n = c.cols();
    let k = match transa {
        Transpose::No => a.cols(),
        Transpose::Yes => a.rows(),
    };
    c.scale(beta);
    if alpha == T::ZERO || k == 0 || m == 0 || n == 0 {
        // No product to accumulate, but the epilogue still applies to
        // the beta-scaled output.
        if let Some((e, ro, co)) = ep {
            e.apply(c, ro, co);
        }
        return;
    }

    // The paper streams rows of A unpacked (prefetch covers the latency);
    // packing becomes mandatory when op(A)'s rows are strided in storage.
    let need_pack_a = params.pack_a || transa == Transpose::Yes;

    scratch.b.ensure_nr(params.nr);
    let (packed_a, packed_b) = (&mut scratch.a, &mut scratch.b);
    let mut sums = [T::ZERO; 8];
    let mut sums2 = [T::ZERO; 8];
    let mut cols: Vec<RawSlice<T>> = Vec::with_capacity(params.nr);
    let mut cols_strided: Vec<(RawSlice<T>, usize)> = Vec::with_capacity(params.nr);

    let mut kk = 0;
    while kk < k {
        let kb_eff = params.kb_eff(k, kk);
        // Fuse the epilogue into the writeback of each element's final
        // k block only (its accumulated value is complete there).
        let fused = if kk + kb_eff == k { ep } else { None };
        if params.pack_b {
            packed_b.pack(b, transb, kk, kb_eff, n);
        }
        let mut ii = 0;
        while ii < m {
            let mb_eff = params.mb.min(m - ii);
            if need_pack_a {
                packed_a.pack(a, transa, ii, mb_eff, kk, kb_eff);
            }
            let npanels = n.div_ceil(params.nr);
            for p in 0..npanels {
                let j0 = p * params.nr;
                let w = params.nr.min(n - j0);
                if params.pack_b {
                    cols.clear();
                    for j in 0..w {
                        cols.push(packed_b.col_span(p, j));
                    }
                } else {
                    // Ablation path: read op(B) through its stored layout.
                    // Each stream's span runs to the end of B's backing
                    // storage, which covers its last read offset
                    // (kb_eff-1)*stride because op(B)[kk+kb_eff-1, j0+w-1]
                    // is a logical element of B.
                    cols_strided.clear();
                    for j in 0..w {
                        let (span, stride) = match transb {
                            Transpose::No => (b.tail_span(kk, j0 + j), b.ld()),
                            Transpose::Yes => (b.tail_span(j0 + j, kk), 1),
                        };
                        cols_strided.push((span, stride));
                    }
                }
                let mut i = 0;
                while i < mb_eff {
                    let arow: RawSlice<T> = if need_pack_a {
                        packed_a.row_span(i)
                    } else {
                        // Row ii+i of A, offset kk: contiguous kb_eff elems.
                        a.row_span(ii + i, kk, kb_eff)
                    };
                    // AVX2 fast path: two A rows per pass re-use every B
                    // vector (see microkernel::avx2_dot_panel2).
                    if isa == VecIsa::Avx2 && params.pack_b && i + 1 < mb_eff {
                        let arow1: RawSlice<T> = if need_pack_a {
                            packed_a.row_span(i + 1)
                        } else {
                            a.row_span(ii + i + 1, kk, kb_eff)
                        };
                        dot_panel2_pass(
                            arow,
                            arow1,
                            kb_eff,
                            &cols,
                            params.unroll,
                            params.prefetch,
                            &mut sums,
                            &mut sums2,
                        );
                        for j in 0..w {
                            let o0 = c.get(ii + i, j0 + j);
                            let mut v0 = o0 + alpha * sums[j];
                            let o1 = c.get(ii + i + 1, j0 + j);
                            let mut v1 = o1 + alpha * sums2[j];
                            if let Some((e, ro, co)) = fused {
                                v0 = e.apply_scalar(v0, ro + ii + i, co + j0 + j);
                                v1 = e.apply_scalar(v1, ro + ii + i + 1, co + j0 + j);
                            }
                            c.set(ii + i, j0 + j, v0);
                            c.set(ii + i + 1, j0 + j, v1);
                        }
                        i += 2;
                        continue;
                    }
                    if params.pack_b {
                        dot_panel_pass(
                            isa,
                            arow,
                            kb_eff,
                            &cols,
                            params.unroll,
                            params.prefetch,
                            &mut sums,
                        );
                    } else {
                        dot_panel_strided_pass(arow, kb_eff, &cols_strided, &mut sums);
                    }
                    for j in 0..w {
                        let old = c.get(ii + i, j0 + j);
                        let mut v = old + alpha * sums[j];
                        if let Some((e, ro, co)) = fused {
                            v = e.apply_scalar(v, ro + ii + i, co + j0 + j);
                        }
                        c.set(ii + i, j0 + j, v);
                    }
                    i += 1;
                }
            }
            ii += mb_eff;
        }
        kk += kb_eff;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::params::Unroll;
    use crate::gemm::testutil::check_grid;

    #[test]
    fn matches_naive_on_grid() {
        check_grid(
            &|ta, tb, alpha, a, b, beta, c| {
                gemm(&BlockParams::emmerald_sse(), ta, tb, alpha, a, b, beta, c)
            },
            "simd",
        );
    }

    #[test]
    fn matches_naive_with_tiny_blocks() {
        // Tiny blocks force every fringe path (k fringe, m fringe, panels).
        let p = BlockParams {
            kb: 3,
            mb: 2,
            nr: 5,
            unroll: Unroll::X2,
            prefetch: false,
            pack_b: true,
            pack_a: false,
        };
        check_grid(&move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c), "simd-tiny");
    }

    #[test]
    fn matches_naive_without_packing() {
        let p = BlockParams { pack_b: false, ..BlockParams::emmerald_sse() };
        check_grid(
            &move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c),
            "simd-nopack",
        );
    }

    #[test]
    fn matches_naive_with_forced_a_packing() {
        let p = BlockParams { pack_a: true, ..BlockParams::emmerald_sse() };
        check_grid(
            &move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c),
            "simd-packa",
        );
    }

    #[test]
    fn scratch_reuse_across_shapes_and_widths() {
        // One Scratch must serve a sequence of GEMMs with different
        // shapes and panel widths (the batched-driver usage pattern).
        use crate::blas::Matrix;
        use crate::util::testkit::assert_allclose;
        let mut scratch = crate::gemm::pack::Scratch::new();
        for (i, &(m, n, k, nr)) in
            [(17usize, 9usize, 23usize, 5usize), (4, 4, 4, 2), (33, 15, 40, 7), (1, 1, 1, 5)]
                .iter()
                .enumerate()
        {
            let p = BlockParams { nr, kb: 16, mb: 8, ..BlockParams::emmerald_sse() };
            let a = Matrix::random(m, k, i as u64, -1.0, 1.0);
            let b = Matrix::random(k, n, 100 + i as u64, -1.0, 1.0);
            let mut c_got = Matrix::zeros(m, n);
            let mut c_ref = Matrix::zeros(m, n);
            gemm_with_scratch(
                &p,
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c_got.view_mut(),
                &mut scratch,
            );
            crate::gemm::naive::gemm(
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c_ref.view_mut(),
            );
            assert_allclose(c_got.data(), c_ref.data(), 2e-4, 1e-5, &format!("scratch reuse {i}"));
        }
    }

    #[test]
    fn all_nr_widths_correct() {
        for nr in 1..=8 {
            let p = BlockParams { nr, kb: 16, mb: 8, ..BlockParams::emmerald_sse() };
            check_grid(
                &move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c),
                &format!("simd-nr{nr}"),
            );
        }
    }

    #[test]
    #[should_panic]
    fn dot_panel_pass_rejects_short_column_span() {
        // The wrapper must catch an undersized span before the kernel
        // reads through it — in every build profile, not just debug.
        let a = vec![1.0f32; 16];
        let short = vec![1.0f32; 8];
        let cols = [crate::util::ptr::RawSlice::from_slice(&short[..])];
        let mut out = [0.0f32; 8];
        dot_panel_pass::<f32>(
            VecIsa::Sse,
            crate::util::ptr::RawSlice::from_slice(&a[..]),
            16,
            &cols,
            Unroll::X1,
            false,
            &mut out,
        );
    }

    #[test]
    #[should_panic]
    fn strided_pass_rejects_span_not_covering_last_offset() {
        // len=4, stride=3 needs offsets {0,3,6,9}; a 9-element span ends
        // one short.
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 9];
        let cols = [(crate::util::ptr::RawSlice::from_slice(&b[..]), 3usize)];
        let mut out = [0.0f32; 8];
        dot_panel_strided_pass::<f32>(
            crate::util::ptr::RawSlice::from_slice(&a[..]),
            4,
            &cols,
            &mut out,
        );
    }
}
