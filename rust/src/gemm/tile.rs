//! Outer-product register-tiled GEMM — the fastest serial tier.
//!
//! The paper's dot-product kernel (§2, fig. 1a) computes `W` dot products
//! at once and pays a horizontal reduction plus a store per `kb`
//! multiply-adds — the right trade on a PIII with 8 XMM registers. On
//! AVX2+FMA the register file holds an entire `MR × NR` tile of `C`, so
//! the BLIS-style **outer product** wins instead: per k step the kernel
//! loads `NR` values of `B'` (two vectors) and broadcasts `MR` values of
//! `A'`, then issues `MR · NR/LANES` FMAs — every loaded element is
//! reused `MR` (resp. `NR`) times, there are **zero horizontal sums**,
//! and `C` is touched once per `MR · NR · kc` FMAs.
//!
//! The tier is generic over the element precision
//! ([`crate::gemm::element::Element`]). Per element the tile is two
//! 256-bit vectors wide: **6×16 for f32** (12 YMM accumulators + 2 `B`
//! streams + 1 `A` broadcast = 15 of 16 registers) and **6×8 for f64**
//! (the same 12-accumulator budget at 4 lanes per register) — the
//! register-tiling analysis of the paper carries over unchanged, only
//! the lane count halves.
//!
//! Both operands are packed ([`crate::gemm::pack::TilePackedA`] MR-row
//! strips, [`crate::gemm::pack::TilePackedB`] NR-panel, both k-major) so
//! the kernel's loads are unit-stride. Fringe tiles (edge rows/columns)
//! run the same full-size kernel against zero-padded strips/panels and
//! write back through a stack [`TempTile`] with a masked scalar pass
//! whose per-element arithmetic (`mul_add`) is bit-identical to a lane
//! of the vector writeback — which is what makes serial, thread-parallel
//! and prepacked executions of one problem produce the same bits, in
//! both precisions (each `C` element accumulates in pure k order, and
//! full-vs-fringe tile membership cannot change the rounding).
//!
//! A scalar reference tile covers non-AVX2 hosts and anchors the
//! conformance suite; the dot-panel kernels ([`super::simd`],
//! [`super::avx2`]) remain as the paper-faithful baseline and the
//! `tile_vs_dot` ablation point.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

use super::element::Element;
use super::epilogue::Epilogue;
use super::pack::{BSource, Scratch, TilePackedA, TilePackedB};
use super::params::TileParams;
use crate::blas::{MatMut, MatRef, Transpose};

/// A fused epilogue as the drivers thread it: the descriptor plus the
/// **global** `(row, col)` offset of the `C` slice being written (the
/// epilogue indexes its bias vectors globally, whichever parallel slice
/// an element lands in — the bit-stability contract of
/// [`crate::gemm::epilogue`]).
pub(crate) type EpRef<'e, T> = Option<(&'e Epilogue<T>, usize, usize)>;

/// Tile width in f32 lanes (two 8-wide AVX2 vectors, feeding both FMA
/// execution ports). The f64 tier's width is [`Element::TILE_NR`] = 8.
pub const NR: usize = 16;

/// Largest supported tile height (both precisions). `6 × NR` is the
/// largest tile whose accumulators (`2·mr`), `B` streams (2) and `A`
/// broadcast (1) fit the 16-register YMM file.
pub const MAX_MR: usize = 6;

/// Prefetch distance into the packed `B` panel, in *elements* per
/// element width (four 64-byte lines ahead; one k step consumes exactly
/// one line in either precision).
const PREFETCH_B_F32: usize = 64;
const PREFETCH_B_F64: usize = 32;

/// One MR×NR accumulator tile on the stack, used for fringe writeback
/// (sized for the widest element; the f64 tier uses the first
/// `MAX_MR * 8` slots with row stride `TILE_NR`).
type TempTile<T> = [T; MAX_MR * NR];

/// The AVX2+FMA outer-product micro-kernel (f32): `dst (MR×16) ⟵ A'·B'`
/// over a `kc`-deep packed strip/panel pair.
///
/// `ap` is an MR-strip (`kc × MR`, k-major), `bp` an NR-panel
/// (`kc × NR`, k-major). With `accumulate` the result is folded into
/// `dst` as `dst += alpha · acc` (one fused multiply-add per element);
/// otherwise the raw accumulators are stored (the [`TempTile`] path,
/// `alpha` unused).
///
/// # Safety
/// * `ap` readable for `kc * MR` f32s, `bp` for `kc * 16` f32s.
/// * `dst` writable at rows `i*dst_ld`, `i < MR`, each row 16 wide.
/// * AVX2 and FMA must be available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn avx2_tile<const MR: usize>(
    ap: *const f32,
    bp: *const f32,
    kc: usize,
    alpha: f32,
    dst: *mut f32,
    dst_ld: usize,
    accumulate: bool,
    prefetch: bool,
) {
    // SAFETY: loads stay inside the packed strip (kc * MR) and panel
    // (kc * NR); stores hit rows i*dst_ld, i < MR, 16 wide — exactly the
    // caller's contract. The prefetch address uses wrapping_add because
    // it runs past the panel near its end (a hint, never a dereference).
    unsafe {
        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
        for p in 0..kc {
            if prefetch {
                _mm_prefetch::<_MM_HINT_T0>(bp.wrapping_add(p * NR + PREFETCH_B_F32).cast());
            }
            let b0 = _mm256_loadu_ps(bp.add(p * NR));
            let b1 = _mm256_loadu_ps(bp.add(p * NR + 8));
            let arow = ap.add(p * MR);
            for (i, a) in acc.iter_mut().enumerate() {
                let av = _mm256_broadcast_ss(&*arow.add(i));
                a[0] = _mm256_fmadd_ps(av, b0, a[0]);
                a[1] = _mm256_fmadd_ps(av, b1, a[1]);
            }
        }
        if accumulate {
            let va = _mm256_set1_ps(alpha);
            for (i, a) in acc.iter().enumerate() {
                let row = dst.add(i * dst_ld);
                _mm256_storeu_ps(row, _mm256_fmadd_ps(va, a[0], _mm256_loadu_ps(row)));
                _mm256_storeu_ps(row.add(8), _mm256_fmadd_ps(va, a[1], _mm256_loadu_ps(row.add(8))));
            }
        } else {
            for (i, a) in acc.iter().enumerate() {
                let row = dst.add(i * dst_ld);
                _mm256_storeu_ps(row, a[0]);
                _mm256_storeu_ps(row.add(8), a[1]);
            }
        }
    }
}

/// The AVX2+FMA outer-product micro-kernel (f64): `dst (MR×8) ⟵ A'·B'` —
/// the 4-wide twin of [`avx2_tile`] with an identical register budget
/// (`2·MR` accumulators + 2 `B` streams + 1 broadcast).
///
/// # Safety
/// * `ap` readable for `kc * MR` f64s, `bp` for `kc * 8` f64s.
/// * `dst` writable at rows `i*dst_ld`, `i < MR`, each row 8 wide.
/// * AVX2 and FMA must be available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn avx2_tile_f64<const MR: usize>(
    ap: *const f64,
    bp: *const f64,
    kc: usize,
    alpha: f64,
    dst: *mut f64,
    dst_ld: usize,
    accumulate: bool,
    prefetch: bool,
) {
    const NRD: usize = 8;
    // SAFETY: loads stay inside the packed strip (kc * MR) and panel
    // (kc * 8); stores hit rows i*dst_ld, i < MR, 8 wide — exactly the
    // caller's contract. Prefetch uses wrapping_add (hint only).
    unsafe {
        let mut acc = [[_mm256_setzero_pd(); 2]; MR];
        for p in 0..kc {
            if prefetch {
                _mm_prefetch::<_MM_HINT_T0>(bp.wrapping_add(p * NRD + PREFETCH_B_F64).cast());
            }
            let b0 = _mm256_loadu_pd(bp.add(p * NRD));
            let b1 = _mm256_loadu_pd(bp.add(p * NRD + 4));
            let arow = ap.add(p * MR);
            for (i, a) in acc.iter_mut().enumerate() {
                let av = _mm256_broadcast_sd(&*arow.add(i));
                a[0] = _mm256_fmadd_pd(av, b0, a[0]);
                a[1] = _mm256_fmadd_pd(av, b1, a[1]);
            }
        }
        if accumulate {
            let va = _mm256_set1_pd(alpha);
            for (i, a) in acc.iter().enumerate() {
                let row = dst.add(i * dst_ld);
                _mm256_storeu_pd(row, _mm256_fmadd_pd(va, a[0], _mm256_loadu_pd(row)));
                _mm256_storeu_pd(row.add(4), _mm256_fmadd_pd(va, a[1], _mm256_loadu_pd(row.add(4))));
            }
        } else {
            for (i, a) in acc.iter().enumerate() {
                let row = dst.add(i * dst_ld);
                _mm256_storeu_pd(row, a[0]);
                _mm256_storeu_pd(row.add(4), a[1]);
            }
        }
    }
}

/// Runtime-MR dispatcher over [`avx2_tile`] (the f32
/// [`Element::avx2_tile_dyn`] hook).
///
/// # Safety
/// Contract of [`avx2_tile`] with `1 <= mr <= MAX_MR`.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn avx2_tile_dyn_f32(
    mr: usize,
    ap: *const f32,
    bp: *const f32,
    kc: usize,
    alpha: f32,
    dst: *mut f32,
    dst_ld: usize,
    accumulate: bool,
    prefetch: bool,
) {
    // SAFETY: forwarding the caller's contract to the mr instantiation.
    unsafe {
        match mr {
            1 => avx2_tile::<1>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            2 => avx2_tile::<2>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            3 => avx2_tile::<3>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            4 => avx2_tile::<4>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            5 => avx2_tile::<5>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            6 => avx2_tile::<6>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            _ => unreachable!("tile mr {mr} out of range"),
        }
    }
}

/// Runtime-MR dispatcher over [`avx2_tile_f64`] (the f64
/// [`Element::avx2_tile_dyn`] hook).
///
/// # Safety
/// Contract of [`avx2_tile_f64`] with `1 <= mr <= MAX_MR`.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn avx2_tile_dyn_f64(
    mr: usize,
    ap: *const f64,
    bp: *const f64,
    kc: usize,
    alpha: f64,
    dst: *mut f64,
    dst_ld: usize,
    accumulate: bool,
    prefetch: bool,
) {
    // SAFETY: forwarding the caller's contract to the mr instantiation.
    unsafe {
        match mr {
            1 => avx2_tile_f64::<1>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            2 => avx2_tile_f64::<2>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            3 => avx2_tile_f64::<3>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            4 => avx2_tile_f64::<4>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            5 => avx2_tile_f64::<5>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            6 => avx2_tile_f64::<6>(ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch),
            _ => unreachable!("tile mr {mr} out of range"),
        }
    }
}

/// The AVX2 quantized micro-kernel: raw `dst (MR×16) ⟵ Σₖ a'·b` over a
/// packed u8×i8 strip/panel pair, accumulated in i32 — **exactly**, for
/// arbitrary inputs on its packed diet, via the sign-split `maddubs`
/// idiom.
///
/// `_mm256_maddubs_epi16(u, s)` multiplies *unsigned* bytes by *signed*
/// bytes and saturates the i16 pair sums, so it cannot be fed the
/// operands directly. The packing stage ([`crate::gemm::quant`]) stores
/// `a' = a XOR 0x80` (= `a − 128` reinterpreted as i8, in [−128, 127]);
/// the kernel splits each product as `a'·b = |a'| · (sign(a')·b)` with
/// `vpabsb`/`vpsignb`:
///
/// * `|a'| ∈ [0, 128]` is a valid unsigned operand;
/// * `sign(a')·b` is exact for `b ∈ [−127, 127]` (the packing stage
///   screens `b = −128`, whose negation overflows `vpsignb`, and routes
///   such panels to the scalar tier);
/// * each i16 pair sum then lies in `[−2·128·127, 2·128·127] =
///   [−32512, 32512]`, strictly inside i16 — `maddubs` **never
///   saturates** on this diet.
///
/// `vpmaddwd` against ones widens the pair sums to one i32 per 4-k
/// group, added into `2·MR` i32 YMM accumulators. The driver restores
/// the true sum at writeback as `S = S' + 128·colsum(b)` (wrapping —
/// all quantized i32 arithmetic is mod 2³², which is what makes serial,
/// parallel and prepacked runs bitwise identical).
///
/// Layouts: `ap` is an MR-strip in 4-k groups (group `g`, row `i`, tap
/// `t` at byte `g·MR·4 + i·4 + t`); `bp` a 16-column panel in 64-byte
/// 4-k groups (group `g`, column `j`, tap `t` at byte `g·64 + j·4 + t`)
/// — so i32 lane `j` of the accumulator pair is column `j` directly,
/// with no cross-lane shuffles anywhere.
///
/// # Safety
/// * `ap` readable for `kgroups * MR * 4` bytes, `bp` for
///   `kgroups * 64` bytes; `bp` must contain no `−128` byte.
/// * `dst` writable at rows `i*dst_ld`, `i < MR`, each row 16 wide.
/// * AVX2 must be available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_qtile<const MR: usize>(
    ap: *const u8,
    bp: *const i8,
    kgroups: usize,
    dst: *mut i32,
    dst_ld: usize,
) {
    // SAFETY: loads stay inside the packed strip (kgroups * MR * 4
    // bytes) and panel (kgroups * 64 bytes); the unaligned 4-byte read
    // of a row's k group is within the strip; stores hit rows i*dst_ld,
    // i < MR, 16 i32 lanes wide — exactly the caller's contract.
    unsafe {
        let ones = _mm256_set1_epi16(1);
        let mut acc = [[_mm256_setzero_si256(); 2]; MR];
        for g in 0..kgroups {
            let bg = bp.add(g * 64);
            let vb0 = _mm256_loadu_si256(bg.cast());
            let vb1 = _mm256_loadu_si256(bg.add(32).cast());
            let ag = ap.add(g * MR * 4);
            for (i, a) in acc.iter_mut().enumerate() {
                let quad = ag.add(i * 4).cast::<i32>().read_unaligned();
                let va = _mm256_set1_epi32(quad);
                let aabs = _mm256_abs_epi8(va);
                let p0 = _mm256_maddubs_epi16(aabs, _mm256_sign_epi8(vb0, va));
                let p1 = _mm256_maddubs_epi16(aabs, _mm256_sign_epi8(vb1, va));
                a[0] = _mm256_add_epi32(a[0], _mm256_madd_epi16(p0, ones));
                a[1] = _mm256_add_epi32(a[1], _mm256_madd_epi16(p1, ones));
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let row = dst.add(i * dst_ld);
            _mm256_storeu_si256(row.cast(), a[0]);
            _mm256_storeu_si256(row.add(8).cast(), a[1]);
        }
    }
}

/// Runtime-MR dispatcher over [`avx2_qtile`]. The u8×i8 triple is not
/// an [`Element`], so there is no trait hook: the quantized driver
/// ([`crate::gemm::quant`]) calls this directly.
///
/// # Safety
/// Contract of [`avx2_qtile`] with `1 <= mr <= MAX_MR`.
#[cfg(target_arch = "x86_64")]
pub(crate) unsafe fn avx2_qtile_dyn(
    mr: usize,
    ap: *const u8,
    bp: *const i8,
    kgroups: usize,
    dst: *mut i32,
    dst_ld: usize,
) {
    // SAFETY: forwarding the caller's contract to the mr instantiation.
    unsafe {
        match mr {
            1 => avx2_qtile::<1>(ap, bp, kgroups, dst, dst_ld),
            2 => avx2_qtile::<2>(ap, bp, kgroups, dst, dst_ld),
            3 => avx2_qtile::<3>(ap, bp, kgroups, dst, dst_ld),
            4 => avx2_qtile::<4>(ap, bp, kgroups, dst, dst_ld),
            5 => avx2_qtile::<5>(ap, bp, kgroups, dst, dst_ld),
            6 => avx2_qtile::<6>(ap, bp, kgroups, dst, dst_ld),
            _ => unreachable!("tile mr {mr} out of range"),
        }
    }
}

/// Masked f32 fringe writeback: fold `h × w` elements of a raw
/// accumulator tile into `C` with one *fused* multiply-add per element,
/// so a fringe element rounds exactly like a lane of [`avx2_tile`]'s
/// vector writeback (the bit-stability contract of the module docs).
///
/// # Safety
/// `tmp` readable at rows `i*tmp_ld` for `i < h`; `dst` writable at rows
/// `i*dst_ld` for `i < h`, each row `w` wide; FMA must be available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
pub(crate) unsafe fn tile_fringe_f32(
    tmp: *const f32,
    tmp_ld: usize,
    alpha: f32,
    dst: *mut f32,
    dst_ld: usize,
    h: usize,
    w: usize,
) {
    // SAFETY: every access is at row i < h, column j < w — exactly the
    // caller's readable/writable window.
    unsafe {
        for i in 0..h {
            for j in 0..w {
                let p = dst.add(i * dst_ld + j);
                *p = alpha.mul_add(*tmp.add(i * tmp_ld + j), *p);
            }
        }
    }
}

/// Masked f64 fringe writeback (the f64 twin of [`tile_fringe_f32`]).
///
/// # Safety
/// As [`tile_fringe_f32`], in f64s.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
pub(crate) unsafe fn tile_fringe_f64(
    tmp: *const f64,
    tmp_ld: usize,
    alpha: f64,
    dst: *mut f64,
    dst_ld: usize,
    h: usize,
    w: usize,
) {
    // SAFETY: every access is at row i < h, column j < w — exactly the
    // caller's readable/writable window.
    unsafe {
        for i in 0..h {
            for j in 0..w {
                let p = dst.add(i * dst_ld + j);
                *p = alpha.mul_add(*tmp.add(i * tmp_ld + j), *p);
            }
        }
    }
}

/// Scalar reference tile: the same outer-product loop order as the
/// vector kernels without SIMD — the conformance anchor and the non-AVX2
/// fallback. Accumulates the raw `mr × T::TILE_NR` product into `tmp`
/// (k-major broadcast of `A`, `TILE_NR`-wide sweep of `B` per step).
///
/// # Safety
/// `ap` readable for `kc * mr` elements, `bp` for `kc * T::TILE_NR`.
unsafe fn scalar_tile_into<T: Element>(
    ap: *const T,
    bp: *const T,
    kc: usize,
    mr: usize,
    tmp: &mut TempTile<T>,
) {
    let nr = T::TILE_NR;
    // SAFETY: reads stay inside the packed strip (kc * mr) and panel
    // (kc * nr) per the caller's contract; tmp writes are bounds-checked
    // slice indexing.
    unsafe {
        for p in 0..kc {
            for i in 0..mr {
                let av = *ap.add(p * mr + i);
                let row = &mut tmp[i * nr..(i + 1) * nr];
                for (j, t) in row.iter_mut().enumerate() {
                    *t += av * *bp.add(p * nr + j);
                }
            }
        }
    }
}

/// Run every tile of one packed (A block, B block) pair against `C`.
///
/// `ta` covers `C` rows `i_base ..` (its strip count), `tb`'s panels
/// `panel0 ..` cover `C` columns `j_base .. j_base + nb_eff`. `C` has
/// already been beta-scaled; each tile folds `alpha · A'B'` in.
///
/// `ep` is the fused epilogue for this block, `Some` only on the **last
/// k block** of each `C` element (the drivers guarantee this): right
/// after a tile's writeback — full-vector, `TempTile` fringe or scalar —
/// the epilogue sweeps the same `h × w` window while it is still hot.
#[allow(clippy::too_many_arguments)]
fn tile_block<T: Element>(
    params: &TileParams,
    use_avx2: bool,
    ta: &TilePackedA<T>,
    tb: &TilePackedB<T>,
    panel0: usize,
    alpha: T,
    c: &mut MatMut<'_, T>,
    i_base: usize,
    j_base: usize,
    nb_eff: usize,
    kc_eff: usize,
    ep: EpRef<'_, T>,
) {
    let (mr, nr) = (params.mr, params.nr);
    debug_assert_eq!(nr, T::TILE_NR, "tile nr must match the element's vector geometry");
    let ldc = c.ld();
    let strips = ta.strips();
    let npanels = nb_eff.div_ceil(nr);
    for q in 0..npanels {
        let j0 = j_base + q * nr;
        let w = nr.min(nb_eff - q * nr);
        let bp = tb.panel_ptr(panel0 + q);
        for s in 0..strips {
            let i0 = i_base + s * mr;
            let h = ta.strip_height(s);
            let ap = ta.strip_ptr(s);
            // window_ptr verifies the whole h × w writeback window sits
            // inside C's logical extent (debug/`checked-ptr` builds).
            let cptr = c.window_ptr(i0, j0, h, w);
            // SAFETY: strips/panels are packed `kc_eff` deep and padded to
            // full mr/nr lanes; the C tile spans rows i0..i0+h <= c.rows()
            // and cols j0..j0+w <= c.cols() (checked by window_ptr above;
            // full-tile vector writeback only runs when h == mr and
            // w == nr, so its NR-wide rows stay inside the logical width);
            // use_avx2 comes from runtime feature detection, never faked.
            unsafe {
                if use_avx2 {
                    if h == mr && w == nr {
                        T::avx2_tile_dyn(mr, ap, bp, kc_eff, alpha, cptr, ldc, true, params.prefetch);
                    } else {
                        let mut tmp: TempTile<T> = [T::ZERO; MAX_MR * NR];
                        T::avx2_tile_dyn(mr, ap, bp, kc_eff, T::ZERO, tmp.as_mut_ptr(), nr, false, params.prefetch);
                        T::tile_fringe(tmp.as_ptr(), nr, alpha, cptr, ldc, h, w);
                    }
                } else {
                    let mut tmp: TempTile<T> = [T::ZERO; MAX_MR * NR];
                    scalar_tile_into(ap, bp, kc_eff, mr, &mut tmp);
                    for i in 0..h {
                        for j in 0..w {
                            let pd = cptr.add(i * ldc + j);
                            *pd += alpha * tmp[i * nr + j];
                        }
                    }
                }
                // Fused epilogue: sweep the tile we just stored, indexing
                // the bias at the element's global C coordinates.
                if let Some((e, ro, co)) = ep {
                    for i in 0..h {
                        for j in 0..w {
                            let pd = cptr.add(i * ldc + j);
                            *pd = e.apply_scalar(*pd, ro + i0 + i, co + j0 + j);
                        }
                    }
                }
            }
        }
    }
}

/// Tile-tier GEMM: `C = alpha * op(A) op(B) + beta * C`.
///
/// Runs the element's AVX2+FMA micro-kernel when the CPU supports it and
/// the scalar reference tile otherwise — always available, fastest on
/// AVX2+FMA (where [`crate::gemm::dispatch`] selects it).
pub fn gemm<T: Element>(
    params: &TileParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
) {
    let mut scratch = Scratch::new();
    gemm_with_scratch(params, transa, transb, alpha, a, b, beta, c, &mut scratch);
}

/// As [`gemm`], reusing caller-provided packing buffers (the batched
/// driver amortises packing allocation across a batch this way).
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_scratch<T: Element>(
    params: &TileParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    scratch: &mut Scratch<T>,
) {
    gemm_scratch_ep(params, transa, alpha, a, BSource::Mat(b, transb), beta, c, scratch, None);
}

/// As [`gemm`], with a fused epilogue (fresh scratch) — the dispatch and
/// parallel tiers' entry.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_ep<T: Element>(
    params: &TileParams,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    ep: EpRef<'_, T>,
) {
    let mut scratch = Scratch::new();
    gemm_scratch_ep(params, transa, alpha, a, BSource::Mat(b, transb), beta, c, &mut scratch, ep);
}

/// The full tile driver: `B` as a stored matrix or a virtual
/// [`PanelSource`](crate::gemm::pack::PanelSource) packed on demand
/// (the fused-im2col conv path), plus an optional fused epilogue applied
/// on each element's **last k block**.
///
/// Loop nest (BLIS order): `jc` over `nc`-wide column blocks, `pc` over
/// `kc`-deep k blocks (pack `B'`), `ic` over `mc`-tall row blocks (pack
/// `A'`), then panels × strips of tiles — `B'` panels stay hot across
/// every `A` strip of the block. A virtual `B` therefore only ever
/// exists as the current `kc × nc` packed block.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_scratch_ep<T: Element>(
    params: &TileParams,
    transa: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: BSource<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    scratch: &mut Scratch<T>,
    ep: EpRef<'_, T>,
) {
    params.validate().expect("invalid tile parameters");
    assert_eq!(
        params.nr,
        T::TILE_NR,
        "tile nr {} does not match element {} (TILE_NR {})",
        params.nr,
        T::ID.name(),
        T::TILE_NR
    );
    let m = c.rows();
    let n = c.cols();
    let k = match transa {
        Transpose::No => a.cols(),
        Transpose::Yes => a.rows(),
    };
    c.scale(beta);
    if alpha == T::ZERO || k == 0 || m == 0 || n == 0 {
        // No product to accumulate, but the epilogue still applies to
        // the beta-scaled output.
        if let Some((e, ro, co)) = ep {
            e.apply(c, ro, co);
        }
        return;
    }
    let use_avx2 = super::dispatch::detect_avx2();
    let (ta, tb) = (&mut scratch.ta, &mut scratch.tb);
    let mut jc = 0;
    while jc < n {
        let nc_eff = params.nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc_eff = params.kc_eff(k, pc);
            b.pack_tile(tb, pc, kc_eff, jc, nc_eff, params.nr);
            // Fuse the epilogue into the writeback of each element's
            // final k block only (its value is complete there).
            let ep_blk = if pc + kc_eff == k { ep } else { None };
            let mut ic = 0;
            while ic < m {
                let mc_eff = params.mc.min(m - ic);
                ta.pack(a, transa, ic, mc_eff, pc, kc_eff, params.mr);
                tile_block(params, use_avx2, ta, tb, 0, alpha, c, ic, jc, nc_eff, kc_eff, ep_blk);
                ic += mc_eff;
            }
            pc += kc_eff;
        }
        jc += nc_eff;
    }
}

/// Where the prepacked tile driver streams `A` from.
#[derive(Clone, Copy)]
pub(crate) enum TileA<'x, T = f32> {
    /// Unpacked `op(A)`: each (row block, k block) is packed on the fly.
    Raw { a: MatRef<'x, T>, transa: Transpose },
    /// Whole-operand prepack: `blocks[kblock][rowblock]`
    /// (see [`crate::gemm::plan::PackedA`]).
    Packed { blocks: &'x [Vec<TilePackedA<T>>] },
}

/// The tile driver over a whole-operand prepacked `B` (and optionally
/// `A`): identical micro-kernel calls in identical k order to
/// [`gemm_with_scratch`], minus the packing work the prepacked operands
/// make redundant — so results are bit-identical to a packing run.
///
/// `c` may be a parallel slice of the full output: `row0`/`col0` are its
/// global offsets. `col0` must be panel-aligned (multiple of `nr`);
/// `row0` must be a multiple of `mc` when `A` is prepacked (a packed row
/// block is indivisible). The parallel split helpers guarantee both.
///
/// `ep` is an optional fused epilogue with the slice's global `(row,
/// col)` offsets; it is applied on each element's last k block exactly
/// as in [`gemm_scratch_ep`], so prepacked fused runs stay bit-identical
/// to packing runs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn prepacked_gemm<T: Element>(
    params: &TileParams,
    alpha: T,
    a: TileA<'_, T>,
    row0: usize,
    b_blocks: &[TilePackedB<T>],
    b_offsets: &[usize],
    col0: usize,
    beta: T,
    c: &mut MatMut<'_, T>,
    ep: EpRef<'_, T>,
) {
    let m = c.rows();
    let n = c.cols();
    debug_assert_eq!(col0 % params.nr, 0, "column slices must be panel-aligned");
    c.scale(beta);
    if alpha == T::ZERO || m == 0 || n == 0 || b_blocks.is_empty() {
        if let Some((e, ro, co)) = ep {
            e.apply(c, ro, co);
        }
        return;
    }
    let use_avx2 = super::dispatch::detect_avx2();
    let p0 = col0 / params.nr;
    let mut scratch_a = TilePackedA::new();
    for (kbi, tb) in b_blocks.iter().enumerate() {
        let kk = b_offsets[kbi];
        let kc_eff = tb.kc_eff();
        let ep_blk = if kbi == b_blocks.len() - 1 { ep } else { None };
        let mut ic = 0;
        while ic < m {
            let mc_eff = params.mc.min(m - ic);
            let ta: &TilePackedA<T> = match a {
                TileA::Raw { a, transa } => {
                    scratch_a.pack(a, transa, ic, mc_eff, kk, kc_eff, params.mr);
                    &scratch_a
                }
                TileA::Packed { blocks } => &blocks[kbi][(row0 + ic) / params.mc],
            };
            tile_block(params, use_avx2, ta, tb, p0, alpha, c, ic, 0, n, kc_eff, ep_blk);
            ic += mc_eff;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::Matrix;
    use crate::gemm::testutil::{check_grid, check_grid_f64};
    use crate::util::testkit::assert_allclose;

    #[test]
    fn matches_naive_on_grid() {
        check_grid(
            &|ta, tb, alpha, a, b, beta, c| gemm(&TileParams::avx2_6x16(), ta, tb, alpha, a, b, beta, c),
            "tile-6x16",
        );
    }

    #[test]
    fn f64_matches_naive_on_grid() {
        check_grid_f64(
            &|ta, tb, alpha, a, b, beta, c| gemm(&TileParams::avx2_6x8_f64(), ta, tb, alpha, a, b, beta, c),
            "tile-6x8-f64",
        );
    }

    #[test]
    fn f64_matches_naive_with_tiny_blocks() {
        // Tiny blocks force every fringe path in the f64 tier too.
        let p = TileParams { mr: 2, kc: 3, mc: 4, nc: 8, ..TileParams::avx2_6x8_f64() };
        check_grid_f64(
            &move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c),
            "tile-tiny-f64",
        );
    }

    #[test]
    fn matches_naive_with_tiny_blocks() {
        // Tiny blocks force every fringe path: k fringe, partial row
        // blocks, fringe strips and fringe panels.
        let p = TileParams { mr: 2, kc: 3, mc: 4, nc: 16, ..TileParams::avx2_6x16() };
        check_grid(&move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c), "tile-tiny");
    }

    #[test]
    fn all_mr_heights_correct() {
        for mr in 1..=MAX_MR {
            let p = TileParams { mr, mc: mr * 2, kc: 16, nc: 32, ..TileParams::avx2_6x16() };
            check_grid(
                &move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c),
                &format!("tile-mr{mr}"),
            );
        }
    }

    #[test]
    fn all_mr_heights_correct_f64() {
        for mr in 1..=MAX_MR {
            let p = TileParams { mr, mc: mr * 2, kc: 16, nc: 16, ..TileParams::avx2_6x8_f64() };
            check_grid_f64(
                &move |ta, tb, alpha, a, b, beta, c| gemm(&p, ta, tb, alpha, a, b, beta, c),
                &format!("tile-f64-mr{mr}"),
            );
        }
    }

    #[test]
    fn scratch_reuse_across_shapes() {
        let mut scratch = Scratch::new();
        for (i, &(m, n, k)) in [(17usize, 9usize, 23usize), (4, 4, 4), (33, 47, 40), (1, 1, 1)].iter().enumerate() {
            let p = TileParams { kc: 16, mc: 12, nc: 32, ..TileParams::avx2_6x16() };
            let a = Matrix::<f32>::random(m, k, i as u64, -1.0, 1.0);
            let b = Matrix::<f32>::random(k, n, 100 + i as u64, -1.0, 1.0);
            let mut c_got = Matrix::zeros(m, n);
            let mut c_ref = Matrix::zeros(m, n);
            gemm_with_scratch(
                &p,
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c_got.view_mut(),
                &mut scratch,
            );
            crate::gemm::naive::gemm(
                Transpose::No,
                Transpose::No,
                1.0,
                a.view(),
                b.view(),
                0.0,
                &mut c_ref.view_mut(),
            );
            assert_allclose(c_got.data(), c_ref.data(), 2e-4, 1e-5, &format!("tile scratch reuse {i}"));
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn scalar_tile_matches_avx2_tile_values() {
        // The scalar reference and the AVX2 kernel compute the same
        // product (within reassociation-free FMA tolerance) on the same
        // packed data — the conformance anchor for the vector kernel.
        if !crate::gemm::dispatch::detect_avx2() {
            eprintln!("SKIP: no AVX2+FMA");
            return;
        }
        let (mr, kc) = (6usize, 37usize);
        let a = Matrix::<f32>::random(mr, kc, 7, -1.0, 1.0);
        let b = Matrix::<f32>::random(kc, NR, 8, -1.0, 1.0);
        let mut ta = TilePackedA::new();
        ta.pack(a.view(), Transpose::No, 0, mr, 0, kc, mr);
        let mut tb = TilePackedB::new();
        tb.pack(b.view(), Transpose::No, 0, kc, 0, NR, NR);
        let mut scalar: TempTile<f32> = [0.0; MAX_MR * NR];
        let mut vector: TempTile<f32> = [0.0; MAX_MR * NR];
        unsafe {
            scalar_tile_into(ta.strip_ptr(0), tb.panel_ptr(0), kc, mr, &mut scalar);
            avx2_tile_dyn_f32(mr, ta.strip_ptr(0), tb.panel_ptr(0), kc, 0.0, vector.as_mut_ptr(), NR, false, true);
        }
        assert_allclose(&vector[..mr * NR], &scalar[..mr * NR], 1e-4, 1e-5, "avx2 vs scalar tile");
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn scalar_tile_matches_avx2_tile_values_f64() {
        if !crate::gemm::dispatch::detect_avx2() {
            eprintln!("SKIP: no AVX2+FMA");
            return;
        }
        let nr = <f64 as Element>::TILE_NR;
        let (mr, kc) = (6usize, 37usize);
        let a = Matrix::<f64>::random(mr, kc, 7, -1.0, 1.0);
        let b = Matrix::<f64>::random(kc, nr, 8, -1.0, 1.0);
        let mut ta = TilePackedA::new();
        ta.pack(a.view(), Transpose::No, 0, mr, 0, kc, mr);
        let mut tb = TilePackedB::new();
        tb.pack(b.view(), Transpose::No, 0, kc, 0, nr, nr);
        let mut scalar: TempTile<f64> = [0.0; MAX_MR * NR];
        let mut vector: TempTile<f64> = [0.0; MAX_MR * NR];
        unsafe {
            scalar_tile_into(ta.strip_ptr(0), tb.panel_ptr(0), kc, mr, &mut scalar);
            avx2_tile_dyn_f64(mr, ta.strip_ptr(0), tb.panel_ptr(0), kc, 0.0, vector.as_mut_ptr(), nr, false, true);
        }
        for i in 0..mr * nr {
            assert!(
                (vector[i] - scalar[i]).abs() < 1e-12 * (1.0 + scalar[i].abs()),
                "f64 tile lane {i}: {} vs {}",
                vector[i],
                scalar[i]
            );
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn qtile_matches_widening_scalar_reference() {
        // Hand-build the quantized packed layouts (documenting them) and
        // check the maddubs kernel against a plain widening i32 loop —
        // exact equality, including 255 × ±127 extremes. b = −128 is
        // excluded per the kernel contract (vpsignb hazard).
        if !crate::gemm::dispatch::detect_avx2() {
            eprintln!("SKIP: no AVX2");
            return;
        }
        use crate::util::prng::Pcg32;
        let (mr, k) = (6usize, 37usize);
        let kgroups = k.div_ceil(4);
        let mut rng = Pcg32::new(0x5117);
        let mut a = vec![0u8; mr * k]; // a[i][p], the *unsigned* operand
        let mut b = vec![0i8; k * NR]; // b[p][j]
        for (idx, v) in a.iter_mut().enumerate() {
            *v = if idx % 11 == 0 { 255 } else { (rng.next_u32() % 256) as u8 };
        }
        for (idx, v) in b.iter_mut().enumerate() {
            *v = match idx % 13 {
                0 => 127,
                1 => -127,
                _ => ((rng.next_u32() % 255) as i16 - 127) as i8,
            };
        }
        // Pack: A strips store a' = a XOR 0x80 at g*mr*4 + i*4 + t; B
        // panels store i8 at g*64 + j*4 + t; pads beyond k are zero in B
        // (A pads may be anything — B's zeros kill those products).
        let mut ap = vec![0u8; kgroups * mr * 4];
        for i in 0..mr {
            for p in 0..k {
                ap[(p / 4) * mr * 4 + i * 4 + (p % 4)] = a[i * k + p] ^ 0x80;
            }
        }
        let mut bp = vec![0i8; kgroups * 64];
        for p in 0..k {
            for j in 0..NR {
                bp[(p / 4) * 64 + j * 4 + (p % 4)] = b[p * NR + j];
            }
        }
        let mut got = [0i32; MAX_MR * NR];
        // SAFETY: buffers sized exactly to the kernel's contract above;
        // AVX2 checked at the top; bp contains no −128 (values clamped
        // to [−127, 127] on construction).
        unsafe {
            avx2_qtile_dyn(mr, ap.as_ptr(), bp.as_ptr(), kgroups, got.as_mut_ptr(), NR);
        }
        for i in 0..mr {
            for j in 0..NR {
                let mut want = 0i32;
                for p in 0..k {
                    let aprime = (a[i * k + p] ^ 0x80) as i8 as i32;
                    want = want.wrapping_add(aprime * b[p * NR + j] as i32);
                }
                assert_eq!(got[i * NR + j], want, "qtile ({i},{j})");
            }
        }
    }

    #[test]
    fn fringe_tiles_leave_padding_untouched() {
        // Strided C with sentinel padding: fringe writeback must stay
        // inside the logical area.
        let (m, n, k) = (7usize, 19usize, 23usize);
        let a = Matrix::<f32>::random(m, k, 3, -1.0, 1.0);
        let b = Matrix::<f32>::random(k, n, 4, -1.0, 1.0);
        let mut c = Matrix::<f32>::random_strided(m, n, n + 5, 5);
        let mut c_ref = c.clone();
        gemm(&TileParams::avx2_6x16(), Transpose::No, Transpose::No, 0.5, a.view(), b.view(), 1.5, &mut c.view_mut());
        crate::gemm::naive::gemm(Transpose::No, Transpose::No, 0.5, a.view(), b.view(), 1.5, &mut c_ref.view_mut());
        for r in 0..m {
            for j in 0..n {
                let got = c.get(r, j);
                let want = c_ref.get(r, j);
                assert!((got - want).abs() <= 1e-4 + 2e-4 * want.abs(), "({r},{j}): {got} vs {want}");
            }
            for p in n..n + 5 {
                assert_eq!(c.data()[r * (n + 5) + p], -77.0, "padding clobbered at row {r}");
            }
        }
    }

    #[test]
    fn fringe_tiles_leave_padding_untouched_f64() {
        let (m, n, k) = (7usize, 11usize, 23usize);
        let a = Matrix::<f64>::random(m, k, 3, -1.0, 1.0);
        let b = Matrix::<f64>::random(k, n, 4, -1.0, 1.0);
        let mut c = Matrix::<f64>::random_strided(m, n, n + 5, 5);
        let mut c_ref = c.clone();
        gemm(&TileParams::avx2_6x8_f64(), Transpose::No, Transpose::No, 0.5, a.view(), b.view(), 1.5, &mut c.view_mut());
        crate::gemm::naive::gemm(Transpose::No, Transpose::No, 0.5, a.view(), b.view(), 1.5, &mut c_ref.view_mut());
        for r in 0..m {
            for j in 0..n {
                let got = c.get(r, j);
                let want = c_ref.get(r, j);
                assert!((got - want).abs() <= 1e-10 + 1e-10 * want.abs(), "({r},{j}): {got} vs {want}");
            }
            for p in n..n + 5 {
                assert_eq!(c.data()[r * (n + 5) + p], -77.0, "padding clobbered at row {r}");
            }
        }
    }

    #[test]
    fn degenerate_dims_scale_by_beta() {
        let p = TileParams::avx2_6x16();
        let a = Matrix::<f32>::zeros(3, 0);
        let b = Matrix::<f32>::zeros(0, 4);
        let mut c = Matrix::<f32>::from_fn(3, 4, |_, _| 2.0);
        gemm(&p, Transpose::No, Transpose::No, 1.0, a.view(), b.view(), 0.5, &mut c.view_mut());
        assert!(c.data().iter().all(|&x| x == 1.0));
        // alpha == 0 likewise.
        let a = Matrix::<f32>::random(3, 5, 1, -1.0, 1.0);
        let b = Matrix::<f32>::random(5, 4, 2, -1.0, 1.0);
        gemm(&p, Transpose::No, Transpose::No, 0.0, a.view(), b.view(), 0.0, &mut c.view_mut());
        assert!(c.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn register_budget_documented_invariant() {
        // 6×16 f32 and 6×8 f64 on AVX2: 12 accumulators + 2 B streams +
        // 1 A broadcast must fit the 16-register YMM file.
        let p = TileParams::avx2_6x16();
        assert!(p.mr * (p.nr / 8) + p.nr / 8 + 1 <= 16);
        assert_eq!(p.nr, NR);
        let pd = TileParams::avx2_6x8_f64();
        assert!(pd.mr * (pd.nr / 4) + pd.nr / 4 + 1 <= 16);
        assert_eq!(pd.nr, <f64 as Element>::TILE_NR);
    }
}
