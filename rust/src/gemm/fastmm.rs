//! Parallel fast matrix multiplication: a family of ⟨m,k,n⟩ base-case
//! factorizations run with DFS/BFS hybrid task parallelism.
//!
//! A fast algorithm ⟨bm,bk,bn⟩:R multiplies a `bm×bk` block matrix by a
//! `bk×bn` block matrix with `R < bm·bk·bn` block products, trading the
//! saved multiplications for extra block additions. Each member is a
//! triple of integer coefficient tables `(U, V, W)` over the operand
//! blocks — the classical bilinear form
//!
//! ```text
//!   P_r = (Σ_t U[r,t]·A_t) · (Σ_t V[r,t]·B_t)      r = 0..R
//!   C_c = Σ_r W[c,r]·P_r
//! ```
//!
//! so one recursion drives every algorithm, generic over [`Element`]
//! (f32 *and* f64). The framework follows Benson & Ballard ("A Framework
//! for Practical Parallel Fast Matrix Multiplication", PAPERS.md):
//!
//! * **Dynamic peeling**, not padding: each level recurses on the largest
//!   `(bm·⌊m/bm⌋, bk·⌊k/bk⌋, bn·⌊n/bn⌋)` core and fixes the ≤ bm−1 /
//!   bk−1 / bn−1 leftover rows/columns with three classical rank-updates
//!   through the base kernel — no per-level full-matrix copies.
//! * **Pooled scratch**: every task owns one [`Arena`] free-list whose
//!   buffers are reused across recursion levels (DFS re-uses the same
//!   S/T/P triple all the way down) instead of allocating per add/sub.
//! * **DFS/BFS hybrid scheduling**: while the shared [`ThreadPool`] has
//!   idle workers, a level fans its R block products out as borrowed
//!   fork-join tasks (BFS); once the pool is saturated — observed via
//!   [`ThreadPool::has_idle`] — levels run depth-first with sequential
//!   scratch reuse. Base cases and fringe fixups run the tiled serial
//!   kernel ([`SerialVecKernel`]) resolved by the dispatch tables.
//! * **Run-to-run determinism**: products are *written back* to `C`
//!   strictly in ascending `r` order whether they were computed BFS or
//!   DFS, and every task computes its product into a private buffer, so
//!   the floating-point sum order — hence every output bit — is
//!   independent of thread timing.
//!
//! Accuracy: each recursion level amplifies rounding by a small constant
//! (≈1 bit per level for Strassen–Winograd; slightly more for
//! ⟨3,3,3⟩:23), which is why dispatch only routes shapes above the tuned
//! crossover here and the conformance tests scale tolerances with depth.
//!
//! Selection lives in [`FastmmTable`]: per (element, [`ShapeClass`]) the
//! autotuner persists a [`FastmmChoice`] — winning algorithm, recursion
//! crossover, and the minimum dimension below which the classical tiers
//! win (see `autotune::tune_fastmm`).

use super::element::{Element, ElementId};
use super::parallel::SerialVecKernel;
use crate::blas::{MatMut, MatRef, Transpose};
use crate::util::threadpool::{run_borrowed_on, ThreadPool};

/// Default recursion crossover: at or below this dimension the recursion
/// bottoms out on the serial base kernel. 256 keeps conformance-grid
/// shapes on the exact base case and matches the measured f32 crossover
/// region of the tile tier.
pub const DEFAULT_CROSSOVER: usize = 256;

/// Floor for the crossover: below ~32 the block additions dominate any
/// saved multiplications and the accuracy loss buys nothing.
pub const MIN_CROSSOVER: usize = 32;

/// Default minimum smallest-dimension before the fast tier outranks the
/// classical drivers (the conservative pre-autotune threshold).
pub const DEFAULT_MIN_DIM: usize = 1024;

/// A subproblem must still carry at least this many core multiply flops
/// (`ms·ks·ns`) for a BFS fan-out to pay its task and buffer overhead.
const BFS_MIN_VOLUME: usize = 64 * 64 * 64;

/// Identifier of one fast algorithm in the family.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FastAlgoId {
    /// Strassen–Winograd ⟨2,2,2⟩:7 — 7 products, 15 additions (the
    /// fewest known for rank 7).
    Strassen222,
    /// Laderman ⟨3,3,3⟩:23 — the non-Strassen member; recursing on
    /// thirds pairs naturally with 3·2ⁿ-ish dimensions where ⟨2,2,2⟩
    /// peels large fringes.
    Laderman333,
    /// ⟨4,2,4⟩:28 — Strassen–Winograd ⟨2,2,2⟩ ⊗ ⟨2,1,2⟩ (28 < 32
    /// classical products). The rectangular base case quarters the row
    /// and column spaces while only halving the depth, so it fits flat
    /// `k ≪ m,n` shapes where the cubic members peel large fringes. A
    /// bounded flip-graph walk (Kauers–Moosbauer-style, from this very
    /// decomposition) did not reach the Hopcroft–Kerr rank 26 under the
    /// {−1,0,1} coefficients the recursion's sign-only combine supports;
    /// the table slot takes a 26 drop-in if one lands.
    Kron424,
}

impl FastAlgoId {
    /// Every algorithm, in registry order.
    pub const ALL: [FastAlgoId; 3] =
        [FastAlgoId::Strassen222, FastAlgoId::Laderman333, FastAlgoId::Kron424];

    /// Stable name (persisted by the tuned cache).
    pub fn name(self) -> &'static str {
        match self {
            FastAlgoId::Strassen222 => "strassen222",
            FastAlgoId::Laderman333 => "laderman333",
            FastAlgoId::Kron424 => "kron424",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn from_name(s: &str) -> Option<FastAlgoId> {
        FastAlgoId::ALL.iter().copied().find(|id| id.name() == s)
    }

    /// The algorithm's coefficient tables.
    pub fn algo(self) -> &'static FastAlgo {
        match self {
            FastAlgoId::Strassen222 => &STRASSEN_222,
            FastAlgoId::Laderman333 => &LADERMAN_333,
            FastAlgoId::Kron424 => &KRON_424,
        }
    }
}

/// One ⟨bm,bk,bn⟩:R fast algorithm as flat coefficient tables.
///
/// Layout (all blocks row-major within their grid):
/// `u[r·(bm·bk) + i·bk + p]` is the coefficient of A block `(i,p)` in
/// product `r`; `v[r·(bk·bn) + q·bn + j]` that of B block `(q,j)`;
/// `w[(x·bn + y)·rank + r]` that of product `r` in C block `(x,y)`.
/// Every table is certified against the Brent equations by
/// `brent_equations_hold` below.
#[derive(Debug)]
pub struct FastAlgo {
    /// Which member this is.
    pub id: FastAlgoId,
    /// Block rows of A / C.
    pub bm: usize,
    /// Block columns of A / block rows of B.
    pub bk: usize,
    /// Block columns of B / C.
    pub bn: usize,
    /// Number of block products (the tensor rank).
    pub rank: usize,
    u: &'static [i8],
    v: &'static [i8],
    w: &'static [i8],
}

/// Strassen–Winograd ⟨2,2,2⟩:7 (the Winograd variant: 15 additions).
static STRASSEN_222: FastAlgo = FastAlgo {
    id: FastAlgoId::Strassen222,
    bm: 2,
    bk: 2,
    bn: 2,
    rank: 7,
    #[rustfmt::skip]
    u: &[
        1, 0, 0, 0,
        0, 1, 0, 0,
        1, 1, -1, -1,
        0, 0, 0, 1,
        0, 0, 1, 1,
        -1, 0, 1, 1,
        1, 0, -1, 0,
    ],
    #[rustfmt::skip]
    v: &[
        1, 0, 0, 0,
        0, 0, 1, 0,
        0, 0, 0, 1,
        1, -1, -1, 1,
        -1, 1, 0, 0,
        1, -1, 0, 1,
        0, -1, 0, 1,
    ],
    #[rustfmt::skip]
    w: &[
        1, 1, 0, 0, 0, 0, 0,
        1, 0, 1, 0, 1, 1, 0,
        1, 0, 0, -1, 0, 1, 1,
        1, 0, 0, 0, 1, 1, 1,
    ],
};

/// Laderman ⟨3,3,3⟩:23 (all coefficients in {−1, 0, 1}).
static LADERMAN_333: FastAlgo = FastAlgo {
    id: FastAlgoId::Laderman333,
    bm: 3,
    bk: 3,
    bn: 3,
    rank: 23,
    #[rustfmt::skip]
    u: &[
        1, 1, 1, -1, -1, 0, 0, -1, -1,
        1, 0, 0, -1, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 1, 0, 0, 0, 0,
        -1, 0, 0, 1, 1, 0, 0, 0, 0,
        0, 0, 0, 1, 1, 0, 0, 0, 0,
        1, 0, 0, 0, 0, 0, 0, 0, 0,
        -1, 0, 0, 0, 0, 0, 1, 1, 0,
        -1, 0, 0, 0, 0, 0, 1, 0, 0,
        0, 0, 0, 0, 0, 0, 1, 1, 0,
        1, 1, 1, 0, -1, -1, -1, -1, 0,
        0, 0, 0, 0, 0, 0, 0, 1, 0,
        0, 0, -1, 0, 0, 0, 0, 1, 1,
        0, 0, 1, 0, 0, 0, 0, 0, -1,
        0, 0, 1, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0, 0, 1, 1,
        0, 0, -1, 0, 1, 1, 0, 0, 0,
        0, 0, 1, 0, 0, -1, 0, 0, 0,
        0, 0, 0, 0, 1, 1, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 1, 0, 0, 0,
        0, 0, 0, 1, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0, 1, 0, 0,
        0, 0, 0, 0, 0, 0, 0, 0, 1,
    ],
    #[rustfmt::skip]
    v: &[
        0, 0, 0, 0, 1, 0, 0, 0, 0,
        0, -1, 0, 0, 1, 0, 0, 0, 0,
        -1, 1, 0, 1, -1, -1, -1, 0, 1,
        1, -1, 0, 0, 1, 0, 0, 0, 0,
        -1, 1, 0, 0, 0, 0, 0, 0, 0,
        1, 0, 0, 0, 0, 0, 0, 0, 0,
        1, 0, -1, 0, 0, 1, 0, 0, 0,
        0, 0, 1, 0, 0, -1, 0, 0, 0,
        -1, 0, 1, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 1, 0, 0, 0,
        -1, 0, 1, 1, -1, -1, -1, 1, 0,
        0, 0, 0, 0, 1, 0, 1, -1, 0,
        0, 0, 0, 0, 1, 0, 0, -1, 0,
        0, 0, 0, 0, 0, 0, 1, 0, 0,
        0, 0, 0, 0, 0, 0, -1, 1, 0,
        0, 0, 0, 0, 0, 1, 1, 0, -1,
        0, 0, 0, 0, 0, 1, 0, 0, -1,
        0, 0, 0, 0, 0, 0, -1, 0, 1,
        0, 0, 0, 1, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0, 0, 1, 0,
        0, 0, 1, 0, 0, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0, 0, 0, 1,
    ],
    #[rustfmt::skip]
    w: &[
        0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0, 0,
        1, 0, 0, 1, 1, 1, 0, 0, 0, 0, 0, 1, 0, 1, 1, 0, 0, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 1, 1, 0, 1, 1, 0, 0, 0, 1, 0, 1, 0, 1, 0, 0, 0, 0, 0,
        0, 1, 1, 1, 0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 1, 1, 0, 0, 0, 0, 0, 0,
        0, 1, 0, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0,
        0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 0, 1, 1, 1, 0, 0, 1, 0, 0,
        0, 0, 0, 0, 0, 1, 1, 1, 0, 0, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 1, 0,
        0, 0, 0, 0, 0, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1,
    ],
};

/// ⟨4,2,4⟩:28 — Strassen–Winograd ⟨2,2,2⟩ tensor-composed with the
/// ⟨2,1,2⟩ outer product: product (r, si, sj) applies Strassen product
/// r to the (si, sj) interleave of the 4×-split row/column spaces.
/// 28 < 32 classical block products; every coefficient stays in
/// {−1, 0, 1} as `combine`/`writeback` require.
static KRON_424: FastAlgo = FastAlgo {
    id: FastAlgoId::Kron424,
    bm: 4,
    bk: 2,
    bn: 4,
    rank: 28,
    #[rustfmt::skip]
    u: &[
        1, 0, 0, 0, 0, 0, 0, 0,
        1, 0, 0, 0, 0, 0, 0, 0,
        0, 0, 1, 0, 0, 0, 0, 0,
        0, 0, 1, 0, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 1, 0, 0, 0, 0,
        0, 0, 0, 1, 0, 0, 0, 0,
        1, 1, 0, 0, -1, -1, 0, 0,
        1, 1, 0, 0, -1, -1, 0, 0,
        0, 0, 1, 1, 0, 0, -1, -1,
        0, 0, 1, 1, 0, 0, -1, -1,
        0, 0, 0, 0, 0, 1, 0, 0,
        0, 0, 0, 0, 0, 1, 0, 0,
        0, 0, 0, 0, 0, 0, 0, 1,
        0, 0, 0, 0, 0, 0, 0, 1,
        0, 0, 0, 0, 1, 1, 0, 0,
        0, 0, 0, 0, 1, 1, 0, 0,
        0, 0, 0, 0, 0, 0, 1, 1,
        0, 0, 0, 0, 0, 0, 1, 1,
        -1, 0, 0, 0, 1, 1, 0, 0,
        -1, 0, 0, 0, 1, 1, 0, 0,
        0, 0, -1, 0, 0, 0, 1, 1,
        0, 0, -1, 0, 0, 0, 1, 1,
        1, 0, 0, 0, -1, 0, 0, 0,
        1, 0, 0, 0, -1, 0, 0, 0,
        0, 0, 1, 0, 0, 0, -1, 0,
        0, 0, 1, 0, 0, 0, -1, 0,
    ],
    #[rustfmt::skip]
    v: &[
        1, 0, 0, 0, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0,
        1, 0, 0, 0, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 1, 0, 0, 0,
        0, 0, 0, 0, 0, 1, 0, 0,
        0, 0, 0, 0, 1, 0, 0, 0,
        0, 0, 0, 0, 0, 1, 0, 0,
        0, 0, 0, 0, 0, 0, 1, 0,
        0, 0, 0, 0, 0, 0, 0, 1,
        0, 0, 0, 0, 0, 0, 1, 0,
        0, 0, 0, 0, 0, 0, 0, 1,
        1, 0, -1, 0, -1, 0, 1, 0,
        0, 1, 0, -1, 0, -1, 0, 1,
        1, 0, -1, 0, -1, 0, 1, 0,
        0, 1, 0, -1, 0, -1, 0, 1,
        -1, 0, 1, 0, 0, 0, 0, 0,
        0, -1, 0, 1, 0, 0, 0, 0,
        -1, 0, 1, 0, 0, 0, 0, 0,
        0, -1, 0, 1, 0, 0, 0, 0,
        1, 0, -1, 0, 0, 0, 1, 0,
        0, 1, 0, -1, 0, 0, 0, 1,
        1, 0, -1, 0, 0, 0, 1, 0,
        0, 1, 0, -1, 0, 0, 0, 1,
        0, 0, -1, 0, 0, 0, 1, 0,
        0, 0, 0, -1, 0, 0, 0, 1,
        0, 0, -1, 0, 0, 0, 1, 0,
        0, 0, 0, -1, 0, 0, 0, 1,
    ],
    #[rustfmt::skip]
    w: &[
        1, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
        1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0,
        0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
        0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 0, 0,
        0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 0,
        1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, -1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, -1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0,
        1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0,
        0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, -1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0,
        0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, -1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1,
        0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 1, 0,
        0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 1,
    ],
};

/// Coarse shape taxonomy for per-shape autotuned selection. Fast
/// algorithms trade differently on square, wide-output and deep-`k`
/// problems, so the tuned cache keys its [`FastmmChoice`] by this.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShapeClass {
    /// No dimension more than 2× the smallest — the classic fast-matmul
    /// home turf.
    Square,
    /// Output-dominated: `m`/`n` stretch past `k`.
    Flat,
    /// Inner-dimension dominated (`k` is the largest).
    Deep,
}

impl ShapeClass {
    /// Every class, in index order.
    pub const ALL: [ShapeClass; 3] = [ShapeClass::Square, ShapeClass::Flat, ShapeClass::Deep];

    /// Classify one `(m, n, k)` shape.
    pub fn of(m: usize, n: usize, k: usize) -> ShapeClass {
        let mx = m.max(n).max(k);
        let mn = m.min(n).min(k).max(1);
        if mx <= 2 * mn {
            ShapeClass::Square
        } else if k >= m && k >= n {
            ShapeClass::Deep
        } else {
            ShapeClass::Flat
        }
    }

    /// Stable name (persisted by the tuned cache).
    pub fn name(self) -> &'static str {
        match self {
            ShapeClass::Square => "square",
            ShapeClass::Flat => "flat",
            ShapeClass::Deep => "deep",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn from_name(s: &str) -> Option<ShapeClass> {
        ShapeClass::ALL.iter().copied().find(|c| c.name() == s)
    }

    fn index(self) -> usize {
        match self {
            ShapeClass::Square => 0,
            ShapeClass::Flat => 1,
            ShapeClass::Deep => 2,
        }
    }
}

/// One tuned selection: which algorithm, where the recursion bottoms
/// out, and the smallest dimension at which the fast tier wins at all.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FastmmChoice {
    /// The winning algorithm for this (element, shape class).
    pub algo: FastAlgoId,
    /// Recursion cutoff: subproblems at or below this run the base kernel.
    pub crossover: usize,
    /// Minimum smallest-dimension before dispatch routes here.
    pub min_dim: usize,
}

impl Default for FastmmChoice {
    fn default() -> Self {
        Self {
            algo: FastAlgoId::Strassen222,
            crossover: DEFAULT_CROSSOVER,
            min_dim: DEFAULT_MIN_DIM,
        }
    }
}

/// The dispatch-facing selection table: one optional [`FastmmChoice`]
/// per (element, shape class). `None` disables the fast tier for that
/// cell. The default enables the conservative default choice on square
/// shapes for both elements — rectangular classes stay off until the
/// autotuner measures a win there.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FastmmTable {
    choices: [[Option<FastmmChoice>; 3]; 2],
}

impl Default for FastmmTable {
    fn default() -> Self {
        let mut t = Self::disabled();
        t.set(ElementId::F32, ShapeClass::Square, Some(FastmmChoice::default()));
        t.set(ElementId::F64, ShapeClass::Square, Some(FastmmChoice::default()));
        t
    }
}

impl FastmmTable {
    /// A table with every cell disabled (tests pin selection off with
    /// this the way `strassen_min_dim: usize::MAX` used to).
    pub fn disabled() -> Self {
        Self { choices: [[None; 3]; 2] }
    }

    /// A table with every cell set to `choice`.
    pub fn uniform(choice: FastmmChoice) -> Self {
        Self { choices: [[Some(choice); 3]; 2] }
    }

    fn element_index(element: ElementId) -> usize {
        match element {
            ElementId::F32 => 0,
            ElementId::F64 => 1,
        }
    }

    /// The choice for one (element, class) cell, if enabled.
    pub fn choice(&self, element: ElementId, class: ShapeClass) -> Option<FastmmChoice> {
        self.choices[Self::element_index(element)][class.index()]
    }

    /// Set (or disable) one cell.
    pub fn set(&mut self, element: ElementId, class: ShapeClass, choice: Option<FastmmChoice>) {
        self.choices[Self::element_index(element)][class.index()] = choice;
    }
}

/// Per-task scratch free-list: `take` hands out a zero-initialised
/// buffer (reusing a returned one when available), `give` returns it.
/// One arena lives on each task's stack, so DFS recursion reuses the
/// same few buffers across every level with zero synchronisation.
struct Arena<T> {
    free: Vec<Vec<T>>,
}

impl<T: Element> Arena<T> {
    fn new() -> Self {
        Self { free: Vec::new() }
    }

    fn take(&mut self, len: usize) -> Vec<T> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(len, T::ZERO);
        v
    }

    fn give(&mut self, v: Vec<T>) {
        self.free.push(v);
    }
}

/// Fast-matmul driver: `C = alpha·A·B + beta·C` (no-transpose views;
/// dispatch degrades transposed calls before reaching here).
///
/// `crossover` bottoms the recursion out on `base`; `pool` enables the
/// BFS fan-out (`None` runs fully DFS on the calling thread). Results
/// are bitwise identical for any pool size including `None`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_fastmm<T: Element>(
    algo: FastAlgoId,
    crossover: usize,
    base: &SerialVecKernel,
    pool: Option<&ThreadPool>,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
) {
    if alpha == T::ZERO {
        base.run(Transpose::No, Transpose::No, alpha, a, b, beta, c);
        return;
    }
    let crossover = crossover.max(MIN_CROSSOVER);
    // Fold beta in up front so the recursion only knows two writeback
    // modes: overwrite (`acc = false`) or accumulate (`acc = true`).
    // `scale` is exact (and a no-op for beta == 1), so this costs one
    // sweep of C at most and keeps every level's fixups uniform.
    let acc = if beta == T::ZERO {
        false
    } else {
        c.scale(beta);
        true
    };
    let mut arena = Arena::new();
    rec(algo.algo(), crossover, base, pool, &mut arena, alpha, acc, a, b, c);
}

/// One recursion level over strided views: fast core plus dynamically
/// peeled classical fringes. `C (+)= alpha·A·B` per `acc`.
#[allow(clippy::too_many_arguments)]
fn rec<T: Element>(
    algo: &'static FastAlgo,
    crossover: usize,
    base: &SerialVecKernel,
    pool: Option<&ThreadPool>,
    arena: &mut Arena<T>,
    alpha: T,
    acc: bool,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    c: &mut MatMut<'_, T>,
) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let beta_eff = if acc { T::ONE } else { T::ZERO };
    let (ms, ks, ns) = (m / algo.bm, k / algo.bk, n / algo.bn);
    if m.max(k).max(n) <= crossover || ms == 0 || ks == 0 || ns == 0 {
        base.run(Transpose::No, Transpose::No, alpha, a, b, beta_eff, c);
        return;
    }
    let (m0, k0, n0) = (ms * algo.bm, ks * algo.bk, ns * algo.bn);
    let (bm, bk, bn, rank) = (algo.bm, algo.bk, algo.bn, algo.rank);

    // The divisible core as block-grid views (row-major block order,
    // matching the U/V/W table layout).
    let ablocks: Vec<MatRef<'_, T>> =
        (0..bm * bk).map(|t| a.block((t / bk) * ms, (t % bk) * ks, ms, ks)).collect();
    let bblocks: Vec<MatRef<'_, T>> =
        (0..bk * bn).map(|t| b.block((t / bn) * ks, (t % bn) * ns, ks, ns)).collect();
    // First product contributing to each C block: on overwrite runs
    // that term stores instead of accumulating.
    let first_r: Vec<usize> = (0..bm * bn)
        .map(|cb| {
            (0..rank)
                .find(|&r| algo.w[cb * rank + r] != 0)
                .expect("certified algorithms cover every C block")
        })
        .collect();

    let fan_out = pool.is_some_and(ThreadPool::has_idle) && ms * ks * ns >= BFS_MIN_VOLUME;
    if fan_out {
        // BFS: all R products into private buffers, concurrently. Each
        // task carries its own arena; nested levels keep deciding
        // BFS-vs-DFS off pool saturation.
        let mut p_bufs: Vec<Vec<T>> = (0..rank).map(|_| vec![T::ZERO; ms * ns]).collect();
        {
            let ablocks = &ablocks;
            let bblocks = &bblocks;
            let base_copy = *base;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = p_bufs
                .iter_mut()
                .enumerate()
                .map(|(r, p_buf)| {
                    Box::new(move || {
                        let mut local = Arena::new();
                        product_into(
                            algo, crossover, &base_copy, pool, &mut local, ablocks, bblocks, r,
                            ms, ks, ns, p_buf,
                        );
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            run_borrowed_on(pool, jobs);
        }
        // Serial writeback in ascending r — the same order the DFS arm
        // uses, which is what makes results schedule-independent.
        for (r, p_buf) in p_bufs.iter().enumerate() {
            writeback(algo, c, &first_r, acc, alpha, r, p_buf, ms, ns);
        }
    } else {
        // DFS: one product at a time, arena scratch reused across all R.
        let mut p_buf = arena.take(ms * ns);
        for r in 0..rank {
            product_into(
                algo, crossover, base, pool, arena, &ablocks, &bblocks, r, ms, ks, ns, &mut p_buf,
            );
            writeback(algo, c, &first_r, acc, alpha, r, &p_buf, ms, ns);
        }
        arena.give(p_buf);
    }

    // Classical fixups for the peeled fringes, disjointly covering the
    // rest of C (and the k remainder of the core):
    //   core      C[..m0, ..n0] (+)= A[..m0, ..k0]  · B[..k0, ..n0]   (above)
    //   k fringe  C[..m0, ..n0]  += A[..m0, k0..]   · B[k0.., ..n0]
    //   n fringe  C[..m0, n0..] (+)= A[..m0, ..]    · B[.., n0..]
    //   m fringe  C[m0.., ..]   (+)= A[m0.., ..]    · B
    if k0 < k {
        let mut c_core = c.block_mut(0, 0, m0, n0);
        base.run(
            Transpose::No,
            Transpose::No,
            alpha,
            a.block(0, k0, m0, k - k0),
            b.block(k0, 0, k - k0, n0),
            T::ONE,
            &mut c_core,
        );
    }
    if n0 < n {
        let mut c_right = c.block_mut(0, n0, m0, n - n0);
        base.run(
            Transpose::No,
            Transpose::No,
            alpha,
            a.block(0, 0, m0, k),
            b.block(0, n0, k, n - n0),
            beta_eff,
            &mut c_right,
        );
    }
    if m0 < m {
        let mut c_bottom = c.block_mut(m0, 0, m - m0, n);
        base.run(
            Transpose::No,
            Transpose::No,
            alpha,
            a.block(m0, 0, m - m0, k),
            b,
            beta_eff,
            &mut c_bottom,
        );
    }
}

/// Compute product `r`: assemble `S = Σ U[r]·A_t` and `T = Σ V[r]·B_t`
/// (borrowing the operand block directly when the row is a lone `+1`),
/// then recurse `P_r = S·T` into `p_buf`.
#[allow(clippy::too_many_arguments)]
fn product_into<T: Element>(
    algo: &'static FastAlgo,
    crossover: usize,
    base: &SerialVecKernel,
    pool: Option<&ThreadPool>,
    arena: &mut Arena<T>,
    ablocks: &[MatRef<'_, T>],
    bblocks: &[MatRef<'_, T>],
    r: usize,
    ms: usize,
    ks: usize,
    ns: usize,
    p_buf: &mut [T],
) {
    let (bm, bk, bn) = (algo.bm, algo.bk, algo.bn);
    let u_row = &algo.u[r * (bm * bk)..(r + 1) * (bm * bk)];
    let v_row = &algo.v[r * (bk * bn)..(r + 1) * (bk * bn)];
    let mut s_buf = None;
    let s_view = match singleton(u_row) {
        Some(t) => ablocks[t],
        None => {
            let mut buf = arena.take(ms * ks);
            combine(u_row, ablocks, ms, ks, &mut buf);
            let buf: &Vec<T> = s_buf.insert(buf);
            MatRef::new(buf, ms, ks, ks).expect("fastmm S scratch view")
        }
    };
    let mut t_buf = None;
    let t_view = match singleton(v_row) {
        Some(t) => bblocks[t],
        None => {
            let mut buf = arena.take(ks * ns);
            combine(v_row, bblocks, ks, ns, &mut buf);
            let buf: &Vec<T> = t_buf.insert(buf);
            MatRef::new(buf, ks, ns, ns).expect("fastmm T scratch view")
        }
    };
    let mut p_view = MatMut::new(p_buf, ms, ns, ns).expect("fastmm P scratch view");
    rec(algo, crossover, base, pool, arena, T::ONE, false, s_view, t_view, &mut p_view);
    if let Some(buf) = s_buf {
        arena.give(buf);
    }
    if let Some(buf) = t_buf {
        arena.give(buf);
    }
}

/// The block index when a coefficient row is exactly one `+1` (the
/// operand view can then feed the recursion without a copy).
fn singleton(coefs: &[i8]) -> Option<usize> {
    let mut found = None;
    for (t, &cf) in coefs.iter().enumerate() {
        if cf == 0 {
            continue;
        }
        if cf != 1 || found.is_some() {
            return None;
        }
        found = Some(t);
    }
    found
}

/// `out = Σ coefs[t]·blocks[t]` over `rows×cols` views, in ascending
/// block order (fixed order ⇒ deterministic rounding).
fn combine<T: Element>(
    coefs: &[i8],
    blocks: &[MatRef<'_, T>],
    rows: usize,
    cols: usize,
    out: &mut [T],
) {
    debug_assert_eq!(out.len(), rows * cols);
    let mut first = true;
    for (t, &cf) in coefs.iter().enumerate() {
        if cf == 0 {
            continue;
        }
        let blk = &blocks[t];
        for i in 0..rows {
            let row = &mut out[i * cols..(i + 1) * cols];
            if first {
                for (j, slot) in row.iter_mut().enumerate() {
                    let v = blk.get(i, j);
                    *slot = if cf < 0 { -v } else { v };
                }
            } else if cf < 0 {
                for (j, slot) in row.iter_mut().enumerate() {
                    *slot -= blk.get(i, j);
                }
            } else {
                for (j, slot) in row.iter_mut().enumerate() {
                    *slot += blk.get(i, j);
                }
            }
        }
        first = false;
    }
    debug_assert!(!first, "every product reads at least one operand block");
}

/// Apply product `r` to every C block it contributes to. The first
/// contribution of an overwrite run stores; everything else accumulates
/// `± alpha·P_r` — alpha is applied exactly once, here, at the level
/// that owns the caller's scaling (inner levels recurse with alpha = 1).
#[allow(clippy::too_many_arguments)]
fn writeback<T: Element>(
    algo: &FastAlgo,
    c: &mut MatMut<'_, T>,
    first_r: &[usize],
    acc: bool,
    alpha: T,
    r: usize,
    p: &[T],
    ms: usize,
    ns: usize,
) {
    let (bm, bn, rank) = (algo.bm, algo.bn, algo.rank);
    for cb in 0..bm * bn {
        let wv = algo.w[cb * rank + r];
        if wv == 0 {
            continue;
        }
        let (x, y) = (cb / bn, cb % bn);
        let overwrite = !acc && first_r[cb] == r;
        let mut cblk = c.block_mut(x * ms, y * ns, ms, ns);
        for i in 0..ms {
            for j in 0..ns {
                let mut v = alpha * p[i * ns + j];
                if wv < 0 {
                    v = -v;
                }
                if overwrite {
                    cblk.set(i, j, v);
                } else {
                    let old = cblk.get(i, j);
                    cblk.set(i, j, old + v);
                }
            }
        }
    }
}

/// Honest arithmetic count of one fast-matmul run on an `m×k · k×n`
/// problem: rank-based recursion on the divisible core (block products
/// plus the S/T/C additions the tables actually perform, scaled by
/// block size) and classical `2mnk` for base cases and peeled fringes.
/// Replaces the old square-only `strassen_flops` model — rectangular
/// shapes report their real counts.
pub fn flops(id: FastAlgoId, m: usize, k: usize, n: usize, crossover: usize) -> f64 {
    flops_rec(id.algo(), m, k, n, crossover.max(MIN_CROSSOVER))
}

fn flops_rec(algo: &FastAlgo, m: usize, k: usize, n: usize, crossover: usize) -> f64 {
    let (ms, ks, ns) = (m / algo.bm, k / algo.bk, n / algo.bn);
    if m.max(k).max(n) <= crossover || ms == 0 || ks == 0 || ns == 0 {
        return 2.0 * m as f64 * k as f64 * n as f64;
    }
    let (m0, k0, n0) = (ms * algo.bm, ks * algo.bk, ns * algo.bn);
    let (bm, bk, bn, rank) = (algo.bm, algo.bk, algo.bn, algo.rank);
    let mut adds = 0.0;
    for r in 0..rank {
        let nu = algo.u[r * bm * bk..(r + 1) * (bm * bk)].iter().filter(|&&cf| cf != 0).count();
        let nv = algo.v[r * bk * bn..(r + 1) * (bk * bn)].iter().filter(|&&cf| cf != 0).count();
        adds += nu.saturating_sub(1) as f64 * (ms * ks) as f64;
        adds += nv.saturating_sub(1) as f64 * (ks * ns) as f64;
    }
    let w_terms = algo.w.iter().filter(|&&cf| cf != 0).count();
    adds += w_terms as f64 * (ms * ns) as f64;
    let mut total = rank as f64 * flops_rec(algo, ms, ks, ns, crossover) + adds;
    if k0 < k {
        total += 2.0 * m0 as f64 * (k - k0) as f64 * n0 as f64;
    }
    if n0 < n {
        total += 2.0 * m0 as f64 * k as f64 * (n - n0) as f64;
    }
    if m0 < m {
        total += 2.0 * (m - m0) as f64 * k as f64 * n as f64;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::Matrix;
    use crate::gemm::dispatch::{DispatchConfig, GemmDispatch};
    use crate::gemm::naive;
    use crate::util::testkit::{assert_allclose, assert_allclose_f64};

    /// The base kernel dispatch would hand the recursion on this host.
    fn base_kernel() -> SerialVecKernel {
        GemmDispatch::new(DispatchConfig::default()).serial_vec_kernel_t::<f32>(64)
    }

    fn base_kernel_f64() -> SerialVecKernel {
        GemmDispatch::new(DispatchConfig::default()).serial_vec_kernel_t::<f64>(64)
    }

    #[test]
    fn brent_equations_hold_for_every_algorithm() {
        // Σ_r U[r,(i,p)]·V[r,(q,j)]·W[(x,y),r] = [p=q][i=x][j=y]: the
        // exact algebraic certificate that each table multiplies
        // matrices — exhaustive over all block-index combinations.
        for id in FastAlgoId::ALL {
            let algo = id.algo();
            let (bm, bk, bn, rank) = (algo.bm, algo.bk, algo.bn, algo.rank);
            assert_eq!(algo.u.len(), rank * bm * bk, "{}", id.name());
            assert_eq!(algo.v.len(), rank * bk * bn, "{}", id.name());
            assert_eq!(algo.w.len(), bm * bn * rank, "{}", id.name());
            for i in 0..bm {
                for p in 0..bk {
                    for q in 0..bk {
                        for j in 0..bn {
                            for x in 0..bm {
                                for y in 0..bn {
                                    let mut sum = 0i32;
                                    for r in 0..rank {
                                        sum += algo.u[r * (bm * bk) + i * bk + p] as i32
                                            * algo.v[r * (bk * bn) + q * bn + j] as i32
                                            * algo.w[(x * bn + y) * rank + r] as i32;
                                    }
                                    let want = i32::from(p == q && i == x && j == y);
                                    assert_eq!(
                                        sum,
                                        want,
                                        "{}: ({i}{p})({q}{j})->({x}{y})",
                                        id.name()
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn shape_class_taxonomy() {
        assert_eq!(ShapeClass::of(512, 512, 512), ShapeClass::Square);
        assert_eq!(ShapeClass::of(500, 700, 400), ShapeClass::Square);
        assert_eq!(ShapeClass::of(2048, 2048, 64), ShapeClass::Flat);
        assert_eq!(ShapeClass::of(64, 64, 2048), ShapeClass::Deep);
        assert_eq!(ShapeClass::of(0, 16, 16), ShapeClass::Flat);
        for class in ShapeClass::ALL {
            assert_eq!(ShapeClass::from_name(class.name()), Some(class));
        }
        for id in FastAlgoId::ALL {
            assert_eq!(FastAlgoId::from_name(id.name()), Some(id));
        }
        assert_eq!(FastAlgoId::from_name("nope"), None);
    }

    #[test]
    fn fastmm_table_cells_are_independent() {
        let mut t = FastmmTable::disabled();
        assert_eq!(t.choice(ElementId::F32, ShapeClass::Square), None);
        let ch = FastmmChoice { algo: FastAlgoId::Laderman333, crossover: 64, min_dim: 128 };
        t.set(ElementId::F64, ShapeClass::Deep, Some(ch));
        assert_eq!(t.choice(ElementId::F64, ShapeClass::Deep), Some(ch));
        assert_eq!(t.choice(ElementId::F32, ShapeClass::Deep), None);
        assert_eq!(t.choice(ElementId::F64, ShapeClass::Square), None);
        // The default enables square shapes only, both elements.
        let d = FastmmTable::default();
        assert!(d.choice(ElementId::F32, ShapeClass::Square).is_some());
        assert!(d.choice(ElementId::F64, ShapeClass::Square).is_some());
        assert!(d.choice(ElementId::F32, ShapeClass::Flat).is_none());
        assert!(d.choice(ElementId::F64, ShapeClass::Deep).is_none());
    }

    fn run_fastmm_f32(
        id: FastAlgoId,
        crossover: usize,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        beta: f32,
    ) -> (Matrix<f32>, Matrix<f32>) {
        let a = Matrix::random(m, k, 0xA0 + m as u64, -1.0, 1.0);
        let b = Matrix::random(k, n, 0xB0 + n as u64, -1.0, 1.0);
        let mut got = Matrix::from_fn(m, n, |r, c| (r * n + c) as f32 * 0.001);
        let mut want = got.clone();
        let base = base_kernel();
        gemm_fastmm(id, crossover, &base, None, alpha, a.view(), b.view(), beta, &mut got.view_mut());
        naive::gemm(
            Transpose::No,
            Transpose::No,
            alpha,
            a.view(),
            b.view(),
            beta,
            &mut want.view_mut(),
        );
        (got, want)
    }

    #[test]
    fn matches_naive_on_odd_and_rectangular_shapes() {
        // Shapes chosen to exercise every peeling case: odd in one, two
        // and three dimensions, plus strongly rectangular cores.
        for id in FastAlgoId::ALL {
            for &(m, n, k) in &[
                (64usize, 64usize, 64usize),
                (33, 47, 29),
                (70, 31, 65),
                (96, 100, 90),
                (128, 40, 128),
            ] {
                let (got, want) = run_fastmm_f32(id, 16, m, n, k, 0.75, 0.5);
                assert_allclose(
                    got.data(),
                    want.data(),
                    5e-3,
                    2e-3,
                    &format!("{} {m}x{n}x{k}", id.name()),
                );
            }
        }
    }

    #[test]
    fn overwrite_and_accumulate_semantics() {
        // beta = 0 must overwrite (NaN in C discarded), beta = 1 must
        // accumulate exactly once.
        let (m, n, k) = (40usize, 36usize, 44usize);
        let a = Matrix::random(m, k, 7, -1.0, 1.0);
        let b = Matrix::random(k, n, 8, -1.0, 1.0);
        let base = base_kernel();
        let mut got = Matrix::from_fn(m, n, |_, _| f32::NAN);
        gemm_fastmm(
            FastAlgoId::Strassen222,
            16,
            &base,
            None,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut got.view_mut(),
        );
        assert!(got.data().iter().all(|v| v.is_finite()), "beta=0 must discard NaN in C");
        let mut want = Matrix::zeros(m, n);
        naive::gemm(
            Transpose::No,
            Transpose::No,
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut want.view_mut(),
        );
        assert_allclose(got.data(), want.data(), 5e-3, 2e-3, "overwrite");
    }

    #[test]
    fn below_crossover_is_exactly_the_base_kernel() {
        let (m, n, k) = (48usize, 40usize, 32usize);
        let a = Matrix::random(m, k, 11, -1.0, 1.0);
        let b = Matrix::random(k, n, 12, -1.0, 1.0);
        let base = base_kernel();
        let mut got = Matrix::from_fn(m, n, |r, c| (r + c) as f32);
        let mut want = got.clone();
        gemm_fastmm(
            FastAlgoId::Laderman333,
            64,
            &base,
            None,
            1.5,
            a.view(),
            b.view(),
            0.5,
            &mut got.view_mut(),
        );
        // At or below the crossover the driver *is* the base kernel
        // (after the exact beta pre-scale) — bit-identical.
        want.view_mut().scale(0.5);
        base.run(
            Transpose::No,
            Transpose::No,
            1.5,
            a.view(),
            b.view(),
            1.0,
            &mut want.view_mut(),
        );
        assert_eq!(got.data(), want.data(), "below-crossover must be the base kernel");
    }

    #[test]
    fn f64_recursion_matches_naive_tightly() {
        for id in FastAlgoId::ALL {
            let (m, n, k) = (70usize, 65usize, 72usize);
            let a = Matrix::<f64>::random(m, k, 21, -1.0, 1.0);
            let b = Matrix::<f64>::random(k, n, 22, -1.0, 1.0);
            let base = base_kernel_f64();
            let mut got = Matrix::<f64>::from_fn(m, n, |r, c| (r * n + c) as f64 * 0.001);
            let mut want = got.clone();
            gemm_fastmm(id, 16, &base, None, 0.5, a.view(), b.view(), 1.5, &mut got.view_mut());
            naive::gemm(
                Transpose::No,
                Transpose::No,
                0.5,
                a.view(),
                b.view(),
                1.5,
                &mut want.view_mut(),
            );
            // f64 headroom: even multi-level recursion stays far inside
            // f32-grade tolerances.
            assert_allclose_f64(got.data(), want.data(), 1e-10, 1e-11, id.name());
        }
    }

    #[test]
    fn parallel_is_bitwise_identical_to_serial() {
        use crate::util::threadpool::ThreadPool;
        // Crossover at the floor + a shape big enough that the top level
        // genuinely fans out (ms·ks·ns ≥ BFS_MIN_VOLUME).
        let (m, n, k) = (260usize, 260usize, 260usize);
        let a = Matrix::random(m, k, 31, -1.0, 1.0);
        let b = Matrix::random(k, n, 32, -1.0, 1.0);
        let base = base_kernel();
        let pool = ThreadPool::new(3);
        for id in FastAlgoId::ALL {
            let mut c_serial = Matrix::from_fn(m, n, |r, c| (r ^ c) as f32 * 1e-3);
            let mut c_par = c_serial.clone();
            let mut c_par2 = c_serial.clone();
            gemm_fastmm(id, 32, &base, None, 1.25, a.view(), b.view(), 0.5, &mut c_serial.view_mut());
            gemm_fastmm(
                id,
                32,
                &base,
                Some(&pool),
                1.25,
                a.view(),
                b.view(),
                0.5,
                &mut c_par.view_mut(),
            );
            gemm_fastmm(
                id,
                32,
                &base,
                Some(&pool),
                1.25,
                a.view(),
                b.view(),
                0.5,
                &mut c_par2.view_mut(),
            );
            assert_eq!(
                c_serial.data(),
                c_par.data(),
                "{}: BFS must be bitwise identical to DFS",
                id.name()
            );
            assert_eq!(
                c_par.data(),
                c_par2.data(),
                "{}: parallel runs must be bitwise repeatable",
                id.name()
            );
        }
    }

    #[test]
    fn flop_model_beats_classical_and_reports_rectangles_honestly() {
        // Above the crossover both algorithms save real flops over 2n³.
        let classical = |m: usize, k: usize, n: usize| 2.0 * m as f64 * k as f64 * n as f64;
        for id in FastAlgoId::ALL {
            let fast = flops(id, 4096, 4096, 4096, 256);
            assert!(
                fast < classical(4096, 4096, 4096),
                "{}: {fast} !< classical",
                id.name()
            );
        }
        // Below the crossover the model is exactly classical.
        assert_eq!(flops(FastAlgoId::Strassen222, 100, 90, 80, 256), classical(100, 90, 80));
        // Rectangular honesty: the count follows the actual (m, k, n),
        // not a cube of the largest dimension.
        let rect = flops(FastAlgoId::Strassen222, 2048, 512, 2048, 256);
        assert!(rect < flops(FastAlgoId::Strassen222, 2048, 2048, 2048, 256));
        assert!(rect > classical(1024, 256, 1024));
        // And a fringe-heavy odd shape still counts its peel work.
        let odd = flops(FastAlgoId::Strassen222, 1025, 1025, 1025, 256);
        assert!(odd > flops(FastAlgoId::Strassen222, 1024, 1024, 1024, 256));
    }
}
