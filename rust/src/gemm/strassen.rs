//! Strassen–Winograd hybrid on top of the Emmerald kernel.
//!
//! The paper's opening sentence sets Strassen aside: *"Without resorting
//! to the complexities associated with implementing Strassen's algorithm
//! on deep-memory hierarchy machines [5], dense matrix-matrix
//! multiplication requires 2MNK floating point operations."* This module
//! implements what the paper deliberately skipped — the Winograd variant
//! of Strassen's algorithm (7 recursive multiplies, 15 additions) with an
//! Emmerald base case — so the `strassen_crossover` bench can answer the
//! question the paper left open: at what size would the asymptotic win
//! have beaten the SIMD kernel's constant factor?
//!
//! Odd dimensions are handled by static padding to the next even size at
//! each level (the standard approach in [5]); below the cutoff the
//! recursion bottoms out into [`crate::blas::sgemm`].

use crate::blas::{sgemm_matrix, Backend, Matrix, Transpose};

/// Default recursion cutoff: problems at or below this size go straight
/// to the blocked SIMD kernel (empirically near the host crossover).
pub const DEFAULT_CUTOFF: usize = 256;

/// `C = A · B` via Strassen–Winograd recursion with an Emmerald base case.
///
/// `A` is `m × k`, `B` is `k × n`. Any shapes are accepted; the recursion
/// pads odd dimensions per level.
pub fn strassen_matmul(a: &Matrix, b: &Matrix, cutoff: usize, backend: Backend) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "inner dimensions disagree");
    let cutoff = cutoff.max(8);
    let mut c = Matrix::zeros(a.rows(), b.cols());
    strassen_into(a, b, &mut c, cutoff, backend);
    c
}

/// Number of *useful* flops Strassen executes for an n³ problem with the
/// given cutoff (for bench reporting): 7 branches per level instead of 8.
pub fn strassen_flops(n: usize, cutoff: usize) -> f64 {
    if n <= cutoff {
        return 2.0 * (n as f64).powi(3);
    }
    let half = n.div_ceil(2);
    7.0 * strassen_flops(half, cutoff) + 15.0 * (half as f64) * (half as f64)
}

fn strassen_into(a: &Matrix, b: &Matrix, c: &mut Matrix, cutoff: usize, backend: Backend) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if m.max(k).max(n) <= cutoff || m < 2 || k < 2 || n < 2 {
        sgemm_matrix(backend, Transpose::No, Transpose::No, 1.0, a, b, 0.0, c)
            .expect("base-case sgemm");
        return;
    }
    // Pad to even on every axis (top-level copies only when needed).
    let (mp, kp, np) = (m.div_ceil(2) * 2, k.div_ceil(2) * 2, n.div_ceil(2) * 2);
    if (mp, kp, np) != (m, k, n) {
        let ap = pad(a, mp, kp);
        let bp = pad(b, kp, np);
        let mut cp = Matrix::zeros(mp, np);
        strassen_into(&ap, &bp, &mut cp, cutoff, backend);
        for r in 0..m {
            for col in 0..n {
                c.set(r, col, cp.get(r, col));
            }
        }
        return;
    }

    let (m2, k2, n2) = (m / 2, k / 2, n / 2);
    let a11 = sub(a, 0, 0, m2, k2);
    let a12 = sub(a, 0, k2, m2, k2);
    let a21 = sub(a, m2, 0, m2, k2);
    let a22 = sub(a, m2, k2, m2, k2);
    let b11 = sub(b, 0, 0, k2, n2);
    let b12 = sub(b, 0, n2, k2, n2);
    let b21 = sub(b, k2, 0, k2, n2);
    let b22 = sub(b, k2, n2, k2, n2);

    // Winograd's 7-multiply schedule.
    let s1 = add(&a21, &a22);
    let s2 = subm(&s1, &a11);
    let s3 = subm(&a11, &a21);
    let s4 = subm(&a12, &s2);
    let t1 = subm(&b12, &b11);
    let t2 = subm(&b22, &t1);
    let t3 = subm(&b22, &b12);
    let t4 = subm(&t2, &b21);

    let mut p1 = Matrix::zeros(m2, n2);
    strassen_into(&a11, &b11, &mut p1, cutoff, backend);
    let mut p2 = Matrix::zeros(m2, n2);
    strassen_into(&a12, &b21, &mut p2, cutoff, backend);
    let mut p3 = Matrix::zeros(m2, n2);
    strassen_into(&s4, &b22, &mut p3, cutoff, backend);
    let mut p4 = Matrix::zeros(m2, n2);
    strassen_into(&a22, &t4, &mut p4, cutoff, backend);
    let mut p5 = Matrix::zeros(m2, n2);
    strassen_into(&s1, &t1, &mut p5, cutoff, backend);
    let mut p6 = Matrix::zeros(m2, n2);
    strassen_into(&s2, &t2, &mut p6, cutoff, backend);
    let mut p7 = Matrix::zeros(m2, n2);
    strassen_into(&s3, &t3, &mut p7, cutoff, backend);

    let u1 = add(&p1, &p6); // = A11·B11 + S2·T2
    let u2 = add(&u1, &p7);
    let u3 = add(&u1, &p5);

    let c11 = add(&p1, &p2);
    let c12 = add3(&u3, &p3);
    let c21 = subm(&u2, &p4);
    let c22 = add(&u2, &p5);

    write_block(c, 0, 0, &c11);
    write_block(c, 0, n2, &c12);
    write_block(c, m2, 0, &c21);
    write_block(c, m2, n2, &c22);
}

fn sub(src: &Matrix, r0: usize, c0: usize, rows: usize, cols: usize) -> Matrix {
    Matrix::from_fn(rows, cols, |r, c| src.get(r0 + r, c0 + c))
}

fn pad(src: &Matrix, rows: usize, cols: usize) -> Matrix {
    Matrix::from_fn(rows, cols, |r, c| {
        if r < src.rows() && c < src.cols() {
            src.get(r, c)
        } else {
            0.0
        }
    })
}

fn add(a: &Matrix, b: &Matrix) -> Matrix {
    Matrix::from_fn(a.rows(), a.cols(), |r, c| a.get(r, c) + b.get(r, c))
}

fn add3(u3: &Matrix, p3: &Matrix) -> Matrix {
    add(u3, p3)
}

fn subm(a: &Matrix, b: &Matrix) -> Matrix {
    Matrix::from_fn(a.rows(), a.cols(), |r, c| a.get(r, c) - b.get(r, c))
}

fn write_block(c: &mut Matrix, r0: usize, c0: usize, block: &Matrix) {
    for r in 0..block.rows() {
        for col in 0..block.cols() {
            c.set(r0 + r, c0 + col, block.get(r, col));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::assert_allclose;

    fn naive_ref(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, 1.0, a, b, 0.0, &mut c)
            .unwrap();
        c
    }

    #[test]
    fn matches_naive_power_of_two() {
        let a = Matrix::random(64, 64, 1, -1.0, 1.0);
        let b = Matrix::random(64, 64, 2, -1.0, 1.0);
        let got = strassen_matmul(&a, &b, 16, Backend::Simd);
        let want = naive_ref(&a, &b);
        assert_allclose(got.data(), want.data(), 2e-3, 1e-3, "strassen 64, cutoff 16");
    }

    #[test]
    fn matches_naive_odd_and_rectangular() {
        for &(m, k, n) in &[(33usize, 47usize, 29usize), (70, 31, 65), (100, 100, 100)] {
            let a = Matrix::random(m, k, 3, -1.0, 1.0);
            let b = Matrix::random(k, n, 4, -1.0, 1.0);
            let got = strassen_matmul(&a, &b, 16, Backend::Simd);
            let want = naive_ref(&a, &b);
            assert_allclose(got.data(), want.data(), 5e-3, 2e-3, &format!("strassen {m}x{k}x{n}"));
        }
    }

    #[test]
    fn below_cutoff_equals_base_kernel_exactly() {
        let a = Matrix::random(40, 40, 5, -1.0, 1.0);
        let b = Matrix::random(40, 40, 6, -1.0, 1.0);
        let via_strassen = strassen_matmul(&a, &b, 64, Backend::Simd);
        let mut direct = Matrix::zeros(40, 40);
        sgemm_matrix(Backend::Simd, Transpose::No, Transpose::No, 1.0, &a, &b, 0.0, &mut direct)
            .unwrap();
        assert_eq!(via_strassen, direct, "at/below cutoff the result is the base kernel's");
    }

    #[test]
    fn flop_count_beats_2n3_above_cutoff() {
        let classic = 2.0 * 1024f64.powi(3);
        let st = strassen_flops(1024, 128);
        assert!(st < classic, "strassen flops {st} should beat classic {classic}");
        // One level of recursion saves exactly 1/8 of the multiplies.
        assert!(st > classic * 7.0 / 8.0 * 7.0 / 8.0 * 7.0 / 8.0 * 0.9);
        // At or below the cutoff it's the classic count.
        assert_eq!(strassen_flops(128, 128), 2.0 * 128f64.powi(3));
    }

    #[test]
    fn deep_recursion_is_numerically_acceptable() {
        // f32 Strassen loses ~1 bit per level; 3 levels must stay within a
        // loose tolerance (this is the "complexity" the paper alludes to).
        let n = 128;
        let a = Matrix::random(n, n, 7, -1.0, 1.0);
        let b = Matrix::random(n, n, 8, -1.0, 1.0);
        let got = strassen_matmul(&a, &b, 16, Backend::Simd);
        let want = naive_ref(&a, &b);
        assert_allclose(got.data(), want.data(), 1e-2, 5e-3, "3-level strassen f32");
    }
}
