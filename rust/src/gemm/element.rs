//! The element subsystem: a sealed storage-scalar trait ([`Scalar`]), a
//! sealed floating-point kernel trait ([`Element`]) layered on top of it,
//! and the **kernel-triple** trait ([`GemmTriple`]) that names one GEMM
//! instantiation by its *four* types: `Lhs × Rhs → Out` accumulated in
//! `Acc`.
//!
//! The paper's blocking and packing design is element-width-agnostic: the
//! register-tiling analysis of §2–§3 applies to integer multiply-add
//! exactly as it does to f32 FMA — only the lane count, the packing
//! granule and the micro-kernel instruction selection change. What *does*
//! change across instantiations is the type relationship between the
//! operands: homogeneous floats (f32·f32→f32) share one type everywhere,
//! while quantized inference multiplies `u8` activations by `i8` weights
//! into `i32` accumulators. The single-type `Element` spine from the
//! first refactor could not express that, so the generic layers now hang
//! off the triple:
//!
//! * **[`Scalar`]** — the storage contract every matrix, view and packing
//!   buffer is generic over: `Copy`, `ZERO`/`ONE`, closed `+`/`*`. It is
//!   implemented by `f32`, `f64`, `u8`, `i8` and `i32` — exactly the
//!   types that appear as an Lhs/Rhs/Out/Acc of some supported triple.
//! * **[`GemmTriple`]** — one kernel instantiation: associated types
//!   `Lhs`/`Rhs`/`Out`/`Acc`, a [`TripleId`] for dispatch tables and the
//!   tuned cache, and the widening multiply-accumulate [`madd`]
//!   (`GemmTriple::madd`) the scalar oracles are built from. A blanket
//!   impl maps every `T: Element` to the homogeneous triple
//!   `T × T → T` with `madd(acc, l, r) = acc + l * r` — literally the
//!   statement the pre-refactor oracles executed, which is what keeps
//!   f32/f64 results bit-for-bit unchanged and existing callers
//!   signature-compatible.
//! * **[`Qu8i8`]** — the quantized triple `u8 × i8 → i32` (accumulated in
//!   `i32` with wrapping adds, so results are exact mod 2³² and
//!   independent of summation order — the property the bitwise
//!   serial/parallel/prepacked conformance contract rests on). `Qu8i8`
//!   deliberately does *not* implement `Element`: the float-only tiers
//!   (SSE dot, fast-matmul, compensated accumulation) are unreachable for
//!   it at the type level, not merely guarded at runtime.
//! * **[`Element`]** — the floating-point kernel surface, unchanged in
//!   role: scalar algebra (`mul_add`, `abs`, `sqrt`, …) for the drivers,
//!   oracles and LAPACK tier; SIMD geometry ([`Element::LANES`],
//!   [`Element::TILE_NR`]); and the unsafe kernel hooks (AVX2 tile,
//!   dot-panels, compensated driver). Each impl delegates to
//!   the same monomorphic kernels as before.
//!
//! Both traits are **sealed**. Everything above the kernels —
//! [`crate::blas::Matrix`] views, `gemm::{naive, blocked, tile, pack,
//! parallel, batch, plan}`, dispatch selection and the tuned-parameter
//! cache — is generic over `T: Scalar` (storage) or `T: Element` /
//! `K: GemmTriple` (arithmetic), with `T = f32` as the default type
//! parameter so the classic SGEMM surface is unchanged. The quantized
//! driver lives in [`crate::gemm::quant`].
//!
//! Precision support matrix (kernel × instantiation):
//!
//! | tier                  | f32          | f64                    | u8×i8→i32                   |
//! |-----------------------|--------------|------------------------|-----------------------------|
//! | naive / blocked       | yes          | yes (generic scalar)   | yes (widening oracle)       |
//! | Emmerald SSE dot      | yes (paper)  | — (no f64 SSE kernel)  | — (by construction)         |
//! | Emmerald AVX2 dot     | yes (8-wide) | yes (4-wide YMM)       | — (tile tier instead)       |
//! | outer-product tile    | yes (6×16)   | yes (6×8, 12 YMM acc)  | yes (6×16, maddubs+madd)    |
//! | parallel split        | yes          | yes                    | yes (row split, bitwise)    |
//! | fast-matmul family    | yes          | yes (element-generic)  | — (by construction)         |
//! | batched / planned     | yes          | yes                    | yes (prepacked qgemm)       |
//! | compensated mode      | yes (Dot2)   | n/a (already f64)      | n/a (i32 is exact)          |
//! | fused epilogue        | yes          | yes                    | requant (i32→f32) + bias/act|

use super::params::{BlockParams, Unroll};
use super::simd::VecIsa;
use crate::blas::{MatMut, MatRef, Transpose};
use crate::util::prng::Pcg32;
use std::fmt::{Debug, Display};
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

mod sealed {
    /// Seals [`super::Scalar`] and [`super::Element`]: the kernel ladder
    /// carries hand-written SIMD instantiations per type, so outside
    /// impls cannot be meaningful.
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
    impl Sealed for u8 {}
    impl Sealed for i8 {}
    impl Sealed for i32 {}
}

/// A matrix storage scalar: the bound every view, matrix and packing
/// buffer is generic over. Implemented by exactly the types that appear
/// as a side of some supported [`GemmTriple`]: `f32`, `f64`, `u8`, `i8`
/// and `i32`.
///
/// Deliberately minimal — closed `+`/`*` and the two identities are all
/// the storage layers need (zero-fill of packing pads, `beta`-scaling of
/// `C`). The floating-point kernel surface lives in the [`Element`]
/// subtrait; integer arithmetic in the quantized driver goes through
/// [`GemmTriple::madd`] (wrapping), never through these ops.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + Default
    + Send
    + Sync
    + PartialEq
    + Debug
    + Add<Output = Self>
    + Mul<Output = Self>
    + AddAssign
    + MulAssign
    + 'static
{
    /// Additive identity (packing-pad fill value).
    const ZERO: Self;
    /// Multiplicative identity (`beta == ONE` fast path).
    const ONE: Self;
}

macro_rules! impl_scalar {
    ($($t:ty => $zero:expr, $one:expr;)*) => {$(
        impl Scalar for $t {
            const ZERO: $t = $zero;
            const ONE: $t = $one;
        }
    )*};
}

impl_scalar! {
    f32 => 0.0, 1.0;
    f64 => 0.0, 1.0;
    u8 => 0, 1;
    i8 => 0, 1;
    i32 => 0, 1;
}

/// Runtime identity of an [`Element`] instantiation — the key the
/// float dispatch tables are segmented by.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElementId {
    /// Single precision (SGEMM — the paper's element).
    F32,
    /// Double precision (DGEMM).
    F64,
}

impl ElementId {
    /// Stable name, as accepted by the CLI `--element` flags.
    pub fn name(self) -> &'static str {
        match self {
            ElementId::F32 => "f32",
            ElementId::F64 => "f64",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(ElementId::F32),
            "f64" => Some(ElementId::F64),
            _ => None,
        }
    }

    /// The homogeneous kernel triple this element instantiates.
    pub fn triple(self) -> TripleId {
        match self {
            ElementId::F32 => TripleId::F32,
            ElementId::F64 => TripleId::F64,
        }
    }
}

/// Runtime identity of a [`GemmTriple`] instantiation — the key the
/// dispatch tables and the tuned-parameter cache (schema v4) are
/// segmented by.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TripleId {
    /// Homogeneous single precision: `f32 × f32 → f32`.
    F32,
    /// Homogeneous double precision: `f64 × f64 → f64`.
    F64,
    /// Quantized inference: `u8 × i8 → i32` (i32 accumulate).
    QU8I8,
}

impl TripleId {
    /// Stable name, as stored in the tuned cache (`"triple"` key).
    pub fn name(self) -> &'static str {
        match self {
            TripleId::F32 => "f32",
            TripleId::F64 => "f64",
            TripleId::QU8I8 => "u8i8i32",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(TripleId::F32),
            "f64" => Some(TripleId::F64),
            "u8i8i32" => Some(TripleId::QU8I8),
            _ => None,
        }
    }

    /// The [`ElementId`] of a homogeneous float triple; `None` for the
    /// quantized triple (which has no `Element` impl by design).
    pub fn element(self) -> Option<ElementId> {
        match self {
            TripleId::F32 => Some(ElementId::F32),
            TripleId::F64 => Some(ElementId::F64),
            TripleId::QU8I8 => None,
        }
    }
}

/// One GEMM kernel instantiation, named by its four types:
/// `C: Out ⟵ A: Lhs × B: Rhs`, accumulated in `Acc`.
///
/// Drivers generic over `K: GemmTriple` take `MatRef<K::Lhs>` /
/// `MatRef<K::Rhs>` operands and a `MatMut<K::Out>` destination; packing
/// buffers pack `Lhs` on the A side and `Rhs` on the B side. The scalar
/// oracles accumulate with [`madd`](Self::madd), so one generic loop
/// states the arithmetic contract for every instantiation.
///
/// The blanket impl for `T: Element` makes every homogeneous float type
/// its own triple with `madd(acc, l, r) = acc + l * r` — the exact
/// pre-refactor statement, preserving f32/f64 bits.
pub trait GemmTriple: Send + Sync + 'static {
    /// Left operand (A) storage type.
    type Lhs: Scalar;
    /// Right operand (B) storage type.
    type Rhs: Scalar;
    /// Destination (C) storage type.
    type Out: Scalar;
    /// Accumulator type (widening for the quantized triple).
    type Acc: Scalar;
    /// Runtime identity (dispatch-table / tuned-cache key).
    const TRIPLE: TripleId;

    /// One widening multiply-accumulate step: `acc ⊕ (l ⊗ r)`. Floats
    /// use plain `+`/`*` (bit-compatibility with the pre-refactor
    /// oracles); integer triples use wrapping adds so accumulation is
    /// exact mod 2³² and order-independent.
    fn madd(acc: Self::Acc, l: Self::Lhs, r: Self::Rhs) -> Self::Acc;

    /// Final accumulator → destination conversion (identity for every
    /// currently supported triple; the quantized requant path converts
    /// in the epilogue instead, where scales are known).
    fn acc_to_out(acc: Self::Acc) -> Self::Out;

    /// Accumulate-into-destination addition (`C += result` mode): plain
    /// `+` for floats, wrapping for integer outputs (exact mod 2³²,
    /// never a debug overflow panic).
    fn out_add(a: Self::Out, b: Self::Out) -> Self::Out;
}

impl<T: Element> GemmTriple for T {
    type Lhs = T;
    type Rhs = T;
    type Out = T;
    type Acc = T;
    const TRIPLE: TripleId = <T as Element>::TRIPLE_ID;

    #[inline(always)]
    fn madd(acc: T, l: T, r: T) -> T {
        acc + l * r
    }

    #[inline(always)]
    fn acc_to_out(acc: T) -> T {
        acc
    }

    #[inline(always)]
    fn out_add(a: T, b: T) -> T {
        a + b
    }
}

/// The quantized-inference triple: `u8` activations × `i8` weights,
/// accumulated and stored as `i32`.
///
/// `madd` wraps (exact mod 2³²): every partial product fits `i32`
/// (`255 · 127 = 32385`), and wrapping addition is associative and
/// commutative, so any blocking/threading schedule produces bitwise
/// identical sums — the foundation of the qgemm conformance contract.
/// `Qu8i8` implements [`GemmTriple`] but *not* [`Element`]: the
/// float-only tiers (SSE dot, fast-matmul, compensated accumulation)
/// cannot even be named for it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Qu8i8;

impl GemmTriple for Qu8i8 {
    type Lhs = u8;
    type Rhs = i8;
    type Out = i32;
    type Acc = i32;
    const TRIPLE: TripleId = TripleId::QU8I8;

    #[inline(always)]
    fn madd(acc: i32, l: u8, r: i8) -> i32 {
        acc.wrapping_add((l as i32) * (r as i32))
    }

    #[inline(always)]
    fn acc_to_out(acc: i32) -> i32 {
        acc
    }

    #[inline(always)]
    fn out_add(a: i32, b: i32) -> i32 {
        a.wrapping_add(b)
    }
}

/// The sealed floating-point kernel trait — see the module docs. `f32`
/// and `f64` only; integer scalars stop at [`Scalar`] and reach the
/// kernels through [`Qu8i8`] instead.
pub trait Element:
    Scalar
    + PartialOrd
    + Display
    + Sub<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + SubAssign
{
    /// Runtime identity (float dispatch-table key).
    const ID: ElementId;
    /// The homogeneous [`TripleId`] (drives the blanket [`GemmTriple`]
    /// impl and the tuned-cache v4 key).
    const TRIPLE_ID: TripleId;
    /// Lanes per 256-bit vector (8 f32, 4 f64).
    const LANES: usize;
    /// Outer-product tile width: two 256-bit vectors (16 f32, 8 f64).
    const TILE_NR: usize;

    /// Lossy conversion from f64 (used for constants and sentinels).
    fn from_f64(v: f64) -> Self;
    /// Widening conversion to f64 (oracles, error measurement).
    fn to_f64(self) -> f64;
    /// Fused multiply-add `self * a + b` (one rounding).
    fn mul_add(self, a: Self, b: Self) -> Self;
    /// Absolute value.
    fn abs(self) -> Self;
    /// IEEE maximum.
    fn max(self, other: Self) -> Self;
    /// Square root (the LAPACK tier's pivot op).
    fn sqrt(self) -> Self;
    /// Hyperbolic tangent (the fused-epilogue activation the MLP layer
    /// uses; f32 delegates to `f32::tanh` so fused results stay bitwise
    /// identical to the legacy separate bias+tanh pass).
    fn tanh(self) -> Self;
    /// Finiteness check (the LAPACK tier's pivot guard).
    fn is_finite(self) -> bool;
    /// One uniform draw in `[lo, hi)` — f32 draws exactly the bits the
    /// pre-refactor `Pcg32::f32_range` produced (test determinism).
    fn sample(rng: &mut Pcg32, lo: Self, hi: Self) -> Self;

    /// The AVX2+FMA outer-product tile micro-kernel for this element
    /// (`dst (mr × TILE_NR) ⟵ A'·B'`; see [`crate::gemm::tile`]).
    ///
    /// # Safety
    /// `ap` readable for `kc * mr` elements, `bp` for `kc * TILE_NR`;
    /// `dst` writable at rows `i*dst_ld` (`i < mr`), each `TILE_NR` wide;
    /// AVX2 and FMA must be available; `1 <= mr <= 6`.
    #[allow(clippy::too_many_arguments)]
    unsafe fn avx2_tile_dyn(
        mr: usize,
        ap: *const Self,
        bp: *const Self,
        kc: usize,
        alpha: Self,
        dst: *mut Self,
        dst_ld: usize,
        accumulate: bool,
        prefetch: bool,
    );

    /// Masked fringe writeback folding a raw accumulator tile into `C`
    /// with one *fused* multiply-add per element, rounding exactly like a
    /// lane of [`avx2_tile_dyn`](Self::avx2_tile_dyn)'s vector writeback
    /// (the tile tier's bit-stability contract).
    ///
    /// # Safety
    /// `tmp` readable at rows `i*tmp_ld` for `i < h`, `dst` writable at
    /// rows `i*dst_ld` for `i < h`, each row `w` wide; FMA available.
    unsafe fn tile_fringe(
        tmp: *const Self,
        tmp_ld: usize,
        alpha: Self,
        dst: *mut Self,
        dst_ld: usize,
        h: usize,
        w: usize,
    );

    /// Dot-panel micro-kernel: `cols.len()` simultaneous dot products of
    /// length `len` against one row of `A'` (the paper's fig. 1a shape).
    /// `VecIsa::Sse` has no f64 instantiation and falls back to the
    /// scalar panel there.
    ///
    /// # Safety
    /// `a` and every `cols[j]` readable for `len` elements;
    /// `1 <= cols.len() <= 8 <= out.len()`; the ISA, where used, must be
    /// available (callers pass runtime-detected features only).
    unsafe fn dot_panel_dyn(
        isa: VecIsa,
        a: *const Self,
        len: usize,
        cols: &[*const Self],
        unroll: Unroll,
        prefetch: bool,
        out: &mut [Self],
    );

    /// Two-row dot-panel micro-kernel (every `B` vector re-used against
    /// two `A` rows — the FMA-bound operating point; AVX2 only).
    ///
    /// # Safety
    /// As [`dot_panel_dyn`](Self::dot_panel_dyn) for both rows; AVX2+FMA
    /// must be available.
    #[allow(clippy::too_many_arguments)]
    unsafe fn dot_panel2_dyn(
        a0: *const Self,
        a1: *const Self,
        len: usize,
        cols: &[*const Self],
        unroll: Unroll,
        prefetch: bool,
        out0: &mut [Self],
        out1: &mut [Self],
    );

    /// The "no re-buffering" ablation kernel: `B` read through its
    /// strided layout (see [`crate::gemm::microkernel`]).
    ///
    /// # Safety
    /// `a` readable for `len` elements; each `cols[j].0` readable at
    /// offsets `p * cols[j].1` for `p < len`; `out.len() >= cols.len()`.
    unsafe fn dot_panel_strided(
        a: *const Self,
        len: usize,
        cols: &[(*const Self, usize)],
        out: &mut [Self],
    );

    /// Compensated-accumulation GEMM for this element: f32 runs the
    /// two-term (Kahan/Dekker) Dot2 driver of [`crate::gemm::comp`];
    /// f64 — which the mode exists to approximate — runs the standard
    /// dot-tier driver.
    #[allow(clippy::too_many_arguments)]
    fn comp_gemm(
        params: &BlockParams,
        transa: Transpose,
        transb: Transpose,
        alpha: Self,
        a: MatRef<'_, Self>,
        b: MatRef<'_, Self>,
        beta: Self,
        c: &mut MatMut<'_, Self>,
    );

}

impl Element for f32 {
    const ID: ElementId = ElementId::F32;
    const TRIPLE_ID: TripleId = TripleId::F32;
    const LANES: usize = 8;
    const TILE_NR: usize = 16;

    #[inline(always)]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn mul_add(self, a: f32, b: f32) -> f32 {
        f32::mul_add(self, a, b)
    }

    #[inline(always)]
    fn abs(self) -> f32 {
        f32::abs(self)
    }

    #[inline(always)]
    fn max(self, other: f32) -> f32 {
        f32::max(self, other)
    }

    #[inline(always)]
    fn sqrt(self) -> f32 {
        f32::sqrt(self)
    }

    #[inline(always)]
    fn tanh(self) -> f32 {
        f32::tanh(self)
    }

    #[inline(always)]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }

    #[inline(always)]
    fn sample(rng: &mut Pcg32, lo: f32, hi: f32) -> f32 {
        rng.f32_range(lo, hi)
    }

    unsafe fn avx2_tile_dyn(
        mr: usize,
        ap: *const f32,
        bp: *const f32,
        kc: usize,
        alpha: f32,
        dst: *mut f32,
        dst_ld: usize,
        accumulate: bool,
        prefetch: bool,
    ) {
        // SAFETY: forwarding the caller's contract verbatim to the f32
        // monomorphic kernel.
        #[cfg(target_arch = "x86_64")]
        unsafe {
            super::tile::avx2_tile_dyn_f32(mr, ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (mr, ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch);
            unreachable!("AVX2 tile kernel invoked without x86_64");
        }
    }

    unsafe fn tile_fringe(
        tmp: *const f32,
        tmp_ld: usize,
        alpha: f32,
        dst: *mut f32,
        dst_ld: usize,
        h: usize,
        w: usize,
    ) {
        // SAFETY: forwarding the caller's contract verbatim to the f32
        // monomorphic kernel.
        #[cfg(target_arch = "x86_64")]
        unsafe {
            super::tile::tile_fringe_f32(tmp, tmp_ld, alpha, dst, dst_ld, h, w)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (tmp, tmp_ld, alpha, dst, dst_ld, h, w);
            unreachable!("FMA fringe writeback invoked without x86_64");
        }
    }

    unsafe fn dot_panel_dyn(
        isa: VecIsa,
        a: *const f32,
        len: usize,
        cols: &[*const f32],
        unroll: Unroll,
        prefetch: bool,
        out: &mut [f32],
    ) {
        // SAFETY: forwarding the caller's contract verbatim to the
        // selected monomorphic kernel.
        #[cfg(target_arch = "x86_64")]
        unsafe {
            match isa {
                VecIsa::Sse => super::microkernel::sse_dot_panel_dyn(a, len, cols, unroll, prefetch, out),
                VecIsa::Avx2 => super::microkernel::avx2_dot_panel_dyn(a, len, cols, unroll, prefetch, out),
            }
        }
        // SAFETY: same forwarding, scalar fallback.
        #[cfg(not(target_arch = "x86_64"))]
        unsafe {
            let _ = (isa, unroll, prefetch);
            super::microkernel::scalar_dot_panel(a, len, cols, out)
        }
    }

    unsafe fn dot_panel2_dyn(
        a0: *const f32,
        a1: *const f32,
        len: usize,
        cols: &[*const f32],
        unroll: Unroll,
        prefetch: bool,
        out0: &mut [f32],
        out1: &mut [f32],
    ) {
        // SAFETY: forwarding the caller's contract verbatim to the f32
        // two-row kernel.
        #[cfg(target_arch = "x86_64")]
        unsafe {
            super::microkernel::avx2_dot_panel2_dyn(a0, a1, len, cols, unroll, prefetch, out0, out1)
        }
        // SAFETY: same forwarding, one scalar panel per row.
        #[cfg(not(target_arch = "x86_64"))]
        unsafe {
            let _ = (unroll, prefetch);
            super::microkernel::scalar_dot_panel(a0, len, cols, out0);
            super::microkernel::scalar_dot_panel(a1, len, cols, out1);
        }
    }

    unsafe fn dot_panel_strided(
        a: *const f32,
        len: usize,
        cols: &[(*const f32, usize)],
        out: &mut [f32],
    ) {
        // SAFETY: forwarding the caller's contract verbatim (SSE is the
        // x86-64 baseline).
        #[cfg(target_arch = "x86_64")]
        unsafe {
            super::microkernel::sse_dot_panel_strided(a, len, cols, out)
        }
        // SAFETY: same forwarding, scalar fallback.
        #[cfg(not(target_arch = "x86_64"))]
        unsafe {
            super::microkernel::scalar_dot_panel_strided(a, len, cols, out)
        }
    }

    fn comp_gemm(
        params: &BlockParams,
        transa: Transpose,
        transb: Transpose,
        alpha: f32,
        a: MatRef<'_, f32>,
        b: MatRef<'_, f32>,
        beta: f32,
        c: &mut MatMut<'_, f32>,
    ) {
        super::comp::gemm(params, transa, transb, alpha, a, b, beta, c);
    }
}

impl Element for f64 {
    const ID: ElementId = ElementId::F64;
    const TRIPLE_ID: TripleId = TripleId::F64;
    const LANES: usize = 4;
    const TILE_NR: usize = 8;

    #[inline(always)]
    fn from_f64(v: f64) -> f64 {
        v
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline(always)]
    fn mul_add(self, a: f64, b: f64) -> f64 {
        f64::mul_add(self, a, b)
    }

    #[inline(always)]
    fn abs(self) -> f64 {
        f64::abs(self)
    }

    #[inline(always)]
    fn max(self, other: f64) -> f64 {
        f64::max(self, other)
    }

    #[inline(always)]
    fn sqrt(self) -> f64 {
        f64::sqrt(self)
    }

    #[inline(always)]
    fn tanh(self) -> f64 {
        f64::tanh(self)
    }

    #[inline(always)]
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }

    #[inline(always)]
    fn sample(rng: &mut Pcg32, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * rng.f64()
    }

    unsafe fn avx2_tile_dyn(
        mr: usize,
        ap: *const f64,
        bp: *const f64,
        kc: usize,
        alpha: f64,
        dst: *mut f64,
        dst_ld: usize,
        accumulate: bool,
        prefetch: bool,
    ) {
        // SAFETY: forwarding the caller's contract verbatim to the f64
        // monomorphic kernel.
        #[cfg(target_arch = "x86_64")]
        unsafe {
            super::tile::avx2_tile_dyn_f64(mr, ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (mr, ap, bp, kc, alpha, dst, dst_ld, accumulate, prefetch);
            unreachable!("AVX2 tile kernel invoked without x86_64");
        }
    }

    unsafe fn tile_fringe(
        tmp: *const f64,
        tmp_ld: usize,
        alpha: f64,
        dst: *mut f64,
        dst_ld: usize,
        h: usize,
        w: usize,
    ) {
        // SAFETY: forwarding the caller's contract verbatim to the f64
        // monomorphic kernel.
        #[cfg(target_arch = "x86_64")]
        unsafe {
            super::tile::tile_fringe_f64(tmp, tmp_ld, alpha, dst, dst_ld, h, w)
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (tmp, tmp_ld, alpha, dst, dst_ld, h, w);
            unreachable!("FMA fringe writeback invoked without x86_64");
        }
    }

    unsafe fn dot_panel_dyn(
        isa: VecIsa,
        a: *const f64,
        len: usize,
        cols: &[*const f64],
        unroll: Unroll,
        prefetch: bool,
        out: &mut [f64],
    ) {
        // SAFETY: forwarding the caller's contract verbatim to the
        // selected monomorphic kernel.
        #[cfg(target_arch = "x86_64")]
        unsafe {
            match isa {
                // The paper's SSE tier has no f64 instantiation (SSE2's
                // 2-wide f64 lanes are not worth a third kernel family);
                // dispatch never selects it for f64, and a forced call runs
                // the scalar panel — correct, merely unvectorised.
                VecIsa::Sse => super::microkernel::scalar_dot_panel(a, len, cols, out),
                VecIsa::Avx2 => {
                    super::microkernel::avx2_dot_panel_dyn_f64(a, len, cols, unroll, prefetch, out)
                }
            }
        }
        // SAFETY: same forwarding, scalar fallback.
        #[cfg(not(target_arch = "x86_64"))]
        unsafe {
            let _ = (isa, unroll, prefetch);
            super::microkernel::scalar_dot_panel(a, len, cols, out)
        }
    }

    unsafe fn dot_panel2_dyn(
        a0: *const f64,
        a1: *const f64,
        len: usize,
        cols: &[*const f64],
        unroll: Unroll,
        prefetch: bool,
        out0: &mut [f64],
        out1: &mut [f64],
    ) {
        // SAFETY: forwarding the caller's contract verbatim to the f64
        // two-row kernel.
        #[cfg(target_arch = "x86_64")]
        unsafe {
            super::microkernel::avx2_dot_panel2_dyn_f64(a0, a1, len, cols, unroll, prefetch, out0, out1)
        }
        // SAFETY: same forwarding, one scalar panel per row.
        #[cfg(not(target_arch = "x86_64"))]
        unsafe {
            let _ = (unroll, prefetch);
            super::microkernel::scalar_dot_panel(a0, len, cols, out0);
            super::microkernel::scalar_dot_panel(a1, len, cols, out1);
        }
    }

    unsafe fn dot_panel_strided(
        a: *const f64,
        len: usize,
        cols: &[(*const f64, usize)],
        out: &mut [f64],
    ) {
        // SAFETY: forwarding the caller's contract verbatim (the strided
        // f64 path is always scalar).
        unsafe { super::microkernel::scalar_dot_panel_strided(a, len, cols, out) }
    }

    fn comp_gemm(
        params: &BlockParams,
        transa: Transpose,
        transb: Transpose,
        alpha: f64,
        a: MatRef<'_, f64>,
        b: MatRef<'_, f64>,
        beta: f64,
        c: &mut MatMut<'_, f64>,
    ) {
        // f64 *is* the accuracy target of the compensated mode; run the
        // standard dot-tier driver (AVX2 when available).
        let isa = if super::dispatch::detect_avx2() { VecIsa::Avx2 } else { VecIsa::Sse };
        super::simd::gemm_vec(isa, params, transa, transb, alpha, a, b, beta, c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_and_names_roundtrip() {
        assert_eq!(ElementId::from_name("f32"), Some(ElementId::F32));
        assert_eq!(ElementId::from_name("f64"), Some(ElementId::F64));
        assert_eq!(ElementId::from_name("f16"), None);
        assert_eq!(<f32 as Element>::ID.name(), "f32");
        assert_eq!(<f64 as Element>::ID.name(), "f64");
    }

    #[test]
    fn triple_ids_and_names_roundtrip() {
        for id in [TripleId::F32, TripleId::F64, TripleId::QU8I8] {
            assert_eq!(TripleId::from_name(id.name()), Some(id));
        }
        assert_eq!(TripleId::from_name("i8i8i32"), None);
        assert_eq!(<f32 as GemmTriple>::TRIPLE, TripleId::F32);
        assert_eq!(<f64 as GemmTriple>::TRIPLE, TripleId::F64);
        assert_eq!(<Qu8i8 as GemmTriple>::TRIPLE, TripleId::QU8I8);
        // Homogeneous triples round-trip to their element; the quantized
        // triple deliberately has none.
        assert_eq!(TripleId::F32.element(), Some(ElementId::F32));
        assert_eq!(TripleId::F64.element(), Some(ElementId::F64));
        assert_eq!(TripleId::QU8I8.element(), None);
        assert_eq!(ElementId::F32.triple(), TripleId::F32);
        assert_eq!(ElementId::F64.triple(), TripleId::F64);
    }

    #[test]
    fn blanket_madd_is_the_pre_refactor_statement() {
        // The homogeneous blanket impl must compute `acc + l * r` with
        // plain ops — bit-identical to the old oracles' `acc += av * bv`.
        let (acc, l, r) = (0.1f32, 0.3f32, 0.7f32);
        assert_eq!(<f32 as GemmTriple>::madd(acc, l, r).to_bits(), (acc + l * r).to_bits());
        let (acc, l, r) = (0.1f64, 0.3f64, 0.7f64);
        assert_eq!(<f64 as GemmTriple>::madd(acc, l, r).to_bits(), (acc + l * r).to_bits());
    }

    #[test]
    fn qu8i8_madd_widens_and_wraps() {
        // Extremes of the operand ranges widen exactly...
        assert_eq!(Qu8i8::madd(0, 255, 127), 32385);
        assert_eq!(Qu8i8::madd(0, 255, -128), -32640);
        // ...and accumulation is wrapping (exact mod 2³², never a debug
        // overflow panic), hence order-independent.
        assert_eq!(Qu8i8::madd(i32::MAX, 1, 1), i32::MIN);
        let terms: [(u8, i8); 3] = [(255, 127), (200, -128), (7, 11)];
        let fwd = terms.iter().fold(i32::MAX - 10_000, |acc, &(l, r)| Qu8i8::madd(acc, l, r));
        let rev = terms.iter().rev().fold(i32::MAX - 10_000, |acc, &(l, r)| Qu8i8::madd(acc, l, r));
        assert_eq!(fwd, rev);
    }

    #[test]
    fn lane_geometry_is_consistent() {
        // TILE_NR is two 256-bit vectors for both elements, and the 6-row
        // tile's register budget (2·mr accumulators + 2 B streams + 1 A
        // broadcast) fits the 16-register YMM file for both.
        assert_eq!(<f32 as Element>::TILE_NR, 2 * <f32 as Element>::LANES);
        assert_eq!(<f64 as Element>::TILE_NR, 2 * <f64 as Element>::LANES);
        assert!(6 * 2 + 2 + 1 <= 16);
    }

    #[test]
    fn f32_sampling_matches_pcg_f32_range() {
        // The bit-compatibility contract behind every seeded f32 test.
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..64 {
            assert_eq!(<f32 as Element>::sample(&mut a, -1.0, 1.0), b.f32_range(-1.0, 1.0));
        }
    }

    #[test]
    fn f64_sampling_is_in_range_and_deterministic() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..64 {
            let x = <f64 as Element>::sample(&mut a, -2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
            assert_eq!(x, <f64 as Element>::sample(&mut b, -2.0, 3.0));
        }
    }
}
