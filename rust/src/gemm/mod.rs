//! The Emmerald GEMM engine — the paper's contribution.
//!
//! Emmerald's performance comes from two ideas (paper §2–§3):
//!
//! 1. **SIMD register strategy**: the inner loop performs *five dot
//!    products at once*. One SSE register holds four consecutive values of
//!    a row of `A`; it is re-used five times against four-value chunks of
//!    five columns of `B`; five SSE registers accumulate the partial sums
//!    (1 + 2 + 5 = 8 = all the PIII's XMM registers).
//! 2. **Memory hierarchy**: `B` is *re-buffered* — reordered into
//!    column-contiguous panels resident in L1 — while rows of `A` stream
//!    through with prefetch hints; the inner loop is unrolled; an outer
//!    L2-level blocking keeps peak rates for matrices far larger than L2.
//!
//! On modern cores a third idea outranks both: **outer-product register
//! tiling** ([`tile`]). The dot-product strategy holds one row of partial
//! sums and pays a horizontal reduction per `C` element — the right trade
//! for 8 XMM registers. A 16-register AVX2+FMA file instead holds an
//! entire `MR × NR` tile of `C` resident in registers: per k step the
//! kernel broadcasts `MR` values of `A'` against `NR` values of `B'` and
//! issues `MR·NR/8` FMAs, reusing every load `MR` (resp. `NR`) times with
//! zero horizontal sums and one store per `MR·NR·kc` FMAs. Dispatch picks
//! the tile tier on AVX2+FMA hosts for every shape tall enough to fill a
//! tile row (`m ≥ tile_min_m`); the dot-panel kernels remain as the
//! paper-faithful baseline, the gemv-shaped fallback and the
//! `tile_vs_dot` ablation point.
//!
//! Since the kernel-triple refactor the whole ladder is generic over
//! **kernel triples** ([`element::GemmTriple`]): a GEMM is typed by its
//! `(Lhs, Rhs, Out)` element types plus an accumulator. The homogeneous
//! float instantiations — **f32 (SGEMM) and f64 (DGEMM)** — come from a
//! blanket impl over [`element::Element`] (every single-type GEMM is the
//! triple `(T, T, T)`), so the float API and its numerics are exactly
//! what they were before the split. Per element only the micro-kernel
//! instantiation changes (8- vs 4-wide YMM lanes, 6×16 vs 6×8 tiles);
//! blocking, packing, planning, batching and the parallel split are
//! shared generic code, and dispatch keeps per-triple kernel tables and
//! tuned geometries. A compensated-f32 accumulation mode ([`comp`],
//! selected via [`dispatch::Accumulation::CompensatedF32`]) gives f32
//! storage with ~f64 dot-product accuracy.
//!
//! The first heterogeneous triple is the **quantized inference tier**
//! ([`quant`], triple [`element::Qu8i8`] = `u8 × i8 → i32`): exact
//! integer GEMM on an AVX2 `maddubs` tile, with a fused
//! [`epilogue::Requant`] writeback (zero-point correction + per-channel
//! scales + bias + activation) dequantizing straight to f32. Integer
//! accumulation is wrapping — associative — so serial, parallel and
//! prepacked runs agree *bitwise* by construction.
//!
//! Modules:
//!
//! * [`element`] — the sealed scalar/element hierarchy and the kernel
//!   -triple model: [`element::Scalar`] (storage types, incl. u8/i8/i32),
//!   [`element::Element`] (full homogeneous GEMM: f32, f64) and
//!   [`element::GemmTriple`] (the `(Lhs, Rhs, Out, Acc)` kernel typing).
//! * [`params`] — block geometry + optimisation toggles (every §3 technique
//!   can be switched off individually for the ablation benches).
//! * [`naive`] — the paper's naive 3-loop comparator.
//! * [`pack`] — re-buffering: panel-major packing of `B`, row packing of
//!   `A`, plus the tile tier's MR-strip / NR-panel k-major layouts.
//! * [`microkernel`] — the SSE dot-product micro-kernels (`nr` = 1..=8) and
//!   their scalar + AVX2 counterparts.
//! * [`blocked`] — the ATLAS proxy: identical blocking, *scalar* kernel.
//! * [`simd`] — the Emmerald driver (SSE).
//! * [`avx2`] — the Emmerald driver re-tuned for AVX2 + FMA (extension).
//! * [`tile`] — the outer-product register-tiled tier (AVX2+FMA 6×16
//!   micro-kernel with C-resident accumulation; scalar reference tile).
//! * [`dispatch`] — the kernel registry: runtime CPU-feature detection and
//!   shape-based selection over every backend (including [`parallel`] and
//!   [`fastmm`]).
//! * [`fastmm`] — the parallel fast-matmul family: ⟨m,k,n⟩ base-case
//!   factorizations (Strassen–Winograd ⟨2,2,2⟩:7, Laderman ⟨3,3,3⟩:23,
//!   the ⟨4,2,4⟩:28 tensor composition)
//!   recursing over strided views with DFS/BFS hybrid scheduling on the
//!   shared pool, element-generic and deterministic, with per-shape
//!   autotuned algorithm/crossover selection.
//! * [`batch`] — batched GEMM over strided tensor slabs, amortising
//!   packing and thread spawn across the batch.
//! * [`plan`] — the production entry point: [`plan::GemmContext`] (kernel
//!   registry + shared worker-thread budget + autotune state) builds
//!   [`plan::GemmPlan`]s that resolve kernel/geometry/split once and
//!   execute many times, with [`plan::PackedA`]/[`plan::PackedB`]
//!   prepacked-operand handles for weight-stationary workloads.
//! * [`epilogue`] — fused epilogues ([`epilogue::Epilogue`]: bias +
//!   activation + clamp) applied inside the kernels' C writeback — one
//!   traversal of `C` instead of two or three, bitwise identical across
//!   the serial, parallel and prepacked drivers. Attach via
//!   `GemmBuilder::epilogue`. Also home of the quantized tier's
//!   [`epilogue::Requant`] writeback stage.
//! * [`quant`] — the quantized inference tier: `u8 × i8 → i32` packing,
//!   the AVX2 `maddubs` drivers and their safe scalar fallbacks.

pub mod avx2;
pub mod batch;
pub mod blocked;
pub mod comp;
pub mod dispatch;
pub mod element;
pub mod epilogue;
pub mod parallel;
pub mod plan;
pub mod fastmm;
pub mod quant;
pub mod microkernel;
pub mod naive;
pub mod pack;
pub mod params;
pub mod simd;
pub mod tile;

pub use batch::{gemm_batch, qgemm_batch, BatchStrides};
pub use dispatch::{registry, registry_for, Accumulation, DispatchConfig, GemmDispatch, KernelId, KernelInfo};
pub use element::{Element, ElementId, GemmTriple, Qu8i8, Scalar, TripleId};
pub use fastmm::{FastAlgoId, FastmmChoice, FastmmTable, ShapeClass};
pub use epilogue::{Activation, Bias, Epilogue, Requant};
pub use params::{BlockParams, TileParams, Unroll};
pub use plan::{GemmBuilder, GemmContext, GemmPlan, PackedA, PackedB};
pub use quant::{qgemm, qgemm_requant, QPackedB};

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared helpers for the GEMM test-suite: every backend is validated
    //! against [`naive`] on a grid of shapes, strides and transposes.

    use crate::blas::{MatMut, MatRef, Matrix, Transpose};
    use crate::util::testkit::assert_allclose;

    /// Type of a full GEMM implementation under test.
    pub type GemmFn = dyn Fn(Transpose, Transpose, f32, MatRef<'_>, MatRef<'_>, f32, &mut MatMut<'_>);

    /// Check `imp` against the naive oracle for one configuration.
    #[allow(clippy::too_many_arguments)]
    pub fn check_one(
        imp: &GemmFn,
        what: &str,
        transa: Transpose,
        transb: Transpose,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        beta: f32,
        seed: u64,
    ) {
        let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
        let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
        // Strided storage shakes out indexing bugs that contiguous hides.
        let a = Matrix::random_strided(ar, ac.max(1), ac.max(1) + 3, seed);
        let b = Matrix::random_strided(br, bc.max(1), bc.max(1) + 1, seed ^ 0xABCD);
        let mut c_ref = Matrix::random_strided(m, n.max(1), n.max(1) + 2, seed ^ 0x1234);
        let mut c_got = c_ref.clone();

        super::naive::gemm(transa, transb, alpha, a.view(), b.view(), beta, &mut c_ref.view_mut());
        imp(transa, transb, alpha, a.view(), b.view(), beta, &mut c_got.view_mut());

        let label = format!("{what} m={m} n={n} k={k} ta={transa:?} tb={transb:?} α={alpha} β={beta}");
        assert_allclose(c_got.data(), c_ref.data(), 2e-4, 1e-5, &label);
    }

    /// Type of a full f64 GEMM implementation under test.
    pub type GemmFn64 =
        dyn Fn(Transpose, Transpose, f64, MatRef<'_, f64>, MatRef<'_, f64>, f64, &mut MatMut<'_, f64>);

    /// Check `imp` against the f64 naive oracle for one configuration.
    #[allow(clippy::too_many_arguments)]
    pub fn check_one_f64(
        imp: &GemmFn64,
        what: &str,
        transa: Transpose,
        transb: Transpose,
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        beta: f64,
        seed: u64,
    ) {
        let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
        let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
        let a = Matrix::<f64>::random_strided(ar, ac.max(1), ac.max(1) + 3, seed);
        let b = Matrix::<f64>::random_strided(br, bc.max(1), bc.max(1) + 1, seed ^ 0xABCD);
        let mut c_ref = Matrix::<f64>::random_strided(m, n.max(1), n.max(1) + 2, seed ^ 0x1234);
        let mut c_got = c_ref.clone();

        super::naive::gemm(transa, transb, alpha, a.view(), b.view(), beta, &mut c_ref.view_mut());
        imp(transa, transb, alpha, a.view(), b.view(), beta, &mut c_got.view_mut());

        let label = format!("{what} m={m} n={n} k={k} ta={transa:?} tb={transb:?} α={alpha} β={beta}");
        crate::util::testkit::assert_allclose_f64(c_got.data(), c_ref.data(), 1e-12, 1e-13, &label);
    }

    /// The f64 twin of [`check_grid`] — same shapes, the f64 oracle.
    pub fn check_grid_f64(imp: &GemmFn64, what: &str) {
        let shapes = [
            (1, 1, 1),
            (1, 5, 4),
            (2, 3, 1),
            (4, 5, 8),
            (5, 5, 5),
            (7, 11, 13),
            (8, 8, 8),
            (16, 16, 16),
            (17, 19, 23),
            (32, 32, 32),
            (33, 17, 65),
            (64, 64, 64),
            (1, 64, 64),
            (64, 1, 64),
            (64, 64, 1),
        ];
        for (ta, tb) in [
            (Transpose::No, Transpose::No),
            (Transpose::Yes, Transpose::No),
            (Transpose::No, Transpose::Yes),
            (Transpose::Yes, Transpose::Yes),
        ] {
            for &(m, n, k) in &shapes {
                for &(alpha, beta) in &[(1.0, 0.0), (0.5, 1.5), (0.0, 0.5)] {
                    check_one_f64(imp, what, ta, tb, m, n, k, alpha, beta, 0xD6E * (m + n + k) as u64);
                }
            }
        }
    }

    /// Standard grid used by each backend's test module.
    pub fn check_grid(imp: &GemmFn, what: &str) {
        let shapes = [
            (1, 1, 1),
            (1, 5, 4),
            (2, 3, 1),
            (4, 5, 8),
            (5, 5, 5),
            (7, 11, 13),
            (8, 10, 16),
            (16, 16, 16),
            (17, 19, 23),
            (32, 6, 40),
            (3, 64, 7),
            (33, 34, 35),
            (64, 64, 64),
            (5, 1, 9),
        ];
        let mut seed = 0x5EED;
        for &(m, n, k) in &shapes {
            for transa in [Transpose::No, Transpose::Yes] {
                for transb in [Transpose::No, Transpose::Yes] {
                    for &(alpha, beta) in &[(1.0, 0.0), (0.5, 2.0), (-1.0, 1.0), (0.0, 0.5)] {
                        check_one(imp, what, transa, transb, m, n, k, alpha, beta, seed);
                        seed += 1;
                    }
                }
            }
        }
    }
}
