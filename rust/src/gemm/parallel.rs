//! Thread-parallel GEMM driver.
//!
//! The paper scaled across nodes (196 PIIIs, one process per CPU);
//! the modern single-box analogue is thread parallelism over slices of
//! `C`. The tier is **layout-complete**: every transa/transb combination
//! parallelises, because each worker runs the same Emmerald driver on its
//! slice and that driver packs its own transposed panels — pack-on-split,
//! the composition Benson & Ballard showed beats bolting threads onto an
//! unpacked sweep. Two split axes:
//!
//! * **Row split** (the default when `op(A)` has at least one row per
//!   worker): each worker takes an `m/t`-row horizontal slice of `C` and
//!   the matching rows of `op(A)`; `B` is shared read-only.
//! * **Column split** (skinny row spaces — `m == 1`, or fewer rows than
//!   workers with a wider column space): each worker takes an `n/t`-column
//!   vertical slice of `C` and the matching columns of `op(B)`; `A` is
//!   shared read-only.
//!
//! Slices write disjoint elements of `C` ([`crate::blas::MatMut`]'s
//! raw-pointer representation makes the interleaved column split
//! expressible), so no synchronisation is needed beyond the final join.
//! [`split_axis`] is the single source of the split policy — the prepacked
//! planned paths ([`crate::gemm::plan::GemmPlan::run_packed_b`] /
//! [`crate::gemm::plan::GemmPlan::run_packed`]) choose their axis through
//! it too. Results are bit-identical to the serial driver for any split:
//! each `C` element's dot products accumulate in the same order whichever
//! slice it lands in.
//!
//! Execution happens on the shared [`crate::gemm::plan::GemmContext`]
//! worker pool (fork-join with the caller participating), so the parallel
//! tier draws from the single process-wide thread budget instead of
//! spawning and joining its own threads per call. Pure beta-scales
//! (`alpha == 0` or `k == 0`) sweep `C` over the same pool.

use crate::blas::{BlasError, MatMut, MatRef, Transpose};
use crate::gemm::element::{Element, Scalar};
use crate::gemm::epilogue::Epilogue;
use crate::gemm::params::TileParams;
use crate::gemm::simd::{gemm_vec, gemm_vec_ep, VecIsa};
use crate::gemm::tile::EpRef;
use crate::gemm::{tile, BlockParams};
use crate::util::threadpool::{run_borrowed_on, ThreadPool};

/// The serial kernel (with its frozen geometry) each parallel slice runs:
/// a dot-panel Emmerald driver, the outer-product tile driver, or the
/// compensated-f32 accumulation driver.
/// [`crate::gemm::dispatch::GemmDispatch::serial_vec_kernel`] is the one
/// place that decides which; slices only execute it. The variants carry
/// plain geometry (no element type): the same value drives any
/// [`Element`] through [`run`](Self::run).
#[derive(Clone, Copy, Debug)]
pub(crate) enum SerialVecKernel {
    /// The paper's dot-product drivers (SSE or AVX2).
    Dot(VecIsa, BlockParams),
    /// The outer-product register-tiled tier.
    Tile(TileParams),
    /// The compensated-accumulation driver (two-term Kahan/Dekker; f32's
    /// [`Element::comp_gemm`] — f64 slices run the standard dot driver).
    Comp(BlockParams),
}

impl SerialVecKernel {
    /// Run one slice through the kernel's serial driver.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run<T: Element>(
        &self,
        transa: Transpose,
        transb: Transpose,
        alpha: T,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        beta: T,
        c: &mut MatMut<'_, T>,
    ) {
        match self {
            SerialVecKernel::Dot(isa, p) => gemm_vec(*isa, p, transa, transb, alpha, a, b, beta, c),
            SerialVecKernel::Tile(p) => tile::gemm(p, transa, transb, alpha, a, b, beta, c),
            SerialVecKernel::Comp(p) => T::comp_gemm(p, transa, transb, alpha, a, b, beta, c),
        }
    }

    /// As [`run`](Self::run), with a fused epilogue carrying the slice's
    /// global `(row, col)` offsets. The dot and tile drivers fuse it into
    /// their writeback; the compensated driver (whose writeback lives
    /// behind [`Element::comp_gemm`]) applies it as a post-pass over the
    /// slice — bitwise identical, since both orders apply the same scalar
    /// function to the same accumulated value.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_ep<T: Element>(
        &self,
        transa: Transpose,
        transb: Transpose,
        alpha: T,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        beta: T,
        c: &mut MatMut<'_, T>,
        ep: EpRef<'_, T>,
    ) {
        match self {
            SerialVecKernel::Dot(isa, p) => {
                gemm_vec_ep(*isa, p, transa, transb, alpha, a, b, beta, c, ep)
            }
            SerialVecKernel::Tile(p) => tile::gemm_ep(p, transa, transb, alpha, a, b, beta, c, ep),
            SerialVecKernel::Comp(p) => {
                T::comp_gemm(p, transa, transb, alpha, a, b, beta, c);
                if let Some((e, ro, co)) = ep {
                    e.apply(c, ro, co);
                }
            }
        }
    }

    /// Row-split granule: tile slices start on MR-strip boundaries so
    /// interior slices carry no padded fringe strips. (Any alignment is
    /// *correct* — per-element accumulation order is pure k order and
    /// fringe writeback rounds identically — this is a locality choice.)
    fn row_align(&self) -> usize {
        match self {
            SerialVecKernel::Dot(..) | SerialVecKernel::Comp(..) => 1,
            SerialVecKernel::Tile(p) => p.mr,
        }
    }

    /// Column-split granule (NR panels for the tile tier, see
    /// [`row_align`](Self::row_align)).
    fn col_align(&self) -> usize {
        match self {
            SerialVecKernel::Dot(..) | SerialVecKernel::Comp(..) => 1,
            SerialVecKernel::Tile(p) => p.nr,
        }
    }
}

/// Which axis of `C` the parallel tier splits, and into how many slices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Split {
    /// No exploitable parallelism (one thread, or a 1×1 output).
    Serial,
    /// Horizontal slices: rows of `C` + matching rows of `op(A)`.
    Rows(usize),
    /// Vertical slices: columns of `C` + matching columns of `op(B)`.
    Cols(usize),
}

/// The parallel tier's split policy — the single decision point shared by
/// the packing driver ([`gemm_parallel_vec`]) and the prepacked planned
/// paths, so every parallel execution of one problem slices the same way.
///
/// Rows win whenever they can feed every worker (better locality: `B`
/// panels are reused across a worker's whole row slice); skinny row
/// spaces fall over to the column split instead of dropping threads.
pub(crate) fn split_axis(m: usize, n: usize, threads: usize) -> Split {
    let t = threads.max(1);
    if t <= 1 || m.max(n) < 2 {
        return Split::Serial;
    }
    if m >= t {
        return Split::Rows(t);
    }
    if n > m {
        return Split::Cols(t.min(n));
    }
    Split::Rows(t.min(m))
}

/// Split `0..len` into at most `slices` contiguous spans `(start, len)`
/// whose starts are multiples of `align` (the final span absorbs the
/// fringe). `align == 1` reproduces the tier's classic ceil-divide row
/// split; the prepacked paths pass the block granule (`mb` rows / `nr`
/// columns) because a packed block is indivisible.
pub(crate) fn chunk_spans(len: usize, slices: usize, align: usize) -> Vec<(usize, usize)> {
    let align = align.max(1);
    let per = len.div_ceil(slices.max(1)).div_ceil(align) * align;
    let mut out = Vec::new();
    let mut start = 0;
    while start < len {
        let span = per.min(len - start);
        out.push((start, span));
        start += span;
    }
    out
}

/// Split `C` into up to `slices` disjoint row slices (starts aligned to
/// `align`), each paired with its start row.
pub(crate) fn c_row_slices<T: Scalar>(c: MatMut<'_, T>, slices: usize, align: usize) -> Vec<(usize, MatMut<'_, T>)> {
    let m = c.rows();
    let mut out = Vec::new();
    let mut rest = c;
    for (r0, rows) in chunk_spans(m, slices, align) {
        let (top, bottom) = rest.split_rows(rows);
        out.push((r0, top));
        rest = bottom;
    }
    out
}

/// Split `C` into up to `slices` disjoint column slices (starts aligned to
/// `align`), each paired with its start column.
pub(crate) fn c_col_slices<T: Scalar>(c: MatMut<'_, T>, slices: usize, align: usize) -> Vec<(usize, MatMut<'_, T>)> {
    let n = c.cols();
    let mut out = Vec::new();
    let mut rest = c;
    for (c0, cols) in chunk_spans(n, slices, align) {
        let (left, right) = rest.split_cols(cols);
        out.push((c0, left));
        rest = right;
    }
    out
}

/// Rows `r0 .. r0+rows` of `op(A)` as a view of the *stored* matrix
/// (columns of storage when `A` is logically transposed).
fn op_a_rows<'a, T: Scalar>(a: MatRef<'a, T>, transa: Transpose, r0: usize, rows: usize) -> MatRef<'a, T> {
    match transa {
        Transpose::No => a.block(r0, 0, rows, a.cols()),
        Transpose::Yes => a.block(0, r0, a.rows(), rows),
    }
}

/// Columns `c0 .. c0+cols` of `op(B)` as a view of the *stored* matrix
/// (rows of storage when `B` is logically transposed).
fn op_b_cols<'a, T: Scalar>(b: MatRef<'a, T>, transb: Transpose, c0: usize, cols: usize) -> MatRef<'a, T> {
    match transb {
        Transpose::No => b.block(0, c0, b.rows(), cols),
        Transpose::Yes => b.block(c0, 0, cols, b.cols()),
    }
}

/// Row slices of `C` paired with the matching rows of `op(A)` — the
/// row-split work list (shared with
/// [`crate::gemm::plan::GemmPlan::run_packed_b`], which is what keeps the
/// prepacked parallel runs bit-identical to this driver's).
pub(crate) fn row_slices<'a, A: Scalar, T: Scalar>(
    a: MatRef<'a, A>,
    transa: Transpose,
    c: MatMut<'a, T>,
    slices: usize,
    align: usize,
) -> Vec<(usize, MatRef<'a, A>, MatMut<'a, T>)> {
    c_row_slices(c, slices, align)
        .into_iter()
        .map(|(r0, cs)| (r0, op_a_rows(a, transa, r0, cs.rows()), cs))
        .collect()
}

/// Column slices of `C` paired with the matching columns of `op(B)` — the
/// column-split twin of [`row_slices`].
pub(crate) fn col_slices<'a, B: Scalar, T: Scalar>(
    b: MatRef<'a, B>,
    transb: Transpose,
    c: MatMut<'a, T>,
    slices: usize,
    align: usize,
) -> Vec<(usize, MatRef<'a, B>, MatMut<'a, T>)> {
    c_col_slices(c, slices, align)
        .into_iter()
        .map(|(c0, cs)| (c0, op_b_cols(b, transb, c0, cs.cols()), cs))
        .collect()
}

/// `C = alpha · A·B + beta · C` split over up to `threads` slices on the
/// process-wide worker pool (no-transpose convenience wrapper; the
/// dispatch layer routes transposed calls through [`gemm_parallel_vec`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel<T: Element>(
    threads: usize,
    params: &BlockParams,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
) -> Result<(), BlasError> {
    gemm_parallel_vec(
        &SerialVecKernel::Dot(VecIsa::Sse, *params),
        crate::gemm::plan::global_pool(),
        threads,
        Transpose::No,
        Transpose::No,
        alpha,
        a,
        b,
        beta,
        c,
    )
}

/// Kernel-, layout- and pool-parameterised driver: the dispatch layer
/// routes here with the widest serial kernel the host supports (the
/// outer-product tile tier on AVX2+FMA) and with the active context's
/// worker pool, so every slice runs that kernel inside the shared thread
/// budget. All four transa/transb combinations are supported — each
/// slice's serial driver packs its own transposed panels (and strips).
/// `pool: None` degrades to a serial sweep of the slices.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_parallel_vec<T: Element>(
    kern: &SerialVecKernel,
    pool: Option<&ThreadPool>,
    threads: usize,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
) -> Result<(), BlasError> {
    gemm_parallel_vec_ep(kern, pool, threads, transa, transb, alpha, a, b, beta, c, None)
}

/// As [`gemm_parallel_vec`], with an optional fused epilogue. Each slice
/// job forwards the epilogue together with the slice's global row/col
/// offset into C, so bias vectors index the full matrix regardless of
/// how the split landed — results are bitwise identical across thread
/// counts and split axes.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_parallel_vec_ep<T: Element>(
    kern: &SerialVecKernel,
    pool: Option<&ThreadPool>,
    threads: usize,
    transa: Transpose,
    transb: Transpose,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut MatMut<'_, T>,
    ep: Option<&Epilogue<T>>,
) -> Result<(), BlasError> {
    let m = c.rows();
    let n = c.cols();
    // k is read off op(A), so A can only mismatch on m; each check below
    // names the operand/dimension that actually disagreed.
    let k = match transa {
        Transpose::No => a.cols(),
        Transpose::Yes => a.rows(),
    };
    let a_m = match transa {
        Transpose::No => a.rows(),
        Transpose::Yes => a.cols(),
    };
    if a_m != m {
        let expect = match transa {
            Transpose::No => (m, k),
            Transpose::Yes => (k, m),
        };
        return Err(BlasError::ShapeMismatch { what: "A", expect, got: (a.rows(), a.cols()) });
    }
    let (b_k, b_n) = match transb {
        Transpose::No => (b.rows(), b.cols()),
        Transpose::Yes => (b.cols(), b.rows()),
    };
    if b_n != n {
        let expect = match transb {
            Transpose::No => (k, n),
            Transpose::Yes => (n, k),
        };
        return Err(BlasError::ShapeMismatch { what: "B", expect, got: (b.rows(), b.cols()) });
    }
    if b_k != k {
        return Err(BlasError::DimMismatch { m, n, k, other_k: b_k });
    }
    if m == 0 || n == 0 {
        return Ok(());
    }

    let split = split_axis(m, n, threads);

    // Pure beta-scale: no kernel work — sweep C's slices over the pool,
    // still applying the epilogue at each slice's global offset.
    if alpha == T::ZERO || k == 0 {
        match split {
            Split::Serial => {
                c.scale(beta);
                if let Some(e) = ep {
                    e.apply(c, 0, 0);
                }
            }
            Split::Rows(t) | Split::Cols(t) => {
                let by_rows = matches!(split, Split::Rows(_));
                let slices = if by_rows {
                    c_row_slices(c.reborrow(), t, 1)
                } else {
                    c_col_slices(c.reborrow(), t, 1)
                };
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = slices
                    .into_iter()
                    .map(|(o0, mut cs)| {
                        Box::new(move || {
                            cs.scale(beta);
                            if let Some(e) = ep {
                                let (ro, co) = if by_rows { (o0, 0) } else { (0, o0) };
                                e.apply(&mut cs, ro, co);
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                run_borrowed_on(pool, jobs);
            }
        }
        return Ok(());
    }

    match split {
        Split::Serial => kern.run_ep(transa, transb, alpha, a, b, beta, c, ep.map(|e| (e, 0, 0))),
        Split::Rows(t) => {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                row_slices(a, transa, c.reborrow(), t, kern.row_align())
                    .into_iter()
                    .map(|(r0, a_slice, mut c_slice)| {
                        let kern = *kern;
                        Box::new(move || {
                            kern.run_ep(
                                transa,
                                transb,
                                alpha,
                                a_slice,
                                b,
                                beta,
                                &mut c_slice,
                                ep.map(|e| (e, r0, 0)),
                            );
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
            run_borrowed_on(pool, jobs);
        }
        Split::Cols(t) => {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                col_slices(b, transb, c.reborrow(), t, kern.col_align())
                    .into_iter()
                    .map(|(c0, b_slice, mut c_slice)| {
                        let kern = *kern;
                        Box::new(move || {
                            kern.run_ep(
                                transa,
                                transb,
                                alpha,
                                a,
                                b_slice,
                                beta,
                                &mut c_slice,
                                ep.map(|e| (e, 0, c0)),
                            );
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
            run_borrowed_on(pool, jobs);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{Backend, Matrix};
    use crate::util::testkit::assert_allclose;

    fn check(threads: usize, m: usize, n: usize, k: usize) {
        let a = Matrix::random(m, k, 1, -1.0, 1.0);
        let b = Matrix::random(k, n, 2, -1.0, 1.0);
        let mut c = Matrix::from_fn(m, n, |r, c| (r + c) as f32 * 0.01);
        let mut c_ref = c.clone();
        gemm_parallel(
            threads,
            &BlockParams::emmerald_sse(),
            0.5,
            a.view(),
            b.view(),
            1.5,
            &mut c.view_mut(),
        )
        .unwrap();
        crate::blas::sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, 0.5, &a, &b, 1.5, &mut c_ref)
            .unwrap();
        assert_allclose(c.data(), c_ref.data(), 5e-4, 1e-4, &format!("parallel t={threads} {m}x{n}x{k}"));
    }

    /// All four layouts vs the naive oracle, on strided operands.
    fn check_layout(threads: usize, transa: Transpose, transb: Transpose, m: usize, n: usize, k: usize) {
        let (ar, ac) = if transa == Transpose::No { (m, k) } else { (k, m) };
        let (br, bc) = if transb == Transpose::No { (k, n) } else { (n, k) };
        let a = Matrix::random_strided(ar, ac, ac + 3, 7);
        let b = Matrix::random_strided(br, bc, bc + 1, 8);
        let mut c = Matrix::random_strided(m, n, n + 2, 9);
        let mut c_ref = c.clone();
        gemm_parallel_vec(
            &SerialVecKernel::Dot(VecIsa::Sse, BlockParams::emmerald_sse()),
            crate::gemm::plan::global_pool(),
            threads,
            transa,
            transb,
            0.75,
            a.view(),
            b.view(),
            0.5,
            &mut c.view_mut(),
        )
        .unwrap();
        crate::gemm::naive::gemm(transa, transb, 0.75, a.view(), b.view(), 0.5, &mut c_ref.view_mut());
        assert_allclose(
            c.data(),
            c_ref.data(),
            5e-4,
            1e-4,
            &format!("parallel t={threads} {m}x{n}x{k} ta={transa:?} tb={transb:?}"),
        );
    }

    #[test]
    fn matches_serial_various_thread_counts() {
        for threads in [1usize, 2, 3, 4, 7] {
            check(threads, 67, 45, 83);
        }
    }

    #[test]
    fn more_threads_than_rows() {
        check(16, 5, 9, 12);
    }

    #[test]
    fn single_row() {
        // m == 1 takes the column split instead of running serial.
        check(4, 1, 33, 21);
    }

    #[test]
    fn all_layouts_row_and_column_split() {
        for ta in [Transpose::No, Transpose::Yes] {
            for tb in [Transpose::No, Transpose::Yes] {
                check_layout(3, ta, tb, 37, 29, 41); // row split
                check_layout(4, ta, tb, 1, 53, 19); // column split (m == 1)
                check_layout(8, ta, tb, 3, 61, 23); // column split (m < t)
            }
        }
    }

    #[test]
    fn bit_identical_to_serial_driver_for_every_split() {
        // The split-invariance claim the prepacked paths rely on: any
        // row/column split produces exactly the serial driver's bits.
        let p = BlockParams::emmerald_sse();
        for (ta, tb) in [
            (Transpose::No, Transpose::No),
            (Transpose::Yes, Transpose::No),
            (Transpose::No, Transpose::Yes),
            (Transpose::Yes, Transpose::Yes),
        ] {
            for &(m, n, k) in &[(23usize, 17usize, 31usize), (1, 40, 13), (5, 48, 9)] {
                let (ar, ac) = if ta == Transpose::No { (m, k) } else { (k, m) };
                let (br, bc) = if tb == Transpose::No { (k, n) } else { (n, k) };
                let a = Matrix::<f32>::random(ar, ac, 21, -1.0, 1.0);
                let b = Matrix::<f32>::random(br, bc, 22, -1.0, 1.0);
                let c0 = Matrix::<f32>::random(m, n, 23, -1.0, 1.0);
                let mut c_serial = c0.clone();
                gemm_vec(VecIsa::Sse, &p, ta, tb, 0.5, a.view(), b.view(), 1.25, &mut c_serial.view_mut());
                for threads in [2usize, 3, 7] {
                    let mut c_par = c0.clone();
                    gemm_parallel_vec(
                        &SerialVecKernel::Dot(VecIsa::Sse, p),
                        crate::gemm::plan::global_pool(),
                        threads,
                        ta,
                        tb,
                        0.5,
                        a.view(),
                        b.view(),
                        1.25,
                        &mut c_par.view_mut(),
                    )
                    .unwrap();
                    assert_eq!(
                        c_par.data(),
                        c_serial.data(),
                        "split must be bit-identical to serial (t={threads} {m}x{n}x{k} ta={ta:?} tb={tb:?})"
                    );
                }
            }
        }
    }

    #[test]
    fn tile_kernel_bit_identical_to_serial_for_every_split() {
        // The outer-product tier's bit-stability contract: any row or
        // column split (MR/NR-aligned or not — the final slice rarely is)
        // reproduces the serial tile driver's exact bits, because each C
        // element accumulates in pure k order and the fringe writeback
        // rounds identically to the vector writeback. Runs the AVX2 tile
        // on capable hosts and the scalar reference tile elsewhere.
        let p = TileParams { kc: 16, mc: 12, nc: 32, ..TileParams::avx2_6x16() };
        let kern = SerialVecKernel::Tile(p);
        for (ta, tb) in [
            (Transpose::No, Transpose::No),
            (Transpose::Yes, Transpose::No),
            (Transpose::No, Transpose::Yes),
            (Transpose::Yes, Transpose::Yes),
        ] {
            for &(m, n, k) in &[(23usize, 37usize, 31usize), (2, 40, 13), (50, 7, 9)] {
                let (ar, ac) = if ta == Transpose::No { (m, k) } else { (k, m) };
                let (br, bc) = if tb == Transpose::No { (k, n) } else { (n, k) };
                let a = Matrix::<f32>::random(ar, ac, 31, -1.0, 1.0);
                let b = Matrix::<f32>::random(br, bc, 32, -1.0, 1.0);
                let c0 = Matrix::<f32>::random(m, n, 33, -1.0, 1.0);
                let mut c_serial = c0.clone();
                tile::gemm(&p, ta, tb, 0.5, a.view(), b.view(), 1.25, &mut c_serial.view_mut());
                for threads in [2usize, 3, 7] {
                    let mut c_par = c0.clone();
                    gemm_parallel_vec(
                        &kern,
                        crate::gemm::plan::global_pool(),
                        threads,
                        ta,
                        tb,
                        0.5,
                        a.view(),
                        b.view(),
                        1.25,
                        &mut c_par.view_mut(),
                    )
                    .unwrap();
                    assert_eq!(
                        c_par.data(),
                        c_serial.data(),
                        "tile split must be bit-identical to serial (t={threads} {m}x{n}x{k} ta={ta:?} tb={tb:?})"
                    );
                }
            }
        }
    }

    #[test]
    fn split_axis_policy() {
        assert_eq!(split_axis(64, 64, 1), Split::Serial);
        assert_eq!(split_axis(1, 1, 8), Split::Serial);
        assert_eq!(split_axis(64, 64, 4), Split::Rows(4));
        assert_eq!(split_axis(1, 4096, 8), Split::Cols(8));
        assert_eq!(split_axis(3, 512, 8), Split::Cols(8));
        assert_eq!(split_axis(4096, 1, 8), Split::Rows(8));
        assert_eq!(split_axis(3, 2, 8), Split::Rows(3));
        assert_eq!(split_axis(1, 3, 8), Split::Cols(3));
    }

    #[test]
    fn chunk_spans_cover_and_align() {
        assert_eq!(chunk_spans(10, 3, 1), vec![(0, 4), (4, 4), (8, 2)]);
        assert_eq!(chunk_spans(512, 8, 128), vec![(0, 128), (128, 128), (256, 128), (384, 128)]);
        assert_eq!(chunk_spans(300, 4, 128), vec![(0, 128), (128, 128), (256, 44)]);
        assert_eq!(chunk_spans(0, 4, 16), vec![]);
        assert_eq!(chunk_spans(5, 8, 1), vec![(0, 1), (1, 1), (2, 1), (3, 1), (4, 1)]);
    }

    #[test]
    fn pure_beta_scale_runs_parallel_and_respects_padding() {
        // alpha == 0: parallel sweep must scale the logical area only.
        let (m, n, k) = (9usize, 7usize, 5usize);
        let a = Matrix::random(m, k, 1, -1.0, 1.0);
        let b = Matrix::random(k, n, 2, -1.0, 1.0);
        let mut c = Matrix::random_strided(m, n, n + 3, 5);
        let expect = Matrix::from_fn(m, n, |r, j| c.get(r, j) * 2.0);
        gemm_parallel(4, &BlockParams::emmerald_sse(), 0.0, a.view(), b.view(), 2.0, &mut c.view_mut())
            .unwrap();
        for r in 0..m {
            for j in 0..n {
                assert_eq!(c.get(r, j), expect.get(r, j), "scaled value at ({r},{j})");
            }
            for p in n..n + 3 {
                assert_eq!(c.data()[r * (n + 3) + p], -77.0, "padding clobbered at row {r}");
            }
        }
        // k == 0 likewise (empty operands).
        let a0 = Matrix::zeros(m, 0);
        let b0 = Matrix::zeros(0, n);
        let mut c0 = Matrix::from_fn(m, n, |_, _| 3.0);
        gemm_parallel(4, &BlockParams::emmerald_sse(), 1.0, a0.view(), b0.view(), 0.5, &mut c0.view_mut())
            .unwrap();
        assert!(c0.data().iter().all(|&x| x == 1.5));
    }

    #[test]
    fn mismatched_a_rows_reports_a() {
        let a = Matrix::zeros(3, 5); // op(A) rows 3 != m 4
        let b = Matrix::zeros(5, 3);
        let mut c = Matrix::zeros(4, 3);
        let err = gemm_parallel(2, &BlockParams::emmerald_sse(), 1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
        assert!(
            matches!(err, Err(BlasError::ShapeMismatch { what: "A", expect: (4, 5), got: (3, 5) })),
            "{err:?}"
        );
    }

    #[test]
    fn mismatched_b_cols_reports_b() {
        let a = Matrix::zeros(4, 5);
        let b = Matrix::zeros(5, 7); // op(B) cols 7 != n 3
        let mut c = Matrix::zeros(4, 3);
        let err = gemm_parallel(2, &BlockParams::emmerald_sse(), 1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
        assert!(
            matches!(err, Err(BlasError::ShapeMismatch { what: "B", expect: (5, 3), got: (5, 7) })),
            "{err:?}"
        );
    }

    #[test]
    fn mismatched_k_reports_dim_mismatch() {
        let a = Matrix::zeros(4, 5);
        let b = Matrix::zeros(6, 3); // op(B) rows 6 != k 5
        let mut c = Matrix::zeros(4, 3);
        let err = gemm_parallel(2, &BlockParams::emmerald_sse(), 1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
        assert!(
            matches!(err, Err(BlasError::DimMismatch { m: 4, n: 3, k: 5, other_k: 6 })),
            "{err:?}"
        );
    }

    #[test]
    fn strided_c_padding_untouched() {
        let (m, n, k) = (9usize, 7usize, 11usize);
        let a = Matrix::random(m, k, 3, -1.0, 1.0);
        let b = Matrix::random(k, n, 4, -1.0, 1.0);
        let mut c = Matrix::random_strided(m, n, n + 3, 5); // padding = -77 sentinel
        gemm_parallel(3, &BlockParams::emmerald_sse(), 1.0, a.view(), b.view(), 0.0, &mut c.view_mut())
            .unwrap();
        for r in 0..m {
            for p in n..n + 3 {
                assert_eq!(c.data()[r * (n + 3) + p], -77.0, "padding clobbered at row {r}");
            }
        }
    }

    #[test]
    fn strided_c_padding_untouched_column_split() {
        // m == 1 forces the column split; the slices interleave in storage,
        // so a stray write would land in the stride padding.
        let (m, n, k) = (1usize, 29usize, 13usize);
        let a = Matrix::random(m, k, 6, -1.0, 1.0);
        let b = Matrix::random(k, n, 7, -1.0, 1.0);
        let mut c = Matrix::random_strided(m, n, n + 4, 8);
        gemm_parallel(5, &BlockParams::emmerald_sse(), 1.0, a.view(), b.view(), 0.0, &mut c.view_mut())
            .unwrap();
        for p in n..n + 4 {
            assert_eq!(c.data()[p], -77.0, "padding clobbered at col {p}");
        }
    }
}
