//! Thread-parallel GEMM driver.
//!
//! The paper scaled across nodes (196 PIIIs, one process per CPU);
//! the modern single-box analogue is thread parallelism over row blocks
//! of `C`. Each thread runs the same Emmerald driver on an `m/t`-row
//! horizontal slice — slices write disjoint rows of `C`, so no
//! synchronisation is needed beyond the final join. `B` is shared
//! read-only (each thread re-packs its own panels, like each cluster node
//! did).

use crate::blas::{BlasError, MatMut, MatRef, Transpose};
use crate::gemm::simd::{gemm_vec, VecIsa};
use crate::gemm::BlockParams;

/// `C = alpha · A·B + beta · C` over `threads` worker threads
/// (no-transpose operands; the coordinator's training path never needs
/// transposed parallel GEMM — transposes are handled by the serial API).
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel(
    threads: usize,
    params: &BlockParams,
    alpha: f32,
    a: MatRef<'_>,
    b: MatRef<'_>,
    beta: f32,
    c: &mut MatMut<'_>,
) -> Result<(), BlasError> {
    gemm_parallel_vec(VecIsa::Sse, threads, params, alpha, a, b, beta, c)
}

/// ISA-parameterised variant: the dispatch layer routes here with AVX2
/// when the host supports it, so every thread runs the widest kernel.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_parallel_vec(
    isa: VecIsa,
    threads: usize,
    params: &BlockParams,
    alpha: f32,
    a: MatRef<'_>,
    b: MatRef<'_>,
    beta: f32,
    c: &mut MatMut<'_>,
) -> Result<(), BlasError> {
    let m = c.rows();
    let n = c.cols();
    let k = a.cols();
    if a.rows() != m || b.rows() != k || b.cols() != n {
        return Err(BlasError::DimMismatch { m, n, k, other_k: b.rows() });
    }
    let threads = threads.max(1).min(m.max(1));
    if threads == 1 || m < 2 {
        gemm_vec(isa, params, Transpose::No, Transpose::No, alpha, a, b, beta, c);
        return Ok(());
    }

    // Split C (and A) into `threads` disjoint row slices via the safe
    // `MatMut::split_rows` (the matrix analogue of `split_at_mut`).
    let rows_per = m.div_ceil(threads);
    let mut slices: Vec<(usize, MatMut<'_>)> = Vec::with_capacity(threads);
    let mut rest = c.reborrow();
    let mut r0 = 0;
    while r0 < m {
        let rows = rows_per.min(m - r0);
        let (top, bottom) = rest.split_rows(rows);
        slices.push((r0, top));
        rest = bottom;
        r0 += rows;
    }
    std::thread::scope(|scope| {
        for (r0, mut c_slice) in slices {
            let rows = c_slice.rows();
            let a_slice = a.block(r0, 0, rows, k);
            let params = *params;
            scope.spawn(move || {
                gemm_vec(
                    isa,
                    &params,
                    Transpose::No,
                    Transpose::No,
                    alpha,
                    a_slice,
                    b,
                    beta,
                    &mut c_slice,
                );
            });
        }
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{Backend, Matrix};
    use crate::util::testkit::assert_allclose;

    fn check(threads: usize, m: usize, n: usize, k: usize) {
        let a = Matrix::random(m, k, 1, -1.0, 1.0);
        let b = Matrix::random(k, n, 2, -1.0, 1.0);
        let mut c = Matrix::from_fn(m, n, |r, c| (r + c) as f32 * 0.01);
        let mut c_ref = c.clone();
        gemm_parallel(
            threads,
            &BlockParams::emmerald_sse(),
            0.5,
            a.view(),
            b.view(),
            1.5,
            &mut c.view_mut(),
        )
        .unwrap();
        crate::blas::sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, 0.5, &a, &b, 1.5, &mut c_ref)
            .unwrap();
        assert_allclose(c.data(), c_ref.data(), 5e-4, 1e-4, &format!("parallel t={threads} {m}x{n}x{k}"));
    }

    #[test]
    fn matches_serial_various_thread_counts() {
        for threads in [1usize, 2, 3, 4, 7] {
            check(threads, 67, 45, 83);
        }
    }

    #[test]
    fn more_threads_than_rows() {
        check(16, 5, 9, 12);
    }

    #[test]
    fn single_row() {
        check(4, 1, 33, 21);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let a = Matrix::zeros(4, 5);
        let b = Matrix::zeros(6, 3); // k mismatch
        let mut c = Matrix::zeros(4, 3);
        let err = gemm_parallel(
            2,
            &BlockParams::emmerald_sse(),
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut c.view_mut(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn strided_c_padding_untouched() {
        let (m, n, k) = (9usize, 7usize, 11usize);
        let a = Matrix::random(m, k, 3, -1.0, 1.0);
        let b = Matrix::random(k, n, 4, -1.0, 1.0);
        let mut c = Matrix::random_strided(m, n, n + 3, 5); // padding = -77 sentinel
        gemm_parallel(3, &BlockParams::emmerald_sse(), 1.0, a.view(), b.view(), 0.0, &mut c.view_mut())
            .unwrap();
        for r in 0..m {
            for p in n..n + 3 {
                assert_eq!(c.data()[r * (n + 3) + p], -77.0, "padding clobbered at row {r}");
            }
        }
    }
}
