//! Thread-parallel GEMM driver.
//!
//! The paper scaled across nodes (196 PIIIs, one process per CPU);
//! the modern single-box analogue is thread parallelism over row blocks
//! of `C`. Each worker runs the same Emmerald driver on an `m/t`-row
//! horizontal slice — slices write disjoint rows of `C`, so no
//! synchronisation is needed beyond the final join. `B` is shared
//! read-only (each worker re-packs its own panels, like each cluster node
//! did; [`crate::gemm::plan::GemmPlan::run_packed_b`] removes even that).
//!
//! Execution happens on the shared [`crate::gemm::plan::GemmContext`]
//! worker pool (fork-join with the caller participating), so the parallel
//! tier draws from the single process-wide thread budget instead of
//! spawning and joining its own threads per call.

use crate::blas::{BlasError, MatMut, MatRef, Transpose};
use crate::gemm::simd::{gemm_vec, VecIsa};
use crate::gemm::BlockParams;
use crate::util::threadpool::{run_borrowed_on, ThreadPool};

/// `C = alpha · A·B + beta · C` split over up to `threads` row slices on
/// the process-wide worker pool (no-transpose operands; the coordinator's
/// training path never needs transposed parallel GEMM — transposes are
/// handled by the serial API).
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel(
    threads: usize,
    params: &BlockParams,
    alpha: f32,
    a: MatRef<'_>,
    b: MatRef<'_>,
    beta: f32,
    c: &mut MatMut<'_>,
) -> Result<(), BlasError> {
    gemm_parallel_vec(VecIsa::Sse, crate::gemm::plan::global_pool(), threads, params, alpha, a, b, beta, c)
}

/// ISA- and pool-parameterised variant: the dispatch layer routes here
/// with AVX2 when the host supports it and with the active context's
/// worker pool, so every slice runs the widest kernel inside the shared
/// thread budget. `pool: None` degrades to a serial sweep of the slices.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_parallel_vec(
    isa: VecIsa,
    pool: Option<&ThreadPool>,
    threads: usize,
    params: &BlockParams,
    alpha: f32,
    a: MatRef<'_>,
    b: MatRef<'_>,
    beta: f32,
    c: &mut MatMut<'_>,
) -> Result<(), BlasError> {
    let m = c.rows();
    let n = c.cols();
    let k = a.cols();
    if a.rows() != m || b.rows() != k || b.cols() != n {
        return Err(BlasError::DimMismatch { m, n, k, other_k: b.rows() });
    }
    let threads = threads.max(1).min(m.max(1));
    if threads == 1 || m < 2 {
        gemm_vec(isa, params, Transpose::No, Transpose::No, alpha, a, b, beta, c);
        return Ok(());
    }

    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = row_slices(a, c.reborrow(), threads)
        .into_iter()
        .map(|(a_slice, mut c_slice)| {
            let params = *params;
            Box::new(move || {
                gemm_vec(
                    isa,
                    &params,
                    Transpose::No,
                    Transpose::No,
                    alpha,
                    a_slice,
                    b,
                    beta,
                    &mut c_slice,
                );
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    run_borrowed_on(pool, jobs);
    Ok(())
}

/// Split `C` (and the matching row blocks of `A`) into up to `threads`
/// disjoint row slices via the safe `MatMut::split_rows` (the matrix
/// analogue of `split_at_mut`). The single source of the parallel tier's
/// split policy — the prepacked planned path
/// ([`crate::gemm::plan::GemmPlan::run_packed_b`]) slices through here
/// too, which is what keeps its results bit-identical to this driver's.
pub(crate) fn row_slices<'a>(
    a: MatRef<'a>,
    c: MatMut<'a>,
    threads: usize,
) -> Vec<(MatRef<'a>, MatMut<'a>)> {
    let m = c.rows();
    let k = a.cols();
    let rows_per = m.div_ceil(threads.max(1));
    let mut out = Vec::with_capacity(threads);
    let mut rest = c;
    let mut r0 = 0;
    while r0 < m {
        let rows = rows_per.min(m - r0);
        let (top, bottom) = rest.split_rows(rows);
        out.push((a.block(r0, 0, rows, k), top));
        rest = bottom;
        r0 += rows;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{Backend, Matrix};
    use crate::util::testkit::assert_allclose;

    fn check(threads: usize, m: usize, n: usize, k: usize) {
        let a = Matrix::random(m, k, 1, -1.0, 1.0);
        let b = Matrix::random(k, n, 2, -1.0, 1.0);
        let mut c = Matrix::from_fn(m, n, |r, c| (r + c) as f32 * 0.01);
        let mut c_ref = c.clone();
        gemm_parallel(
            threads,
            &BlockParams::emmerald_sse(),
            0.5,
            a.view(),
            b.view(),
            1.5,
            &mut c.view_mut(),
        )
        .unwrap();
        crate::blas::sgemm_matrix(Backend::Naive, Transpose::No, Transpose::No, 0.5, &a, &b, 1.5, &mut c_ref)
            .unwrap();
        assert_allclose(c.data(), c_ref.data(), 5e-4, 1e-4, &format!("parallel t={threads} {m}x{n}x{k}"));
    }

    #[test]
    fn matches_serial_various_thread_counts() {
        for threads in [1usize, 2, 3, 4, 7] {
            check(threads, 67, 45, 83);
        }
    }

    #[test]
    fn more_threads_than_rows() {
        check(16, 5, 9, 12);
    }

    #[test]
    fn single_row() {
        check(4, 1, 33, 21);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let a = Matrix::zeros(4, 5);
        let b = Matrix::zeros(6, 3); // k mismatch
        let mut c = Matrix::zeros(4, 3);
        let err = gemm_parallel(
            2,
            &BlockParams::emmerald_sse(),
            1.0,
            a.view(),
            b.view(),
            0.0,
            &mut c.view_mut(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn strided_c_padding_untouched() {
        let (m, n, k) = (9usize, 7usize, 11usize);
        let a = Matrix::random(m, k, 3, -1.0, 1.0);
        let b = Matrix::random(k, n, 4, -1.0, 1.0);
        let mut c = Matrix::random_strided(m, n, n + 3, 5); // padding = -77 sentinel
        gemm_parallel(3, &BlockParams::emmerald_sse(), 1.0, a.view(), b.view(), 0.0, &mut c.view_mut())
            .unwrap();
        for r in 0..m {
            for p in n..n + 3 {
                assert_eq!(c.data()[r * (n + 3) + p], -77.0, "padding clobbered at row {r}");
            }
        }
    }
}
